"""Hypothesis property tests on the system's invariants."""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    ALPHA,
    Platform,
    PredictorModel,
    best_policy,
    optimize_exact,
    t_extr,
    t_p_opt,
    waste_exact,
    waste_nockpt,
    waste_withckpt,
    waste_young,
)
from repro.checkpoint.codec import decode_array, encode_array
from repro.optim.adamw import _dequantize, _quantize
from repro.optim.compress import ef_compress_step

# parameter spaces: mu in [2h, 100d], C in [10s, 30mn] with C << mu
mus = st.floats(min_value=7200.0, max_value=8.64e6)
cs = st.floats(min_value=10.0, max_value=1800.0)
rs = st.floats(min_value=0.0, max_value=0.99)
ps = st.floats(min_value=0.05, max_value=1.0)
qs = st.floats(min_value=0.0, max_value=1.0)


@settings(max_examples=200, deadline=None)
@given(mu=mus, C=cs, r=rs, p=ps, q=qs)
def test_unified_formula_structure(mu, C, r, p, q):
    t = t_extr(mu, C, r, q)
    assert t >= t_extr(mu, C)  # prediction never shortens the period
    if r * q < 1:
        assert t == pytest.approx(math.sqrt(2 * mu * C / (1 - r * q)))


@settings(max_examples=200, deadline=None)
@given(mu=mus, C=cs, r=rs, p=ps, q=qs)
def test_waste_affine_in_q(mu, C, r, p, q):
    D, R = 60.0, 600.0
    T = max(C * 1.5, math.sqrt(2 * mu * C))
    w0 = waste_exact(T, 0.0, C, D, R, mu, r, p)
    w1 = waste_exact(T, 1.0, C, D, R, mu, r, p)
    wq = waste_exact(T, q, C, D, R, mu, r, p)
    assert wq == pytest.approx(w0 + q * (w1 - w0), rel=1e-9, abs=1e-12)


@settings(max_examples=200, deadline=None)
@given(mu=mus, C=cs, r=rs, p=ps)
def test_optimal_policy_at_least_young(mu, C, r, p):
    """Taking the predictor into account never hurts: the chosen policy's
    waste <= Young's waste at its own optimum."""
    plat = Platform(mu=mu, C=C, D=60.0, R=600.0)
    pol = optimize_exact(plat, PredictorModel(r, p))
    wy = optimize_exact(plat, PredictorModel(0.0, 1.0)).waste
    assert pol.waste <= wy + 1e-12


@settings(max_examples=100, deadline=None)
@given(mu=mus, C=cs, r=st.floats(0.05, 0.99), p=ps)
def test_waste_monotone_in_recall(mu, C, r, p):
    plat = Platform(mu=mu, C=C, D=60.0, R=600.0)
    w_low = optimize_exact(plat, PredictorModel(r * 0.5, p)).waste
    w_high = optimize_exact(plat, PredictorModel(r, p)).waste
    assert w_high <= w_low + 1e-12


@settings(max_examples=100, deadline=None)
@given(C=cs, p=ps, I=st.floats(10.0, 50000.0))
def test_tp_opt_divides_window(C, p, I):
    got = t_p_opt(C, p, I)
    if got is None:
        assert I < C
    else:
        tp, k = got
        assert k >= 1
        assert tp * k == pytest.approx(I, rel=1e-9)


@settings(max_examples=100, deadline=None)
@given(mu=mus, C=cs, r=rs, p=ps, I=st.floats(0.0, 20000.0))
def test_window_wastes_positive_and_floor(mu, C, r, p, I):
    """Window wastes carry at least the regular-mode checkpointing floor
    (1 - I'/mu_P) * C/T (time spent in proactive mode is excused from it),
    and are strictly positive."""
    from repro.core.events import mu_p as _mu_p
    from repro.core.waste import i_prime

    D, R = 60.0, 600.0
    T = max(C * 1.5, math.sqrt(2 * mu * C))
    reg_frac = 1.0
    if r > 0:
        reg_frac = max(0.0, 1.0 - i_prime(1.0, p, I, I / 2) / _mu_p(mu, r, p))
    for w in (
        waste_nockpt(T, 1.0, C, D, R, mu, r, p, I, I / 2),
        waste_withckpt(T, max(C, I or C), 1.0, C, D, R, mu, r, p, I, I / 2),
    ):
        assert w > 0
        assert w >= reg_frac * C / T * (1 - 1e-9)


@settings(max_examples=50, deadline=None)
@given(
    data=st.lists(
        st.floats(-1e3, 1e3, allow_nan=False, width=32), min_size=1, max_size=2000
    )
)
def test_codec_roundtrip_bounded_error(data):
    x = np.asarray(data, np.float32)
    payload, meta = encode_array(x)
    back = decode_array(payload, meta)
    # blockwise absmax int8: error bounded by scale/2 = absmax/254 per block
    flat = np.pad(x.reshape(-1), (0, (-x.size) % 256)).reshape(-1, 256)
    bound = np.abs(flat).max(axis=1) / 127.0
    err = np.abs(back - x).reshape(-1)
    err = np.pad(err, (0, (-x.size) % 256)).reshape(-1, 256)
    assert np.all(err <= bound[:, None] * 0.5 + 1e-7)


@settings(max_examples=50, deadline=None)
@given(
    data=st.lists(st.floats(-10, 10, width=32), min_size=1, max_size=1000),
    scale=st.floats(1e-4, 1e-2),
)
def test_delta_codec_beats_plain(data, scale):
    rng = np.random.default_rng(0)
    prev = np.asarray(data, np.float32)
    cur = prev + rng.standard_normal(prev.shape).astype(np.float32) * scale
    pay_d, meta_d = encode_array(cur, prev)
    pay_p, meta_p = encode_array(cur)
    err_d = np.abs(decode_array(pay_d, meta_d, prev) - cur).max(initial=0.0)
    err_p = np.abs(decode_array(pay_p, meta_p) - cur).max(initial=0.0)
    assert err_d <= err_p + 1e-7


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(10, 4000),
    seed=st.integers(0, 2**31 - 1),
)
def test_moment_quantization_roundtrip(n, seed):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    q, s = _quantize(x)
    back = _dequantize(q, s, (n,))
    assert np.abs(np.asarray(back) - np.asarray(x)).max() <= float(
        np.abs(np.asarray(x)).max()
    ) / 127.0 * 0.51 + 1e-7


def test_error_feedback_compensates():
    """With error feedback, the accumulated applied gradient converges to
    the true accumulated gradient (bounded residual)."""
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    residual = {"g": jnp.zeros(512, jnp.float32)}
    applied = jnp.zeros(512, jnp.float32)
    for _ in range(20):
        out, residual = ef_compress_step({"g": g_true}, residual)
        applied = applied + out["g"]
    total_err = np.abs(np.asarray(applied - 20 * g_true)).max()
    # residual is bounded (single-step quantization error), not accumulating
    single = np.abs(np.asarray(g_true)).max() / 127.0
    assert total_err <= 2 * single + 1e-6
