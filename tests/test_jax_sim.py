"""JAX device engine vs NumPy batch engine vs scalar oracle.

The three engines consume the *same* generated ``BatchTraces`` (trust
filtering is deterministic for q in {0, 1}), so makespans must agree to
float rounding across all five paper strategies + migration, the
exponential / Weibull / lognormal failure laws, and both trust settings.
Also covers the chunked lane scheduler, the ``run_grid(engine="jax")``
dispatch (the per-cell waste acceptance gate), and a hypothesis property
test randomizing platforms, laws, and strategies.
"""

import math

import numpy as np
import pytest

from repro.core import (
    Platform,
    PredictorModel,
    make_event_traces_batch,
    simulate_batch,
)
from repro.core import events as E
from repro.core import simulator as S
from repro.core.jax_sim import simulate_batch_jax
from repro.core.simulator import Strategy, simulate

MN = 60.0
PLAT = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN, M=5 * MN)
WORK = 20 * 86400.0
PREDW = PredictorModel(recall=0.85, precision=0.82, window=3000.0)
PRED = PredictorModel(recall=0.85, precision=0.82)
PRED0 = PredictorModel(0.0, 1.0)

#: scalar-vs-vectorized tolerance (fast-forward float fusion, see
#: tests/test_batch_sim.py); jax-vs-numpy agreement is far tighter
MK_TOL = 1e-3


def _strategies():
    return [
        (S.young(PLAT), PRED0),  # q = 0 baseline
        (S.exact_prediction(PLAT, PRED), PRED),
        (S.instant(PLAT, PREDW), PREDW),
        (S.nockpt(PLAT, PREDW), PREDW),
        (S.withckpt(PLAT, PREDW), PREDW),
        (S.migration(PLAT, PRED), PRED),
        # q = 0 with predictions present in the trace: the trust filter
        # must hide them identically in both vectorized engines
        (Strategy("Distrust", S.young(PLAT).T_R, q=0.0, mode="exact"), PRED),
    ]


def _traces_for(strat, pred, dist, n=4, seed=42):
    rng = np.random.default_rng(seed)
    return make_event_traces_batch(
        rng,
        n,
        horizon=12 * WORK,
        mtbf=PLAT.mu,
        recall=pred.recall if strat.mode != "none" else 0.0,
        precision=pred.precision,
        window=pred.window,
        lead=pred.lead,
        fault_dist=dist,
    )


@pytest.mark.parametrize(
    "dist",
    [E.exponential(), E.weibull(0.7), E.lognormal(1.0)],
    ids=["exp", "weibull0.7", "lognormal"],
)
def test_jax_matches_batch_and_scalar(dist):
    """Three-way equivalence on every strategy: jax-vs-numpy to float
    rounding (identical primitive sequence), both-vs-oracle to MK_TOL."""
    for strat, pred in _strategies():
        traces = _traces_for(strat, pred, dist)
        bj = simulate_batch_jax(WORK, PLAT, strat, traces)
        bn = simulate_batch(WORK, PLAT, strat, traces)
        np.testing.assert_allclose(
            bj.makespan, bn.makespan, rtol=1e-12, atol=1e-6,
            err_msg=f"{strat.name}/{dist.name}",
        )
        np.testing.assert_array_equal(bj.n_faults, bn.n_faults)
        np.testing.assert_array_equal(bj.n_regular_ckpts, bn.n_regular_ckpts)
        np.testing.assert_array_equal(
            bj.n_proactive_ckpts, bn.n_proactive_ckpts
        )
        np.testing.assert_array_equal(bj.n_migrations, bn.n_migrations)
        np.testing.assert_array_equal(bj.trace_exhausted, bn.trace_exhausted)
        for i in range(traces.n_lanes):
            sr = simulate(WORK, PLAT, strat, traces.lane(i))
            assert bj.lane(i).makespan == pytest.approx(
                sr.makespan, abs=MK_TOL
            ), (strat.name, dist.name, i)


def test_chunked_scheduling_matches_unchunked():
    """Chunk boundaries (including a ragged final chunk) are invisible."""
    strat, pred = S.instant(PLAT, PREDW), PREDW
    traces = _traces_for(strat, pred, E.exponential(), n=7, seed=3)
    whole = simulate_batch_jax(WORK, PLAT, strat, traces, chunk=None)
    chunked = simulate_batch_jax(WORK, PLAT, strat, traces, chunk=3)
    np.testing.assert_array_equal(whole.makespan, chunked.makespan)
    np.testing.assert_array_equal(whole.n_faults, chunked.n_faults)


def test_pallas_and_jnp_paths_agree():
    """The Pallas hot step (interpret mode on CPU) and the pure-jnp
    fallback share one body — results must be bit-identical."""
    strat, pred = S.withckpt(PLAT, PREDW), PREDW
    traces = _traces_for(strat, pred, E.weibull(0.7), n=4, seed=11)
    a = simulate_batch_jax(WORK, PLAT, strat, traces, use_pallas=True)
    b = simulate_batch_jax(WORK, PLAT, strat, traces, use_pallas=False)
    np.testing.assert_array_equal(a.makespan, b.makespan)
    np.testing.assert_array_equal(a.n_regular_ckpts, b.n_regular_ckpts)


def test_heterogeneous_lanes_jax():
    """Per-lane platforms/strategies in one device call."""
    plats = [PLAT, Platform(mu=400 * MN, C=5 * MN, D=1 * MN, R=5 * MN)]
    strats = [S.young(plats[0]), S.exact_prediction(plats[1], PRED)]
    rng = np.random.default_rng(11)
    traces = make_event_traces_batch(
        rng, 2, horizon=12 * WORK,
        mtbf=[p.mu for p in plats],
        recall=[0.0, PRED.recall],
        precision=[1.0, PRED.precision],
        window=0.0,
    )
    bj = simulate_batch_jax(WORK, plats, strats, traces)
    bn = simulate_batch(WORK, plats, strats, traces)
    np.testing.assert_allclose(bj.makespan, bn.makespan, rtol=1e-12, atol=1e-6)


def test_run_grid_jax_matches_batch():
    """Acceptance gate: per-cell mean waste of the jax engine agrees with
    the NumPy batch engine to <= 1e-6 (same traces, float-rounding-level
    per-lane agreement)."""
    from repro.experiments import ExperimentCell, GridSpec, run_grid

    cells = []
    for k in range(2):
        plat = Platform(mu=(500 + 500 * k) * MN, C=10 * MN, D=1 * MN, R=10 * MN)
        pred = PredictorModel(0.85, 0.82, window=300.0, lead=3600.0)
        dist = E.exponential() if k % 2 == 0 else E.weibull(0.7)
        for strat in (
            S.young(plat),
            S.exact_prediction(plat, PredictorModel(pred.recall, pred.precision)),
            S.instant(plat, pred),
            S.nockpt(plat, pred),
            S.withckpt(plat, pred),
        ):
            cells.append(
                ExperimentCell(
                    label=f"k{k}/{strat.name}",
                    work=6 * 86400.0,
                    platform=plat,
                    predictor=pred,
                    strategy=strat,
                    fault_dist=dist,
                )
            )
    grid = GridSpec(tuple(cells), n_runs=4, seed=17)
    sj = run_grid(grid, engine="jax")
    sb = run_grid(grid, engine="batch")
    assert sj.engine == "jax"
    for cj, cb in zip(sj.cells, sb.cells):
        assert abs(cj.mean_waste - cb.mean_waste) <= 1e-6, cj.cell.label
        np.testing.assert_allclose(cj.makespan, cb.makespan, rtol=1e-12)


def _n_devices() -> int:
    import jax

    return len(jax.devices())


@pytest.mark.parametrize("devices", [1, 2, 8])
def test_device_count_invariance(devices):
    """Sharded dispatch is invisible: per-lane makespans are *identical*
    (not just close) for any device count, and counters match exactly —
    including a ragged lane count (13) that leaves uneven final shards.

    With a single local device only devices=1 runs; the CI multi-device
    job forces 8 host devices so every count is exercised."""
    if devices > _n_devices():
        pytest.skip(f"needs {devices} devices, have {_n_devices()}")
    strat, pred = S.instant(PLAT, PREDW), PREDW
    traces = _traces_for(strat, pred, E.exponential(), n=13, seed=29)
    ref = simulate_batch_jax(WORK, PLAT, strat, traces, devices=1)
    got = simulate_batch_jax(WORK, PLAT, strat, traces, devices=devices)
    np.testing.assert_array_equal(got.makespan, ref.makespan)
    np.testing.assert_array_equal(got.n_faults, ref.n_faults)
    np.testing.assert_array_equal(got.n_regular_ckpts, ref.n_regular_ckpts)
    np.testing.assert_array_equal(
        got.n_proactive_ckpts, ref.n_proactive_ckpts
    )
    bn = simulate_batch(WORK, PLAT, strat, traces)
    np.testing.assert_allclose(
        got.makespan, bn.makespan, rtol=1e-12, atol=1e-6
    )


def test_mesh_dispatch_matches_devices():
    """mesh= is shorthand for devices= over the mesh's device set."""
    import jax

    mesh = jax.make_mesh((_n_devices(),), ("lanes",))
    strat, pred = S.instant(PLAT, PREDW), PREDW
    traces = _traces_for(strat, pred, E.exponential(), n=5, seed=31)
    ref = simulate_batch_jax(WORK, PLAT, strat, traces, devices=_n_devices())
    got = simulate_batch_jax(WORK, PLAT, strat, traces, mesh=mesh)
    np.testing.assert_array_equal(got.makespan, ref.makespan)


def test_devices_validation():
    strat, pred = S.instant(PLAT, PREDW), PREDW
    traces = _traces_for(strat, pred, E.exponential(), n=2, seed=1)
    with pytest.raises(ValueError, match="device"):
        simulate_batch_jax(WORK, PLAT, strat, traces, devices=4096)
    with pytest.raises(ValueError, match="not both"):
        simulate_batch_jax(WORK, PLAT, strat, traces, devices=1, mesh=object())
    with pytest.raises(ValueError, match="expected 'all'"):
        simulate_batch_jax(WORK, PLAT, strat, traces, devices="most")
    with pytest.raises(ValueError, match="engine"):
        S.simulate_many(
            WORK, PLAT, strat, pred, n_runs=2, engine="batch", devices=1
        )
    from repro.experiments import ExperimentCell, run_cells

    cell = ExperimentCell(
        label="x", work=WORK, platform=PLAT, predictor=pred, strategy=strat
    )
    with pytest.raises(ValueError, match="engine"):
        run_cells([cell], n_runs=2, engine="batch", devices=1)


@pytest.mark.slow
def test_sharded_invariance_subprocess():
    """1/2/8 forced-host-device invariance, guaranteed even on
    single-device hosts (the device count must be fixed before jax
    initializes, hence the subprocess)."""
    import os
    import subprocess
    import sys

    if _n_devices() >= 2:
        pytest.skip("multi-device process: covered in-process above")
    script = os.path.join(
        os.path.dirname(__file__), "_jax_sharded_check.py"
    )
    proc = subprocess.run(
        [sys.executable, script], capture_output=True, text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, (
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    assert "JAX_SHARDED_OK" in proc.stdout


@pytest.mark.slow
def test_persistent_compilation_cache_env(tmp_path):
    """REPRO_JAX_CACHE_DIR populates a persistent compilation cache.

    Subprocess: the cache directory must be configured before the jax
    backend initializes, which has long happened in the test process."""
    import os
    import subprocess
    import sys

    cache = tmp_path / "jax-cache"
    env = dict(os.environ)
    env["REPRO_JAX_CACHE_DIR"] = str(cache)
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    body = (
        "import numpy as np\n"
        "from repro.core import Platform, PredictorModel, "
        "make_event_traces_batch\n"
        "from repro.core import simulator as S\n"
        "from repro.core.jax_sim import simulate_batch_jax\n"
        "plat = Platform(mu=60000.0, C=600.0, D=60.0, R=600.0)\n"
        "pred = PredictorModel(0.0, 1.0)\n"
        "tr = make_event_traces_batch(np.random.default_rng(0), 2, "
        "horizon=1e6, mtbf=plat.mu, recall=0.0, precision=1.0, window=0.0)\n"
        "simulate_batch_jax(86400.0, plat, S.young(plat), tr)\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", body], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    assert cache.is_dir() and any(cache.iterdir()), (
        "persistent compilation cache is empty"
    )


def test_simulate_many_jax_engine():
    res_j = S.simulate_many(
        WORK, PLAT, S.exact_prediction(PLAT, PRED), PRED,
        n_runs=4, seed=3, engine="jax",
    )
    res_b = S.simulate_many(
        WORK, PLAT, S.exact_prediction(PLAT, PRED), PRED,
        n_runs=4, seed=3, engine="batch",
    )
    for j, b in zip(res_j, res_b):
        assert j.makespan == pytest.approx(b.makespan, abs=1e-6)
        assert j.n_faults == b.n_faults


# ---------------------------------------------------------------------- #
# randomized three-way agreement (hypothesis when available, otherwise a
# fixed seed sweep — a bare module-level importorskip would silently skip
# the deterministic equivalence tests above too)
# ---------------------------------------------------------------------- #
_LAWS = {
    "exp": E.exponential(),
    "weibull0.7": E.weibull(0.7),
    "lognormal": E.lognormal(1.0),
}


def _check_three_way(mu_mn, c_mn, law, mode, q, seed):
    """Randomized platform x law x strategy x q in {0,1}: the scalar
    oracle, the NumPy batch engine, and the JAX device engine agree on
    every lane's makespan."""
    plat = Platform(
        mu=mu_mn * MN, C=c_mn * MN, D=1 * MN, R=c_mn * MN, M=3 * MN
    )
    work = 6 * 86400.0
    t_r = max(plat.C * 1.5, math.sqrt(2 * plat.mu * plat.C))
    strat = Strategy("Rand", t_r, q=q, mode=mode,
                     T_P=max(plat.C, 1000.0) if mode == "withckpt" else None)
    rng = np.random.default_rng(seed)
    traces = make_event_traces_batch(
        rng, 2, horizon=12 * work, mtbf=plat.mu,
        recall=0.7 if mode != "none" else 0.0, precision=0.5,
        window=2000.0, fault_dist=_LAWS[law],
    )
    bj = simulate_batch_jax(work, plat, strat, traces)
    bn = simulate_batch(work, plat, strat, traces)
    np.testing.assert_allclose(bj.makespan, bn.makespan, rtol=1e-12, atol=1e-6)
    for i in range(traces.n_lanes):
        sr = simulate(work, plat, strat, traces.lane(i))
        assert bj.lane(i).makespan == pytest.approx(sr.makespan, abs=MK_TOL)
        assert bj.lane(i).n_faults == sr.n_faults


try:
    from hypothesis import given, settings, strategies as st
except ImportError:

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_three_way_makespan_agreement(seed):
        rng = np.random.default_rng(seed)
        _check_three_way(
            mu_mn=float(rng.uniform(400.0, 2000.0)),
            c_mn=float(rng.uniform(3.0, 15.0)),
            law=sorted(_LAWS)[seed % len(_LAWS)],
            mode=["none", "exact", "nockpt", "withckpt", "migration"][
                seed % 5
            ],
            q=float(seed % 2),
            seed=seed * 977,
        )

else:

    @settings(max_examples=10, deadline=None)
    @given(
        mu_mn=st.floats(400.0, 2000.0),
        c_mn=st.floats(3.0, 15.0),
        law=st.sampled_from(sorted(_LAWS)),
        mode=st.sampled_from(
            ["none", "exact", "nockpt", "withckpt", "migration"]
        ),
        q=st.sampled_from([0.0, 1.0]),
        seed=st.integers(0, 10_000),
    )
    def test_three_way_makespan_agreement(mu_mn, c_mn, law, mode, q, seed):
        _check_three_way(mu_mn, c_mn, law, mode, q, seed)


# ---------------------------------------------------------------------- #
# device trace generation (trace_mode="device" / TraceSpec)
# ---------------------------------------------------------------------- #
def _spec_for(strat, pred, dist, n=4, seed=42, window=None):
    return E.make_trace_spec(
        n,
        horizon=12 * WORK,
        mtbf=PLAT.mu,
        recall=pred.recall if strat.mode != "none" else 0.0,
        precision=pred.precision,
        window=pred.window if window is None else window,
        lead=pred.lead,
        fault_dist=dist,
        seed=seed,
    )


@pytest.mark.parametrize(
    "dist",
    [E.exponential(), E.weibull(0.7), E.lognormal(1.0)],
    ids=["exp", "weibull0.7", "lognormal"],
)
def test_device_gen_matches_host_engines_exact(dist):
    """Exact-date predictions (window=0): the device-generated run and
    the NumPy engine on the *materialized* replay of the same counter
    streams agree to float rounding — fault dates are bit-identical and
    the TP merge order coincides, so this pins the whole generation
    pipeline (keys, counters, transforms, trust, migration cancel)."""
    for strat, pred in [
        (S.young(PLAT), PRED0),
        (S.exact_prediction(PLAT, PRED), PRED),
        (S.migration(PLAT, PRED), PRED),
    ]:
        spec = _spec_for(strat, pred, dist, window=0.0)
        bn = simulate_batch(WORK, PLAT, strat, spec.materialize())
        bj = simulate_batch_jax(WORK, PLAT, strat, spec)
        np.testing.assert_allclose(
            bj.makespan, bn.makespan, rtol=1e-12, atol=1e-6,
            err_msg=f"{strat.name}/{dist.name}",
        )
        np.testing.assert_array_equal(bj.n_faults, bn.n_faults)
        np.testing.assert_array_equal(bj.n_regular_ckpts, bn.n_regular_ckpts)
        np.testing.assert_array_equal(
            bj.n_proactive_ckpts, bn.n_proactive_ckpts
        )
        np.testing.assert_array_equal(bj.n_migrations, bn.n_migrations)


def test_device_gen_window_statistical():
    """Prediction windows: the device cursor consumes true positives in
    fault order while the host replay time-sorts them, so individual
    makespans may differ where two windows overlap — but only at the
    episode scale (<< makespan), and the waste means agree tightly."""
    for strat in (S.instant(PLAT, PREDW), S.nockpt(PLAT, PREDW),
                  S.withckpt(PLAT, PREDW)):
        spec = _spec_for(strat, PREDW, E.exponential(), n=6, seed=13)
        bn = simulate_batch(WORK, PLAT, strat, spec.materialize())
        bj = simulate_batch_jax(WORK, PLAT, strat, spec)
        np.testing.assert_allclose(
            bj.makespan, bn.makespan, rtol=5e-3, err_msg=strat.name
        )
        assert abs(bj.waste.mean() - bn.waste.mean()) < 1e-3, strat.name
        np.testing.assert_array_equal(bj.n_faults, bn.n_faults)


def test_device_gen_chunk_invariance():
    """Stream ids travel with the lanes, so chunk boundaries are
    invisible: byte-identical results for any chunk size — including
    with fractional trust (coins are counter-indexed, not sequential)."""
    strat = S.instant(PLAT, PREDW)
    spec = _spec_for(strat, PREDW, E.weibull(0.7), n=7, seed=3)
    whole = simulate_batch_jax(WORK, PLAT, strat, spec, chunk=None)
    for chunk in (2, 3):
        got = simulate_batch_jax(WORK, PLAT, strat, spec, chunk=chunk)
        np.testing.assert_array_equal(whole.makespan, got.makespan)
        np.testing.assert_array_equal(whole.n_faults, got.n_faults)
    frac = Strategy("Frac", strat.T_R, q=0.5, mode="exact")
    f1 = simulate_batch_jax(WORK, PLAT, frac, spec, chunk=None)
    f2 = simulate_batch_jax(WORK, PLAT, frac, spec, chunk=2)
    np.testing.assert_array_equal(f1.makespan, f2.makespan)


@pytest.mark.parametrize("devices", [2, 8])
def test_device_gen_device_count_invariance(devices):
    """Device-generated streams are sharding-invariant: per-lane results
    identical for any device count (the CI multi-device job forces 8
    host devices so both counts run)."""
    if devices > _n_devices():
        pytest.skip(f"needs {devices} devices, have {_n_devices()}")
    strat = S.instant(PLAT, PREDW)
    spec = _spec_for(strat, PREDW, E.exponential(), n=13, seed=29)
    ref = simulate_batch_jax(WORK, PLAT, strat, spec, devices=1)
    got = simulate_batch_jax(WORK, PLAT, strat, spec, devices=devices)
    np.testing.assert_array_equal(got.makespan, ref.makespan)
    np.testing.assert_array_equal(got.n_faults, ref.n_faults)
    np.testing.assert_array_equal(got.n_proactive_ckpts, ref.n_proactive_ckpts)


def test_device_gen_pallas_and_jnp_agree():
    """The fused sampling hot step (Pallas, interpret on CPU) and the
    pure-jnp fallback share one body: identical results."""
    strat = S.withckpt(PLAT, PREDW)
    spec = _spec_for(strat, PREDW, E.weibull(0.7), n=4, seed=11)
    a = simulate_batch_jax(WORK, PLAT, strat, spec, use_pallas=True)
    b = simulate_batch_jax(WORK, PLAT, strat, spec, use_pallas=False)
    np.testing.assert_array_equal(a.makespan, b.makespan)
    np.testing.assert_array_equal(a.n_regular_ckpts, b.n_regular_ckpts)


def test_device_gen_trust_filter():
    """mode='none' / q=0 hide every prediction (identical to a Young
    baseline on the same fault stream); fractional q lands between."""
    spec = _spec_for(S.instant(PLAT, PREDW), PREDW, E.exponential(), n=6,
                     seed=21)
    t_r = S.young(PLAT).T_R
    none = simulate_batch_jax(
        WORK, PLAT, Strategy("Y", t_r, q=0.0, mode="none"), spec
    )
    distrust = simulate_batch_jax(
        WORK, PLAT, Strategy("D", t_r, q=0.0, mode="exact"), spec
    )
    np.testing.assert_array_equal(none.makespan, distrust.makespan)
    trust = simulate_batch_jax(
        WORK, PLAT, Strategy("T", t_r, q=1.0, mode="exact"), spec
    )
    assert not np.array_equal(none.makespan, trust.makespan)


def test_device_gen_take_pairing():
    """Lanes sharing a stream id face identical traces (paired design),
    and take() reorders results consistently."""
    strat = S.exact_prediction(PLAT, PRED)
    spec = _spec_for(strat, PRED, E.exponential(), n=4, seed=8)
    paired = spec.take([2, 2, 0, 1])
    res = simulate_batch_jax(WORK, PLAT, strat, paired)
    base = simulate_batch_jax(WORK, PLAT, strat, spec)
    assert res.makespan[0] == res.makespan[1] == base.makespan[2]
    assert res.makespan[2] == base.makespan[0]


# ---------------------------------------------------------------------- #
# device-RNG statistical fidelity (fixed keys: fully deterministic)
# ---------------------------------------------------------------------- #
def _cdf(dist, mean, x):
    if dist.kind == "exponential":
        return 1.0 - np.exp(-x / mean)
    if dist.kind == "weibull":
        scale = mean / math.gamma(1.0 + 1.0 / dist.param)
        return 1.0 - np.exp(-((x / scale) ** dist.param))
    if dist.kind == "lognormal":
        mu = math.log(mean) - dist.param**2 / 2.0
        z = (np.log(x) - mu) / (dist.param * math.sqrt(2.0))
        return 0.5 * (1.0 + np.vectorize(math.erf)(z))
    if dist.kind == "uniform":
        return np.clip(x / (2.0 * mean), 0.0, 1.0)
    raise ValueError(dist.kind)


@pytest.mark.parametrize(
    "dist",
    [E.exponential(), E.weibull(0.7), E.lognormal(1.0), E.uniform()],
    ids=["exp", "weibull0.7", "lognormal", "uniform"],
)
def test_device_gen_rng_ks_interarrival(dist):
    """KS test: inter-arrival samples drawn through the device sampling
    path match the host Distribution's law (alpha = 0.01; deterministic
    via fixed keys)."""
    from repro.core.jax_sim import device_interarrival_samples

    n, mean = 4000, 6.0e4
    g = device_interarrival_samples(dist, mean, n, seed=123, stream=5)
    assert g.shape == (n,) and (g > 0).all()
    # mean sanity (lognormal sigma=1 has heavy tails: generous bound)
    assert abs(g.mean() / mean - 1.0) < 0.15
    xs = np.sort(g)
    ecdf = np.arange(1, n + 1) / n
    cdf = _cdf(dist, mean, xs)
    d = np.abs(ecdf - cdf).max()
    assert d < 1.63 / math.sqrt(n), f"KS D={d:.4f} for {dist.name}"


def test_device_gen_recall_precision_accounting():
    """Empirical recall/precision of the generated streams match the
    configured (r, p) within CI, and the materialized accounting is
    exact (every prediction is a TP with a matching fault or an FP)."""
    spec = E.make_trace_spec(
        8, horizon=3e7, mtbf=6e4, recall=0.7, precision=0.4, window=300.0,
        seed=31,
    )
    traces = spec.materialize()
    tp = fp = fn = 0
    for i in range(spec.n_lanes):
        tr = traces.lane(i)
        tp += tr.n_true_positive
        fp += tr.n_false_positive
        fn += tr.n_false_negative
        for p in tr.predictions:
            if p.fault_time is not None:
                assert p.t0 <= p.fault_time <= p.t0 + p.window + 1e-9
    assert abs(tp / (tp + fn) - 0.7) < 0.03
    assert abs(tp / (tp + fp) - 0.4) < 0.03


def test_device_gen_simulate_many_and_run_grid():
    """trace_mode='device' plumbing: simulate_many and run_grid accept
    it for every batched engine; the jax (device sampling) and batch
    (host replay of the same streams) paths agree statistically; the
    legacy engine and superposed traces are rejected."""
    from repro.experiments import ExperimentCell, GridSpec, run_grid

    strat = S.exact_prediction(PLAT, PRED)
    rj = S.simulate_many(
        WORK, PLAT, strat, PRED, n_runs=4, seed=3, engine="jax",
        trace_mode="device",
    )
    rb = S.simulate_many(
        WORK, PLAT, strat, PRED, n_runs=4, seed=3, engine="batch",
        trace_mode="device",
    )
    for j, b in zip(rj, rb):
        assert j.makespan == pytest.approx(b.makespan, abs=1e-6)
        assert j.n_faults == b.n_faults

    cells = [
        ExperimentCell(
            label=f"m{k}", work=6 * 86400.0, platform=PLAT,
            predictor=PREDW, strategy=s,
        )
        for k, s in enumerate([S.young(PLAT), S.instant(PLAT, PREDW)])
    ]
    grid = GridSpec(tuple(cells), n_runs=6, seed=5)
    sj = run_grid(grid, engine="jax", trace_mode="device")
    sb = run_grid(grid, engine="batch", trace_mode="device")
    for cj, cb in zip(sj.cells, sb.cells):
        assert abs(cj.mean_waste - cb.mean_waste) < 1e-3, cj.cell.label

    with pytest.raises(ValueError, match="trace_mode"):
        run_grid(grid, engine="legacy", trace_mode="device")
    with pytest.raises(ValueError, match="trace_mode"):
        S.simulate_many(WORK, PLAT, strat, PRED, n_runs=2,
                        trace_mode="nope")
    with pytest.raises(ValueError, match="superposed|n_components"):
        S.simulate_many(WORK, PLAT, strat, PRED, n_runs=2,
                        trace_mode="device", n_components=16)
    with pytest.raises(ValueError, match="kind"):
        E.make_trace_spec(
            2, horizon=1e6, mtbf=6e4, recall=0.5, precision=0.5,
            fault_dist=E.Distribution("custom", lambda r, m, n: r.exponential(m, n)),
        )


def test_device_gen_migration_cancel_slots_dense():
    """Adversarial migration density (M comparable to the fault gaps,
    recall ~1): several migration episodes can pend cancellations
    simultaneously; the 3-slot counter-indexed cancel tracking must
    still bit-match the NumPy engine's per-fault mask at window=0."""
    plat = Platform(mu=100 * MN, C=2 * MN, D=0.5 * MN, R=2 * MN, M=30 * MN)
    work = 4 * 86400.0
    strat = S.migration(plat, PredictorModel(0.95, 0.9))
    for seed in (0, 2, 7, 13):  # seeds that diverged with one slot
        spec = E.make_trace_spec(
            16, horizon=12 * work, mtbf=plat.mu, recall=0.95,
            precision=0.9, window=0.0, seed=seed,
        )
        bn = simulate_batch(work, plat, strat, spec.materialize())
        bj = simulate_batch_jax(work, plat, strat, spec)
        np.testing.assert_allclose(
            bj.makespan, bn.makespan, rtol=1e-12, atol=1e-6,
            err_msg=f"seed {seed}",
        )
        np.testing.assert_array_equal(bj.n_faults, bn.n_faults)
        np.testing.assert_array_equal(bj.n_migrations, bn.n_migrations)


def test_device_gen_empty_spec():
    """A 0-lane TraceSpec round-trips through every engine entry."""
    spec = E.make_trace_spec(
        0, horizon=1e6, mtbf=6e4, recall=0.5, precision=0.5
    )
    assert spec.materialize().n_lanes == 0
    strat = S.young(PLAT)
    assert simulate_batch(WORK, [], [], spec).n_lanes == 0
    assert simulate_batch_jax(WORK, [], [], spec).n_lanes == 0


# ---------------------------------------------------------------------- #
# cell multiplexing (fused experiment sweeps)
# ---------------------------------------------------------------------- #
def _cell_fixture(n_runs=4, seed=7):
    """Three heterogeneous cells (different platforms, strategies,
    predictors — one migration cell) as a cell-indexed TraceSpec plus
    the per-lane expansion reference."""
    plat2 = Platform(mu=500 * MN, C=5 * MN, D=1 * MN, R=5 * MN, M=3 * MN)
    cells_plat = [PLAT, plat2, plat2]
    cells_pred = [PREDW, PRED, PRED]
    strats = [
        S.instant(PLAT, PREDW), S.young(plat2), S.migration(plat2, PRED)
    ]
    cidx = np.repeat(np.arange(3, dtype=np.int32), n_runs)
    spec = E.make_trace_spec(
        3 * n_runs,
        horizon=[12 * WORK] * 3,
        mtbf=[p.mu for p in cells_plat],
        recall=[p.recall for p in cells_pred],
        precision=[p.precision for p in cells_pred],
        window=[p.window for p in cells_pred],
        lead=[p.lead for p in cells_pred],
        seed=seed,
        cell_index=cidx,
    )
    return cells_plat, strats, cidx, spec


def test_cell_index_matches_per_lane_dispatch():
    """The fused cell-table path gathers per-lane parameters on device;
    results are bit-identical to the expanded per-lane call — the
    gather is semantically invisible."""
    cells_plat, strats, cidx, spec = _cell_fixture()
    spec_lane = spec.expand()
    assert spec.n_cells == 3 and spec_lane.cell_index is None
    ref = simulate_batch_jax(
        WORK, [cells_plat[c] for c in cidx], [strats[c] for c in cidx],
        spec_lane,
    )
    got = simulate_batch_jax([WORK] * 3, cells_plat, strats, spec)
    np.testing.assert_array_equal(ref.makespan, got.makespan)
    np.testing.assert_array_equal(ref.n_faults, got.n_faults)
    np.testing.assert_array_equal(ref.n_migrations, got.n_migrations)
    np.testing.assert_array_equal(ref.n_proactive_ckpts, got.n_proactive_ckpts)
    # chunk boundaries cut through cells without changing anything
    for chunk in (5, 7):
        chunked = simulate_batch_jax(
            [WORK] * 3, cells_plat, strats, spec, chunk=chunk
        )
        np.testing.assert_array_equal(ref.makespan, chunked.makespan)


def test_cell_stats_collect_matches_lane_reduction():
    """collect='stats' segment-reduces per-cell moments on device; they
    equal the host-side reduction of the per-lane results."""
    from repro.core.jax_sim import CellSums

    cells_plat, strats, cidx, spec = _cell_fixture()
    ref = simulate_batch_jax([WORK] * 3, cells_plat, strats, spec)
    st = simulate_batch_jax(
        [WORK] * 3, cells_plat, strats, spec, collect="stats"
    )
    assert isinstance(st, CellSums) and st.n_cells == 3
    np.testing.assert_array_equal(st.n, [4, 4, 4])
    for c in range(3):
        sel = cidx == c
        np.testing.assert_allclose(
            st.mean_waste[c], ref.waste[sel].mean(), rtol=1e-12
        )
        np.testing.assert_allclose(
            st.ci95_waste[c],
            1.96 * ref.waste[sel].std(ddof=1) / np.sqrt(sel.sum()),
            rtol=1e-9,
        )
        assert st.n_faults[c] == ref.n_faults[sel].sum()
        assert st.n_migrations[c] == ref.n_migrations[sel].sum()
    # stats collection is chunk-invariant too (sums accumulate across
    # chunk boundaries that cut through cells)
    st2 = simulate_batch_jax(
        [WORK] * 3, cells_plat, strats, spec, collect="stats", chunk=5
    )
    np.testing.assert_allclose(st.waste_sum, st2.waste_sum, rtol=1e-12)
    np.testing.assert_array_equal(st.n, st2.n)


@pytest.mark.parametrize("devices", [2, 8])
def test_cell_index_device_count_invariance(devices):
    """Fused cell tables replicate per device; per-lane results and the
    per-cell segment sums are identical for any device count."""
    if devices > _n_devices():
        pytest.skip(f"needs {devices} devices, have {_n_devices()}")
    cells_plat, strats, cidx, spec = _cell_fixture(n_runs=5)  # ragged shards
    ref = simulate_batch_jax([WORK] * 3, cells_plat, strats, spec, devices=1)
    got = simulate_batch_jax(
        [WORK] * 3, cells_plat, strats, spec, devices=devices
    )
    np.testing.assert_array_equal(ref.makespan, got.makespan)
    st1 = simulate_batch_jax(
        [WORK] * 3, cells_plat, strats, spec, devices=1, collect="stats"
    )
    stn = simulate_batch_jax(
        [WORK] * 3, cells_plat, strats, spec, devices=devices,
        collect="stats",
    )
    np.testing.assert_allclose(st1.waste_sum, stn.waste_sum, rtol=1e-12)
    np.testing.assert_array_equal(st1.n, stn.n)


def test_cell_index_host_traces():
    """cell_index also tables the engine parameters over host-generated
    BatchTraces (events stay per-lane): same results, and stats
    collection works."""
    cells_plat, strats, cidx, spec = _cell_fixture()
    traces = spec.materialize()
    ref = simulate_batch_jax(
        WORK, [cells_plat[c] for c in cidx], [strats[c] for c in cidx],
        traces,
    )
    got = simulate_batch_jax(
        [WORK] * 3, cells_plat, strats, traces, cell_index=cidx
    )
    np.testing.assert_array_equal(ref.makespan, got.makespan)
    st = simulate_batch_jax(
        [WORK] * 3, cells_plat, strats, traces, cell_index=cidx,
        collect="stats",
    )
    for c in range(3):
        np.testing.assert_allclose(
            st.mean_waste[c], ref.waste[cidx == c].mean(), rtol=1e-12
        )


def test_cell_index_validation_errors():
    cells_plat, strats, cidx, spec = _cell_fixture()
    with pytest.raises(ValueError, match="cell_index"):
        simulate_batch_jax(
            [WORK] * 3, cells_plat, strats, spec,
            cell_index=cidx[:5],  # wrong length
        )
    with pytest.raises(ValueError, match="cell-indexed"):
        simulate_batch_jax(
            [WORK] * 3, cells_plat, strats, spec.expand(), cell_index=cidx
        )
    with pytest.raises(ValueError, match="collect"):
        simulate_batch_jax([WORK] * 3, cells_plat, strats, spec,
                           collect="rows")
    with pytest.raises(ValueError, match="cell_index"):
        simulate_batch_jax(
            WORK, PLAT, S.young(PLAT),
            _traces_for(S.young(PLAT), PRED0, E.exponential(), n=2),
            collect="stats",
        )
    bad = np.array(cidx)
    bad[0] = 7  # out of the 3-cell table
    with pytest.raises(ValueError, match="cell_index"):
        simulate_batch_jax(
            [WORK] * 3, cells_plat, strats, spec.materialize(),
            cell_index=bad,
        )
    with pytest.raises(ValueError, match="cell_index"):
        E.make_trace_spec(
            4, horizon=1e6, mtbf=6e4, recall=0.5, precision=0.5,
            cell_index=[0, 1],  # wrong shape
        )


# ---------------------------------------------------------------------- #
# mixed-law cell tables (law-multiplexed device sampling)
# ---------------------------------------------------------------------- #
_MIXED_LAWS = (
    E.exponential(), E.weibull(0.7), E.lognormal(0.5), E.uniform()
)


def _mixed_law_fixture(n_runs=4, seed=19):
    """Four cells on one platform/strategy — one per failure-law family —
    as a single mixed-law cell-indexed spec (the law is a data column)."""
    strat = S.exact_prediction(PLAT, PRED)
    cidx = np.repeat(np.arange(4, dtype=np.int32), n_runs)
    spec = E.make_trace_spec(
        4 * n_runs, horizon=12 * WORK, mtbf=PLAT.mu, recall=PRED.recall,
        precision=PRED.precision, window=0.0, lead=PRED.lead, seed=seed,
        cell_index=cidx, fault_dist=_MIXED_LAWS,
    )
    return strat, cidx, spec


def test_device_gen_mixed_law_cells_match_single_law():
    """Law multiplexing is semantically invisible: every cell of a
    4-law fused dispatch is bit-identical to a single-law run of the
    same streams through the law-indexed sampler, and matches the
    law-*specialized* static sampler exactly for the closed-form laws
    (lognormal to float rounding — XLA fuses its transcendentals
    differently per compilation context)."""
    strat, cidx, spec = _mixed_law_fixture()
    got = simulate_batch_jax([WORK] * 4, [PLAT] * 4, [strat] * 4, spec)
    for c, dist in enumerate(_MIXED_LAWS):
        sel = cidx == c
        ref_spec = E.make_trace_spec(
            int(sel.sum()), horizon=12 * WORK, mtbf=PLAT.mu,
            recall=PRED.recall, precision=PRED.precision, window=0.0,
            lead=PRED.lead, seed=19, stream=np.flatnonzero(sel),
            fault_dist=dist,
        )
        ref_ix = simulate_batch_jax(WORK, PLAT, strat, ref_spec.indexed())
        np.testing.assert_array_equal(
            got.makespan[sel], ref_ix.makespan, err_msg=dist.name
        )
        np.testing.assert_array_equal(got.n_faults[sel], ref_ix.n_faults)
        np.testing.assert_array_equal(
            got.n_proactive_ckpts[sel], ref_ix.n_proactive_ckpts
        )
        ref_st = simulate_batch_jax(WORK, PLAT, strat, ref_spec)
        if dist.kind == "lognormal":
            np.testing.assert_allclose(
                got.makespan[sel], ref_st.makespan, rtol=1e-12,
                err_msg=dist.name,
            )
        else:
            np.testing.assert_array_equal(
                got.makespan[sel], ref_st.makespan, err_msg=dist.name
            )


def test_device_gen_mixed_law_chunk_invariance():
    """Mixed-law lane packing travels with the lanes: chunk boundaries
    cutting through law families change nothing."""
    strat, cidx, spec = _mixed_law_fixture()
    whole = simulate_batch_jax(
        [WORK] * 4, [PLAT] * 4, [strat] * 4, spec, chunk=None
    )
    for chunk in (3, 7):
        got = simulate_batch_jax(
            [WORK] * 4, [PLAT] * 4, [strat] * 4, spec, chunk=chunk
        )
        np.testing.assert_array_equal(whole.makespan, got.makespan)
        np.testing.assert_array_equal(whole.n_faults, got.n_faults)


@pytest.mark.parametrize("devices", [1, 2, 8])
def test_device_gen_mixed_law_stats_invariance(devices):
    """The shard_map segment reduction accumulates per-cell sums in a
    donated replicated buffer (one psum per chunk): mixed-law per-cell
    stats are invariant to chunk size and device count — including
    ragged shards and chunk cuts through law families."""
    if devices > _n_devices():
        pytest.skip(f"needs {devices} devices, have {_n_devices()}")
    strat, cidx, spec = _mixed_law_fixture(n_runs=5)
    args = ([WORK] * 4, [PLAT] * 4, [strat] * 4, spec)
    ref = simulate_batch_jax(*args, collect="stats", devices=1)
    np.testing.assert_array_equal(ref.n, [5, 5, 5, 5])
    for chunk in (None, 7):
        got = simulate_batch_jax(
            *args, collect="stats", devices=devices, chunk=chunk
        )
        np.testing.assert_allclose(got.waste_sum, ref.waste_sum, rtol=1e-12)
        np.testing.assert_array_equal(got.n, ref.n)
        np.testing.assert_array_equal(got.n_faults, ref.n_faults)


def test_device_gen_mixed_law_stats_transfer_guard():
    """collect='stats' never materializes per-lane arrays on the host:
    after executable warmup the whole mixed-law stats call — sharded
    when the process has several devices — runs under
    ``jax.transfer_guard("disallow")``.  Packing and the O(cells) fetch
    are explicit device_put/device_get; nothing transfers implicitly."""
    import jax

    strat, cidx, spec = _mixed_law_fixture()
    args = ([WORK] * 4, [PLAT] * 4, [strat] * 4, spec)
    kw = {"collect": "stats", "devices": _n_devices()}
    ref = simulate_batch_jax(*args, **kw)  # compile outside the guard
    with jax.transfer_guard("disallow"):
        got = simulate_batch_jax(*args, **kw)
    np.testing.assert_array_equal(got.waste_sum, ref.waste_sum)
    np.testing.assert_array_equal(got.n, ref.n)


def _mixed_law_grid(n_runs=4, seed=23):
    from repro.experiments import ExperimentCell, GridSpec

    pred = PredictorModel(0.85, 0.82, window=300.0, lead=3600.0)
    cells = [
        ExperimentCell(
            label=f"{lk}/{strat.name}", work=6 * 86400.0, platform=PLAT,
            predictor=pred, strategy=strat, fault_dist=dist,
        )
        for lk, dist in (("exp", E.exponential()), ("wb", E.weibull(0.7)))
        for strat in (S.young(PLAT), S.instant(PLAT, pred))
    ]
    return GridSpec(tuple(cells), n_runs=n_runs, seed=seed)


def test_device_gen_mixed_law_run_grid_one_dispatch():
    """A mixed-law grid in device trace mode runs as exactly ONE fused
    engine dispatch; its per-cell results are bit-identical to the
    per-family baseline (same law-indexed sampler per family) and to
    per-cell dispatch (static samplers — exact for these laws), and the
    device-reduced stats agree bit-for-bit too."""
    from repro.core import jax_sim
    from repro.experiments import run_grid

    grid = _mixed_law_grid()
    fused = run_grid(grid, engine="jax", trace_mode="device")
    assert jax_sim.LAST_TIMINGS["n_chunks"] == 1
    perfam = run_grid(
        grid, engine="jax", trace_mode="device", dispatch="perfamily"
    )
    percell = run_grid(
        grid, engine="jax", trace_mode="device", dispatch="percell"
    )
    for cf, cp, cc in zip(fused.cells, perfam.cells, percell.cells):
        np.testing.assert_array_equal(
            cf.makespan, cp.makespan, err_msg=cf.cell.label
        )
        np.testing.assert_array_equal(
            cf.makespan, cc.makespan, err_msg=cf.cell.label
        )
        np.testing.assert_array_equal(cf.n_faults, cp.n_faults)
    sf = run_grid(grid, engine="jax", trace_mode="device", collect="stats")
    sp = run_grid(
        grid, engine="jax", trace_mode="device", dispatch="perfamily",
        collect="stats",
    )
    for cf, cp in zip(sf.cells, sp.cells):
        assert cf.mean_waste == cp.mean_waste, cf.cell.label
        assert cf.ci95_waste == cp.ci95_waste, cf.cell.label
    with pytest.raises(ValueError, match="perfamily"):
        run_grid(grid, engine="jax", dispatch="perfamily")


def test_mixed_law_run_grid_host_traces():
    """Host trace mode: a mixed-law grid still fuses into one engine
    dispatch over the per-group event arrays — bit-identical to
    per-cell dispatch and float-rounding-close to the batch engine."""
    from repro.experiments import run_grid

    grid = _mixed_law_grid()
    fused = run_grid(grid, engine="jax")
    percell = run_grid(grid, engine="jax", dispatch="percell")
    batch = run_grid(grid, engine="batch")
    for cf, cc, cb in zip(fused.cells, percell.cells, batch.cells):
        np.testing.assert_array_equal(
            cf.makespan, cc.makespan, err_msg=cf.cell.label
        )
        np.testing.assert_allclose(
            cf.makespan, cb.makespan, rtol=1e-12, atol=1e-6,
            err_msg=cf.cell.label,
        )


def test_run_cache_lru_eviction(monkeypatch):
    """The engine-executable cache is a bounded LRU: hits refresh
    recency and inserts over the cap evict the least recently used
    entry (long-lived advisor services can't grow it unboundedly)."""
    import jax

    from repro.core import jax_sim

    saved = jax_sim._RUN_CACHE.copy()
    jax_sim._RUN_CACHE.clear()
    monkeypatch.setattr(jax_sim, "_RUN_CACHE_MAX", 2)
    try:
        devs = tuple(jax.devices()[:1])
        r0 = jax_sim._get_runner(False, True, 100, 1e-9, False, devs)
        r1 = jax_sim._get_runner(False, True, 101, 1e-9, False, devs)
        assert len(jax_sim._RUN_CACHE) == 2
        # a hit returns the cached executable and refreshes its recency
        assert jax_sim._get_runner(
            False, True, 100, 1e-9, False, devs
        ) is r0
        jax_sim._get_runner(False, True, 102, 1e-9, False, devs)
        assert len(jax_sim._RUN_CACHE) == 2
        # the refreshed entry survived; the stale one was evicted
        assert jax_sim._get_runner(
            False, True, 100, 1e-9, False, devs
        ) is r0
        assert jax_sim._get_runner(
            False, True, 101, 1e-9, False, devs
        ) is not r1
    finally:
        jax_sim._RUN_CACHE.clear()
        jax_sim._RUN_CACHE.update(saved)


def test_best_period_search_jax_matches_batch():
    """engine='jax' brute-forces the period as ONE cell-multiplexed
    collect='stats' dispatch (one cell per candidate): identical traces,
    so the argmin and the winning waste match the batch engine."""
    for base, pred in (
        (S.exact_prediction(PLAT, PRED), PRED),
        (S.young(PLAT), PRED0),
    ):
        tb, wb = S.best_period_search(
            6 * 86400.0, PLAT, base, pred, n_runs=3, seed=5,
            grid=(0.6, 1.0, 1.6),
        )
        tj, wj = S.best_period_search(
            6 * 86400.0, PLAT, base, pred, n_runs=3, seed=5,
            grid=(0.6, 1.0, 1.6), engine="jax",
        )
        assert tj == tb, base.name
        assert wj == pytest.approx(wb, rel=1e-9), base.name


def test_cell_spec_take_and_expand():
    """take() on a cell-indexed spec selects lanes (table untouched);
    expand() is the per-lane reference layout; materialize() routes
    through it."""
    cells_plat, strats, cidx, spec = _cell_fixture()
    sub = spec.take([0, 4, 8, 9])
    assert sub.n_lanes == 4 and sub.n_cells == 3
    np.testing.assert_array_equal(sub.cell_index, [0, 1, 2, 2])
    np.testing.assert_array_equal(sub.stream, spec.stream[[0, 4, 8, 9]])
    full = spec.materialize()
    part = sub.materialize()
    assert part.n_faults[0] == full.n_faults[0]
    nf = int(full.n_faults[0])
    np.testing.assert_array_equal(
        part.fault_times[0, :nf], full.fault_times[0, :nf]
    )
    np.testing.assert_array_equal(
        part.horizon, spec.expand().horizon[[0, 4, 8, 9]]
    )
