"""Closed-form waste-model tests (paper Sections 3-4)."""

import math

import numpy as np
import pytest

from repro.core import (
    ALPHA,
    Platform,
    PredictorModel,
    best_policy,
    mu_e,
    mu_np,
    mu_p,
    nockpt_dominates,
    optimize_exact,
    optimize_migration,
    optimize_nockpt,
    optimize_withckpt,
    t_extr,
    t_one,
    t_p_extr,
    t_p_opt,
    t_young,
    waste_exact,
    waste_instant,
    waste_migration,
    waste_nockpt,
    waste_withckpt,
    waste_young,
)

MN = 60.0
PLAT = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN, M=5 * MN)
PRED = PredictorModel(recall=0.85, precision=0.82, window=300.0)


class TestRateIdentities:
    def test_section_2_3(self):
        mu, r, p = 3.6e5, 0.7, 0.4
        assert mu_np(mu, r) == pytest.approx(mu / (1 - r))
        assert mu_p(mu, r, p) == pytest.approx(p * mu / r)
        assert 1 / mu_e(mu, r, p) == pytest.approx(
            1 / mu_np(mu, r) + 1 / mu_p(mu, r, p)
        )

    def test_degenerate(self):
        assert math.isinf(mu_np(1000.0, 1.0))
        assert math.isinf(mu_p(1000.0, 0.0, 0.5))


class TestUnifiedFormula:
    def test_reduces_to_young(self):
        # r q = 0 -> sqrt(2 mu C) (Young [11])
        assert t_extr(PLAT.mu, PLAT.C) == pytest.approx(
            math.sqrt(2 * PLAT.mu * PLAT.C)
        )
        assert t_extr(PLAT.mu, PLAT.C, 0.9, 0.0) == t_extr(PLAT.mu, PLAT.C)

    def test_prediction_lengthens_period(self):
        t0 = t_extr(PLAT.mu, PLAT.C)
        t1 = t_extr(PLAT.mu, PLAT.C, 0.85, 1.0)
        assert t1 == pytest.approx(t0 / math.sqrt(1 - 0.85))
        assert t1 > t0

    def test_rq_one_diverges(self):
        assert math.isinf(t_extr(PLAT.mu, PLAT.C, 1.0, 1.0))

    def test_extremum_is_zero_of_derivative(self):
        r, q = 0.7, 1.0
        t = t_extr(PLAT.mu, PLAT.C, r, q)
        eps = 1e-3
        w = lambda T: waste_exact(T, q, PLAT.C, PLAT.D, PLAT.R, PLAT.mu, r, 0.4)
        deriv = (w(t + eps) - w(t - eps)) / (2 * eps)
        assert abs(deriv) < 1e-10


class TestWasteEquation1:
    def test_matches_young_at_q0(self):
        for T in [3000.0, 8485.0, 20000.0]:
            assert waste_exact(
                T, 0.0, PLAT.C, PLAT.D, PLAT.R, PLAT.mu, 0.85, 0.82
            ) == pytest.approx(waste_young(T, PLAT.C, PLAT.D, PLAT.R, PLAT.mu))

    def test_convex_in_T(self):
        ts = np.linspace(PLAT.C, ALPHA * PLAT.mu, 200)
        w = np.array(
            [waste_exact(t, 1.0, PLAT.C, PLAT.D, PLAT.R, PLAT.mu, 0.85, 0.82) for t in ts]
        )
        d2 = np.diff(w, 2)
        assert np.all(d2 > -1e-12)

    def test_affine_in_q(self):
        # Section 3.3: waste is affine in q => optimum at q in {0,1}
        T = 9000.0
        w = lambda q: waste_exact(T, q, PLAT.C, PLAT.D, PLAT.R, PLAT.mu, 0.85, 0.82)
        mid = w(0.5)
        assert mid == pytest.approx(0.5 * (w(0.0) + w(1.0)))


class TestOptimalPolicies:
    def test_exact_prefers_prediction_for_good_predictor(self):
        pol = optimize_exact(PLAT, PredictorModel(0.85, 0.82))
        assert pol.q == 1
        assert pol.waste < waste_young(
            t_young(PLAT.mu, PLAT.C), PLAT.C, PLAT.D, PLAT.R, PLAT.mu
        )

    def test_exact_rejects_useless_predictor(self):
        # terrible precision + tiny recall: not worth the extra checkpoints
        pol = optimize_exact(PLAT, PredictorModel(recall=0.05, precision=0.02))
        assert pol.q == 0

    def test_clamping_to_domain(self):
        # enormous C: T_extr < C -> clamp to C
        plat = Platform(mu=5000.0, C=4000.0, D=60.0, R=600.0)
        pol = optimize_exact(plat, PredictorModel(0.0, 1.0))
        assert pol.T_R >= plat.C

    def test_migration_beats_checkpoint_when_M_small(self):
        pm = PredictorModel(0.85, 0.82)
        plat = Platform(mu=PLAT.mu, C=PLAT.C, D=PLAT.D, R=PLAT.R, M=30.0)
        wm = optimize_migration(plat, pm).waste
        wc = optimize_exact(plat, pm).waste
        assert wm < wc


class TestWindowStrategies:
    def test_tp_extr_equation7(self):
        C, p, I = 600.0, 0.82, 3000.0
        E = I / 2
        K = ((1 - p) * I + p * E) / p
        assert t_p_extr(C, p, I, E) == pytest.approx(math.sqrt(K * C))

    def test_tp_opt_integer_partition(self):
        got = t_p_opt(600.0, 0.82, 3000.0)
        assert got is not None
        tp, k = got
        assert k == pytest.approx(3000.0 / tp)
        assert tp >= 600.0

    def test_tp_opt_infeasible_window(self):
        assert t_p_opt(600.0, 0.82, 300.0) is None  # I < C

    def test_equation12_uniform_reduction(self):
        # uniform faults: NoCkptI dominates iff I <= 16 (1 - p/2) C / p
        C, p = 600.0, 0.82
        bound = 16 * (1 - p / 2) * C / p
        assert nockpt_dominates(C, p, bound * 0.99)
        assert not nockpt_dominates(C, p, bound * 1.01)

    def test_instant_equals_exact_when_window_zero(self):
        T = 9000.0
        wi = waste_instant(T, 1.0, PLAT.C, PLAT.D, PLAT.R, PLAT.mu, 0.85, 0.82, 0.0, 0.0)
        we = waste_exact(T, 1.0, PLAT.C, PLAT.D, PLAT.R, PLAT.mu, 0.85, 0.82)
        assert wi == pytest.approx(we)

    def test_nockpt_equals_instant_when_window_zero(self):
        T = 9000.0
        wn = waste_nockpt(T, 1.0, PLAT.C, PLAT.D, PLAT.R, PLAT.mu, 0.85, 0.82, 0.0, 0.0)
        wi = waste_instant(T, 1.0, PLAT.C, PLAT.D, PLAT.R, PLAT.mu, 0.85, 0.82, 0.0, 0.0)
        assert wn == pytest.approx(wi)

    def test_best_policy_prunes_withckpt_under_eq12(self):
        pred = PredictorModel(0.85, 0.82, window=300.0)  # I < C: NoCkptI wins
        pol = best_policy(PLAT, pred)
        assert pol.strategy in ("instant", "nockpt")

    def test_withckpt_viable_for_large_window(self):
        pred = PredictorModel(0.85, 0.82, window=20000.0)
        pol = optimize_withckpt(PLAT, pred)
        if pol.q == 1:
            assert pol.T_P is not None and pol.T_P >= PLAT.C


class TestPaperHeadlines:
    """Quantitative checks against the paper's own claims."""

    def test_prediction_gain_grows_with_scale(self):
        """Tables 1-2 trend: the *execution-time* gain from prediction
        (time = W / (1 - waste)) increases with the number of processors."""
        pred = PredictorModel(0.85, 0.82)
        gains = []
        for mu_mn in [4000, 1000, 250, 125]:
            plat = Platform(mu=mu_mn * MN, C=10 * MN, D=1 * MN, R=10 * MN)
            wy = optimize_exact(plat, PredictorModel(0.0, 1.0)).waste
            wp = optimize_exact(plat, pred).waste
            gains.append(1.0 - (1.0 - wy) / (1.0 - wp))
        assert all(g2 >= g1 - 1e-9 for g1, g2 in zip(gains, gains[1:]))
        assert gains[-1] > 0.2  # paper: tens of percent at 2^19

    def test_recall_matters_more_than_precision(self):
        """Section 5.2: improving recall helps more than precision."""
        base = PredictorModel(recall=0.4, precision=0.4)
        up_r = PredictorModel(recall=0.8, precision=0.4)
        up_p = PredictorModel(recall=0.4, precision=0.8)
        plat = Platform(mu=125 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
        w0 = optimize_exact(plat, base).waste
        wr = optimize_exact(plat, up_r).waste
        wp = optimize_exact(plat, up_p).waste
        assert (w0 - wr) > (w0 - wp)

    def test_even_poor_predictor_helps(self):
        """Section 5: p=0.4, r=0.7 still yields a real execution-time gain
        (the paper's 32% at 2^19 includes the Weibull penalty on Young;
        the exponential-analytic share is smaller but clearly positive)."""
        plat = Platform(mu=125 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
        wy = optimize_exact(plat, PredictorModel(0.0, 1.0)).waste
        wp = optimize_exact(plat, PredictorModel(0.7, 0.4)).waste
        time_gain = 1.0 - (1.0 - wy) / (1.0 - wp)
        assert time_gain > 0.05
