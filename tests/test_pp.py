"""Pipeline parallelism: pipelined == sequential, bubble accounting.

Runs in a subprocess (forced 4 host devices for the stage axis)."""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.parallel.pp import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(4, 16) == pytest.approx(3 / 19)
    assert bubble_fraction(1, 8) == 0.0  # no pipeline, no bubble


SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.parallel.pp import pipeline_apply

    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((4,), ("stage",))
    S, D, MB, NM = 4, 16, 8, 6
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((S, D, D)) * 0.3, jnp.float32)

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    params = {"w": w}
    x = jnp.asarray(rng.standard_normal((NM, MB, D)), jnp.float32)

    # sequential reference
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ w[s])

    out = pipeline_apply(stage_fn, params, x, mesh, axis="stage")
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-5, f"pipeline mismatch {err}"

    # differentiable through the pipeline
    def loss(wq):
        o = pipeline_apply(stage_fn, {"w": wq}, x, mesh, axis="stage")
        return jnp.sum(o * o)

    g = jax.grad(loss)(w)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert float(jnp.max(jnp.abs(g))) > 0
    print("PP_CHECK_OK")
    """
)


@pytest.mark.slow
def test_pipeline_matches_sequential(tmp_path):
    script = tmp_path / "pp_check.py"
    script.write_text(SCRIPT)
    proc = subprocess.run(
        [sys.executable, str(script)],
        capture_output=True,
        text=True,
        timeout=900,
        env={
            **os.environ,
            "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
        },
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "PP_CHECK_OK" in proc.stdout
