"""Benchmark harness CLI + regression-gate unit coverage.

The ``--only`` validation must fail fast (before any benchmark module —
and hence jax — is imported), and the regression gate's comparison logic
is pure, so both are cheap to test."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")


def _run_cli(*args, timeout=120):
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *args],
        capture_output=True, text=True, timeout=timeout, cwd=REPO,
    )


def test_run_only_unknown_name_exits_nonzero():
    proc = _run_cli("--only", "definitely_not_a_benchmark")
    assert proc.returncode != 0
    err = proc.stderr + proc.stdout
    assert "definitely_not_a_benchmark" in err
    assert "waste_curves" in err  # the message lists the valid names


def test_run_only_unknown_name_writes_nothing(tmp_path):
    out = tmp_path / "should_not_exist.json"
    proc = _run_cli("--only", "nope", "--json", str(out))
    assert proc.returncode != 0
    assert not out.exists()


# ---------------------------------------------------------------------- #
# regression-gate comparison logic
# ---------------------------------------------------------------------- #
def _rec(name, **derived):
    return {"name": name, "us_per_call": 1.0, "derived": derived}


def test_compare_passes_on_identical_records():
    from benchmarks.check_regression import compare

    recs = [
        _rec("fig4/a", waste_pred_sim=0.05, waste_pred_capped=0.06),
        _rec("jax_engine/lanes1024", jax_lanes_per_s=20000.0,
             numpy_lanes_per_s=15000.0, max_abs_waste_diff=1e-15),
    ]
    assert compare(recs, recs) == []


def test_compare_flags_analytic_gap_and_drift():
    from benchmarks.check_regression import compare

    base = [_rec("fig4/a", waste_pred_sim=0.05, waste_pred_capped=0.06)]
    gap = [_rec("fig4/a", waste_pred_sim=0.30, waste_pred_capped=0.06)]
    fails = compare(base, gap)
    assert any("analytic-vs-sim" in f for f in fails)
    assert any("drifted" in f for f in fails)
    # small jitter within both tolerances passes
    ok = [_rec("fig4/a", waste_pred_sim=0.055, waste_pred_capped=0.06)]
    assert compare(base, ok) == []


def test_compare_flags_throughput_regression():
    from benchmarks.check_regression import compare

    base = [_rec("jax_engine/lanes1024", jax_lanes_per_s=20000.0)]
    slow = [_rec("jax_engine/lanes1024", jax_lanes_per_s=10000.0)]
    fails = compare(base, slow)
    assert len(fails) == 1 and "regressed" in fails[0]
    assert compare(base, slow, perf_tol=0.0) == []  # gate disabled
    within = [_rec("jax_engine/lanes1024", jax_lanes_per_s=15000.0)]
    assert compare(base, within) == []  # -25% is inside the 30% budget


def test_compare_flags_engine_disagreement():
    from benchmarks.check_regression import compare

    base = [_rec("jax_engine/lanes1024", max_abs_waste_diff=1e-15)]
    bad = [_rec("jax_engine/lanes1024", max_abs_waste_diff=1e-3)]
    fails = compare(base, bad)
    assert len(fails) == 1 and "jax-vs-numpy" in fails[0]


def test_compare_ignores_new_and_removed_names():
    from benchmarks.check_regression import compare

    base = [_rec("old/gone", jax_lanes_per_s=1.0)]
    fresh = [_rec("new/added", jax_lanes_per_s=1.0)]
    assert compare(base, fresh) == []


def test_check_regression_cli_missing_baseline(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         "--baseline-dir", str(tmp_path), "--out-dir",
         str(tmp_path / "fresh")],
        capture_output=True, text=True, timeout=60, cwd=REPO,
    )
    assert proc.returncode != 0
    assert "missing baseline" in proc.stdout


@pytest.mark.slow
def test_check_regression_cli_passes_on_committed_baselines(tmp_path):
    """End-to-end gate run against the repo's committed BENCH_*.json:
    must pass (and write fresh artifact records) on a healthy tree.
    Restricted to the seeded waste_curves module so the test stays fast;
    the CI bench-regression job runs the full gate."""
    out = tmp_path / "fresh"
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         "--baseline-dir", REPO, "--out-dir", str(out),
         "--modules", "waste_curves",
         "--perf-tol", "0"],  # perf floors need comparable hardware
        capture_output=True, text=True, timeout=600, env=env, cwd=REPO,
    )
    assert proc.returncode == 0, (
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    fresh = json.loads((out / "BENCH_sim.waste_curves.json").read_text())
    assert fresh["benchmarks"], "no fresh waste_curves records written"


def test_run_profile_help_and_unknown_name_precedence():
    """--profile parses; --only validation still fails fast before any
    module import even when --profile is passed."""
    proc = _run_cli("--only", "nope", "--profile")
    assert proc.returncode != 0
    assert "nope" in proc.stderr + proc.stdout


def test_compare_flags_device_trace_floor():
    from benchmarks.check_regression import compare

    base = [_rec("jax_engine/device_trace_lanes40960",
                 jax_dev_lanes_per_s=20000.0)]
    fresh = [_rec("jax_engine/device_trace_lanes40960",
                  jax_dev_lanes_per_s=10000.0)]
    fails = compare(base, fresh, perf_tol=0.30)
    assert len(fails) == 1 and "jax_dev_lanes_per_s" in fails[0]
    assert compare(base, fresh, perf_tol=0.0) == []


def test_compare_flags_fused_grid_floor_and_equality():
    """The fused-sweep record is gated on both axes: cells/sec within
    the perf tolerance of the baseline (and the tolerance flags apply),
    and exact fused-vs-percell per-cell agreement."""
    from benchmarks.check_regression import compare

    base = [_rec("jax_engine/fused_grid_cells72",
                 fused_cells_per_s=50.0, fused_vs_percell_max_diff=0.0)]
    slow = [_rec("jax_engine/fused_grid_cells72",
                 fused_cells_per_s=20.0, fused_vs_percell_max_diff=0.0)]
    fails = compare(base, slow, perf_tol=0.30)
    assert len(fails) == 1 and "fused_cells_per_s" in fails[0]
    assert compare(base, slow, perf_tol=0.0) == []  # tolerance flag applies
    assert compare(base, slow, perf_tol=0.70) == []
    split = [_rec("jax_engine/fused_grid_cells72",
                  fused_cells_per_s=50.0, fused_vs_percell_max_diff=1e-4)]
    fails = compare(base, split, perf_tol=0.30)
    assert len(fails) == 1 and "fused-vs-percell" in fails[0]
    assert compare(base, split, agree_tol=1e-3) == []
