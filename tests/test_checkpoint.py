"""Checkpoint substrate: roundtrip, atomic commit, codec, async, buddy,
re-shard restore."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    BuddyMemoryCheckpoint,
    CheckpointStore,
    latest_step,
)


@pytest.fixture
def tree():
    return {
        "params": {
            "w": jnp.arange(24.0, dtype=jnp.float32).reshape(4, 6),
            "b": jnp.ones((2048,), jnp.float32) * 0.25,
        },
        "step": jnp.asarray(7, jnp.int32),
    }


class TestStore:
    def test_roundtrip_raw(self, tmp_path, tree):
        store = CheckpointStore(str(tmp_path), codec="raw")
        store.save(3, tree)
        back = store.restore(3, target=jax.eval_shape(lambda: tree))
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_roundtrip_int8(self, tmp_path, tree):
        store = CheckpointStore(str(tmp_path), codec="int8")
        m = store.save(3, tree)
        assert m["stored_bytes"] < m["raw_bytes"]
        back = store.restore(3, target=jax.eval_shape(lambda: tree))
        np.testing.assert_allclose(
            np.asarray(back["params"]["b"]), 0.25, atol=0.25 / 100
        )
        # small tensors and ints stored raw => exact
        np.testing.assert_array_equal(
            np.asarray(back["step"]), np.asarray(tree["step"])
        )

    def test_delta_codec(self, tmp_path, tree):
        store = CheckpointStore(str(tmp_path), codec="int8_delta")
        store.save(1, tree)
        tree2 = jax.tree.map(
            lambda x: x + 1e-4 if x.dtype == jnp.float32 else x, tree
        )
        store.save(2, tree2, prev_tree=tree)
        back = store.restore(2, target=jax.eval_shape(lambda: tree), prev_tree=tree)
        np.testing.assert_allclose(
            np.asarray(back["params"]["b"]),
            np.asarray(tree2["params"]["b"]),
            atol=1e-6,
        )

    def test_latest_step_ignores_staging(self, tmp_path, tree):
        store = CheckpointStore(str(tmp_path))
        store.save(5, tree)
        os.makedirs(os.path.join(str(tmp_path), "step_000000009.tmp-dead"))
        assert latest_step(str(tmp_path)) == 5

    def test_corruption_detected(self, tmp_path, tree):
        store = CheckpointStore(str(tmp_path))
        store.save(5, tree)
        d = os.path.join(str(tmp_path), "step_000000005")
        victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
        path = os.path.join(d, victim)
        arr = np.load(path)
        arr_view = arr.reshape(-1)
        arr_view[0] += 1.0
        np.save(path, arr)
        with pytest.raises(IOError, match="corruption"):
            store.restore(5, target=jax.eval_shape(lambda: tree))

    def test_gc_keeps_newest(self, tmp_path, tree):
        store = CheckpointStore(str(tmp_path))
        for s in (1, 2, 3, 4):
            store.save(s, tree)
        store.gc(keep=2)
        assert latest_step(str(tmp_path)) == 4
        assert not os.path.exists(os.path.join(str(tmp_path), "step_000000001"))

    def test_manifest_sidecar_written_and_checked(self, tmp_path, tree):
        store = CheckpointStore(str(tmp_path))
        store.save(4, tree)
        d = os.path.join(str(tmp_path), "step_000000004")
        assert os.path.exists(os.path.join(d, "manifest.crc"))
        # rot the manifest bytes: the sidecar catches it before JSON does
        with open(os.path.join(d, "manifest.json"), "a") as f:
            f.write(" ")
        with pytest.raises(IOError, match="manifest corruption"):
            store.restore(4, target=jax.eval_shape(lambda: tree))

    def test_steps_lists_committed_only(self, tmp_path, tree):
        store = CheckpointStore(str(tmp_path))
        for s in (3, 1, 7):
            store.save(s, tree)
        os.makedirs(os.path.join(str(tmp_path), "step_000000009.tmp-dead"))
        assert store.steps() == [1, 3, 7]

    def test_restore_latest_skips_truncated_shard(self, tmp_path, tree):
        """Regression: a shard torn mid-write (power cut after commit of
        a buggy fs, partial copy, ...) must not brick the restore — the
        previous durable checkpoint is the restore point."""
        store = CheckpointStore(str(tmp_path))
        store.save(1, tree)
        store.save(2, tree)
        d = os.path.join(str(tmp_path), "step_000000002")
        victim = sorted(f for f in os.listdir(d) if f.endswith(".npy"))[0]
        with open(os.path.join(d, victim), "r+b") as f:
            f.truncate(10)  # npy magic cut short
        with pytest.warns(RuntimeWarning, match="skipping unusable"):
            got = store.restore_latest(target=jax.eval_shape(lambda: tree))
        assert got is not None
        step, back = got
        assert step == 1
        np.testing.assert_array_equal(
            np.asarray(back["params"]["w"]), np.asarray(tree["params"]["w"])
        )

    def test_restore_latest_skips_crc_mismatch(self, tmp_path, tree):
        store = CheckpointStore(str(tmp_path))
        store.save(1, tree)
        store.save(2, tree)
        d = os.path.join(str(tmp_path), "step_000000002")
        victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
        path = os.path.join(d, victim)
        arr = np.load(path)
        arr.reshape(-1)[0] += 1.0
        np.save(path, arr)
        with pytest.warns(RuntimeWarning):
            got = store.restore_latest(target=jax.eval_shape(lambda: tree))
        assert got is not None and got[0] == 1

    def test_restore_latest_none_when_nothing_survives(self, tmp_path, tree):
        store = CheckpointStore(str(tmp_path))
        assert store.restore_latest() is None  # empty root
        store.save(1, tree)
        d = os.path.join(str(tmp_path), "step_000000001")
        os.remove(os.path.join(d, "manifest.json"))
        with pytest.warns(RuntimeWarning):
            assert store.restore_latest() is None

    def test_restore_latest_prefers_newest_valid(self, tmp_path, tree):
        store = CheckpointStore(str(tmp_path))
        for s in (1, 2, 3):
            store.save(s, tree)
        got = store.restore_latest(target=jax.eval_shape(lambda: tree))
        assert got is not None and got[0] == 3

    def test_reshard_restore(self, tmp_path, tree):
        """Restore with explicit target sharding (single-device here; the
        path exercises device_put with a Sharding, i.e. elastic restore)."""
        store = CheckpointStore(str(tmp_path))
        store.save(1, tree)
        sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        back = store.restore(1, target=jax.eval_shape(lambda: tree), shardings=sharding)
        assert back["params"]["w"].sharding == sharding


class TestAsync:
    def test_durability_and_metrics(self, tmp_path, tree):
        ac = AsyncCheckpointer(CheckpointStore(str(tmp_path)))
        c_block = ac.save(11, tree)
        assert c_block >= 0.0
        ac.wait()
        assert ac.durable_step == 11
        m = ac.metrics
        assert m["c_full"] >= m["c_block"]

    def test_serialized_inflight(self, tmp_path, tree):
        ac = AsyncCheckpointer(CheckpointStore(str(tmp_path)), keep=3)
        for s in (1, 2, 3):
            ac.save(s, tree)
        ac.wait()
        assert ac.durable_step == 3


class TestBuddy:
    def test_buddy_survives_node_loss(self, tree):
        bm = BuddyMemoryCheckpoint(n_nodes=4)
        bm.save(9, tree, rank=2)
        got = bm.restore(2, lost=True)
        assert got is not None and got[0] == 9
        np.testing.assert_array_equal(
            np.asarray(got[1]["params"]["w"]), np.asarray(tree["params"]["w"])
        )

    def test_missing_returns_none(self):
        bm = BuddyMemoryCheckpoint(n_nodes=2)
        assert bm.restore(0) is None
