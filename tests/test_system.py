"""End-to-end behaviour tests: the shipped drivers run, survive injected
faults, and reproduce the paper's headline result live (prediction-aware
checkpointing beats Young on the same fault trace)."""

import os
import re
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
ENV = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}


def _run(args, timeout=1200):
    proc = subprocess.run(
        [sys.executable, "-m", *args],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=ENV,
        cwd=ROOT,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


@pytest.mark.slow
def test_train_driver_faultfree(tmp_path):
    out = _run(
        [
            "repro.launch.train",
            "--arch", "smollm-135m",
            "--steps", "30",
            "--batch", "4",
            "--seq", "64",
            "--ckpt-dir", str(tmp_path / "ck"),
        ]
    )
    assert "run report" in out
    losses = [float(m) for m in re.findall(r"loss (\d+\.\d+)", out)]
    assert len(losses) >= 2 and losses[-1] < losses[0]


@pytest.mark.slow
def test_train_driver_with_faults_and_predictor(tmp_path):
    out = _run(
        [
            "repro.launch.train",
            "--arch", "qwen2-0.5b",
            "--steps", "25",
            "--batch", "4",
            "--seq", "48",
            "--inject-faults",
            "--fault-mtbf", "6",
            "--predictor", "paper-accurate",
            "--ckpt-dir", str(tmp_path / "ck2"),
        ]
    )
    assert "run report" in out
    m = re.search(r"waste=(\d+\.\d+)", out)
    assert m is not None
    assert float(m.group(1)) < 1.0


@pytest.mark.slow
def test_serve_driver_with_faults(tmp_path):
    out = _run(
        [
            "repro.launch.serve",
            "--arch", "smollm-135m",
            "--requests", "2",
            "--prompt-len", "16",
            "--gen", "24",
            "--snapshot-every", "8",
            "--inject-faults",
            "--fault-mtbf", "2",
        ]
    )
    assert "generated" in out


def test_paper_headline_live():
    """The core claim, executed through the real executor machinery:
    on the same platform, the paper's policy wastes less than Young."""
    import numpy as np

    from repro.core import Platform, PredictorModel
    from repro.core.events import make_event_trace
    from repro.core.predictor import SimulatedPredictor
    from repro.ft import FaultInjector, FaultTolerantExecutor, SimClock

    MN = 60.0
    plat = Platform(mu=125 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    pm = PredictorModel(0.85, 0.82, window=300.0, lead=3600.0)

    def run(strategy, recall):
        trace = make_event_trace(
            np.random.default_rng(42), horizon=40 * 86400, mtbf=plat.mu,
            recall=recall, precision=pm.precision, window=pm.window,
            lead=pm.lead,
        )
        ex = FaultTolerantExecutor(
            step_fn=lambda s, k: s, state=0, platform=plat, pred_model=pm,
            predictor=SimulatedPredictor(trace, pm) if recall else None,
            injector=FaultInjector(trace), clock=SimClock(), step_time=30.0,
            strategy=strategy,
        )
        return ex.run(int(8 * 86400 / 30.0))

    rep_pred = run("auto", pm.recall)
    rep_young = run("young", 0.0)
    assert rep_pred.ledger.waste() < rep_young.ledger.waste()
    # the gain at this scale is substantial (paper: tens of percent)
    gain = 1 - rep_pred.ledger.waste() / rep_young.ledger.waste()
    assert gain > 0.15
