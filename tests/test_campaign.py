"""Resumable campaign runner: kill/resume bit-exactness at every chunk
boundary (in-process and real SIGKILL), the chaos recovery matrix (OOM
chunk-halving, device loss, engine degradation), snapshot-period choice
via the paper's own optimize(), and the retry/chaos primitives."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.engine import EngineConfig
from repro.core.waste import Platform
from repro.experiments import run_grid
from repro.experiments.grid import GridSpec
from repro.experiments.paper_grid import paper_grid_cells
from repro.ft import (
    CampaignConfig,
    CampaignKilled,
    CampaignRunner,
    ChaosInjector,
    FailureKind,
    RetryPolicy,
    SyntheticDeviceLoss,
    SyntheticJaxFailure,
    SyntheticOOM,
    classify_failure,
    run_campaign,
)

#: chaos-fuzz budget (CI sets it higher in the chaos job)
N_FUZZ = int(os.environ.get("REPRO_CHAOS_EXAMPLES", "2"))

CHUNK = 25  # one shape for every campaign test: a single engine compile


def small_grid(n_runs=30, seed=7, n_cells=4):
    cells = paper_grid_cells("validation")[:n_cells]
    return GridSpec(cells=tuple(cells), n_runs=n_runs, seed=seed)


def cfg(trace_mode="device", collect="stats", chunk=CHUNK):
    return EngineConfig(
        engine="jax", trace_mode=trace_mode, collect=collect,
        chunk_lanes=chunk,
    )


def nosleep():
    return RetryPolicy(sleep=lambda s: None)


def key_vec(res):
    return np.stack(
        [
            [c.mean_waste for c in res.cells],
            [c.mean_makespan for c in res.cells],
            [c.mean_faults for c in res.cells],
            [c.mean_regular_ckpts for c in res.cells],
        ]
    )


@pytest.fixture(scope="module")
def grid():
    return small_grid()


@pytest.fixture(scope="module")
def ref_device(grid):
    return run_grid(grid, config=cfg("device"))


class TestCampaignEquivalence:
    def test_matches_run_grid_device(self, tmp_path, grid, ref_device):
        res = run_campaign(
            grid, CampaignConfig(ckpt_dir=str(tmp_path), ckpt_period=0.0),
            cfg("device"),
        )
        np.testing.assert_array_equal(key_vec(ref_device), key_vec(res))
        camp = res.meta["campaign"]
        assert camp["n_snapshots"] >= grid.n_lanes // CHUNK
        assert not camp["engine_degraded"]

    def test_lanes_collect_matches_run_grid(self, tmp_path, grid):
        ref = run_grid(grid, config=cfg("device", collect="lanes"))
        res = run_campaign(
            grid, CampaignConfig(ckpt_dir=str(tmp_path), ckpt_period=0.0),
            cfg("device", collect="lanes"),
        )
        for rc, cc in zip(ref.cells, res.cells):
            np.testing.assert_array_equal(rc.waste, cc.waste)
            np.testing.assert_array_equal(rc.makespan, cc.makespan)

    def test_period_none_uses_optimize(self, tmp_path, grid):
        mtbf = 1800.0
        res = run_campaign(
            grid,
            CampaignConfig(ckpt_dir=str(tmp_path), mtbf=mtbf,
                           restore_cost=2.0),
            cfg("device"),
        )
        camp = res.meta["campaign"]
        from repro.core import optimize

        want = optimize(
            "young",
            Platform(mu=mtbf, C=max(camp["snapshot_cost_est_s"], 1e-4),
                     D=0.0, R=2.0),
        ).T_R
        assert camp["snapshot_period_s"] == pytest.approx(want)
        assert camp["snapshot_period_s"] > 0


class TestKillResume:
    @pytest.mark.parametrize("trace_mode", ["device", "host"])
    def test_kill_at_every_boundary_is_bit_exact(self, tmp_path, grid,
                                                 trace_mode):
        # sync snapshots: every boundary is deterministically durable,
        # so each k>0 must actually resume (async durability is covered
        # by the SIGKILL and fuzz tests, where racing the drain is the
        # point)
        c = cfg(trace_mode)
        base = run_campaign(
            grid,
            CampaignConfig(ckpt_dir=str(tmp_path / "base"), ckpt_period=0.0,
                           async_snapshots=False),
            c,
        )
        n_chunks = -(-grid.n_lanes // CHUNK)
        for k in range(n_chunks):
            d = str(tmp_path / f"{trace_mode}_{k}")
            camp = CampaignConfig(
                ckpt_dir=d, ckpt_period=0.0, async_snapshots=False,
                chaos=ChaosInjector(kill_at=(k,)),
            )
            with pytest.raises(CampaignKilled):
                run_campaign(grid, camp, c)
            res = run_campaign(
                grid,
                CampaignConfig(ckpt_dir=d, ckpt_period=0.0,
                               async_snapshots=False),
                c,
            )
            np.testing.assert_array_equal(key_vec(base), key_vec(res))
            if k > 0:  # every prior boundary was durable before the kill
                ev = res.meta["campaign"]["events"]
                assert any(e["kind"] == "resume" for e in ev)

    def test_kill_resume_lanes_collect(self, tmp_path, grid):
        c = cfg("device", collect="lanes")
        base = run_campaign(
            grid,
            CampaignConfig(ckpt_dir=str(tmp_path / "b"), ckpt_period=0.0),
            c,
        )
        d = str(tmp_path / "k")
        with pytest.raises(CampaignKilled):
            run_campaign(
                grid,
                CampaignConfig(ckpt_dir=d, ckpt_period=0.0,
                               chaos=ChaosInjector(kill_at=(3,))),
                c,
            )
        res = run_campaign(
            grid, CampaignConfig(ckpt_dir=d, ckpt_period=0.0), c
        )
        for bc, cc in zip(base.cells, res.cells):
            np.testing.assert_array_equal(bc.waste, cc.waste)

    def test_fingerprint_mismatch_refuses_resume(self, tmp_path, grid):
        d = str(tmp_path)
        with pytest.raises(CampaignKilled):
            run_campaign(
                grid,
                CampaignConfig(ckpt_dir=d, ckpt_period=0.0,
                               chaos=ChaosInjector(kill_at=(2,))),
                cfg("device"),
            )
        other = small_grid(seed=8)
        with pytest.raises(ValueError, match="fingerprint"):
            run_campaign(
                other, CampaignConfig(ckpt_dir=d, ckpt_period=0.0),
                cfg("device"), resume=True,
            )

    def test_resume_true_requires_snapshot(self, tmp_path, grid):
        with pytest.raises(FileNotFoundError):
            run_campaign(
                grid, CampaignConfig(ckpt_dir=str(tmp_path)),
                cfg("device"), resume=True,
            )

    def test_sigkill_subprocess_resume(self, tmp_path):
        """The real thing: the CLI process dies on SIGKILL mid-campaign
        (no atexit, no flush) and a fresh process resumes bit-exactly."""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src"),
             env.get("PYTHONPATH", "")]
        )
        common = [
            sys.executable, "-m", "repro.experiments.campaign",
            "--preset", "validation", "--limit-cells", "3",
            "--n-runs", "20", "--seed", "5",
            "--chunk-lanes", str(CHUNK), "--ckpt-period", "0",
        ]
        ref = str(tmp_path / "ref.json")
        subprocess.run(
            common + ["--ckpt-dir", str(tmp_path / "r"), "--out", ref],
            env=env, check=True, timeout=300,
        )
        proc = subprocess.run(
            common + [
                "--ckpt-dir", str(tmp_path / "k"),
                "--chaos-kill-at", "2", "--chaos-kill-mode", "sigkill",
            ],
            env=env, timeout=300,
        )
        assert proc.returncode in (-9, 137)
        out = str(tmp_path / "resumed.json")
        subprocess.run(
            [sys.executable, "-m", "repro.experiments.campaign",
             "--resume", str(tmp_path / "k"), "--out", out],
            env=env, check=True, timeout=300,
        )
        with open(ref) as f:
            a = json.load(f)
        with open(out) as f:
            b = json.load(f)
        keys = ("label", "mean_waste", "mean_makespan", "mean_faults")
        assert [[c[k] for k in keys] for c in a["cells"]] == (
            [[c[k] for k in keys] for c in b["cells"]]
        )
        assert b["meta"]["campaign"]["incarnation"] >= 1


def scenario_grid(n_runs=30, seed=13):
    """A small mixed scenario grid: two-level (untrusted) + silent cells,
    exercising the DISK/DET statistics columns through the campaign."""
    from repro.experiments.paper_grid import (
        silent_grid_cells,
        two_level_grid_cells,
    )

    cells = tuple(two_level_grid_cells("validation")[:2]) + tuple(
        silent_grid_cells("validation")[:2]
    )
    return GridSpec(cells=cells, n_runs=n_runs, seed=seed)


class TestScenarioCampaign:
    """Kill/resume + snapshot-matrix coverage of the two new phase
    families (two-level checkpointing, silent errors)."""

    @pytest.fixture(scope="class")
    def sgrid(self):
        return scenario_grid()

    @pytest.mark.parametrize("trace_mode", ["device", "host"])
    def test_kill_resume_scenario_bit_exact(self, tmp_path, sgrid,
                                            trace_mode):
        c = cfg(trace_mode)
        ref = run_grid(sgrid, config=c)
        base = run_campaign(
            sgrid,
            CampaignConfig(ckpt_dir=str(tmp_path / "base"), ckpt_period=0.0,
                           async_snapshots=False),
            c,
        )
        np.testing.assert_array_equal(key_vec(ref), key_vec(base))
        for k in (1, 3):
            d = str(tmp_path / f"{trace_mode}_{k}")
            with pytest.raises(CampaignKilled):
                run_campaign(
                    sgrid,
                    CampaignConfig(ckpt_dir=d, ckpt_period=0.0,
                                   async_snapshots=False,
                                   chaos=ChaosInjector(kill_at=(k,))),
                    c,
                )
            res = run_campaign(
                sgrid,
                CampaignConfig(ckpt_dir=d, ckpt_period=0.0,
                               async_snapshots=False),
                c,
            )
            np.testing.assert_array_equal(key_vec(base), key_vec(res))
            ev = res.meta["campaign"]["events"]
            assert any(e["kind"] == "resume" for e in ev)

    def test_snapshot_matrix_carries_scenario_columns(self, tmp_path, sgrid):
        """The campaign accumulator is the full 12-column CellSums
        matrix: disk-tier recoveries on the two-level cells, silent
        detections on the silent cells, zero cross-talk."""
        from repro.core.jax_sim import CellSums

        runner = CampaignRunner(
            sgrid,
            CampaignConfig(ckpt_dir=str(tmp_path), ckpt_period=0.0),
            cfg("device"),
        )
        runner.run()
        assert runner._sums.shape == (len(sgrid.cells), 12)
        sums = CellSums.from_matrix(runner._sums)
        disk = np.asarray(sums.n_disk_recoveries)
        det = np.asarray(sums.n_detections)
        assert (disk[:2] > 0).all()  # two-level cells hit the disk tier
        assert (det[2:] > 0).all()  # silent cells detect corruptions
        assert (disk[2:] == 0).all() and (det[:2] == 0).all()

    def test_pre_scenario_snapshot_shape_refused(self, tmp_path, sgrid):
        """A snapshot written before the DISK/DET columns existed (10-col
        accumulator) must be refused, not silently mis-summed."""
        from repro.checkpoint.store import CheckpointStore

        d = str(tmp_path)
        with pytest.raises(CampaignKilled):
            run_campaign(
                sgrid,
                CampaignConfig(ckpt_dir=d, ckpt_period=0.0,
                               async_snapshots=False,
                               chaos=ChaosInjector(kill_at=(2,))),
                cfg("device"),
            )
        store = CheckpointStore(d, codec="raw")
        step, tree = store.restore_latest()
        tree["sums"] = np.asarray(tree["sums"])[:, :10]
        store.save(step + 1, tree)
        with pytest.raises(ValueError, match="refusing to resume"):
            run_campaign(
                sgrid, CampaignConfig(ckpt_dir=d, ckpt_period=0.0),
                cfg("device"), resume=True,
            )


class TestChaosRecovery:
    def test_oom_halves_chunk_and_completes(self, tmp_path, grid,
                                            ref_device):
        res = run_campaign(
            grid,
            CampaignConfig(ckpt_dir=str(tmp_path), ckpt_period=0.0,
                           retry=nosleep(),
                           chaos=ChaosInjector(oom_at=(1,))),
            cfg("device"),
        )
        camp = res.meta["campaign"]
        kinds = [e["kind"] for e in camp["events"]]
        assert "oom" in kinds and "chunk_halved" in kinds
        assert camp["chunk_lanes_final"] == CHUNK // 2
        # partition changed -> f64 summation order changed: allclose
        np.testing.assert_allclose(
            key_vec(ref_device), key_vec(res), rtol=1e-9
        )

    def test_device_loss_completes_bit_exact(self, tmp_path, grid,
                                             ref_device):
        import jax

        res = run_campaign(
            grid,
            CampaignConfig(ckpt_dir=str(tmp_path), ckpt_period=0.0,
                           retry=nosleep(),
                           chaos=ChaosInjector(device_loss_at=(2,))),
            cfg("device"),
        )
        camp = res.meta["campaign"]
        kinds = [e["kind"] for e in camp["events"]]
        assert "device_loss" in kinds
        if len(jax.devices()) > 1:
            # multi-device (CI chaos job): the dispatch shrank and the
            # result is still bit-exact (device-count invariance)
            assert "devices_shrunk" in kinds
            assert camp["n_devices_final"] < len(jax.devices())
        np.testing.assert_array_equal(key_vec(ref_device), key_vec(res))

    def test_persistent_jax_failure_degrades_to_batch(self, tmp_path, grid,
                                                      ref_device):
        res = run_campaign(
            grid,
            CampaignConfig(ckpt_dir=str(tmp_path), ckpt_period=0.0,
                           retry=nosleep(),
                           chaos=ChaosInjector(jax_fail_at=1)),
            cfg("device"),
        )
        camp = res.meta["campaign"]
        assert camp["engine_degraded"]
        assert res.engine == "batch"
        kinds = [e["kind"] for e in camp["events"]]
        assert "engine_degraded" in kinds
        assert kinds.count("transient") >= 2  # retried before degrading
        # host replay of the same counter streams: statistically equal
        np.testing.assert_allclose(
            key_vec(ref_device)[0], key_vec(res)[0], rtol=0.35
        )

    def test_degraded_state_survives_kill(self, tmp_path, grid):
        """Degradation is durable: a campaign killed *after* degrading
        resumes on the batch engine, bit-identical to an uninterrupted
        degraded run."""
        c = cfg("device")
        base = run_campaign(
            grid,
            CampaignConfig(ckpt_dir=str(tmp_path / "b"), ckpt_period=0.0,
                           retry=nosleep(),
                           chaos=ChaosInjector(jax_fail_at=0)),
            c,
        )
        assert base.meta["campaign"]["engine_degraded"]
        d = str(tmp_path / "k")
        with pytest.raises(CampaignKilled):
            run_campaign(
                grid,
                CampaignConfig(ckpt_dir=d, ckpt_period=0.0, retry=nosleep(),
                               chaos=ChaosInjector(jax_fail_at=0,
                                                   kill_at=(3,))),
                c,
            )
        res = run_campaign(
            grid, CampaignConfig(ckpt_dir=d, ckpt_period=0.0), c
        )
        assert res.meta["campaign"]["engine_degraded"]
        np.testing.assert_array_equal(key_vec(base), key_vec(res))

    @pytest.mark.parametrize("fuzz_seed", range(N_FUZZ))
    def test_chaos_fuzz_converges(self, tmp_path, grid, ref_device,
                                  fuzz_seed):
        """Probabilistic kill/OOM/device-loss storms (bounded fire
        budget): the campaign always completes across incarnations and
        the result stays equal to the plain sweep (bit-exact unless an
        OOM changed the chunk partition)."""
        chaos = ChaosInjector(
            seed=1000 + fuzz_seed, p_kill=0.25, p_oom=0.2,
            p_device_loss=0.15, max_fires=5,
        )
        camp = CampaignConfig(
            ckpt_dir=str(tmp_path), ckpt_period=0.0, retry=nosleep(),
            chaos=chaos,
        )
        res = None
        for _ in range(chaos.max_fires + 2):
            try:
                res = CampaignRunner(grid, camp, cfg("device")).run()
                break
            except CampaignKilled:
                continue
        assert res is not None, "campaign never completed under chaos"
        np.testing.assert_allclose(
            key_vec(ref_device), key_vec(res), rtol=1e-9
        )


class TestRetryPrimitives:
    def test_classifier(self):
        assert classify_failure(SyntheticOOM(0)) is FailureKind.OOM
        assert classify_failure(SyntheticDeviceLoss(0)) is (
            FailureKind.DEVICE_LOSS
        )
        assert classify_failure(SyntheticJaxFailure(0)) is (
            FailureKind.TRANSIENT
        )
        assert classify_failure(
            RuntimeError("RESOURCE_EXHAUSTED: out of memory")
        ) is FailureKind.OOM
        assert classify_failure(ValueError("bad arg")) is FailureKind.FATAL
        assert classify_failure(RuntimeError("???")) is FailureKind.TRANSIENT

    def test_backoff_deterministic_and_bounded(self):
        pol = RetryPolicy(base=0.1, factor=2.0, jitter=0.5, seed=4)
        a = [pol.backoff(k, counter=k) for k in range(4)]
        b = [pol.backoff(k, counter=k) for k in range(4)]
        assert a == b  # counter-keyed jitter replays
        for k, dt in enumerate(a):
            assert 0.1 * 2 ** k <= dt <= 0.1 * 2 ** k * 1.5

    def test_campaign_killed_is_not_an_exception(self):
        assert not issubclass(CampaignKilled, Exception)
        assert issubclass(CampaignKilled, BaseException)

    def test_chaos_scheduled_fire_once(self):
        ch = ChaosInjector(oom_at=(2,))
        ch.at_chunk_boundary(0)
        ch.at_chunk_boundary(1)
        with pytest.raises(SyntheticOOM):
            ch.at_chunk_boundary(2)
        ch.at_chunk_boundary(2)  # already fired: retry proceeds

    def test_chaos_retries_skip_scheduled(self):
        ch = ChaosInjector(oom_at=(0,), kill_at=(0,))
        ch.at_chunk_boundary(0, attempt=1)  # nothing fires on retries

    def test_chaos_jax_failure_persists_until_degraded(self):
        ch = ChaosInjector(jax_fail_at=1)
        ch.at_chunk_boundary(0)
        for attempt in range(3):
            with pytest.raises(SyntheticJaxFailure):
                ch.at_chunk_boundary(1, attempt=attempt)
        with pytest.raises(SyntheticJaxFailure):
            ch.at_chunk_boundary(5, incarnation=2, attempt=1)
        ch.at_chunk_boundary(5, engine="batch")  # bug lives in the jax path

    def test_chaos_budget_bounds_probabilistic_fires(self):
        ch = ChaosInjector(seed=3, p_oom=1.0, max_fires=2)
        fired = 0
        for k in range(10):
            try:
                ch.at_chunk_boundary(k)
            except SyntheticOOM:
                fired += 1
        assert fired == 2
