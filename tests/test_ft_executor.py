"""FaultTolerantExecutor: policy behaviour, recovery correctness, waste
ledger vs the analytic model, elastic/straggler logic."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, CheckpointStore, latest_step
from repro.core.events import make_event_trace
from repro.core.predictor import SimulatedPredictor
from repro.core.waste import Platform, PredictorModel
from repro.ft import (
    ElasticManager,
    FaultInjector,
    FaultTolerantExecutor,
    SimClock,
    StragglerDetector,
    WallClock,
)

MN = 60.0


def _sim_executor(strategy="auto", recall=0.85, precision=0.82, seed=0,
                  steps_days=15.0, window=300.0, mu_mn=1000):
    plat = Platform(mu=mu_mn * MN, C=10 * MN, D=1 * MN, R=10 * MN, M=5 * MN)
    pm = PredictorModel(recall, precision, window=window, lead=3600.0)
    rng = np.random.default_rng(seed)
    trace = make_event_trace(
        rng, horizon=steps_days * 86400 * 4, mtbf=plat.mu,
        recall=recall, precision=precision, window=window, lead=3600.0,
    )
    step_time = 30.0
    ex = FaultTolerantExecutor(
        step_fn=lambda s, k: s,
        state=0,
        platform=plat,
        pred_model=pm,
        predictor=SimulatedPredictor(trace, pm) if recall > 0 else None,
        injector=FaultInjector(trace),
        clock=SimClock(),
        step_time=step_time,
        strategy=strategy,
    )
    n_steps = int(steps_days * 86400 / step_time)
    return ex, ex.run(n_steps)


class TestSimulatedPolicy:
    def test_waste_below_analytic_bound(self):
        ex, rep = _sim_executor()
        assert rep.ledger.waste() <= rep.analytic_waste * 1.1

    def test_prediction_reduces_waste(self):
        _, rep_pred = _sim_executor(strategy="auto", seed=1)
        _, rep_young = _sim_executor(strategy="young", recall=0.0, seed=1)
        assert rep_pred.ledger.waste() < rep_young.ledger.waste()

    def test_proactive_checkpoints_taken(self):
        _, rep = _sim_executor(seed=2)
        assert rep.n_proactive > 0
        assert rep.q == 1

    def test_young_mode_has_no_proactive(self):
        _, rep = _sim_executor(strategy="young", recall=0.0, seed=3)
        assert rep.n_proactive == 0 and rep.n_migrations == 0

    def test_migration_cancels_predicted_faults(self):
        ex, rep = _sim_executor(strategy="migration", seed=4)
        assert rep.n_migrations > 0
        # most predicted faults are dodged: fault count well below Young's
        _, rep_y = _sim_executor(strategy="young", recall=0.0, seed=4)
        assert rep.n_faults < rep_y.n_faults

    def test_period_matches_unified_formula(self):
        ex, rep = _sim_executor(seed=5, window=0.0)
        # uncapped unified period (Section 5 practice; see periods.py); the
        # executor blends the configured recall with the *observed* recall,
        # so allow the estimator's drift around r=0.85
        t_pred = math.sqrt(2 * ex.platform.mu * ex.c_est / (1 - 0.85))
        assert rep.period_T == pytest.approx(t_pred, rel=0.25)
        # and it is strictly longer than Young's period (rq > 0)
        assert rep.period_T > math.sqrt(2 * ex.platform.mu * ex.c_est) * 1.5


class TestOnlineEstimation:
    def test_zero_evidence_precision_is_zero(self):
        """Regression: with zero observed predictions the estimator used
        to return precision 1.0 — perfect trust in a predictor that had
        never predicted anything."""
        from repro.core.predictor import estimate_recall_precision

        r, p = estimate_recall_precision(0, 0, 25)
        assert r == 0.0
        assert p == 0.0
        # evidence present: plain ratios
        r, p = estimate_recall_precision(3, 1, 1)
        assert r == pytest.approx(0.75)
        assert p == pytest.approx(0.75)

    def test_zero_true_faults_recall_is_zero(self):
        """Edge: a campaign segment with no true faults at all (TP + FN
        == 0 — e.g. a silent-error lane, whose corruptions the fail-stop
        predictor never sees) must degrade recall to 0.0 instead of
        raising ZeroDivisionError or claiming perfect recall."""
        from repro.core.predictor import estimate_recall_precision

        r, p = estimate_recall_precision(0, 5, 0)
        assert r == 0.0
        assert p == 0.0
        r, p = estimate_recall_precision(0, 0, 0)
        assert (r, p) == (0.0, 0.0)

    def test_reoptimization_gated_on_prediction_evidence(self):
        """A silent predictor (25 faults seen, zero predictions) must not
        inflate the precision fed to the online re-optimization: the
        observed model keeps the prior precision until TP + FP evidence
        exists, so the policy cannot flip to q=1 trust on nothing."""
        plat = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
        pm = PredictorModel(0.85, 0.82, window=300.0, lead=3600.0)
        trace = make_event_trace(
            np.random.default_rng(0), horizon=1e6, mtbf=plat.mu,
            recall=0.85, precision=0.82, window=300.0, lead=3600.0,
        )
        ex = FaultTolerantExecutor(
            step_fn=lambda s, k: s, state=0, platform=plat,
            pred_model=pm, predictor=SimulatedPredictor(trace, pm),
            clock=SimClock(), strategy="auto",
        )
        ex.fn_obs = 25  # only unpredicted faults observed
        obs = ex._observed_model()
        assert obs.precision == pytest.approx(pm.precision)  # prior held
        assert obs.recall < pm.recall  # recall evidence *is* used
        # once predictions are actually observed, precision evidence flows
        ex.tp_obs, ex.fp_obs = 4, 2
        obs = ex._observed_model()
        assert obs.precision < pm.precision

    def test_recall_gated_symmetrically(self):
        """The mirror failure: a chatty false-positive predictor (20 FPs,
        zero faults seen yet) must not drag the recall estimate off the
        prior — recall has no evidence until faults are observed."""
        plat = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
        pm = PredictorModel(0.85, 0.82, window=300.0, lead=3600.0)
        trace = make_event_trace(
            np.random.default_rng(1), horizon=1e6, mtbf=plat.mu,
            recall=0.85, precision=0.82, window=300.0, lead=3600.0,
        )
        ex = FaultTolerantExecutor(
            step_fn=lambda s, k: s, state=0, platform=plat,
            pred_model=pm, predictor=SimulatedPredictor(trace, pm),
            clock=SimClock(), strategy="auto",
        )
        ex.fp_obs = 20  # no faults observed at all: tp + fn == 0
        obs = ex._observed_model()
        assert obs.recall == pytest.approx(pm.recall)  # prior held
        assert obs.precision < pm.precision  # FP evidence *is* used


class TestRealTrainingRecovery:
    """Real CPU model + real checkpoints: the loss trajectory after an
    injected fault + restore matches a fault-free run (deterministic
    resume of the data pipeline)."""

    def _run(self, tmp_path, inject: bool, n_steps=12):
        from repro import configs
        from repro.data.pipeline import SyntheticLMDataset
        from repro.launch.steps import build_model, build_train_step
        from repro.models.layers import RuntimeFlags
        from repro.optim.adamw import adamw_init

        cfg = configs.get("smollm-135m").reduced()
        model, _ = build_model(cfg, mesh=None, flags=RuntimeFlags(dense_attn_max=256))
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        state = {"params": params, "opt": opt}
        inner = jax.jit(build_train_step(model, lr=1e-3))
        data = SyntheticLMDataset(cfg.vocab_size, 32, 4, seed=5)
        losses = {}

        def step_fn(st, k):
            batch = {kk: jnp.asarray(v) for kk, v in data.batch(k).items()}
            p, o, m = inner(st["params"], st["opt"], batch)
            losses[k] = float(m["loss"])
            return {"params": p, "opt": o}

        store = CheckpointStore(str(tmp_path / ("inj" if inject else "ref")))
        ckpt = AsyncCheckpointer(store)
        injector = None
        if inject:
            # one fault mid-run (simulated times: 1s per step)
            from repro.core.events import EventTrace, FaultEvent

            trace = EventTrace(
                horizon=1e9, faults=[FaultEvent(6.5)], predictions=[]
            )
            injector = FaultInjector(trace)

        def restore_fn(step_k):
            s = latest_step(store.root)
            if s is None:  # fault before the first checkpoint: re-init
                p0 = model.init(jax.random.PRNGKey(0))
                return {"params": p0, "opt": adamw_init(p0)}
            return store.restore(s, target=jax.eval_shape(lambda: state))

        plat = Platform(mu=1e9 if not inject else 50.0, C=2.0, D=0.1, R=0.1)
        ex = FaultTolerantExecutor(
            step_fn=step_fn,
            state=state,
            platform=plat,
            checkpointer=ckpt,
            restore_fn=restore_fn,
            load_state=lambda st, tree, k: tree,
            injector=injector,
            clock=SimClock(),
            step_time=1.0,
            strategy="young",
        )
        rep = ex.run(n_steps)
        return losses, rep

    def test_recovery_replays_identically(self, tmp_path):
        ref_losses, _ = self._run(tmp_path, inject=False)
        inj_losses, rep = self._run(tmp_path, inject=True)
        assert rep.n_restores >= 1
        # the final losses agree: the injected run replayed the same stream
        last = max(ref_losses)
        assert inj_losses[last] == pytest.approx(ref_losses[last], rel=1e-5)


class TestRestoreTiers:
    """Restore failures route through the shared retry/backoff classifier:
    memory tier -> disk tier -> older step, with the extra time charged to
    the ledger's recovery bucket."""

    def _executor(self, tiers, mu=200.0):
        from repro.core.events import EventTrace, FaultEvent
        from repro.ft import RetryPolicy

        plat = Platform(mu=mu, C=2.0, D=0.5, R=3.0)
        trace = EventTrace(horizon=1e9, faults=[FaultEvent(40.5)],
                           predictions=[])
        return FaultTolerantExecutor(
            step_fn=lambda s, k: s,
            state="init",
            platform=plat,
            restore_tiers=tiers,
            restore_retry=RetryPolicy(max_attempts=2, base=0.25,
                                      jitter=0.0, sleep=lambda s: None),
            load_state=lambda st, tree, k: tree,
            injector=FaultInjector(trace),
            clock=SimClock(),
            step_time=1.0,
            strategy="young",
        )

    def test_memory_tier_down_falls_to_disk(self):
        calls = []

        def memory_tier(step):
            calls.append(("mem", step))
            raise IOError("buddy peer unreachable")

        def disk_tier(step):
            calls.append(("disk", step))
            return f"disk@{step}"

        ex = self._executor([memory_tier, disk_tier])
        rep = ex.run(60)
        assert rep.n_restores == 1
        assert ex.state.startswith("disk@")
        # the memory tier burned its full retry budget before the fallback
        assert [c[0] for c in calls].count("mem") == 2
        # each failed attempt cost a restore R plus backoff on the ledger
        assert rep.ledger.recovery >= 2 * 3.0 + 3.0

    def test_flaky_tier_recovers_via_retry(self):
        attempts = []

        def flaky(step):
            attempts.append(step)
            if len(attempts) == 1:
                raise IOError("transient read failure")
            return f"mem@{step}"

        ex = self._executor([flaky])
        rep = ex.run(60)
        assert ex.state.startswith("mem@")
        assert len(attempts) == 2
        assert rep.ledger.recovery >= 3.0 + 3.0  # failed try + real restore

    def test_fallback_to_older_step_relosts_work(self):
        """Newest checkpoint unreadable everywhere: the ladder falls back
        to an older checkpointed step and the work in between is re-lost."""
        def tier(step):
            if step == newest[0]:
                raise IOError("shard torn")
            return f"ok@{step}"

        newest = [None]
        ex = self._executor([tier])
        # run() checkpoints a few times before the fault at t=40.5
        orig_handle = ex._restore_with_fallback

        def spy(step):
            newest[0] = step
            return orig_handle(step)

        ex._restore_with_fallback = spy
        rep = ex.run(60)
        assert rep.n_restores == 1
        restored = int(ex.state.split("@")[1])
        assert restored < newest[0]
        assert rep.ledger.lost_work > 0

    def test_all_tiers_dead_raises_last_error(self):
        def dead(step):
            raise IOError("gone")

        ex = self._executor([dead])
        with pytest.raises(IOError, match="gone"):
            ex.run(60)

    def test_fatal_restore_error_skips_tier_immediately(self):
        calls = []

        def broken(step):
            calls.append("broken")
            raise ValueError("shape mismatch")  # FATAL: no retry

        def good(step):
            calls.append("good")
            return f"ok@{step}"

        ex = self._executor([broken, good])
        ex.run(60)
        assert calls.count("broken") == 1  # no second attempt on FATAL
        assert ex.state.startswith("ok@")

    def test_restore_fn_still_works_as_single_tier(self):
        ex = self._executor(None)
        ex.restore_tiers = []  # mimic legacy: only restore_fn given
        ex.restore_fn = lambda step: f"legacy@{step}"
        ex.restore_tiers = [ex.restore_fn]
        rep = ex.run(60)
        assert rep.n_restores == 1
        assert ex.state.startswith("legacy@")


class TestElastic:
    def test_spare_pool_swap(self):
        em = ElasticManager(n_nodes=8, n_spares=2)
        ev = em.migrate(node=3, reason="prediction")
        assert not ev["shrunk"] and em.world_size == 8
        em.migrate(node=5)
        ev3 = em.migrate(node=7)  # spares exhausted -> shrink
        assert ev3["shrunk"] and em.world_size == 7

    def test_straggler_detector(self):
        det = StragglerDetector(n_ranks=4, window=8, threshold=1.5, patience=2)
        rng = np.random.default_rng(0)
        flagged = []
        for t in range(40):
            for r in range(4):
                dt = 1.0 + rng.normal(0, 0.02)
                if r == 2 and t > 10:
                    dt *= 2.5  # rank 2 degrades
                det.record(r, dt)
            flagged = det.check()
        assert flagged == [2]

    def test_no_false_positives_when_uniform(self):
        det = StragglerDetector(n_ranks=4, window=8)
        rng = np.random.default_rng(1)
        for _t in range(40):
            for r in range(4):
                det.record(r, 1.0 + rng.normal(0, 0.05))
        assert det.check() == []
