"""Discrete-event simulator vs the analytic model (paper Section 5)."""

import math

import numpy as np
import pytest

from repro.core import (
    Platform,
    PredictorModel,
    Strategy,
    best_period_search,
    simulate,
    simulate_many,
    t_extr,
    waste_exact,
    waste_young,
)
from repro.core import events as E
from repro.core import simulator as S

MN = 60.0
PLAT = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN, M=5 * MN)
WORK = 20 * 86400.0
PRED0 = PredictorModel(recall=0.0, precision=1.0)


def _mean_waste(results):
    return float(np.mean([r.waste for r in results]))


class TestAgainstAnalytic:
    def test_young_exponential(self):
        """Simulated Young waste within the analytic upper bound and close."""
        strat = S.young(PLAT)
        res = simulate_many(WORK, PLAT, strat, PRED0, n_runs=30, seed=11)
        w_sim = _mean_waste(res)
        w_an = waste_young(strat.T_R, PLAT.C, PLAT.D, PLAT.R, PLAT.mu)
        assert w_sim <= w_an * 1.05  # formula is an upper bound
        assert abs(w_sim - w_an) / w_an < 0.25

    def test_exact_prediction_exponential(self):
        pred = PredictorModel(recall=0.85, precision=0.82)
        strat = S.exact_prediction(PLAT, pred)
        res = simulate_many(WORK, PLAT, strat, pred, n_runs=30, seed=13)
        w_sim = _mean_waste(res)
        w_an = waste_exact(
            strat.T_R, 1.0, PLAT.C, PLAT.D, PLAT.R, PLAT.mu, 0.85, 0.82
        )
        assert w_sim <= w_an * 1.05
        assert abs(w_sim - w_an) / w_an < 0.3

    def test_prediction_beats_young(self):
        pred = PredictorModel(recall=0.85, precision=0.82)
        wy = _mean_waste(
            simulate_many(WORK, PLAT, S.young(PLAT), PRED0, n_runs=20, seed=3)
        )
        wp = _mean_waste(
            simulate_many(
                WORK, PLAT, S.exact_prediction(PLAT, pred), pred, n_runs=20, seed=3
            )
        )
        assert wp < wy

    def test_best_period_close_to_formula(self):
        """Section 5 claim (ii): brute-force best period ~= T_extr^{1}."""
        pred = PredictorModel(recall=0.85, precision=0.82)
        base = S.exact_prediction(PLAT, pred)
        best_t, best_w = best_period_search(
            WORK / 4, PLAT, base, pred, n_runs=8, seed=5
        )
        w_formula = _mean_waste(
            simulate_many(WORK / 4, PLAT, base, pred, n_runs=8, seed=5)
        )
        # the formula period's waste is within 10% of the brute-force best
        assert w_formula <= best_w * 1.10


class TestWindowStrategies:
    PREDW = PredictorModel(recall=0.85, precision=0.82, window=3000.0)

    def test_withckpt_uses_proactive_period(self):
        strat = S.withckpt(PLAT, self.PREDW)
        assert strat.mode == "withckpt" and strat.T_P is not None

    def test_small_window_degenerates_to_nockpt(self):
        pred = PredictorModel(recall=0.85, precision=0.82, window=300.0)
        strat = S.withckpt(PLAT, pred)  # I < C: no checkpoint fits
        assert strat.mode == "nockpt"

    def test_all_strategies_run_and_beat_young(self):
        wy = _mean_waste(
            simulate_many(WORK, PLAT, S.young(PLAT), PRED0, n_runs=10, seed=7)
        )
        for mk in (S.instant, S.nockpt, S.withckpt):
            strat = mk(PLAT, self.PREDW)
            w = _mean_waste(
                simulate_many(WORK, PLAT, strat, self.PREDW, n_runs=10, seed=7)
            )
            assert w < wy, strat.name

    def test_migration_strategy(self):
        pred = PredictorModel(recall=0.85, precision=0.82)
        strat = S.migration(PLAT, pred)
        res = simulate_many(WORK, PLAT, strat, pred, n_runs=10, seed=9)
        assert all(r.n_migrations > 0 for r in res)
        wy = _mean_waste(
            simulate_many(WORK, PLAT, S.young(PLAT), PRED0, n_runs=10, seed=9)
        )
        assert _mean_waste(res) < wy


class TestDistributions:
    def test_trace_mean_scaling(self):
        rng = np.random.default_rng(0)
        for dist in [E.exponential(), E.weibull(0.7), E.weibull(0.5), E.lognormal()]:
            x = dist.sample(rng, 5000.0, 200_000)
            assert abs(x.mean() - 5000.0) / 5000.0 < 0.05, dist.name

    def test_empirical_recall_precision(self):
        rng = np.random.default_rng(1)
        tr = E.make_event_trace(
            rng, horizon=3e7, mtbf=6e4, recall=0.7, precision=0.4, window=300.0
        )
        assert abs(tr.empirical_recall() - 0.7) < 0.06
        assert abs(tr.empirical_precision() - 0.4) < 0.06

    def test_true_positive_fault_inside_window(self):
        rng = np.random.default_rng(2)
        tr = E.make_event_trace(
            rng, horizon=1e7, mtbf=6e4, recall=1.0, precision=1.0, window=600.0
        )
        for p in tr.predictions:
            assert p.fault_time is not None
            assert p.t0 <= p.fault_time <= p.t0 + p.window + 1e-9

    def test_superposed_freshstart_burnin(self):
        """Weibull k<1 components fresh at t=0 => early hazard burst (the
        mechanism behind the paper's heavy k=0.5 slowdowns)."""
        rng = np.random.default_rng(3)
        times = E.superposed_fault_times(
            rng, horizon=50 * 86400.0, mtbf=6e4, n_components=4096,
            dist=E.weibull(0.5),
        )
        day = 86400.0
        first = np.searchsorted(times, day)
        stationary_per_day = day / 6e4
        assert first > 20 * stationary_per_day

    def test_superposed_stationary_is_poissonish(self):
        rng = np.random.default_rng(4)
        times = E.superposed_fault_times(
            rng, horizon=200 * 86400.0, mtbf=6e4, n_components=4096,
            dist=E.weibull(0.7), stationary=True,
        )
        rate = len(times) / (200 * 86400.0)
        assert abs(rate - 1 / 6e4) * 6e4 < 0.15


class TestWeibullBehaviour:
    def test_gain_larger_under_weibull_freshstart(self):
        """Paper Tables 1-2: prediction gains are larger under Weibull
        (k=0.7) with fresh-start superposed components than exponential."""
        plat = Platform(mu=250 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
        pred = PredictorModel(recall=0.85, precision=0.82)
        kw = {"n_runs": 8, "seed": 21, "n_components": 2**14,
              "fault_dist": E.weibull(0.7), "horizon_factor": 20}
        wy = _mean_waste(simulate_many(WORK / 4, plat, S.young(plat), PRED0, **kw))
        wp = _mean_waste(
            simulate_many(WORK / 4, plat, S.exact_prediction(plat, pred), pred, **kw)
        )
        gain_wb = (wy - wp) / wy
        kw2 = {"n_runs": 8, "seed": 21}
        wy_e = _mean_waste(simulate_many(WORK / 4, plat, S.young(plat), PRED0, **kw2))
        wp_e = _mean_waste(
            simulate_many(WORK / 4, plat, S.exact_prediction(plat, pred), pred, **kw2)
        )
        gain_exp = (wy_e - wp_e) / wy_e
        assert gain_wb > 0
        assert wy > wy_e  # fresh-start Weibull hurts Young more


class TestBatchedGeneration:
    def test_arrival_times_batch_refill_stragglers(self):
        """Refill rounds draw only for lanes still short of their
        horizon: heavy-tail Weibull with heterogeneous means forces
        several refill rounds, and every lane's arrivals must still be
        a monotone prefix covering (0, horizon]."""
        from repro.core.events import _arrival_times_batch

        rng = np.random.default_rng(7)
        L = 512
        means = np.where(np.arange(L) % 7 == 0, 2e3, 6e4)
        horizons = np.full(L, 3e6)
        times, counts = _arrival_times_batch(
            rng, E.weibull(0.5), means, horizons
        )
        cols = np.arange(times.shape[1])[None, :]
        valid = cols < counts[:, None]
        assert np.isinf(times[~valid]).all()
        assert (times[valid] > 0).all() and (times[valid] <= 3e6).all()
        # rows sorted (monotone cumulative arrivals; inf - inf padding
        # diffs are NaN and excluded)
        with np.errstate(invalid="ignore"):
            d = np.diff(times, axis=1)
        assert (d[np.isfinite(d)] >= 0).all()
        # counts track each lane's own rate, not the batch max
        fast = counts[np.arange(L) % 7 == 0].mean()
        slow = counts[np.arange(L) % 7 != 0].mean()
        assert abs(fast / (3e6 / 2e3) - 1) < 0.2
        assert abs(slow / (3e6 / 6e4) - 1) < 0.2

    def test_superposed_stationary_batch_vectorized(self):
        """The vectorized equilibrium (stationary) superposition matches
        the scalar path's Poisson-like rate — no per-lane Python loop."""
        rng = np.random.default_rng(4)
        horizon = 100 * 86400.0
        times, counts = E.superposed_fault_times_batch(
            rng, np.full(4, horizon), np.full(4, 6e4), 4096,
            dist=E.weibull(0.7), stationary=True,
        )
        rate = counts.mean() / horizon
        assert abs(rate - 1 / 6e4) * 6e4 < 0.15
        # and the full batched trace generator accepts it
        tr = E.make_event_traces_batch(
            rng, 3, horizon=5e6, mtbf=6e4, recall=0.5, precision=0.5,
            n_components=1024, stationary=True,
        )
        assert tr.n_lanes == 3 and (tr.n_faults > 0).all()
