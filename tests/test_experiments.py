"""The experiment-sweep layer: grid execution, batched-vs-scalar cell
agreement (the CI equivalence gate for the benchmark acceptance), and the
CSV/JSON writers."""

import json
import math

import numpy as np
import pytest

from repro.core import Platform, PredictorModel
from repro.core import events as E
from repro.core import simulator as S
from repro.experiments import ExperimentCell, GridSpec, run_cells, run_grid

MN = 60.0
WORK = 6 * 86400.0


def _small_grid(n_platforms=2):
    cells = []
    for k in range(n_platforms):
        plat = Platform(mu=(500 + 500 * k) * MN, C=10 * MN, D=1 * MN, R=10 * MN)
        pred = PredictorModel(0.85, 0.82, window=300.0, lead=3600.0)
        dist = E.exponential() if k % 2 == 0 else E.weibull(0.7)
        for strat in (
            S.young(plat),
            S.exact_prediction(plat, PredictorModel(pred.recall, pred.precision)),
            S.instant(plat, pred),
            S.nockpt(plat, pred),
            S.withckpt(plat, pred),
        ):
            cells.append(
                ExperimentCell(
                    label=f"k{k}/{strat.name}",
                    work=WORK,
                    platform=plat,
                    predictor=pred,
                    strategy=strat,
                    fault_dist=dist,
                )
            )
    return GridSpec(tuple(cells), n_runs=5, seed=17)


def test_run_grid_shapes_and_labels():
    grid = _small_grid()
    sweep = run_grid(grid, engine="batch")
    assert len(sweep.cells) == len(grid.cells)
    assert sweep.labels() == [c.label for c in grid.cells]
    for cr in sweep.cells:
        assert cr.waste.shape == (grid.n_runs,)
        assert np.all(cr.makespan >= WORK)
        assert 0.0 < cr.mean_waste < 1.0
        assert math.isfinite(cr.ci95_waste)


def test_batch_scalar_cell_equivalence():
    """Acceptance gate: per-cell mean waste of the batched path agrees with
    the scalar path on the same grid within 2 relative percent (identical
    traces make the agreement essentially exact)."""
    grid = _small_grid()
    batch = run_grid(grid, engine="batch")
    scalar = run_grid(grid, engine="scalar")
    for b, s in zip(batch.cells, scalar.cells):
        rel = abs(b.mean_waste - s.mean_waste) / max(abs(s.mean_waste), 1e-12)
        assert rel <= 0.02, (b.cell.label, rel)
        # the agreement is in fact near-exact lane by lane
        np.testing.assert_allclose(b.makespan, s.makespan, rtol=1e-9)


def test_traces_shared_across_strategies():
    """Cells differing only in strategy face identical traces (the paper's
    paired design) — including the mode-"none" Young baseline, which shares
    the fault stream and simply never acts on the predictions."""
    from repro.experiments.runner import _group_cells, _group_traces

    grid = _small_grid(n_platforms=1)
    (_, cell_idx), = _group_cells(grid)
    traces = _group_traces(grid, cell_idx, 0)
    lanes_of = {grid.cells[ci].strategy.name: k for k, ci in enumerate(cell_idx)}
    n = grid.n_runs
    young = lanes_of["Young"] * n
    inst = lanes_of["Instant"] * n
    nock = lanes_of["NoCkptI"] * n
    np.testing.assert_array_equal(
        traces.fault_times[young : young + n], traces.fault_times[inst : inst + n]
    )
    np.testing.assert_array_equal(
        traces.fault_times[inst : inst + n], traces.fault_times[nock : nock + n]
    )
    # ... and the baseline never takes a proactive checkpoint despite the
    # predictions being present in its trace (trust filter drops them)
    sweep = run_grid(grid, engine="batch")
    assert sweep["k0/Young"].n_proactive_ckpts.sum() == 0
    assert sweep["k0/Instant"].n_proactive_ckpts.sum() > 0


def test_grid_rejects_duplicate_labels():
    plat = Platform(mu=500 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    pred = PredictorModel(0.85, 0.82)
    cell = ExperimentCell("dup", WORK, plat, pred, S.young(plat))
    with pytest.raises(ValueError, match="duplicate"):
        GridSpec((cell, cell), n_runs=2)


def test_unknown_engine_rejected():
    grid = _small_grid(n_platforms=1)
    with pytest.raises(ValueError, match="unknown engine"):
        run_grid(grid, engine="quantum")


def test_csv_json_writers(tmp_path):
    sweep = run_grid(_small_grid(n_platforms=1), engine="batch")
    csv_path = tmp_path / "sweep.csv"
    json_path = tmp_path / "sweep.json"
    sweep.write_csv(csv_path)
    sweep.write_json(json_path)

    lines = csv_path.read_text().strip().splitlines()
    assert len(lines) == 1 + len(sweep.cells)
    assert lines[0].startswith("label,strategy,T_R,mode,mu")

    payload = json.loads(json_path.read_text())
    assert payload["engine"] == "batch"
    assert payload["n_runs"] == sweep.grid.n_runs
    rows = {r["label"]: r for r in payload["cells"]}
    for cr in sweep.cells:
        assert rows[cr.cell.label]["mean_waste"] == pytest.approx(cr.mean_waste)


def test_simulate_many_engines_agree():
    """The rewired simulate_many: batch and scalar engines on the same
    generated traces return matching per-run results."""
    plat = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    pred = PredictorModel(0.85, 0.82)
    strat = S.exact_prediction(plat, pred)
    rb = S.simulate_many(WORK, plat, strat, pred, n_runs=6, seed=3, engine="batch")
    rs = S.simulate_many(WORK, plat, strat, pred, n_runs=6, seed=3, engine="scalar")
    for b, s in zip(rb, rs):
        assert b.makespan == pytest.approx(s.makespan, abs=1e-3)
        assert b.n_faults == s.n_faults


def test_best_period_search_batched():
    """Batched best-period brute force: formula period's waste within 10%
    of the best grid point (paper Section 5 claim (ii))."""
    plat = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    pred = PredictorModel(0.85, 0.82)
    base = S.exact_prediction(plat, pred)
    best_t, best_w = S.best_period_search(WORK, plat, base, pred, n_runs=6, seed=5)
    assert best_t >= plat.C
    assert 0.0 < best_w < 1.0
    res = S.simulate_many(WORK, plat, base, pred, n_runs=6, seed=5)
    w_formula = float(np.mean([r.waste for r in res]))
    assert w_formula <= best_w * 1.15


def test_legacy_engine_runs():
    """The legacy (seed-pipeline) engine stays available as the perf
    baseline and returns the same structure."""
    grid = _small_grid(n_platforms=1)
    sweep = run_grid(grid, engine="legacy")
    assert sweep.engine == "legacy"
    for cr in sweep.cells:
        assert cr.waste.shape == (grid.n_runs,)
        assert 0.0 < cr.mean_waste < 1.0
