"""The experiment-sweep layer: grid execution, batched-vs-scalar cell
agreement (the CI equivalence gate for the benchmark acceptance), and the
CSV/JSON writers."""

import json
import math

import numpy as np
import pytest

from repro.core import Platform, PredictorModel
from repro.core import events as E
from repro.core import simulator as S
from repro.experiments import ExperimentCell, GridSpec, run_cells, run_grid

MN = 60.0
WORK = 6 * 86400.0


def _small_grid(n_platforms=2):
    cells = []
    for k in range(n_platforms):
        plat = Platform(mu=(500 + 500 * k) * MN, C=10 * MN, D=1 * MN, R=10 * MN)
        pred = PredictorModel(0.85, 0.82, window=300.0, lead=3600.0)
        dist = E.exponential() if k % 2 == 0 else E.weibull(0.7)
        for strat in (
            S.young(plat),
            S.exact_prediction(plat, PredictorModel(pred.recall, pred.precision)),
            S.instant(plat, pred),
            S.nockpt(plat, pred),
            S.withckpt(plat, pred),
        ):
            cells.append(
                ExperimentCell(
                    label=f"k{k}/{strat.name}",
                    work=WORK,
                    platform=plat,
                    predictor=pred,
                    strategy=strat,
                    fault_dist=dist,
                )
            )
    return GridSpec(tuple(cells), n_runs=5, seed=17)


def test_run_grid_shapes_and_labels():
    grid = _small_grid()
    sweep = run_grid(grid, engine="batch")
    assert len(sweep.cells) == len(grid.cells)
    assert sweep.labels() == [c.label for c in grid.cells]
    for cr in sweep.cells:
        assert cr.waste.shape == (grid.n_runs,)
        assert np.all(cr.makespan >= WORK)
        assert 0.0 < cr.mean_waste < 1.0
        assert math.isfinite(cr.ci95_waste)


def test_batch_scalar_cell_equivalence():
    """Acceptance gate: per-cell mean waste of the batched path agrees with
    the scalar path on the same grid within 2 relative percent (identical
    traces make the agreement essentially exact)."""
    grid = _small_grid()
    batch = run_grid(grid, engine="batch")
    scalar = run_grid(grid, engine="scalar")
    for b, s in zip(batch.cells, scalar.cells):
        rel = abs(b.mean_waste - s.mean_waste) / max(abs(s.mean_waste), 1e-12)
        assert rel <= 0.02, (b.cell.label, rel)
        # the agreement is in fact near-exact lane by lane
        np.testing.assert_allclose(b.makespan, s.makespan, rtol=1e-9)


def test_traces_shared_across_strategies():
    """Cells differing only in strategy face identical traces (the paper's
    paired design) — including the mode-"none" Young baseline, which shares
    the fault stream and simply never acts on the predictions."""
    from repro.experiments.runner import _group_cells, _group_traces

    grid = _small_grid(n_platforms=1)
    (_, cell_idx), = _group_cells(grid)
    traces = _group_traces(grid, cell_idx, 0)
    lanes_of = {grid.cells[ci].strategy.name: k for k, ci in enumerate(cell_idx)}
    n = grid.n_runs
    young = lanes_of["Young"] * n
    inst = lanes_of["Instant"] * n
    nock = lanes_of["NoCkptI"] * n
    np.testing.assert_array_equal(
        traces.fault_times[young : young + n], traces.fault_times[inst : inst + n]
    )
    np.testing.assert_array_equal(
        traces.fault_times[inst : inst + n], traces.fault_times[nock : nock + n]
    )
    # ... and the baseline never takes a proactive checkpoint despite the
    # predictions being present in its trace (trust filter drops them)
    sweep = run_grid(grid, engine="batch")
    assert sweep["k0/Young"].n_proactive_ckpts.sum() == 0
    assert sweep["k0/Instant"].n_proactive_ckpts.sum() > 0


def test_grid_rejects_duplicate_labels():
    plat = Platform(mu=500 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    pred = PredictorModel(0.85, 0.82)
    cell = ExperimentCell("dup", WORK, plat, pred, S.young(plat))
    with pytest.raises(ValueError, match="duplicate"):
        GridSpec((cell, cell), n_runs=2)


def test_unknown_engine_rejected():
    grid = _small_grid(n_platforms=1)
    with pytest.raises(ValueError, match="unknown engine"):
        run_grid(grid, engine="quantum")


def test_csv_json_writers(tmp_path):
    sweep = run_grid(_small_grid(n_platforms=1), engine="batch")
    csv_path = tmp_path / "sweep.csv"
    json_path = tmp_path / "sweep.json"
    sweep.write_csv(csv_path)
    sweep.write_json(json_path)

    lines = csv_path.read_text().strip().splitlines()
    assert len(lines) == 1 + len(sweep.cells)
    assert lines[0].startswith("label,strategy,T_R,mode,mu")

    payload = json.loads(json_path.read_text())
    assert payload["engine"] == "batch"
    assert payload["n_runs"] == sweep.grid.n_runs
    rows = {r["label"]: r for r in payload["cells"]}
    for cr in sweep.cells:
        assert rows[cr.cell.label]["mean_waste"] == pytest.approx(cr.mean_waste)


def test_simulate_many_engines_agree():
    """The rewired simulate_many: batch and scalar engines on the same
    generated traces return matching per-run results."""
    plat = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    pred = PredictorModel(0.85, 0.82)
    strat = S.exact_prediction(plat, pred)
    rb = S.simulate_many(WORK, plat, strat, pred, n_runs=6, seed=3, engine="batch")
    rs = S.simulate_many(WORK, plat, strat, pred, n_runs=6, seed=3, engine="scalar")
    for b, s in zip(rb, rs):
        assert b.makespan == pytest.approx(s.makespan, abs=1e-3)
        assert b.n_faults == s.n_faults


def test_best_period_search_batched():
    """Batched best-period brute force: formula period's waste within 10%
    of the best grid point (paper Section 5 claim (ii))."""
    plat = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    pred = PredictorModel(0.85, 0.82)
    base = S.exact_prediction(plat, pred)
    best_t, best_w = S.best_period_search(WORK, plat, base, pred, n_runs=6, seed=5)
    assert best_t >= plat.C
    assert 0.0 < best_w < 1.0
    res = S.simulate_many(WORK, plat, base, pred, n_runs=6, seed=5)
    w_formula = float(np.mean([r.waste for r in res]))
    assert w_formula <= best_w * 1.15


def test_legacy_engine_runs():
    """The legacy (seed-pipeline) engine stays available as the perf
    baseline and returns the same structure."""
    grid = _small_grid(n_platforms=1)
    sweep = run_grid(grid, engine="legacy")
    assert sweep.engine == "legacy"
    assert sweep.dispatch == "percell"  # inherently per-cell
    for cr in sweep.cells:
        assert cr.waste.shape == (grid.n_runs,)
        assert 0.0 < cr.mean_waste < 1.0


# ---------------------------------------------------------------------- #
# fused dispatch, per-cell dispatch, stats collection, edge cases
# ---------------------------------------------------------------------- #
def _sweep_lanes_equal(a, b, exact=True):
    assert a.labels() == b.labels()
    for ca, cb in zip(a.cells, b.cells):
        if exact:
            np.testing.assert_array_equal(
                ca.makespan, cb.makespan, err_msg=ca.cell.label
            )
            np.testing.assert_array_equal(ca.waste, cb.waste)
        else:
            np.testing.assert_allclose(
                ca.makespan, cb.makespan, rtol=1e-12, err_msg=ca.cell.label
            )
        np.testing.assert_array_equal(ca.n_faults, cb.n_faults)
        np.testing.assert_array_equal(
            ca.n_proactive_ckpts, cb.n_proactive_ckpts
        )
        assert ca.n_exhausted == cb.n_exhausted


@pytest.mark.parametrize("trace_mode", ["host", "device"])
def test_fused_vs_percell_sweepresult_equality(trace_mode):
    """Acceptance gate: the fused cell-multiplexed dispatch and the
    per-cell dispatch produce identical SweepResults (per-lane arrays,
    counters, exhaustion counts) for the jax engine in both trace
    modes."""
    grid = _small_grid()
    fused = run_grid(grid, engine="jax", trace_mode=trace_mode)
    percell = run_grid(
        grid, engine="jax", trace_mode=trace_mode, dispatch="percell"
    )
    assert fused.dispatch == "fused" and percell.dispatch == "percell"
    _sweep_lanes_equal(fused, percell)
    if trace_mode == "host":  # the oracle too: per-lane rng seeds match
        sf = run_grid(grid, engine="scalar")
        sp = run_grid(grid, engine="scalar", dispatch="percell")
        _sweep_lanes_equal(sf, sp)


def test_fused_chunk_size_invariance():
    """Fused device-mode results are invariant to the chunk size (cell
    tables ride every chunk; stream ids travel with the lanes)."""
    grid = _small_grid()
    ref = run_grid(grid, engine="jax", trace_mode="device", chunk_lanes=None)
    for chunk in (4, 7):
        got = run_grid(
            grid, engine="jax", trace_mode="device", chunk_lanes=chunk
        )
        _sweep_lanes_equal(ref, got)


def test_single_cell_group():
    """A grid whose groups are all singletons (every cell its own
    failure law) exercises the one-cell megabatch path."""
    plat = Platform(mu=800 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    pred = PredictorModel(0.85, 0.82)
    cells = tuple(
        ExperimentCell(
            label=f"d{i}", work=WORK, platform=plat, predictor=pred,
            strategy=S.exact_prediction(plat, pred), fault_dist=dist,
        )
        for i, dist in enumerate(
            [E.exponential(), E.weibull(0.7), E.lognormal(1.0)]
        )
    )
    grid = GridSpec(cells, n_runs=3, seed=5)
    sj = run_grid(grid, engine="jax", trace_mode="device")
    sb = run_grid(grid, engine="batch", trace_mode="device")
    assert len(sj.cells) == 3
    for cj, cb in zip(sj.cells, sb.cells):
        np.testing.assert_allclose(cj.makespan, cb.makespan, rtol=1e-12)


def test_mixed_failure_law_grid_fused():
    """Mixed exponential/Weibull grids split into per-family megabatches
    (compilation specializes on the law); per-cell results still match
    the per-cell dispatch bit for bit."""
    grid = _small_grid()  # k0 exponential + k1 weibull
    laws = {c.dist.name for c in grid.cells}
    assert len(laws) == 2
    fused = run_grid(grid, engine="jax", trace_mode="device")
    percell = run_grid(
        grid, engine="jax", trace_mode="device", dispatch="percell"
    )
    _sweep_lanes_equal(fused, percell)


def test_per_cell_n_runs_heterogeneity():
    """Cells may override the grid's n_runs; every engine (legacy
    included) sizes its per-cell arrays accordingly, pairing holds on
    the shared-run prefix, and fused == percell."""
    plat = Platform(mu=700 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    pred = PredictorModel(0.85, 0.82, window=300.0, lead=3600.0)
    cells = (
        ExperimentCell("young", WORK, plat, pred, S.young(plat), n_runs=3),
        ExperimentCell("inst", WORK, plat, pred, S.instant(plat, pred)),
        ExperimentCell(
            "nock", WORK, plat, pred, S.nockpt(plat, pred), n_runs=7
        ),
    )
    grid = GridSpec(cells, n_runs=5, seed=9)
    assert grid.cell_n_runs == (3, 5, 7)
    assert grid.n_lanes == 15
    for engine, kw in [
        ("batch", {}), ("legacy", {}),
        ("jax", {"trace_mode": "device"}),
    ]:
        sweep = run_grid(grid, engine=engine, **kw)
        assert [c.waste.shape[0] for c in sweep.cells] == [3, 5, 7]
        for cr in sweep.cells:
            assert cr.to_row()["n_runs"] == cr.waste.shape[0]
    fused = run_grid(grid, engine="jax", trace_mode="device")
    percell = run_grid(
        grid, engine="jax", trace_mode="device", dispatch="percell"
    )
    _sweep_lanes_equal(fused, percell)
    # paired design on the shared prefix: the 3 Young lanes face the
    # same fault stream as the first 3 lanes of both window strategies
    from repro.experiments.runner import _group_cells, _group_traces

    (_, idx), = _group_cells(grid)
    tr = _group_traces(grid, idx, 0)
    np.testing.assert_array_equal(tr.fault_times[0:3], tr.fault_times[3:6])
    np.testing.assert_array_equal(tr.fault_times[3:6], tr.fault_times[8:11])


def test_grid_rejects_bad_n_runs():
    plat = Platform(mu=500 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    pred = PredictorModel(0.85, 0.82)
    cell = ExperimentCell(
        "x", WORK, plat, pred, S.young(plat), n_runs=0
    )
    with pytest.raises(ValueError, match="n_runs"):
        GridSpec((cell,), n_runs=2)


def test_stats_collect_matches_lanes_collect():
    """collect='stats' (device-reduced per-cell moments) reproduces the
    lanes-collect summary statistics to float rounding, round-trips
    through CSV/JSON, and refuses invalid combinations."""
    grid = _small_grid()
    lanes = run_grid(grid, engine="jax", trace_mode="device")
    stats = run_grid(
        grid, engine="jax", trace_mode="device", collect="stats"
    )
    assert stats.collect == "stats"
    for cl, cs in zip(lanes.cells, stats.cells):
        assert cs.waste is None and cs.stats is not None
        assert cs.n_runs == cl.n_runs
        assert cs.mean_waste == pytest.approx(cl.mean_waste, rel=1e-12)
        assert cs.ci95_waste == pytest.approx(cl.ci95_waste, rel=1e-9)
        assert cs.mean_makespan == pytest.approx(cl.mean_makespan, rel=1e-12)
        assert cs.mean_faults == pytest.approx(cl.mean_faults, rel=1e-12)
        assert cs.n_exhausted == cl.n_exhausted
        rl, rs = cl.to_row(), cs.to_row()
        for k in rl:
            if isinstance(rl[k], float) and rl[k] is not None:
                assert rs[k] == pytest.approx(rl[k], rel=1e-9, abs=1e-12), k
            else:
                assert rs[k] == rl[k], k
    with pytest.raises(ValueError, match="stats"):
        run_grid(grid, engine="batch", collect="stats")
    with pytest.raises(ValueError, match="dispatch"):
        run_grid(grid, engine="jax", collect="stats", dispatch="percell")
    with pytest.raises(ValueError, match="collect"):
        run_grid(grid, engine="jax", collect="everything")
    with pytest.raises(ValueError, match="dispatch"):
        run_grid(grid, engine="jax", dispatch="warp")
    with pytest.raises(ValueError, match="per-cell"):
        run_grid(grid, engine="legacy", dispatch="fused")


def test_fused_stats_csv_json_roundtrip(tmp_path):
    """Fused-sweep results (stats collect) serialize like any sweep and
    agree with a lanes-collect sweep row for row after the round-trip."""
    grid = _small_grid(n_platforms=1)
    stats = run_grid(grid, engine="jax", trace_mode="device", collect="stats")
    lanes = run_grid(grid, engine="jax", trace_mode="device")
    csv_path = tmp_path / "fused.csv"
    json_path = tmp_path / "fused.json"
    stats.write_csv(csv_path)
    stats.write_json(json_path)
    import csv as _csv

    with open(csv_path) as f:
        rows = {r["label"]: r for r in _csv.DictReader(f)}
    payload = json.loads(json_path.read_text())
    assert payload["engine"] == "jax"
    assert payload["dispatch"] == "fused"
    assert payload["collect"] == "stats"
    jrows = {r["label"]: r for r in payload["cells"]}
    for cr in lanes.cells:
        lab = cr.cell.label
        assert float(rows[lab]["mean_waste"]) == pytest.approx(
            cr.mean_waste, rel=1e-9
        )
        assert jrows[lab]["mean_waste"] == pytest.approx(
            cr.mean_waste, rel=1e-9
        )
        assert int(rows[lab]["n_runs"]) == cr.n_runs
