"""Batched engine vs scalar oracle: same traces, same results.

The vectorized lane-per-trace engine (core/batch_sim.py) must agree with
the scalar reference engine on every lane — across all five paper
strategies and exponential/Weibull failure laws — up to the float drift of
the clean-period fast-forward fusion (ulp-level on the makespan)."""

import math

import numpy as np
import pytest

from repro.core import (
    BatchTraces,
    Platform,
    PredictorModel,
    make_event_traces_batch,
    simulate_batch,
)
from repro.core import events as E
from repro.core import simulator as S
from repro.core.simulator import Strategy, simulate

MN = 60.0
PLAT = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN, M=5 * MN)
WORK = 20 * 86400.0
PREDW = PredictorModel(recall=0.85, precision=0.82, window=3000.0)
PRED = PredictorModel(recall=0.85, precision=0.82)
PRED0 = PredictorModel(0.0, 1.0)

#: absolute makespan tolerance: fast-forward fuses k work+checkpoint adds
#: into one multiply, drifting the clock by ~ulp per fused period
MK_TOL = 1e-3


def _strategies():
    return [
        (S.young(PLAT), PRED0),
        (S.exact_prediction(PLAT, PRED), PRED),
        (S.instant(PLAT, PREDW), PREDW),
        (S.nockpt(PLAT, PREDW), PREDW),
        (S.withckpt(PLAT, PREDW), PREDW),
        (S.migration(PLAT, PRED), PRED),
    ]


def _traces_for(strat, pred, dist, n=6, seed=42, **kw):
    rng = np.random.default_rng(seed)
    return make_event_traces_batch(
        rng,
        n,
        horizon=12 * WORK,
        mtbf=PLAT.mu,
        recall=pred.recall if strat.mode != "none" else 0.0,
        precision=pred.precision,
        window=pred.window,
        lead=pred.lead,
        fault_dist=dist,
        **kw,
    )


@pytest.mark.parametrize(
    "dist", [E.exponential(), E.weibull(0.7), E.weibull(0.5)],
    ids=["exp", "weibull0.7", "weibull0.5"],
)
def test_batch_matches_scalar_all_strategies(dist):
    """Same seeds/traces through both engines: makespan within tolerance and
    identical event counters, for all five paper strategies + migration."""
    for strat, pred in _strategies():
        traces = _traces_for(strat, pred, dist)
        br = simulate_batch(WORK, PLAT, strat, traces)
        for i in range(traces.n_lanes):
            sr = simulate(WORK, PLAT, strat, traces.lane(i))
            bl = br.lane(i)
            assert bl.makespan == pytest.approx(sr.makespan, abs=MK_TOL), (
                strat.name, dist.name, i,
            )
            assert bl.n_faults == sr.n_faults, (strat.name, dist.name, i)
            assert bl.n_regular_ckpts == sr.n_regular_ckpts
            assert bl.n_proactive_ckpts == sr.n_proactive_ckpts
            assert bl.n_migrations == sr.n_migrations
            assert bl.trace_exhausted == sr.trace_exhausted


def test_batch_matches_scalar_superposed():
    """Fresh-start superposed Weibull traces (the paper's heavy-burn-in
    scenario) agree between engines too."""
    plat = Platform(mu=250 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    strat = S.exact_prediction(plat, PRED)
    rng = np.random.default_rng(5)
    traces = make_event_traces_batch(
        rng, 4, horizon=8 * WORK / 4, mtbf=plat.mu,
        recall=PRED.recall, precision=PRED.precision,
        fault_dist=E.weibull(0.7), n_components=2**12,
    )
    br = simulate_batch(WORK / 4, plat, strat, traces)
    for i in range(traces.n_lanes):
        sr = simulate(WORK / 4, plat, strat, traces.lane(i))
        assert br.lane(i).makespan == pytest.approx(sr.makespan, abs=MK_TOL)
        assert br.lane(i).n_faults == sr.n_faults


def test_heterogeneous_lanes():
    """Per-lane platforms/strategies in one call: each lane agrees with its
    own scalar run."""
    plats = [PLAT, Platform(mu=400 * MN, C=5 * MN, D=1 * MN, R=5 * MN)]
    strats = [S.young(plats[0]), S.exact_prediction(plats[1], PRED)]
    rng = np.random.default_rng(11)
    traces = make_event_traces_batch(
        rng, 2, horizon=12 * WORK,
        mtbf=[p.mu for p in plats],
        recall=[0.0, PRED.recall],
        precision=[1.0, PRED.precision],
        window=0.0,
    )
    br = simulate_batch(WORK, plats, strats, traces)
    for i in range(2):
        sr = simulate(WORK, plats[i], strats[i], traces.lane(i))
        assert br.lane(i).makespan == pytest.approx(sr.makespan, abs=MK_TOL)


def test_tile_and_take():
    traces = _traces_for(S.young(PLAT), PRED0, E.exponential(), n=3)
    tiled = traces.tile(2)
    assert tiled.n_lanes == 6
    taken = traces.take([2, 0, 0])
    assert taken.n_lanes == 3
    assert taken.n_faults[1] == taken.n_faults[2] == traces.n_faults[0]
    br = simulate_batch(WORK, PLAT, S.young(PLAT), taken)
    assert br.lane(1).makespan == br.lane(2).makespan


def test_concat_pads_and_preserves_lanes():
    a = _traces_for(S.young(PLAT), PRED0, E.exponential(), n=2, seed=1)
    b = _traces_for(S.instant(PLAT, PREDW), PREDW, E.weibull(0.7), n=3, seed=2)
    cat = BatchTraces.concat([a, b])
    assert cat.n_lanes == 5
    np.testing.assert_array_equal(cat.n_faults[:2], a.n_faults)
    np.testing.assert_array_equal(cat.n_preds[2:], b.n_preds)
    # lane views survive the width padding
    la = a.lane(1)
    lc = cat.lane(1)
    assert [f.time for f in lc.faults] == [f.time for f in la.faults]
    strat = S.instant(PLAT, PREDW)
    br_cat = simulate_batch(WORK, PLAT, strat, cat)
    br_b = simulate_batch(WORK, PLAT, strat, b)
    for i in range(3):
        assert br_cat.lane(2 + i).makespan == br_b.lane(i).makespan


def test_batch_trace_statistics():
    """Batched generation obeys the Section 2.3 rate identities."""
    rng = np.random.default_rng(1)
    traces = make_event_traces_batch(
        rng, 8, horizon=3e7, mtbf=6e4, recall=0.7, precision=0.4, window=300.0
    )
    tr = BatchTraces.concat([traces])  # exercise the single-part path too
    rec, prec = [], []
    for i in range(tr.n_lanes):
        lane = tr.lane(i)
        rec.append(lane.empirical_recall())
        prec.append(lane.empirical_precision())
    assert abs(float(np.mean(rec)) - 0.7) < 0.05
    assert abs(float(np.mean(prec)) - 0.4) < 0.05
    # true positives sit inside their windows
    lane = tr.lane(0)
    for p in lane.predictions:
        if p.fault_time is not None:
            assert p.t0 <= p.fault_time <= p.t0 + p.window + 1e-9


def test_fractional_q_trust_filter():
    """0 < q < 1 keeps a ~q fraction of predictions (statistical check)."""
    strat = Strategy("Half", S.young(PLAT).T_R, q=0.5, mode="exact")
    traces = _traces_for(strat, PRED, E.exponential(), n=20, seed=9)
    res = simulate_batch(WORK, PLAT, strat, traces, rng=np.random.default_rng(0))
    full = simulate_batch(
        WORK, PLAT, Strategy("Full", strat.T_R, q=1.0, mode="exact"), traces
    )
    # trusting half the predictions -> roughly half the proactive ckpts
    ratio = res.n_proactive_ckpts.sum() / max(full.n_proactive_ckpts.sum(), 1)
    assert 0.3 < ratio < 0.7


def test_sentinel_columns_present():
    """Generated batches carry the trailing pad column the engine adopts."""
    traces = _traces_for(S.instant(PLAT, PREDW), PREDW, E.exponential(), n=4)
    assert traces.fault_times.shape[1] > int(traces.n_faults.max())
    assert np.all(np.isinf(traces.fault_times[:, -1]))
    assert traces.pred_t0.shape[1] > int(traces.n_preds.max())
