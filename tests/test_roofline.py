"""HLO cost-parser validation: hand-computable cases in a subprocess
(forced multi-device), checking scan trip-count weighting and collective
wire-byte factors."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.launch.hlo_analysis import analyze_hlo

    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((2, 4), ("data", "model"))

    L, D, F, B = 3, 64, 128, 16
    def step(w1, w2, x):
        def body(h, ws):
            a, b = ws
            return jnp.tanh(h @ a) @ b, ()
        h, _ = jax.lax.scan(body, x, (w1, w2))
        return jnp.sum(h * h)

    w1 = jax.ShapeDtypeStruct((L, D, F), jnp.float32)
    w2 = jax.ShapeDtypeStruct((L, F, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    in_sh = (NamedSharding(mesh, P(None, None, "model")),
             NamedSharding(mesh, P(None, "model", None)),
             NamedSharding(mesh, P("data", None)))
    c = jax.jit(step, in_shardings=in_sh).lower(w1, w2, x).compile()
    cost = analyze_hlo(c.as_text())

    # per-device matmul flops, scan-corrected: L * (2*B/2*F/4*D + 2*B/2*D*F/4)
    expect = L * (2 * (B // 2) * (F // 4) * D + 2 * (B // 2) * D * (F // 4))
    assert abs(cost.flops - expect) / expect < 0.02, (cost.flops, expect)

    # collectives: per-iter all-reduce of f32[B/2, D] over model (g=4):
    # ring factor 2*(g-1)/g -> 1.5; plus final scalar loss all-reduce over
    # data (g=2): 4 bytes * 1.0
    per_iter = (B // 2) * D * 4 * 2 * 3 / 4
    expect_coll = L * per_iter + 4 * 1.0
    assert abs(cost.collective_bytes - expect_coll) / expect_coll < 0.02, (
        cost.collective_bytes, expect_coll)

    # XLA's own cost_analysis counts the while body once -> our number
    # must exceed it for L > 1 (older JAX returns a per-device list)
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    xla_flops = ca["flops"]
    assert cost.flops > xla_flops, (cost.flops, xla_flops)
    print("ROOFLINE_PARSER_OK")
    """
)


@pytest.mark.slow
def test_parser_scan_and_collectives(tmp_path):
    script = tmp_path / "parser_check.py"
    script.write_text(SCRIPT)
    # the script resolves src relative to its own dir; symlink tests layout
    proc = subprocess.run(
        [sys.executable, str(script)],
        capture_output=True,
        text=True,
        timeout=600,
        cwd=os.path.dirname(__file__),
        env={**os.environ, "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")},
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ROOFLINE_PARSER_OK" in proc.stdout
