"""Tests for the ``repro.analysis`` static-analysis suite.

Three families mirror the three passes:

* per-rule lint fixtures — every rule fires on a seeded violation,
  stays quiet on the idiomatic negative, and honours its
  ``# repro-lint: disable=`` escape hatch;
* twin parity — the checked-in registry passes, a mutated twin (one
  rotation constant changed in memory) fails with a diff, and the
  annotation cross-check catches unregistered / unannotated twins;
* jaxpr audit — the engine's entry points pass in both trace modes,
  and deliberately seeded violations (an f32 round-trip, a host
  ``np.asarray`` of a tracer, undonated buffers) are each caught.

The lint/twin tests are pure AST work (no JAX); the audit tests trace
abstractly only — nothing in this file executes a compiled program.
"""

import json

import numpy as np
import pytest

from repro.analysis import run_all
from repro.analysis.jaxpr_audit import (
    audit_callable,
    audit_engine,
    audit_mixed_law,
)
from repro.analysis.linter import (
    lint_tree,
    load_baseline,
    partition_findings,
    repo_root,
)
from repro.analysis.rules import RULES, scan_source
from repro.analysis.twins import (
    TWIN_REGISTRY,
    TwinPair,
    check_annotations,
    check_twins,
)

ROOT = repo_root()


def _rules_of(findings):
    return {f.rule for f in findings}


# --------------------------------------------------------------------- #
# AST lint: one positive, one negative, one disable per rule
# --------------------------------------------------------------------- #
class TestHostSync:
    REL = "src/repro/core/somewhere.py"

    def test_flags_device_get(self):
        src = "import jax\n\ndef f(x):\n    return jax.device_get(x)\n"
        assert "host-sync" in _rules_of(scan_source(self.REL, src))

    def test_flags_sync_methods(self):
        src = "import jax\n\ndef f(x):\n    return x.block_until_ready()\n"
        assert "host-sync" in _rules_of(scan_source(self.REL, src))

    def test_flags_float_of_tracer_in_jit(self):
        src = (
            "import jax\nimport jax.numpy as jnp\n\n"
            "@jax.jit\ndef f(x):\n    return float(x)\n"
        )
        assert "host-sync" in _rules_of(scan_source(self.REL, src))

    def test_quiet_on_boundary_module(self):
        src = "import jax\n\ndef f(x):\n    return jax.device_get(x)\n"
        for rel in ("benchmarks/timing.py", "src/repro/experiments/runner.py"):
            assert "host-sync" not in _rules_of(scan_source(rel, src))

    def test_quiet_without_jax_import(self):
        # .item() on a plain NumPy scalar is not a device sync
        src = "import numpy as np\n\ndef f(x):\n    return np.float64(x).item()\n"
        assert "host-sync" not in _rules_of(scan_source(self.REL, src))

    def test_disable_comment(self):
        src = (
            "import jax\n\ndef f(x):\n"
            "    return jax.device_get(x)  # repro-lint: disable=host-sync\n"
        )
        assert scan_source(self.REL, src) == []


class TestTwinImport:
    TWIN = "src/repro/core/events.py"

    def test_flags_jax_import_in_twin_module(self):
        assert "twin-import" in _rules_of(scan_source(self.TWIN, "import jax\n"))

    def test_flags_from_import(self):
        src = "from jax import numpy as jnp\n"
        assert "twin-import" in _rules_of(scan_source(self.TWIN, src))

    def test_quiet_elsewhere(self):
        rel = "src/repro/core/jax_sim.py"
        assert "twin-import" not in _rules_of(scan_source(rel, "import jax\n"))

    def test_disable_comment(self):
        src = "import jax  # repro-lint: disable=twin-import\n"
        assert scan_source(self.TWIN, src) == []


class TestNpInJit:
    REL = "src/repro/core/somewhere.py"

    def test_flags_np_compute_in_jit(self):
        src = (
            "import jax\nimport numpy as np\n\n"
            "@jax.jit\ndef f(x):\n    return np.cumsum(x)\n"
        )
        assert "np-in-jit" in _rules_of(scan_source(self.REL, src))

    def test_quiet_on_dtype_references(self):
        src = (
            "import jax\nimport numpy as np\nimport jax.numpy as jnp\n\n"
            "@jax.jit\ndef f(x):\n"
            "    return jnp.asarray(x, np.float64) + np.pi\n"
        )
        assert "np-in-jit" not in _rules_of(scan_source(self.REL, src))

    def test_quiet_outside_jit(self):
        src = "import numpy as np\n\ndef f(x):\n    return np.cumsum(x)\n"
        assert "np-in-jit" not in _rules_of(scan_source(self.REL, src))

    def test_disable_comment(self):
        src = (
            "import jax\nimport numpy as np\n\n"
            "@jax.jit\ndef f(x):\n"
            "    return np.cumsum(x)  # repro-lint: disable=np-in-jit\n"
        )
        assert scan_source(self.REL, src) == []


class TestTracerBranch:
    REL = "src/repro/core/somewhere.py"

    def test_flags_if_on_tracer_param(self):
        src = (
            "import jax\n\n@jax.jit\ndef f(x):\n"
            "    if x > 0:\n        return x\n    return -x\n"
        )
        assert "tracer-branch" in _rules_of(scan_source(self.REL, src))

    def test_flags_branch_via_partial_jit_root(self):
        # jit reaches the body through functools.partial indirection
        src = (
            "import jax\nfrom functools import partial\n\n"
            "def _run(consts, state):\n"
            "    if state:\n        return consts\n    return state\n\n"
            "step = jax.jit(partial(_run, {}))\n"
        )
        assert "tracer-branch" in _rules_of(scan_source(self.REL, src))

    def test_quiet_on_static_kwonly_param(self):
        # kw-only params are the static configuration by repo convention
        src = (
            "import jax\nfrom functools import partial\n\n"
            "@partial(jax.jit, static_argnames=('mode',))\n"
            "def f(x, *, mode):\n"
            "    if mode == 'fast':\n        return x\n    return x + 1\n"
        )
        assert "tracer-branch" not in _rules_of(scan_source(self.REL, src))

    def test_quiet_on_scalar_annotated_param(self):
        # positional params annotated as Python scalars are statics too
        src = (
            "import jax\n\n@jax.jit\ndef f(x, kind: str):\n"
            "    if kind == 'exp':\n        return x\n    return x + 1\n"
        )
        assert "tracer-branch" not in _rules_of(scan_source(self.REL, src))

    def test_quiet_on_shape_branch(self):
        src = (
            "import jax\n\n@jax.jit\ndef f(x):\n"
            "    if x.ndim == 2:\n        return x\n    return x[None]\n"
        )
        assert "tracer-branch" not in _rules_of(scan_source(self.REL, src))

    def test_disable_comment(self):
        src = (
            "import jax\n\n@jax.jit\ndef f(x):\n"
            "    if x > 0:  # repro-lint: disable=tracer-branch\n"
            "        return x\n    return -x\n"
        )
        assert scan_source(self.REL, src) == []


class TestUnseededRng:
    REL = "src/repro/experiments/somewhere.py"

    def test_flags_global_rng(self):
        src = "import numpy as np\n\nx = np.random.rand(4)\n"
        assert "unseeded-rng" in _rules_of(scan_source(self.REL, src))

    def test_flags_global_seed(self):
        src = "import numpy as np\n\nnp.random.seed(0)\n"
        assert "unseeded-rng" in _rules_of(scan_source(self.REL, src))

    def test_quiet_on_default_rng(self):
        src = (
            "import numpy as np\n\n"
            "rng = np.random.default_rng(7)\nx = rng.random(4)\n"
        )
        assert "unseeded-rng" not in _rules_of(scan_source(self.REL, src))

    def test_disable_comment(self):
        src = (
            "import numpy as np\n\n"
            "x = np.random.rand(4)  # repro-lint: disable=unseeded-rng\n"
        )
        assert scan_source(self.REL, src) == []


class TestKernelDtype:
    KERNEL = "src/repro/kernels/somewhere.py"

    def test_flags_float64_literal(self):
        src = "import jax.numpy as jnp\n\ndef f(x):\n    return jnp.float64(x)\n"
        assert "kernel-dtype" in _rules_of(scan_source(self.KERNEL, src))

    def test_flags_module_level_bare_float(self):
        src = "NEG_INF = -1e30\n"
        assert "kernel-dtype" in _rules_of(scan_source(self.KERNEL, src))

    def test_flags_asarray_without_dtype(self):
        src = "import jax.numpy as jnp\n\ndef f(x):\n    return jnp.asarray(x)\n"
        assert "kernel-dtype" in _rules_of(scan_source(self.KERNEL, src))

    def test_quiet_with_explicit_dtype(self):
        src = (
            "import numpy as np\nimport jax.numpy as jnp\n\n"
            "NEG_INF = np.float32(-1e30)\n\n"
            "def f(x, dtype):\n    return jnp.asarray(x, dtype)\n"
        )
        assert "kernel-dtype" not in _rules_of(scan_source(self.KERNEL, src))

    def test_quiet_outside_kernels(self):
        src = "import jax.numpy as jnp\n\ndef f(x):\n    return jnp.asarray(x)\n"
        rel = "src/repro/core/jax_sim.py"
        assert "kernel-dtype" not in _rules_of(scan_source(rel, src))

    def test_disable_comment(self):
        src = (
            "import jax.numpy as jnp\n\ndef f(x):\n"
            "    return jnp.asarray(x)  # repro-lint: disable=kernel-dtype\n"
        )
        assert scan_source(self.KERNEL, src) == []


class TestLintTree:
    def test_repo_has_no_new_findings(self):
        findings = lint_tree(ROOT)
        new, _, stale = partition_findings(findings, load_baseline(ROOT))
        assert not new, "\n".join(f.format() for f in new)
        assert not stale, f"stale baseline entries: {stale}"

    def test_baseline_entries_are_justified(self):
        baseline = json.loads((ROOT / "LINT_BASELINE.json").read_text())
        for entry in baseline["findings"]:
            just = entry.get("justification", "")
            assert just and not just.startswith("TODO"), entry

    def test_fingerprint_survives_line_shift(self):
        src = "import jax\n\ndef f(x):\n    return jax.device_get(x)\n"
        shifted = "import jax\n\n# a new comment line\n" + src.split("\n\n", 1)[1]
        rel = "src/repro/core/somewhere.py"
        fp = lambda s: [f.fingerprint() for f in scan_source(rel, s)]
        assert fp(src) == fp(shifted)


# --------------------------------------------------------------------- #
# twin parity
# --------------------------------------------------------------------- #
class TestTwins:
    def test_registry_passes_on_checkout(self):
        errors = check_twins(ROOT)
        assert errors == [], "\n\n".join(errors)

    def test_mutated_twin_fails_with_diff(self):
        # rotate constant 31 -> 29 in the NumPy splitmix64 only
        mod = "repro.core.events"
        src = (ROOT / "src/repro/core/events.py").read_text()
        mutated = src.replace("z ^ (z >> np.uint64(31))", "z ^ (z >> np.uint64(29))")
        assert mutated != src
        errors = check_twins(ROOT, sources={mod: mutated})
        assert len(errors) == 1
        assert "splitmix64" in errors[0]
        assert "---" in errors[0] and "+++" in errors[0]  # unified diff

    def test_unannotated_registered_twin_fails(self):
        mod = "repro.core.events"
        src = (ROOT / "src/repro/core/events.py").read_text()
        stripped = src.replace(
            "# repro-twin: repro.kernels.sim_step.splitmix64\n", ""
        )
        assert stripped != src
        errors = check_annotations(ROOT, sources={mod: stripped})
        assert any("missing" in e and "splitmix64" in e for e in errors)

    def test_annotated_unregistered_twin_fails(self):
        mod = "repro.core.events"
        src = (ROOT / "src/repro/core/events.py").read_text()
        extra = src + (
            "\n\n# repro-twin: repro.kernels.sim_step.bogus\n"
            "def bogus_np(x):\n    return x\n"
        )
        errors = check_annotations(ROOT, sources={mod: extra})
        assert any("unregistered" in e and "bogus_np" in e for e in errors)

    def test_missing_function_reported(self):
        pair = TwinPair(
            "repro.core.events", "does_not_exist",
            "repro.kernels.sim_step", "splitmix64",
        )
        errors = check_twins(ROOT, registry=(pair,))
        assert any("not found" in e for e in errors)

    def test_normalizer_erases_dialect_only_noise(self):
        # pure dialect differences (np vs jnp, dtype plumbing, np.pi vs
        # its IEEE value) must compare equal
        np_side = (
            "def tw(x, dtype=None):\n"
            '    """doc"""\n'
            "    x = np.asarray(x, np.float64)\n"
            "    return np.power(x, 2.0) * (2.0 * np.pi)\n"
        )
        jnp_side = (
            "def tw(x):\n"
            "    return jnp.power(x, 2.0) * (2.0 * 3.141592653589793)\n"
        )
        pair = TwinPair("m_np", "tw", "m_jnp", "tw")
        errors = check_twins(
            ROOT, registry=(pair,),
            sources={
                "m_np": "# repro-twin: m_jnp.tw\n" + np_side,
                "m_jnp": "# repro-twin: m_np.tw\n" + jnp_side,
            },
        )
        assert errors == [], "\n\n".join(errors)


# --------------------------------------------------------------------- #
# jaxpr audit
# --------------------------------------------------------------------- #
jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402


class TestJaxprAudit:
    @pytest.mark.parametrize("trace_mode", ["device", "host"])
    def test_engine_lanes_passes(self, trace_mode):
        report = audit_engine("lanes", trace_mode)
        assert report.ok, report.format()
        assert any("donated" in p for p in report.passed)

    def test_engine_stats_passes_and_is_o_cells(self):
        report = audit_engine("stats", "device")
        assert report.ok, report.format()
        assert any("O(cells)" in p for p in report.passed)

    def test_mixed_law_single_executable(self):
        report = audit_mixed_law()
        assert report.ok, report.format()
        assert any("one executable" in p for p in report.passed)

    def test_seeded_f32_roundtrip_is_caught(self):
        def bad(x):
            return x.astype(jnp.float32).astype(jnp.float64) * 2.0

        x = np.zeros((8,), np.float64)
        report = audit_callable(bad, x, label="f32-roundtrip",
                                check_outputs=False)
        assert not report.ok
        assert any("float32" in e or "convert_element_type" in e
                   for e in report.errors)

    def test_seeded_host_transfer_is_caught(self):
        def bad(x):
            return jnp.asarray(np.asarray(x).cumsum())

        x = np.zeros((8,), np.float64)
        report = audit_callable(bad, x, label="host-transfer",
                                check_outputs=False)
        assert not report.ok
        assert any("abstract trace failed" in e for e in report.errors)

    def test_seeded_weak_type_is_caught(self):
        def bad(x):
            # jnp.asarray of a Python float carries weak_type=True
            return {"t": x.sum(), "lit": jnp.asarray(3.0)}

        x = np.zeros((8,), np.float64)
        report = audit_callable(bad, x, label="weak-type")
        assert any("weakly typed" in e for e in report.errors)

    def test_missing_donation_is_caught(self):
        def f(x):
            return x + 1.0

        x = np.zeros((8,), np.float64)
        report = audit_callable(
            f, x, label="no-donation", expect_donation="state",
            check_outputs=False,
        )
        assert any("no tf.aliasing_output" in e for e in report.errors)

    def test_schema_role_mismatch_is_caught(self):
        def bad(x):
            # 't' carries schema role "fdt" (float64 in x64) — returning
            # it as int32 must trip the schema check
            return {"t": jnp.zeros((4,), jnp.int32), "y": x}

        x = np.zeros((8,), np.float64)
        report = audit_callable(bad, x, label="schema-mismatch")
        assert any("schema role" in e for e in report.errors)


class TestRunAll:
    def test_run_all_clean_without_jaxpr(self):
        # lint + twins only (the jaxpr pass is covered above; skipping it
        # keeps this a fast AST-only smoke check of the aggregate report)
        code, report = run_all(ROOT, jaxpr=False)
        assert code == 0, json.dumps(report, indent=2)
        assert report["lint"]["new"] == []
        assert report["twins"]["errors"] == []

    def test_rule_table_is_documented(self):
        import repro.analysis as A

        for rule in RULES:
            assert f"``{rule}``" in A.__doc__
