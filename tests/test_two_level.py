"""Beyond-paper: two-level (memory buddy + disk) checkpointing model."""

import math

import numpy as np
import pytest

from repro.core.periods import t_extr, two_level_periods
from repro.core.waste import waste_two_level, waste_young

MN = 60.0
MU = 1000 * MN
C_M, C_D = 30.0, 600.0  # RAM snapshot vs durable store
D_, R_M, R_D = 60.0, 60.0, 600.0


class TestTwoLevel:
    def test_periods_are_stationary_points(self):
        f = 0.9  # 90% of failures are single-node -> buddy-recoverable
        t_m, t_d = two_level_periods(MU, C_M, C_D, f)
        eps = 1e-3

        def w(tm, td):
            return waste_two_level(tm, td, C_M, C_D, D_, R_M, R_D, MU, f)

        for _dt, fixed in ((eps, "m"), (eps, "d")):
            if fixed == "m":
                d = (w(t_m + eps, t_d) - w(t_m - eps, t_d)) / (2 * eps)
            else:
                d = (w(t_m, t_d + eps) - w(t_m, t_d - eps)) / (2 * eps)
            assert abs(d) < 1e-9

    def test_beats_single_level(self):
        """With a fast buddy tier covering most failures, two-level waste
        beats the best single-level (disk-only) Young policy."""
        f = 0.9
        t_m, t_d = two_level_periods(MU, C_M, C_D, f)
        w2 = waste_two_level(t_m, t_d, C_M, C_D, D_, R_M, R_D, MU, f)
        t1 = max(t_extr(MU, C_D), C_D)
        w1 = waste_young(t1, C_D, D_, R_D, MU)
        assert w2 < w1
        assert (w1 - w2) / w1 > 0.3  # the fast tier is a big win

    def test_reduces_to_young_when_no_memory_tier(self):
        """f -> 0: every failure needs disk; the disk term is Young's."""
        t_m, t_d = two_level_periods(MU, 1e-9, C_D, f=1e-9)
        assert t_d == pytest.approx(math.sqrt(2 * MU * C_D), rel=1e-3)

    def test_prediction_composes(self):
        """rq > 0 lengthens the MEMORY period by 1/sqrt(1-rq) — Eq (1)
        applied to the only tier predictions can shield.  The disk
        extremizer is rq-free: a disk-tier failure destroys the
        proactive memory checkpoint along with the tier, so the old
        revision's (1-rq) scaling of the disk term was a latent bug
        (refuted by all three engines)."""
        f, r, q = 0.9, 0.85, 1.0
        t_m0, t_d0 = two_level_periods(MU, C_M, C_D, f)
        t_m1, t_d1 = two_level_periods(MU, C_M, C_D, f, r, q)
        k = 1 / math.sqrt(1 - r * q)
        assert t_m1 / t_m0 == pytest.approx(k, rel=1e-6)
        assert t_d1 == pytest.approx(t_d0, rel=1e-6)

    def test_precision_zero_guard(self):
        """Regression: an active predictor with precision 0 (every
        prediction false) used to raise ZeroDivisionError through the
        proactive term ``(qr/p) C_m / mu``.  The clamp must keep the
        waste finite and monotone in p (worse precision, more waste)."""
        f, r, q = 0.9, 0.85, 1.0
        t_m, t_d = two_level_periods(MU, C_M, C_D, f, r, q, p=0.0)
        w0 = waste_two_level(t_m, t_d, C_M, C_D, D_, R_M, R_D, MU, f, r, q,
                             p=0.0)
        assert math.isfinite(w0)
        w_half = waste_two_level(t_m, t_d, C_M, C_D, D_, R_M, R_D, MU, f,
                                 r, q, p=0.5)
        w_one = waste_two_level(t_m, t_d, C_M, C_D, D_, R_M, R_D, MU, f,
                                r, q, p=1.0)
        assert w0 >= w_half >= w_one

    def test_extremizers_dominate_period_scan(self):
        """The corrected closed-form periods must beat (or match) a dense
        feasible-set scan of the same waste model — including trusted
        cells, where the old extremizers stretched the disk period by the
        spurious 1/sqrt(1-rq) factor and a scan would undercut them."""
        scan = np.geomspace(C_M, 20 * MU, 80)
        for f, r, q, p in (
            (0.9, 0.0, 0.0, 1.0),
            (0.9, 0.85, 1.0, 0.82),
            (0.5, 0.6, 0.7, 0.5),
            (0.05, 0.85, 1.0, 0.82),
        ):
            t_m, t_d = two_level_periods(
                MU, C_M, C_D, f, r, q, p, D_, R_M, R_D
            )
            w_star = waste_two_level(
                t_m, t_d, C_M, C_D, D_, R_M, R_D, MU, f, r, q, p
            )
            w_scan = min(
                waste_two_level(tm, td, C_M, C_D, D_, R_M, R_D, MU, f,
                                r, q, p)
                for tm in scan
                for td in scan
                if td >= tm and td >= C_D
            )
            # the scan is a subset of the feasible set: the closed form
            # may only undercut it, never sit above (beyond grid slack)
            assert w_star <= w_scan * (1.0 + 1e-6), (f, r, q, p)

    def test_disk_period_not_shorter_than_memory(self):
        for f in (0.05, 0.5, 0.99):
            t_m, t_d = two_level_periods(MU, C_M, C_D, f)
            assert t_d >= t_m >= C_M

    def test_disk_period_not_shorter_than_disk_checkpoint(self):
        """Regression: a tiny MTBF used to yield T_d < C_d (a disk period
        shorter than the disk checkpoint itself) — the C_d clamp was
        missing.  e.g. mu=5, C_d=50, f=0.5 gave T_d ~= 31.6."""
        t_m, t_d = two_level_periods(5.0, C_m=1.0, C_d=50.0, f=0.5)
        assert t_d >= 50.0
        assert t_d >= t_m
        for mu in (1.0, 5.0, 100.0, MU):
            for f in (1e-9, 0.3, 0.7, 1.0 - 1e-9):
                t_m, t_d = two_level_periods(mu, C_M, C_D, f)
                assert t_d >= C_D
                assert t_d >= t_m >= C_M
