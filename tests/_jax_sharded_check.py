"""Subprocess body for test_jax_sim.py's sharded-dispatch invariance test:
per-lane results of the JAX engine must be *identical* for any device
count (1/2/8 forced host devices), including ragged final shards and
chunk boundaries, and agree with the NumPy engine.  Run directly:

    python tests/_jax_sharded_check.py
"""

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8"
).strip()

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import Platform, PredictorModel, make_event_traces_batch, simulate_batch
from repro.core import simulator as S
from repro.core.jax_sim import simulate_batch_jax

assert len(jax.devices()) == 8, jax.devices()

MN = 60.0
PLAT = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN, M=5 * MN)
WORK = 8 * 86400.0
PREDW = PredictorModel(recall=0.85, precision=0.82, window=3000.0)
PRED = PredictorModel(recall=0.85, precision=0.82)

# 9 lanes: ragged against every shard width (1024 for 1 device, 128 for
# the sharded dispatch), so padding/inert-lane handling is exercised
for strat, pred in [(S.instant(PLAT, PREDW), PREDW),
                    (S.migration(PLAT, PRED), PRED)]:
    rng = np.random.default_rng(5)
    traces = make_event_traces_batch(
        rng, 9, horizon=12 * WORK, mtbf=PLAT.mu,
        recall=pred.recall, precision=pred.precision,
        window=pred.window, lead=pred.lead,
    )
    ref = simulate_batch_jax(WORK, PLAT, strat, traces, devices=1)
    ref_np = simulate_batch(WORK, PLAT, strat, traces)
    np.testing.assert_allclose(
        ref.makespan, ref_np.makespan, rtol=1e-12, atol=1e-6
    )
    for devices, chunk in [(2, "auto"), (8, "auto"), (8, 4)]:
        got = simulate_batch_jax(
            WORK, PLAT, strat, traces, devices=devices, chunk=chunk
        )
        np.testing.assert_array_equal(
            got.makespan, ref.makespan,
            err_msg=f"{strat.name} devices={devices} chunk={chunk}",
        )
        for field in ("n_faults", "n_proactive_ckpts", "n_regular_ckpts",
                      "n_migrations", "trace_exhausted"):
            np.testing.assert_array_equal(
                getattr(got, field), getattr(ref, field),
                err_msg=f"{strat.name} devices={devices} {field}",
            )
    print(f"  {strat.name}: 1/2/8-device results identical", flush=True)

# device trace generation: the counter-based RNG streams must also be
# device-count and chunk invariant (stream ids travel with the lanes)
from repro.core.events import make_trace_spec  # noqa: E402

for strat, pred in [(S.instant(PLAT, PREDW), PREDW),
                    (S.migration(PLAT, PRED), PRED)]:
    spec = make_trace_spec(
        9, horizon=12 * WORK, mtbf=PLAT.mu,
        recall=pred.recall, precision=pred.precision,
        window=pred.window, lead=pred.lead, seed=5,
    )
    ref = simulate_batch_jax(WORK, PLAT, strat, spec, devices=1)
    for devices, chunk in [(2, "auto"), (8, "auto"), (8, 4)]:
        got = simulate_batch_jax(
            WORK, PLAT, strat, spec, devices=devices, chunk=chunk
        )
        np.testing.assert_array_equal(
            got.makespan, ref.makespan,
            err_msg=f"device-gen {strat.name} devices={devices} chunk={chunk}",
        )
        np.testing.assert_array_equal(got.n_faults, ref.n_faults)
    print(f"  device-gen {strat.name}: 1/2/8-device results identical",
          flush=True)

print("JAX_SHARDED_OK")
