"""Per-architecture smoke tests: reduced same-family configs run one
forward/train step on CPU; output shapes + no NaNs (assignment (f))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.layers import RuntimeFlags
from repro.models.transformer import LanguageModel

FLAGS = RuntimeFlags(dense_attn_max=64, kv_chunk=16)


def _batch(cfg, B=2, S_tok=24):
    rng = np.random.default_rng(0)
    b = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S_tok)), jnp.int32
        )
    }
    if cfg.frontend:
        b["frontend"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_prefix, cfg.d_model)) * 0.02,
            jnp.bfloat16,
        )
    return b


@pytest.fixture(scope="module")
def models():
    return {}


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
class TestArchSmoke:
    def test_train_step(self, arch):
        cfg = configs.get(arch).reduced()
        model = LanguageModel(cfg, rules=None, flags=FLAGS)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg)

        def loss(p):
            return model.loss_fn(p, batch)[0]

        val, grads = jax.jit(jax.value_and_grad(loss))(params)
        assert jnp.isfinite(val), f"{arch}: loss not finite"
        gn = sum(
            jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(grads)
        )
        assert jnp.isfinite(gn), f"{arch}: grads not finite"
        # every parameter receives gradient signal somewhere
        n_zero = sum(
            int(jnp.all(l == 0)) for l in jax.tree.leaves(grads)
        )
        assert n_zero < len(jax.tree.leaves(grads)) * 0.5

    def test_prefill_decode_consistency(self, arch):
        """Greedy decode token from prefill == token from teacher-forced
        full forward (cache correctness)."""
        cfg = configs.get(arch).reduced()
        model = LanguageModel(cfg, rules=None, flags=FLAGS)
        params = model.init(jax.random.PRNGKey(1))
        batch = _batch(cfg, B=2, S_tok=16)
        max_seq = 16 + (cfg.frontend_prefix if cfg.frontend else 0) + 4

        logits_p, cache = jax.jit(
            lambda p, t, f: model.prefill(p, t, max_seq, f)
        )(params, batch["tokens"], batch.get("frontend"))
        assert bool(jnp.all(jnp.isfinite(logits_p.astype(jnp.float32))))

        # decode one token and verify cache pos advanced
        tok = jnp.argmax(logits_p[:, -1], axis=-1).astype(jnp.int32)[:, None]
        logits_d, cache2 = jax.jit(model.decode_step)(params, cache, tok)
        assert cache2["pos"] == cache["pos"] + 1
        assert bool(jnp.all(jnp.isfinite(logits_d.astype(jnp.float32))))

    def test_param_specs_align(self, arch):
        cfg = configs.get(arch).reduced()
        model = LanguageModel(cfg, rules=None, flags=FLAGS)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = model.param_specs()
        # identical tree structure (raises on mismatch)
        jax.tree.map(
            lambda a, b: None,
            params,
            specs,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(i, (str, type(None))) for i in x),
        )


class TestFullConfigs:
    @pytest.mark.parametrize("arch", configs.ARCH_NAMES)
    def test_published_dims(self, arch):
        cfg = configs.get(arch)
        assert cfg.num_layers % len(cfg.pattern) == 0
        assert cfg.d_model > 0 and cfg.vocab_size > 0

    def test_param_counts_match_scale(self):
        """Sanity: analytic parameter counts land near the advertised sizes."""
        expect = {
            "arctic-480b": (4.0e11, 5.4e11),
            "qwen3-moe-30b-a3b": (2.6e10, 3.4e10),
            "granite-8b": (7e9, 9e9),
            "qwen2-0.5b": (3.5e8, 7e8),
            "qwen2-72b": (6.5e10, 8.2e10),
            "smollm-135m": (1.1e8, 1.7e8),
            # advertised 3.3B; our uniform SwiGLU MLP adds the gate matrix
            "musicgen-large": (1.5e9, 3.5e9),
            "rwkv6-7b": (6e9, 9e9),
            "jamba-1.5-large-398b": (3.3e11, 4.6e11),
            "llava-next-mistral-7b": (6e9, 8.5e9),
        }
        for arch, (lo, hi) in expect.items():
            n = configs.get(arch).param_count()
            assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e},{hi:.1e}]"

    def test_moe_active_params(self):
        cfg = configs.get("qwen3-moe-30b-a3b")
        active = cfg.active_param_count()
        assert active < 0.2 * cfg.param_count()  # top-8 of 128

    def test_long_context_applicability(self):
        from repro.configs.base import SHAPES, shape_applicable

        long = SHAPES["long_500k"]
        ok_archs = {
            a for a in configs.ARCH_NAMES if shape_applicable(configs.get(a), long)[0]
        }
        assert ok_archs == {"rwkv6-7b", "jamba-1.5-large-398b"}


class TestDeterminism:
    def test_loss_deterministic(self):
        cfg = configs.get("granite-8b").reduced()
        model = LanguageModel(cfg, rules=None, flags=FLAGS)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg)
        l1 = jax.jit(lambda p: model.loss_fn(p, batch)[0])(params)
        l2 = jax.jit(lambda p: model.loss_fn(p, batch)[0])(params)
        assert float(l1) == float(l2)
