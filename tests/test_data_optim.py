"""Data pipeline determinism/sharding + optimizer correctness."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.pipeline import PrefetchIterator, SyntheticLMDataset
from repro.optim.adamw import adamw_init, adamw_update, cosine_schedule, global_norm


class TestPipeline:
    def test_deterministic_resume(self):
        d = SyntheticLMDataset(1000, 64, 8, seed=3)
        a = d.batch(17)["tokens"]
        b = d.batch(17)["tokens"]
        np.testing.assert_array_equal(a, b)

    def test_steps_differ(self):
        d = SyntheticLMDataset(1000, 64, 8, seed=3)
        assert not np.array_equal(d.batch(1)["tokens"], d.batch(2)["tokens"])

    def test_shards_partition_global_batch(self):
        shards = [
            SyntheticLMDataset(1000, 16, 8, seed=3, n_shards=4, shard=i)
            for i in range(4)
        ]
        batches = [s.batch(0)["tokens"] for s in shards]
        assert all(b.shape == (2, 16) for b in batches)
        # different shards see different data
        assert not np.array_equal(batches[0], batches[1])

    def test_tokens_in_vocab(self):
        d = SyntheticLMDataset(137, 32, 4, seed=0)
        t = d.batch(0)["tokens"]
        assert t.min() >= 0 and t.max() < 137

    def test_frontend_embeddings(self):
        d = SyntheticLMDataset(100, 8, 2, frontend_prefix=4, d_model=16)
        b = d.batch(0)
        assert b["frontend"].shape == (2, 4, 16)

    def test_prefetch_ordering(self):
        d = SyntheticLMDataset(100, 8, 2, seed=1)
        it = PrefetchIterator(d, start_step=5, depth=2)
        try:
            s0, b0 = next(it)
            s1, b1 = next(it)
            assert (s0, s1) == (5, 6)
            np.testing.assert_array_equal(b0["tokens"], d.batch(5)["tokens"])
        finally:
            it.close()


class TestAdamW:
    def _params(self):
        rng = np.random.default_rng(0)
        return {
            "w": jnp.asarray(rng.standard_normal((32, 16)), jnp.float32),
            "b": jnp.zeros((16,), jnp.float32),
        }

    def test_descends_quadratic(self):
        params = self._params()
        target = jax.tree.map(lambda p: p * 0 + 1.0, params)
        state = adamw_init(params)

        def loss(p):
            return sum(
                jnp.sum((a - t) ** 2)
                for a, t in zip(jax.tree.leaves(p), jax.tree.leaves(target))
            )

        l0 = float(loss(params))
        for _ in range(50):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(
                g, state, params, lr=0.05, weight_decay=0.0
            )
        assert float(loss(params)) < l0 * 0.2

    def test_quantized_matches_fp32_closely(self):
        params = self._params()
        s_fp = adamw_init(params)
        s_q = adamw_init(params, quantize=True)
        p_fp, p_q = params, params
        rng = np.random.default_rng(1)
        for _ in range(10):
            g = jax.tree.map(
                lambda p: jnp.asarray(
                    rng.standard_normal(p.shape), jnp.float32
                ),
                params,
            )
            p_fp, s_fp, _ = adamw_update(g, s_fp, p_fp, lr=1e-2)
            p_q, s_q, _ = adamw_update(g, s_q, p_q, lr=1e-2)
        diff = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p_fp), jax.tree.leaves(p_q))
        )
        scale = max(float(jnp.max(jnp.abs(l))) for l in jax.tree.leaves(p_fp))
        assert diff < 0.05 * scale  # 8-bit moments track fp32 closely

    def test_clipping(self):
        params = self._params()
        state = adamw_init(params)
        g = jax.tree.map(lambda p: jnp.full(p.shape, 100.0), params)
        _, _, m = adamw_update(g, state, params, lr=1e-3, clip_norm=1.0)
        assert float(m["grad_norm"]) > 1.0  # reported pre-clip

    def test_cosine_schedule(self):
        assert float(cosine_schedule(0, 1.0, warmup=10, total=100)) == 0.0
        assert float(cosine_schedule(10, 1.0, warmup=10, total=100)) == pytest.approx(1.0)
        assert float(cosine_schedule(100, 1.0, warmup=10, total=100)) == pytest.approx(0.1)

    def test_global_norm(self):
        t = {"a": jnp.ones((3,)) * 2.0}
        assert float(global_norm(t)) == pytest.approx(np.sqrt(12.0))
