"""Distribution correctness on a small forced-host-device mesh.

Runs in a subprocess because the device count must be fixed before jax
initializes (the main test process keeps the default single device, per
the assignment's instruction not to set XLA_FLAGS globally)."""

import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_sharded_numerics_subprocess():
    script = os.path.join(os.path.dirname(__file__), "_sharded_check.py")
    proc = subprocess.run(
        [sys.executable, script],
        capture_output=True,
        text=True,
        timeout=1500,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "SHARDED_CHECK_OK" in proc.stdout
