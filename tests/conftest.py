import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running end-to-end / subprocess tests"
    )
    config.addinivalue_line(
        "markers",
        "fuzz: randomized differential engine fuzz "
        "(REPRO_FUZZ_EXAMPLES scales the example budget)",
    )
