"""Statistical acceptance suite: simulation vs the paper's analytic models.

Runs the paper grid (both predictors, exact + window strategies, all
platform sizes of the ``validation`` preset) through the fused device
engine and asserts, cell by cell, that the simulated waste is
statistically compatible with the closed-form :mod:`repro.core.waste`
predictions under validity-scaled equivalence margins, with
Holm–Bonferroni control pinning the suite's family-wise false-alarm rate
(see :mod:`repro.experiments.validation` for the contract).

The per-cell z-score table is written to ``$REPRO_VALIDATION_DIR`` when
set (the CI validation job uploads it as an artifact).

Environment knobs: ``REPRO_VALIDATION_RUNS`` (Monte-Carlo repetitions per
cell, default 200) lets nightly jobs buy more power.
"""

import csv
import math
import os

import numpy as np
import pytest

from repro.core import Platform, PredictorModel
from repro.core import simulator as S
from repro.core import waste as W
from repro.experiments import (
    ExperimentCell,
    GridSpec,
    SweepResult,
    paper_grid_cells,
    run_grid,
)
from repro.experiments.paper_grid import silent_grid_cells, two_level_grid_cells
from repro.experiments.validation import (
    analytic_waste,
    cell_z_rows,
    holm_bonferroni,
    model_validity,
    validate_sweep,
    write_z_table,
)

N_RUNS = int(os.environ.get("REPRO_VALIDATION_RUNS", "200"))
SEED = 11
ALPHA = 0.01


@pytest.fixture(scope="module")
def paper_sweep():
    """One fused device-engine sweep of the validation paper grid,
    shared by every test in the module (device-reduced statistics: the
    suite itself exercises the tentpole collect='stats' path)."""
    grid = GridSpec(
        tuple(paper_grid_cells("validation")), n_runs=N_RUNS, seed=SEED
    )
    return run_grid(grid, engine="jax", trace_mode="device", collect="stats")


@pytest.fixture(scope="module")
def paper_rows(paper_sweep):
    """Full-grid z-table, written as the CI artifact before any
    assertion can fail."""
    rows, _ = validate_sweep(paper_sweep, alpha=ALPHA)
    art = os.environ.get("REPRO_VALIDATION_DIR")
    if art:
        os.makedirs(art, exist_ok=True)
        write_z_table(
            rows,
            os.path.join(art, "validation_ztable.csv"),
            os.path.join(art, "validation_ztable.json"),
        )
    return rows


@pytest.fixture(scope="module")
def scenario_sweep():
    """The two new phase families — two-level (memory + disk tiers, with
    and without a trusted predictor) and silent errors (verified
    checkpoints, detection-latency rollback) — through the SAME fused
    device dispatch with device-reduced statistics as the paper grid."""
    cells = tuple(two_level_grid_cells("validation")) + tuple(
        silent_grid_cells("validation")
    )
    grid = GridSpec(cells, n_runs=N_RUNS, seed=SEED)
    return run_grid(grid, engine="jax", trace_mode="device", collect="stats")


def _subset(sweep: SweepResult, keep) -> SweepResult:
    cells = [cr for cr in sweep.cells if keep(cr.cell)]
    return SweepResult(
        grid=sweep.grid, cells=cells, engine=sweep.engine,
        wall_time_s=0.0, dispatch=sweep.dispatch, collect=sweep.collect,
    )


def _assert_no_rejects(sweep):
    rows, fails = validate_sweep(sweep, alpha=ALPHA)
    assert not fails, "cells out of the analytic envelope:\n" + "\n".join(
        f"  {r.label}: sim={r.mean_sim:.4f} analytic={r.analytic:.4f} "
        f"margin={r.margin:.4f} z={r.z:.2f}"
        for r in fails
    )
    return rows


def test_exact_predictor_cells_match_theory(paper_sweep):
    """Equations (1)/(3) + Young: every exact-date-predictor cell (and
    the q=0 baselines and migration cells) sits inside its margin."""
    sub = _subset(paper_sweep, lambda c: c.predictor.window == 0.0)
    assert len(sub.cells) >= 18
    rows = _assert_no_rejects(sub)
    # the grid genuinely exercises prediction: trusted cells beat their
    # Young baseline where theory says they should (large mu)
    assert any(r.strategy in ("ExactPrediction", "Migration") for r in rows)


def test_window_predictor_cells_match_theory(paper_sweep):
    """Equations (4)/(5)/(6): every window-predictor cell (Instant /
    NoCkptI / WithCkptI at both window lengths) sits inside its margin."""
    sub = _subset(paper_sweep, lambda c: c.predictor.window > 0.0)
    assert len(sub.cells) >= 36
    rows = _assert_no_rejects(sub)
    assert {r.strategy for r in rows} >= {"Instant", "NoCkptI", "WithCkptI"}


def test_full_grid_family_controlled(paper_rows):
    """The headline gate: Holm over the *entire* paper grid rejects
    nothing, and the z-table covers every cell with finite statistics."""
    assert not [r for r in paper_rows if r.reject]
    assert all(math.isfinite(r.z) for r in paper_rows)
    assert all(r.se_sim > 0 for r in paper_rows)


def test_two_level_cells_match_theory(scenario_sweep):
    """The corrected two-level model (prediction shields only the memory
    tier): every untrusted AND predictor-trusted two-level cell sits
    inside its margin.  The trusted cells are the regression sentinel —
    under the old (1-rq)-scaled disk term they overshot by up to +0.30
    absolute waste (z ~ +58)."""
    sub = _subset(scenario_sweep, lambda c: c.label.startswith("tl/"))
    assert len(sub.cells) >= 18
    rows = _assert_no_rejects(sub)
    trusted = [r for r in rows if r.label.count("/") == 4]
    untrusted = [r for r in rows if r.label.count("/") == 3]
    assert trusted and untrusted
    assert all(r.strategy == "TwoLevel" for r in rows)


def test_silent_cells_match_theory(scenario_sweep):
    """The silent-error model (arXiv:1310.8486 detection-latency
    rollback): every verified-checkpoint cell sits inside its margin at
    both verification costs.  Under the strike-cursor clobbering bug the
    fused device path simulated zero corruptions (zero variance,
    z = +inf); these cells pin the counter-stream contract."""
    sub = _subset(scenario_sweep, lambda c: c.label.startswith("sil/"))
    assert len(sub.cells) >= 6
    rows = _assert_no_rejects(sub)
    assert all(r.strategy == "Silent" for r in rows)
    # the cells genuinely corrupt: Monte-Carlo noise is present
    assert all(r.se_sim > 0 for r in rows)


def test_scenario_grid_family_controlled(scenario_sweep):
    """Holm over the combined two-level + silent grid rejects nothing,
    with finite statistics in every cell (the acceptance gate of the
    scenario phase families)."""
    rows, fails = validate_sweep(scenario_sweep, alpha=ALPHA)
    assert not fails
    assert all(math.isfinite(r.z) for r in rows)
    assert all(r.se_sim > 0 for r in rows)
    assert len(rows) >= 24


def test_suite_catches_an_engine_regression(paper_sweep):
    """Power check: shifting one cell's simulated waste just past its
    overshoot margin (by 10 standard errors — the scale a lost-work
    accounting bug produces at any Monte-Carlo budget) is flagged by the
    Holm pass.  Stated relative to the cell's own margin and se so the
    check holds for every REPRO_VALIDATION_RUNS setting."""
    import copy

    from repro.experiments.validation import ABS_MARGIN, REL_MARGIN_HI

    tampered = copy.deepcopy(paper_sweep)
    victim = tampered.cells[7]
    wa = analytic_waste(victim.cell)
    se = victim.ci95_waste / 1.96
    victim.stats["mean_waste"] = (
        wa + REL_MARGIN_HI * abs(wa) + ABS_MARGIN + 10.0 * se
    )
    _, fails = validate_sweep(tampered, alpha=ALPHA)
    assert any(r.label == victim.cell.label for r in fails), (
        "a margin+10se waste shift went undetected"
    )


def test_z_table_artifact_roundtrip(paper_rows, tmp_path):
    """The artifact writer emits a parseable CSV + JSON with one row per
    cell and the Holm verdict column."""
    csv_path = tmp_path / "ztable.csv"
    json_path = tmp_path / "ztable.json"
    write_z_table(paper_rows, csv_path, str(json_path))
    with open(csv_path) as f:
        rows = list(csv.DictReader(f))
    assert len(rows) == len(paper_rows)
    assert {"label", "z", "p", "margin", "reject", "validity"} <= set(rows[0])
    import json

    payload = json.loads(json_path.read_text())
    assert payload["n_cells"] == len(paper_rows)
    assert payload["n_rejected"] == 0


# ---------------------------------------------------------------------- #
# unit tests of the statistical machinery
# ---------------------------------------------------------------------- #
def test_holm_bonferroni_step_down():
    # m=4, alpha=0.05 -> step-down thresholds .0125 / .0167 / .025 / .05:
    # 0.04 > 0.025 stops the walk, retaining everything larger too
    rej = holm_bonferroni([0.010, 0.013, 0.04, 0.20], alpha=0.05)
    assert rej.tolist() == [True, True, False, False]
    # rejection set is order-independent (sorted internally)
    rej = holm_bonferroni([0.010, 0.020, 0.011, 0.9], alpha=0.05)
    assert rej.tolist() == [True, True, True, False]
    assert holm_bonferroni([], alpha=0.05).shape == (0,)
    # uniformly more powerful than plain Bonferroni, never less
    p = [0.001, 0.012, 0.3]
    bonf = [pi <= 0.05 / 3 for pi in p]
    holm = holm_bonferroni(p, alpha=0.05)
    assert all(h or not b for h, b in zip(holm, bonf))


def test_holm_bonferroni_pins_family_wise_error():
    """Monte-Carlo FWER check: under the global null (uniform p-values)
    the fraction of families with >= 1 rejection stays ~alpha."""
    rng = np.random.default_rng(5)
    alpha, m, fam = 0.05, 20, 2000
    hits = sum(
        holm_bonferroni(rng.random(m), alpha=alpha).any() for _ in range(fam)
    )
    # FWER <= alpha; allow 4 sigma of binomial noise above it
    assert hits / fam <= alpha + 4 * math.sqrt(alpha * (1 - alpha) / fam)


def test_analytic_waste_dispatch():
    MN = 60.0
    plat = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN, M=5 * MN)
    pred = PredictorModel(0.85, 0.82)
    predw = PredictorModel(0.85, 0.82, window=3000.0)

    def cell(strat, p=pred):
        return ExperimentCell("x", 6 * 86400.0, plat, p, strat)

    y = S.young(plat)
    assert analytic_waste(cell(y)) == pytest.approx(
        W.waste_young(y.T_R, plat.C, plat.D, plat.R, plat.mu)
    )
    e = S.exact_prediction(plat, pred)
    assert analytic_waste(cell(e)) == pytest.approx(
        W.waste_exact(e.T_R, 1.0, plat.C, plat.D, plat.R, plat.mu, 0.85, 0.82)
    )
    m = S.migration(plat, pred)
    assert analytic_waste(cell(m)) == pytest.approx(
        W.waste_migration(
            m.T_R, 1.0, plat.C, plat.D, plat.R, plat.M, plat.mu, 0.85, 0.82
        )
    )
    i = S.instant(plat, predw)
    assert analytic_waste(cell(i, predw)) == pytest.approx(
        W.waste_instant(
            i.T_R, 1.0, plat.C, plat.D, plat.R, plat.mu, 0.85, 0.82,
            3000.0, 1500.0,
        )
    )
    wc = S.withckpt(plat, predw)
    assert analytic_waste(cell(wc, predw)) == pytest.approx(
        W.waste_withckpt(
            wc.T_R, wc.T_P, 1.0, plat.C, plat.D, plat.R, plat.mu,
            0.85, 0.82, 3000.0, 1500.0,
        )
    )
    # the scenario families dispatch through the same one-cell table:
    # two-level maps (T_m = T_R, T_d = rho T_R) with D+R folded per tier
    plat2 = Platform(
        mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN,
        C2=30 * MN, R2=30 * MN, f=0.85,
    )
    tl = S.two_level(plat2)

    def cell2(strat, p=pred):
        return ExperimentCell("x", 6 * 86400.0, plat2, p, strat)

    def w_tl(s, r=0.0, q=0.0, prec=1.0):
        return W.waste_two_level(
            s.T_R, s.rho * s.T_R, plat2.C, plat2.C2, 0.0,
            plat2.D + plat2.R, plat2.D + plat2.R2, plat2.mu, plat2.f,
            r, q, prec,
        )

    assert analytic_waste(cell2(tl)) == pytest.approx(w_tl(tl))
    tlt = S.two_level(plat2, pred)
    assert tlt.q == 1.0
    assert analytic_waste(cell2(tlt)) == pytest.approx(
        w_tl(tlt, 0.85, 1.0, 0.82)
    )
    plats = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN, V=5 * MN)
    sil = S.silent(plats)
    assert analytic_waste(
        ExperimentCell("x", 6 * 86400.0, plats, pred, sil)
    ) == pytest.approx(
        W.waste_silent(
            sil.T_R, plats.C, plats.V, plats.D, plats.R, plats.mu, sil.k_V
        )
    )


def test_model_validity_scales_with_period_and_window():
    MN = 60.0
    pred = PredictorModel(0.85, 0.82)
    predw = PredictorModel(0.85, 0.82, window=6000.0)
    big = Platform(mu=4000 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    small = Platform(mu=250 * MN, C=10 * MN, D=1 * MN, R=10 * MN)

    def v(plat, p, strat):
        return model_validity(ExperimentCell("x", 1e5, plat, p, strat))

    # shorter MTBF -> larger T/mu_e -> farther from validity
    assert v(small, pred, S.exact_prediction(small, pred)) > v(
        big, pred, S.exact_prediction(big, pred)
    )
    # a window adds proactive occupancy on top of the exact-date value
    assert v(small, predw, S.instant(small, predw)) > v(
        small, pred, S.exact_prediction(small, pred)
    )
    # untrusted baselines never see prediction events
    assert v(big, pred, S.young(big)) == pytest.approx(
        S.young(big).T_R / big.mu
    )


def test_model_validity_scenario_spans():
    """The scenario families widen the validity distance by their actual
    rollback span: two-level by the rho-weighted mixture of tier losses,
    silent by 2 k_V periods (a struck pattern forfeits its full wall
    time, not the T/2 mean loss of a fail-stop fault)."""
    MN = 60.0
    plat = Platform(
        mu=250 * MN, C=10 * MN, D=1 * MN, R=10 * MN,
        C2=40 * MN, R2=40 * MN, f=0.6, V=10 * MN,
    )

    def v(strat):
        return model_validity(
            ExperimentCell("x", 1e5, plat, PredictorModel(0.0, 1.0), strat)
        )

    tl = S.two_level(plat)
    f = plat.f
    assert v(tl) == pytest.approx(
        tl.T_R * (f + (1.0 - f) * tl.rho) / plat.mu
    )
    assert tl.rho > 1  # the span genuinely exceeds one memory period
    sil = S.silent(plat)
    assert v(sil) == pytest.approx(2.0 * sil.T_R * sil.k_V / plat.mu)
    assert v(sil) > sil.T_R / plat.mu


def test_cell_z_rows_margin_sides(paper_sweep):
    """The asymmetric margin: overshoot cells get the tight hi margin,
    undershoot cells the validity-scaled lo margin (>= the base)."""
    rows = cell_z_rows(paper_sweep)
    for r in rows:
        if r.delta > 0:
            assert r.margin == pytest.approx(0.12 * abs(r.analytic) + 0.004)
        else:
            assert r.margin >= 0.10 * abs(r.analytic) + 0.004 - 1e-12
            assert r.margin <= 0.55 * abs(r.analytic) + 0.004 + 1e-12
