"""The differentiable analytic layer and the unified optimizer API.

Four contracts pinned here:

* the branchless table waste models (:mod:`repro.core.analytic`) agree
  with the scalar :mod:`repro.core.waste` dispatch to float rounding on
  the whole validation grid, and their jnp twins
  (:mod:`repro.kernels.analytic`) agree with the NumPy side under x64;
* the jnp models are *differentiable*: ``jax.grad`` matches central
  finite differences of the NumPy twin (randomized parameter draws —
  hypothesis when available, fixed-seed sweep otherwise);
* the batched safeguarded-Newton optimizer dominates the host period
  scan on every grid cell and lands on the closed-form extremizer for
  the smooth families;
* ``repro.core.optimize`` reproduces every legacy ``optimize_*`` /
  ``best_policy`` / ``best_period_search`` result (the legacy names
  still work but warn), and the :class:`EngineConfig` deprecation shims
  keep the old ad-hoc engine keywords behaviour-identical.
"""

import contextlib
import warnings
from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.core import Platform, PredictorModel, optimize
from repro.core import analytic as A
from repro.core import periods as P
from repro.core import simulator as S
from repro.core import waste as W
from repro.core.analytic import PolicyTable
from repro.core.engine import EngineConfig
from repro.core.periods import OptimalPolicy
from repro.experiments import (
    ExperimentCell,
    GridSpec,
    paper_grid_cells,
    paper_policy_table,
    run_grid,
)
from repro.experiments.validation import analytic_waste, analytic_waste_batch
from repro.kernels import analytic as K

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


def _x64():
    """x64 tracing context (no-op when the session already enables it)."""
    if jax.config.jax_enable_x64:
        return contextlib.nullcontext()
    from jax.experimental import enable_x64

    return enable_x64()


def _scalar_waste(cell: ExperimentCell) -> float:
    """The legacy per-cell scalar dispatch (the pre-table
    ``validation.analytic_waste``), kept here as the oracle."""
    s, p, pred = cell.strategy, cell.platform, cell.predictor
    r, prec, I = pred.recall, pred.precision, pred.window
    if s.mode == "none" or s.q <= 0.0 or r <= 0.0:
        return W.waste_young(s.T_R, p.C, p.D, p.R, p.mu)
    if s.mode == "exact":
        if I > 0.0:
            return W.waste_instant(
                s.T_R, s.q, p.C, p.D, p.R, p.mu, r, prec, I, pred.e_f
            )
        return W.waste_exact(s.T_R, s.q, p.C, p.D, p.R, p.mu, r, prec)
    if s.mode == "migration":
        m = p.M if p.M is not None else p.C
        return W.waste_migration(s.T_R, s.q, p.C, p.D, p.R, m, p.mu, r, prec)
    if s.mode == "nockpt":
        return W.waste_nockpt(
            s.T_R, s.q, p.C, p.D, p.R, p.mu, r, prec, I, pred.e_f
        )
    if s.mode == "withckpt":
        return W.waste_withckpt(
            s.T_R, s.T_P, s.q, p.C, p.D, p.R, p.mu, r, prec, I, pred.e_f
        )
    raise ValueError(s.mode)


def _table_precision(tabs):
    with np.errstate(invalid="ignore"):
        return A.precision_from_fp(tabs["mtbf"], tabs["fp_mean"], tabs["recall"])


@pytest.fixture(scope="module")
def vcells():
    return paper_grid_cells("validation")


PLAT = Platform(mu=7500.0, C=600.0, D=60.0, R=300.0, M=300.0)
PREDS = [
    PredictorModel(0.85, 0.82),
    PredictorModel(0.7, 0.4),
    PredictorModel(0.85, 0.82, window=1200.0),
    PredictorModel(0.7, 0.4, window=6000.0),
    PredictorModel(0.0, 1.0),
]


# --------------------------------------------------------------------------- #
# Table waste models vs the scalar formulas
# --------------------------------------------------------------------------- #
class TestTableWaste:
    def test_matches_scalar_dispatch(self, vcells):
        got = A.analytic_waste_cells(vcells)
        want = np.array([_scalar_waste(c) for c in vcells])
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)

    def test_validation_reroute(self, vcells):
        batch = analytic_waste_batch(vcells)
        for wa, c in zip(batch, vcells):
            assert analytic_waste(c) == pytest.approx(float(wa), abs=1e-15)
            assert abs(float(wa) - _scalar_waste(c)) <= 1e-12

    def test_validation_batch_empty(self):
        out = analytic_waste_batch([])
        assert out.shape == (0,)

    def test_validation_unknown_mode(self, vcells):
        bad = replace(
            vcells[0], strategy=replace(vcells[0].strategy, mode="bogus")
        )
        with pytest.raises(ValueError, match="no analytic model"):
            analytic_waste_batch([bad])

    def test_precision_roundtrip(self):
        mu = np.array([7500.0, 3600.0, 1e5])
        r = np.array([0.85, 0.7, 0.0])
        p = np.array([0.82, 0.4, 1.0])
        from repro.core.events import false_prediction_mtbf_batch

        fp = false_prediction_mtbf_batch(mu, r, p)
        np.testing.assert_allclose(
            A.precision_from_fp(mu, fp, r), p, rtol=1e-12
        )

    def test_two_level_matches_scalar(self):
        rng = np.random.default_rng(3)
        for _ in range(20):
            C_m, C_d = rng.uniform(20, 200), rng.uniform(200, 900)
            D, R_m, R_d = rng.uniform(10, 90), rng.uniform(10, 90), rng.uniform(90, 500)
            mu = rng.uniform(2e3, 1e5)
            f = rng.uniform(0.1, 0.95)
            r, q, p = rng.uniform(0.05, 0.95), 1.0, rng.uniform(0.1, 0.95)
            T_m, T_d = rng.uniform(400, 3000), rng.uniform(3000, 2e4)
            want = W.waste_two_level(T_m, T_d, C_m, C_d, D, R_m, R_d, mu, f, r, q, p)
            got = A.two_level_waste(
                T_m, T_d, C_m, C_d, D, R_m, R_d, mu, f, r, q, p
            )
            assert got == pytest.approx(want, rel=1e-12)


class TestJnpTwins:
    @pytest.mark.parametrize("scale", [0.6, 1.0, 1.9])
    def test_cell_waste_twin_parity(self, vcells, scale):
        tabs = A.tables_from_cells(vcells)
        T = tabs["T_R"] * scale
        want = A.table_waste(T, tabs)
        p = _table_precision(tabs)
        with _x64():
            got = np.asarray(
                K.cell_waste(
                    T, tabs["mode"].astype(np.int32), tabs["q_eff"],
                    tabs["C"], tabs["DR"], tabs["lead_act"], tabs["mtbf"],
                    tabs["recall"], p, tabs["window"], tabs["T_P"],
                    tabs["tp_eff_default"], tabs["C2"], tabs["DR2"],
                    tabs["V"], tabs["fmem"], tabs["rho"], tabs["kv"],
                )
            )
        np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)

    def test_precision_twin_parity(self):
        mu = np.array([7500.0, 3600.0])
        fp = np.array([2e4, np.inf])
        r = np.array([0.85, 0.0])
        with _x64():
            got = np.asarray(K.precision_from_fp(mu, fp, r))
        np.testing.assert_allclose(
            got, A.precision_from_fp(mu, fp, r), rtol=1e-12
        )


# --------------------------------------------------------------------------- #
# Differentiability: jax.grad vs central finite differences
# --------------------------------------------------------------------------- #
def _model_cases(m, d):
    """(name, T -> waste) closures over a parameter draw, for module
    ``m`` (the NumPy or the jnp twin — identical signatures)."""
    C, DR, mu = d["C"], d["DR"], d["mu"]
    r, p, I = d["r"], d["p"], d["I"]
    E_f, q, M = I / 2.0, 1.0, 1.3 * d["C"]
    tp = max(1.5 * C, I / 3.0)
    return [
        ("young", lambda T: m.young_waste(T, C, DR, mu)),
        ("exact", lambda T: m.exact_waste(T, q, C, DR, mu, r, p)),
        ("migration", lambda T: m.migration_waste(T, q, C, DR, M, mu, r, p)),
        ("instant", lambda T: m.instant_waste(T, q, C, DR, mu, r, p, E_f)),
        ("nockpt", lambda T: m.nockpt_waste(T, q, C, DR, mu, r, p, I, E_f)),
        ("withckpt", lambda T: m.withckpt_waste(T, tp, q, C, DR, mu, r, p, I, E_f)),
    ]


def _check_grads(seed: int) -> None:
    rng = np.random.default_rng(seed)
    d = {
        "C": rng.uniform(60.0, 1200.0),
        "DR": rng.uniform(30.0, 500.0),
        "mu": rng.uniform(1800.0, 1e5),
        "r": rng.uniform(0.05, 0.95),
        "p": rng.uniform(0.05, 0.98),
        "I": rng.uniform(100.0, 8000.0),
    }
    T = rng.uniform(1.2, 4.0) * np.sqrt(2.0 * d["mu"] * d["C"])
    # stay off the Instant kink at T = I (min(E_f, T/2) switches there)
    if abs(T - d["I"]) < 0.05 * max(T, d["I"]):
        T *= 1.2
    h = 1e-5 * T
    np_cases = dict(_model_cases(A, d))
    with _x64():
        for name, f_jnp in _model_cases(K, d):
            f_np = np_cases[name]
            got = float(jax.grad(f_jnp)(T))
            want = (f_np(T + h) - f_np(T - h)) / (2.0 * h)
            np.testing.assert_allclose(
                got, want, rtol=1e-6, atol=1e-10,
                err_msg=f"grad mismatch for {name} (seed {seed})",
            )


class TestGradients:
    if HAVE_HYPOTHESIS:

        @settings(max_examples=25, deadline=None)
        @given(seed=st.integers(0, 2**31 - 1))
        def test_grad_matches_finite_differences(self, seed):
            _check_grads(seed)

    else:

        @pytest.mark.parametrize("seed", range(25))
        def test_grad_matches_finite_differences(self, seed):
            _check_grads(seed)

    @pytest.mark.parametrize("scale", [0.8, 1.6])
    def test_table_grad_matches_finite_differences(self, vcells, scale):
        tabs = A.tables_from_cells(vcells)
        T = tabs["T_R"] * scale
        # mask the Instant kink cells whose evaluation point sits on it
        kink = (
            (tabs["mode"] == 1)
            & (tabs["window"] > 0.0)
            & (np.abs(T - tabs["window"]) < 0.02 * np.maximum(T, tabs["window"]))
        )
        h = 1e-5 * T
        want = (A.table_waste(T + h, tabs) - A.table_waste(T - h, tabs)) / (2 * h)
        p = _table_precision(tabs)
        cols = (
            tabs["mode"].astype(np.int32), tabs["q_eff"], tabs["C"],
            tabs["DR"], tabs["lead_act"], tabs["mtbf"], tabs["recall"], p,
            tabs["window"], tabs["T_P"], tabs["tp_eff_default"],
            tabs["C2"], tabs["DR2"], tabs["V"], tabs["fmem"],
            tabs["rho"], tabs["kv"],
        )
        with _x64():
            grad_v = jax.vmap(jax.grad(K.cell_waste), in_axes=(0,) * 18)
            got = np.asarray(grad_v(T, *cols))
        np.testing.assert_allclose(
            got[~kink], want[~kink], rtol=1e-6, atol=1e-10
        )


# --------------------------------------------------------------------------- #
# The batched Newton optimizer
# --------------------------------------------------------------------------- #
class TestNewtonOptimizer:
    @pytest.fixture(scope="class")
    def newton_sol(self, vcells):
        tabs = A.tables_from_cells(vcells)
        return tabs, A.newton_optimize_tables(tabs)

    def test_dominates_host_period_scan(self, vcells, newton_sol):
        _, sol = newton_sol
        worse = []
        for i, c in enumerate(vcells):
            periods = [
                max(c.platform.C * 1.01, c.strategy.T_R * m)
                for m in S.PERIOD_GRID
            ]
            best = min(
                min(
                    _scalar_waste(
                        replace(c, strategy=replace(c.strategy, T_R=t))
                    ),
                    1.0,
                )
                for t in periods
            )
            if sol["waste"][i] > best + 1e-9:
                worse.append((c.label, float(sol["waste"][i]), best))
        assert not worse, f"Newton beaten by the host scan on {worse}"

    def test_period_matches_closed_form_extremizer(self, vcells, newton_sol):
        tabs, sol = newton_sol
        te = A.analytic_period_cells(vcells)
        # smooth families only: the Instant objective is kinked at T = I,
        # and cells whose q case analysis dropped to q=0 optimize Young's
        # model, not the q_eff one the closed form describes
        smooth = (
            (tabs["q_eff"] > 0.0)
            & (tabs["recall"] > 0.0)
            & (sol["q"] == tabs["q_eff"])
            & ~((tabs["mode"] == 1) & (tabs["window"] > 0.0))
        )
        assert smooth.any()
        np.testing.assert_allclose(
            sol["T_R"][smooth], te[smooth], rtol=1e-9
        )

    @pytest.mark.parametrize("pred", PREDS)
    @pytest.mark.parametrize(
        "family",
        ["young", "daly", "exact", "instant", "nockpt", "withckpt",
         "migration", "best"],
    )
    def test_newton_vs_analytic_policies(self, family, pred):
        newt = optimize(family, PLAT, pred, method="newton")
        assert isinstance(newt, OptimalPolicy)
        if family == "exact" and pred.window > 0.0:
            # a window predictor has no exact dates: the shared table
            # marks such a cell as the Instant objective (the lost-time
            # term q r min(E_f, T/2) is physically there), so the host
            # counterpart of the Newton answer is the Instant analysis
            inst = optimize("instant", PLAT, pred, method="newton")
            assert newt.waste == pytest.approx(inst.waste, abs=1e-12)
            host = optimize("instant", PLAT, pred, method="analytic")
        else:
            host = optimize(family, PLAT, pred, method="analytic")
        assert newt.waste <= host.waste + 1e-9
        # equality breaks where the two sides model different things:
        # Daly's period is not the model extremizer, and a degenerate
        # (window-free) WithCkptI falls back to q=0 on the host side but
        # degenerates to the exact-date strategy in the simulator tables
        if family == "daly" or (family == "withckpt" and pred.window <= 0.0):
            return
        assert newt.waste == pytest.approx(host.waste, abs=1e-9)
        if newt.q == host.q:
            assert newt.T_R == pytest.approx(host.T_R, rel=1e-6)

    def test_batched_newton_matches_scalar_calls(self):
        names = ["exact", "young", "best", "nockpt"]
        preds = [PREDS[0], PREDS[1], PREDS[2], PREDS[3]]
        table = optimize(names, PLAT, preds, method="newton")
        assert isinstance(table, PolicyTable)
        assert len(table) == 4
        for i, (name, pm) in enumerate(zip(names, preds)):
            one = optimize(name, PLAT, pm, method="newton")
            assert table.waste[i] == pytest.approx(one.waste, abs=1e-12)
            assert table.T_R[i] == pytest.approx(one.T_R, rel=1e-12)

    def test_padding_rows_do_not_leak(self, vcells):
        # a 3-cell table pads to 8 benign rows; results must match the
        # same cells solved inside the full grid
        sub = list(vcells[:3])
        sol3 = A.newton_optimize_tables(A.tables_from_cells(sub))
        soln = A.newton_optimize_tables(A.tables_from_cells(vcells))
        for k in ("T_R", "q", "waste"):
            assert sol3[k].shape == (3,)
            np.testing.assert_allclose(sol3[k], soln[k][:3], rtol=1e-12)


# --------------------------------------------------------------------------- #
# The unified optimizer API and its deprecated aliases
# --------------------------------------------------------------------------- #
def _same_policy(a: OptimalPolicy, b: OptimalPolicy) -> None:
    assert a.strategy == b.strategy
    assert a.q == b.q
    assert a.T_R == pytest.approx(b.T_R, rel=1e-15)
    assert a.waste == pytest.approx(b.waste, rel=1e-15)
    assert a.T_P == b.T_P and a.k_P == b.k_P


class TestOptimizeAPI:
    @pytest.mark.parametrize(
        "family,legacy",
        [
            ("exact", "optimize_exact"),
            ("migration", "optimize_migration"),
            ("instant", "optimize_instant"),
            ("nockpt", "optimize_nockpt"),
            ("withckpt", "optimize_withckpt"),
            ("best", "best_policy"),
        ],
    )
    @pytest.mark.parametrize("pred", PREDS)
    def test_matches_legacy_alias(self, family, legacy, pred):
        with pytest.warns(DeprecationWarning, match=f"{legacy}.*deprecated"):
            old = getattr(P, legacy)(PLAT, pred)
        new = optimize(family, PLAT, pred)
        _same_policy(new, old)
        assert new.objective == "waste" and new.value == new.waste

    def test_young_daly_match_legacy_periods(self):
        with pytest.warns(DeprecationWarning):
            ty = P.t_young(PLAT.mu, PLAT.C)
        assert optimize("young", PLAT, capped=True).T_R == pytest.approx(ty)
        with pytest.warns(DeprecationWarning):
            td = P.t_daly(PLAT.mu, PLAT.R, PLAT.C)
        assert optimize("daly", PLAT).T_R == pytest.approx(max(td, PLAT.C))
        with pytest.warns(DeprecationWarning):
            te = P.t_extr(PLAT.mu, PLAT.C)
        assert optimize("young", PLAT).T_R == pytest.approx(max(te, PLAT.C))

    def test_availability_objective(self):
        w = optimize("exact", PLAT, PREDS[0])
        av = optimize("exact", PLAT, PREDS[0], objective="availability")
        assert av.objective == "availability"
        assert av.T_R == w.T_R and av.q == w.q  # same argmin
        assert av.value == pytest.approx(1.0 - av.waste)
        table = optimize(
            ("exact", "young"), PLAT, PREDS[0], objective="availability"
        )
        np.testing.assert_allclose(table.value, 1.0 - table.waste)

    def test_policy_table_container(self):
        table = optimize(["young", "daly", "exact", "best"], PLAT, PREDS[0])
        assert len(table) == 4
        pols = list(table)
        assert all(isinstance(p, OptimalPolicy) for p in pols)
        assert table[2].strategy == "exact"
        assert pols[0].strategy == "young"

    def test_search_matches_deprecated_best_period_search(self):
        work, pred = 4 * 3600.0, PREDS[0]
        base = S.exact_prediction(PLAT, pred)
        with pytest.warns(DeprecationWarning, match="best_period_search"):
            t_old, w_old = S.best_period_search(
                work, PLAT, base, pred, n_runs=2, seed=5
            )
        pol = optimize(
            "exact", PLAT, pred, method="search", work=work, n_runs=2, seed=5
        )
        assert pol.T_R == t_old
        assert pol.waste == min(w_old, 1.0)

    def test_errors(self):
        with pytest.raises(ValueError, match="unknown strategy 'quantum'"):
            optimize("quantum", PLAT)
        with pytest.raises(ValueError, match="unknown objective 'speed'"):
            optimize("exact", PLAT, PREDS[0], objective="speed")
        with pytest.raises(ValueError, match="unknown method 'sgd'"):
            optimize("exact", PLAT, PREDS[0], method="sgd")
        with pytest.raises(ValueError, match="not supported with method='search'"):
            optimize("best", PLAT, PREDS[0], method="search")
        with pytest.raises(ValueError, match="sequence length"):
            optimize(["exact", "young"], [PLAT], PREDS[0])
        with pytest.raises(ValueError, match="not both"):
            optimize(
                "exact", PLAT, PREDS[0], method="search",
                config=EngineConfig(), engine="batch",
            )

    def test_optimize_cells(self, vcells):
        table = A.optimize_cells(vcells)
        assert len(table) == len(vcells)
        aw = np.minimum(A.analytic_waste_cells(vcells), 1.0)
        assert np.all(table.waste <= aw + 1e-9)
        with pytest.raises(ValueError, match="method='newton' only"):
            A.optimize_cells(vcells[:2], method="analytic")

    def test_paper_policy_table(self, vcells):
        table = paper_policy_table()
        assert isinstance(table, PolicyTable)
        assert len(table) == len(vcells)
        assert table.T_P is not None and len(table.T_P) == len(vcells)


# --------------------------------------------------------------------------- #
# EngineConfig and the legacy-keyword deprecation shims
# --------------------------------------------------------------------------- #
def _tiny_grid():
    plat = Platform(mu=5000.0, C=120.0, D=60.0, R=120.0)
    pred = PredictorModel(0.85, 0.82)
    cell = ExperimentCell(
        "tiny/exact", 4 * 3600.0, plat, pred, S.exact_prediction(plat, pred)
    )
    return GridSpec((cell,), n_runs=3, seed=2)


class TestEngineConfig:
    def test_run_grid_legacy_kwargs_warn_and_match(self):
        grid = _tiny_grid()
        want = run_grid(grid, EngineConfig())
        with pytest.warns(DeprecationWarning, match="run_grid.*deprecated"):
            got = run_grid(grid, engine="batch")
        assert got.cells[0].mean_waste == want.cells[0].mean_waste

    def test_run_grid_positional_engine_string(self):
        grid = _tiny_grid()
        want = run_grid(grid, EngineConfig())
        with pytest.warns(DeprecationWarning):
            got = run_grid(grid, "batch")
        assert got.cells[0].mean_waste == want.cells[0].mean_waste

    def test_simulate_many_legacy_kwargs_warn_and_match(self):
        plat, pred = PLAT, PREDS[0]
        strat = S.exact_prediction(plat, pred)
        want = S.simulate_many(
            4 * 3600.0, plat, strat, pred, n_runs=2, seed=1,
            config=EngineConfig(),
        )
        with pytest.warns(DeprecationWarning, match="simulate_many.*deprecated"):
            got = S.simulate_many(
                4 * 3600.0, plat, strat, pred, n_runs=2, seed=1, engine="batch"
            )
        assert [r.waste for r in got] == [r.waste for r in want]

    def test_config_plus_legacy_is_an_error(self):
        with pytest.raises(ValueError, match="not both"):
            run_grid(_tiny_grid(), EngineConfig(), engine="batch")

    def test_bad_config_type(self):
        with pytest.raises(TypeError, match="must be an EngineConfig"):
            run_grid(_tiny_grid(), 42)

    def test_historical_engine_error_message(self):
        with pytest.raises(ValueError, match="unknown engine 'quantum'"):
            run_grid(_tiny_grid(), EngineConfig(engine="quantum"))

    def test_validate(self):
        with pytest.raises(ValueError, match="require engine='jax'"):
            EngineConfig(devices="all").validate()
        with pytest.raises(ValueError, match="unknown trace_mode"):
            EngineConfig(trace_mode="bogus").validate()
        with pytest.raises(ValueError, match="unknown collect"):
            EngineConfig(collect="bogus").validate()
        cfg = EngineConfig().replace(engine="jax", collect="stats")
        assert cfg.validate() is cfg
        assert cfg.engine == "jax" and cfg.collect == "stats"

    def test_internal_callers_emit_no_deprecations(self):
        # the repo's own entry points all pass EngineConfig explicitly
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            run_grid(_tiny_grid(), EngineConfig())


# --------------------------------------------------------------------------- #
# Shared table layout and the grid's analytic columns
# --------------------------------------------------------------------------- #
class TestTableLayout:
    def test_tables_from_cells_columns(self, vcells):
        tabs = A.tables_from_cells(vcells)
        n = len(vcells)
        for key in A.TABLE_COLS + ("T_R", "fp_mean"):
            assert key in tabs, key
            assert tabs[key].shape[0] == n, key
        assert np.issubdtype(tabs["mode"].dtype, np.integer)
        assert set(np.unique(tabs["mode"])) <= {0, 1, 2, 3, 4}

    def test_sweep_rows_carry_analytic_columns(self):
        sweep = run_grid(_tiny_grid(), EngineConfig())
        row = sweep.to_rows()[0]
        assert "analytic_waste" in row and "analytic_period" in row
        cr = sweep.cells[0]
        assert row["analytic_waste"] == pytest.approx(
            analytic_waste(cr.cell), rel=1e-12
        )
        assert cr.analytic_waste == pytest.approx(
            analytic_waste(cr.cell), rel=1e-12
        )
        assert cr.analytic_period == pytest.approx(
            float(A.analytic_period_cells([cr.cell])[0]), rel=1e-12
        )
