"""Subprocess body for test_sharding.py: numerical equivalence of the
sharded (GSPMD + shard_map MoE) execution vs single-device, on 8 forced
host devices.  Run directly:  python tests/_sharded_check.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.steps import (
    build_model,
    build_train_step,
    decode_arg_structs,
    train_arg_structs,
)
from repro.models.layers import RuntimeFlags
from repro.models.transformer import LanguageModel
from repro.optim.adamw import adamw_init

from repro.launch.mesh import make_mesh_compat

assert len(jax.devices()) == 8, jax.devices()

mesh = make_mesh_compat((2, 4), ("data", "model"))
# chunked attention exercised via tiny dense_attn_max; capacity factor is
# raised so no MoE tokens drop — capacity dropping is legitimately
# locality-dependent (per-DP-group vs global), which would differ between
# the sharded and single-device runs by design
FLAGS = RuntimeFlags(dense_attn_max=16, kv_chunk=8, moe_capacity_factor=4.0)


def check_arch(name: str) -> None:
    cfg = configs.get(name).reduced()
    model_1d = LanguageModel(cfg, rules=None, flags=FLAGS)
    model_sh, rules = build_model(cfg, mesh, FLAGS)

    params = model_1d.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 4, 32
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    }
    if cfg.frontend:
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_prefix, cfg.d_model)) * 0.02,
            jnp.bfloat16,
        )

    loss_1d, _ = jax.jit(model_1d.loss_fn)(params, batch)

    with mesh:
        loss_sh, _ = jax.jit(model_sh.loss_fn)(params, batch)
    err = abs(float(loss_1d) - float(loss_sh))
    assert err < 5e-2, f"{name}: sharded loss mismatch {loss_1d} vs {loss_sh}"

    # decode parity
    max_seq = S + (cfg.frontend_prefix or 0) + 8
    logits_1d, cache_1d = jax.jit(
        lambda p, t, f: model_1d.prefill(p, t, max_seq, f)
    )(params, batch["tokens"], batch.get("frontend"))
    with mesh:
        logits_sh, cache_sh = jax.jit(
            lambda p, t, f: model_sh.prefill(p, t, max_seq, f)
        )(params, batch["tokens"], batch.get("frontend"))
    # bf16 reduction-order noise flips argmax among near-ties on tiny
    # random-weight models; assert numeric closeness of the logits and a
    # loose argmax majority instead
    l1 = np.asarray(logits_1d[:, -1], np.float32)
    l2 = np.asarray(logits_sh[:, -1], np.float32)
    lerr = np.abs(l1 - l2).max()
    # bf16 partial-sum reordering through 8+ residual layers yields O(0.1-1)
    # per-logit noise on random-weight reduced models; the token-mean loss
    # (checked above to 5e-2) is the meaningful numerical invariant
    assert lerr < 1.5, f"{name}: prefill logits diverge ({lerr})"
    tok_1d = l1.argmax(-1)
    tok_sh = l2.argmax(-1)
    agree = (tok_1d == tok_sh).mean()
    assert agree >= 0.5, f"{name}: prefill argmax agreement {agree}"
    print(f"  {name}: loss err {err:.2e}, logits err {lerr:.3f}, "
          f"agreement {agree:.2f}", flush=True)


def check_train_step_compiles_and_runs(name: str) -> None:
    """Full train step with ZeRO shardings executes on the 2x4 mesh."""
    cfg = configs.get(name).reduced()
    model, rules = build_model(cfg, mesh, FLAGS)
    from repro.configs.base import ShapeConfig

    shape = ShapeConfig("tiny", 32, 4, "train")
    step = build_train_step(model, micro_batches=2)
    args, in_sh, out_sh = train_arg_structs(model, shape, rules)
    params = model.init(jax.random.PRNGKey(1))
    opt = adamw_init(params, quantize=cfg.optimizer == "adamw8bit")
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
    }
    if cfg.frontend:
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((4, cfg.frontend_prefix, cfg.d_model)) * 0.02,
            jnp.bfloat16,
        )
    with mesh:
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        p2, o2, metrics = fn(params, opt, batch)
        p3, o3, m2 = fn(p2, o2, batch)
    assert np.isfinite(float(metrics["loss"])), name
    assert float(m2["loss"]) < float(metrics["loss"]) + 1.0
    print(f"  {name}: sharded train step loss {float(metrics['loss']):.3f} -> "
          f"{float(m2['loss']):.3f}", flush=True)


for arch in ["qwen3-moe-30b-a3b", "granite-8b", "rwkv6-7b", "jamba-1.5-large-398b"]:
    check_arch(arch)
for arch in ["qwen3-moe-30b-a3b", "smollm-135m"]:
    check_train_step_compiles_and_runs(arch)
print("SHARDED_CHECK_OK")
