"""ElasticManager spare accounting / shrink semantics and
StragglerDetector windowing, patience and strike-reset behaviour."""

import numpy as np

from repro.ft import ElasticManager, StragglerDetector


class TestElasticManager:
    def test_initial_pools(self):
        em = ElasticManager(n_nodes=4, n_spares=2)
        assert em.active == {0, 1, 2, 3}
        assert em.spares == [4, 5]
        assert em.retired == set()
        assert em.world_size == 4

    def test_migrate_explicit_node_spare_accounting(self):
        em = ElasticManager(n_nodes=4, n_spares=2)
        ev = em.migrate(node=1, reason="prediction")
        assert ev["kind"] == "migration"
        assert ev["from"] == 1 and ev["to"] == 4
        assert not ev["shrunk"]
        assert 1 in em.retired and 1 not in em.active
        assert 4 in em.active and em.spares == [5]
        assert em.world_size == 4  # swap preserves the world size

    def test_spares_consumed_in_order(self):
        em = ElasticManager(n_nodes=3, n_spares=2)
        assert em.migrate(node=0)["to"] == 3
        assert em.migrate(node=1)["to"] == 4

    def test_migrate_default_picks_an_active_node(self):
        em = ElasticManager(n_nodes=2, n_spares=1)
        ev = em.migrate()
        assert ev["from"] in {0, 1}
        assert ev["from"] in em.retired

    def test_shrink_when_spares_exhausted(self):
        em = ElasticManager(n_nodes=3, n_spares=1)
        em.migrate(node=0)  # consumes the only spare
        ev = em.migrate(node=1)
        assert ev["kind"] == "shrink" and ev["shrunk"] and ev["to"] is None
        assert em.world_size == 2  # 3 -> swap keeps 3 -> shrink drops to 2

    def test_lose_node_is_failure_reason(self):
        em = ElasticManager(n_nodes=4, n_spares=1)
        ev = em.lose_node(2)
        assert ev["reason"] == "failure" and ev["from"] == 2
        assert not ev["shrunk"]
        assert em.world_size == 4

    def test_events_log_ordered(self):
        em = ElasticManager(n_nodes=3, n_spares=1, migration_cost=123.0)
        em.migrate(node=0, reason="prediction")
        em.lose_node(1)
        assert [e["kind"] for e in em.events] == ["migration", "shrink"]
        assert [e["reason"] for e in em.events] == ["prediction", "failure"]
        assert all(e["cost"] == 123.0 for e in em.events)


class TestStragglerDetector:
    def _feed(self, det, times_by_rank, rounds):
        for _ in range(rounds):
            for r, t in times_by_rank.items():
                det.record(r, t)

    def test_needs_window_of_evidence(self):
        det = StragglerDetector(n_ranks=2, window=8, patience=1)
        det.record(0, 1.0)
        det.record(1, 9.0)
        assert det.check() == []  # fewer than window//2 samples per rank

    def test_needs_two_ranks_reporting(self):
        det = StragglerDetector(n_ranks=4, window=4, patience=1)
        self._feed(det, {0: 5.0}, rounds=4)
        assert det.check() == []  # no cross-rank median to compare with

    def test_patience_gates_flagging(self):
        det = StragglerDetector(n_ranks=3, window=4, threshold=1.5,
                                patience=3)
        self._feed(det, {0: 1.0, 1: 1.0, 2: 4.0}, rounds=4)
        assert det.check() == []  # strike 1
        assert det.check() == []  # strike 2
        assert det.check() == [2]  # strike 3 == patience

    def test_strikes_reset_when_rank_recovers(self):
        det = StragglerDetector(n_ranks=2, window=4, threshold=1.5,
                                patience=2)
        self._feed(det, {0: 1.0, 1: 4.0}, rounds=4)
        assert det.check() == []  # strike 1 for rank 1
        self._feed(det, {0: 1.0, 1: 1.0}, rounds=4)  # rank 1 recovers
        assert det.check() == []  # strikes reset to zero
        self._feed(det, {0: 1.0, 1: 4.0}, rounds=4)
        assert det.check() == []  # strike 1 again, not 2: reset held
        assert det.check() == [1]  # strike 2 == patience: flagged now

    def test_threshold_is_relative_to_global_median(self):
        det = StragglerDetector(n_ranks=3, window=4, threshold=2.0,
                                patience=1)
        # rank 2 is 1.8x the median: below the 2.0 threshold, never flagged
        self._feed(det, {0: 1.0, 1: 1.0, 2: 1.8}, rounds=4)
        assert det.check() == []

    def test_multiple_stragglers(self):
        det = StragglerDetector(n_ranks=5, window=4, threshold=1.5,
                                patience=1)
        self._feed(det, {0: 1.0, 1: 1.0, 2: 1.0, 3: 3.0, 4: 5.0}, rounds=4)
        assert sorted(det.check()) == [3, 4]

    def test_noisy_uniform_fleet_stays_clean(self):
        det = StragglerDetector(n_ranks=6, window=8, patience=2)
        rng = np.random.default_rng(3)
        for _ in range(50):
            for r in range(6):
                det.record(r, 1.0 + rng.normal(0.0, 0.05))
            assert det.check() == []
