"""Differential engine fuzz: random experiment cells through every engine.

Each example draws a random cell (failure law, strategy mode, window,
trust, recall/precision, platform scale), pairs it with a Young baseline
on the same traces, and runs the grid through scalar vs batch vs jax —
host *and* device trace modes, fused *and* per-cell dispatch — asserting
the engine-equivalence contracts:

* host trace mode: batch and jax consume identical event arrays, so
  per-lane makespans agree to float rounding; the scalar oracle agrees
  to the fast-forward tolerance; fused and per-cell dispatch are
  bit-identical (deterministic trust);
* device trace mode: fused and per-cell dispatch are bit-identical
  (counter streams travel with the lanes); the batch engine replaying
  the materialized streams matches exactly for exact-date predictions
  and statistically (TP merge order) for windows;
* mixed-law grids (the drawn law + its successor): the one-dispatch
  law-indexed path is bit-identical to the per-family baseline and
  float-rounding-close to the law-specialized per-cell dispatch.

Uses hypothesis when available (the ``fuzz`` marker lets CI run a larger
budget nightly via ``REPRO_FUZZ_EXAMPLES``); falls back to a fixed-seed
parameter sweep otherwise so the differential coverage never silently
disappears.
"""

import dataclasses
import math
import os

import numpy as np
import pytest

from repro.core import Platform, PredictorModel
from repro.core import events as E
from repro.core import simulator as S
from repro.experiments import ExperimentCell, GridSpec, run_grid

pytestmark = pytest.mark.fuzz

MN = 60.0
N_EXAMPLES = int(os.environ.get("REPRO_FUZZ_EXAMPLES", "6"))

#: fixed distribution instances — the failure law statically specializes
#: the compiled device sampler, so a bounded set keeps the fuzz budget in
#: executables small while still crossing every family
LAWS = {
    "exp": E.exponential(),
    "weibull0.7": E.weibull(0.7),
    "weibull0.5": E.weibull(0.5),
    "lognormal": E.lognormal(1.0),
}
MODES = [
    "none", "exact", "two_level", "silent", "nockpt", "withckpt",
    "migration",
]
#: modes whose strategy factory fixes q itself (silent is never
#: predictor-trusted; two_level trusts iff it is built with a predictor)
_FIXED_Q_MODES = ("none", "two_level", "silent")

#: scalar-vs-vectorized tolerance (fast-forward float fusion)
MK_TOL = 1e-3


def _make_grid(mu_mn, c_mn, law_key, mode, window, q, recall, precision, seed):
    plat = Platform(
        mu=mu_mn * MN, C=c_mn * MN, D=1 * MN, R=c_mn * MN, M=3 * MN,
        # scenario knobs, inert for the paper modes: a 3x-cost disk tier
        # covering the non-buddy failures, and a half-checkpoint-cost
        # verification step
        C2=3 * c_mn * MN, R2=3 * c_mn * MN, f=0.8, V=0.5 * c_mn * MN,
    )
    work = 5 * 86400.0
    pred = PredictorModel(recall, precision, window=window, lead=3600.0)
    if mode == "none":
        strat = S.young(plat)
    elif mode == "exact":
        strat = S.instant(plat, pred) if window > 0 else S.exact_prediction(plat, pred)
    elif mode == "two_level":
        # exact-date predictions only (proactive memory checkpoints);
        # q <= 0 draws the untrusted factory variant
        epred = dataclasses.replace(pred, window=0.0)
        strat = S.two_level(plat, epred if q > 0 else None)
        pred = epred
    elif mode == "silent":
        strat = S.silent(plat)  # corruptions are never predicted: q = 0
    elif mode == "nockpt":
        strat = S.nockpt(plat, pred)
    elif mode == "withckpt":
        strat = S.withckpt(plat, pred)
    else:
        strat = S.migration(plat, pred)
    if q != strat.q and strat.mode not in _FIXED_Q_MODES:
        strat = dataclasses.replace(strat, q=q)
    cells = (
        ExperimentCell(
            "base/Young", work, plat, pred, S.young(plat),
            fault_dist=LAWS[law_key],
        ),
        ExperimentCell(
            f"rand/{strat.name}", work, plat, pred, strat,
            fault_dist=LAWS[law_key],
        ),
    )
    return GridSpec(cells, n_runs=3, seed=seed)


def _assert_lanes_equal(a, b, exact=True, context=""):
    for ca, cb in zip(a.cells, b.cells):
        if exact:
            np.testing.assert_array_equal(
                ca.makespan, cb.makespan, err_msg=f"{context}:{ca.cell.label}"
            )
        else:
            np.testing.assert_allclose(
                ca.makespan, cb.makespan, rtol=1e-12, atol=1e-6,
                err_msg=f"{context}:{ca.cell.label}",
            )
        np.testing.assert_array_equal(
            ca.n_faults, cb.n_faults, err_msg=f"{context}:{ca.cell.label}"
        )


def _check_differential(mu_mn, c_mn, law_key, mode, window, q, recall,
                        precision, seed):
    grid = _make_grid(
        mu_mn, c_mn, law_key, mode, window, q, recall, precision, seed
    )
    # ---- host trace mode: three engines, two dispatch granularities --- #
    sb = run_grid(grid, engine="batch")
    sj = run_grid(grid, engine="jax")
    _assert_lanes_equal(sj, sb, exact=False, context="jax-vs-batch")
    sjp = run_grid(grid, engine="jax", dispatch="percell")
    sbp = run_grid(grid, engine="batch", dispatch="percell")
    if q in (0.0, 1.0):  # deterministic trust: dispatch is invisible
        _assert_lanes_equal(sjp, sj, context="jax-percell-vs-fused")
        _assert_lanes_equal(sbp, sb, context="batch-percell-vs-fused")
        ss = run_grid(grid, engine="scalar")
        for cs, cb in zip(ss.cells, sb.cells):
            np.testing.assert_allclose(
                cs.makespan, cb.makespan, atol=MK_TOL,
                err_msg=f"scalar-vs-batch:{cs.cell.label}",
            )
            np.testing.assert_array_equal(cs.n_faults, cb.n_faults)

    # ---- device trace mode (counter streams) -------------------------- #
    sjd = run_grid(grid, engine="jax", trace_mode="device")
    sjdp = run_grid(grid, engine="jax", trace_mode="device", dispatch="percell")
    _assert_lanes_equal(sjdp, sjd, context="device-percell-vs-fused")
    sbd = run_grid(grid, engine="batch", trace_mode="device")
    if window == 0.0:
        # exact-date predictions: the materialized replay is the same
        # event sequence — float-rounding agreement
        _assert_lanes_equal(sjd, sbd, exact=False, context="device-jax-vs-batch")
    else:
        # window TP merge order differs (fault order vs time sort):
        # agreement is at the episode scale, not bit-exact
        for ca, cb in zip(sjd.cells, sbd.cells):
            np.testing.assert_allclose(
                ca.makespan, cb.makespan, rtol=5e-3,
                err_msg=f"device-window:{ca.cell.label}",
            )
    # per-cell mean waste is engine-invariant within MC resolution
    for ca, cb in zip(sjd.cells, sbd.cells):
        assert abs(ca.mean_waste - cb.mean_waste) < 2e-3, ca.cell.label

    # ---- mixed-law grid: the drawn law + its successor in one fused
    # dispatch through the law-indexed sampler ----------------------- #
    law2 = sorted(LAWS)[(sorted(LAWS).index(law_key) + 1) % len(LAWS)]
    mixed = GridSpec(
        tuple(
            dataclasses.replace(
                c, label=f"{lk}/{c.label}", fault_dist=LAWS[lk]
            )
            for lk in (law_key, law2)
            for c in grid.cells
        ),
        n_runs=grid.n_runs, seed=grid.seed,
    )
    mf = run_grid(mixed, engine="jax", trace_mode="device")
    mpf = run_grid(
        mixed, engine="jax", trace_mode="device", dispatch="perfamily"
    )
    # per-family runs the same law-indexed sampler: bit-identical
    _assert_lanes_equal(mpf, mf, context="mixed-perfamily-vs-fused")
    mpc = run_grid(
        mixed, engine="jax", trace_mode="device", dispatch="percell"
    )
    # per-cell uses the law-*specialized* static samplers: exact up to
    # XLA's per-context transcendental fusion (lognormal ~1e-12 rel)
    for ca, cb in zip(mf.cells, mpc.cells):
        np.testing.assert_allclose(
            ca.makespan, cb.makespan, rtol=1e-9,
            err_msg=f"mixed-percell-vs-fused:{ca.cell.label}",
        )


def _params_from_seed(i: int):
    rng = np.random.default_rng(1000 + i)
    return {
        "mu_mn": float(rng.uniform(400.0, 2000.0)),
        "c_mn": float(rng.uniform(3.0, 15.0)),
        "law_key": sorted(LAWS)[i % len(LAWS)],
        "mode": MODES[i % len(MODES)],
        "window": [0.0, 1500.0, 4000.0][i % 3],
        "q": float(i % 2),
        "recall": float(rng.uniform(0.3, 0.95)),
        "precision": float(rng.uniform(0.3, 0.95)),
        "seed": int(rng.integers(0, 10_000)),
    }


try:
    from hypothesis import given, settings, strategies as st
except ImportError:

    @pytest.mark.parametrize("i", range(N_EXAMPLES))
    def test_differential_engines(i):
        _check_differential(**_params_from_seed(i))

else:

    # derandomize: the window-mode device-vs-host agreement bounds are
    # statistical (empirically calibrated), so the example set must be
    # deterministic per budget — same contract as the fixed-seed
    # fallback, and no irreproducible CI-only failures
    @settings(max_examples=N_EXAMPLES, deadline=None, derandomize=True)
    @given(
        mu_mn=st.floats(400.0, 2000.0),
        c_mn=st.floats(3.0, 15.0),
        law_key=st.sampled_from(sorted(LAWS)),
        mode=st.sampled_from(MODES),
        window=st.sampled_from([0.0, 1500.0, 4000.0]),
        q=st.sampled_from([0.0, 1.0]),
        recall=st.floats(0.3, 0.95),
        precision=st.floats(0.3, 0.95),
        seed=st.integers(0, 10_000),
    )
    def test_differential_engines(
        mu_mn, c_mn, law_key, mode, window, q, recall, precision, seed
    ):
        _check_differential(
            mu_mn, c_mn, law_key, mode, window, q, recall, precision, seed
        )


@pytest.mark.parametrize(
    "mode,q",
    [("two_level", 0.0), ("two_level", 1.0), ("silent", 0.0)],
)
def test_scenario_modes_differential(mode, q):
    """Guaranteed coverage of the scenario phase families regardless of
    the fuzz budget: two-level (untrusted + predictor-trusted) and
    silent-error lanes through the full three-engine / two-trace-mode /
    two-dispatch differential contract."""
    _check_differential(
        mu_mn=900.0, c_mn=6.0, law_key="weibull0.7", mode=mode,
        window=0.0, q=q, recall=0.8, precision=0.7, seed=42,
    )


def test_fractional_trust_dispatch_invariance():
    """Device trace mode draws trust coins from per-event counter
    streams, so even fractional q is bit-identical between fused and
    per-cell dispatch (host mode only promises distributional agreement
    there)."""
    grid = _make_grid(
        mu_mn=800.0, c_mn=8.0, law_key="exp", mode="exact", window=0.0,
        q=0.5, recall=0.8, precision=0.6, seed=77,
    )
    fused = run_grid(grid, engine="jax", trace_mode="device")
    percell = run_grid(
        grid, engine="jax", trace_mode="device", dispatch="percell"
    )
    _assert_lanes_equal(percell, fused, context="frac-q-device")
    # and the trusted cell actually acts on some predictions
    assert sum(c.mean_proactive_ckpts for c in fused.cells) > 0


def test_fuzz_examples_budget_env():
    """The nightly knob is wired: the example budget follows the env."""
    assert N_EXAMPLES >= 1
    assert math.isfinite(N_EXAMPLES)
