"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU, per the assignment)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.ckpt_codec import dequantize_blocks, quantize_blocks
from repro.kernels.decode_attention import decode_attention_bhd
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rwkv6 import wkv6_bhsd


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


ATTN_SHAPES = [
    # (BH, S, hd, blk_q, blk_k)
    (2, 64, 32, 32, 32),
    (4, 128, 64, 64, 32),
    (1, 256, 16, 64, 64),
    (3, 128, 128, 128, 128),
]


class TestFlashAttention:
    @pytest.mark.parametrize("bh,s,hd,bq,bk", ATTN_SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal", [True, False])
    def test_vs_oracle(self, bh, s, hd, bq, bk, dtype, causal):
        rng = np.random.default_rng(hash((bh, s, hd, str(dtype), causal)) % 2**31)
        q = _rand(rng, (bh, s, hd), dtype)
        k = _rand(rng, (bh, s, hd), dtype)
        v = _rand(rng, (bh, s, hd), dtype)
        got = flash_attention_bhsd(
            q, k, v, causal=causal, blk_q=bq, blk_k=bk, interpret=True
        )
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        tol = 2e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=tol, rtol=tol,
        )

    def test_model_layout_wrapper(self):
        rng = np.random.default_rng(0)
        B, S, H, hd = 2, 64, 4, 32
        q = _rand(rng, (B, S, H, hd), jnp.float32)
        k = _rand(rng, (B, S, H, hd), jnp.float32)
        v = _rand(rng, (B, S, H, hd), jnp.float32)
        got = ops.flash_attention(q, k, v, blk_q=32, blk_k=32)
        qf = jnp.moveaxis(q, 2, 1).reshape(B * H, S, hd)
        kf = jnp.moveaxis(k, 2, 1).reshape(B * H, S, hd)
        vf = jnp.moveaxis(v, 2, 1).reshape(B * H, S, hd)
        want = jnp.moveaxis(
            ref.flash_attention_ref(qf, kf, vf).reshape(B, H, S, hd), 1, 2
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-6)


class TestDecodeAttention:
    @pytest.mark.parametrize("bh,s,hd", [(2, 128, 32), (4, 256, 64), (1, 64, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("pos_frac", [0.0, 0.3, 0.99])
    def test_vs_oracle(self, bh, s, hd, dtype, pos_frac):
        rng = np.random.default_rng(hash((bh, s, hd, pos_frac)) % 2**31)
        q = _rand(rng, (bh, hd), dtype)
        k = _rand(rng, (bh, s, hd), dtype)
        v = _rand(rng, (bh, s, hd), dtype)
        pos = jnp.asarray(int(pos_frac * (s - 1)), jnp.int32)
        got = decode_attention_bhd(q, k, v, pos, blk_k=32, interpret=True)
        want = ref.decode_attention_ref(q, k, v, pos)
        tol = 2e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=tol, rtol=tol,
        )


class TestWKV6:
    @pytest.mark.parametrize("bh,s,hd,chunk", [
        (2, 64, 16, 16), (1, 128, 32, 64), (3, 32, 64, 32), (2, 96, 16, 96),
    ])
    def test_vs_oracle(self, bh, s, hd, chunk):
        rng = np.random.default_rng(hash((bh, s, hd, chunk)) % 2**31)
        r = _rand(rng, (bh, s, hd), jnp.float32) * 0.3
        k = _rand(rng, (bh, s, hd), jnp.float32) * 0.3
        v = _rand(rng, (bh, s, hd), jnp.float32) * 0.3
        w = jnp.asarray(rng.uniform(0.001, 0.9999, (bh, s, hd)), jnp.float32)
        u = _rand(rng, (bh, hd), jnp.float32) * 0.1
        s0 = _rand(rng, (bh, hd, hd), jnp.float32) * 0.05
        y, sT = wkv6_bhsd(r, k, v, w, u, s0, chunk=chunk, interpret=True)
        y_ref, sT_ref = ref.wkv6_ref(r, k, v, w, u, s0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
        np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref), atol=1e-5)

    def test_matches_model_ssm_path(self):
        """Kernel == models.ssm._wkv_scan (the model's exact scan)."""
        from repro.models.ssm import _wkv_scan

        rng = np.random.default_rng(5)
        B, S, H, hd = 2, 32, 2, 16
        r = _rand(rng, (B, S, H, hd), jnp.float32) * 0.3
        k = _rand(rng, (B, S, H, hd), jnp.float32) * 0.3
        v = _rand(rng, (B, S, H, hd), jnp.float32) * 0.3
        w = jnp.asarray(rng.uniform(0.01, 0.999, (B, S, H, hd)), jnp.float32)
        u = _rand(rng, (H, hd), jnp.float32) * 0.1
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        y_model, s_model = _wkv_scan(r, k, v, w, u, s0)
        y_kern, s_kern = ops.wkv6(r, k, v, w, u, s0, chunk=16)
        np.testing.assert_allclose(
            np.asarray(y_kern), np.asarray(y_model), atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(s_kern), np.asarray(s_model), atol=1e-5
        )


class TestCkptCodecKernel:
    @pytest.mark.parametrize("nblocks,tile", [(4, 2), (16, 16), (8, 4)])
    @pytest.mark.parametrize("delta", [False, True])
    def test_vs_oracle(self, nblocks, tile, delta):
        rng = np.random.default_rng(nblocks * 100 + tile + delta)
        x = jnp.asarray(rng.standard_normal((nblocks, 256)), jnp.float32)
        prev = (
            x + jnp.asarray(rng.standard_normal((nblocks, 256)) * 1e-3, jnp.float32)
            if delta
            else None
        )
        q, s = quantize_blocks(x, prev, tile=tile, interpret=True)
        q_ref, s_ref = ref.quantize_ref(x, prev)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-6)
        back = dequantize_blocks(q, s, prev, tile=tile, interpret=True)
        want = ref.dequantize_ref(q_ref, s_ref, prev)
        np.testing.assert_allclose(np.asarray(back), np.asarray(want), rtol=1e-6)

    def test_host_codec_interop(self):
        """Kernel output decodes with the host (checkpoint/codec.py) layout."""
        rng = np.random.default_rng(9)
        x = rng.standard_normal(1000).astype(np.float32)
        q, s, n = ops.quantize_checkpoint(jnp.asarray(x))
        back = ops.dequantize_checkpoint(q, s, n, (1000,))
        assert np.abs(np.asarray(back) - x).max() < np.abs(x).max() / 127.0 + 1e-6
