"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU, per the assignment)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.ckpt_codec import dequantize_blocks, quantize_blocks
from repro.kernels.decode_attention import decode_attention_bhd
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rwkv6 import wkv6_bhsd


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


ATTN_SHAPES = [
    # (BH, S, hd, blk_q, blk_k)
    (2, 64, 32, 32, 32),
    (4, 128, 64, 64, 32),
    (1, 256, 16, 64, 64),
    (3, 128, 128, 128, 128),
]


class TestFlashAttention:
    @pytest.mark.parametrize("bh,s,hd,bq,bk", ATTN_SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal", [True, False])
    def test_vs_oracle(self, bh, s, hd, bq, bk, dtype, causal):
        rng = np.random.default_rng(hash((bh, s, hd, str(dtype), causal)) % 2**31)
        q = _rand(rng, (bh, s, hd), dtype)
        k = _rand(rng, (bh, s, hd), dtype)
        v = _rand(rng, (bh, s, hd), dtype)
        got = flash_attention_bhsd(
            q, k, v, causal=causal, blk_q=bq, blk_k=bk, interpret=True
        )
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        tol = 2e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=tol, rtol=tol,
        )

    def test_model_layout_wrapper(self):
        rng = np.random.default_rng(0)
        B, S, H, hd = 2, 64, 4, 32
        q = _rand(rng, (B, S, H, hd), jnp.float32)
        k = _rand(rng, (B, S, H, hd), jnp.float32)
        v = _rand(rng, (B, S, H, hd), jnp.float32)
        got = ops.flash_attention(q, k, v, blk_q=32, blk_k=32)
        qf = jnp.moveaxis(q, 2, 1).reshape(B * H, S, hd)
        kf = jnp.moveaxis(k, 2, 1).reshape(B * H, S, hd)
        vf = jnp.moveaxis(v, 2, 1).reshape(B * H, S, hd)
        want = jnp.moveaxis(
            ref.flash_attention_ref(qf, kf, vf).reshape(B, H, S, hd), 1, 2
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-6)


class TestDecodeAttention:
    @pytest.mark.parametrize("bh,s,hd", [(2, 128, 32), (4, 256, 64), (1, 64, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("pos_frac", [0.0, 0.3, 0.99])
    def test_vs_oracle(self, bh, s, hd, dtype, pos_frac):
        rng = np.random.default_rng(hash((bh, s, hd, pos_frac)) % 2**31)
        q = _rand(rng, (bh, hd), dtype)
        k = _rand(rng, (bh, s, hd), dtype)
        v = _rand(rng, (bh, s, hd), dtype)
        pos = jnp.asarray(int(pos_frac * (s - 1)), jnp.int32)
        got = decode_attention_bhd(q, k, v, pos, blk_k=32, interpret=True)
        want = ref.decode_attention_ref(q, k, v, pos)
        tol = 2e-6 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=tol, rtol=tol,
        )


class TestWKV6:
    @pytest.mark.parametrize("bh,s,hd,chunk", [
        (2, 64, 16, 16), (1, 128, 32, 64), (3, 32, 64, 32), (2, 96, 16, 96),
    ])
    def test_vs_oracle(self, bh, s, hd, chunk):
        rng = np.random.default_rng(hash((bh, s, hd, chunk)) % 2**31)
        r = _rand(rng, (bh, s, hd), jnp.float32) * 0.3
        k = _rand(rng, (bh, s, hd), jnp.float32) * 0.3
        v = _rand(rng, (bh, s, hd), jnp.float32) * 0.3
        w = jnp.asarray(rng.uniform(0.001, 0.9999, (bh, s, hd)), jnp.float32)
        u = _rand(rng, (bh, hd), jnp.float32) * 0.1
        s0 = _rand(rng, (bh, hd, hd), jnp.float32) * 0.05
        y, sT = wkv6_bhsd(r, k, v, w, u, s0, chunk=chunk, interpret=True)
        y_ref, sT_ref = ref.wkv6_ref(r, k, v, w, u, s0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
        np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref), atol=1e-5)

    def test_matches_model_ssm_path(self):
        """Kernel == models.ssm._wkv_scan (the model's exact scan)."""
        from repro.models.ssm import _wkv_scan

        rng = np.random.default_rng(5)
        B, S, H, hd = 2, 32, 2, 16
        r = _rand(rng, (B, S, H, hd), jnp.float32) * 0.3
        k = _rand(rng, (B, S, H, hd), jnp.float32) * 0.3
        v = _rand(rng, (B, S, H, hd), jnp.float32) * 0.3
        w = jnp.asarray(rng.uniform(0.01, 0.999, (B, S, H, hd)), jnp.float32)
        u = _rand(rng, (H, hd), jnp.float32) * 0.1
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        y_model, s_model = _wkv_scan(r, k, v, w, u, s0)
        y_kern, s_kern = ops.wkv6(r, k, v, w, u, s0, chunk=16)
        np.testing.assert_allclose(
            np.asarray(y_kern), np.asarray(y_model), atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(s_kern), np.asarray(s_model), atol=1e-5
        )


class TestCkptCodecKernel:
    @pytest.mark.parametrize("nblocks,tile", [(4, 2), (16, 16), (8, 4)])
    @pytest.mark.parametrize("delta", [False, True])
    def test_vs_oracle(self, nblocks, tile, delta):
        rng = np.random.default_rng(nblocks * 100 + tile + delta)
        x = jnp.asarray(rng.standard_normal((nblocks, 256)), jnp.float32)
        prev = (
            x + jnp.asarray(rng.standard_normal((nblocks, 256)) * 1e-3, jnp.float32)
            if delta
            else None
        )
        q, s = quantize_blocks(x, prev, tile=tile, interpret=True)
        q_ref, s_ref = ref.quantize_ref(x, prev)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-6)
        back = dequantize_blocks(q, s, prev, tile=tile, interpret=True)
        want = ref.dequantize_ref(q_ref, s_ref, prev)
        np.testing.assert_allclose(np.asarray(back), np.asarray(want), rtol=1e-6)

    def test_host_codec_interop(self):
        """Kernel output decodes with the host (checkpoint/codec.py) layout."""
        rng = np.random.default_rng(9)
        x = rng.standard_normal(1000).astype(np.float32)
        q, s, n = ops.quantize_checkpoint(jnp.asarray(x))
        back = ops.dequantize_checkpoint(q, s, n, (1000,))
        assert np.abs(np.asarray(back) - x).max() < np.abs(x).max() / 127.0 + 1e-6


class TestCounterRNG:
    """The device trace generator's counter-based RNG primitives: the
    NumPy reference (core/events.py) and the jnp twins (kernels/
    sim_step.py) must agree bit-for-bit, and both must reproduce the
    published reference sequences."""

    #: Random123 known-answer vectors for Threefry-2x32, 20 rounds
    TF_KATS = [
        ((0, 0), (0, 0), (0x6B200159, 0x99BA4EFE)),
        (
            (0xFFFFFFFF, 0xFFFFFFFF),
            (0xFFFFFFFF, 0xFFFFFFFF),
            (0x1CB996FC, 0xBB002BE7),
        ),
        (
            (0x13198A2E, 0x03707344),
            (0x243F6A88, 0x85A308D3),
            (0xC4923A9C, 0x483DF7A0),
        ),
    ]

    #: SplitMix64 reference outputs for seed 0 (Vigna's splitmix64.c)
    SM_KATS = [0xE220A8397B1DCDAF, 0x6E789E6AA1B965F4, 0x06C45D188009454F]

    def test_threefry_known_answers(self):
        from repro.core import events as E

        for (k0, k1), (c0, c1), (w0, w1) in self.TF_KATS:
            x0, x1 = E.threefry2x32(k0, k1, c0, c1, rounds=20)
            assert (int(x0), int(x1)) == (w0, w1)

    def test_splitmix_known_answers(self):
        from repro.core import events as E

        for i, want in enumerate(self.SM_KATS):
            x0, x1 = E.splitmix64(np.uint64(0), np.int64(i))
            assert (int(x0) << 32) | int(x1) == want

    def test_numpy_vs_jnp_bit_equality(self):
        from jax.experimental import enable_x64

        from repro.core import events as E
        from repro.kernels import sim_step as K

        rng = np.random.default_rng(3)
        k0, k1, c0, c1 = (
            rng.integers(0, 2**32, size=257, dtype=np.uint32) for _ in range(4)
        )
        for rounds in (13, 20):
            a = E.threefry2x32(k0, k1, c0, c1, rounds=rounds)
            b = K.threefry2x32(k0, k1, c0, c1, rounds=rounds)
            np.testing.assert_array_equal(a[0], np.asarray(b[0]))
            np.testing.assert_array_equal(a[1], np.asarray(b[1]))
        with enable_x64():
            key = rng.integers(0, 2**64, size=129, dtype=np.uint64)
            ctr = rng.integers(0, 2**20, size=129).astype(np.int64)
            a = E.splitmix64(key, ctr)
            b = K.splitmix64(jnp.asarray(key), jnp.asarray(ctr))
            np.testing.assert_array_equal(a[0], np.asarray(b[0]))
            np.testing.assert_array_equal(a[1], np.asarray(b[1]))

    def test_pallas_stream_advance_matches_jnp(self):
        """The Pallas sampling kernel entry and the shared jnp body are
        bit-identical (interpret mode on CPU)."""
        from jax.experimental import enable_x64

        from repro.core import events as E
        from repro.kernels import sim_step as K

        with enable_x64():
            L = 256
            rng = np.random.default_rng(11)
            k0, k1 = E.stream_subkey_np(7, np.arange(L), E.STREAM_FAULT_GAP)
            key = K.stream_key(jnp.asarray(k0), jnp.asarray(k1))
            mask = jnp.asarray(rng.random(L) < 0.7)
            ctr = jnp.asarray(rng.integers(0, 50, L), jnp.int32)
            tm = jnp.asarray(rng.random(L) * 1e5, jnp.float64)
            mean = jnp.full((L,), 6e4, jnp.float64)
            horizon = jnp.full((L,), 1e6, jnp.float64)
            for kind, param in [("exponential", 0.0), ("weibull", 0.7),
                                ("lognormal", 1.0), ("uniform", 0.0)]:
                a = K.stream_advance(
                    mask, ctr, tm, key, mean, horizon, kind=kind, param=param
                )
                b = K.masked_stream_advance(
                    mask, ctr, tm, key, mean, horizon, kind=kind, param=param,
                    interpret=True,
                )
                np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
                if kind == "lognormal":
                    # the two compilation paths may contract the
                    # transcendental chain (log/cos/exp) differently: ulp
                    np.testing.assert_allclose(
                        np.asarray(a[1]), np.asarray(b[1]), rtol=1e-12
                    )
                else:
                    np.testing.assert_array_equal(
                        np.asarray(a[1]), np.asarray(b[1])
                    )
