"""Real CPU step timing for reduced configs (train + decode) and the
measured per-step checkpoint cost feeding the executor's C estimate."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.steps import build_decode_step, build_model, build_prefill_step, build_train_step
from repro.models.layers import RuntimeFlags
from repro.optim.adamw import adamw_init

from .common import emit, timed

ARCHS_QUICK = ["smollm-135m", "qwen3-moe-30b-a3b", "rwkv6-7b"]


def run(quick: bool = True) -> None:
    archs = ARCHS_QUICK if quick else configs.ARCH_NAMES
    for name in archs:
        cfg = configs.get(name).reduced()
        model, _ = build_model(cfg, mesh=None, flags=RuntimeFlags(dense_attn_max=256))
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        rng = np.random.default_rng(0)
        B, S = 4, 64
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        }
        if cfg.frontend:
            batch["frontend"] = jnp.asarray(
                rng.standard_normal((B, cfg.frontend_prefix, cfg.d_model)) * 0.02,
                jnp.bfloat16,
            )
        step = jax.jit(build_train_step(model))
        p, o, m = step(params, opt, batch)  # compile+warm
        jax.block_until_ready(m["loss"])
        (_, _, m2), us = timed(
            lambda step=step, p=p, o=o, batch=batch: step(p, o, batch), n=3
        )
        jax.block_until_ready(m2["loss"])
        tokens = B * S
        emit(
            f"step/train/{name}", us,
            {"tok_per_s": round(tokens / (us / 1e6)), "loss": round(float(m2["loss"]), 3)},
        )

        max_seq = S + (cfg.frontend_prefix or 0) + 8
        prefill = jax.jit(
            lambda p_, b_, model=model, max_seq=max_seq:
                build_prefill_step(model, max_seq)(p_, b_)
        )
        logits, cache = prefill(params, batch)
        decode = jax.jit(build_decode_step(model))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out = decode(params, cache, tok)
        jax.block_until_ready(out[0])
        (_, cache2), us_d = timed(
            lambda decode=decode, params=params, cache=cache, tok=tok:
                decode(params, cache, tok),
            n=5,
        )
        emit(
            f"step/decode/{name}", us_d,
            {"tok_per_s": round(B / (us_d / 1e6))},
        )


if __name__ == "__main__":
    run(quick=False)
