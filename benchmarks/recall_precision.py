"""Figures 8-11 analog: impact of recall vs precision on the waste.

Fix one of (r, p), sweep the other; report analytic optimal waste and a
spot-check simulation.  The paper's conclusion — recall matters much more
than precision — shows as the slope difference."""

from __future__ import annotations

import numpy as np

from repro.configs.paper import C, D, MU_IND, R
from repro.core import Platform, PredictorModel, optimize_exact, simulate_many
from repro.core import simulator as S

from .common import emit, timed


def run(quick: bool = True) -> None:
    n_runs = 4 if quick else 20
    work = 6 * 86400.0
    sweep = [0.3, 0.5, 0.7, 0.9, 0.99]
    for n in [2**16, 2**19]:
        plat = Platform(mu=MU_IND / n, C=C, D=D, R=R)
        for fixed_r in [0.4, 0.8]:
            for p in sweep:
                pred = PredictorModel(fixed_r, p)
                pol = optimize_exact(plat, pred)
                emit(
                    f"fig8/N{n}/r{fixed_r}/p{p}", 0.0,
                    {"waste_analytic": round(pol.waste, 4), "q": pol.q},
                )
        for fixed_p in [0.4, 0.8]:
            for r in sweep:
                pred = PredictorModel(r, fixed_p)
                pol = optimize_exact(plat, pred)
                res, us = timed(
                    simulate_many, work, plat,
                    S.exact_prediction(plat, pred), pred,
                    n_runs=n_runs, seed=3,
                )
                emit(
                    f"fig10/N{n}/p{fixed_p}/r{r}",
                    us / n_runs,
                    {
                        "waste_analytic": round(pol.waste, 4),
                        "waste_sim": round(
                            float(np.mean([x.waste for x in res])), 4
                        ),
                        "q": pol.q,
                    },
                )


if __name__ == "__main__":
    run(quick=False)
