"""Figures 8-11 analog: impact of recall vs precision on the waste.

Fix one of (r, p), sweep the other; report analytic optimal waste and a
spot-check simulation (one batched grid over all sweep points).  The
paper's conclusion — recall matters much more than precision — shows as
the slope difference."""

from __future__ import annotations

import numpy as np

from repro.configs.paper import C, D, MU_IND, R
from repro.core import Platform, PredictorModel, optimize
from repro.core import simulator as S
from repro.experiments import ExperimentCell, run_cells

from .common import emit


def run(quick: bool = True) -> None:
    n_runs = 4 if quick else 20
    work = 6 * 86400.0
    sweep_vals = [0.3, 0.5, 0.7, 0.9, 0.99]

    cells = []
    for n in [2**16, 2**19]:
        plat = Platform(mu=MU_IND / n, C=C, D=D, R=R)
        for fixed_p in [0.4, 0.8]:
            for r in sweep_vals:
                pred = PredictorModel(r, fixed_p)
                cells.append(
                    ExperimentCell(
                        label=f"fig10/N{n}/p{fixed_p}/r{r}",
                        work=work,
                        platform=plat,
                        predictor=pred,
                        strategy=S.exact_prediction(plat, pred),
                    )
                )
    sweep = run_cells(cells, n_runs=n_runs, seed=3)
    us_per_run = sweep.wall_time_s * 1e6 / sweep.grid.n_lanes

    for n in [2**16, 2**19]:
        plat = Platform(mu=MU_IND / n, C=C, D=D, R=R)
        for fixed_r in [0.4, 0.8]:
            for p in sweep_vals:
                pol = optimize("exact", plat, PredictorModel(fixed_r, p))
                emit(
                    f"fig8/N{n}/r{fixed_r}/p{p}", 0.0,
                    {"waste_analytic": round(pol.waste, 4), "q": pol.q},
                )
    for cr in sweep.cells:
        pol = optimize("exact", cr.cell.platform, cr.cell.predictor)
        emit(
            cr.cell.label,
            us_per_run,
            {
                "waste_analytic": round(pol.waste, 4),
                "waste_sim": round(cr.mean_waste, 4),
                "q": pol.q,
            },
        )


if __name__ == "__main__":
    run(quick=False)
