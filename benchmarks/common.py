"""Shared helpers for the benchmark harness (CSV: name,us_per_call,derived).

Every :func:`emit` line is also recorded in :data:`RECORDS` so the runner
can dump a machine-readable ``BENCH_sim.json`` for cross-PR perf tracking.
"""

from __future__ import annotations

import json
import time
from typing import Callable, Dict, List

#: records of the current harness run: {"name", "us_per_call", "derived"}
RECORDS: List[Dict] = []


def reset_records() -> None:
    RECORDS.clear()


def emit(name: str, us_per_call: float, derived) -> None:
    RECORDS.append(
        {"name": name, "us_per_call": round(us_per_call, 1), "derived": derived}
    )
    if not isinstance(derived, str):
        derived = json.dumps(derived, separators=(",", ":"))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def write_records_json(
    path: str, meta: Dict | None = None, records: List[Dict] | None = None
) -> None:
    """Dump everything emitted so far (or an explicit subset) as JSON."""
    payload = {
        "schema": "bench-sim/v1",
        "generated_unix": time.time(),
        **(meta or {}),
        "benchmarks": RECORDS if records is None else records,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


def timed(fn: Callable, *args, n: int = 1, **kw):
    t0 = time.monotonic()
    out = None
    for _ in range(n):
        out = fn(*args, **kw)
    dt = (time.monotonic() - t0) / n
    return out, dt * 1e6
