"""Shared helpers for the benchmark harness (CSV: name,us_per_call,derived)."""

from __future__ import annotations

import json
import time
from typing import Callable


def emit(name: str, us_per_call: float, derived) -> None:
    if not isinstance(derived, str):
        derived = json.dumps(derived, separators=(",", ":"))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timed(fn: Callable, *args, n: int = 1, **kw):
    t0 = time.monotonic()
    out = None
    for _ in range(n):
        out = fn(*args, **kw)
    dt = (time.monotonic() - t0) / n
    return out, dt * 1e6
