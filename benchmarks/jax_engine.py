"""Lanes-throughput curve: JAX device engine vs the NumPy batch engine,
the host-vs-device *trace-mode* comparison, the multi-device scaling
curve of the sharded dispatch, and the fused-vs-per-cell paper-grid
sweep comparison.

One representative paper cell (Instant strategy, exponential faults,
accurate predictor) swept over lane counts; both engines consume the same
generated ``BatchTraces``, so the per-lane results must agree while the
wall-clock diverges.  The JAX engine is warmed up first (its jit compile
is a one-off, amortized across every later call at the same chunk shape)
and timed in steady state — the number a long Monte-Carlo campaign sees.

``jax_engine/device_trace_lanes{n}`` times the same cell end-to-end in
device trace mode (``TraceSpec``: counter-based RNG streams sampled
inside the engine, O(1) cursor state per lane) against the host path
(host NumPy generation + event-array engine), with the
generation/packing/dispatch(=compute)/fetch split of both.  The 40960-lane record
carries the acceptance number ``speedup_end_to_end`` (device-mode
campaign throughput vs the host-trace JAX path) plus the waste-mean
z-score against the NumPy engine.

The devices curve (``jax_engine/devices{d}_lanes{n}``) times the sharded
engine on 1/2/4/8 devices at a >= 10k lane count.  It runs in a child
process with ``--xla_force_host_platform_device_count=8`` so the parent
benchmark process keeps its real device topology; on actual accelerator
fleets pass ``--devices`` to use the local devices directly.

``jax_engine/fused_grid_cells{n}`` is the experiment-sweep acceptance
record: the paper grid (``repro.experiments.paper_grid``, every platform
size x both predictors x all six strategies, device trace mode) run as
one fused cell-multiplexed dispatch (``run_grid(dispatch="fused")`` —
per-cell parameter tables broadcast on device by the lane -> cell index,
one compiled executable for the whole exponential family) vs one engine
call per cell (``dispatch="percell"``) at equal lanes per cell.  The
record carries ``speedup_fused_vs_percell`` (acceptance: >= 3x),
``fused_cells_per_s`` (the regression-gate floor), the device-reduced
``collect="stats"`` timing, and the fused-vs-percell per-cell equality
check (must be 0.0 — both paths consume identical counter streams).

``jax_engine/mixed_law_grid_cells{n}`` is the mixed-law one-dispatch
acceptance record: the paper grid replicated under three failure-law
families (exponential, Weibull k=0.7, lognormal sigma=0.5) and run as
literally ONE law-multiplexed device dispatch (per-cell ``law_index`` +
unified parameter tables, branchless law-indexed sampler) vs the
per-family baseline (one dispatch per law through the *same* indexed
sampler).  The record carries ``mixed_law_cells_per_s`` (the
regression-gate floor), ``speedup_vs_perfamily``, the engine-executable
build counts of both paths, and ``fused_vs_perfamily_max_diff`` (must
be 0.0 — identical sampler, identical counter streams).  On a
compute-bound CPU the two paths are near parity (total lane-steps are
equal, and the fused hot loop runs to the slowest family's iteration
count); the one-dispatch win is the 3x dispatch/fetch amortization and
the single executable, which pays off in dispatch-bound regimes — real
accelerators, many-family grids, and distributed meshes.

``jax_engine/analytic_opt_cells{n}`` is the analytic-layer acceptance
record: every bench-grid cell's optimal regular period solved in one
jitted batched safeguarded-Newton dispatch (``jax.grad`` of the
branchless waste twins over the shared per-cell tables) vs a host
scalar scan of the same analytic objective over the
``best_period_search`` period grid.  The record carries
``analytic_opt_cells_per_s`` (the regression-gate floor),
``speedup_vs_host_scan``, ``newton_excess_waste_max`` (gate: the
continuous optimum must dominate the 10-point scan on every cell, to
float rounding) and ``newton_vs_extremizer_max_rel`` (smooth-family
periods must land on the closed-form extremizer).

``jax_engine/two_level_silent_cells{n}`` is the scenario-family
acceptance record: the two-level (memory + disk tiers, rho-stride
nesting, Bernoulli(f) tier recovery) and silent-error (verified
checkpoints every k_V-th period, detection-latency rollback) grids
through the fused device engine with ``collect="stats"``.  It carries
``two_level_silent_cells_per_s`` (the regression-gate perf floor),
``fused_vs_percell_max_diff`` (0.0 — identical counter streams, tier
coins and strike cursors included) and ``newton_excess_waste_max`` (the
analytic-dominance gate over the corrected two-level/silent waste
models).

Acceptance trajectory: jax lanes/s >= numpy lanes/s at 10k lanes on CPU,
device trace mode >= 2x the host-trace path end-to-end at 40960 lanes,
and sharded lanes/s non-decreasing with device count (expected >> on an
accelerator, where the Pallas hot step compiles to a real Mosaic kernel
instead of interpret mode and every device is a physical chip).

    PYTHONPATH=src python -m benchmarks.jax_engine [--full] [--devices all]
    PYTHONPATH=src python -m benchmarks.run --only jax_engine
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.core import (
    EngineConfig,
    Platform,
    PredictorModel,
    make_event_traces_batch,
    simulate_batch,
)
from repro.core import jax_sim
from repro.core import simulator as S
from repro.core.events import lognormal, make_trace_spec, weibull
from repro.core.jax_sim import simulate_batch_jax

from .common import emit

MN = 60.0
WORK = 10 * 86400.0
LANES_QUICK = [1024, 4096, 10240]
LANES_FULL = [1024, 4096, 10240, 32768, 102400]

#: sharded-dispatch scaling curve: forced host device counts x lane count
DEVICES_CURVE = (1, 2, 4, 8)
DEVICES_LANES = 40960

#: lane count of the trace-mode acceptance comparison
TRACE_MODE_LANES = 40960

#: lanes per cell of the fused-grid sweep comparison (equal for both
#: dispatch granularities — the acceptance condition)
FUSED_GRID_RUNS = 16

#: lanes per chunk of the campaign-overhead record: small enough that
#: the bench grid spans several chunks (= several snapshots at period 0,
#: the worst-case durability cost); the plain sweep is chunked the same
CAMPAIGN_CHUNK = 256

#: failure laws of the mixed-law one-dispatch sweep — one family each of
#: the memoryless / aging / heavy-tail classes (None = the preset's
#: exponential default)
MIXED_LAWS = (
    ("exp", None),
    ("weibull", weibull(0.7)),
    ("lognormal", lognormal(0.5)),
)


#: engine configurations of the grid-sweep records (one fused device
#: dispatch is the headline path; the rest are its baselines)
_CFG_FUSED = EngineConfig(engine="jax", trace_mode="device")
_CFG_STATS = _CFG_FUSED.replace(collect="stats")
_CFG_PERCELL = _CFG_FUSED.replace(dispatch="percell")
_CFG_PERFAMILY = _CFG_STATS.replace(dispatch="perfamily")


def _cell():
    plat = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    pred = PredictorModel(0.85, 0.82, window=300.0, lead=3600.0)
    return plat, pred, S.instant(plat, pred)


def _traces(n: int, plat: Platform, pred: PredictorModel, seed: int = 7):
    rng = np.random.default_rng(seed)
    return make_event_traces_batch(
        rng, n, horizon=12 * WORK, mtbf=plat.mu,
        recall=pred.recall, precision=pred.precision,
        window=pred.window, lead=pred.lead,
    )


def _spec(n: int, plat: Platform, pred: PredictorModel, seed: int = 7):
    return make_trace_spec(
        n, horizon=12 * WORK, mtbf=plat.mu,
        recall=pred.recall, precision=pred.precision,
        window=pred.window, lead=pred.lead, seed=seed,
    )


def _split():
    # dispatch_s is the device-compute leg on CPU (execution blocks the
    # dispatch); on accelerators compute hides under dispatch + fetch
    t = jax_sim.LAST_TIMINGS
    return {
        "pack_s": round(t.get("pack_s", 0.0), 3),
        "dispatch_s": round(t.get("dispatch_s", 0.0), 3),
        "fetch_s": round(t.get("fetch_s", 0.0), 3),
    }


def run(quick: bool = True, devices=None) -> None:
    plat, pred, strat = _cell()
    reps = 3 if quick else 5
    for n in (LANES_QUICK if quick else LANES_FULL) + [TRACE_MODE_LANES]:
        t0 = time.monotonic()
        traces = _traces(n, plat, pred)
        gen_s = time.monotonic() - t0
        spec = _spec(n, plat, pred)

        res_np = simulate_batch(WORK, plat, strat, traces)
        res_jx = simulate_batch_jax(  # jit warmup
            WORK, plat, strat, traces, devices=devices
        )
        res_dev = simulate_batch_jax(  # device-generation warmup
            WORK, plat, strat, spec, devices=devices
        )

        # interleaved best-of-N: all engines see the same machine noise;
        # the pack/fetch split is captured from the winning rep so it
        # decomposes the reported time
        np_s = jx_s = dv_s = float("inf")
        jx_split = dv_split = {}
        for _ in range(reps):
            np_s = min(
                np_s,
                _timed(lambda traces=traces: simulate_batch(
                    WORK, plat, strat, traces
                )),
            )
            t = _timed(lambda traces=traces: simulate_batch_jax(
                WORK, plat, strat, traces, devices=devices
            ))
            if t < jx_s:
                jx_s, jx_split = t, _split()
            t = _timed(lambda spec=spec: simulate_batch_jax(
                WORK, plat, strat, spec, devices=devices
            ))
            if t < dv_s:
                dv_s, dv_split = t, _split()

        agree = float(np.abs(res_jx.waste - res_np.waste).max())
        emit(
            f"jax_engine/lanes{n}",
            jx_s * 1e6 / n,
            {
                "numpy_s": round(np_s, 3),
                "jax_s": round(jx_s, 3),
                "gen_s": round(gen_s, 3),
                **jx_split,
                "numpy_lanes_per_s": round(n / np_s, 1),
                "jax_lanes_per_s": round(n / jx_s, 1),
                "speedup_vs_numpy": round(np_s / jx_s, 2),
                "max_abs_waste_diff": agree,
            },
        )
        # device trace mode: generation happens inside the engine, so the
        # end-to-end comparison charges the host path its generation time
        mw_np = float(res_np.waste.mean())
        mw_dev = float(res_dev.waste.mean())
        se = float(res_np.waste.std(ddof=1)) / np.sqrt(n)
        emit(
            f"jax_engine/device_trace_lanes{n}",
            dv_s * 1e6 / n,
            {
                "jax_dev_s": round(dv_s, 3),
                **dv_split,
                "jax_dev_lanes_per_s": round(n / dv_s, 1),
                "host_end_to_end_s": round(gen_s + jx_s, 3),
                "speedup_end_to_end": round((gen_s + jx_s) / dv_s, 2),
                "mean_waste_numpy": round(mw_np, 6),
                "mean_waste_device": round(mw_dev, 6),
                # independent samples of the same law: |z| <~ 2-3
                "waste_z_vs_numpy": round(
                    (mw_dev - mw_np) / (se * np.sqrt(2.0)), 2
                ),
            },
        )
    _run_fused_grid(reps=reps)
    _run_campaign_grid(reps=reps)
    _run_mixed_law_grid(reps=reps)
    _run_analytic_opt(reps=reps)
    _run_two_level_silent(reps=reps)
    _run_devices_curve(reps=reps)


def _run_fused_grid(reps: int = 3) -> None:
    """Time the paper grid end-to-end: fused cell-multiplexed dispatch
    (lanes + device-reduced stats collection) vs per-cell dispatch."""
    from repro.experiments import GridSpec, paper_grid_cells, run_grid

    cells = paper_grid_cells("bench")
    grid = GridSpec(tuple(cells), n_runs=FUSED_GRID_RUNS, seed=3)
    n_cells = len(cells)

    # warm the fused executable at the *full* cell-table shape (the
    # table length is a static of the compiled program) and the percell
    # executables on a 4-cell subgrid that covers both the plain and the
    # migration-specialized variants — per-cell chunk shapes are
    # cell-count independent, so the subgrid warms them all
    sweep_f = run_grid(grid, _CFG_FUSED)
    sub = GridSpec(tuple(cells[:4]), n_runs=FUSED_GRID_RUNS, seed=3)
    assert any(c.strategy.mode == "migration" for c in sub.cells)
    run_grid(sub, _CFG_PERCELL)

    fused_s = stats_s = percell_s = float("inf")
    fused_split = {}
    for _ in range(reps):
        t = _timed(lambda: run_grid(grid, _CFG_FUSED))
        if t < fused_s:
            fused_s, fused_split = t, _split()
        stats_s = min(stats_s, _timed(lambda: run_grid(grid, _CFG_STATS)))
    for _ in range(max(1, reps - 1)):  # the slow leg: fewer reps
        t0 = time.monotonic()
        sweep_p = run_grid(grid, _CFG_PERCELL)
        percell_s = min(percell_s, time.monotonic() - t0)

    # both dispatches consume identical counter streams: exact equality
    diff = max(
        abs(a.mean_waste - b.mean_waste)
        for a, b in zip(sweep_f.cells, sweep_p.cells)
    )
    emit(
        f"jax_engine/fused_grid_cells{n_cells}",
        fused_s * 1e6 / n_cells,
        {
            "n_cells": n_cells,
            "lanes_per_cell": FUSED_GRID_RUNS,
            "n_lanes": grid.n_lanes,
            "fused_s": round(fused_s, 3),
            "fused_stats_s": round(stats_s, 3),
            "percell_s": round(percell_s, 3),
            "speedup_fused_vs_percell": round(percell_s / fused_s, 2),
            "speedup_stats_vs_percell": round(percell_s / stats_s, 2),
            "fused_cells_per_s": round(n_cells / fused_s, 1),
            "fused_lanes_per_s": round(grid.n_lanes / fused_s, 1),
            "fused_vs_percell_max_diff": diff,
            **fused_split,
        },
    )


def _run_campaign_grid(reps: int = 3) -> None:
    """Time the resumable campaign runner (``repro.ft.run_campaign``)
    against the plain fused sweep at the *same* chunking: the price of
    durability — chunk-boundary CellSums snapshots through
    CheckpointStore at the production default period (chosen online by
    ``repro.core.optimize`` from the measured snapshot cost and the
    configured MTBF) — expressed as ``campaign_overhead_frac``.
    check_regression gates it at <= 5%: resilience must stay
    effectively free."""
    import shutil
    import tempfile

    from repro.experiments import GridSpec, paper_grid_cells, run_grid
    from repro.ft import CampaignConfig, run_campaign

    cells = paper_grid_cells("bench")
    grid = GridSpec(tuple(cells), n_runs=FUSED_GRID_RUNS, seed=3)
    n_cells = len(cells)
    cfg = _CFG_STATS.replace(chunk_lanes=CAMPAIGN_CHUNK)

    run_grid(grid, cfg)  # warm the chunk-shape executable
    plain_s = camp_s = float("inf")
    n_snapshots = 0
    for _ in range(reps):
        plain_s = min(plain_s, _timed(lambda: run_grid(grid, cfg)))
        root = tempfile.mkdtemp(prefix="bench_campaign_")
        try:
            t0 = time.monotonic()
            res = run_campaign(
                grid, CampaignConfig(ckpt_dir=root), cfg
            )
            t = time.monotonic() - t0
        finally:
            shutil.rmtree(root, ignore_errors=True)
        if t < camp_s:
            camp_s = t
            n_snapshots = res.meta["campaign"]["n_snapshots"]
    overhead = camp_s / plain_s - 1.0
    emit(
        f"jax_engine/campaign_grid_cells{n_cells}",
        camp_s * 1e6 / n_cells,
        {
            "n_cells": n_cells,
            "n_lanes": grid.n_lanes,
            "chunk_lanes": CAMPAIGN_CHUNK,
            "n_snapshots": n_snapshots,
            "plain_s": round(plain_s, 3),
            "campaign_s": round(camp_s, 3),
            "campaign_overhead_frac": round(max(overhead, 0.0), 4),
        },
    )


def _run_mixed_law_grid(reps: int = 3) -> None:
    """Time the mixed-law paper grid: one law-multiplexed device
    dispatch over the concatenated per-law grids vs the per-family
    baseline (one dispatch per failure-law family, same law-indexed
    sampler — the equality reference).  On CPU expect ~parity end to
    end (compute-bound; see the module docstring) with bit-exact
    per-cell stats and a single engine-executable build."""
    from dataclasses import replace

    from repro.experiments import GridSpec, paper_grid_cells, run_grid

    cells = [
        replace(c, label=f"{law}/{c.label}", fault_dist=dist)
        for law, dist in MIXED_LAWS
        for c in paper_grid_cells("bench")
    ]
    grid = GridSpec(tuple(cells), n_runs=FUSED_GRID_RUNS, seed=5)
    n_cells = len(cells)

    # warm both executables and capture the engine-executable build
    # counts: the fused path compiles ONE program for the whole 3-law
    # grid; the per-family baseline compiles one per *shape*, reused
    # across its (equal-sized) family dispatches
    n0 = len(jax_sim._RUN_CACHE)
    sweep_f = run_grid(grid, _CFG_STATS)
    fused_builds = len(jax_sim._RUN_CACHE) - n0
    assert jax_sim.LAST_TIMINGS["n_chunks"] == 1, (
        "mixed-law grid must run as one fused dispatch"
    )
    n0 = len(jax_sim._RUN_CACHE)
    sweep_p = run_grid(grid, _CFG_PERFAMILY)
    perfamily_builds = len(jax_sim._RUN_CACHE) - n0

    fused_s = perfam_s = float("inf")
    fused_split = {}
    for _ in range(reps):
        t = _timed(lambda: run_grid(grid, _CFG_STATS))
        if t < fused_s:
            fused_s, fused_split = t, _split()
        perfam_s = min(perfam_s, _timed(lambda: run_grid(grid, _CFG_PERFAMILY)))

    # both granularities run the same law-indexed sampler on the same
    # counter streams: per-cell device-reduced stats are bit-identical
    diff = max(
        abs(a.mean_waste - b.mean_waste)
        for a, b in zip(sweep_f.cells, sweep_p.cells)
    )
    emit(
        f"jax_engine/mixed_law_grid_cells{n_cells}",
        fused_s * 1e6 / n_cells,
        {
            "n_cells": n_cells,
            "n_laws": len(MIXED_LAWS),
            "lanes_per_cell": FUSED_GRID_RUNS,
            "n_lanes": grid.n_lanes,
            "fused_s": round(fused_s, 3),
            "perfamily_s": round(perfam_s, 3),
            "speedup_vs_perfamily": round(perfam_s / fused_s, 2),
            "mixed_law_cells_per_s": round(n_cells / fused_s, 1),
            "fused_engine_builds": fused_builds,
            "perfamily_engine_builds": perfamily_builds,
            "perfamily_dispatches": len(MIXED_LAWS),
            "fused_vs_perfamily_max_diff": diff,
            **fused_split,
        },
    )


def _run_analytic_opt(reps: int = 3) -> None:
    """Time the batched-Newton period optimizer: every bench-grid cell's
    optimal regular period solved in ONE jitted device dispatch
    (``repro.core.analytic.newton_optimize_tables`` — per-cell
    safeguarded Newton through ``jax.grad`` of the branchless waste
    twins) against the host baseline (a scalar Python scan of the
    analytic objective over ``best_period_search``'s period grid,
    argmin per cell — the pre-redesign way to pick a period without a
    Monte-Carlo campaign).

    Acceptance is dominance, not agreement: the Newton period's waste
    must be <= the scan's best on EVERY cell up to float rounding
    (``newton_excess_waste_max`` — the continuous optimum can only
    undercut a 10-point grid), and on the smooth strategy families the
    period itself must land on the closed-form extremizer
    (``newton_vs_extremizer_max_rel``)."""
    from dataclasses import replace

    from repro.core import analytic as A
    from repro.core.simulator import PERIOD_GRID
    from repro.experiments import paper_grid_cells
    from repro.experiments.validation import analytic_waste

    cells = paper_grid_cells("bench")
    n_cells = len(cells)
    tabs = A.tables_from_cells(cells)
    res = A.newton_optimize_tables(tabs)  # jit warmup

    newton_s = float("inf")
    for _ in range(reps):
        newton_s = min(
            newton_s, _timed(lambda: A.newton_optimize_tables(tabs))
        )

    # host scan baseline: the analytic objective at best_period_search's
    # period candidates, one scalar evaluation at a time
    t0 = time.monotonic()
    scan_w = np.empty(n_cells)
    for i, c in enumerate(cells):
        periods = [
            max(c.platform.C * 1.01, c.strategy.T_R * m) for m in PERIOD_GRID
        ]
        scan_w[i] = min(
            analytic_waste(replace(c, strategy=replace(c.strategy, T_R=t)))
            for t in periods
        )
    scan_s = time.monotonic() - t0
    scan_w = np.minimum(scan_w, 1.0)

    # dominance: the one-dispatch Newton periods must be at least as
    # good as the host grid scan on every cell
    excess = float((res["waste"] - scan_w).max())

    # period agreement on the smooth families (everything except the
    # Instant kink cells), against the closed-form extremizer the host
    # optimizers use
    t_ext = A.analytic_period_cells(cells)
    smooth = np.array(
        [
            not (c.strategy.mode == "exact" and c.predictor.window > 0.0)
            for c in cells
        ]
    ) & (res["q"] > 0.0)
    rel = np.abs(res["T_R"] - t_ext) / t_ext
    agree = float(rel[smooth].max()) if smooth.any() else 0.0

    emit(
        f"jax_engine/analytic_opt_cells{n_cells}",
        newton_s * 1e6 / n_cells,
        {
            "n_cells": n_cells,
            "newton_s": round(newton_s, 4),
            "host_scan_s": round(scan_s, 4),
            "analytic_opt_cells_per_s": round(n_cells / newton_s, 1),
            "speedup_vs_host_scan": round(scan_s / newton_s, 2),
            "newton_excess_waste_max": excess,
            "newton_vs_extremizer_max_rel": agree,
        },
    )


def _run_two_level_silent(reps: int = 3) -> None:
    """Scenario-grid acceptance record: the two-level + silent phase
    families through the SAME one-dispatch fused device engine with
    device-reduced statistics.

    Carries ``two_level_silent_cells_per_s`` (the regression-gate perf
    floor for the scenario families), ``fused_vs_percell_max_diff``
    (must be 0.0 — fused and per-cell dispatch consume identical counter
    streams, including the per-fault tier coins and silent strike
    cursors), and ``newton_excess_waste_max`` (the analytic-dominance
    gate: the batched-Newton optimum of the corrected two-level / silent
    waste models must dominate a host scan of the same objective on
    every cell — the gate that would have caught the old
    (1-rq)-scaled-disk-term extremizers, which a scan undercuts)."""
    from dataclasses import replace

    from repro.core import analytic as A
    from repro.core.simulator import PERIOD_GRID
    from repro.experiments import GridSpec, run_grid
    from repro.experiments.paper_grid import (
        silent_grid_cells,
        two_level_grid_cells,
    )
    from repro.experiments.validation import analytic_waste

    cells = tuple(two_level_grid_cells("bench")) + tuple(
        silent_grid_cells("bench")
    )
    n_cells = len(cells)
    grid = GridSpec(cells, n_runs=FUSED_GRID_RUNS, seed=9)
    sweep_f = run_grid(grid, _CFG_STATS)  # jit warmup
    sweep_p = run_grid(grid, _CFG_PERCELL)

    stats_s = float("inf")
    stats_split = {}
    for _ in range(reps):
        t = _timed(lambda: run_grid(grid, _CFG_STATS))
        if t < stats_s:
            stats_s, stats_split = t, _split()

    diff = max(
        abs(a.mean_waste - b.mean_waste)
        for a, b in zip(sweep_f.cells, sweep_p.cells)
    )

    # analytic dominance: one batched-Newton dispatch over the scenario
    # cell tables vs a host scan of the same corrected waste objective
    tabs = A.tables_from_cells(cells)
    res = A.newton_optimize_tables(tabs)
    scan_w = np.empty(n_cells)
    for i, c in enumerate(cells):
        periods = [
            max(c.platform.C * 1.01, c.strategy.T_R * m) for m in PERIOD_GRID
        ]
        scan_w[i] = min(
            analytic_waste(replace(c, strategy=replace(c.strategy, T_R=t)))
            for t in periods
        )
    scan_w = np.minimum(scan_w, 1.0)
    excess = float((res["waste"] - scan_w).max())

    emit(
        f"jax_engine/two_level_silent_cells{n_cells}",
        stats_s * 1e6 / n_cells,
        {
            "n_cells": n_cells,
            "lanes_per_cell": FUSED_GRID_RUNS,
            "n_lanes": grid.n_lanes,
            "fused_stats_s": round(stats_s, 3),
            "two_level_silent_cells_per_s": round(n_cells / stats_s, 1),
            "fused_vs_percell_max_diff": diff,
            "newton_excess_waste_max": excess,
            **stats_split,
        },
    )


def _run_devices_curve(reps: int = 3) -> None:
    """Emit the sharded-dispatch scaling records from a child process.

    The device count must be fixed before jax initializes, so the curve
    is measured under ``--xla_force_host_platform_device_count=8`` in a
    subprocess; the parent re-emits the child's JSON records."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.jax_engine",
         "--devices-curve-child", "--reps", str(reps)],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    if proc.returncode != 0:  # pragma: no cover - surfaced to the runner
        sys.stderr.write(proc.stderr)
        raise RuntimeError("devices-curve child failed")
    for line in proc.stdout.splitlines():
        if line.startswith("{"):
            rec = json.loads(line)
            emit(rec["name"], rec["us_per_call"], rec["derived"])


def _devices_curve_child(reps: int) -> None:
    """Body of the forced-8-host-device scaling measurement."""
    import statistics

    import jax

    plat, pred, strat = _cell()
    n = DEVICES_LANES
    traces = _traces(n, plat, pred)
    counts = [d for d in DEVICES_CURVE if d <= len(jax.devices())]
    base = None
    times = {d: [] for d in counts}
    for d in counts:  # compile every specialization up front
        simulate_batch_jax(WORK, plat, strat, traces, devices=d)
    # interleaved, median-of-N: the scaling ratios survive noisy shared
    # runners far better than best-of (all device counts see every phase
    # of the machine noise)
    for _ in range(max(reps, 5)):
        for d in counts:
            times[d].append(_timed(lambda d=d: simulate_batch_jax(
                WORK, plat, strat, traces, devices=d
            )))
    for d in counts:
        s = statistics.median(times[d])
        base = base or s
        print(json.dumps({
            "name": f"jax_engine/devices{d}_lanes{n}",
            "us_per_call": round(s * 1e6 / n, 1),
            "derived": {
                "jax_s": round(s, 3),
                "jax_lanes_per_s": round(n / s, 1),
                "speedup_vs_1dev": round(base / s, 2),
                "n_devices": d,
            },
        }), flush=True)


def _timed(fn) -> float:
    t0 = time.monotonic()
    fn()
    return time.monotonic() - t0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--devices", default=None,
        help="shard the timed engine calls ('all', an int, default: one)",
    )
    ap.add_argument("--devices-curve-child", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--reps", type=int, default=3, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.devices_curve_child:
        _devices_curve_child(args.reps)
    else:
        devices = args.devices
        if devices and devices != "all":
            devices = int(devices)
        run(quick=not args.full, devices=devices)
