"""Lanes-throughput curve: JAX device engine vs the NumPy batch engine.

One representative paper cell (Instant strategy, exponential faults,
accurate predictor) swept over lane counts; both engines consume the same
generated ``BatchTraces``, so the per-lane results must agree while the
wall-clock diverges.  The JAX engine is warmed up first (its jit compile
is a one-off, amortized across every later call at the same chunk shape)
and timed in steady state — the number a long Monte-Carlo campaign sees.

Acceptance trajectory: jax lanes/s >= numpy lanes/s at 10k lanes on CPU
(expected >> on an accelerator, where the Pallas hot step compiles to a
real Mosaic kernel instead of interpret mode).

    PYTHONPATH=src python -m benchmarks.jax_engine [--full]
    PYTHONPATH=src python -m benchmarks.run --only jax_engine
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import Platform, PredictorModel, make_event_traces_batch, simulate_batch
from repro.core import simulator as S
from repro.core.jax_sim import simulate_batch_jax

from .common import emit

MN = 60.0
WORK = 10 * 86400.0
LANES_QUICK = [1024, 4096, 10240]
LANES_FULL = [1024, 4096, 10240, 32768, 102400]


def _traces(n: int, plat: Platform, pred: PredictorModel, seed: int = 7):
    rng = np.random.default_rng(seed)
    return make_event_traces_batch(
        rng, n, horizon=12 * WORK, mtbf=plat.mu,
        recall=pred.recall, precision=pred.precision,
        window=pred.window, lead=pred.lead,
    )


def run(quick: bool = True) -> None:
    plat = Platform(mu=1000 * MN, C=10 * MN, D=1 * MN, R=10 * MN)
    pred = PredictorModel(0.85, 0.82, window=300.0, lead=3600.0)
    strat = S.instant(plat, pred)
    reps = 3 if quick else 5
    for n in LANES_QUICK if quick else LANES_FULL:
        traces = _traces(n, plat, pred)

        res_np = simulate_batch(WORK, plat, strat, traces)
        res_jx = simulate_batch_jax(WORK, plat, strat, traces)  # jit warmup

        # interleaved best-of-N: both engines see the same machine noise
        np_times, jx_times = [], []
        for _ in range(reps):
            np_times.append(
                _timed(lambda: simulate_batch(WORK, plat, strat, traces))
            )
            jx_times.append(
                _timed(lambda: simulate_batch_jax(WORK, plat, strat, traces))
            )
        np_s, jx_s = min(np_times), min(jx_times)

        agree = float(np.abs(res_jx.waste - res_np.waste).max())
        emit(
            f"jax_engine/lanes{n}",
            jx_s * 1e6 / n,
            {
                "numpy_s": round(np_s, 3),
                "jax_s": round(jx_s, 3),
                "numpy_lanes_per_s": round(n / np_s, 1),
                "jax_lanes_per_s": round(n / jx_s, 1),
                "speedup_vs_numpy": round(np_s / jx_s, 2),
                "max_abs_waste_diff": agree,
            },
        )


def _timed(fn) -> float:
    t0 = time.monotonic()
    fn()
    return time.monotonic() - t0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full)
