"""Benchmark harness — one module per paper table/figure plus the
system-level checkpoint/step/roofline benches.

Prints ``name,us_per_call,derived`` CSV (assignment format).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only sim_tables]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale run counts")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import ckpt_bench, recall_precision, roofline_report, sim_tables, step_bench, waste_curves

    modules = {
        "sim_tables": sim_tables,        # Tables 1-2
        "waste_curves": waste_curves,    # Figures 4-7
        "recall_precision": recall_precision,  # Figures 8-11
        "ckpt_bench": ckpt_bench,        # C measurement + waste impact
        "step_bench": step_bench,        # real CPU step timings
        "roofline_report": roofline_report,  # Roofline table from cache
    }
    print("name,us_per_call,derived")
    t0 = time.monotonic()
    for name, mod in modules.items():
        if args.only and name != args.only:
            continue
        print(f"# == {name} ==", file=sys.stderr, flush=True)
        mod.run(quick=not args.full)
    print(f"# total {time.monotonic() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
