"""Benchmark harness — one module per paper table/figure plus the
system-level checkpoint/step/roofline benches.

Prints ``name,us_per_call,derived`` CSV (assignment format) and writes the
same records as machine-readable JSON (default ``BENCH_sim.json``) so the
perf trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only sim_tables]
                                            [--json BENCH_sim.json]
"""

from __future__ import annotations

import argparse
import sys
import time

from . import common

#: benchmark registry (name -> module), importable lazily so ``--only``
#: validation fails fast instead of paying every module's import cost
MODULE_NAMES = (
    "sim_tables",        # Tables 1-2
    "waste_curves",      # Figures 4-7
    "recall_precision",  # Figures 8-11
    "jax_engine",        # device-engine throughput + scaling curves
    "ckpt_bench",        # C measurement + waste impact
    "step_bench",        # real CPU step timings
    "roofline_report",   # Roofline table from cache
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale run counts")
    ap.add_argument("--only", default=None, metavar="NAME",
                    help=f"run a single benchmark: {', '.join(MODULE_NAMES)}")
    ap.add_argument(
        "--json", default=None,
        help="machine-readable output path ('' disables; default "
        "BENCH_sim.json, or BENCH_sim.<module>.json under --only so "
        "partial runs never clobber the full tracking file)",
    )
    ap.add_argument(
        "--profile", nargs="?", const="bench-profile", default=None,
        metavar="DIR",
        help="wrap each benchmark module in jax.profiler.trace(DIR) "
        "(default DIR: bench-profile) and record the trace directory in "
        "the bench JSON; skipped with a warning if jax is unavailable",
    )
    args = ap.parse_args()
    if args.only and args.only not in MODULE_NAMES:
        ap.exit(
            2,
            f"error: unknown benchmark {args.only!r} for --only; "
            f"expected one of: {', '.join(MODULE_NAMES)}\n",
        )
    if args.json is None:
        args.json = (
            f"BENCH_sim.{args.only}.json" if args.only else "BENCH_sim.json"
        )

    import importlib

    modules = {
        name: importlib.import_module(f".{name}", __package__)
        for name in MODULE_NAMES
        if not args.only or name == args.only
    }
    profile_ctx = None
    if args.profile is not None:
        try:
            import jax.profiler as _jp

            profile_ctx = lambda: _jp.trace(args.profile)
        except Exception as exc:  # pragma: no cover - env-dependent
            print(f"# --profile unavailable ({exc}); running unprofiled",
                  file=sys.stderr)

    import contextlib

    common.reset_records()
    print("name,us_per_call,derived")
    t0 = time.monotonic()
    ran = []
    for name, mod in modules.items():
        print(f"# == {name} ==", file=sys.stderr, flush=True)
        with profile_ctx() if profile_ctx else contextlib.nullcontext():
            mod.run(quick=not args.full)
        ran.append(name)
    total = time.monotonic() - t0
    print(f"# total {total:.1f}s", file=sys.stderr)
    if args.json:
        meta = {
            "mode": "full" if args.full else "quick",
            "modules": ran,
            "total_s": round(total, 1),
        }
        if args.profile is not None and profile_ctx is not None:
            meta["profile_trace_dir"] = args.profile
        common.write_records_json(args.json, meta=meta)
        print(f"# wrote {args.json}", file=sys.stderr)
        if "jax_engine" in ran and not args.only:
            # the device-engine throughput curve also lands in its own
            # tracking file, next to the main BENCH_sim.json
            common.write_records_json(
                "BENCH_sim.jax_engine.json",
                meta=meta,
                records=[
                    r for r in common.RECORDS
                    if r["name"].startswith("jax_engine/")
                ],
            )
            print("# wrote BENCH_sim.jax_engine.json", file=sys.stderr)


if __name__ == "__main__":
    main()
