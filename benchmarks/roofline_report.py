"""Roofline table from the dry-run JSON cache (results/dryrun/).

Emits one CSV row per (arch x shape x mesh x tag) cell with the three
roofline terms, the dominant bottleneck, and the MODEL_FLOPS / HLO_FLOPs
ratio — the §Roofline deliverable, regenerable without recompiling."""

from __future__ import annotations

import glob
import json
import os

from .common import emit

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def rows(tag=None):
    out = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if "skipped" in r:
            continue
        if tag and r.get("tag") != tag:
            continue
        out.append(r)
    return out


def run(quick: bool = True) -> None:
    for r in rows():
        roof = r["roofline"]
        emit(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/{r.get('tag','baseline')}",
            roof["step_lower_bound_s"] * 1e6,
            {
                "t_comp_ms": round(roof["t_compute_s"] * 1e3, 2),
                "t_mem_ms": round(roof["t_memory_s"] * 1e3, 2),
                "t_coll_ms": round(roof["t_collective_s"] * 1e3, 2),
                "dominant": roof["dominant"],
                "useful_flops_frac": round(roof["useful_flops_fraction"], 3),
                "fits_hbm": r["memory"]["fits_hbm"],
                "mem_gib": round(r["memory"]["peak_est_bytes"] / 2**30, 2),
            },
        )


if __name__ == "__main__":
    run()
