"""Figures 4-7 analog: waste vs platform size N, analytic (capped and
uncapped periods) vs simulation, for both paper predictors.

The simulated column is produced by the experiment-sweep layer: every
(predictor, N) point is one cell of a single batched grid."""

from __future__ import annotations

import numpy as np

from repro.configs.paper import C, D, MU_IND, N_RANGE, R
from repro.core import (
    Platform,
    PredictorModel,
    optimize,
    waste_exact,
    waste_young,
)
from repro.core import simulator as S
from repro.experiments import ExperimentCell, run_cells

from .common import emit


def run(quick: bool = True) -> None:
    n_runs = 5 if quick else 25
    work = 8 * 86400.0
    cells = []
    for p, r in [(0.82, 0.85), (0.4, 0.7)]:
        pred = PredictorModel(r, p)
        for n in N_RANGE if not quick else N_RANGE[::2]:
            plat = Platform(mu=MU_IND / n, C=C, D=D, R=R)
            cells.append(
                ExperimentCell(
                    label=f"fig4/p{p}_r{r}/N{n}",
                    work=work,
                    platform=plat,
                    predictor=pred,
                    strategy=S.exact_prediction(plat, pred),
                )
            )
    sweep = run_cells(cells, n_runs=n_runs, seed=7)
    us_per_run = sweep.wall_time_s * 1e6 / sweep.grid.n_lanes

    for cr in sweep.cells:
        plat, pred = cr.cell.platform, cr.cell.predictor
        r, p = pred.recall, pred.precision
        # analytic: capped (Section 3.3 domain) and uncapped (Section 5)
        pol = optimize("exact", plat, pred)
        # T_extr at q=1 and q=0 (Equation (12) extrema, uncapped)
        t1 = float(np.sqrt(2.0 * plat.mu * C / (1.0 - r)))
        w_uncapped = waste_exact(t1, 1.0, C, D, R, plat.mu, r, p)
        ty = float(np.sqrt(2.0 * plat.mu * C))
        w_young = waste_young(ty, C, D, R, plat.mu)
        emit(
            cr.cell.label,
            us_per_run,
            {
                "waste_young_analytic": round(w_young, 4),
                "waste_pred_capped": round(pol.waste, 4),
                "waste_pred_uncapped": round(min(w_uncapped, 1.0), 4),
                "waste_pred_sim": round(cr.mean_waste, 4),
                "ci95": round(cr.ci95_waste, 4),
                "q": pol.q,
            },
        )


if __name__ == "__main__":
    run(quick=False)
