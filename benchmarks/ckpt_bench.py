"""Checkpoint-path benchmark: measured C (the paper's key constant).

Reports blocking vs full cost of the async path, codec compression ratios,
buddy-memory restore time, and what each C_eff implies for the optimal
period and waste at the paper's 2^19-processor platform."""

from __future__ import annotations

import math
import os
import shutil
import tempfile

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, BuddyMemoryCheckpoint, CheckpointStore
from repro.configs.paper import C, D, MU_IND, R
from repro.core import Platform, PredictorModel, optimize

from .common import emit, timed


def _state(mb: float = 64.0):
    rng = np.random.default_rng(0)
    n = int(mb * 2**20 / 4)
    return {
        "params": jax.numpy.asarray(rng.standard_normal(n // 2).astype(np.float32)),
        "m": jax.numpy.asarray(rng.standard_normal(n // 4).astype(np.float32)),
        "v": jax.numpy.asarray(
            np.abs(rng.standard_normal(n // 4)).astype(np.float32)
        ),
    }


def run(quick: bool = True) -> None:
    state = _state(32.0 if quick else 256.0)
    raw_bytes = sum(x.nbytes for x in jax.tree.leaves(state))
    root = tempfile.mkdtemp(prefix="ckpt_bench")
    try:
        for codec in ["raw", "int8", "int8_delta"]:
            store = CheckpointStore(os.path.join(root, codec), codec=codec)
            prev = None
            if codec == "int8_delta":
                store.save(0, state)
                prev = state
            m, us = timed(store.save, 1, state, prev_tree=prev)
            _, us_r = timed(
                store.restore, 1, jax.eval_shape(lambda: state), None, prev
            )
            emit(
                f"ckpt/save/{codec}",
                us,
                {
                    "MBps": round(raw_bytes / (us / 1e6) / 2**20, 1),
                    "ratio": round(m["raw_bytes"] / m["stored_bytes"], 2),
                    "restore_us": round(us_r, 1),
                },
            )

        ac = AsyncCheckpointer(CheckpointStore(os.path.join(root, "async")))
        c_block, us = timed(ac.save, 2, state)
        ac.wait()
        mm = ac.metrics
        emit(
            "ckpt/async", us,
            {
                "c_block_s": round(mm["c_block"], 4),
                "c_full_s": round(mm["c_full"], 4),
                "overlap_ratio": round(mm["c_full"] / max(mm["c_block"], 1e-9), 1),
            },
        )

        bm = BuddyMemoryCheckpoint(n_nodes=2)
        _, us_save = timed(bm.save, 3, state)
        _, us_rest = timed(bm.restore, 0, lost=True)
        emit("ckpt/buddy", us_save, {"restore_us": round(us_rest, 1)})

        # beyond-paper: two-level (buddy RAM + disk) optimal hierarchy
        from repro.core.periods import two_level_periods
        from repro.core.waste import waste_two_level, waste_young

        mu19 = MU_IND / 2**19
        f = 0.9  # single-node failures recoverable from the buddy tier
        c_m = C / 20.0
        t_m, t_d = two_level_periods(mu19, c_m, C, f)
        w2 = waste_two_level(t_m, t_d, c_m, C, D, D, R, mu19, f)
        t_y = optimize("young", Platform(mu=mu19, C=C, D=D, R=R)).T_R
        w1 = waste_young(t_y, C, D, R, mu19)
        emit(
            "ckpt/two_level", 0.0,
            {
                "T_mem_s": round(t_m, 1),
                "T_disk_s": round(t_d, 1),
                "waste": round(w2, 4),
                "vs_single_level": round(w1, 4),
                "reduction_pct": round(100 * (1 - w2 / w1), 1),
            },
        )

        # what C_eff means for the paper's platform (2^19 procs)
        plat0 = Platform(mu=MU_IND / 2**19, C=C, D=D, R=R)
        pred = PredictorModel(0.85, 0.82)
        w0 = optimize("exact", plat0, pred).waste
        for factor, name in [(1.0, "baseline_C"), (0.25, "int8_C"), (0.1, "async_C")]:
            plat = Platform(mu=plat0.mu, C=C * factor, D=D, R=R)
            pol = optimize("exact", plat, pred)
            emit(
                f"ckpt/waste_impact/{name}", 0.0,
                {
                    "C_s": C * factor,
                    "T_opt_s": round(pol.T_R, 1),
                    "waste": round(pol.waste, 4),
                    "waste_reduction_pct": round(100 * (1 - pol.waste / w0), 1),
                },
            )
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    run(quick=False)
