"""Benchmark regression gate (CI step).

Re-runs the two tracked benchmark modules — ``waste_curves`` (the paper's
Figures 4-7 cells: analytic waste vs simulated waste) and ``jax_engine``
(device-engine throughput + multi-device scaling) — and fails if either

* the *correctness* signal drifts: a cell's simulated waste moves away
  from the committed baseline (the sweep is seeded, so a drift means the
  engine's semantics changed) or leaves the analytic-model envelope, the
  jax-vs-numpy engine disagreement exceeds float-rounding level, or the
  one-dispatch mixed-law grid stops matching its per-family baseline
  bit-for-bit; or
* the *performance* signal regresses: an engine's lanes/sec — or the
  fused paper-grid sweep's cells/sec (``fused_cells_per_s``), the
  mixed-law one-dispatch sweep's (``mixed_law_cells_per_s``) or the
  two-level + silent scenario sweep's (``two_level_silent_cells_per_s``)
  — falls more than ``--perf-tol`` (default 30%) below the committed
  ``BENCH_*.json`` baseline; or
* the *durability* price regresses: the resumable campaign runner's
  snapshot overhead vs the plain fused sweep at the same chunking
  (``campaign_overhead_frac``, a self-contained in-record comparison)
  exceeds ``--campaign-tol`` (default 5%).

Fresh records are written to ``--out-dir`` so the CI workflow can upload
them as artifacts (and a maintainer can promote them to new baselines).

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--baseline-dir .] [--out-dir bench-fresh] \
        [--waste-tol 0.12] [--drift-tol 0.02] [--perf-tol 0.30]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List

from . import common

#: tracked modules and their committed baseline files
BASELINES = {
    "waste_curves": "BENCH_sim.waste_curves.json",
    "jax_engine": "BENCH_sim.jax_engine.json",
}


def _by_name(records: List[Dict]) -> Dict[str, Dict]:
    return {r["name"]: r for r in records}


def compare(
    baseline: List[Dict],
    fresh: List[Dict],
    *,
    waste_tol: float = 0.12,
    drift_tol: float = 0.02,
    perf_tol: float = 0.30,
    agree_tol: float = 1e-9,
    campaign_tol: float = 0.05,
) -> List[str]:
    """Compare fresh benchmark records against committed baselines.

    Returns a list of human-readable failure strings (empty = gate
    passes).  Baseline-relative checks only fire for names present in
    *both* record sets, so adding new benchmarks never trips the gate
    retroactively; *self-contained* checks (the campaign-overhead
    fraction, which carries its own in-record baseline) fire regardless."""
    failures: List[str] = []
    base = _by_name(baseline)
    for rec in fresh:
        d = rec.get("derived")
        if not isinstance(d, dict):
            continue

        # self-contained: durable campaign sweeps must price their
        # chunk-boundary snapshots within campaign_tol of the plain
        # fused sweep at the same chunking (the record carries both legs)
        if (
            campaign_tol
            and "campaign_overhead_frac" in d
            and d["campaign_overhead_frac"] > campaign_tol
        ):
            failures.append(
                f"{rec['name']}: campaign snapshot overhead "
                f"{d['campaign_overhead_frac']:.1%} > {campaign_tol:.0%} "
                f"(campaign {d.get('campaign_s')}s vs plain "
                f"{d.get('plain_s')}s)"
            )

        # -- self-contained correctness invariants: these hold absolutely
        # (no committed baseline involved), so they gate brand-new
        # records too -------------------------------------------------- #

        # correctness: simulated waste within the analytic envelope
        if "waste_pred_sim" in d and "waste_pred_capped" in d:
            gap = abs(d["waste_pred_sim"] - d["waste_pred_capped"])
            if gap > waste_tol:
                failures.append(
                    f"{rec['name']}: analytic-vs-sim waste gap {gap:.4f} "
                    f"> {waste_tol} (sim {d['waste_pred_sim']}, "
                    f"analytic {d['waste_pred_capped']})"
                )

        # correctness: device engine still agrees with the NumPy engine
        if "max_abs_waste_diff" in d and d["max_abs_waste_diff"] > agree_tol:
            failures.append(
                f"{rec['name']}: jax-vs-numpy waste diff "
                f"{d['max_abs_waste_diff']:.2e} > {agree_tol:.0e}"
            )

        # correctness: fused and per-cell sweep dispatch consume the
        # same counter streams, so their per-cell results are exact
        if (
            "fused_vs_percell_max_diff" in d
            and d["fused_vs_percell_max_diff"] > agree_tol
        ):
            failures.append(
                f"{rec['name']}: fused-vs-percell waste diff "
                f"{d['fused_vs_percell_max_diff']:.2e} > {agree_tol:.0e}"
            )

        # correctness: the one-dispatch mixed-law grid and the
        # per-family baseline run the same law-indexed sampler on the
        # same counter streams, so their per-cell stats are bit-exact
        if (
            "fused_vs_perfamily_max_diff" in d
            and d["fused_vs_perfamily_max_diff"] > 0.0
        ):
            failures.append(
                f"{rec['name']}: fused-vs-perfamily stats diff "
                f"{d['fused_vs_perfamily_max_diff']:.2e} != 0"
            )

        # correctness: the batched-Newton period optimizer must dominate
        # the host grid scan on every cell (the continuous optimum can
        # only undercut a 10-point period grid; anything beyond float
        # rounding means the optimizer converged to the wrong point)
        if (
            "newton_excess_waste_max" in d
            and d["newton_excess_waste_max"] > 1e-12
        ):
            failures.append(
                f"{rec['name']}: Newton period waste exceeds the host "
                f"scan best by {d['newton_excess_waste_max']:.2e} "
                "(must dominate to float rounding)"
            )

        # -- baseline-relative checks: only for names present in the
        # committed records ------------------------------------------- #
        b = base.get(rec["name"])
        if b is None:
            continue
        bd = b.get("derived") if isinstance(b.get("derived"), dict) else {}

        # correctness: reproducing the seeded baseline waste value
        if "waste_pred_sim" in d and "waste_pred_sim" in bd:
            drift = abs(d["waste_pred_sim"] - bd["waste_pred_sim"])
            if drift > drift_tol:
                failures.append(
                    f"{rec['name']}: simulated waste drifted "
                    f"{drift:.4f} > {drift_tol} vs baseline "
                    f"(fresh {d['waste_pred_sim']}, "
                    f"baseline {bd['waste_pred_sim']})"
                )

        # performance: lanes/sec (and the fused sweep's cells/sec)
        # within perf_tol of the baseline (the jax_dev floor gates the
        # device-generation trace mode, fused_cells_per_s the fused
        # experiment dispatch, analytic_opt_cells_per_s the batched-
        # Newton optimizer dispatch)
        if perf_tol:
            for key in (
                "jax_lanes_per_s", "numpy_lanes_per_s",
                "jax_dev_lanes_per_s", "fused_cells_per_s",
                "mixed_law_cells_per_s", "analytic_opt_cells_per_s",
                "two_level_silent_cells_per_s",
            ):
                if key in d and key in bd and bd[key] > 0:
                    floor = (1.0 - perf_tol) * bd[key]
                    if d[key] < floor:
                        failures.append(
                            f"{rec['name']}: {key} {d[key]:.0f} regressed "
                            f">{perf_tol:.0%} below baseline {bd[key]:.0f}"
                        )
    return failures


def _load(path: str) -> List[Dict]:
    with open(path) as f:
        return json.load(f)["benchmarks"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default=".",
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--out-dir", default="bench-fresh",
                    help="where fresh BENCH_*.json records are written")
    ap.add_argument("--waste-tol", type=float, default=0.12,
                    help="max |analytic - simulated| waste per cell")
    ap.add_argument("--drift-tol", type=float, default=0.02,
                    help="max simulated-waste drift vs the seeded baseline")
    ap.add_argument("--perf-tol", type=float, default=0.30,
                    help="max fractional lanes/sec regression (0 disables)")
    ap.add_argument("--campaign-tol", type=float, default=0.05,
                    help="max campaign-vs-plain sweep snapshot overhead "
                    "fraction (0 disables)")
    ap.add_argument("--modules", default=None, metavar="A,B",
                    help="comma-separated subset of "
                    f"{','.join(BASELINES)} (default: all)")
    ap.add_argument("--skip-preflight", action="store_true",
                    help="skip the repro.analysis static-analysis preflight")
    args = ap.parse_args()

    if not args.skip_preflight:
        # refuse to spend benchmark minutes on an engine whose static
        # contracts are already broken: the lint/twin passes are cheap
        # AST work, the jaxpr audit traces abstractly (no XLA executes)
        from repro.analysis import run_all

        print("# == preflight: repro.analysis --all ==",
              file=sys.stderr, flush=True)
        code, report = run_all()
        if code != 0:
            print("PREFLIGHT FAILED: static analysis is dirty — "
                  "fix it before benchmarking:")
            for line in report["lint"]["new"]:
                print(f"  - NEW {line}")
            for err in report["twins"]["errors"]:
                print(f"  - {err.splitlines()[0]}")
            for rep in report["jaxpr"]["reports"]:
                for err in rep["errors"]:
                    print(f"  - [{rep['label']}] {err}")
            sys.exit(1)

    selected = dict(BASELINES)
    if args.modules:
        unknown = set(args.modules.split(",")) - set(BASELINES)
        if unknown:
            ap.exit(2, f"error: unknown module(s) {sorted(unknown)}; "
                       f"expected subset of {sorted(BASELINES)}\n")
        selected = {
            k: v for k, v in BASELINES.items()
            if k in args.modules.split(",")
        }

    import importlib

    os.makedirs(args.out_dir, exist_ok=True)
    failures: List[str] = []
    for name, fname in selected.items():
        bpath = os.path.join(args.baseline_dir, fname)
        if not os.path.exists(bpath):
            failures.append(f"{name}: missing baseline {bpath}")
            continue
        mod = importlib.import_module(f".{name}", __package__)
        common.reset_records()
        print(f"# == regression gate: {name} ==", file=sys.stderr, flush=True)
        mod.run(quick=True)
        fresh = list(common.RECORDS)
        common.write_records_json(
            os.path.join(args.out_dir, fname),
            meta={"mode": "quick", "modules": [name]},
        )
        failures.extend(
            compare(
                _load(bpath), fresh,
                waste_tol=args.waste_tol, drift_tol=args.drift_tol,
                perf_tol=args.perf_tol, campaign_tol=args.campaign_tol,
            )
        )

    if failures:
        print(f"\nREGRESSION GATE FAILED ({len(failures)} finding(s)):")
        for f in failures:
            print(f"  - {f}")
        sys.exit(1)
    print("\nregression gate passed "
          f"(fresh records in {args.out_dir}/)")


if __name__ == "__main__":
    main()
