"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun/.

    PYTHONPATH=src python -m benchmarks.make_roofline_md [tag]
"""

from __future__ import annotations

import sys

from .roofline_report import rows


def gib(b):
    return b / 2**30


def main(tag: str = "baseline") -> None:
    rs = [r for r in rows() if r.get("tag") == tag]
    rs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    print("### Dry-run: memory per device (both meshes)\n")
    print("| arch | shape | mesh | compile s | args GiB | temp GiB | peak GiB | fits 16GiB |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rs:
        m = r["memory"]
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['t_compile_s']} "
            f"| {gib(m['argument_bytes']):.2f} | {gib(m['temp_bytes']):.2f} "
            f"| {gib(m['peak_est_bytes']):.2f} | {'yes' if m['fits_hbm'] else 'NO'} |"
        )

    print("\n### Roofline terms (single-pod 16x16, per step)\n")
    print("| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant "
          "| MODEL/HLO flops | bound ms |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rs:
        if r["mesh"] != "16x16":
            continue
        ro = r["roofline"]
        print(
            f"| {r['arch']} | {r['shape']} | {ro['t_compute_s']*1e3:.2f} "
            f"| {ro['t_memory_s']*1e3:.2f} | {ro['t_collective_s']*1e3:.2f} "
            f"| **{ro['dominant']}** | {ro['useful_flops_fraction']:.2f} "
            f"| {ro['step_lower_bound_s']*1e3:.2f} |"
        )

    print("\n### Collective breakdown (single-pod)\n")
    print("| arch | shape | wire GB/dev | by kind |")
    print("|---|---|---|---|")
    for r in rs:
        if r["mesh"] != "16x16":
            continue
        h = r["hlo"]
        kinds = ", ".join(
            f"{k.replace('all-','a')}: {v/1e9:.1f}"
            for k, v in sorted(h["collective_by_kind"].items())
        )
        print(f"| {r['arch']} | {r['shape']} | {h['collective_bytes']/1e9:.2f} | {kinds} |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "baseline")
