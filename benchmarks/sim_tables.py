"""Tables 1-2 analog: job execution times and gains vs Young.

Grid: (p, r) in {(0.82, 0.85), (0.4, 0.7)} x N in {2^16, 2^19} x
I in {300 s, 3000 s} x failure law in {Exponential, Weibull k=0.7,
Weibull k=0.5 (fresh-start superposed — see DESIGN.md on the paper's
under-specified trace generator)}.  Strategies: Young baseline,
ExactPrediction, Instant, NoCkptI, WithCkptI.

The whole grid is declared as experiment cells and executed by the
vectorized sweep layer (one batched engine call per failure-law group).

    PYTHONPATH=src python -m benchmarks.sim_tables [--quick] [--engine batch|jax|scalar]
    PYTHONPATH=src python -m benchmarks.sim_tables --quick --compare   # speedup + equivalence
"""

from __future__ import annotations

import numpy as np

from repro.core import EngineConfig, Platform, PredictorModel
from repro.core import events as E
from repro.core import simulator as S
from repro.configs.paper import C, D, MU_IND, R
from repro.experiments import ExperimentCell, run_cells

from .common import emit

MN = 60.0
WORK = 10 * 86400.0


def _strategies(plat, pred):
    return [
        S.young(plat),
        S.exact_prediction(plat, PredictorModel(pred.recall, pred.precision)),
        S.instant(plat, pred),
        S.nockpt(plat, pred),
        S.withckpt(plat, pred),
    ]


def build_cells(quick: bool = True) -> list[ExperimentCell]:
    dists = [
        ("exp", E.exponential(), None),
        ("weibull0.7", E.weibull(0.7), None),
        ("weibull0.5-fresh", E.weibull(0.5), "superposed"),
    ]
    cells: list[ExperimentCell] = []
    for p, r in [(0.82, 0.85), (0.4, 0.7)]:
        for n_procs in [2**16, 2**19]:
            plat = Platform(mu=MU_IND / n_procs, C=C, D=D, R=R)
            for I in [300.0, 3000.0]:
                pred = PredictorModel(r, p, window=I, lead=3600.0)
                for dname, dist, mode in dists:
                    if quick and dname == "weibull0.5-fresh" and n_procs == 2**19:
                        continue  # heavy burn-in trace; full mode only
                    n_comp = min(n_procs, 2**15) if mode == "superposed" else None
                    for strat in _strategies(plat, pred):
                        cells.append(
                            ExperimentCell(
                                label=(
                                    f"table12/{dname}/p{p}_r{r}/N{n_procs}/"
                                    f"I{int(I)}/{strat.name}"
                                ),
                                work=WORK,
                                platform=plat,
                                predictor=pred,
                                strategy=strat,
                                fault_dist=dist,
                                n_components=n_comp,
                                horizon_factor=30,
                            )
                        )
    return cells


def run_sweep(quick: bool = True, engine: str = "batch", seed: int = 100):
    # quick mode used 6 runs when the scalar path was the bottleneck; the
    # batched engine amortizes extra runs almost for free, so quick now
    # carries 16 (full: 30, the paper's own count is 100)
    n_runs = 16 if quick else 30
    return run_cells(
        build_cells(quick), n_runs=n_runs, seed=seed,
        config=EngineConfig(engine=engine),
    )


def run(quick: bool = True, engine: str = "batch") -> None:
    sweep = run_sweep(quick, engine=engine)
    us_per_run = sweep.wall_time_s * 1e6 / sweep.grid.n_lanes
    base_mk: dict[str, float] = {}
    for cr in sweep.cells:
        label = cr.cell.label
        mk = cr.mean_makespan
        prefix = label.rsplit("/", 1)[0]
        if cr.cell.strategy.name == "Young":
            base_mk[prefix] = mk
        base = base_mk.get(prefix)
        gain = 0.0 if base is None else (1 - mk / base)
        emit(
            label,
            us_per_run,
            {
                "days": round(mk / 86400, 2),
                "gain_vs_young_pct": round(100 * gain, 1),
                "waste": round(cr.mean_waste, 4),
                "ci95_waste": round(cr.ci95_waste, 4),
            },
        )


def compare(quick: bool = True) -> dict:
    """Batched vs scalar paths on the same grid.

    Two baselines: ``legacy`` is the seed's full scalar pipeline (per-run
    object-based trace generation + scalar engine) — the wall-clock
    comparison (acceptance: >=10x); ``scalar`` is the reference engine fed
    the *identical* batch-generated traces — the per-cell mean-waste
    agreement check (acceptance: <= 2 rel%, actual: exact up to float
    fast-forward fusion, ~1e-15).
    """
    batch = run_sweep(quick, engine="batch")
    oracle = run_sweep(quick, engine="scalar")
    legacy = run_sweep(quick, engine="legacy")
    rel = [
        abs(b.mean_waste - s.mean_waste) / max(abs(s.mean_waste), 1e-12)
        for b, s in zip(batch.cells, oracle.cells)
    ]
    out = {
        "batch_s": round(batch.wall_time_s, 2),
        "legacy_scalar_s": round(legacy.wall_time_s, 2),
        "oracle_scalar_s": round(oracle.wall_time_s, 2),
        "speedup_vs_legacy": round(legacy.wall_time_s / batch.wall_time_s, 1),
        "speedup_vs_oracle": round(oracle.wall_time_s / batch.wall_time_s, 1),
        "max_rel_waste_diff_same_traces": float(np.max(rel)),
        "n_cells": len(batch.cells),
        "n_runs": batch.grid.n_runs,
    }
    emit("table12/compare", batch.wall_time_s * 1e6 / batch.grid.n_lanes, out)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--engine", choices=["batch", "jax", "scalar", "legacy"], default="batch"
    )
    ap.add_argument(
        "--compare", action="store_true",
        help="run both engines on the same grid; report speedup + agreement",
    )
    args = ap.parse_args()
    if args.compare:
        compare(quick=args.quick)
    else:
        run(quick=args.quick, engine=args.engine)
