"""Tables 1-2 analog: job execution times and gains vs Young.

Grid: (p, r) in {(0.82, 0.85), (0.4, 0.7)} x N in {2^16, 2^19} x
I in {300 s, 3000 s} x failure law in {Exponential, Weibull k=0.7,
Weibull k=0.5 (fresh-start superposed — see DESIGN.md on the paper's
under-specified trace generator)}.  Strategies: Young baseline,
ExactPrediction, Instant, NoCkptI, WithCkptI.
"""

from __future__ import annotations

import numpy as np

from repro.core import Platform, PredictorModel, simulate_many
from repro.core import events as E
from repro.core import simulator as S
from repro.configs.paper import C, D, MU_IND, R

from .common import emit, timed

MN = 60.0
WORK = 10 * 86400.0


def _strategies(plat, pred):
    return [
        S.young(plat),
        S.exact_prediction(plat, PredictorModel(pred.recall, pred.precision)),
        S.instant(plat, pred),
        S.nockpt(plat, pred),
        S.withckpt(plat, pred),
    ]


def run(quick: bool = True) -> None:
    n_runs = 6 if quick else 30
    dists = [
        ("exp", E.exponential(), None),
        ("weibull0.7", E.weibull(0.7), None),
        ("weibull0.5-fresh", E.weibull(0.5), "superposed"),
    ]
    for p, r in [(0.82, 0.85), (0.4, 0.7)]:
        for n_procs in [2**16, 2**19]:
            plat = Platform(mu=MU_IND / n_procs, C=C, D=D, R=R)
            for I in [300.0, 3000.0]:
                pred = PredictorModel(r, p, window=I, lead=3600.0)
                for dname, dist, mode in dists:
                    if quick and dname == "weibull0.5-fresh" and n_procs == 2**19:
                        continue  # heavy burn-in trace; full mode only
                    kw = dict(
                        n_runs=n_runs,
                        seed=100,
                        fault_dist=dist,
                        horizon_factor=30,
                    )
                    if mode == "superposed":
                        kw["n_components"] = min(n_procs, 2**15)
                    base_t = None
                    for strat in _strategies(plat, pred):
                        res, us = timed(
                            simulate_many, WORK, plat, strat, pred, **kw
                        )
                        mk = float(np.mean([x.makespan for x in res]))
                        if strat.name == "Young":
                            base_t = mk
                        gain = 0.0 if base_t is None else (1 - mk / base_t)
                        emit(
                            f"table12/{dname}/p{p}_r{r}/N{n_procs}/I{int(I)}/"
                            f"{strat.name}",
                            us / n_runs,
                            {
                                "days": round(mk / 86400, 2),
                                "gain_vs_young_pct": round(100 * gain, 1),
                                "waste": round(
                                    float(np.mean([x.waste for x in res])), 4
                                ),
                            },
                        )


if __name__ == "__main__":
    run(quick=False)
