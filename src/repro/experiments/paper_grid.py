"""The paper's Section 5 experiment grid as a reusable cell factory.

One place defines the sweep every consumer shares — the fused-dispatch
benchmark (:mod:`benchmarks.jax_engine`), the statistical validation
suite (:mod:`tests.test_validation` via
:mod:`repro.experiments.validation`), and ad-hoc reproduction runs:

* **platforms**: the paper's Section 5 scenarios (C = R = 10 mn,
  D = 1 mn, individual MTBF 125 years, N = 2^14 .. 2^19 processors —
  platform MTBF ~4000 mn down to ~125 mn), from
  :mod:`repro.configs.paper`;
* **predictors**: the paper's two operating points — precision 0.82 /
  recall 0.85 and precision 0.4 / recall 0.7;
* **strategies**: the q = 0 Young baseline, ExactPrediction (Section 3),
  Migration (Section 3.4), and the window strategies Instant / NoCkptI /
  WithCkptI (Section 4) at each window length, every one at its
  analytic-optimal (uncapped) period — the policy the paper's own
  simulations validate.

All cells of a preset share one failure-law family (exponential unless
overridden), so the fused device dispatch runs the whole grid as a
single megabatch per law.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

from ..configs.paper import N_RANGE, platform
from ..core import simulator as S
from ..core.events import Distribution
from ..core.waste import Platform, PredictorModel
from .grid import ExperimentCell

__all__ = [
    "PAPER_PREDICTORS",
    "paper_grid_cells",
    "paper_policy_table",
    "two_level_grid_cells",
    "silent_grid_cells",
]

#: the paper's two (recall, precision) predictor operating points
PAPER_PREDICTORS = {
    "p82r85": PredictorModel(recall=0.85, precision=0.82),
    "p40r70": PredictorModel(recall=0.7, precision=0.4),
}

#: preset -> (platform sizes, window lengths in seconds)
_PRESETS = {
    # trimmed-but-representative: every strategy family and predictor on
    # small / medium / large platforms — the CI validation grid
    "validation": (N_RANGE[::2], (1200.0, 6000.0)),
    # every platform size, one window: the fused-dispatch benchmark grid
    "bench": (N_RANGE, (1200.0,)),
    # the full Section 5 sweep
    "full": (N_RANGE, (1200.0, 6000.0)),
}


def paper_grid_cells(
    preset: str = "validation",
    work: float = 8 * 86400.0,
    migration_m: float = 300.0,
    lead: float = 3600.0,
    fault_dist: Optional[Distribution] = None,
    n_list: Optional[Sequence[int]] = None,
    windows: Optional[Sequence[float]] = None,
    horizon_factor: float = 12.0,
) -> List[ExperimentCell]:
    """Build the paper grid's :class:`ExperimentCell` list.

    ``preset`` picks the (platform sizes, windows) pair; ``n_list`` /
    ``windows`` override it.  Every (platform, predictor) point carries
    its own Young baseline so the paired-trace design holds within each
    predictor scenario (the baseline shares the fault stream and ignores
    the predictions)."""
    if preset not in _PRESETS:
        raise ValueError(
            f"unknown preset {preset!r} (expected one of {sorted(_PRESETS)})"
        )
    p_n, p_w = _PRESETS[preset]
    n_list = list(p_n if n_list is None else n_list)
    windows = list(p_w if windows is None else windows)
    cells: List[ExperimentCell] = []
    for pk, pred in PAPER_PREDICTORS.items():
        for n in n_list:
            plat = platform(n, M=migration_m)
            exact_pred = PredictorModel(pred.recall, pred.precision, lead=lead)
            prefix = f"{pk}/N{n}"

            def cell(tag: str, strat, p, prefix=prefix, plat=plat) -> ExperimentCell:
                return ExperimentCell(
                    label=f"{prefix}/{tag}",
                    work=work,
                    platform=plat,
                    predictor=p,
                    strategy=strat,
                    fault_dist=fault_dist,
                    horizon_factor=horizon_factor,
                )

            cells.append(cell("Young", S.young(plat), exact_pred))
            cells.append(
                cell("Exact", S.exact_prediction(plat, exact_pred), exact_pred)
            )
            cells.append(
                cell("Migration", S.migration(plat, exact_pred), exact_pred)
            )
            for w in windows:
                wpred = PredictorModel(
                    pred.recall, pred.precision, lead=lead, window=w
                )
                cells.append(
                    cell(f"I{int(w)}/Instant", S.instant(plat, wpred), wpred)
                )
                cells.append(
                    cell(f"I{int(w)}/NoCkptI", S.nockpt(plat, wpred), wpred)
                )
                cells.append(
                    cell(f"I{int(w)}/WithCkptI", S.withckpt(plat, wpred), wpred)
                )
    return cells


#: beyond-paper scenario knobs: disk-tier cost multiple and fast-tier
#: coverage fractions (two-level cells), verification-cost multiples
#: (silent cells)
_TL_DISK_MULT = 3.0
_TL_FRACS = (0.6, 0.9)
_SIL_V_MULTS = (0.5, 2.0)

#: predictionless predictor row (recall 0: nothing is ever trusted)
_NO_PRED = PredictorModel(recall=0.0, precision=1.0)


def two_level_grid_cells(
    preset: str = "validation",
    work: float = 8 * 86400.0,
    lead: float = 3600.0,
    fault_dist: Optional[Distribution] = None,
    n_list: Optional[Sequence[int]] = None,
    horizon_factor: float = 12.0,
) -> List[ExperimentCell]:
    """Beyond-paper two-level scenario grid: memory-tier checkpoints
    (period T_m) nested in disk-tier checkpoints (stride rho), disk
    costs ``_TL_DISK_MULT`` times the memory costs, fast-tier coverage
    swept over ``_TL_FRACS``.  Each (platform, f) point carries an
    untrusted baseline plus one trusted cell per paper predictor, all at
    the corrected joint extremizers of
    :func:`repro.core.periods.two_level_periods`."""
    if preset not in _PRESETS:
        raise ValueError(
            f"unknown preset {preset!r} (expected one of {sorted(_PRESETS)})"
        )
    n_list = list(_PRESETS[preset][0] if n_list is None else n_list)
    cells: List[ExperimentCell] = []
    for n in n_list:
        base = platform(n)
        for f in _TL_FRACS:
            plat = Platform(
                mu=base.mu, C=base.C, D=base.D, R=base.R,
                C2=_TL_DISK_MULT * base.C, R2=_TL_DISK_MULT * base.R, f=f,
            )
            prefix = f"N{n}/f{int(round(100 * f))}"
            cells.append(
                ExperimentCell(
                    label=f"tl/{prefix}/TwoLevel",
                    work=work, platform=plat, predictor=_NO_PRED,
                    strategy=S.two_level(plat), fault_dist=fault_dist,
                    horizon_factor=horizon_factor,
                )
            )
            for pk, pred in PAPER_PREDICTORS.items():
                epred = PredictorModel(pred.recall, pred.precision, lead=lead)
                cells.append(
                    ExperimentCell(
                        label=f"tl/{pk}/{prefix}/TwoLevel",
                        work=work, platform=plat, predictor=epred,
                        strategy=S.two_level(plat, epred),
                        fault_dist=fault_dist,
                        horizon_factor=horizon_factor,
                    )
                )
    return cells


def silent_grid_cells(
    preset: str = "validation",
    work: float = 8 * 86400.0,
    fault_dist: Optional[Distribution] = None,
    n_list: Optional[Sequence[int]] = None,
    horizon_factor: float = 12.0,
) -> List[ExperimentCell]:
    """Beyond-paper silent-error scenario grid (arXiv:1310.8486): latent
    corruptions detected only by the every-``k_V``-th-checkpoint
    verification, verification cost swept over ``_SIL_V_MULTS`` times C.
    Predictors never fire on latent corruptions, so every cell runs the
    untrusted :func:`repro.core.simulator.silent` policy at its optimal
    (period, stride) point."""
    if preset not in _PRESETS:
        raise ValueError(
            f"unknown preset {preset!r} (expected one of {sorted(_PRESETS)})"
        )
    n_list = list(_PRESETS[preset][0] if n_list is None else n_list)
    cells: List[ExperimentCell] = []
    for n in n_list:
        base = platform(n)
        for vm in _SIL_V_MULTS:
            plat = Platform(
                mu=base.mu, C=base.C, D=base.D, R=base.R, V=vm * base.C
            )
            cells.append(
                ExperimentCell(
                    label=f"sil/N{n}/V{int(round(100 * vm))}/Silent",
                    work=work, platform=plat, predictor=_NO_PRED,
                    strategy=S.silent(plat), fault_dist=fault_dist,
                    horizon_factor=horizon_factor,
                )
            )
    return cells


def paper_policy_table(preset: str = "validation", devices=None, **kwargs):
    """Batched-Newton optimal policies for a whole paper-grid preset.

    Builds the preset's cells, lowers them onto the shared per-cell
    parameter tables and solves every cell's optimal regular period in
    one jitted device dispatch (:func:`repro.core.optimize_cells`).
    Returns a :class:`~repro.core.analytic.PolicyTable` indexed like the
    cell list; ``kwargs`` pass through to :func:`paper_grid_cells`."""
    from ..core import analytic as A  # lazy: cell factories stay jax-free

    cells = paper_grid_cells(preset, **kwargs)
    return A.optimize_cells(cells, devices=devices)
