"""Statistical acceptance harness: simulation vs the analytic waste models.

The paper's headline claim is that its analytic optimal periods are
"nicely corroborated by a comprehensive set of simulations".  This module
pins that claim with a *controlled* statistical contract instead of ad-hoc
tolerances:

* :func:`analytic_waste` evaluates the closed-form first-order waste of a
  grid cell's strategy at its operating point (Equations (1), (3), (4),
  (5), (6) of the paper via :mod:`repro.core.waste`);
* :func:`cell_z_rows` turns each simulated cell into an
  equivalence-margin z-test.  The first-order models carry *systematic*
  error O(T/mu) (they assume at most one event per period and a uniform
  fault position, so simulation sits consistently at or below the
  analytic value — exactly what the paper's own figures show); a fixed
  tolerance on the mean would therefore either mask engine regressions
  or turn flaky as ``n_runs`` changes.  Instead each cell tests

      H0: |waste_sim - waste_analytic| <= margin

  with an asymmetric margin (simulation may undershoot the pessimistic
  model by ``rel_margin_lo``, but overshoot — the direction real engine
  regressions push — only by ``rel_margin_hi``), and the Monte-Carlo
  noise enters only through the standard error, so the test neither
  loosens nor tightens as run counts change;
* :func:`holm_bonferroni` applies step-down multiple-comparison control
  across the grid: the suite's family-wise false-alarm rate is pinned at
  ``alpha`` no matter how many cells the grid grows to, which is what
  stops CI from trading tolerance slack for flakiness.

The margins below were calibrated on the paper grid (exponential faults,
``n_runs`` 100-400): observed |model error| peaks around 17-20% of the
analytic value for the uncapped periods at large N (T/mu ~ 0.7) and a few
percent elsewhere, while the engines agree with each other to float
rounding — a genuine engine regression moves the simulated waste far
outside these envelopes.
"""

from __future__ import annotations

import csv
import json
import math
from dataclasses import asdict, dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core import analytic as A
from ..core import waste as W
from ..core.batch_sim import MODE_CODES
from ..core.events import mu_e as _mu_e
from ..core.events import mu_p as _mu_p
from .grid import ExperimentCell, SweepResult

__all__ = [
    "analytic_waste",
    "analytic_waste_batch",
    "model_validity",
    "CellCheck",
    "cell_z_rows",
    "holm_bonferroni",
    "validate_sweep",
    "write_z_table",
]

#: asymmetric equivalence margins, as fractions of the analytic waste.
#: The undershoot side scales with the cell's distance from the model's
#: validity domain (see :func:`model_validity`): margin_lo =
#: (LO_BASE + LO_SLOPE * min(validity, 1)) * |analytic| + ABS_MARGIN.
#: The overshoot side — the direction engine regressions push — stays
#: flat at REL_MARGIN_HI.
REL_MARGIN_LO_BASE = 0.10
REL_MARGIN_LO_SLOPE = 0.45
REL_MARGIN_HI = 0.12
ABS_MARGIN = 0.004


def analytic_waste(cell: ExperimentCell) -> float:
    """First-order analytic waste of ``cell``'s strategy at its operating
    point (the quantity the paper's simulations corroborate).

    Dispatches on the strategy mode: Young's model for the q = 0
    baselines, Equation (1) for exact-date predictions, Equation (3) for
    migration, and Equations (5)/(6)/(4) for Instant / NoCkptI /
    WithCkptI window strategies.

    Since the analytic-layer redesign this evaluates the branchless
    table models of :mod:`repro.core.analytic` — the same functions the
    batched Newton optimizer differentiates — on a one-cell table; they
    agree with the scalar :mod:`repro.core.waste` formulas to float
    rounding (locked by the twin-parity tests)."""
    return float(analytic_waste_batch([cell])[0])


def analytic_waste_batch(cells: Sequence[ExperimentCell]) -> np.ndarray:
    """Vectorized :func:`analytic_waste`: one table build + one
    evaluation for a whole sweep's cells."""
    for cell in cells:
        if cell.strategy.mode not in MODE_CODES:
            raise ValueError(
                f"no analytic model for strategy mode {cell.strategy.mode!r}"
            )
    if not cells:
        return np.zeros(0, dtype=np.float64)
    return A.analytic_waste_cells(cells)


def model_validity(cell: ExperimentCell) -> float:
    """How far ``cell`` sits from the first-order models' validity domain.

    The paper's waste formulas assume at most one event per regular
    period (Section 3.2: ``T <= alpha * mu_e`` keeps the chance of 2+
    events under 3%) and, for window strategies, that proactive episodes
    occupy a small fraction of the time.  Both break down progressively
    at the *uncapped* periods the simulations run (Section 5), so the
    systematic model error scales with

        T_R / mu_e  +  I' / mu_P        (second term: window cells)

    where ``I' = q((1-p) I + p E_f)`` is the expected proactive time per
    prediction.  The validation margins widen linearly in this quantity
    (clamped at 1): tight tests where the model is exact, honest slack
    where the paper's own figures show simulation drifting below the
    pessimistic formula."""
    s, p, pred = cell.strategy, cell.platform, cell.predictor
    r, prec = pred.recall, pred.precision
    trusts = s.mode not in ("none", "silent") and s.q > 0.0 and r > 0.0
    me = _mu_e(p.mu, r, prec) if trusts else p.mu
    if s.mode == "two_level":
        # expected rollback span: memory-tier faults (fraction f) lose at
        # most one memory period, disk-tier faults lose up to rho of them
        rho = s.rho if s.rho is not None else 1
        f = p.f if p.f is not None else 0.0
        span = s.T_R * (f + (1.0 - f) * rho)
    elif s.mode == "silent":
        # detection latency: a corruption survives up to k_V periods, and
        # a struck pattern forfeits its FULL wall time (not the T/2 mean
        # loss of a fail-stop fault) — twice the second-order sensitivity,
        # so the span doubles relative to the fail-stop scale
        span = 2.0 * s.T_R * (s.k_V if s.k_V is not None else 1)
    else:
        span = s.T_R
    v = span / me if math.isfinite(me) else 0.0
    if trusts and pred.window > 0.0:
        mp = _mu_p(p.mu, r, prec)
        if math.isfinite(mp):
            v += W.i_prime(s.q, prec, pred.window, pred.e_f) / mp
    return v


@dataclass
class CellCheck:
    """One cell's equivalence-margin z-test (see module docstring)."""

    label: str
    strategy: str
    dist: str
    n_runs: int
    mean_sim: float
    se_sim: float
    analytic: float
    delta: float  # mean_sim - analytic
    validity: float  # model-validity distance (see model_validity)
    margin: float  # the side-appropriate equivalence margin
    z: float  # (|delta| - margin) / se
    p: float  # one-sided p-value of H0: |delta_true| <= margin
    reject: bool = False  # set by the Holm pass


def _norm_sf(z: float) -> float:
    """Standard-normal survival function 1 - Phi(z)."""
    return 0.5 * math.erfc(z / math.sqrt(2.0))


def cell_z_rows(
    sweep: SweepResult,
    rel_margin_lo_base: float = REL_MARGIN_LO_BASE,
    rel_margin_lo_slope: float = REL_MARGIN_LO_SLOPE,
    rel_margin_hi: float = REL_MARGIN_HI,
    abs_margin: float = ABS_MARGIN,
) -> List[CellCheck]:
    """Per-cell z-statistics of a sweep against the analytic models."""
    rows: List[CellCheck] = []
    was = analytic_waste_batch([cr.cell for cr in sweep.cells])
    for wa, cr in zip(was, sweep.cells):
        wa = float(wa)
        v = model_validity(cr.cell)
        n = cr.n_runs
        # promote the simulated moments to IEEE doubles at the boundary:
        # on the f32 (TPU) engine path the sweep statistics arrive as
        # float32 scalars, and `f32 - float` would silently narrow the
        # analytic-vs-simulated comparison the z-test is built on
        # (schema role "fdt" at the analytic layer is float64; see
        # repro.analysis.schema)
        se = float(cr.ci95_waste) / 1.96
        delta = float(cr.mean_waste) - wa
        if delta > 0:
            rel = rel_margin_hi
        else:
            rel = rel_margin_lo_base + rel_margin_lo_slope * min(v, 1.0)
        margin = rel * abs(wa) + abs_margin
        stat = abs(delta) - margin
        if se > 0 and math.isfinite(se):
            z = stat / se
        else:  # degenerate cells (n < 2 / zero variance): margin decides
            z = math.inf if stat > 0 else -math.inf
        rows.append(
            CellCheck(
                label=cr.cell.label,
                strategy=cr.cell.strategy.name,
                dist=cr.cell.dist.name,
                n_runs=n,
                mean_sim=float(cr.mean_waste),
                se_sim=se,
                analytic=wa,
                delta=delta,
                validity=v,
                margin=margin,
                z=z,
                p=_norm_sf(z),
            )
        )
    return rows


def holm_bonferroni(pvals: Sequence[float], alpha: float = 0.01) -> np.ndarray:
    """Holm's step-down procedure: boolean reject mask at family-wise
    error rate ``alpha``.

    The i-th smallest p-value is compared against ``alpha / (m - i)``
    (i = 0..m-1); the first failure retains that hypothesis and every
    larger one.  Uniformly more powerful than plain Bonferroni at the
    same FWER guarantee, with no independence assumption."""
    p = np.asarray(pvals, dtype=np.float64)
    m = p.shape[0]
    reject = np.zeros(m, dtype=bool)
    if m == 0:
        return reject
    order = np.argsort(p, kind="stable")
    for i, idx in enumerate(order):
        if p[idx] <= alpha / (m - i):
            reject[idx] = True
        else:
            break
    return reject


def validate_sweep(
    sweep: SweepResult,
    alpha: float = 0.01,
    rel_margin_lo_base: float = REL_MARGIN_LO_BASE,
    rel_margin_lo_slope: float = REL_MARGIN_LO_SLOPE,
    rel_margin_hi: float = REL_MARGIN_HI,
    abs_margin: float = ABS_MARGIN,
) -> Tuple[List[CellCheck], List[CellCheck]]:
    """Run the full acceptance harness on a sweep.

    Returns ``(rows, failures)``: every cell's :class:`CellCheck` (with
    ``reject`` filled by the Holm pass) and the rejected subset.  An
    empty ``failures`` list means the simulated grid is statistically
    compatible with the analytic models under the stated margins, at
    family-wise false-alarm rate ``alpha``."""
    rows = cell_z_rows(
        sweep, rel_margin_lo_base, rel_margin_lo_slope, rel_margin_hi,
        abs_margin,
    )
    reject = holm_bonferroni([r.p for r in rows], alpha=alpha)
    for r, rej in zip(rows, reject):
        r.reject = bool(rej)
    return rows, [r for r in rows if r.reject]


def write_z_table(
    rows: Sequence[CellCheck], csv_path, json_path: Optional[str] = None
) -> None:
    """Dump the per-cell z-score table (the CI artifact)."""
    fields = list(CellCheck.__dataclass_fields__)
    with open(csv_path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields)
        w.writeheader()
        for r in rows:
            w.writerow(asdict(r))
    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump(
                {
                    "n_cells": len(rows),
                    "n_rejected": sum(r.reject for r in rows),
                    "cells": [asdict(r) for r in rows],
                },
                f,
                indent=1,
            )
