"""Experiment grid specification and structured sweep results.

An :class:`ExperimentCell` pins down one Monte-Carlo estimation problem —
(platform, predictor, strategy, failure law, job) — and a :class:`GridSpec`
bundles many cells with shared run count and seed.  The runner
(:mod:`repro.experiments.runner`) flattens every (cell, run) pair into one
lane of the vectorized engine, so the whole grid advances in a single
batched simulation call.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.events import Distribution, exponential
from ..core.simulator import Strategy
from ..core.waste import Platform, PredictorModel

__all__ = ["ExperimentCell", "GridSpec", "CellResult", "SweepResult"]


@dataclass(frozen=True)
class ExperimentCell:
    """One grid cell: a (platform, predictor, strategy, failure-law) point."""

    label: str
    work: float
    platform: Platform
    predictor: PredictorModel
    strategy: Strategy
    fault_dist: Optional[Distribution] = None  # None -> exponential
    false_pred_dist: Optional[Distribution] = None
    n_components: Optional[int] = None
    stationary: bool = False
    horizon_factor: float = 12.0

    @property
    def dist(self) -> Distribution:
        return self.fault_dist or exponential()

    @property
    def gen_recall(self) -> float:
        """Recall the *legacy* pipeline used at trace-generation time:
        strategies that ignore predictions got a prediction-free trace
        (mirrors ``simulate_many``).  The batched runner instead generates
        full traces keyed on the predictor alone — faults are drawn before
        prediction marking, so a mode-"none" baseline shares its fault
        stream with the strategies measured against it (paired design),
        and the engine's trust filter drops the predictions."""
        return self.predictor.recall if self.strategy.mode != "none" else 0.0

    def group_key(self) -> Tuple:
        """Cells sharing a key can be generated in one batched pass."""
        fp = self.false_pred_dist
        return (
            self.dist.name,
            fp.name if fp is not None else None,
            self.n_components,
            self.stationary,
        )


@dataclass(frozen=True)
class GridSpec:
    """A full sweep: cells x ``n_runs`` Monte-Carlo repetitions."""

    cells: Tuple[ExperimentCell, ...]
    n_runs: int = 100
    seed: int = 0

    def __post_init__(self):
        labels = [c.label for c in self.cells]
        if len(set(labels)) != len(labels):
            dupes = sorted({l for l in labels if labels.count(l) > 1})
            raise ValueError(f"duplicate cell labels: {dupes}")

    @property
    def n_lanes(self) -> int:
        return len(self.cells) * self.n_runs


@dataclass
class CellResult:
    """Aggregated Monte-Carlo statistics of one cell (mean +- 95% CI)."""

    cell: ExperimentCell
    waste: np.ndarray  # (n_runs,) per-run empirical waste
    makespan: np.ndarray  # (n_runs,)
    n_faults: np.ndarray
    n_proactive_ckpts: np.ndarray
    n_regular_ckpts: np.ndarray
    n_migrations: np.ndarray
    n_exhausted: int

    @staticmethod
    def _ci95(x: np.ndarray) -> float:
        n = x.shape[0]
        if n < 2:
            return math.nan
        return 1.96 * float(x.std(ddof=1)) / math.sqrt(n)

    @property
    def mean_waste(self) -> float:
        return float(self.waste.mean())

    @property
    def ci95_waste(self) -> float:
        return self._ci95(self.waste)

    @property
    def mean_makespan(self) -> float:
        return float(self.makespan.mean())

    @property
    def ci95_makespan(self) -> float:
        return self._ci95(self.makespan)

    def to_row(self) -> Dict:
        c = self.cell
        def fin(x: float):  # keep serialized rows strict-JSON/CSV clean
            return float(x) if math.isfinite(x) else None
        return {
            "label": c.label,
            "strategy": c.strategy.name,
            "T_R": c.strategy.T_R,
            "mode": c.strategy.mode,
            "mu": c.platform.mu,
            "C": c.platform.C,
            "recall": c.predictor.recall,
            "precision": c.predictor.precision,
            "window": c.predictor.window,
            "dist": c.dist.name,
            "work": c.work,
            "n_runs": int(self.waste.shape[0]),
            "mean_waste": self.mean_waste,
            "ci95_waste": fin(self.ci95_waste),
            "mean_makespan": self.mean_makespan,
            "ci95_makespan": fin(self.ci95_makespan),
            "mean_faults": float(self.n_faults.mean()),
            "mean_proactive_ckpts": float(self.n_proactive_ckpts.mean()),
            "mean_regular_ckpts": float(self.n_regular_ckpts.mean()),
            "mean_migrations": float(self.n_migrations.mean()),
            "n_exhausted": self.n_exhausted,
        }


#: column order of the CSV writer (and of ``to_row``)
_CSV_FIELDS = [
    "label", "strategy", "T_R", "mode", "mu", "C", "recall", "precision",
    "window", "dist", "work", "n_runs", "mean_waste", "ci95_waste",
    "mean_makespan", "ci95_makespan", "mean_faults", "mean_proactive_ckpts",
    "mean_regular_ckpts", "mean_migrations", "n_exhausted",
]


@dataclass
class SweepResult:
    """Structured result of a grid sweep, with CSV/JSON serialization."""

    grid: GridSpec
    cells: List[CellResult]
    engine: str
    wall_time_s: float

    def __getitem__(self, label: str) -> CellResult:
        for c in self.cells:
            if c.cell.label == label:
                return c
        raise KeyError(label)

    def labels(self) -> List[str]:
        return [c.cell.label for c in self.cells]

    def to_rows(self) -> List[Dict]:
        return [c.to_row() for c in self.cells]

    def write_csv(self, path) -> None:
        import csv

        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=_CSV_FIELDS)
            w.writeheader()
            for row in self.to_rows():
                w.writerow(row)

    def write_json(self, path) -> None:
        payload = {
            "engine": self.engine,
            "wall_time_s": self.wall_time_s,
            "n_runs": self.grid.n_runs,
            "seed": self.grid.seed,
            "cells": self.to_rows(),
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, allow_nan=False)
