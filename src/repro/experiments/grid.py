"""Experiment grid specification and structured sweep results.

An :class:`ExperimentCell` pins down one Monte-Carlo estimation problem —
(platform, predictor, strategy, failure law, job) — and a :class:`GridSpec`
bundles many cells with shared run count and seed.  The runner
(:mod:`repro.experiments.runner`) flattens every (cell, run) pair into one
lane of the vectorized engine, so the whole grid advances in a single
batched simulation call.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.events import Distribution, exponential
from ..core.simulator import Strategy
from ..core.waste import Platform, PredictorModel

__all__ = ["ExperimentCell", "GridSpec", "CellResult", "SweepResult"]


@dataclass(frozen=True)
class ExperimentCell:
    """One grid cell: a (platform, predictor, strategy, failure-law) point.

    ``n_runs`` overrides the grid-wide Monte-Carlo repetition count for
    this cell (heterogeneous grids: spend lanes where the variance is);
    ``None`` inherits :attr:`GridSpec.n_runs`."""

    label: str
    work: float
    platform: Platform
    predictor: PredictorModel
    strategy: Strategy
    fault_dist: Optional[Distribution] = None  # None -> exponential
    false_pred_dist: Optional[Distribution] = None
    n_components: Optional[int] = None
    stationary: bool = False
    horizon_factor: float = 12.0
    n_runs: Optional[int] = None

    @property
    def dist(self) -> Distribution:
        return self.fault_dist or exponential()

    @property
    def gen_recall(self) -> float:
        """Recall the *legacy* pipeline used at trace-generation time:
        strategies that ignore predictions got a prediction-free trace
        (mirrors ``simulate_many``).  The batched runner instead generates
        full traces keyed on the predictor alone — faults are drawn before
        prediction marking, so a mode-"none" baseline shares its fault
        stream with the strategies measured against it (paired design),
        and the engine's trust filter drops the predictions."""
        return self.predictor.recall if self.strategy.mode != "none" else 0.0

    def group_key(self) -> Tuple:
        """Cells sharing a key can be generated in one batched pass."""
        fp = self.false_pred_dist
        return (
            self.dist.name,
            fp.name if fp is not None else None,
            self.n_components,
            self.stationary,
        )


@dataclass(frozen=True)
class GridSpec:
    """A full sweep: cells x ``n_runs`` Monte-Carlo repetitions (cells
    may override their own run count via :attr:`ExperimentCell.n_runs`)."""

    cells: Tuple[ExperimentCell, ...]
    n_runs: int = 100
    seed: int = 0

    def __post_init__(self):
        labels = [c.label for c in self.cells]
        if len(set(labels)) != len(labels):
            dupes = sorted({l for l in labels if labels.count(l) > 1})
            raise ValueError(f"duplicate cell labels: {dupes}")
        if any(r < 1 for r in self.cell_n_runs):
            raise ValueError("every cell needs n_runs >= 1")

    def cell_runs(self, ci: int) -> int:
        """Monte-Carlo repetition count of cell ``ci``."""
        r = self.cells[ci].n_runs
        return self.n_runs if r is None else int(r)

    @property
    def cell_n_runs(self) -> Tuple[int, ...]:
        return tuple(self.cell_runs(ci) for ci in range(len(self.cells)))

    @property
    def n_lanes(self) -> int:
        return sum(self.cell_n_runs)


@dataclass
class CellResult:
    """Aggregated Monte-Carlo statistics of one cell (mean +- 95% CI).

    Two backing layouts share one interface:

    * **per-run arrays** (the default ``collect="lanes"`` sweep): every
      field holds the raw ``(n_runs,)`` samples and the summary
      properties reduce them on demand;
    * **device-reduced stats** (``collect="stats"``): the arrays are
      ``None`` and :attr:`stats` carries the summary moments segment-
      reduced on the device — O(cells) fetched, no per-run data.
    """

    cell: ExperimentCell
    waste: Optional[np.ndarray] = None  # (n_runs,) per-run empirical waste
    makespan: Optional[np.ndarray] = None  # (n_runs,)
    n_faults: Optional[np.ndarray] = None
    n_proactive_ckpts: Optional[np.ndarray] = None
    n_regular_ckpts: Optional[np.ndarray] = None
    n_migrations: Optional[np.ndarray] = None
    n_exhausted: int = 0
    stats: Optional[Dict[str, float]] = None

    #: stats keys (from_stats argument order)
    _STAT_KEYS = (
        "n", "mean_waste", "ci95_waste", "mean_makespan", "ci95_makespan",
        "mean_faults", "mean_proactive_ckpts", "mean_regular_ckpts",
        "mean_migrations",
    )

    @classmethod
    def from_stats(cls, cell: ExperimentCell, n_exhausted: int, *moments
                   ) -> "CellResult":
        """Build a stats-backed result from device-reduced summary
        moments (``_STAT_KEYS`` order)."""
        return cls(
            cell=cell, n_exhausted=int(n_exhausted),
            stats=dict(zip(cls._STAT_KEYS, (float(m) for m in moments))),
        )

    @staticmethod
    def _ci95(x: np.ndarray) -> float:
        n = x.shape[0]
        if n < 2:
            return math.nan
        return 1.96 * float(x.std(ddof=1)) / math.sqrt(n)

    @property
    def n_runs(self) -> int:
        if self.waste is None:
            return int(self.stats["n"])
        return int(self.waste.shape[0])

    def _stat(self, key: str, arr_name: str, reduce):
        if self.stats is not None and getattr(self, arr_name) is None:
            return self.stats[key]
        return reduce(getattr(self, arr_name))

    @property
    def mean_waste(self) -> float:
        return self._stat("mean_waste", "waste", lambda a: float(a.mean()))

    @property
    def ci95_waste(self) -> float:
        return self._stat("ci95_waste", "waste", self._ci95)

    @property
    def mean_makespan(self) -> float:
        return self._stat("mean_makespan", "makespan", lambda a: float(a.mean()))

    @property
    def ci95_makespan(self) -> float:
        return self._stat("ci95_makespan", "makespan", self._ci95)

    @property
    def mean_faults(self) -> float:
        return self._stat("mean_faults", "n_faults", lambda a: float(a.mean()))

    @property
    def mean_proactive_ckpts(self) -> float:
        return self._stat(
            "mean_proactive_ckpts", "n_proactive_ckpts",
            lambda a: float(a.mean()),
        )

    @property
    def mean_regular_ckpts(self) -> float:
        return self._stat(
            "mean_regular_ckpts", "n_regular_ckpts", lambda a: float(a.mean())
        )

    @property
    def mean_migrations(self) -> float:
        return self._stat(
            "mean_migrations", "n_migrations", lambda a: float(a.mean())
        )

    @property
    def analytic_waste(self) -> float:
        """First-order analytic waste of the cell's strategy at its
        operating point (shared table models; see repro.core.analytic)."""
        return float(_analytic_cols([self.cell])[0][0])

    @property
    def analytic_period(self) -> float:
        """The analytic optimal regular period T_extr at the cell's trust
        level (the period the paper predicts; compare with the tabled
        ``T_R`` the cell actually ran)."""
        return float(_analytic_cols([self.cell])[1][0])

    def to_row(self, analytic: Optional[Tuple[float, float]] = None) -> Dict:
        c = self.cell
        if analytic is None:
            aw, at = _analytic_cols([c])
            analytic = (float(aw[0]), float(at[0]))
        def fin(x: float):  # keep serialized rows strict-JSON/CSV clean
            return float(x) if math.isfinite(x) else None
        return {
            "label": c.label,
            "strategy": c.strategy.name,
            "T_R": c.strategy.T_R,
            "mode": c.strategy.mode,
            "mu": c.platform.mu,
            "C": c.platform.C,
            "recall": c.predictor.recall,
            "precision": c.predictor.precision,
            "window": c.predictor.window,
            "dist": c.dist.name,
            "work": c.work,
            "n_runs": self.n_runs,
            "mean_waste": self.mean_waste,
            "ci95_waste": fin(self.ci95_waste),
            "mean_makespan": self.mean_makespan,
            "ci95_makespan": fin(self.ci95_makespan),
            "mean_faults": self.mean_faults,
            "mean_proactive_ckpts": self.mean_proactive_ckpts,
            "mean_regular_ckpts": self.mean_regular_ckpts,
            "mean_migrations": self.mean_migrations,
            "n_exhausted": self.n_exhausted,
            # analytic-layer columns (appended last: downstream readers
            # key on the historical column prefix)
            "analytic_waste": fin(analytic[0]),
            "analytic_period": fin(analytic[1]),
        }


def _analytic_cols(cells) -> Tuple[np.ndarray, np.ndarray]:
    """(analytic waste at the tabled T_R, analytic optimal period) for a
    batch of cells, via the shared per-cell table layer."""
    from ..core import analytic as A  # lazy: grid stays light at import

    return A.analytic_waste_cells(cells), A.analytic_period_cells(cells)


#: column order of the CSV writer (and of ``to_row``)
_CSV_FIELDS = [
    "label", "strategy", "T_R", "mode", "mu", "C", "recall", "precision",
    "window", "dist", "work", "n_runs", "mean_waste", "ci95_waste",
    "mean_makespan", "ci95_makespan", "mean_faults", "mean_proactive_ckpts",
    "mean_regular_ckpts", "mean_migrations", "n_exhausted",
    "analytic_waste", "analytic_period",
]


@dataclass
class SweepResult:
    """Structured result of a grid sweep, with CSV/JSON serialization.

    ``dispatch`` records the engine-call granularity ("fused": the grid
    rode cell-multiplexed megabatch dispatches; "percell": one call per
    cell) and ``collect`` the result layout ("lanes": per-run arrays;
    "stats": device-reduced summary moments).  ``meta`` carries
    execution provenance that is not part of the statistical result —
    e.g. a resumable campaign's recovery events (retries, engine
    degradation, snapshots, resume points); ``None`` for plain sweeps."""

    grid: GridSpec
    cells: List[CellResult]
    engine: str
    wall_time_s: float
    dispatch: str = "fused"
    collect: str = "lanes"
    meta: Optional[Dict] = None

    def __getitem__(self, label: str) -> CellResult:
        for c in self.cells:
            if c.cell.label == label:
                return c
        raise KeyError(label)

    def labels(self) -> List[str]:
        return [c.cell.label for c in self.cells]

    def to_rows(self) -> List[Dict]:
        if not self.cells:
            return []
        # one table build for the whole sweep, not one per row
        aw, at = _analytic_cols([c.cell for c in self.cells])
        return [
            c.to_row(analytic=(float(w), float(t)))
            for c, w, t in zip(self.cells, aw, at)
        ]

    def write_csv(self, path) -> None:
        import csv

        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=_CSV_FIELDS)
            w.writeheader()
            for row in self.to_rows():
                w.writerow(row)

    def write_json(self, path) -> None:
        payload = {
            "engine": self.engine,
            "dispatch": self.dispatch,
            "collect": self.collect,
            "wall_time_s": self.wall_time_s,
            "n_runs": self.grid.n_runs,
            "seed": self.grid.seed,
            "cells": self.to_rows(),
        }
        if self.meta is not None:
            payload["meta"] = self.meta
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, allow_nan=False)
