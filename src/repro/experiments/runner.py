"""Batched execution of experiment grids.

The runner flattens a :class:`~repro.experiments.grid.GridSpec` into engine
lanes — one lane per (cell, run) pair — and advances the *entire grid* in a
single vectorized engine call:

1. cells are grouped by trace-generation compatibility (failure-law family,
   superposition settings), and within a group cells with identical trace
   parameters (MTBF, predictor, window, horizon) *share* their traces — the
   paper's paired design, where every strategy faces the same failures;
2. each group's unique traces are generated in one batched pass
   (:func:`repro.core.events.make_event_traces_batch`);
3. the groups are concatenated and every lane advances simultaneously in
   one :func:`repro.core.batch_sim.simulate_batch` call.

``engine="jax"`` advances the very same lanes with the device-resident
engine (:mod:`repro.core.jax_sim`): jit + ``lax.while_loop`` over a stacked
lane-state pytree, Pallas hot step, host-side chunked lane scheduling
(``chunk_lanes``) so 100k-lane grids never exceed device memory, and
optional lane sharding across a device set (``devices=`` / ``mesh=``) with
device-count-invariant results.
``engine="scalar"`` feeds each lane's :class:`EventTrace` view to the scalar
reference engine instead: identical traces, Python event loop — the oracle
for equivalence checks.  ``engine="legacy"`` reproduces the pre-batching
pipeline exactly (per-run Python-object trace generation via
:func:`make_event_trace` + scalar engine, per-run seeds ``seed + 1000 i +
17``) — the wall-clock baseline the vectorized path is measured against.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.batch_sim import simulate_batch
from ..core.events import (
    BatchTraces,
    TraceSpec,
    make_event_trace,
    make_event_traces_batch,
    make_trace_spec,
)
from ..core.simulator import simulate
from .grid import CellResult, ExperimentCell, GridSpec, SweepResult

__all__ = ["run_grid", "run_cells"]


def _group_cells(grid: GridSpec) -> List[Tuple[Tuple, List[int]]]:
    groups: Dict[Tuple, List[int]] = {}
    for ci, cell in enumerate(grid.cells):
        groups.setdefault(cell.group_key(), []).append(ci)
    return list(groups.items())


def _trace_key(cell: ExperimentCell) -> Tuple:
    """Cells with equal keys face identical traces (paired comparison).

    Keyed on the predictor's true parameters — not the strategy — so a
    mode-"none" baseline (Young/Daly) shares its fault stream with the
    prediction-following strategies it is compared against; the engine's
    trust filter hides the predictions from it."""
    return (
        cell.work,
        cell.horizon_factor,
        cell.platform.mu,
        cell.predictor.recall,
        cell.predictor.precision,
        cell.predictor.window,
        cell.predictor.lead,
    )


def _group_traces(grid: GridSpec, cell_idx: List[int], group_no: int) -> BatchTraces:
    """Generate one group's traces: one batched pass over the group's
    *unique* trace parameters, then row-expansion to per-cell lanes."""
    cells = [grid.cells[ci] for ci in cell_idx]
    n_runs = grid.n_runs
    uniq: Dict[Tuple, int] = {}
    cell_slot = []
    for c in cells:
        cell_slot.append(uniq.setdefault(_trace_key(c), len(uniq)))
    uniq_cells = [None] * len(uniq)
    for c, slot in zip(cells, cell_slot):
        if uniq_cells[slot] is None:
            uniq_cells[slot] = c

    rep = lambda vals: np.repeat(np.asarray(vals, dtype=np.float64), n_runs)
    rng = np.random.default_rng([grid.seed, group_no])
    proto = cells[0]
    traces = make_event_traces_batch(
        rng,
        len(uniq_cells) * n_runs,
        horizon=rep([c.horizon_factor * c.work for c in uniq_cells]),
        mtbf=rep([c.platform.mu for c in uniq_cells]),
        recall=rep([c.predictor.recall for c in uniq_cells]),
        precision=rep([c.predictor.precision for c in uniq_cells]),
        window=rep([c.predictor.window for c in uniq_cells]),
        lead=rep([c.predictor.lead for c in uniq_cells]),
        fault_dist=proto.dist,
        false_pred_dist=proto.false_pred_dist,
        n_components=proto.n_components,
        stationary=proto.stationary,
    )
    rows = np.concatenate(
        [slot * n_runs + np.arange(n_runs) for slot in cell_slot]
    )
    return traces.take(rows)


def _group_trace_spec(
    grid: GridSpec, cell_idx: List[int], stream_base: int
) -> Tuple[TraceSpec, int]:
    """Device-generation counterpart of :func:`_group_traces`: build the
    group's :class:`TraceSpec` with *globally unique* stream ids per
    unique (trace-parameters, run) pair — cells sharing trace parameters
    share stream ids (paired design), and stream ids are stable across
    engines, chunk sizes and device counts.  Returns the expanded spec
    and the next free stream id."""
    cells = [grid.cells[ci] for ci in cell_idx]
    n_runs = grid.n_runs
    proto = cells[0]
    if proto.n_components:
        raise ValueError(
            "trace_mode='device' does not support superposed component "
            "traces (n_components); use trace_mode='host'"
        )
    uniq: Dict[Tuple, int] = {}
    cell_slot = []
    for c in cells:
        cell_slot.append(uniq.setdefault(_trace_key(c), len(uniq)))
    uniq_cells = [None] * len(uniq)
    for c, slot in zip(cells, cell_slot):
        if uniq_cells[slot] is None:
            uniq_cells[slot] = c

    rep = lambda vals: np.repeat(np.asarray(vals, dtype=np.float64), n_runs)
    n_uniq_lanes = len(uniq_cells) * n_runs
    spec = make_trace_spec(
        n_uniq_lanes,
        horizon=rep([c.horizon_factor * c.work for c in uniq_cells]),
        mtbf=rep([c.platform.mu for c in uniq_cells]),
        recall=rep([c.predictor.recall for c in uniq_cells]),
        precision=rep([c.predictor.precision for c in uniq_cells]),
        window=rep([c.predictor.window for c in uniq_cells]),
        lead=rep([c.predictor.lead for c in uniq_cells]),
        fault_dist=proto.dist,
        false_pred_dist=proto.false_pred_dist,
        seed=grid.seed,
        stream=stream_base + np.arange(n_uniq_lanes, dtype=np.int64),
    )
    rows = np.concatenate(
        [slot * n_runs + np.arange(n_runs) for slot in cell_slot]
    )
    return spec.take(rows), stream_base + n_uniq_lanes


def _run_legacy(grid: GridSpec) -> List[List]:
    """The seed repository's exact pipeline: per-run object-based trace
    generation + scalar engine, one trace per (cell, run)."""
    out = []
    for cell in grid.cells:
        runs = []
        for i in range(grid.n_runs):
            rng = np.random.default_rng(grid.seed + 1000 * i + 17)
            trace = make_event_trace(
                rng,
                horizon=cell.horizon_factor * cell.work,
                mtbf=cell.platform.mu,
                recall=cell.gen_recall,
                precision=cell.predictor.precision,
                window=cell.predictor.window,
                lead=cell.predictor.lead,
                fault_dist=cell.dist,
                false_pred_dist=cell.false_pred_dist,
                n_components=cell.n_components,
                stationary=cell.stationary,
            )
            runs.append(simulate(cell.work, cell.platform, cell.strategy, trace, rng))
        out.append(runs)
    return out


def run_grid(
    grid: GridSpec, engine: str = "batch", chunk_lanes="auto",
    devices=None, mesh=None, trace_mode: str = "host",
) -> SweepResult:
    """Execute every cell of ``grid`` and aggregate per-cell statistics.

    ``chunk_lanes`` (jax engine only) caps the lanes resident on the
    device per engine call — "auto" picks a backend-appropriate chunk,
    an int forces one, None runs the whole grid in a single call.
    ``devices`` / ``mesh`` (jax engine only) shard each chunk's lanes
    across a device set (:func:`repro.core.jax_sim.simulate_batch_jax`);
    per-lane results are identical for any device count.

    ``trace_mode="device"`` replaces host trace generation with per-lane
    counter-based RNG streams (:class:`~repro.core.events.TraceSpec`):
    the JAX engine samples events lazily on the device (one engine
    dispatch per trace-compatibility group, since the failure law
    specializes the compiled sampler), while the batch/scalar engines
    replay the identical streams host-side.  The paired design is
    preserved (cells sharing trace parameters share stream ids), and
    results are chunk-size and device-count invariant.  Not supported
    for the legacy engine or superposed (``n_components``) traces."""
    if engine not in ("batch", "scalar", "legacy", "jax"):
        raise ValueError(
            f"unknown engine {engine!r} "
            "(expected 'batch', 'jax', 'scalar' or 'legacy')"
        )
    if engine != "jax" and (devices is not None or mesh is not None):
        raise ValueError("devices=/mesh= require engine='jax'")
    if trace_mode not in ("host", "device"):
        raise ValueError(
            f"unknown trace_mode {trace_mode!r} (expected 'host' or 'device')"
        )
    if trace_mode == "device" and engine == "legacy":
        raise ValueError("trace_mode='device' requires a batched engine")
    t0 = time.monotonic()
    if engine == "legacy":
        cells = []
        for cell, runs in zip(grid.cells, _run_legacy(grid)):
            cells.append(
                CellResult(
                    cell=cell,
                    waste=np.array([r.waste for r in runs]),
                    makespan=np.array([r.makespan for r in runs]),
                    n_faults=np.array([r.n_faults for r in runs]),
                    n_proactive_ckpts=np.array([r.n_proactive_ckpts for r in runs]),
                    n_regular_ckpts=np.array([r.n_regular_ckpts for r in runs]),
                    n_migrations=np.array([r.n_migrations for r in runs]),
                    n_exhausted=sum(r.trace_exhausted for r in runs),
                )
            )
        return SweepResult(
            grid=grid, cells=cells, engine=engine,
            wall_time_s=time.monotonic() - t0,
        )
    n_runs = grid.n_runs
    groups = _group_cells(grid)
    cell_order: List[int] = [ci for _, idx in groups for ci in idx]
    specs: List[TraceSpec] = []
    if trace_mode == "device":
        base = 0
        for _, idx in groups:
            spec, base = _group_trace_spec(grid, idx, base)
            specs.append(spec)
        traces = None
    else:
        # per-group batched generation, then one engine call over all
        # groups: with zero-copy sentinel adoption the width padding of
        # concat costs less than the extra iterations of per-group calls
        traces = BatchTraces.concat(
            [
                _group_traces(grid, idx, gno)
                for gno, (_, idx) in enumerate(groups)
            ]
        )
    work = np.repeat(
        np.asarray([grid.cells[ci].work for ci in cell_order], dtype=np.float64),
        n_runs,
    )
    platforms = [grid.cells[ci].platform for ci in cell_order for _ in range(n_runs)]
    strategies = [grid.cells[ci].strategy for ci in cell_order for _ in range(n_runs)]
    if trace_mode == "device" and engine != "jax":
        # host engines replay the device streams via materialize()
        traces = BatchTraces.concat([s.materialize() for s in specs])

    if engine == "jax" and trace_mode == "device":
        # one dispatch per trace-compatibility group: the failure law is
        # a static specialization of the compiled on-device sampler
        from ..core.jax_sim import simulate_batch_jax

        parts = []
        lo = 0
        for (_, idx), spec in zip(groups, specs):
            hi = lo + len(idx) * n_runs
            parts.append(
                simulate_batch_jax(
                    work[lo:hi], platforms[lo:hi], strategies[lo:hi], spec,
                    chunk=chunk_lanes, devices=devices, mesh=mesh,
                )
            )
            lo = hi
        waste = np.concatenate([p.waste for p in parts])
        makespan = np.concatenate([p.makespan for p in parts])
        n_faults = np.concatenate([p.n_faults for p in parts])
        n_pro = np.concatenate([p.n_proactive_ckpts for p in parts])
        n_reg = np.concatenate([p.n_regular_ckpts for p in parts])
        n_mig = np.concatenate([p.n_migrations for p in parts])
        exhausted = np.concatenate([p.trace_exhausted for p in parts])
    elif engine in ("batch", "jax"):
        if engine == "jax":
            from ..core.jax_sim import simulate_batch_jax

            res = simulate_batch_jax(
                work, platforms, strategies, traces,
                rng=np.random.default_rng([grid.seed, len(groups)]),
                chunk=chunk_lanes, devices=devices, mesh=mesh,
            )
        else:
            res = simulate_batch(
                work, platforms, strategies, traces,
                rng=np.random.default_rng([grid.seed, len(groups)]),
            )
        waste = res.waste
        makespan = res.makespan
        n_faults, n_pro = res.n_faults, res.n_proactive_ckpts
        n_reg, n_mig = res.n_regular_ckpts, res.n_migrations
        exhausted = res.trace_exhausted
    else:
        outs = [
            simulate(
                float(work[i]), platforms[i], strategies[i], traces.lane(i),
                np.random.default_rng([grid.seed, len(groups), i]),
            )
            for i in range(traces.n_lanes)
        ]
        waste = np.array([r.waste for r in outs])
        makespan = np.array([r.makespan for r in outs])
        n_faults = np.array([r.n_faults for r in outs])
        n_pro = np.array([r.n_proactive_ckpts for r in outs])
        n_reg = np.array([r.n_regular_ckpts for r in outs])
        n_mig = np.array([r.n_migrations for r in outs])
        exhausted = np.array([r.trace_exhausted for r in outs])

    cells: List[CellResult] = [None] * len(grid.cells)
    for k, ci in enumerate(cell_order):
        sl = slice(k * n_runs, (k + 1) * n_runs)
        cells[ci] = CellResult(
            cell=grid.cells[ci],
            waste=waste[sl],
            makespan=makespan[sl],
            n_faults=n_faults[sl],
            n_proactive_ckpts=n_pro[sl],
            n_regular_ckpts=n_reg[sl],
            n_migrations=n_mig[sl],
            n_exhausted=int(np.count_nonzero(exhausted[sl])),
        )
    return SweepResult(
        grid=grid, cells=cells, engine=engine,
        wall_time_s=time.monotonic() - t0,
    )


def run_cells(
    cells: Sequence[ExperimentCell],
    n_runs: int = 100,
    seed: int = 0,
    engine: str = "batch",
    chunk_lanes="auto",
    devices=None,
    mesh=None,
    trace_mode: str = "host",
) -> SweepResult:
    """Convenience wrapper: build a :class:`GridSpec` and run it."""
    return run_grid(
        GridSpec(tuple(cells), n_runs=n_runs, seed=seed),
        engine=engine,
        chunk_lanes=chunk_lanes,
        devices=devices,
        mesh=mesh,
        trace_mode=trace_mode,
    )
