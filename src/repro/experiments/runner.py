"""Batched execution of experiment grids.

The runner flattens a :class:`~repro.experiments.grid.GridSpec` into engine
lanes — one lane per (cell, run) pair — and advances the *entire grid* in a
handful of vectorized engine calls:

1. cells are grouped by trace-generation compatibility (failure-law family,
   superposition settings), and within a group cells with identical trace
   parameters (MTBF, predictor, window, horizon) *share* their traces — the
   paper's paired design, where every strategy faces the same failures;
2. each group's unique traces are generated in one batched pass
   (:func:`repro.core.events.make_event_traces_batch`);
3. the groups are concatenated and every lane advances simultaneously in
   one :func:`repro.core.batch_sim.simulate_batch` call.

``engine="jax"`` advances the very same lanes with the device-resident
engine (:mod:`repro.core.jax_sim`): jit + ``lax.while_loop`` over a stacked
lane-state pytree, Pallas hot step, host-side chunked lane scheduling
(``chunk_lanes``) so 100k-lane grids never exceed device memory, and
optional lane sharding across a device set (``devices=`` / ``mesh=``) with
device-count-invariant results.
``engine="scalar"`` feeds each lane's :class:`EventTrace` view to the scalar
reference engine instead: identical traces, Python event loop — the oracle
for equivalence checks.  ``engine="legacy"`` reproduces the pre-batching
pipeline exactly (per-run Python-object trace generation via
:func:`make_event_trace` + scalar engine, per-run seeds ``seed + 1000 i +
17``) — the wall-clock baseline the vectorized path is measured against.

Fused vs per-cell dispatch
==========================

``dispatch="fused"`` (the default for the batched engines) makes the
experiment cell a *lane-level axis* of the engine: strategy, period,
checkpoint costs, predictor parameters and trust ship as per-cell tables
broadcast on device through an int32 per-lane cell index
(``simulate_batch_jax(cell_index=...)``), so one device dispatch runs the
entire grid with lanes from many cells interleaved across chunks and
shards.  In device trace mode the failure law is part of those tables
too: a grid mixing exponential / Weibull / lognormal families
concatenates its per-family specs (:meth:`TraceSpec.concat_cells`) and
runs as literally ONE dispatch through the law-indexed sampler — one
compiled executable per grid *shape*, not per family.  (A single-family
grid keeps the law-specialized sampler: same results, slightly cheaper
draws.)

``dispatch="perfamily"`` (jax engine, device trace mode) is the
pre-fusion baseline the mixed-law benchmark is measured against: one
engine call per trace-compatibility group, paying k executables, k host
round-trips and k pipeline drains on a k-family grid.  Its specs are
tuple-ized (:meth:`TraceSpec.indexed`) so both dispatch granularities
run the *same* law-indexed sampler — per-lane results (and device-
reduced stats) are bit-identical to the one-dispatch path by
construction, which is what the benchmark equality gate asserts.

``dispatch="percell"`` launches one engine call per cell instead (the
original pre-fusion baseline, and a differential-validation path: paired
per-lane RNG streams make both dispatches bit-identical in device trace
mode for single-family grids and for the deterministic trust settings
``q in {0, 1}`` in host mode; fractional-``q`` host-mode trust coins are
drawn per engine call and agree only in distribution; on *mixed*-law
grids percell runs law-specialized samplers whose lognormal draws can
differ from the fused path's law-indexed transform by XLA
fusion-context rounding, ~1e-12 relative).

``collect="stats"`` (jax engine) segment-reduces each cell's waste /
makespan / event-counter moments *on device* and fetches O(cells) sums
instead of O(lanes) per-run arrays; the resulting
:class:`~repro.experiments.grid.CellResult` rows carry identical summary
statistics (to float rounding) without the raw samples.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.batch_sim import simulate_batch
from ..core.engine import UNSET, resolve_engine_config
from ..core.events import (
    BatchTraces,
    TraceSpec,
    make_event_trace,
    make_event_traces_batch,
    make_trace_spec,
)
from ..core.simulator import simulate
from .grid import CellResult, ExperimentCell, GridSpec, SweepResult

__all__ = ["run_grid", "run_cells", "FusedLayout", "build_fused_layout"]


def _group_cells(grid: GridSpec) -> List[Tuple[Tuple, List[int]]]:
    groups: Dict[Tuple, List[int]] = {}
    for ci, cell in enumerate(grid.cells):
        groups.setdefault(cell.group_key(), []).append(ci)
    return list(groups.items())


def _trace_key(cell: ExperimentCell) -> Tuple:
    """Cells with equal keys face identical traces (paired comparison).

    Keyed on the predictor's true parameters — not the strategy — so a
    mode-"none" baseline (Young/Daly) shares its fault stream with the
    prediction-following strategies it is compared against; the engine's
    trust filter hides the predictions from it."""
    return (
        cell.work,
        cell.horizon_factor,
        cell.platform.mu,
        cell.predictor.recall,
        cell.predictor.precision,
        cell.predictor.window,
        cell.predictor.lead,
    )


def _trace_slots(grid: GridSpec, cell_idx: List[int]):
    """Shared-trace layout of one group: cells mapping to the same
    :func:`_trace_key` share one *slot* of unique traces.  A slot is as
    wide as its widest cell (per-cell ``n_runs`` heterogeneity): every
    cell consumes the slot's first ``n_runs`` lanes, so pairing holds on
    the common prefix.  Returns ``(uniq_cells, cell_slot, slot_runs,
    slot_off, rows)`` where ``rows[lane]`` indexes the unique-lane pool.
    """
    cells = [grid.cells[ci] for ci in cell_idx]
    runs = [grid.cell_runs(ci) for ci in cell_idx]
    uniq: Dict[Tuple, int] = {}
    cell_slot = [uniq.setdefault(_trace_key(c), len(uniq)) for c in cells]
    uniq_cells: List[Optional[ExperimentCell]] = [None] * len(uniq)
    slot_runs = np.zeros(len(uniq), dtype=np.int64)
    for c, slot, r in zip(cells, cell_slot, runs):
        if uniq_cells[slot] is None:
            uniq_cells[slot] = c
        slot_runs[slot] = max(slot_runs[slot], r)
    slot_off = np.concatenate([[0], np.cumsum(slot_runs)])
    rows = (
        np.concatenate(
            [
                slot_off[slot] + np.arange(r)
                for slot, r in zip(cell_slot, runs)
            ]
        )
        if cells
        else np.zeros(0, dtype=np.int64)
    )
    return uniq_cells, cell_slot, slot_runs, slot_off, rows


def _group_traces(grid: GridSpec, cell_idx: List[int], group_no: int) -> BatchTraces:
    """Generate one group's traces: one batched pass over the group's
    *unique* trace parameters, then row-expansion to per-cell lanes."""
    uniq_cells, _, slot_runs, slot_off, rows = _trace_slots(grid, cell_idx)
    rep = lambda vals: np.repeat(np.asarray(vals, dtype=np.float64), slot_runs)
    rng = np.random.default_rng([grid.seed, group_no])
    proto = grid.cells[cell_idx[0]]
    traces = make_event_traces_batch(
        rng,
        int(slot_off[-1]),
        horizon=rep([c.horizon_factor * c.work for c in uniq_cells]),
        mtbf=rep([c.platform.mu for c in uniq_cells]),
        recall=rep([c.predictor.recall for c in uniq_cells]),
        precision=rep([c.predictor.precision for c in uniq_cells]),
        window=rep([c.predictor.window for c in uniq_cells]),
        lead=rep([c.predictor.lead for c in uniq_cells]),
        fault_dist=proto.dist,
        false_pred_dist=proto.false_pred_dist,
        n_components=proto.n_components,
        stationary=proto.stationary,
        # recovery-tier uniforms for two-level cells; drawn after every
        # other draw, so enabling them never perturbs the group's traces
        tier=any(
            grid.cells[ci].strategy.mode == "two_level" for ci in cell_idx
        ),
    )
    return traces.take(rows)


def _group_trace_spec(
    grid: GridSpec, cell_idx: List[int], stream_base: int
) -> Tuple[TraceSpec, int]:
    """Device-generation counterpart of :func:`_group_traces`: build the
    group's *cell-indexed* :class:`TraceSpec` — one parameter row per
    cell, O(lanes) stream ids — with *globally unique* stream ids per
    unique (trace-parameters, run) pair: cells sharing trace parameters
    share stream ids (paired design), and stream ids are stable across
    engines, dispatch granularities, chunk sizes and device counts.
    Returns the spec and the next free stream id."""
    cells = [grid.cells[ci] for ci in cell_idx]
    runs = [grid.cell_runs(ci) for ci in cell_idx]
    proto = cells[0]
    if proto.n_components:
        raise ValueError(
            "trace_mode='device' does not support superposed component "
            "traces (n_components); use trace_mode='host'"
        )
    _, cell_slot, _, slot_off, _ = _trace_slots(grid, cell_idx)
    stream = np.concatenate(
        [
            stream_base + slot_off[slot] + np.arange(r, dtype=np.int64)
            for slot, r in zip(cell_slot, runs)
        ]
    )
    cidx = np.repeat(np.arange(len(cells), dtype=np.int32), runs)
    spec = make_trace_spec(
        stream.shape[0],
        horizon=[c.horizon_factor * c.work for c in cells],
        mtbf=[c.platform.mu for c in cells],
        recall=[c.predictor.recall for c in cells],
        precision=[c.predictor.precision for c in cells],
        window=[c.predictor.window for c in cells],
        lead=[c.predictor.lead for c in cells],
        fault_dist=proto.dist,
        false_pred_dist=proto.false_pred_dist,
        seed=grid.seed,
        stream=stream,
        cell_index=cidx,
    )
    return spec, stream_base + int(slot_off[-1])


def _run_legacy(grid: GridSpec) -> List[List]:
    """The seed repository's exact pipeline: per-run object-based trace
    generation + scalar engine, one trace per (cell, run)."""
    out = []
    for ci, cell in enumerate(grid.cells):
        runs = []
        for i in range(grid.cell_runs(ci)):
            rng = np.random.default_rng(grid.seed + 1000 * i + 17)
            trace = make_event_trace(
                rng,
                horizon=cell.horizon_factor * cell.work,
                mtbf=cell.platform.mu,
                recall=cell.gen_recall,
                precision=cell.predictor.precision,
                window=cell.predictor.window,
                lead=cell.predictor.lead,
                fault_dist=cell.dist,
                false_pred_dist=cell.false_pred_dist,
                n_components=cell.n_components,
                stationary=cell.stationary,
            )
            runs.append(simulate(cell.work, cell.platform, cell.strategy, trace, rng))
        out.append(runs)
    return out


#: per-lane result fields assembled into CellResult arrays
_LANE_FIELDS = (
    "waste", "makespan", "n_faults", "n_proactive_ckpts",
    "n_regular_ckpts", "n_migrations", "trace_exhausted",
)


def _lane_arrays(res) -> Dict[str, np.ndarray]:
    return {k: getattr(res, k) for k in _LANE_FIELDS}


def _scalar_lane_arrays(outs) -> Dict[str, np.ndarray]:
    return {
        "waste": np.array([r.waste for r in outs]),
        "makespan": np.array([r.makespan for r in outs]),
        "n_faults": np.array([r.n_faults for r in outs]),
        "n_proactive_ckpts": np.array([r.n_proactive_ckpts for r in outs]),
        "n_regular_ckpts": np.array([r.n_regular_ckpts for r in outs]),
        "n_migrations": np.array([r.n_migrations for r in outs]),
        "trace_exhausted": np.array([r.trace_exhausted for r in outs]),
    }


def _cat_lane_arrays(parts: List[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    return {k: np.concatenate([p[k] for p in parts]) for k in _LANE_FIELDS}


@dataclass
class FusedLayout:
    """The fused dispatch's lane layout, grid-deterministic.

    Everything the cell-multiplexed engine call needs, assembled once
    from a :class:`GridSpec`: cells regrouped in trace-compatibility
    order (``cell_order``), per-cell lane counts and offsets, the
    per-cell engine tables (``work_c`` / ``plats_c`` / ``strats_c``),
    the lane -> cell index, and the trace source — per-group
    :class:`TraceSpec` streams (device trace mode) or one concatenated
    :class:`BatchTraces` (host mode).  Both :func:`run_grid` and the
    resumable :class:`~repro.ft.campaign.CampaignRunner` build the
    *same* layout from the same grid, which is what makes a campaign's
    lane partition (and therefore its results) reconstructible from
    ``(grid, cursor)`` alone — no trace replay, no stored traces."""

    grid: GridSpec
    groups: List[Tuple[Tuple, List[int]]]
    cell_order: List[int]
    runs_o: np.ndarray  # (n_cells,) lanes per cell, cell_order order
    offs: np.ndarray  # (n_cells + 1,) lane offsets per cell
    specs: List[TraceSpec]  # device trace mode: one spec per group
    traces: Optional[BatchTraces]  # host trace mode: all lanes
    work_c: np.ndarray
    plats_c: List
    strats_c: List
    cidx: np.ndarray  # (n_lanes,) lane -> cell_order position

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    @property
    def n_lanes(self) -> int:
        return int(self.offs[-1])

    def concat_spec(self) -> TraceSpec:
        """The one-dispatch device-mode spec: multi-group grids
        concatenate per-group specs into a single cell-indexed spec
        (law-indexed sampler); single-group grids keep the
        law-specialized spec — same results, cheaper draws."""
        if not self.specs:
            raise ValueError("concat_spec requires trace_mode='device'")
        if len(self.specs) == 1:
            return self.specs[0]
        return TraceSpec.concat_cells(self.specs)

    def host_traces(self) -> BatchTraces:
        """Host-materialized event arrays for all lanes (host engines,
        and the campaign's batch-engine degradation path in device
        trace mode)."""
        if self.traces is not None:
            return self.traces
        return BatchTraces.concat([s.materialize() for s in self.specs])


def build_fused_layout(grid: GridSpec, trace_mode: str) -> FusedLayout:
    """Assemble the fused dispatch's :class:`FusedLayout` for ``grid``.

    Deterministic in ``(grid, trace_mode)``: host traces are generated
    from ``grid.seed`` per group, device specs carry globally-unique
    counter-RNG stream ids — so two processes building the layout from
    the same grid get bit-identical lanes in the same order."""
    groups = _group_cells(grid)
    cell_order: List[int] = [ci for _, idx in groups for ci in idx]
    runs_o = np.array([grid.cell_runs(ci) for ci in cell_order], np.int64)
    offs = np.concatenate([[0], np.cumsum(runs_o)])
    specs: List[TraceSpec] = []
    traces: Optional[BatchTraces] = None
    if trace_mode == "device":
        base = 0
        for _, idx in groups:
            spec, base = _group_trace_spec(grid, idx, base)
            specs.append(spec)
    else:
        # per-group batched generation, then one engine call over all
        # groups: with zero-copy sentinel adoption the width padding of
        # concat costs less than the extra iterations of per-group calls
        traces = BatchTraces.concat(
            [
                _group_traces(grid, idx, gno)
                for gno, (_, idx) in enumerate(groups)
            ]
        )
    # per-cell tables in cell_order (the fused dispatch's cell axis)
    work_c = np.asarray(
        [grid.cells[ci].work for ci in cell_order], dtype=np.float64
    )
    plats_c = [grid.cells[ci].platform for ci in cell_order]
    strats_c = [grid.cells[ci].strategy for ci in cell_order]
    cidx = np.repeat(np.arange(len(cell_order), dtype=np.int32), runs_o)
    return FusedLayout(
        grid=grid, groups=groups, cell_order=cell_order, runs_o=runs_o,
        offs=offs, specs=specs, traces=traces, work_c=work_c,
        plats_c=plats_c, strats_c=strats_c, cidx=cidx,
    )


def _stats_cell_result(cell: ExperimentCell, sums, i: int) -> CellResult:
    """One stats-backed CellResult row from device-reduced CellSums."""
    return CellResult.from_stats(
        cell,
        int(sums.n_exhausted[i]),
        sums.n[i],
        sums.mean_waste[i], sums.ci95_waste[i],
        sums.mean_makespan[i], sums.ci95_makespan[i],
        sums.n_faults[i] / sums.n[i],
        sums.n_proactive_ckpts[i] / sums.n[i],
        sums.n_regular_ckpts[i] / sums.n[i],
        sums.n_migrations[i] / sums.n[i],
    )


def run_grid(
    grid: GridSpec, config=None, *, engine=UNSET, chunk_lanes=UNSET,
    devices=UNSET, mesh=UNSET, trace_mode=UNSET,
    dispatch=UNSET, collect=UNSET,
) -> SweepResult:
    """Execute every cell of ``grid`` and aggregate per-cell statistics.

    ``config`` is an :class:`~repro.core.engine.EngineConfig` (or a bare
    engine-name string, honoring the historical positional form); the
    individual engine keywords below are deprecated shims for it.

    ``chunk_lanes`` (jax engine only) caps the lanes resident on the
    device per engine call — "auto" picks a backend-appropriate chunk,
    an int forces one, None runs the whole grid in a single call.
    ``devices`` / ``mesh`` (jax engine only) shard each chunk's lanes
    across a device set (:func:`repro.core.jax_sim.simulate_batch_jax`);
    per-lane results are identical for any device count.

    ``trace_mode="device"`` replaces host trace generation with per-lane
    counter-based RNG streams (:class:`~repro.core.events.TraceSpec`):
    the JAX engine samples events lazily on the device — mixed-law grids
    fuse into ONE dispatch through the law-indexed sampler — while the
    batch/scalar engines replay the identical streams host-side.  The paired design is
    preserved (cells sharing trace parameters share stream ids), and
    results are chunk-size and device-count invariant.  Not supported
    for the legacy engine or superposed (``n_components``) traces.

    ``dispatch`` selects "fused" (default for batched engines: the whole
    grid — all failure-law families included in device trace mode —
    rides ONE cell-multiplexed engine call), "perfamily" (jax + device
    trace mode: one call per trace-compatibility group through the same
    law-indexed sampler — the bit-exact pre-fusion baseline), or
    "percell" (one engine call per cell; see the module docstring).  The
    legacy engine is inherently per-cell.  ``collect="stats"`` (jax
    only) fetches device-reduced per-cell statistics instead of per-run
    arrays."""
    cfg = resolve_engine_config(
        config, "run_grid", engine=engine, chunk_lanes=chunk_lanes,
        devices=devices, mesh=mesh, trace_mode=trace_mode,
        dispatch=dispatch, collect=collect,
    )
    engine, chunk_lanes = cfg.engine, cfg.chunk_lanes
    devices, mesh = cfg.devices, cfg.mesh
    trace_mode, dispatch, collect = cfg.trace_mode, cfg.dispatch, cfg.collect
    if engine not in ("batch", "scalar", "legacy", "jax"):
        raise ValueError(
            f"unknown engine {engine!r} "
            "(expected 'batch', 'jax', 'scalar' or 'legacy')"
        )
    if engine != "jax" and (devices is not None or mesh is not None):
        raise ValueError("devices=/mesh= require engine='jax'")
    if trace_mode not in ("host", "device"):
        raise ValueError(
            f"unknown trace_mode {trace_mode!r} (expected 'host' or 'device')"
        )
    if trace_mode == "device" and engine == "legacy":
        raise ValueError("trace_mode='device' requires a batched engine")
    if dispatch is None:
        dispatch = "percell" if engine == "legacy" else "fused"
    if dispatch not in ("fused", "percell", "perfamily"):
        raise ValueError(
            f"unknown dispatch {dispatch!r} "
            "(expected 'fused', 'perfamily' or 'percell')"
        )
    if engine == "legacy" and dispatch == "fused":
        raise ValueError("engine='legacy' is inherently per-cell")
    if dispatch == "perfamily" and not (
        engine == "jax" and trace_mode == "device"
    ):
        raise ValueError(
            "dispatch='perfamily' requires engine='jax' and "
            "trace_mode='device'"
        )
    if collect not in ("lanes", "stats"):
        raise ValueError(
            f"unknown collect {collect!r} (expected 'lanes' or 'stats')"
        )
    if collect == "stats" and engine != "jax":
        raise ValueError("collect='stats' requires engine='jax'")
    if collect == "stats" and dispatch == "percell":
        raise ValueError(
            "collect='stats' requires dispatch='fused' or 'perfamily'"
        )
    t0 = time.monotonic()
    if engine == "legacy":
        cells = []
        for cell, runs in zip(grid.cells, _run_legacy(grid)):
            cells.append(
                CellResult(
                    cell=cell,
                    waste=np.array([r.waste for r in runs]),
                    makespan=np.array([r.makespan for r in runs]),
                    n_faults=np.array([r.n_faults for r in runs]),
                    n_proactive_ckpts=np.array([r.n_proactive_ckpts for r in runs]),
                    n_regular_ckpts=np.array([r.n_regular_ckpts for r in runs]),
                    n_migrations=np.array([r.n_migrations for r in runs]),
                    n_exhausted=sum(r.trace_exhausted for r in runs),
                )
            )
        return SweepResult(
            grid=grid, cells=cells, engine=engine,
            wall_time_s=time.monotonic() - t0, dispatch=dispatch,
        )
    layout = build_fused_layout(grid, trace_mode)
    groups, cell_order = layout.groups, layout.cell_order
    runs_o, offs, specs = layout.runs_o, layout.offs, layout.specs
    work_c, plats_c = layout.work_c, layout.plats_c
    strats_c, cidx = layout.strats_c, layout.cidx
    traces = layout.traces
    if trace_mode == "device" and engine != "jax":
        # host engines replay the device streams via materialize()
        traces = layout.host_traces()

    lane_parts: List[Dict[str, np.ndarray]] = []
    stats_rows: List[CellResult] = []

    def _stats_from(sums, first_pos: int):
        for i in range(sums.n_cells):
            ci = cell_order[first_pos + i]
            stats_rows.append(_stats_cell_result(grid.cells[ci], sums, i))

    if dispatch == "percell":
        # one engine call per cell: same traces/streams as the fused
        # path, so per-cell results match it (bit-identically for the
        # deterministic trust settings; see module docstring)
        if engine == "jax":
            from ..core.jax_sim import simulate_batch_jax

        # cell position -> (owning group, group's first lane offset)
        group_of: List[int] = []
        group_lane0: List[int] = []
        p = 0
        for g, (_, idx) in enumerate(groups):
            group_of.extend([g] * len(idx))
            group_lane0.extend([int(offs[p])] * len(idx))
            p += len(idx)
        expanded: List[Optional[TraceSpec]] = [None] * len(specs)
        for k in range(len(cell_order)):
            sl = slice(int(offs[k]), int(offs[k + 1]))
            n_k = int(runs_o[k])
            wk = np.full(n_k, work_c[k])
            pk, sk = [plats_c[k]] * n_k, [strats_c[k]] * n_k
            if trace_mode == "device" and engine == "jax":
                g = group_of[k]
                if expanded[g] is None:
                    expanded[g] = specs[g].expand()
                glo = group_lane0[k]
                sub = expanded[g].take(
                    np.arange(sl.start - glo, sl.stop - glo)
                )
            else:
                sub = traces.take(np.arange(sl.start, sl.stop))
            if engine == "jax":
                res = simulate_batch_jax(
                    wk, pk, sk, sub,
                    rng=np.random.default_rng([grid.seed, len(groups), k]),
                    chunk=chunk_lanes, devices=devices, mesh=mesh,
                )
                lane_parts.append(_lane_arrays(res))
            elif engine == "batch":
                res = simulate_batch(
                    wk, pk, sk, sub,
                    rng=np.random.default_rng([grid.seed, len(groups), k]),
                )
                lane_parts.append(_lane_arrays(res))
            else:  # scalar: per-lane rng seeds match the fused path
                outs = [
                    simulate(
                        float(work_c[k]), plats_c[k], strats_c[k],
                        sub.lane(j),
                        np.random.default_rng(
                            [grid.seed, len(groups), sl.start + j]
                        ),
                    )
                    for j in range(n_k)
                ]
                lane_parts.append(_scalar_lane_arrays(outs))
    elif engine == "jax" and trace_mode == "device":
        from ..core.jax_sim import simulate_batch_jax

        if dispatch == "fused" and len(groups) > 1:
            # ONE mixed-law dispatch: the per-group specs concatenate
            # into a single cell-indexed spec whose failure laws ride
            # the cell tables through the law-indexed sampler — one
            # compiled executable per grid *shape*, not per family
            spec = TraceSpec.concat_cells(specs)
            res = simulate_batch_jax(
                work_c, plats_c, strats_c, spec,
                chunk=chunk_lanes, devices=devices, mesh=mesh,
                collect=collect,
            )
            if collect == "stats":
                _stats_from(res, 0)
            else:
                lane_parts.append(_lane_arrays(res))
        else:
            # one dispatch per trace-compatibility group: the
            # single-family fast path of "fused" (law-specialized
            # sampler, no indexed overhead) and the explicit
            # "perfamily" baseline, whose specs are tuple-ized so the
            # law-indexed sampler — hence every per-lane result — is
            # bit-identical to the one-dispatch path
            pos = 0
            for (_, idx), spec in zip(groups, specs):
                a, b = pos, pos + len(idx)
                if dispatch == "perfamily":
                    spec = spec.indexed()
                res = simulate_batch_jax(
                    work_c[a:b], plats_c[a:b], strats_c[a:b], spec,
                    chunk=chunk_lanes, devices=devices, mesh=mesh,
                    collect=collect,
                )
                if collect == "stats":
                    _stats_from(res, a)
                else:
                    lane_parts.append(_lane_arrays(res))
                pos = b
    elif engine == "jax":
        # fused host-trace dispatch: per-cell engine tables + the lane ->
        # cell index (event arrays stay per-lane)
        from ..core.jax_sim import simulate_batch_jax

        res = simulate_batch_jax(
            work_c, plats_c, strats_c, traces,
            rng=np.random.default_rng([grid.seed, len(groups)]),
            chunk=chunk_lanes, devices=devices, mesh=mesh,
            cell_index=cidx, collect=collect,
        )
        if collect == "stats":
            _stats_from(res, 0)
        else:
            lane_parts.append(_lane_arrays(res))
    elif engine == "batch":
        res = simulate_batch(
            np.repeat(work_c, runs_o),
            [plats_c[k] for k in range(len(cell_order)) for _ in range(runs_o[k])],
            [strats_c[k] for k in range(len(cell_order)) for _ in range(runs_o[k])],
            traces,
            rng=np.random.default_rng([grid.seed, len(groups)]),
        )
        lane_parts.append(_lane_arrays(res))
    else:  # scalar
        work_l = np.repeat(work_c, runs_o)
        plats_l = [
            plats_c[k] for k in range(len(cell_order)) for _ in range(runs_o[k])
        ]
        strats_l = [
            strats_c[k] for k in range(len(cell_order)) for _ in range(runs_o[k])
        ]
        outs = [
            simulate(
                float(work_l[i]), plats_l[i], strats_l[i], traces.lane(i),
                np.random.default_rng([grid.seed, len(groups), i]),
            )
            for i in range(traces.n_lanes)
        ]
        lane_parts.append(_scalar_lane_arrays(outs))

    cells: List[Optional[CellResult]] = [None] * len(grid.cells)
    if collect == "stats":
        for k, cr in enumerate(stats_rows):
            cells[cell_order[k]] = cr
    else:
        lanes = _cat_lane_arrays(lane_parts)
        for k, ci in enumerate(cell_order):
            sl = slice(int(offs[k]), int(offs[k + 1]))
            cells[ci] = CellResult(
                cell=grid.cells[ci],
                waste=lanes["waste"][sl],
                makespan=lanes["makespan"][sl],
                n_faults=lanes["n_faults"][sl],
                n_proactive_ckpts=lanes["n_proactive_ckpts"][sl],
                n_regular_ckpts=lanes["n_regular_ckpts"][sl],
                n_migrations=lanes["n_migrations"][sl],
                n_exhausted=int(np.count_nonzero(lanes["trace_exhausted"][sl])),
            )
    return SweepResult(
        grid=grid, cells=cells, engine=engine,
        wall_time_s=time.monotonic() - t0, dispatch=dispatch, collect=collect,
    )


def run_cells(
    cells: Sequence[ExperimentCell],
    n_runs: int = 100,
    seed: int = 0,
    config=None,
    *,
    engine=UNSET,
    chunk_lanes=UNSET,
    devices=UNSET,
    mesh=UNSET,
    trace_mode=UNSET,
    dispatch=UNSET,
    collect=UNSET,
) -> SweepResult:
    """Convenience wrapper: build a :class:`GridSpec` and run it."""
    cfg = resolve_engine_config(
        config, "run_cells", engine=engine, chunk_lanes=chunk_lanes,
        devices=devices, mesh=mesh, trace_mode=trace_mode,
        dispatch=dispatch, collect=collect,
    )
    return run_grid(GridSpec(tuple(cells), n_runs=n_runs, seed=seed), cfg)
