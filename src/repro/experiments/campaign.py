"""CLI for resumable paper-grid campaigns.

Launch::

    python -m repro.experiments.campaign --preset validation \
        --ckpt-dir /scratch/camp --out sweep.json

Kill it at any point (SIGKILL included) and resume with nothing but the
checkpoint directory — the launch parameters are persisted alongside the
snapshots, and the resumed run's results are bit-identical to an
uninterrupted one::

    python -m repro.experiments.campaign --resume /scratch/camp --out sweep.json

The ``--chaos-*`` flags arm a deterministic :class:`~repro.ft.injection.
ChaosInjector` (chunk-boundary kills / OOMs / device losses) for tests
and CI; chaos configuration is deliberately *not* persisted, so a resume
is always chaos-free unless re-armed explicitly.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from ..core.engine import EngineConfig
from ..ft.campaign import CampaignConfig, CampaignRunner
from ..ft.injection import ChaosInjector
from .grid import GridSpec
from .paper_grid import paper_grid_cells

__all__ = ["main"]

#: launch-parameter sidecar living next to the snapshots
_PARAMS_FILE = "campaign_cli.json"


def _int_list(text: str) -> List[int]:
    return [int(t) for t in text.split(",") if t.strip() != ""]


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments.campaign",
        description="killable/resumable fused paper-grid sweep",
    )
    ap.add_argument("--preset", default="validation",
                    choices=("validation", "bench", "full"))
    ap.add_argument("--limit-cells", type=int, default=None,
                    help="truncate the preset's cell list (smoke tests)")
    ap.add_argument("--n-runs", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-mode", default="device",
                    choices=("device", "host"))
    ap.add_argument("--collect", default="stats", choices=("stats", "lanes"))
    ap.add_argument("--chunk-lanes", default="auto",
                    help="lanes per chunk (int) or 'auto'")
    ap.add_argument("--ckpt-dir", default=None,
                    help="snapshot directory (required unless --resume)")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="resume the campaign whose snapshots live in DIR")
    ap.add_argument("--ckpt-period", type=float, default=None,
                    help="snapshot period seconds; 0 = every chunk; "
                         "default lets optimize('young') choose")
    ap.add_argument("--mtbf", type=float, default=3600.0,
                    help="assumed MTBF of the machine running the sweep")
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--sync-snapshots", action="store_true",
                    help="block on disk drain (default: async)")
    ap.add_argument("--out", default=None, help="write SweepResult JSON")
    chaos = ap.add_argument_group("chaos injection (tests/CI)")
    chaos.add_argument("--chaos-seed", type=int, default=0)
    chaos.add_argument("--chaos-p-kill", type=float, default=0.0)
    chaos.add_argument("--chaos-p-oom", type=float, default=0.0)
    chaos.add_argument("--chaos-p-device-loss", type=float, default=0.0)
    chaos.add_argument("--chaos-kill-at", type=_int_list, default=[])
    chaos.add_argument("--chaos-oom-at", type=_int_list, default=[])
    chaos.add_argument("--chaos-device-loss-at", type=_int_list, default=[])
    chaos.add_argument("--chaos-jax-fail-at", type=int, default=None)
    chaos.add_argument("--chaos-kill-mode", default="raise",
                       choices=("raise", "sigkill"))
    chaos.add_argument("--chaos-max-fires", type=int, default=None)
    return ap


def _chaos_from(args) -> Optional[ChaosInjector]:
    armed = (
        args.chaos_p_kill or args.chaos_p_oom or args.chaos_p_device_loss
        or args.chaos_kill_at or args.chaos_oom_at
        or args.chaos_device_loss_at or args.chaos_jax_fail_at is not None
    )
    if not armed:
        return None
    return ChaosInjector(
        seed=args.chaos_seed,
        p_kill=args.chaos_p_kill,
        p_oom=args.chaos_p_oom,
        p_device_loss=args.chaos_p_device_loss,
        kill_at=tuple(args.chaos_kill_at),
        oom_at=tuple(args.chaos_oom_at),
        device_loss_at=tuple(args.chaos_device_loss_at),
        jax_fail_at=args.chaos_jax_fail_at,
        kill_mode=args.chaos_kill_mode,
        max_fires=args.chaos_max_fires,
    )


def _grid_params(args) -> dict:
    return {
        "preset": args.preset,
        "limit_cells": args.limit_cells,
        "n_runs": args.n_runs,
        "seed": args.seed,
        "trace_mode": args.trace_mode,
        "collect": args.collect,
        "chunk_lanes": args.chunk_lanes,
        "mtbf": args.mtbf,
        "ckpt_period": args.ckpt_period,
        "keep": args.keep,
    }


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    resume: object = "auto"
    if args.resume is not None:
        ckpt_dir = args.resume
        path = os.path.join(ckpt_dir, _PARAMS_FILE)
        if not os.path.exists(path):
            print(f"no {_PARAMS_FILE} in {ckpt_dir}; nothing to resume",
                  file=sys.stderr)
            return 2
        with open(path) as f:
            params = json.load(f)
        resume = "auto"  # finished campaigns re-emit from the final snapshot
    else:
        if args.ckpt_dir is None:
            print("--ckpt-dir is required unless --resume", file=sys.stderr)
            return 2
        ckpt_dir = args.ckpt_dir
        params = _grid_params(args)
        os.makedirs(ckpt_dir, exist_ok=True)
        with open(os.path.join(ckpt_dir, _PARAMS_FILE), "w") as f:
            json.dump(params, f, indent=1)

    cells = paper_grid_cells(params["preset"])
    if params.get("limit_cells"):
        cells = cells[: params["limit_cells"]]
    grid = GridSpec(cells=tuple(cells), n_runs=params["n_runs"],
                    seed=params["seed"])
    chunk = params["chunk_lanes"]
    cfg = EngineConfig(
        engine="jax",
        trace_mode=params["trace_mode"],
        collect=params["collect"],
        chunk_lanes="auto" if chunk == "auto" else int(chunk),
    )
    camp = CampaignConfig(
        ckpt_dir=ckpt_dir,
        mtbf=params["mtbf"],
        ckpt_period=params["ckpt_period"],
        keep=params["keep"],
        async_snapshots=not args.sync_snapshots,
        chaos=_chaos_from(args),
    )
    res = CampaignRunner(grid, camp, cfg).run(resume=resume)
    info = res.meta["campaign"]
    print(
        f"campaign done: {len(res.cells)} cells, {grid.n_lanes} lanes, "
        f"incarnation {info['incarnation']}, "
        f"{info['n_snapshots']} snapshots, wall {res.wall_time_s:.1f}s"
    )
    if args.out:
        res.write_json(args.out)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
