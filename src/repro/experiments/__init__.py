"""Unified experiment-sweep layer.

Turns paper-scale Monte-Carlo sweeps — (recall, precision) x platform size x
window x failure law x strategy — into a declarative grid executed by the
vectorized lane-per-trace engine (:mod:`repro.core.batch_sim`):

    from repro.experiments import ExperimentCell, run_cells

    cells = [
        ExperimentCell("young/N65536", work, platform, pred, young(platform)),
        ExperimentCell("instant/N65536", work, platform, pred, instant(platform, pred)),
    ]
    sweep = run_cells(cells, n_runs=100, seed=0)
    sweep["instant/N65536"].mean_waste
    sweep.write_csv("sweep.csv"); sweep.write_json("sweep.json")

``run_grid(grid, engine="scalar")`` replays the identical traces through
the scalar reference engine for equivalence checks and speedup baselines.
"""

from .grid import CellResult, ExperimentCell, GridSpec, SweepResult
from .paper_grid import PAPER_PREDICTORS, paper_grid_cells, paper_policy_table
from .runner import run_cells, run_grid
from .validation import (
    analytic_waste,
    analytic_waste_batch,
    cell_z_rows,
    holm_bonferroni,
    validate_sweep,
    write_z_table,
)

__all__ = [
    "CellResult",
    "ExperimentCell",
    "GridSpec",
    "SweepResult",
    "run_cells",
    "run_grid",
    "PAPER_PREDICTORS",
    "paper_grid_cells",
    "paper_policy_table",
    "analytic_waste",
    "analytic_waste_batch",
    "cell_z_rows",
    "holm_bonferroni",
    "validate_sweep",
    "write_z_table",
]
