"""CLI for the static-analysis suite — see the package docstring."""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import run_all
from .jaxpr_audit import run_audit
from .linter import (
    lint_tree,
    load_baseline,
    partition_findings,
    repo_root,
    write_baseline,
)
from .twins import check_twins


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: AST lint, twin parity, jaxpr audit",
    )
    ap.add_argument("--all", action="store_true", help="run every pass")
    ap.add_argument("--lint", action="store_true", help="AST lint pass")
    ap.add_argument("--twins", action="store_true", help="twin-parity pass")
    ap.add_argument("--jaxpr", action="store_true", help="jaxpr audit pass")
    ap.add_argument(
        "--write-baseline", action="store_true",
        help="accept current lint findings into LINT_BASELINE.json",
    )
    ap.add_argument("--root", type=Path, default=None, help="repo root")
    ap.add_argument(
        "--out", type=Path, default=None,
        help="write the findings report as JSON (CI artifact)",
    )
    args = ap.parse_args(argv)
    if not (args.lint or args.twins or args.jaxpr):
        args.all = True
    root = args.root if args.root is not None else repo_root()

    if args.all:
        code, report = run_all(root)
        _print_report(report)
        if args.out:
            args.out.write_text(json.dumps(report, indent=2) + "\n")
        return code

    code = 0
    report = {}
    if args.lint:
        findings = lint_tree(root)
        if args.write_baseline:
            path = write_baseline(root, findings)
            print(f"wrote {len(findings)} finding(s) to {path}")
        new, baselined, stale = partition_findings(
            findings, load_baseline(root)
        )
        report["lint"] = {
            "new": [f.format() for f in new],
            "baselined": [f.format() for f in baselined],
            "stale_baseline_entries": [
                f"{e.get('path')}: [{e.get('rule')}] {e.get('line_text')}"
                for e in stale
            ],
        }
        if new and not args.write_baseline:
            code = 1
    if args.twins:
        errors = check_twins(root)
        report["twins"] = {"errors": errors}
        if errors:
            code = 1
    if args.jaxpr:
        audits = run_audit()
        report["jaxpr"] = {
            "reports": [
                {"label": r.label, "ok": r.ok, "errors": r.errors,
                 "passed": r.passed}
                for r in audits
            ],
        }
        if any(not r.ok for r in audits):
            code = 1
    _print_report(report)
    if args.out:
        args.out.write_text(json.dumps(report, indent=2) + "\n")
    return code


def _print_report(report: dict) -> None:
    lint = report.get("lint")
    if lint is not None:
        for line in lint["new"]:
            print(f"NEW {line}")
        for line in lint["stale_baseline_entries"]:
            print(f"STALE-BASELINE {line}")
        print(
            f"[lint] {len(lint['new'])} new, "
            f"{len(lint['baselined'])} baselined, "
            f"{len(lint['stale_baseline_entries'])} stale baseline entr"
            f"{'y' if len(lint['stale_baseline_entries']) == 1 else 'ies'}"
        )
    twins = report.get("twins")
    if twins is not None:
        for err in twins["errors"]:
            print(err)
        print(f"[twins] {len(twins['errors'])} divergence(s)")
    jaxpr = report.get("jaxpr")
    if jaxpr is not None:
        for r in jaxpr["reports"]:
            status = "OK" if r["ok"] else "FAIL"
            print(f"[jaxpr-audit] {r['label']}: {status}")
            for e in r["errors"]:
                print(f"  FAIL: {e}")


if __name__ == "__main__":
    sys.exit(main())
