"""File walking + baseline workflow for the ``repro-lint`` AST pass.

The checked-in baseline (``LINT_BASELINE.json`` at the repo root)
records *deliberate* findings — each with a one-line justification — so
CI fails only on **new** findings.  Baseline entries are matched by a
line-number-independent fingerprint ``(rule, path, symbol, line_text)``:
editing unrelated code above a baselined finding does not resurface it,
while editing the flagged line itself does (the finding must then be
re-justified or fixed).

Workflow::

    python -m repro.analysis --lint                  # fail on new findings
    python -m repro.analysis --lint --write-baseline # accept current tree

``--write-baseline`` preserves the justifications of entries that are
still live and stamps new entries with ``"TODO: justify"`` — the review
gate is that no TODO justification lands on main.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from .rules import Finding, scan_source

__all__ = [
    "repo_root",
    "iter_source_files",
    "lint_tree",
    "load_baseline",
    "write_baseline",
    "partition_findings",
]

#: repo-relative path of the checked-in lint baseline
BASELINE_NAME = "LINT_BASELINE.json"

#: directories scanned by the lint pass (repo-relative)
SCAN_DIRS = ("src", "benchmarks")


def repo_root(start: Optional[Path] = None) -> Path:
    """Locate the repo root: the nearest ancestor of ``start`` (or of
    this file) containing ``pyproject.toml``."""
    here = Path(start) if start is not None else Path(__file__).resolve()
    for cand in [here] + list(here.parents):
        if (cand / "pyproject.toml").exists():
            return cand
    raise FileNotFoundError(
        f"no pyproject.toml above {here}; pass --root explicitly"
    )


def iter_source_files(root: Path) -> List[Path]:
    files: List[Path] = []
    for d in SCAN_DIRS:
        base = root / d
        if base.is_dir():
            files.extend(sorted(base.rglob("*.py")))
    return files


def lint_tree(root: Path) -> List[Finding]:
    """Run every lint rule over the repo's scanned source trees."""
    findings: List[Finding] = []
    for path in iter_source_files(root):
        rel = path.relative_to(root).as_posix()
        findings.extend(scan_source(rel, path.read_text()))
    return findings


def load_baseline(root: Path) -> List[Dict]:
    path = root / BASELINE_NAME
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    return list(data.get("findings", []))


def _entry_fingerprint(entry: Dict) -> Tuple[str, str, str, str]:
    return (
        entry.get("rule", ""),
        entry.get("path", ""),
        entry.get("symbol", ""),
        entry.get("line_text", ""),
    )


def partition_findings(
    findings: Sequence[Finding], baseline: Sequence[Dict]
) -> Tuple[List[Finding], List[Finding], List[Dict]]:
    """Split findings into (new, baselined, stale-baseline-entries).

    A baseline entry absorbs at most as many findings as it was recorded
    for (identical lines in one function collapse to one fingerprint —
    they are the same deliberate idiom)."""
    known = {_entry_fingerprint(e) for e in baseline}
    new: List[Finding] = []
    old: List[Finding] = []
    live: set = set()
    for f in findings:
        if f.fingerprint() in known:
            old.append(f)
            live.add(f.fingerprint())
        else:
            new.append(f)
    stale = [e for e in baseline if _entry_fingerprint(e) not in live]
    return new, old, stale


def write_baseline(root: Path, findings: Sequence[Finding]) -> Path:
    """Accept the current tree: rewrite the baseline from ``findings``,
    preserving justifications of entries that are still live."""
    prior = {
        _entry_fingerprint(e): e.get("justification", "")
        for e in load_baseline(root)
    }
    entries: List[Dict] = []
    seen: set = set()
    for f in sorted(findings, key=lambda f: (f.path, f.line)):
        fp = f.fingerprint()
        if fp in seen:
            continue
        seen.add(fp)
        entries.append(
            {
                "rule": f.rule,
                "path": f.path,
                "symbol": f.symbol,
                "line_text": f.line_text,
                "justification": prior.get(fp, "TODO: justify"),
            }
        )
    path = root / BASELINE_NAME
    path.write_text(
        json.dumps(
            {
                "comment": (
                    "Deliberate repro-lint findings; matched by "
                    "(rule, path, symbol, line_text) so line numbers "
                    "may drift.  Regenerate with "
                    "`python -m repro.analysis --lint --write-baseline`."
                ),
                "findings": entries,
            },
            indent=2,
        )
        + "\n"
    )
    return path
