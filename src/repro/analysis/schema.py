"""Declared dtype schema of the fused device engine.

This module is the single written-down source of truth for the dtypes
the engine's state, constants and outputs are allowed to carry — the
contract the jaxpr auditor (:mod:`repro.analysis.jaxpr_audit`) checks
abstractly against every traced entry point, and the vocabulary the
analytic layer (:mod:`repro.core.waste`, :mod:`repro.core.periods`)
uses to annotate its formulas.

It is deliberately dependency-light (NumPy only, no JAX import) so that
``repro.core`` modules can import the type aliases without pulling the
analysis tooling — or JAX — into their import graph.

Roles
=====

The engine resolves two dtype knobs from its ``precision`` argument
(``repro.core.jax_sim.simulate_batch_jax``):

``fdt``
    the working float — ``float64`` in x64 mode (the default off-TPU,
    where float-rounding agreement with the NumPy engine is asserted),
    ``float32`` on TPU;
``idt``
    the event-counter int — ``int64`` in x64 mode, ``int32`` otherwise.

Everything else is precision-independent: the lane phase machine is
``int32``, boolean masks are ``bool``, and the counter-based RNG streams
are ``uint32``/``uint64`` (Threefry words / SplitMix64 state — see the
twin registry in :mod:`repro.analysis.twins`).
"""

from __future__ import annotations

from typing import Sequence, Union

import numpy as np

__all__ = [
    "FloatLike",
    "FloatArray",
    "IntArray",
    "BoolArray",
    "STATE_SCHEMA",
    "OUT_SCHEMA",
    "CELL_SUMS_ROLE",
    "resolve_role",
]

#: A float64-precision scalar or NumPy-broadcastable array — the value
#: type of every analytic waste/period formula.  Plain Python floats are
#: fine (they are IEEE doubles); what the schema forbids is *narrower*
#: floats (f32) leaking into the analytic/simulated comparison boundary.
FloatLike = Union[float, np.floating, np.ndarray]

#: An ndarray of the engine's working float (``fdt``; float64 in x64).
FloatArray = np.ndarray

#: An ndarray of the engine's counter int (``idt``; int64 in x64).
IntArray = np.ndarray

#: A boolean mask array.
BoolArray = np.ndarray

#: dtype role of every leaf of the per-lane engine state pytree
#: (``repro.core.jax_sim._chunk_state``).  Roles: "fdt" (working
#: float), "idt" (counter int), "int32" (phase machine), "bool".
STATE_SCHEMA = {
    "t": "fdt",
    "saved": "fdt",
    "unsaved": "fdt",
    "period_work": "fdt",
    "na_saved": "fdt",
    "ep_t0": "fdt",
    "ep_end": "fdt",
    "n_faults": "idt",
    "n_pro": "idt",
    "n_reg": "idt",
    "n_mig": "idt",
    "phase": "int32",
    "exhausted": "bool",
}

#: dtype role of every per-lane result array fetched back to the host
#: (``repro.core.jax_sim._OUT_KEYS``).
OUT_SCHEMA = {
    "t": "fdt",
    "n_faults": "idt",
    "n_pro": "idt",
    "n_reg": "idt",
    "n_mig": "idt",
    "exhausted": "bool",
    "phase": "int32",
}

#: dtype role of the device-reduced per-cell accumulator
#: (``collect="stats"``): one (n_cells, 11) matrix of Monte-Carlo sums.
CELL_SUMS_ROLE = "fdt"


def resolve_role(role: str, x64: bool = True) -> np.dtype:
    """Resolve a schema role to the concrete dtype of a precision mode."""
    if role == "fdt":
        return np.dtype(np.float64 if x64 else np.float32)
    if role == "idt":
        return np.dtype(np.int64 if x64 else np.int32)
    if role == "int32":
        return np.dtype(np.int32)
    if role == "bool":
        return np.dtype(bool)
    raise ValueError(f"unknown schema role {role!r}")
