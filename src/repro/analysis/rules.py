"""repo-specific AST lint rules (the ``repro-lint`` pass).

Each rule has a stable id — the token used both in findings and in the
inline escape hatch::

    something_deliberate()  # repro-lint: disable=host-sync

and a second directive marks functions that are traced by ``jax.jit``
even though no decorator says so (they reach the jit through
``functools.partial`` + a call-site ``jax.jit``)::

    def _jit_run(consts, state, *, ...):  # repro-lint: jit-root

Rules
=====

``host-sync``
    No implicit device->host synchronization outside the engine's
    designed boundary: calls to ``jax.device_get`` and the syncing
    methods ``.item()`` / ``.tolist()`` / ``.block_until_ready()`` /
    ``.copy_to_host_async()`` are flagged in any module that imports
    JAX, and ``float(x)`` / ``int(x)`` / ``bool(x)`` / ``np.asarray(x)``
    on tracer-valued names are flagged inside jit-traced bodies.
    Allowlisted boundary set: ``benchmarks/`` and
    ``src/repro/experiments/runner.py`` (both sit above the engine and
    consume fetched results).

``twin-import``
    The NumPy twin modules (``core/events.py``, ``core/batch_sim.py``)
    must stay importable — and *auditable* — without JAX: any
    ``import jax`` / ``from jax ...`` there is a layering break that
    would let the twins silently diverge from pure-NumPy semantics.

``np-in-jit``
    No host NumPy *compute* inside jit-traced bodies: ``np.<fn>(...)``
    under tracing either constant-folds silently (hiding a value that
    should be traced) or raises at dispatch time.  Dtype/constant
    references (``np.float64``, ``np.inf``, ``np.pi``, ``np.dtype`` ...)
    are allowed — they are static metadata, not compute.

``tracer-branch``
    No Python ``if`` / ``while`` / ``assert`` on tracer-valued names
    inside jit-traced bodies: control flow on tracers must go through
    ``lax.cond`` / ``lax.while_loop`` / ``jnp.where``.  Names are
    tracer-valued if they are positional parameters of a jit-root (its
    keyword-only parameters are the static configuration by repo
    convention) or are assigned from expressions involving tracers or
    ``jnp`` / ``lax`` calls; ``.shape`` / ``.dtype`` / ``.ndim`` /
    ``.size`` access sanitizes (those are static under tracing).

``unseeded-rng``
    No legacy global-state NumPy RNG (``np.random.seed`` /
    ``np.random.rand`` / ...): every random draw must flow from an
    explicitly seeded ``np.random.default_rng`` / ``SeedSequence`` so
    runs are reproducible and streams are isolated.

``kernel-dtype``
    Kernel code (``src/repro/kernels/``) must be dtype-explicit:
    no ``float64`` literals (the engine's working float is a parameter,
    f32 on TPU), no module-level bare Python float constants (weakly
    typed f64 doubles that widen NumPy expressions; wrap in
    ``np.float32(...)``), and no ``jnp.array`` / ``jnp.asarray`` /
    ``jnp.full`` constant materialization without an explicit ``dtype``.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

__all__ = ["Finding", "RULES", "FileContext", "scan_source"]

#: rule id -> one-line description (the README / module-doc rule table)
RULES = {
    "host-sync": (
        "no jax.device_get / .item() / .tolist() / .block_until_ready() "
        "/ float(tracer) / np.asarray(tracer) host syncs outside the "
        "allowlisted boundary (benchmarks/, experiments/runner.py)"
    ),
    "twin-import": (
        "no jax/jnp imports in the NumPy-twin modules "
        "(core/events.py, core/batch_sim.py)"
    ),
    "np-in-jit": (
        "no host-NumPy compute inside jit-traced bodies "
        "(np dtype/constant references are allowed)"
    ),
    "tracer-branch": (
        "no Python if/while/assert on tracer-valued names inside "
        "jit-traced bodies"
    ),
    "unseeded-rng": (
        "no global-state np.random.* calls; use an explicitly seeded "
        "np.random.default_rng"
    ),
    "kernel-dtype": (
        "kernel code must be dtype-explicit: no float64 literals, no "
        "module-level bare float constants, no jnp constant "
        "materialization without dtype"
    ),
}

#: modules that must stay JAX-free (the NumPy side of the twin registry)
TWIN_MODULES = (
    "src/repro/core/events.py",
    "src/repro/core/batch_sim.py",
)

#: designed host boundary: these consume fetched results by construction
HOST_BOUNDARY_PREFIXES = ("benchmarks/",)
HOST_BOUNDARY_FILES = ("src/repro/experiments/runner.py",)

KERNEL_PREFIX = "src/repro/kernels/"

#: np attributes that are static metadata, not host compute
_ALLOWED_NP_IN_JIT = {
    "float16", "float32", "float64", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool_", "complex64",
    "complex128", "inf", "nan", "pi", "e", "euler_gamma", "newaxis",
    "dtype", "finfo", "iinfo", "errstate", "ndarray", "integer",
    "floating", "generic",
}

#: np.random members that *are* the seeded API
_SEEDED_RNG_OK = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "Philox", "SFC64", "MT19937",
}

#: method calls that force (or schedule) a device->host transfer
_SYNC_METHODS = {"item", "tolist", "block_until_ready", "copy_to_host_async"}

#: callables whose function-valued arguments are traced by JAX
_TRACING_CALLS = {
    "jit", "while_loop", "cond", "scan", "fori_loop", "switch",
    "shard_map", "pallas_call", "vmap", "pmap", "grad",
    "value_and_grad", "checkpoint", "remat", "custom_jvp", "custom_vjp",
}

_DIRECTIVE_RE = re.compile(r"#\s*repro-lint:\s*([a-z-]+)(?:=([\w,-]+))?")


@dataclass(frozen=True)
class Finding:
    """One lint finding, line-number-independent fingerprint included."""

    rule: str
    path: str  # repo-relative posix path
    line: int
    col: int
    message: str
    symbol: str  # enclosing function qualname, or "<module>"
    line_text: str  # stripped source line — the baseline fingerprint

    def fingerprint(self) -> Tuple[str, str, str, str]:
        """Identity under which the baseline suppresses a finding.

        Deliberately excludes the line *number* so unrelated edits above
        a baselined finding don't resurface it."""
        return (self.rule, self.path, self.symbol, self.line_text)

    def format(self) -> str:
        return (
            f"{self.path}:{self.line}:{self.col}: [{self.rule}] "
            f"{self.message} (in {self.symbol})"
        )


@dataclass
class FileContext:
    """Parsed source + repo-relative location + inline directives."""

    rel: str  # repo-relative posix path
    source: str
    tree: ast.AST = field(init=False)
    lines: List[str] = field(init=False)
    #: line number -> set of rule ids disabled on that line ("*" = all)
    disabled: Dict[int, Set[str]] = field(default_factory=dict)
    #: line numbers carrying a "jit-root" directive
    jit_root_lines: Set[int] = field(default_factory=set)

    def __post_init__(self):
        self.tree = ast.parse(self.source)
        self.lines = self.source.splitlines()
        for i, text in enumerate(self.lines, start=1):
            m = _DIRECTIVE_RE.search(text)
            if not m:
                continue
            kind, arg = m.group(1), m.group(2)
            if kind == "disable":
                rules = set((arg or "*").split(","))
                self.disabled.setdefault(i, set()).update(rules)
            elif kind == "jit-root":
                self.jit_root_lines.add(i)

    @property
    def imports_jax(self) -> bool:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                if any(a.name.split(".")[0] == "jax" for a in node.names):
                    return True
            elif isinstance(node, ast.ImportFrom):
                if node.module and node.module.split(".")[0] == "jax":
                    return True
        return False

    @property
    def is_twin_module(self) -> bool:
        return self.rel in TWIN_MODULES

    @property
    def is_kernel(self) -> bool:
        return self.rel.startswith(KERNEL_PREFIX)

    @property
    def host_boundary(self) -> bool:
        return self.rel in HOST_BOUNDARY_FILES or any(
            self.rel.startswith(p) for p in HOST_BOUNDARY_PREFIXES
        )

    def is_disabled(self, rule: str, line: int) -> bool:
        rules = self.disabled.get(line, ())
        return "*" in rules or rule in rules

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""


def _dotted(node: ast.AST) -> Optional[str]:
    """Render a Name/Attribute chain as 'a.b.c' (None if not a chain)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _call_name(node: ast.Call) -> Optional[str]:
    return _dotted(node.func)


def _is_float_const(node: ast.AST) -> bool:
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, (ast.USub, ast.UAdd)):
        node = node.operand
    return isinstance(node, ast.Constant) and isinstance(node.value, float)


class _Scanner:
    """One pass over a file, emitting findings for every applicable rule."""

    def __init__(self, ctx: FileContext):
        self.ctx = ctx
        self.findings: List[Finding] = []
        self._scope: List[str] = []
        self._jit_names = self._collect_traced_names()

    # -- plumbing ------------------------------------------------------

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 1)
        if self.ctx.is_disabled(rule, line):
            return
        self.findings.append(
            Finding(
                rule=rule,
                path=self.ctx.rel,
                line=line,
                col=getattr(node, "col_offset", 0),
                message=message,
                symbol=".".join(self._scope) or "<module>",
                line_text=self.ctx.line_text(line),
            )
        )

    def _collect_traced_names(self) -> Set[str]:
        """Names of local functions passed to tracing transforms.

        Resolves one common indirection: ``f = partial(g, ...)`` followed
        by ``jax.jit(f)`` / ``pallas_call(f, ...)`` marks ``g`` too (the
        engine's ``step = partial(_jit_run, ...)`` / kernel idiom)."""
        names: Set[str] = set()
        # name -> first positional function a partial(...) wraps
        partial_alias: Dict[str, str] = {}

        def _partial_target(call: ast.AST) -> Optional[str]:
            if not isinstance(call, ast.Call):
                return None
            inner = _call_name(call) or ""
            if inner.split(".")[-1] != "partial" or not call.args:
                return None
            return call.args[0].id if isinstance(call.args[0], ast.Name) else None

        for node in ast.walk(self.ctx.tree):
            if isinstance(node, ast.Assign):
                tgt = _partial_target(node.value)
                if tgt is not None:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            partial_alias[t.id] = tgt
                continue
            if not isinstance(node, ast.Call):
                continue
            fn = _call_name(node)
            if fn is None or fn.split(".")[-1] not in _TRACING_CALLS:
                continue
            for arg in list(node.args) + [k.value for k in node.keywords]:
                if isinstance(arg, ast.Name):
                    names.add(arg.id)
                else:
                    tgt = _partial_target(arg)
                    if tgt is not None:
                        names.add(tgt)
        for _ in range(4):  # resolve chained partial aliases
            extra = {partial_alias[n] for n in names if n in partial_alias}
            if extra <= names:
                break
            names |= extra
        return names

    def _is_jit_root(self, node: ast.FunctionDef) -> bool:
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            name = _dotted(target) or ""
            if name.split(".")[-1] in _TRACING_CALLS:
                return True
            # @partial(jax.jit, ...) — jit travels as the first argument
            if isinstance(dec, ast.Call) and dec.args:
                inner = _dotted(dec.args[0]) or ""
                if inner.split(".")[-1] in _TRACING_CALLS:
                    return True
        if node.name in self._jit_names:
            return True
        lines = {node.lineno, node.lineno - 1}
        if node.decorator_list:
            lines.add(node.decorator_list[0].lineno - 1)
        return bool(lines & self.ctx.jit_root_lines)

    # -- entry point ---------------------------------------------------

    def run(self) -> List[Finding]:
        self._scan_module_level()
        self._walk(self.ctx.tree, in_jit=False)
        return self.findings

    def _walk(self, node: ast.AST, in_jit: bool) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scope.append(child.name)
                child_in_jit = in_jit or self._is_jit_root(child)
                if child_in_jit and not in_jit:
                    _JitBodyChecker(self, child).run()
                self._walk(child, in_jit=child_in_jit)
                self._scope.pop()
            else:
                self._check_node(child, in_jit)
                self._walk(child, in_jit)

    # -- module-level rules --------------------------------------------

    def _scan_module_level(self) -> None:
        if not self.ctx.is_kernel:
            return
        body = getattr(self.ctx.tree, "body", [])
        for stmt in body:
            if isinstance(stmt, ast.Assign) and _is_float_const(stmt.value):
                self._emit(
                    "kernel-dtype", stmt,
                    "module-level bare float constant is a weakly-typed "
                    "f64 double; wrap in np.float32(...) (or carry a "
                    "dtype at the use sites)",
                )

    # -- per-node rules ------------------------------------------------

    def _check_node(self, node: ast.AST, in_jit: bool) -> None:
        ctx = self.ctx
        if ctx.is_twin_module and isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.split(".")[0] == "jax":
                    self._emit(
                        "twin-import", node,
                        f"NumPy-twin module imports {alias.name!r}",
                    )
        if ctx.is_twin_module and isinstance(node, ast.ImportFrom):
            if node.module and node.module.split(".")[0] == "jax":
                self._emit(
                    "twin-import", node,
                    f"NumPy-twin module imports from {node.module!r}",
                )

        if isinstance(node, ast.Attribute):
            chain = _dotted(node)
            if (
                chain
                and chain.startswith("np.random.")
                and chain.split(".")[2] not in _SEEDED_RNG_OK
            ):
                self._emit(
                    "unseeded-rng", node,
                    f"global-state RNG {chain}; draw from an explicitly "
                    "seeded np.random.default_rng instead",
                )
            if ctx.is_kernel and node.attr == "float64":
                self._emit(
                    "kernel-dtype", node,
                    "float64 literal in kernel code (the working float "
                    "is a parameter; f32 on TPU)",
                )

        if isinstance(node, ast.Constant) and node.value == "float64":
            if ctx.is_kernel:
                self._emit(
                    "kernel-dtype", node, "float64 dtype string in kernel code"
                )

        if isinstance(node, ast.Call):
            name = _call_name(node) or ""
            if ctx.imports_jax and not ctx.host_boundary:
                if name == "jax.device_get":
                    self._emit(
                        "host-sync", node,
                        "jax.device_get forces a device->host transfer",
                    )
                if (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr in _SYNC_METHODS
                ):
                    self._emit(
                        "host-sync", node,
                        f".{node.func.attr}() forces or schedules a "
                        "device->host sync",
                    )
            if ctx.is_kernel and name.split(".")[-1] in (
                "array", "asarray", "full"
            ) and name.split(".")[0] in ("jnp", "np"):
                need = 3 if name.endswith("full") else 2
                has_dtype = len(node.args) >= need or any(
                    k.arg == "dtype" for k in node.keywords
                )
                if not has_dtype:
                    self._emit(
                        "kernel-dtype", node,
                        f"{name}(...) without an explicit dtype in "
                        "kernel code",
                    )


class _JitBodyChecker:
    """Taint-based checks inside one jit-root function body.

    Tracer taint seeds from the root's *positional* parameters (the
    repo convention: keyword-only parameters are the static
    configuration baked into the compiled program) and propagates
    through assignments whose right-hand side involves tainted names or
    ``jnp`` / ``lax`` calls.  Single forward pass in statement order —
    the engine's traced bodies are straight-line + nested defs, which
    this covers without a fixpoint."""

    _SANITIZING_ATTRS = {"shape", "dtype", "ndim", "size", "weak_type",
                         "sharding", "aval"}
    _TRACER_NAMESPACES = {"jnp", "lax", "pl", "pltpu"}

    def __init__(self, scanner: _Scanner, root: ast.FunctionDef):
        self.s = scanner
        self.root = root
        self.tainted: Set[str] = set()
        for fn in [root] + [
            n for n in ast.walk(root)
            if isinstance(n, ast.FunctionDef) and n is not root
        ]:
            args = fn.args.posonlyargs + fn.args.args
            if fn.args.vararg is not None:
                args = args + [fn.args.vararg]
            for a in args:
                if a.arg in ("self", "cls"):
                    continue
                # positional params annotated as plain Python scalars are
                # compile-time statics by repo convention (e.g.
                # ``kind: str`` in gap_transform)
                ann = a.annotation
                if isinstance(ann, ast.Name) and ann.id in (
                    "str", "int", "float", "bool", "bytes"
                ):
                    continue
                self.tainted.add(a.arg)

    def run(self) -> None:
        self._propagate_taint()
        self._scan_body(self.root)

    def _propagate_taint(self) -> None:
        """Fixpoint taint propagation over all assignments (and for-loop
        targets) in the root's body — order-insensitive."""
        assigns = [
            n for n in ast.walk(self.root)
            if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign, ast.For))
        ]
        for _ in range(16):
            before = len(self.tainted)
            for stmt in assigns:
                if isinstance(stmt, ast.For):
                    if self._expr_tainted(stmt.iter):
                        self._taint_target(stmt.target)
                    continue
                value = stmt.value
                if value is not None and self._expr_tainted(value):
                    targets = (
                        stmt.targets
                        if isinstance(stmt, ast.Assign)
                        else [stmt.target]
                    )
                    for t in targets:
                        self._taint_target(t)
            if len(self.tainted) == before:
                break

    def _scan_body(self, fn: ast.FunctionDef) -> None:
        for stmt in ast.walk(fn):
            if isinstance(stmt, (ast.If, ast.While)):
                if self._expr_tainted(stmt.test):
                    self.s._emit(
                        "tracer-branch", stmt,
                        "Python control flow on a tracer-valued "
                        "expression inside a jit-traced body; use "
                        "lax.cond / jnp.where",
                    )
            elif isinstance(stmt, ast.Assert):
                if self._expr_tainted(stmt.test):
                    self.s._emit(
                        "tracer-branch", stmt,
                        "assert on a tracer-valued expression inside a "
                        "jit-traced body",
                    )
            elif isinstance(stmt, ast.Call):
                self._check_call(stmt)
            elif isinstance(stmt, ast.Attribute):
                chain = _dotted(stmt)
                if chain and chain.startswith("np."):
                    attr = chain.split(".")[1]
                    if attr not in _ALLOWED_NP_IN_JIT and attr != "random":
                        self.s._emit(
                            "np-in-jit", stmt,
                            f"host NumPy compute {chain} inside a "
                            "jit-traced body",
                        )
                    elif chain.startswith("np.random."):
                        self.s._emit(
                            "np-in-jit", stmt,
                            f"host RNG {chain} inside a jit-traced body",
                        )

    def _check_call(self, node: ast.Call) -> None:
        name = _call_name(node) or ""
        if name in ("float", "int", "bool") and node.args:
            if self._expr_tainted(node.args[0]):
                self.s._emit(
                    "host-sync", node,
                    f"{name}(tracer) concretizes a traced value "
                    "(device sync / trace error)",
                )
        if name in ("np.asarray", "np.array") and node.args:
            if self._expr_tainted(node.args[0]):
                self.s._emit(
                    "host-sync", node,
                    f"{name}(tracer) pulls a traced value to host",
                )

    def _taint_target(self, target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            self.tainted.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._taint_target(elt)
        elif isinstance(target, (ast.Subscript, ast.Starred)):
            self._taint_target(target.value)

    def _expr_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Attribute):
            if node.attr in self._SANITIZING_ATTRS:
                return False
            chain = _dotted(node)
            if chain and chain.split(".")[0] in self._TRACER_NAMESPACES:
                return True
            return self._expr_tainted(node.value)
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Call):
            fn = _call_name(node) or ""
            head = fn.split(".")[0]
            if head in self._TRACER_NAMESPACES or fn.startswith("jax.lax"):
                return True
            if fn in ("len", "isinstance", "type", "range", "print"):
                return False
            return any(
                self._expr_tainted(a)
                for a in list(node.args) + [k.value for k in node.keywords]
            )
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.Lambda)):
                continue
            if self._expr_tainted(child):
                return True
        return False


def scan_source(rel: str, source: str) -> List[Finding]:
    """Lint one file's source; ``rel`` is its repo-relative posix path."""
    ctx = FileContext(rel=rel, source=source)
    return _Scanner(ctx).run()
