"""Twin-parity checker: structural equality of the NumPy / jnp samplers.

The engine's validity argument leans on *bit-identical twins*: every
counter-RNG sampler exists twice — a pure-NumPy reference in
:mod:`repro.core.events` and a jnp implementation in
:mod:`repro.kernels.sim_step` — and the waste optima are only validated
against simulation because both engines draw identical streams.  The
known-answer tests pin the pair dynamically; this pass pins it
*statically*: editing one twin without the other is a failure at
analysis time, with a unified diff of the divergent subtrees.

How it works
============

``TWIN_REGISTRY`` declares the pairs.  Each side is parsed (source only
— the NumPy side must stay importable without JAX, and nothing is
executed) and normalized modulo the known cross-dialect idioms:

- ``np`` / ``jnp`` / ``math`` namespace prefixes are stripped
  (``np.where`` ↔ ``jnp.where``), and ``_gamma`` ↔ ``math.gamma``
  canonicalize to one name;
- docstrings, annotations, defaults and decorators are dropped;
- ``with np.errstate(...):`` blocks are inlined (NumPy-only masking of
  intentional overflow in the integer mixers);
- dtype plumbing is erased: single-argument casts
  (``np.uint32(x)`` / ``dtype(x)``), ``asarray(x[, dtype])``,
  ``.astype(...)``, parameters and call arguments named ``dtype``;
- ``np.power(a, b)`` rewrites to ``a ** b``, ``np.pi`` substitutes its
  IEEE value, and literal arithmetic constant-folds (so
  ``2.0 * np.pi`` ↔ ``2.0 * 3.141592653589793`` agree);
- ``raise`` payloads are dropped (both sides must *fail* on the same
  branch, the message may differ) and post-normalization identity
  assignments (``k0 = k0``, the residue of an unwrapped ``asarray``
  coercion) are deleted.

What survives normalization is the computation's shape — operators,
operand order, control flow, select chains (including the dual-``where``
pow strength-reduction both sides mirror deliberately).  Any residual
difference is reported.

A second check keeps the registry itself honest: every twin function
must carry a ``# repro-twin: <dotted path of its counterpart>`` comment
above its ``def``, and the set of annotations in the twin modules must
match the registry exactly (both directions), so a new twin cannot land
annotated-but-unregistered or registered-but-unannotated.
"""

from __future__ import annotations

import ast
import difflib
import math
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["TwinPair", "TWIN_REGISTRY", "check_twins", "compare_pair"]

_TWIN_RE = re.compile(r"#\s*repro-twin:\s*([\w.]+)")

#: namespaces whose attribute access is a dialect detail, not structure
_NAMESPACES = {"np", "jnp", "numpy", "math", "lax"}

#: single-argument calls that are dtype coercions, not computation
_CASTS = {
    "uint8", "uint16", "uint32", "uint64",
    "int8", "int16", "int32", "int64",
    "float16", "float32", "float64", "bool_", "dtype",
}

_FOLD_BINOPS = {
    ast.Add: lambda a, b: a + b,
    ast.Sub: lambda a, b: a - b,
    ast.Mult: lambda a, b: a * b,
    ast.Div: lambda a, b: a / b,
    ast.Pow: lambda a, b: a ** b,
    ast.Mod: lambda a, b: a % b,
    ast.FloorDiv: lambda a, b: a // b,
    ast.LShift: lambda a, b: a << b,
    ast.RShift: lambda a, b: a >> b,
}


@dataclass(frozen=True)
class TwinPair:
    """One registered NumPy/jnp twin: module dotted paths + function names."""

    np_module: str
    np_func: str
    jnp_module: str
    jnp_func: str

    @property
    def label(self) -> str:
        return (
            f"{self.np_module}.{self.np_func} <-> "
            f"{self.jnp_module}.{self.jnp_func}"
        )


#: the declared twin registry — extend this when adding a sampler pair
#: (and annotate both defs with ``# repro-twin:``, see module docstring)
TWIN_REGISTRY: Tuple[TwinPair, ...] = (
    TwinPair("repro.core.events", "threefry2x32",
             "repro.kernels.sim_step", "threefry2x32"),
    TwinPair("repro.core.events", "splitmix64",
             "repro.kernels.sim_step", "splitmix64"),
    TwinPair("repro.core.events", "uniform24",
             "repro.kernels.sim_step", "uniform24"),
    TwinPair("repro.core.events", "gap_transform_np",
             "repro.kernels.sim_step", "gap_transform"),
    TwinPair("repro.core.events", "gap_transform_indexed_np",
             "repro.kernels.sim_step", "gap_transform_indexed"),
    # the differentiable analytic waste layer (branchless table models)
    TwinPair("repro.core.analytic", "precision_from_fp",
             "repro.kernels.analytic", "precision_from_fp"),
    TwinPair("repro.core.analytic", "young_waste",
             "repro.kernels.analytic", "young_waste"),
    TwinPair("repro.core.analytic", "exact_waste",
             "repro.kernels.analytic", "exact_waste"),
    TwinPair("repro.core.analytic", "migration_waste",
             "repro.kernels.analytic", "migration_waste"),
    TwinPair("repro.core.analytic", "instant_waste",
             "repro.kernels.analytic", "instant_waste"),
    TwinPair("repro.core.analytic", "nockpt_waste",
             "repro.kernels.analytic", "nockpt_waste"),
    TwinPair("repro.core.analytic", "withckpt_waste",
             "repro.kernels.analytic", "withckpt_waste"),
    TwinPair("repro.core.analytic", "two_level_waste",
             "repro.kernels.analytic", "two_level_waste"),
    TwinPair("repro.core.analytic", "silent_waste",
             "repro.kernels.analytic", "silent_waste"),
    TwinPair("repro.core.analytic", "cell_waste",
             "repro.kernels.analytic", "cell_waste"),
)


def _module_path(root: Path, dotted: str) -> Path:
    return root / "src" / Path(*dotted.split(".")).with_suffix(".py")


def _module_source(
    root: Path, dotted: str, sources: Optional[Dict[str, str]]
) -> str:
    if sources and dotted in sources:
        return sources[dotted]
    return _module_path(root, dotted).read_text()


def _find_function(source: str, name: str) -> Optional[ast.FunctionDef]:
    for node in ast.parse(source).body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


class _Normalize(ast.NodeTransformer):
    """Erase the np/jnp dialect differences listed in the module doc."""

    # -- namespaces and names ------------------------------------------

    def visit_Attribute(self, node: ast.Attribute):
        self.generic_visit(node)
        if isinstance(node.value, ast.Name) and node.value.id in _NAMESPACES:
            if node.attr == "pi":
                return ast.copy_location(ast.Constant(value=math.pi), node)
            return ast.copy_location(ast.Name(id=node.attr, ctx=node.ctx), node)
        return node

    def visit_Name(self, node: ast.Name):
        if node.id == "_gamma":
            return ast.copy_location(ast.Name(id="gamma", ctx=node.ctx), node)
        return node

    # -- calls: casts, asarray/astype, power, dtype plumbing -----------

    def visit_Call(self, node: ast.Call):
        self.generic_visit(node)
        node.args = [
            a for a in node.args
            if not (isinstance(a, ast.Name) and a.id == "dtype")
        ]
        node.keywords = [
            k for k in node.keywords
            if not (isinstance(k.value, ast.Name) and k.value.id == "dtype")
        ]
        fn = node.func
        # .astype(X) -> receiver
        if isinstance(fn, ast.Attribute) and fn.attr == "astype":
            return fn.value
        if isinstance(fn, ast.Name) and not node.keywords:
            if fn.id in ("asarray", "array") and 1 <= len(node.args) <= 2:
                return node.args[0]
            if fn.id in _CASTS and len(node.args) == 1:
                return node.args[0]
            if fn.id == "power" and len(node.args) == 2:
                return ast.copy_location(
                    ast.BinOp(
                        left=node.args[0], op=ast.Pow(), right=node.args[1]
                    ),
                    node,
                )
        return node

    # -- constant folding ----------------------------------------------

    def visit_BinOp(self, node: ast.BinOp):
        self.generic_visit(node)
        fold = _FOLD_BINOPS.get(type(node.op))
        if (
            fold is not None
            and isinstance(node.left, ast.Constant)
            and isinstance(node.right, ast.Constant)
            and isinstance(node.left.value, (int, float))
            and isinstance(node.right.value, (int, float))
        ):
            try:
                return ast.copy_location(
                    ast.Constant(value=fold(node.left.value, node.right.value)),
                    node,
                )
            except (ZeroDivisionError, OverflowError, ValueError):
                return node
        return node

    def visit_UnaryOp(self, node: ast.UnaryOp):
        self.generic_visit(node)
        if isinstance(node.operand, ast.Constant) and isinstance(
            node.operand.value, (int, float)
        ):
            if isinstance(node.op, ast.USub):
                return ast.copy_location(
                    ast.Constant(value=-node.operand.value), node
                )
            if isinstance(node.op, ast.UAdd):
                return node.operand
        return node

    # -- statements -----------------------------------------------------

    def visit_With(self, node: ast.With):
        self.generic_visit(node)
        if all(
            isinstance(item.context_expr, ast.Call)
            and isinstance(item.context_expr.func, ast.Name)
            and item.context_expr.func.id == "errstate"
            for item in node.items
        ):
            return node.body  # inline: NumPy-only overflow masking
        return node

    def visit_Assign(self, node: ast.Assign):
        self.generic_visit(node)
        if (
            len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and isinstance(node.value, ast.Name)
            and node.targets[0].id == node.value.id
        ):
            return None  # residue of an unwrapped asarray coercion
        return node

    def visit_Raise(self, node: ast.Raise):
        return ast.copy_location(ast.Raise(exc=None, cause=None), node)

    def visit_arg(self, node: ast.arg):
        node.annotation = None
        return node

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self.generic_visit(node)
        if (
            node.body
            and isinstance(node.body[0], ast.Expr)
            and isinstance(node.body[0].value, ast.Constant)
            and isinstance(node.body[0].value.value, str)
        ):
            node.body = node.body[1:] or [ast.Pass()]
        node.args.args = [a for a in node.args.args if a.arg != "dtype"]
        node.args.defaults = []
        node.args.kw_defaults = [None] * len(node.args.kwonlyargs)
        node.returns = None
        node.decorator_list = []
        return node


def normalize_function(fn: ast.FunctionDef, name: str) -> ast.FunctionDef:
    """Normalized deep copy of one twin's AST, renamed to ``name`` so the
    two sides of a pair compare under a common function name."""
    fn = ast.parse(ast.unparse(fn)).body[0]  # deep copy via round-trip
    assert isinstance(fn, ast.FunctionDef)
    fn.name = name
    out = _Normalize().visit(fn)
    ast.fix_missing_locations(out)
    return out


def compare_pair(
    root: Path,
    pair: TwinPair,
    sources: Optional[Dict[str, str]] = None,
) -> List[str]:
    """Check one registered pair; returns error strings (empty = parity).

    ``sources`` optionally overrides module sources by dotted path
    (used by the mutation tests to perturb one side in memory)."""
    errors: List[str] = []
    np_src = _module_source(root, pair.np_module, sources)
    jnp_src = _module_source(root, pair.jnp_module, sources)
    np_fn = _find_function(np_src, pair.np_func)
    jnp_fn = _find_function(jnp_src, pair.jnp_func)
    if np_fn is None:
        errors.append(
            f"{pair.label}: {pair.np_module}.{pair.np_func} not found"
        )
    if jnp_fn is None:
        errors.append(
            f"{pair.label}: {pair.jnp_module}.{pair.jnp_func} not found"
        )
    if errors:
        return errors
    a = normalize_function(np_fn, "twin")
    b = normalize_function(jnp_fn, "twin")
    if ast.dump(a) == ast.dump(b):
        return []
    diff = "\n".join(
        difflib.unified_diff(
            ast.unparse(a).splitlines(),
            ast.unparse(b).splitlines(),
            fromfile=f"{pair.np_module}.{pair.np_func} (normalized)",
            tofile=f"{pair.jnp_module}.{pair.jnp_func} (normalized)",
            lineterm="",
        )
    )
    return [
        f"{pair.label}: twins diverge structurally — edit both sides "
        f"together (or extend the normalizer for a new shared idiom)\n{diff}"
    ]


def _annotations(source: str) -> Dict[str, str]:
    """``# repro-twin:`` comments mapped ``func name -> counterpart``.

    A twin comment binds to the next ``def`` at most 3 lines below it
    (other directives / decorators may sit between)."""
    out: Dict[str, str] = {}
    lines = source.splitlines()
    for i, text in enumerate(lines):
        m = _TWIN_RE.search(text)
        if not m:
            continue
        for follow in lines[i + 1:i + 4]:
            dm = re.match(r"\s*def\s+(\w+)", follow)
            if dm:
                out[dm.group(1)] = m.group(1)
                break
    return out


def check_annotations(
    root: Path,
    registry: Sequence[TwinPair] = TWIN_REGISTRY,
    sources: Optional[Dict[str, str]] = None,
) -> List[str]:
    """Registry <-> ``# repro-twin:`` comment consistency, both ways."""
    errors: List[str] = []
    modules = {p.np_module for p in registry} | {p.jnp_module for p in registry}
    annotated = {
        mod: _annotations(_module_source(root, mod, sources))
        for mod in modules
    }
    expected: Dict[str, Dict[str, str]] = {mod: {} for mod in modules}
    for p in registry:
        expected[p.np_module][p.np_func] = f"{p.jnp_module}.{p.jnp_func}"
        expected[p.jnp_module][p.jnp_func] = f"{p.np_module}.{p.np_func}"
    for mod in sorted(modules):
        got, want = annotated[mod], expected[mod]
        for func in sorted(set(want) - set(got)):
            errors.append(
                f"{mod}.{func}: registered twin is missing its "
                f"'# repro-twin: {want[func]}' comment"
            )
        for func in sorted(set(got) - set(want)):
            errors.append(
                f"{mod}.{func}: '# repro-twin:' comment on an "
                "unregistered function — add it to TWIN_REGISTRY"
            )
        for func in sorted(set(got) & set(want)):
            if got[func] != want[func]:
                errors.append(
                    f"{mod}.{func}: twin comment names {got[func]!r} "
                    f"but the registry pairs it with {want[func]!r}"
                )
    return errors


def check_twins(
    root: Path,
    registry: Sequence[TwinPair] = TWIN_REGISTRY,
    sources: Optional[Dict[str, str]] = None,
) -> List[str]:
    """Run the full twin-parity pass; returns error strings (empty = OK)."""
    errors = check_annotations(root, registry, sources)
    for pair in registry:
        errors.extend(compare_pair(root, pair, sources))
    return errors
