"""jaxpr auditor: abstract-eval contracts of the fused engine dispatch.

The third ``repro-lint`` pass traces the engine's *actual* jitted entry
points — nothing executes, no kernel launches — and checks the
machine-readable contracts the paper-grid validity argument rests on:

- **dtype schema**: every output leaf carries exactly the dtype the
  declared schema (:mod:`repro.analysis.schema`) assigns its role — the
  working float is uniformly ``float64`` in x64 mode, counters ``int64``,
  the phase machine ``int32`` — and no output is weakly typed;
- **no silent promotions**: the trace contains no ``float32`` avals (in
  x64 mode) and no float-to-float ``convert_element_type`` — the
  fingerprints of a literal or intermediate silently widening/narrowing
  the comparison boundary the analytic z-tests depend on;
- **donation**: the per-chunk state buffers declared in
  ``donate_argnums`` really are donated in the lowering (the chunk loop
  would otherwise double its device footprint);
- **O(cells) stats**: a ``collect="stats"`` dispatch returns only the
  ``(n_cells, 11)`` accumulator — no output dimension equals the padded
  lane count, so per-lane state provably never crosses to host;
- **one executable**: a mixed-law grid in device trace mode reuses ONE
  compiled runner across every chunk (the law-indexed sampler fuses the
  families; per-family dispatch would show distinct runners).

Capture works by intercepting ``repro.core.jax_sim._dispatch``: the
engine's own packing code builds the real ``(consts, state)`` chunk,
the spy grabs the jitted runner plus its arguments and aborts (lanes
mode) or passes the untouched accumulator through (stats mode, so the
chunk loop and the mixed-law sweep complete without running XLA).
``audit_callable`` exposes the same checks for arbitrary functions —
the test suite uses it to prove seeded violations (an injected f32
round-trip, a host ``np.asarray`` of a tracer) are caught.
"""

from __future__ import annotations

import contextlib
import warnings
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .schema import OUT_SCHEMA, STATE_SCHEMA, resolve_role

__all__ = [
    "AuditReport",
    "audit_callable",
    "audit_engine",
    "audit_mixed_law",
    "run_audit",
]


@dataclass
class AuditReport:
    """Outcome of one audit: the entry label, failures, and passed checks."""

    label: str
    errors: List[str] = field(default_factory=list)
    passed: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.errors

    def format(self) -> str:
        lines = [f"[jaxpr-audit] {self.label}: "
                 f"{'OK' if self.ok else 'FAIL'}"]
        lines += [f"  pass: {c}" for c in self.passed]
        lines += [f"  FAIL: {e}" for e in self.errors]
        return "\n".join(lines)


class _AuditDone(Exception):
    """Abort the engine's chunk loop once the dispatch is captured."""


@dataclass
class _Capture:
    runner: object
    devs: tuple
    consts: dict
    state: dict
    acc: tuple


# --------------------------------------------------------------------- #
# jaxpr / lowering checks
# --------------------------------------------------------------------- #
def _iter_eqns(jaxpr):
    """All equations, recursing into call/scan/while sub-jaxprs."""
    from jax.core import ClosedJaxpr, Jaxpr

    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            subs = v if isinstance(v, (tuple, list)) else (v,)
            for sub in subs:
                if isinstance(sub, ClosedJaxpr):
                    yield from _iter_eqns(sub.jaxpr)
                elif isinstance(sub, Jaxpr):
                    yield from _iter_eqns(sub)


def _check_trace_dtypes(jaxpr, fdt: np.dtype) -> Tuple[List[str], List[str]]:
    """No banned-float avals, no float<->float convert_element_type."""
    errors: List[str] = []
    passed: List[str] = []
    banned = np.dtype(np.float32) if fdt == np.float64 else None
    n_bad_avals = 0
    n_bad_convert = 0
    for eqn in _iter_eqns(jaxpr):
        for var in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(var, "aval", None)
            dt = getattr(aval, "dtype", None)
            if banned is not None and dt == banned:
                n_bad_avals += 1
                if n_bad_avals <= 3:
                    errors.append(
                        f"float32 aval in an x64 trace: {eqn.primitive.name} "
                        f"operates on {aval}"
                    )
        if eqn.primitive.name == "convert_element_type":
            src = eqn.invars[0].aval.dtype
            dst = eqn.params.get("new_dtype")
            if (
                np.issubdtype(src, np.floating)
                and dst is not None
                and np.issubdtype(np.dtype(dst), np.floating)
                and np.dtype(dst) != src
            ):
                n_bad_convert += 1
                if n_bad_convert <= 3:
                    errors.append(
                        f"float->float convert_element_type {src} -> "
                        f"{np.dtype(dst)} (silent precision change)"
                    )
    if n_bad_avals > 3:
        errors.append(f"... {n_bad_avals - 3} more float32 avals")
    if n_bad_convert > 3:
        errors.append(f"... {n_bad_convert - 3} more float converts")
    if not n_bad_avals:
        passed.append("no float32 avals in the x64 trace")
    if not n_bad_convert:
        passed.append("no float<->float convert_element_type")
    return errors, passed


def _check_out_leaves(
    out_shapes, fdt: np.dtype, idt: np.dtype
) -> Tuple[List[str], List[str]]:
    """Output dtype schema + weak-type check over an eval_shape pytree."""
    import jax

    errors: List[str] = []
    passed: List[str] = []
    allowed = {
        fdt, idt, np.dtype(np.int32), np.dtype(bool),
        np.dtype(np.uint32), np.dtype(np.uint64),
    }
    leaves_with_path = jax.tree_util.tree_flatten_with_path(out_shapes)[0]
    n_weak = n_dtype = n_schema = 0
    for path, leaf in leaves_with_path:
        name = jax.tree_util.keystr(path)
        key = name.strip("[]'\"").split("'")[-1] if name else name
        if getattr(leaf, "weak_type", False):
            n_weak += 1
            errors.append(f"output {name} is weakly typed ({leaf.dtype})")
        if leaf.dtype not in allowed:
            n_dtype += 1
            errors.append(
                f"output {name} dtype {leaf.dtype} outside the engine's "
                f"schema universe {sorted(str(d) for d in allowed)}"
            )
        role = STATE_SCHEMA.get(key) or OUT_SCHEMA.get(key)
        if role is not None:
            want = resolve_role(role, x64=fdt == np.float64)
            if leaf.dtype != want:
                n_schema += 1
                errors.append(
                    f"output {name} is {leaf.dtype}, schema role "
                    f"{role!r} requires {want}"
                )
    if not n_weak:
        passed.append("no weak-typed outputs")
    if not n_dtype:
        passed.append("all output dtypes inside the schema universe")
    if not n_schema:
        passed.append("schema-named outputs match their declared role")
    return errors, passed


def _check_donation(lowered, donated_names: str) -> Tuple[List[str], List[str]]:
    """Donation declared in donate_argnums must survive into the lowering."""
    try:
        text = lowered.as_text()
    except Exception as exc:  # pragma: no cover - lowering always works on CPU
        return [f"could not lower for donation check: {exc}"], []
    if "tf.aliasing_output" in text or "jax.buffer_donor" in text:
        return [], [f"{donated_names} buffers marked donated in the lowering"]
    return [
        f"donate_argnums declared for {donated_names} but the lowering "
        "carries no tf.aliasing_output / jax.buffer_donor marks"
    ], []


def audit_callable(
    fn: Callable,
    *args,
    label: str = "callable",
    fdt=np.float64,
    idt=np.int64,
    expect_donation: Optional[str] = None,
    check_outputs: bool = True,
) -> AuditReport:
    """Trace ``fn`` abstractly (under x64 if ``fdt`` is float64) and run
    the dtype/promotion/donation checks.  ``fn`` may already be jitted;
    plain callables are wrapped.  Nothing executes."""
    import jax

    report = AuditReport(label=label)
    fdt, idt = np.dtype(fdt), np.dtype(idt)
    ctx = contextlib.nullcontext()
    if fdt == np.float64 and not jax.config.jax_enable_x64:
        from jax.experimental import enable_x64

        ctx = enable_x64()
    jitted = fn if hasattr(fn, "trace") else jax.jit(fn)
    with ctx:
        try:
            traced = jitted.trace(*args)
        except Exception as exc:
            report.errors.append(
                f"abstract trace failed ({type(exc).__name__}): {exc}"
            )
            return report
        report.passed.append("abstract trace succeeded (no host transfer)")
        errs, ok = _check_trace_dtypes(traced.jaxpr.jaxpr, fdt)
        report.errors += errs
        report.passed += ok
        if check_outputs:
            errs, ok = _check_out_leaves(jax.eval_shape(jitted, *args), fdt, idt)
            report.errors += errs
            report.passed += ok
        if expect_donation is not None:
            errs, ok = _check_donation(traced.lower(), expect_donation)
            report.errors += errs
            report.passed += ok
    return report


# --------------------------------------------------------------------- #
# engine entry points
# --------------------------------------------------------------------- #
def _small_problem(trace_mode: str):
    from repro.core import Platform, PredictorModel
    from repro.core import events as E
    from repro.core import simulator as S

    mn = 60.0
    plat = Platform(mu=1000 * mn, C=10 * mn, D=1 * mn, R=10 * mn, M=5 * mn)
    work = 8 * 86400.0
    pred = PredictorModel(recall=0.85, precision=0.82, window=3000.0)
    strat = S.instant(plat, pred)
    kw = {
        "horizon": 12 * work, "mtbf": plat.mu, "recall": pred.recall,
        "precision": pred.precision, "window": pred.window,
        "lead": pred.lead, "fault_dist": E.exponential(),
    }
    if trace_mode == "device":
        traces = E.make_trace_spec(
            8, seed=7, cell_index=np.zeros(8, np.int32), **kw
        )
    else:
        traces = E.make_event_traces_batch(np.random.default_rng(7), 8, **kw)
    return work, plat, strat, traces


@contextlib.contextmanager
def _spy_dispatch(captures: list, passthrough: bool):
    """Swap ``jax_sim._dispatch`` for a capturing spy.

    ``passthrough=False`` raises :class:`_AuditDone` after the first
    capture (lanes mode: nothing fabricates per-lane results);
    ``passthrough=True`` returns the accumulator untouched so the chunk
    loop — and a whole ``run_grid`` sweep — completes without ever
    executing a compiled program."""
    from repro.core import jax_sim

    orig = jax_sim._dispatch

    def spy(runner, devs, consts, state, *acc):
        captures.append(_Capture(runner, devs, consts, state, acc))
        if passthrough and acc:
            return acc[0]
        raise _AuditDone

    jax_sim._dispatch = spy
    try:
        yield
    finally:
        jax_sim._dispatch = orig


def audit_engine(collect: str = "lanes", trace_mode: str = "device") -> AuditReport:
    """Audit one ``simulate_batch_jax`` entry point abstractly."""
    from repro.core.jax_sim import simulate_batch_jax

    label = f"simulate_batch_jax collect={collect} trace_mode={trace_mode}"
    work, plat, strat, traces = _small_problem(trace_mode)
    captures: List[_Capture] = []
    want_stats = collect == "stats"
    with _spy_dispatch(captures, passthrough=want_stats):
        try:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")  # zeroed stats -> 0/0 noise
                simulate_batch_jax(
                    work, plat, strat, traces, collect=collect, chunk=None,
                )
        except _AuditDone:
            pass
    if not captures:
        return AuditReport(label, errors=["engine never reached _dispatch"])
    cap = captures[0]
    args = (cap.consts, cap.state) + cap.acc
    donated = "state+accumulator" if want_stats else "state"
    report = audit_callable(
        cap.runner, *args, label=label, expect_donation=donated,
    )
    if want_stats:
        import jax

        n_pad = cap.state["t"].shape[0]
        out = jax.eval_shape(cap.runner, *args)
        dims = {
            d
            for leaf in jax.tree_util.tree_leaves(out)
            for d in getattr(leaf, "shape", ())
        }
        if n_pad in dims:
            report.errors.append(
                f"collect='stats' output carries a lane-sized dimension "
                f"({n_pad}): per-lane state would cross to host"
            )
        else:
            report.passed.append(
                f"stats output is O(cells): no dimension equals the "
                f"padded lane count {n_pad}"
            )
    return report


def audit_mixed_law(n_runs: int = 128, chunk_lanes: int = 128) -> AuditReport:
    """A mixed-law paper-grid sweep must compile exactly one executable.

    Runs ``run_grid`` (device trace mode, fused dispatch, stats
    collection) over three cells with three different failure laws, with
    the dispatch spied out — every chunk's runner is recorded and no XLA
    program executes.  Per-family dispatch would surface distinct jitted
    runners; the law-indexed fused grid reuses one."""
    import dataclasses

    from repro.core import events as E
    from repro.core.engine import EngineConfig
    from repro.experiments.grid import GridSpec
    from repro.experiments.paper_grid import paper_grid_cells
    from repro.experiments.runner import run_grid

    label = "run_grid mixed-law device-trace fused dispatch"
    report = AuditReport(label)
    dists = [E.exponential(), E.weibull(0.7), E.lognormal(1.0)]
    # non-migration cells only: the engine legitimately specializes
    # has_migration per chunk, which is orthogonal to law fusion
    base = [c for c in paper_grid_cells("bench") if "Migration" not in c.label]
    cells = [
        dataclasses.replace(c, fault_dist=d) for c, d in zip(base, dists)
    ]
    grid = GridSpec(tuple(cells), n_runs=n_runs, seed=3)
    captures: List[_Capture] = []
    with _spy_dispatch(captures, passthrough=True):
        try:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")  # zeroed stats -> 0/0 noise
                run_grid(
                    grid,
                    EngineConfig(
                        engine="jax", trace_mode="device",
                        collect="stats", chunk_lanes=chunk_lanes,
                    ),
                )
        except Exception as exc:
            # aggregation of the all-zero spy statistics may trip
            # downstream sanity checks; the dispatch pattern is already
            # recorded by then, which is all this audit needs
            if not captures:
                report.errors.append(f"sweep failed before dispatch: {exc}")
                return report
    if len(captures) < 2:
        report.errors.append(
            f"expected multiple chunks (got {len(captures)} dispatches); "
            "shrink chunk_lanes so the one-executable claim is exercised"
        )
        return report
    runners = {id(c.runner) for c in captures}
    if len(runners) > 1:
        report.errors.append(
            f"mixed-law sweep used {len(runners)} distinct compiled "
            f"runners across {len(captures)} dispatches — the law-indexed "
            "grid must lower to exactly one executable"
        )
    else:
        report.passed.append(
            f"one executable across {len(captures)} mixed-law chunk "
            "dispatches (3 failure-law families)"
        )
    return report


def run_audit() -> List[AuditReport]:
    """The full jaxpr pass: both collects, both trace modes, mixed-law."""
    return [
        audit_engine("lanes", "device"),
        audit_engine("lanes", "host"),
        audit_engine("stats", "device"),
        audit_mixed_law(),
    ]
