"""``repro.analysis`` — static-analysis suite for the engine's invariants.

Three passes, one CLI (``python -m repro.analysis``), zero execution of
engine code in the lint/twin passes and abstract evaluation only in the
jaxpr pass:

1. **AST lint** (:mod:`.rules`, :mod:`.linter`) — repo-specific rules
   over ``src/`` and ``benchmarks/``:

   ========================  ==================================================
   rule id                   enforces
   ========================  ==================================================
   ``host-sync``             no ``jax.device_get`` / ``.item()`` /
                             ``.tolist()`` / ``.block_until_ready()`` /
                             ``.copy_to_host_async()`` / ``float(tracer)`` /
                             ``np.asarray(tracer)`` outside the allowlisted
                             host boundary (``benchmarks/``,
                             ``experiments/runner.py``)
   ``twin-import``           no ``jax`` imports in the NumPy-twin modules
                             (``core/events.py``, ``core/batch_sim.py``)
   ``np-in-jit``             no host-NumPy compute inside jit-traced bodies
                             (dtype/constant references allowed)
   ``tracer-branch``         no Python ``if``/``while``/``assert`` on
                             tracer-valued names inside jit-traced bodies
   ``unseeded-rng``          no global-state ``np.random.*``; seeded
                             ``default_rng`` only
   ``kernel-dtype``          kernel code (``src/repro/kernels/``) is
                             dtype-explicit: no ``float64`` literals, no
                             module-level bare float constants, no
                             ``jnp.asarray``/``array``/``full`` without dtype
   ========================  ==================================================

   Escape hatches: ``# repro-lint: disable=RULE`` on the offending line,
   ``# repro-lint: jit-root`` marks functions traced via
   ``functools.partial`` indirection, and the checked-in
   ``LINT_BASELINE.json`` records deliberate findings (with one-line
   justifications) so only *new* findings fail.

2. **Twin parity** (:mod:`.twins`) — the declared NumPy/jnp sampler
   registry, compared structurally modulo the known dialect idioms;
   editing one twin without the other fails with a unified diff.  Twin
   defs carry ``# repro-twin: <counterpart>`` comments, cross-checked
   against the registry in both directions.

3. **jaxpr audit** (:mod:`.jaxpr_audit`) — abstract-evals the fused
   engine dispatch and checks the dtype schema (:mod:`.schema`),
   weak-type and float-promotion freedom, buffer donation, O(cells)
   stats outputs, and the mixed-law one-executable property.

CLI::

    python -m repro.analysis --all               # every pass; exit != 0 on findings
    python -m repro.analysis --lint              # AST lint vs baseline
    python -m repro.analysis --lint --write-baseline
    python -m repro.analysis --twins             # twin-parity only
    python -m repro.analysis --jaxpr             # jaxpr audit only
    python -m repro.analysis --all --out report.json
"""

from .jaxpr_audit import AuditReport, audit_callable, run_audit
from .linter import lint_tree, load_baseline, partition_findings, repo_root
from .rules import RULES, Finding, scan_source
from .schema import OUT_SCHEMA, STATE_SCHEMA, resolve_role
from .twins import TWIN_REGISTRY, TwinPair, check_twins

__all__ = [
    "AuditReport",
    "Finding",
    "OUT_SCHEMA",
    "RULES",
    "STATE_SCHEMA",
    "TWIN_REGISTRY",
    "TwinPair",
    "audit_callable",
    "check_twins",
    "lint_tree",
    "load_baseline",
    "partition_findings",
    "repo_root",
    "resolve_role",
    "run_audit",
    "run_all",
    "scan_source",
]


def run_all(root=None, jaxpr: bool = True):
    """Run every pass; returns ``(exit_code, report_dict)``.

    ``report_dict`` is JSON-serializable (the CI artifact).  Exit code 0
    iff there are no new lint findings, no twin divergences, and every
    jaxpr audit passes."""
    root = repo_root() if root is None else root
    findings = lint_tree(root)
    new, baselined, stale = partition_findings(findings, load_baseline(root))
    twin_errors = check_twins(root)
    audits = run_audit() if jaxpr else []
    report = {
        "lint": {
            "new": [f.format() for f in new],
            "baselined": [f.format() for f in baselined],
            "stale_baseline_entries": [
                f"{e.get('path')}: [{e.get('rule')}] {e.get('line_text')}"
                for e in stale
            ],
        },
        "twins": {"errors": twin_errors},
        "jaxpr": {
            "reports": [
                {"label": r.label, "ok": r.ok, "errors": r.errors,
                 "passed": r.passed}
                for r in audits
            ],
        },
    }
    bad = bool(new) or bool(twin_errors) or any(not r.ok for r in audits)
    return (1 if bad else 0), report
