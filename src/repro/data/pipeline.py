"""Deterministic synthetic LM data pipeline.

Properties a real pipeline needs and tests rely on:

* **Deterministic resume** — ``batch(step)`` is a pure function of
  ``(seed, step, shard)``, so restarting from a checkpoint at step k replays
  exactly the same stream (validated in test_ft_executor.py: the loss
  trajectory after an injected fault matches the fault-free run).
* **Sharded** — each data-parallel rank materializes only its slice of the
  global batch.
* **Prefetch** — a background thread keeps a bounded queue of ready batches
  so host time hides behind device time.

Tokens are drawn from a counter-mode Philox stream (``np.random.Generator``
re-keyed per (seed, step)), with a Zipf-ish skew so losses are non-trivial.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

__all__ = ["SyntheticLMDataset", "PrefetchIterator"]


@dataclass(frozen=True)
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0
    frontend_prefix: int = 0
    d_model: int = 0  # only needed when frontend_prefix > 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard])
        )
        # zipf-ish marginal over the vocab for a non-flat loss surface
        u = rng.random((self.local_batch, self.seq_len))
        tokens = (
            (self.vocab_size ** u - 1.0) / (self.vocab_size - 1) * self.vocab_size
        ).astype(np.int32) % self.vocab_size
        out = {"tokens": tokens}
        if self.frontend_prefix:
            out["frontend"] = rng.standard_normal(
                (self.local_batch, self.frontend_prefix, self.d_model), np.float32
            ).astype(np.float32) * 0.02
        return out


class PrefetchIterator:
    """Background-thread prefetch over ``dataset.batch(step)``."""

    def __init__(
        self, dataset: SyntheticLMDataset, start_step: int = 0, depth: int = 2
    ):
        self.dataset = dataset
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.dataset.batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def __iter__(self) -> Iterator:
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
