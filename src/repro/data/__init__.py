"""Data substrate: deterministic synthetic LM pipeline with host prefetch."""

from .pipeline import SyntheticLMDataset, PrefetchIterator

__all__ = ["SyntheticLMDataset", "PrefetchIterator"]
