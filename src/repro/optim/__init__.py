"""Optimizer substrate: AdamW (+8-bit moments) and gradient compression."""

from .adamw import AdamWState, adamw_init, adamw_update, cosine_schedule
from .compress import compress_gradients, decompress_gradients

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "compress_gradients",
    "decompress_gradients",
]
