"""AdamW with optional 8-bit (blockwise-quantized) moments.

The 8-bit variant stores m/v as int8 with per-block float32 absmax scales
(block = 256 elements along the flattened tensor), the standard
memory-for-precision trade that brings the 400B-class archs (arctic,
jamba-1.5-large) under the 16 GB/chip HBM budget at 256 chips — see
DESIGN.md and the roofline memory terms.

State layout (a pytree mirroring params):
    fp32:  {"m": f32[shape], "v": f32[shape]}
    int8:  {"m_q": i8[shape], "m_s": f32[nblocks], "v_q": ..., "v_s": ...}
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "global_norm",
]

_BLOCK = 256


class AdamWState(NamedTuple):
    step: jax.Array
    moments: Any  # pytree of per-param moment dicts


# --------------------------------------------------------------------------- #
# blockwise int8 quantization — along each tensor's LAST dim, keeping the
# parameter layout.  A flat (n,)-layout would require sharded<->flat
# reshapes that GSPMD resolves by replicating the f32 moments (measured:
# 3.5 TB/device on arctic-480b).  Here q has the param's own shape (and
# sharding); scales are tiny (1/256) and effectively replicated.  The
# per-block max uses reduce_window so no reshape ever touches the sharded
# tensor.
# --------------------------------------------------------------------------- #
def _n_blocks(last: int) -> int:
    return (last + _BLOCK - 1) // _BLOCK


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: f32[..., L] -> (int8[..., L], f32[..., ceil(L/256)])."""
    if x.ndim == 0:
        x = x[None]
    L = x.shape[-1]
    pad = _n_blocks(L) * _BLOCK - L
    window = (1,) * (x.ndim - 1) + (_BLOCK,)
    scale = jax.lax.reduce_window(
        jnp.abs(x),
        -jnp.inf,
        jax.lax.max,
        window_dimensions=window,
        window_strides=window,
        padding=[(0, 0)] * (x.ndim - 1) + [(0, pad)],
    ) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    scale_exp = jnp.repeat(scale, _BLOCK, axis=-1)[..., :L]
    q = jnp.clip(jnp.round(x / scale_exp), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, shape=None) -> jax.Array:
    L = q.shape[-1]
    scale_exp = jnp.repeat(scale, _BLOCK, axis=-1)[..., :L]
    out = q.astype(jnp.float32) * scale_exp
    if shape is not None:
        out = out.reshape(shape)
    return out


# --------------------------------------------------------------------------- #
# init / update
# --------------------------------------------------------------------------- #
def adamw_init(params, quantize: bool = False) -> AdamWState:
    def leaf(p):
        if quantize:
            shape = p.shape if p.ndim else (1,)
            s_shape = shape[:-1] + (_n_blocks(shape[-1]),)
            return {
                "m_q": jnp.zeros(shape, jnp.int8),
                "m_s": jnp.zeros(s_shape, jnp.float32),
                "v_q": jnp.zeros(shape, jnp.int8),
                "v_s": jnp.zeros(s_shape, jnp.float32),
            }
        return {
            "m": jnp.zeros(p.shape, jnp.float32),
            "v": jnp.zeros(p.shape, jnp.float32),
        }

    return AdamWState(
        step=jnp.zeros((), jnp.int32), moments=jax.tree.map(leaf, params)
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree))
    )


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: Optional[float] = 1.0,
):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mom):
        g = g.astype(jnp.float32)
        if "m" in mom:
            m = b1 * mom["m"] + (1 - b1) * g
            v = b2 * mom["v"] + (1 - b2) * jnp.square(g)
            new_mom = {"m": m, "v": v}
        else:
            gq = g if g.ndim else g[None]
            m_prev = _dequantize(mom["m_q"], mom["m_s"])
            v_prev = _dequantize(mom["v_q"], mom["v_s"])
            m = b1 * m_prev + (1 - b1) * gq
            v = b2 * v_prev + (1 - b2) * jnp.square(gq)
            mq, ms = _quantize(m)
            vq, vs = _quantize(v)
            new_mom = {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
            m = m.reshape(p.shape)
            v = v.reshape(p.shape)
        m_hat = m / c1
        v_hat = v / c2
        delta = m_hat / (jnp.sqrt(v_hat) + eps)
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (delta + weight_decay * p32)
        return new_p.astype(p.dtype), new_mom

    def upd_leaf(p, g, mom):
        # giant stacked-layer leaves (hundreds of GB global) update via a
        # scan over the layer dim so the transient f32 m/v copies are one
        # layer slice, not the whole stack
        if p.ndim >= 2 and p.size >= (1 << 29):
            return jax.lax.map(lambda a: upd(*a), (p, g, mom))
        return upd(p, g, mom)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.moments)
    out = [upd_leaf(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_moments = tdef.unflatten([o[1] for o in out])
    return new_params, AdamWState(step, new_moments), {"grad_norm": gnorm}


def cosine_schedule(
    step, base_lr: float, warmup: int = 100, total: int = 10000, floor: float = 0.1
):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor * base_lr + (1 - floor) * base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)
