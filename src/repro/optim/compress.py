"""Int8 gradient compression with error feedback (distributed-optimization
trick for the DP all-reduce).

Classic EF-SGD/1-bit-Adam structure: the *transmitted* gradient is an int8
blockwise quantization of (gradient + residual); the quantization error is
carried to the next step.  Under GSPMD the data-parallel reduction of a jit
train step is implicit, so the wire-format win is realized via the explicit
``shard_map`` reduction in :func:`dp_allreduce_int8`; the pure functions
here are also used by the checkpoint codec tests and the convergence test.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "compress_gradients",
    "decompress_gradients",
    "ef_compress_step",
    "dp_allreduce_int8",
]

_BLOCK = 256


def _blockwise(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def compress_gradients(tree):
    """tree of f32/bf16 -> tree of (int8 blocks, f32 scales)."""
    return jax.tree.map(lambda g: _blockwise(g.astype(jnp.float32)), tree)


def decompress_gradients(ctree, shapes_tree):
    def leaf(c, ref):
        q, s = c
        x = (q.astype(jnp.float32) * s[:, None]).reshape(-1)
        n = ref.size
        return x[:n].reshape(ref.shape)

    return jax.tree.map(
        leaf, ctree, shapes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


def ef_compress_step(grads, residual):
    """Error-feedback compression: returns (decompressed grads, new residual).

    residual has the same structure/shapes as grads (zeros at step 0)."""
    def leaf(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = _blockwise(g32)
        deq = (q.astype(jnp.float32) * s[:, None]).reshape(-1)[: g.size].reshape(
            g.shape
        )
        return deq.astype(g.dtype), (g32 - deq).astype(jnp.float32)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    out = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def dp_allreduce_int8(x: jax.Array, mesh, axis: str = "data") -> jax.Array:
    """All-reduce over the data axis moving int8 on the wire.

    shard_map kernel: quantize the local shard -> psum the int8 payload as
    int32 partial sums of dequantized blocks is NOT int8 on the wire, so we
    instead all_gather the (int8, scale) pairs and reduce locally: wire
    bytes = (N-1)/N * (1 byte + 4/256) per element versus 2x4 bytes for a
    ring all-reduce of f32 — a ~7x wire reduction at the cost of a local
    N-way sum."""

    def kern(xs):
        q, s = _blockwise(xs)
        qg = jax.lax.all_gather(q, axis)  # (N, blocks, BLOCK) int8
        sg = jax.lax.all_gather(s, axis)
        deq = qg.astype(jnp.float32) * sg[..., None]
        total = deq.sum(axis=0).reshape(-1)[: xs.size].reshape(xs.shape)
        return total

    from jax.experimental.shard_map import shard_map

    spec = P(axis)
    return shard_map(
        kern, mesh=mesh, in_specs=(spec,), out_specs=spec, check_rep=False
    )(x)
