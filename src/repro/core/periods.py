"""Optimal checkpointing periods (Sections 3.3, 3.4, 4.3 of the paper).

The central result implemented here is the *unified period formula*

    T_extr^{q} = sqrt( 2 mu C / (1 - r q) )

which extends Young's T = sqrt(2 mu C) (and Daly's variant) to platforms
with a fault predictor of recall ``r`` trusted with probability ``q``,
together with the case analyses that clamp the period to its admissible
domain and the proof-backed fact that the optimal ``q`` is always 0 or 1
(the waste is affine in ``q``).

Public API note: the per-strategy ``optimize_*`` case analyses, the
``t_*`` period helpers and ``best_policy`` are **deprecated aliases** —
:func:`repro.core.optimize` (see :mod:`repro.core.analytic`) is the one
entry point, covering the same closed forms (``method="analytic"``),
the batched on-device Newton solver (``method="newton"``) and the
simulated brute force (``method="search"``).  The implementations live
on here as the underscore-prefixed functions the unified optimizer
dispatches to.

Dtype contract: every function here is scalar ``float`` — IEEE doubles
via ``math.*``, the analytic layer's schema role ``"fdt"`` (see
:mod:`repro.analysis.schema`).  The :mod:`.waste` formulas these optima
feed are the broadcastable (``FloatLike``) counterparts; the jaxpr
auditor checks the simulated side of the comparison keeps the same
precision.
"""

from __future__ import annotations

import functools
import math
import warnings
from dataclasses import dataclass
from typing import Optional, Tuple

from . import waste as W
from .events import mu_e as _mu_e

__all__ = [
    "t_extr",
    "t_young",
    "t_daly",
    "t_one",
    "t_p_extr",
    "t_p_opt",
    "OptimalPolicy",
    "optimize_exact",
    "optimize_migration",
    "optimize_instant",
    "optimize_nockpt",
    "optimize_withckpt",
    "best_policy",
    "nockpt_dominates",
    "two_level_periods",
    "silent_period",
]


# --------------------------------------------------------------------------- #
# Extremal and clamped periods
# --------------------------------------------------------------------------- #
def _t_extr(mu: float, C: float, r: float = 0.0, q: float = 0.0) -> float:
    """Unified extremal period T_extr^{q} = sqrt(2 mu C / (1 - r q)).

    For r q -> 1 the period diverges: the predictor catches every fault and
    is always trusted, so periodic checkpointing is useless (the caller
    clamps to the admissible domain).
    """
    denom = 1.0 - r * q
    if denom <= 0.0:
        return math.inf
    return math.sqrt(2.0 * mu * C / denom)


def _t_young(mu: float, C: float, alpha: float = W.ALPHA) -> float:
    """T_Y = min(alpha mu, max(sqrt(2 mu C), C)) (Section 3.3).

    Degenerate platforms where alpha*mu < C have an empty validity domain;
    C is the least-bad admissible period (waste ~= 1 regardless)."""
    return max(C, min(alpha * mu, max(math.sqrt(2.0 * mu * C), C)))


def _t_daly(mu: float, R: float, C: float) -> float:
    """Daly's first-order refinement T = sqrt(2 (mu + R) C) [Daly 2004]."""
    return math.sqrt(2.0 * (mu + R) * C)


def _t_one(
    mu: float,
    C: float,
    r: float,
    p: float,
    I: float = 0.0,
    alpha: float = W.ALPHA,
) -> float:
    """T_1 = min(alpha mu_e - I, max(sqrt(2 mu C / (1 - r)), C)).

    The upper clamp uses the mean time between *events* (predictions of any
    kind + unpredicted faults) minus the window length, per Section 4.3.
    For I = 0 this is the Section 3.3 domain.
    """
    cap = alpha * _mu_e(mu, r, p) - I
    cap = max(cap, C)  # degenerate platforms: keep the domain non-empty
    return min(cap, max(_t_extr(mu, C, r, 1.0), C))


def _t_p_extr(C: float, p: float, I: float, E_f: Optional[float] = None) -> float:
    """Equation (7): T_P^extr = sqrt( ((1-p) I + p E_I^f) / p * C )."""
    if E_f is None:
        E_f = I / 2.0
    K = ((1.0 - p) * I + p * E_f) / p
    return math.sqrt(K * C)


def _t_p_opt(
    C: float, p: float, I: float, E_f: Optional[float] = None
) -> Optional[Tuple[float, int]]:
    """Integer-partition proactive period (Section 4.3).

    Returns ``(T_P, k)`` with ``k = I / T_P`` integer and ``T_P >= C``
    minimizing WASTE_{T_P} = K C / T_P + T_P, or ``None`` when the window
    cannot hold a checkpoint (I < C).
    """
    if E_f is None:
        E_f = I / 2.0
    if I < C or I <= 0.0:
        return None
    K = ((1.0 - p) * I + p * E_f) / p
    te = _t_p_extr(C, p, I, E_f)

    def cost(tp: float) -> float:
        return K * C / tp + tp

    k_lo = max(1, math.floor(I / te)) if te > 0 else 1
    candidates = []
    for k in {k_lo, k_lo + 1}:
        tp = I / k
        if tp >= C:
            candidates.append((cost(tp), tp, k))
    if not candidates:
        # every candidate shorter than C: largest feasible k with I/k >= C
        k = max(1, math.floor(I / C))
        tp = I / k
        candidates.append((cost(tp), tp, k))
    _, tp, k = min(candidates)
    return tp, k


# --------------------------------------------------------------------------- #
# Full policy optimization
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class OptimalPolicy:
    """Result of a waste minimization: the strategy's operating point.

    ``objective`` / ``value`` record what the unified optimizer was asked
    to optimize ("waste" minimization or "availability" = 1 - waste
    maximization — same argmin, the affine flip only changes the reported
    value); legacy constructions leave them at the waste default."""

    strategy: str
    q: int  # 0 or 1 — affine-in-q argument, Section 3.3
    T_R: float
    waste: float
    T_P: Optional[float] = None  # proactive period (WithCkptI only)
    k_P: Optional[int] = None  # number of proactive periods in the window
    objective: str = "waste"
    value: Optional[float] = None
    T_d: Optional[float] = None  # disk-tier period (two-level only)
    k_V: Optional[int] = None  # checkpoints per verification (silent only)


def _clamp(T: float, lo: float, hi: float) -> float:
    hi = max(hi, lo)
    return min(hi, max(T, lo))


def _t0(mu, C, alpha, capped) -> float:
    return _t_young(mu, C, alpha) if capped else max(_t_extr(mu, C), C)


def _t1(mu, C, r, p, I, alpha, capped) -> float:
    if capped:
        return _t_one(mu, C, r, p, I, alpha)
    return max(_t_extr(mu, C, r, 1.0), C)


def _optimize_exact(
    platform: W.Platform,
    pred: W.PredictorModel,
    alpha: float = W.ALPHA,
    capped: bool = False,
) -> OptimalPolicy:
    """Section 3.3 case analysis: min(WASTE_Y(T_Y), WASTE^{1}(T_1)).

    ``capped=True`` restricts periods to the Section 3.2 validity domain
    [C, alpha*mu_e].  The paper's own simulations (Section 5) use the
    *uncapped* extremal periods — the capped model over-penalizes poor
    precision (mu_e shrinks with false predictions), so uncapped is the
    default here, matching the policy the paper validates."""
    mu, C, D, R = platform.mu, platform.C, platform.D, platform.R
    r, p = pred.recall, pred.precision

    ty = _t0(mu, C, alpha, capped)
    w0 = W.waste_exact(ty, 0.0, C, D, R, mu, r, p)

    if r <= 0:
        return OptimalPolicy("exact", 0, ty, min(w0, 1.0))

    t1 = _t1(mu, C, r, p, 0.0, alpha, capped)
    w1 = W.waste_exact(t1, 1.0, C, D, R, mu, r, p)
    if w1 < w0:
        return OptimalPolicy("exact", 1, t1, min(w1, 1.0))
    return OptimalPolicy("exact", 0, ty, min(w0, 1.0))


def _optimize_migration(
    platform: W.Platform,
    pred: W.PredictorModel,
    alpha: float = W.ALPHA,
    capped: bool = False,
) -> OptimalPolicy:
    """Section 3.4: same case analysis with Equation (3)."""
    mu, C, D, R = platform.mu, platform.C, platform.D, platform.R
    M = platform.M if platform.M is not None else C
    r, p = pred.recall, pred.precision

    ty = _t0(mu, C, alpha, capped)
    w0 = W.waste_migration(ty, 0.0, C, D, R, M, mu, r, p)
    if r <= 0:
        return OptimalPolicy("migration", 0, ty, min(w0, 1.0))
    t1 = _t1(mu, C, r, p, 0.0, alpha, capped)
    w1 = W.waste_migration(t1, 1.0, C, D, R, M, mu, r, p)
    if w1 < w0:
        return OptimalPolicy("migration", 1, t1, min(w1, 1.0))
    return OptimalPolicy("migration", 0, ty, min(w0, 1.0))


def _optimize_window(
    strategy: str,
    platform: W.Platform,
    pred: W.PredictorModel,
    alpha: float,
    capped: bool = False,
) -> OptimalPolicy:
    mu, C, D, R = platform.mu, platform.C, platform.D, platform.R
    r, p, I = pred.recall, pred.precision, pred.window
    E_f = pred.e_f

    # q = 0 branch is Young's waste with the window-reduced cap (Section 4.3).
    if capped:
        cap0 = max(alpha * _mu_e(mu, r, p) - I, C) if r > 0 else alpha * mu
        t_r0 = _clamp(_t_extr(mu, C), C, cap0)
    else:
        t_r0 = max(_t_extr(mu, C), C)
    w0 = W.waste_young(t_r0, C, D, R, mu)
    best = OptimalPolicy(strategy, 0, t_r0, min(w0, 1.0))
    if r <= 0:
        return best

    t_r1 = _t1(mu, C, r, p, I, alpha, capped)
    if strategy == "instant":
        w1 = W.waste_instant(t_r1, 1.0, C, D, R, mu, r, p, I, E_f)
        cand = OptimalPolicy(strategy, 1, t_r1, min(w1, 1.0))
    elif strategy == "nockpt":
        w1 = W.waste_nockpt(t_r1, 1.0, C, D, R, mu, r, p, I, E_f)
        cand = OptimalPolicy(strategy, 1, t_r1, min(w1, 1.0))
    elif strategy == "withckpt":
        tp = _t_p_opt(C, p, I, E_f)
        if tp is None:
            return best  # window cannot hold a checkpoint
        T_P, k = tp
        w1 = W.waste_withckpt(t_r1, T_P, 1.0, C, D, R, mu, r, p, I, E_f)
        cand = OptimalPolicy(strategy, 1, t_r1, min(w1, 1.0), T_P=T_P, k_P=k)
    else:  # pragma: no cover - guarded by callers
        raise ValueError(strategy)

    return cand if cand.waste < best.waste else best


def _optimize_instant(platform, pred, alpha: float = W.ALPHA, capped: bool = False) -> OptimalPolicy:
    return _optimize_window("instant", platform, pred, alpha, capped)


def _optimize_nockpt(platform, pred, alpha: float = W.ALPHA, capped: bool = False) -> OptimalPolicy:
    return _optimize_window("nockpt", platform, pred, alpha, capped)


def _optimize_withckpt(platform, pred, alpha: float = W.ALPHA, capped: bool = False) -> OptimalPolicy:
    return _optimize_window("withckpt", platform, pred, alpha, capped)


def two_level_periods(
    mu: float,
    C_m: float,
    C_d: float,
    f: float,
    r: float = 0.0,
    q: float = 0.0,
    p: float = 1.0,
    D: float = 0.0,
    R_m: float = 0.0,
    R_d: float = 0.0,
) -> Tuple[float, float]:
    """Argmin periods of the two-level model (see waste.waste_two_level).

    The model's proactive term ``(qr/p) C_m / mu`` is constant in both
    periods, so it shifts the waste but never the argmin; ``p`` (and the
    D/R costs) are threaded through so this optimizer evaluates the same
    model :func:`waste.waste_two_level` charges.  Each tier's term is
    Young-shaped in its own period — but prediction shields only the
    memory tier (a disk-tier failure destroys the proactive memory
    checkpoint with the tier, see ``waste.waste_two_level``), so ``rq``
    stretches the memory extremizer alone:

      T_m* = sqrt(2 mu C_m / ((1-rq) f))
      T_d* = sqrt(2 mu C_d / (1-f))

    When the unconstrained extremizers violate ``T_d >= T_m`` the
    constrained argmin sits ON that boundary (the objective is separable
    convex), where every checkpoint is a combined memory+disk one of cost
    ``C_m + C_d`` against the blended loss slope ``f(1-rq) + (1-f)`` — a
    joint Young problem, NOT the pair of independently clamped per-tier
    optima the previous revision returned:

      T* = sqrt(2 mu (C_m + C_d) / (f(1-rq) + 1-f))     (T_m = T_d = T*)

    Periods are floored at their own checkpoint cost (``T_m >= C_m``,
    ``T_d >= C_d`` per tier, ``T >= C_m + C_d`` on the boundary)."""
    del p  # constant proactive term: affects the waste, never the argmin
    denom = max(1.0 - r * q, 1e-12)
    t_m = math.sqrt(2.0 * mu * C_m / (denom * max(f, 1e-12)))
    t_d = math.sqrt(2.0 * mu * C_d / max(1.0 - f, 1e-12))
    t_m = max(t_m, C_m)
    t_d = max(t_d, C_d)
    if t_d < t_m:
        blend = max(f * denom + (1.0 - f), 1e-12)
        t = max(math.sqrt(2.0 * mu * (C_m + C_d) / blend), C_m + C_d)
        return t, t
    del D, R_m, R_d  # additive fault costs: shift the waste, not the argmin
    return t_m, t_d


def silent_period(
    mu: float,
    C: float,
    V: float,
    D: float = 0.0,
    R: float = 0.0,
    k: Optional[int] = None,
    k_max: int = 16,
) -> Tuple[float, int]:
    """Argmin period and verification stride of the silent-error model
    (see waste.waste_silent, arXiv:1310.8486).

    For a fixed stride ``k`` (one verification every ``k`` checkpoints)
    the waste (k C + V)/(k T) + (k T + V + D + R)/mu is Young-shaped with
    extremizer

      T*(k) = sqrt(mu (k C + V)) / k

    (note: no factor 2 — a latent corruption forfeits the *whole* pattern,
    not half a period).  With ``k=None`` the stride is chosen by scanning
    ``1..k_max`` and keeping the argmin of the full model."""
    def t_star(kk: int) -> float:
        return max(math.sqrt(mu * (kk * C + V)) / kk, C)

    if k is not None:
        return t_star(k), k
    best = None
    for kk in range(1, max(k_max, 1) + 1):
        t = t_star(kk)
        w = W.waste_silent(t, C, V, D, R, mu, kk)
        if best is None or w < best[0]:
            best = (w, t, kk)
    return best[1], best[2]


def _two_level_platform(platform: W.Platform):
    """Two-level knobs with their degenerate-platform fallbacks: a missing
    disk tier costs like the memory one, a missing coverage fraction means
    no failure is memory-recoverable."""
    C2 = platform.C2 if platform.C2 is not None else platform.C
    R2 = platform.R2 if platform.R2 is not None else platform.R
    f = platform.f if platform.f is not None else 0.0
    return C2, R2, f


def _optimize_two_level(
    platform: W.Platform,
    pred: W.PredictorModel,
    alpha: float = W.ALPHA,
    capped: bool = False,
) -> OptimalPolicy:
    """Two-level case analysis: the corrected extremizers of
    :func:`two_level_periods` under the q in {0, 1} affine argument."""
    mu, C, D, R = platform.mu, platform.C, platform.D, platform.R
    C2, R2, f = _two_level_platform(platform)
    r, p = pred.recall, pred.precision

    def pol(q: float) -> OptimalPolicy:
        t_m, t_d = two_level_periods(mu, C, C2, f, r, q, p, D, R, R2)
        if capped:
            cap = max(alpha * mu, C)
            t_m = _clamp(t_m, C, cap)
            t_d = max(min(t_d, max(cap, C2)), t_m)
        w = W.waste_two_level(t_m, t_d, C, C2, D, R, R2, mu, f, r, q, p)
        return OptimalPolicy("two_level", int(q), t_m, min(w, 1.0), T_d=t_d)

    best = pol(0.0)
    if r <= 0:
        return best
    cand = pol(1.0)
    return cand if cand.waste < best.waste else best


def _optimize_silent(
    platform: W.Platform,
    pred: W.PredictorModel,
    alpha: float = W.ALPHA,
    capped: bool = False,
) -> OptimalPolicy:
    """Silent-error optimum: scan the verification stride, Young-shaped
    period per stride (predictions never fire on latent corruptions, so
    the predictor is ignored: q = 0 always)."""
    mu, C, D, R = platform.mu, platform.C, platform.D, platform.R
    V = platform.V if platform.V is not None else C
    t, k = silent_period(mu, C, V, D, R)
    if capped:
        t = _clamp(t, C, max(alpha * mu, C))
    w = W.waste_silent(t, C, V, D, R, mu, k)
    return OptimalPolicy("silent", 0, t, min(w, 1.0), k_V=k)


def _nockpt_dominates(
    C: float, p: float, I: float, E_f: Optional[float] = None
) -> bool:
    """Equation (12): sufficient condition for NoCkptI <= WithCkptI.

    2 sqrt( ((1-p) I + p E_f) / p * C ) >= E_f.
    Under the uniform assumption (E_f = I/2) this reduces to
    I <= 16 (1 - p/2) C / p.
    """
    if E_f is None:
        E_f = I / 2.0
    return 2.0 * _t_p_extr(C, p, I, E_f) >= E_f


def _best_policy(
    platform: W.Platform,
    pred: W.PredictorModel,
    alpha: float = W.ALPHA,
    capped: bool = False,
) -> OptimalPolicy:
    """The paper's final recipe (Section 4.3 Summary): evaluate every
    strategy at its own optimum and keep the best; when Equation (12)
    holds, WithCkptI cannot beat NoCkptI and is pruned."""
    if pred.window <= 0.0:
        return _optimize_exact(platform, pred, alpha, capped)
    cands = [
        _optimize_instant(platform, pred, alpha, capped),
        _optimize_nockpt(platform, pred, alpha, capped),
    ]
    if not _nockpt_dominates(platform.C, pred.precision, pred.window, pred.e_f):
        cands.append(_optimize_withckpt(platform, pred, alpha, capped))
    return min(cands, key=lambda pol: pol.waste)


# --------------------------------------------------------------------------- #
# Deprecated aliases (the pre-unified-optimizer public API)
# --------------------------------------------------------------------------- #
def _deprecated(impl, name: str, instead: str):
    """Thin warning shim: identical signature and behaviour, plus a
    :class:`DeprecationWarning` pointing at the unified optimizer."""

    @functools.wraps(impl)
    def shim(*args, **kwargs):
        warnings.warn(
            f"repro.core.periods.{name}() is deprecated; use {instead}",
            DeprecationWarning,
            stacklevel=2,
        )
        return impl(*args, **kwargs)

    shim.__name__ = name
    shim.__qualname__ = name
    return shim


t_extr = _deprecated(
    _t_extr, "t_extr", "repro.core.optimize(...).T_R (method='analytic')"
)
t_young = _deprecated(
    _t_young, "t_young", "repro.core.optimize('young', platform, capped=True).T_R"
)
t_daly = _deprecated(
    _t_daly, "t_daly", "repro.core.optimize('daly', platform).T_R"
)
t_one = _deprecated(
    _t_one, "t_one", "repro.core.optimize(..., capped=True).T_R"
)
t_p_extr = _deprecated(
    _t_p_extr, "t_p_extr", "repro.core.optimize('withckpt', ...).T_P"
)
t_p_opt = _deprecated(
    _t_p_opt, "t_p_opt", "repro.core.optimize('withckpt', ...).T_P"
)
optimize_exact = _deprecated(
    _optimize_exact, "optimize_exact", "repro.core.optimize('exact', platform, pred)"
)
optimize_migration = _deprecated(
    _optimize_migration, "optimize_migration",
    "repro.core.optimize('migration', platform, pred)",
)
optimize_instant = _deprecated(
    _optimize_instant, "optimize_instant",
    "repro.core.optimize('instant', platform, pred)",
)
optimize_nockpt = _deprecated(
    _optimize_nockpt, "optimize_nockpt",
    "repro.core.optimize('nockpt', platform, pred)",
)
optimize_withckpt = _deprecated(
    _optimize_withckpt, "optimize_withckpt",
    "repro.core.optimize('withckpt', platform, pred)",
)
nockpt_dominates = _deprecated(
    _nockpt_dominates, "nockpt_dominates", "repro.core.optimize('best', ...)"
)
best_policy = _deprecated(
    _best_policy, "best_policy", "repro.core.optimize('best', platform, pred)"
)
