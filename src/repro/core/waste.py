"""Closed-form waste models from the paper.

Equation numbers refer to Aupy, Robert, Vivien, Zaidouni, "Impact of fault
prediction on checkpointing strategies" (2012).

All functions return the *waste*: the steady-state fraction of platform time
not spent on useful work.  Parameters follow the paper's notation:

    T   checkpointing period (regular mode), seconds
    C   checkpoint duration
    D   downtime after a fault
    R   recovery duration
    mu  platform MTBF
    r   predictor recall
    p   predictor precision
    q   probability of trusting a prediction
    I   prediction-window length
    E_f expectation of the fault position inside the window (E_I^{(f)};
        I/2 under the paper's uniform assumption)
    M   migration duration (Section 3.4)

The functions are plain-float friendly and numpy-broadcastable so they can be
vectorized over parameter grids by the benchmark harness.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..analysis.schema import FloatLike
from .events import mu_e as _mu_e
from .events import mu_np as _mu_np
from .events import mu_p as _mu_p

__all__ = [
    "ALPHA",
    "Platform",
    "PredictorModel",
    "waste_checkpoint_only",
    "waste_young",
    "waste_exact",
    "waste_migration",
    "waste_instant",
    "waste_nockpt",
    "waste_withckpt",
    "waste_two_level",
    "waste_silent",
    "i_prime",
]

#: Validity tuning parameter (Section 3.2): with T <= ALPHA * mu_e the
#: probability of 2+ events in a period is <= 3%.
ALPHA = 0.27


@dataclass(frozen=True)
class Platform:
    """Fault-tolerance characteristics of a platform (Section 2.1).

    If built from individual components, ``mu = mu_ind / N``.
    """

    mu: float  # platform MTBF, seconds
    C: float  # checkpoint duration
    D: float  # downtime
    R: float  # recovery duration
    M: Optional[float] = None  # migration duration (Section 3.4)
    C2: Optional[float] = None  # disk-tier checkpoint duration (two-level)
    R2: Optional[float] = None  # disk-tier recovery duration (two-level)
    f: Optional[float] = None  # fraction of failures covered by the fast tier
    V: Optional[float] = None  # verification duration (silent errors)

    @staticmethod
    def from_components(
        mu_ind: float, n: int, C: float, D: float, R: float, M: Optional[float] = None
    ) -> "Platform":
        return Platform(mu=mu_ind / n, C=C, D=D, R=R, M=M)


@dataclass(frozen=True)
class PredictorModel:
    """Recall/precision/lead/window description of a predictor (Section 2.2)."""

    recall: float
    precision: float
    lead: float = math.inf
    window: float = 0.0

    @property
    def e_f(self) -> float:
        """E_I^{(f)} under the paper's uniform-fault-in-window assumption."""
        return self.window / 2.0

    def mu_p(self, mu: float) -> float:
        return _mu_p(mu, self.recall, self.precision)

    def mu_np(self, mu: float) -> float:
        return _mu_np(mu, self.recall)

    def mu_e(self, mu: float) -> float:
        return _mu_e(mu, self.recall, self.precision)


# --------------------------------------------------------------------------- #
# Section 2.1 / Section 3
# --------------------------------------------------------------------------- #
def waste_checkpoint_only(T: FloatLike, C: FloatLike) -> FloatLike:
    """Fault-free waste: C / T (Section 2.1)."""
    return C / T


def waste_young(
    T: FloatLike, C: FloatLike, D: FloatLike, R: FloatLike, mu: FloatLike
) -> FloatLike:
    """WASTE^{q=0}: Young's waste model (Section 3.3).

    WASTE_Y(T) = C/T + (1/mu) [ T/2 + D + R ]
    """
    return C / T + (T / 2.0 + D + R) / mu


def waste_exact(
    T: FloatLike, q: FloatLike, C: FloatLike, D: FloatLike, R: FloatLike,
    mu: FloatLike, r: FloatLike, p: FloatLike,
) -> FloatLike:
    """Equation (1): predictor with exact event dates.

    WASTE = C/T + (1/mu) [ (1 - r q) T/2 + D + R + (q r / p) C ]
    """
    pred_term = (q * r / p) * C if r > 0 else 0.0
    return C / T + ((1.0 - r * q) * T / 2.0 + D + R + pred_term) / mu


def waste_migration(
    T: FloatLike, q: FloatLike, C: FloatLike, D: FloatLike, R: FloatLike,
    M: FloatLike, mu: FloatLike, r: FloatLike, p: FloatLike,
) -> FloatLike:
    """Equation (3): proactive migration instead of proactive checkpoint.

    WASTE = C/T + (1/mu) [ (1 - r q)(T/2 + D + R) + (q r / p) M ]
    """
    pred_term = (q * r / p) * M if r > 0 else 0.0
    return C / T + ((1.0 - r * q) * (T / 2.0 + D + R) + pred_term) / mu


# --------------------------------------------------------------------------- #
# Section 4: window-based predictions
# --------------------------------------------------------------------------- #
def i_prime(q: FloatLike, p: FloatLike, I: FloatLike, E_f: FloatLike) -> FloatLike:
    """I' = q ((1-p) I + p E_I^f): expected time spent in proactive mode per
    prediction (Section 4.1)."""
    return q * ((1.0 - p) * I + p * E_f)


def waste_instant(
    T_R: FloatLike, q: FloatLike, C: FloatLike, D: FloatLike, R: FloatLike,
    mu: FloatLike, r: FloatLike, p: FloatLike, I: FloatLike, E_f: FloatLike,
) -> FloatLike:
    """Equation (5): strategy Instant (ignore the window, act at t0).

    WASTE = C/T_R + (1/mu)[ (1-rq) T_R/2 + D + R + (qr/p) C
                            + q r min(E_I^f, T_R/2) ]
    """
    pred_term = (q * r / p) * C if r > 0 else 0.0
    lost = q * r * np.minimum(E_f, T_R / 2.0)
    return C / T_R + ((1.0 - r * q) * T_R / 2.0 + D + R + pred_term + lost) / mu


def waste_nockpt(
    T_R: FloatLike, q: FloatLike, C: FloatLike, D: FloatLike, R: FloatLike,
    mu: FloatLike, r: FloatLike, p: FloatLike, I: FloatLike, E_f: FloatLike,
) -> FloatLike:
    """Equation (6): strategy NoCkptI (no checkpoints inside the window).

    Outside the validity domain (windows so long/frequent that I' > mu_P,
    i.e. the platform would sit in proactive mode permanently) the
    proactive fraction is clamped to 1, keeping the formula total."""
    if r <= 0:
        return waste_young(T_R, C, D, R, mu)
    m_p = _mu_p(mu, r, p)
    m_np = _mu_np(mu, r)
    ip = min(i_prime(q, p, I, E_f), m_p)
    reg_frac = 1.0 - ip / m_p
    waste = (reg_frac / T_R + q / m_p) * C
    waste += (p * (1.0 - q) / m_p) * (T_R / 2.0)
    waste += (p * q / m_p) * E_f
    waste += reg_frac / m_np * (T_R / 2.0)
    waste += (p / m_p + reg_frac / m_np) * (D + R)
    return waste


def waste_withckpt(
    T_R: FloatLike, T_P: FloatLike, q: FloatLike, C: FloatLike,
    D: FloatLike, R: FloatLike, mu: FloatLike, r: FloatLike, p: FloatLike,
    I: FloatLike, E_f: FloatLike,
) -> FloatLike:
    """Equation (4): strategy WithCkptI (periodic checkpoints of period T_P
    inside the window)."""
    if r <= 0:
        return waste_young(T_R, C, D, R, mu)
    m_p = _mu_p(mu, r, p)
    m_np = _mu_np(mu, r)
    ip = min(i_prime(q, p, I, E_f), m_p)  # validity clamp (see waste_nockpt)
    reg_frac = 1.0 - ip / m_p
    waste = (reg_frac / T_R + (ip / m_p) / T_P + q / m_p) * C
    waste += (p * (1.0 - q) / m_p) * (T_R / 2.0)
    waste += (p * q / m_p) * T_P
    waste += reg_frac / m_np * (T_R / 2.0)
    waste += (p / m_p + reg_frac / m_np) * (D + R)
    return waste


def waste_two_level(
    T_m: FloatLike, T_d: FloatLike, C_m: FloatLike, C_d: FloatLike,
    D: FloatLike, R_m: FloatLike, R_d: FloatLike, mu: FloatLike,
    f: FloatLike, r: float = 0.0, q: float = 0.0, p: float = 1.0,
) -> FloatLike:
    """Beyond-paper: two-level checkpointing (memory buddy tier + disk).

    A fraction ``f`` of failures is recoverable from the in-memory buddy
    tier (single-node loss: cost D + R_m, work lost since the last
    *memory* checkpoint, period T_m, cost C_m); the remaining (1-f)
    require the durable disk tier (period T_d >= T_m, cost C_d, recovery
    R_d).  Prediction only protects the *memory* tier: a trusted true
    positive triggers a proactive memory checkpoint right before the
    fault, so a memory-tier failure then loses (almost) no work — but a
    disk-tier failure destroys the memory tier, proactive checkpoint
    included, and still rolls back to the last disk checkpoint.  (The
    previous revision scaled the disk term by (1-rq) too, which
    simulation refutes: predictions cannot shield losses the surviving
    tier never held.)  Downtime + recovery is paid on every fault,
    predicted or not:

      WASTE = C_m/T_m + C_d/T_d
            + (1/mu) [ f ((1-rq) T_m/2 + D + R_m)
                       + (1-f)(T_d/2 + D + R_d) ]
            + (qr/p) C_m / mu                      (proactive ckpts hit the
                                                    fast tier)
    """
    waste = C_m / T_m + C_d / T_d
    waste += (
        f * ((1.0 - r * q) * T_m / 2.0 + D + R_m)
        + (1 - f) * (T_d / 2.0 + D + R_d)
    ) / mu
    if r > 0 and q > 0:
        # p <= 0 means "no true positive is ever trusted for free": clamp the
        # denominator exactly like the other prediction-aware models instead
        # of raising ZeroDivisionError when a predictor is active with p=0.
        waste += (q * r / max(p, 1e-12)) * C_m / mu
    return waste


def waste_silent(
    T: FloatLike, C: FloatLike, V: FloatLike, D: FloatLike, R: FloatLike,
    mu: FloatLike, k: int = 1,
) -> FloatLike:
    """Beyond-paper: silent-data-corruption waste (arXiv:1310.8486).

    Pattern of ``k`` checkpointing periods of length ``T`` (each ending in a
    checkpoint of cost ``C``); the ``k``-th checkpoint additionally runs a
    verification of cost ``V``, so the pattern wall time is ``P = k T + V``.
    Corruptions strike at rate ``1/mu`` but stay latent until the pattern-end
    verification, which rolls back to the last *verified* checkpoint: a
    struck pattern forfeits its full wall time (detection latency reaches
    past the k-1 unverified checkpoints) plus the recovery ``D + R``:

      WASTE = (k C + V) / (k T) + (k T + V + D + R) / mu
    """
    return (k * C + V) / (k * T) + (k * T + V + D + R) / mu


def withckpt_minus_nockpt(
    T_P: FloatLike, C: FloatLike, mu: FloatLike, r: FloatLike,
    p: FloatLike, I: FloatLike, E_f: FloatLike,
) -> FloatLike:
    """Equation (11) at q=1: WASTE_withCkpt - WASTE_noCkpt.

    = (r ((1-p) I + p E_f) / (p mu)) * C / T_P + (r/mu) (T_P - E_f)
    """
    K = ((1.0 - p) * I + p * E_f) / p
    return (r / mu) * (K * C / T_P + T_P - E_f)
