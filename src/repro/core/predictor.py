"""Fault-predictor interfaces and literature presets (paper Table 3).

Two layers:

* :class:`PredictorModel` (in ``waste.py``) — the *statistical* description
  (recall, precision, lead, window) used by the closed-form optimizers.
* :class:`OnlinePredictor` — the *runtime* interface consumed by the
  fault-tolerant executor: a stream of :class:`PredictionEvent` announcements.
  :class:`SimulatedPredictor` replays a generated trace; a production
  deployment would adapt fleet health telemetry (ECC rates, link flaps,
  thermal alarms) to the same interface.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterator, List, Optional, Protocol, Sequence

import numpy as np

from .events import EventTrace, PredictionEvent, make_event_trace, exponential
from .waste import PredictorModel

__all__ = [
    "TABLE3_PREDICTORS",
    "predictor_preset",
    "OnlinePredictor",
    "SimulatedPredictor",
    "estimate_recall_precision",
]


#: Paper Table 3 — published predictor operating points.
#: (label, lead seconds, precision, recall, window seconds or None)
TABLE3_PREDICTORS: dict[str, PredictorModel] = {
    # Zheng et al. [14], Blue Gene/P event-driven, 300 s lead
    "zheng-lead300": PredictorModel(recall=0.70, precision=0.40, lead=300.0),
    "zheng-lead600": PredictorModel(recall=0.60, precision=0.35, lead=600.0),
    # Yu et al. [12], Blue Gene/P period-based (window size unpublished)
    "yu-2h": PredictorModel(recall=0.652, precision=0.648, lead=7200.0, window=3600.0),
    "yu-0min": PredictorModel(recall=0.854, precision=0.823, lead=0.0, window=300.0),
    # Gainaru et al. [6]
    "gainaru": PredictorModel(recall=0.43, precision=0.93, lead=32.0),
    # Fulp et al. [5], SVM on syslogs
    "fulp": PredictorModel(recall=0.75, precision=0.70, lead=math.inf),
    # Liang et al. [9], BG/L event logs, several window sizes
    "liang-1h": PredictorModel(recall=0.30, precision=0.20, window=3600.0),
    "liang-4h": PredictorModel(recall=0.75, precision=0.30, window=4 * 3600.0),
    "liang-6h": PredictorModel(recall=0.90, precision=0.40, window=6 * 3600.0),
    "liang-12h": PredictorModel(recall=0.85, precision=0.60, window=12 * 3600.0),
    # The paper's two simulation operating points (Section 5.1)
    "paper-accurate": PredictorModel(recall=0.85, precision=0.82, window=300.0),
    "paper-limited": PredictorModel(recall=0.70, precision=0.40, window=300.0),
}


def predictor_preset(name: str) -> PredictorModel:
    try:
        return TABLE3_PREDICTORS[name]
    except KeyError:
        raise KeyError(
            f"unknown predictor preset {name!r}; available: "
            f"{sorted(TABLE3_PREDICTORS)}"
        ) from None


class OnlinePredictor(Protocol):
    """Runtime prediction stream consumed by the FT executor."""

    model: PredictorModel

    def poll(self, now: float) -> List[PredictionEvent]:
        """Predictions announced at or before ``now`` not yet delivered."""
        ...


class SimulatedPredictor:
    """Replays the prediction half of an :class:`EventTrace`."""

    def __init__(self, trace: EventTrace, model: PredictorModel):
        self.model = model
        # deliver in announce order
        self._events = sorted(trace.predictions, key=lambda e: e.announce_time)
        self._i = 0

    def poll(self, now: float) -> List[PredictionEvent]:
        out: List[PredictionEvent] = []
        while self._i < len(self._events) and (
            self._events[self._i].announce_time <= now
        ):
            out.append(self._events[self._i])
            self._i += 1
        return out

    @staticmethod
    def generate(
        model: PredictorModel,
        mtbf: float,
        horizon: float,
        seed: int = 0,
    ) -> tuple["SimulatedPredictor", EventTrace]:
        rng = np.random.default_rng(seed)
        trace = make_event_trace(
            rng,
            horizon=horizon,
            mtbf=mtbf,
            recall=model.recall,
            precision=model.precision,
            window=model.window,
            lead=model.lead,
        )
        return SimulatedPredictor(trace, model), trace


def estimate_recall_precision(
    n_true_positive: int, n_false_positive: int, n_false_negative: int
) -> tuple[float, float]:
    """Online r/p estimation from observed counters (Section 2.2).

    With zero observed predictions (TP + FP == 0) there is *no evidence*
    of precision, and the estimate must not be trusted: returning the
    old optimistic 1.0 let the executor's online re-optimization flip to
    full q=1 trust in a predictor that had never produced a prediction.
    Both undefined ratios now degrade to 0.0 (claim nothing you have not
    observed); callers wanting a prior should gate on the evidence count
    instead (see ``ft.executor._MIN_PRED_EVIDENCE``)."""
    tp, fp, fn = n_true_positive, n_false_positive, n_false_negative
    r = tp / (tp + fn) if tp + fn else 0.0
    p = tp / (tp + fp) if tp + fp else 0.0
    return r, p
