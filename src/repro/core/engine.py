"""Engine selection for the simulation entry points, in one place.

:func:`repro.core.simulate_many`, :func:`repro.core.best_period_search`
and :func:`repro.experiments.run_grid` historically grew overlapping
ad-hoc keyword arguments (``engine=``, ``devices=``, ``mesh=``,
``trace_mode=``, ``dispatch=``, ``collect=``, ``chunk_lanes=``).
:class:`EngineConfig` collects them into one frozen dataclass threaded
through all three, so new engine knobs land here once; the old keyword
arguments are still accepted (per-call) through a deprecation shim that
builds the equivalent config.

The cross-field rules shared by every entry point live in
:meth:`EngineConfig.validate`; rules specific to one entry point (e.g.
``dispatch`` granularity, which only grid sweeps have) stay with that
entry point, driven by the config's fields.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, fields, replace
from typing import Any, Dict, Optional, Union

__all__ = ["EngineConfig", "resolve_engine_config", "UNSET"]


class _Unset:
    """Sentinel distinguishing "not passed" from a legitimate ``None``
    (``chunk_lanes=None`` means "one engine call", ``devices=None`` means
    "default device")."""

    __slots__ = ()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return "<unset>"


#: module-wide "keyword not passed" sentinel of the deprecation shims
UNSET = _Unset()


@dataclass(frozen=True)
class EngineConfig:
    """How to run a batch of simulations.

    engine       "batch" (NumPy lanes), "jax" (device-resident),
                 "scalar" (reference engine) or "legacy" (seed pipeline;
                 grid sweeps only).
    trace_mode   "host" (materialized event arrays) or "device"
                 (counter-RNG :class:`~repro.core.events.TraceSpec`
                 streams sampled inside the engine).
    dispatch     grid sweeps only: "fused" / "perfamily" / "percell"
                 (None picks the engine's default granularity).
    collect      "lanes" (per-run results) or "stats" (device-reduced
                 per-cell statistics; jax engine only).
    devices      shard lanes across a device set (jax engine only):
                 None, "all", an int, or an explicit device sequence.
    mesh         a ``jax.sharding.Mesh`` as shorthand for ``devices=``
                 over its device set; mutually exclusive with it.
    chunk_lanes  lanes resident on the device per engine call ("auto",
                 an int, or None for one single call).
    """

    engine: str = "batch"
    trace_mode: str = "host"
    dispatch: Optional[str] = None
    collect: str = "lanes"
    devices: Any = None
    mesh: Any = None
    chunk_lanes: Union[int, str, None] = "auto"

    def validate(self) -> "EngineConfig":
        """Check the cross-field rules every entry point shares (each
        entry point additionally restricts ``engine`` to the set it
        supports, with its historical error message)."""
        if self.engine != "jax" and (
            self.devices is not None or self.mesh is not None
        ):
            raise ValueError("devices=/mesh= require engine='jax'")
        if self.trace_mode not in ("host", "device"):
            raise ValueError(
                f"unknown trace_mode {self.trace_mode!r} "
                "(expected 'host' or 'device')"
            )
        if self.collect not in ("lanes", "stats"):
            raise ValueError(
                f"unknown collect {self.collect!r} "
                "(expected 'lanes' or 'stats')"
            )
        return self

    def replace(self, **changes) -> "EngineConfig":
        return replace(self, **changes)


_FIELD_NAMES = tuple(f.name for f in fields(EngineConfig))


def resolve_engine_config(
    config: Union[EngineConfig, str, None],
    caller: str,
    **legacy,
) -> EngineConfig:
    """Merge a ``config=`` argument with legacy ad-hoc keywords.

    ``config`` may be an :class:`EngineConfig`, ``None`` (defaults +
    legacy keywords), or — because the old signatures took ``engine`` as
    the first optional positional — a bare engine-name string.  Legacy
    keywords arrive valued or :data:`UNSET`; passing any of them emits a
    :class:`DeprecationWarning` naming the replacement, and combining
    them with an explicit :class:`EngineConfig` is an error (there is no
    sensible precedence between the two spellings)."""
    if isinstance(config, str):
        if legacy.get("engine", UNSET) is not UNSET:
            raise ValueError(
                f"{caller}: engine given both positionally and as engine="
            )
        legacy["engine"] = config
        config = None
    provided: Dict[str, Any] = {
        k: v for k, v in legacy.items() if v is not UNSET
    }
    unknown = set(provided) - set(_FIELD_NAMES)
    if unknown:  # pragma: no cover - programming error guard
        raise TypeError(f"{caller}: unknown engine kwargs {sorted(unknown)}")
    if config is None:
        if provided:
            warnings.warn(
                f"{caller}: the {sorted(provided)} keyword(s) are "
                "deprecated; pass config=EngineConfig(...) instead",
                DeprecationWarning,
                stacklevel=3,
            )
        return EngineConfig(**provided)
    if not isinstance(config, EngineConfig):
        raise TypeError(
            f"{caller}: config must be an EngineConfig, an engine name or "
            f"None, got {type(config).__name__}"
        )
    if provided:
        raise ValueError(
            f"{caller}: pass either config=EngineConfig(...) or the legacy "
            f"{sorted(provided)} keyword(s), not both"
        )
    return config
