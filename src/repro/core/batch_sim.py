"""Lane-per-trace vectorized simulation engine.

This module advances a whole batch of independent simulations — one *lane*
per fault/prediction trace — through NumPy array operations, mirroring the
scalar reference engine (:class:`repro.core.simulator._Engine`, Algorithm 1
of the paper) transition for transition.

Lane semantics
==============

* **One lane = one complete simulation**: a job of ``W_i`` seconds of work on
  a platform ``(C_i, D_i, R_i, M_i)`` running strategy ``(T_R_i, mode_i,
  T_P_i, q_i)`` against trace lane ``i`` of a :class:`~repro.core.events.
  BatchTraces`.  All parameters are per-lane arrays, so a single engine call
  can carry an entire heterogeneous experiment sweep (different platform
  sizes, predictors, strategies and failure laws side by side).
* **Per-lane cursors**: each lane keeps its own fault cursor ``fi`` and
  prediction cursor ``pi`` into the padded, time-sorted event arrays (a
  sentinel ``+inf`` column terminates every row), plus the scalar engine's
  state — clock ``t``, ``saved``/``unsaved`` work, ``period_work`` credited
  toward the current regular period, and event counters.
* **Phases, not threads**: every lane carries a small phase code (regular
  mode, the sub-steps of a proactive episode, the in-window WithCkptI loop).
  One engine iteration executes exactly one *primitive* timeline operation
  per active lane — a work segment, an idle segment (migration), a
  checkpoint, or a pure phase transition — with masked NumPy updates.  Lanes
  in different phases advance simultaneously; a lane whose job completes
  drops out of the active mask while the others keep running.
* **Faithful to the oracle**: primitives replicate the scalar engine's exact
  order of operations (work targets capped by remaining work *before* stale
  faults are resolved, checkpoint end dates fixed before the fault check,
  faults during downtime cascading the recovery clock, migration cancelling
  the predicted fault from the lane's trace).  Feeding the same
  ``BatchTraces`` lane to both engines yields bit-identical makespans for
  the deterministic trust settings ``q ∈ {0, 1}`` used by all paper
  strategies; fractional ``q`` draws trust coins from a batch RNG and is
  only distributionally equivalent.

Wall-clock cost is ``O(max_lane_primitives)`` iterations, each touching
``O(n_lanes)`` contiguous memory — for paper-scale sweeps (hundreds of
lanes, thousands of primitives per lane) this amortizes the Python
interpreter overhead that dominates the scalar engine and yields order-of-
magnitude speedups.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Union

import numpy as np

from .events import BatchTraces, TraceSpec, pad_sentinel
from .simulator import SimResult, Strategy, _EPS
from .waste import Platform

__all__ = ["MODE_CODES", "BatchResult", "pad_lane_axis", "simulate_batch"]

#: strategy-mode codes shared with :class:`repro.core.simulator.Strategy`
MODE_CODES = {
    "none": 0, "exact": 1, "nockpt": 2, "withckpt": 3, "migration": 4,
    "two_level": 5, "silent": 6,
}
(
    _M_NONE, _M_EXACT, _M_NOCKPT, _M_WITHCKPT, _M_MIGRATION,
    _M_TWO_LEVEL, _M_SILENT,
) = range(7)

# lane phases (continuation points of the scalar engine's control flow)
_PH_MAIN = 0  # top of Algorithm 1's regular-mode loop
_PH_EP_START = 1  # trusted prediction popped; episode entry decision
_PH_EP_PRECKPT = 2  # pre-window proactive checkpoint pending
_PH_EP_NT2 = 3  # "no time" path: uncredited work to t0 pending
_PH_EP_NOCKPT = 4  # NoCkptI: uncredited work to t0 + I pending
_PH_EP_WC = 5  # WithCkptI in-window loop: next segment decision
_PH_EP_WC_CKPT = 6  # WithCkptI proactive checkpoint pending
_PH_DONE = 7  # job complete: lane parked until harvested

# primitive kinds (one per lane per iteration)
_PR_NOOP, _PR_WORK, _PR_IDLE, _PR_CKPT = 0, 1, 2, 3

# continuations applied when a primitive completes without fault
(
    _C_MAIN,  # back to regular mode
    _C_CKPTREG,  # regular ckpt done: act on a prediction that fell inside it?
    _C_POP_EP,  # work-to-action done: pop the prediction, start episode
    _C_PRECKPT,  # work to t0 - C done: take the pre-window checkpoint
    _C_MODE,  # episode head done: dispatch on strategy mode
    _C_NT2,  # degenerate credited work done: uncredited work to t0
    _C_MIG,  # migration idle done: count it, back to regular mode
    _C_WC_CKPT,  # in-window work segment done: proactive checkpoint
    _C_WC,  # in-window checkpoint done: loop
) = range(9)

#: continuation -> next phase; special codes (_C_CKPTREG, _C_POP_EP, _C_MODE,
#: _C_MIG) get the MAIN placeholder and are patched by dedicated handlers
_CONT2PH = np.array(
    [
        _PH_MAIN, _PH_MAIN, _PH_MAIN, _PH_EP_PRECKPT, _PH_MAIN,
        _PH_EP_NT2, _PH_MAIN, _PH_EP_WC_CKPT, _PH_EP_WC,
    ],
    dtype=np.int8,
)

#: strategy mode -> phase after the episode head (Instant returns to regular
#: mode, NoCkptI idles through the window, WithCkptI enters the T_P loop;
#: two-level episodes behave like exact — the proactive checkpoint hits the
#: memory tier — and silent lanes never trust predictions, so both are MAIN)
_MODE2PH = np.array(
    [_PH_MAIN, _PH_MAIN, _PH_EP_NOCKPT, _PH_EP_WC, _PH_MAIN,
     _PH_MAIN, _PH_MAIN],
    dtype=np.int8,
)


@dataclass
class BatchResult:
    """Per-lane results of a batch simulation (arrays of shape ``(L,)``)."""

    makespan: np.ndarray
    work: np.ndarray
    n_faults: np.ndarray
    n_proactive_ckpts: np.ndarray
    n_regular_ckpts: np.ndarray
    n_migrations: np.ndarray
    trace_exhausted: np.ndarray
    #: two-level disk-tier recoveries / silent-error detections per lane
    #: (zeros unless the lane runs the corresponding mode; ``None`` only on
    #: legacy hand-built results predating the two phase families)
    n_disk_recoveries: Optional[np.ndarray] = None
    n_detections: Optional[np.ndarray] = None

    @property
    def n_lanes(self) -> int:
        return int(self.makespan.shape[0])

    @property
    def waste(self) -> np.ndarray:
        return 1.0 - self.work / self.makespan

    def lane(self, i: int) -> SimResult:
        """Scalar :class:`SimResult` view of lane ``i``."""
        nd = self.n_disk_recoveries
        nv = self.n_detections
        return SimResult(
            makespan=float(self.makespan[i]),
            work=float(self.work[i]),
            n_faults=int(self.n_faults[i]),
            n_proactive_ckpts=int(self.n_proactive_ckpts[i]),
            n_regular_ckpts=int(self.n_regular_ckpts[i]),
            n_migrations=int(self.n_migrations[i]),
            trace_exhausted=bool(self.trace_exhausted[i]),
            n_disk_recoveries=int(nd[i]) if nd is not None else 0,
            n_detections=int(nv[i]) if nv is not None else 0,
        )

    def to_results(self) -> List[SimResult]:
        return [self.lane(i) for i in range(self.n_lanes)]


def _lane_params(work, platform, strategy, L: int):
    plats = [platform] * L if isinstance(platform, Platform) else list(platform)
    strats = [strategy] * L if isinstance(strategy, Strategy) else list(strategy)
    if len(plats) != L or len(strats) != L:
        raise ValueError(
            f"platform/strategy length mismatch: {len(plats)}/{len(strats)} vs {L} lanes"
        )
    W = np.broadcast_to(np.asarray(work, dtype=np.float64), (L,)).copy()
    C = np.array([p.C for p in plats], dtype=np.float64)
    D = np.array([p.D for p in plats], dtype=np.float64)
    R = np.array([p.R for p in plats], dtype=np.float64)
    M = np.array(
        [p.M if p.M is not None else p.C for p in plats], dtype=np.float64
    )
    T_R = np.array([s.T_R for s in strats], dtype=np.float64)
    T_P = np.array(
        [s.T_P if s.T_P is not None else np.nan for s in strats], dtype=np.float64
    )
    mode = np.array([MODE_CODES[s.mode] for s in strats], dtype=np.int8)
    q = np.array([s.q for s in strats], dtype=np.float64)
    # two-level / silent-error columns (benign on every other mode's lanes:
    # a missing disk tier mirrors the memory one, f=0 sends every failure to
    # disk, rho/k_V=1 make the nesting/verification strides degenerate)
    C2 = np.array(
        [p.C2 if p.C2 is not None else p.C for p in plats], dtype=np.float64
    )
    R2 = np.array(
        [p.R2 if p.R2 is not None else p.R for p in plats], dtype=np.float64
    )
    V = np.array(
        [p.V if p.V is not None else p.C for p in plats], dtype=np.float64
    )
    fmem = np.array(
        [p.f if p.f is not None else 0.0 for p in plats], dtype=np.float64
    )
    rho = np.array(
        [s.rho if getattr(s, "rho", None) is not None else 1 for s in strats],
        dtype=np.int64,
    )
    kv = np.array(
        [s.k_V if getattr(s, "k_V", None) is not None else 1 for s in strats],
        dtype=np.int64,
    )
    return W, C, D, R, M, T_R, T_P, mode, q, C2, R2, V, fmem, rho, kv


def pad_lane_axis(a: np.ndarray, n: int, fill) -> np.ndarray:
    """Pad the lane axis of a 1-D or 2-D per-lane array to ``n`` lanes.

    Shared packing helper of the device engines: padding lanes are filled
    with ``fill`` (a value that keeps them inert — ``+inf`` fault dates,
    phase ``DONE`` state, benign platform constants)."""
    if a.shape[0] == n:
        return a
    shape = (n - a.shape[0],) + a.shape[1:]
    return np.concatenate([a, np.full(shape, fill, dtype=a.dtype)], axis=0)


def _filter_trusted(
    traces: BatchTraces,
    q: np.ndarray,
    mode: np.ndarray,
    rng: Optional[np.random.Generator],
):
    """Per-lane trust filter (probability ``q`` per prediction), mirroring
    the scalar engine's init: mode "none" or q<=0 drops everything, q>=1
    keeps everything, fractional q flips one coin per prediction."""
    t0 = traces.pred_t0
    ft = traces.pred_fault
    n = traces.n_preds.astype(np.int64)
    # silent-error lanes never trust predictions: a latent corruption is not
    # a fail-stop event, so the fail-stop predictor has nothing to predict
    q_eff = np.where((mode == _M_NONE) | (mode == _M_SILENT), 0.0, q)
    frac_any = bool(((q_eff > 0.0) & (q_eff < 1.0)).any())
    if not frac_any and not ((q_eff <= 0.0) & (n > 0)).any():
        return t0, ft, n  # nothing dropped: arrays already engine-ready
    cols = np.arange(t0.shape[1])[None, :]
    keep = cols < n[:, None]
    keep &= (q_eff > 0.0)[:, None]
    frac = (q_eff > 0.0) & (q_eff < 1.0)
    if frac.any():
        rng = rng or np.random.default_rng(0)
        keep &= ~frac[:, None] | (rng.random(t0.shape) < q_eff[:, None])
    t0 = np.where(keep, t0, np.inf)
    ft = np.where(keep, ft, np.nan)
    if frac.any():
        # only fractional-q lanes can drop a strict subset mid-row and
        # need re-compaction; q<=0 rows are wholly +inf (already sorted)
        order = np.argsort(t0, axis=1, kind="stable")
        t0 = np.take_along_axis(t0, order, axis=1)
        ft = np.take_along_axis(ft, order, axis=1)
    return t0, ft, keep.sum(axis=1).astype(np.int64)


class _BatchEngine:
    def __init__(
        self, W, C, D, R, M, T_R, T_P, mode, traces, p_t0, p_ft,
        C2=None, R2=None, V=None, fmem=None, rho=None, kv=None,
    ):
        L = W.shape[0]
        self.L = L
        self.W, self.C, self.D, self.R, self.M = W, C, D, R, M
        self.work_full = W.copy()
        self.T_R, self.T_P, self.mode = T_R, T_P, mode
        self.horizon = np.asarray(traces.horizon, dtype=np.float64)
        self.window = np.asarray(traces.window, dtype=np.float64)
        self.C2 = C2 if C2 is not None else C
        self.R2 = R2 if R2 is not None else R
        self.V = V if V is not None else C
        self.fmem = fmem if fmem is not None else np.zeros(L)
        self.rho = rho if rho is not None else np.ones(L, dtype=np.int64)
        self.kv = kv if kv is not None else np.ones(L, dtype=np.int64)

        # the cursors need an +inf sentinel column; generated batches carry
        # one already, so the arrays are adopted without copying (the engine
        # never writes them — lane-local mutation goes through Fcancel)
        F = pad_sentinel(traces.fault_times, traces.n_faults, np.inf)
        self.F = F
        self.Fcancel = np.zeros(F.shape, dtype=bool)
        self.P0 = pad_sentinel(p_t0, traces.n_preds, np.inf)
        self.Pft = pad_sentinel(p_ft, traces.n_preds, np.nan)
        # per-fault recovery-tier uniforms, aligned with F's columns (only
        # consulted on two-level lanes; the 1.0 pad means "disk")
        FT = getattr(traces, "fault_tier", None)
        if FT is None:
            FT = np.ones((L, 1))
        elif FT.shape[1] < F.shape[1]:
            FT = np.concatenate(
                [FT, np.ones((L, F.shape[1] - FT.shape[1]))], axis=1
            )
        self.Ftier = FT

        z = lambda dt: np.zeros(L, dtype=dt)
        self.t = z(np.float64)
        self.saved = z(np.float64)
        self.unsaved = z(np.float64)
        self.period_work = z(np.float64)
        self.na_saved = z(np.float64)
        self.ep_t0 = z(np.float64)
        self.ep_end = z(np.float64)
        self.ep_ft = np.full(L, np.nan)
        self.fi = z(np.int64)
        self.pi = z(np.int64)
        self.n_faults = z(np.int64)
        self.n_pro = z(np.int64)
        self.n_reg = z(np.int64)
        self.n_mig = z(np.int64)
        self.phase = z(np.int8)
        self.done = z(bool)
        self.exhausted = z(bool)
        # two-level lane state: work at the last disk checkpoint, memory
        # checkpoints since it, and the duration of the repair in progress
        # (faults during a repair restart the SAME repair: rc, not D+R)
        self.saved_d = z(np.float64)
        self.dk_ctr = z(np.int64)
        self.rc = (D + R).copy()
        # silent-error lane state: work at the last *verified* checkpoint,
        # unverified checkpoints since it, earliest latent corruption time
        self.saved_v = z(np.float64)
        self.ck_v = z(np.int64)
        self.corrupt = np.full(L, np.inf)
        self.n_disk = z(np.int64)
        self.n_det = z(np.int64)

        # finished lanes are harvested into these and repacked away, so the
        # iteration cost tracks the number of *live* lanes, not the batch size
        self.lane_id = np.arange(L)
        self.out_makespan = z(np.float64)
        self.out_n_faults = z(np.int64)
        self.out_n_pro = z(np.int64)
        self.out_n_reg = z(np.int64)
        self.out_n_mig = z(np.int64)
        self.out_exhausted = z(bool)
        self.out_n_disk = z(np.int64)
        self.out_n_det = z(np.int64)

    #: per-lane state sliced on repack (2-D trace arrays included)
    _LANE_ATTRS = (
        "W", "C", "D", "R", "M", "T_R", "T_P", "mode", "horizon", "window",
        "C2", "R2", "V", "fmem", "rho", "kv",
        "t", "saved", "unsaved", "period_work", "na_saved",
        "ep_t0", "ep_end", "ep_ft", "fi", "pi",
        "n_faults", "n_pro", "n_reg", "n_mig",
        "saved_d", "dk_ctr", "rc", "saved_v", "ck_v", "corrupt",
        "n_disk", "n_det",
        "phase", "done", "exhausted", "lane_id",
        "F", "Fcancel", "P0", "Pft", "Ftier",
    )

    def _derived(self) -> None:
        """Per-lane constants, recomputed whenever lanes are repacked."""
        self.lanes = np.arange(self.t.shape[0])
        self.DR = self.D + self.R
        self.DR2 = self.D + self.R2
        self.wpp = np.maximum(self.T_R - self.C, 1e-9)
        self.lead_act = np.where(self.mode == _M_MIGRATION, self.M, self.C)
        self.tp_eff_default = np.maximum(self.C, self.window)
        self.tl_m = self.mode == _M_TWO_LEVEL
        self.sil_m = self.mode == _M_SILENT
        self.has_tl = bool(self.tl_m.any())
        self.has_sil = bool(self.sil_m.any())

    def _harvest(self, rows: np.ndarray) -> None:
        ids = self.lane_id[rows]
        self.out_makespan[ids] = self.t[rows]
        self.out_n_faults[ids] = self.n_faults[rows]
        self.out_n_pro[ids] = self.n_pro[rows]
        self.out_n_reg[ids] = self.n_reg[rows]
        self.out_n_mig[ids] = self.n_mig[rows]
        self.out_exhausted[ids] = self.exhausted[rows]
        self.out_n_disk[ids] = self.n_disk[rows]
        self.out_n_det[ids] = self.n_det[rows]

    def _repack(self, keep: np.ndarray) -> None:
        for name in self._LANE_ATTRS:
            setattr(self, name, getattr(self, name)[keep])

    def run(self, max_iters: int = 50_000_000) -> BatchResult:
        it = 0
        self._derived()
        while True:
            live = self.t.shape[0]
            done = self.done
            n_done = int(np.count_nonzero(done))
            if n_done == live:
                self._harvest(done)
                break
            if n_done and (n_done * 2 >= live or live - n_done <= 16):
                self._harvest(done)
                self._repack(~done)
                self._derived()
            L = self.t.shape[0]
            lanes = self.lanes
            DR = self.DR
            wpp = self.wpp
            lead_act = self.lead_act
            tp_eff_default = self.tp_eff_default
            it += 1
            if it > max_iters:  # pragma: no cover
                raise RuntimeError("batch simulator did not converge")

            prim = np.zeros(L, dtype=np.int8)
            target = np.zeros(L)
            credit = np.zeros(L, dtype=bool)
            cont = np.full(L, -1, dtype=np.int8)
            occ = np.bincount(self.phase, minlength=8)

            # ---- regular-mode decisions -------------------------------- #
            if occ[_PH_MAIN]:
                mn = self.phase == _PH_MAIN
                idx = np.flatnonzero(mn)
                while idx.size:  # skip predictions whose action point passed
                    adv = (
                        self.P0[idx, self.pi[idx]] - lead_act[idx] < self.t[idx]
                    )
                    idx = idx[adv]
                    self.pi[idx] += 1
                na = self.P0[lanes, self.pi] - lead_act
                self._fast_forward(mn, na, lanes, wpp)
                # horizon check after fast-forward: ff'd periods never finish
                # the job, so a crossing is observed at this (real) loop top
                # exactly as the scalar engine would at a period boundary
                self.exhausted |= mn & (self.t > self.horizon)
                remaining = wpp - self.period_work
                ck = mn & (remaining <= _EPS)
                prim[ck] = _PR_CKPT
                cont[ck] = _C_CKPTREG
                self.na_saved[ck] = na[ck]
                wk_na = mn & ~ck & (na < self.t + remaining)
                prim[wk_na] = _PR_WORK
                target[wk_na] = na[wk_na]
                credit[wk_na] = True
                cont[wk_na] = _C_POP_EP
                wk_seg = mn & ~ck & ~wk_na
                prim[wk_seg] = _PR_WORK
                target[wk_seg] = (self.t + remaining)[wk_seg]
                credit[wk_seg] = True
                cont[wk_seg] = _C_MAIN

            # ---- episode entry ----------------------------------------- #
            if occ[_PH_EP_START]:
                eidx = np.flatnonzero(self.phase == _PH_EP_START)
                emig = self.mode[eidx] == _M_MIGRATION
                mig_i = eidx[emig]
                if mig_i.size:
                    # the predicted fault hits the vacated node: cancel it
                    ftv = self.ep_ft[mig_i]
                    can_i = mig_i[~np.isnan(ftv) & (ftv >= self.t[mig_i])]
                    if can_i.size:
                        rows = self.F[can_i]
                        cols = np.arange(rows.shape[1])[None, :]
                        match = (
                            (rows == self.ep_ft[can_i, None])
                            & (cols >= self.fi[can_i, None])
                            & ~self.Fcancel[can_i]
                        )
                        has = match.any(axis=1)
                        j = match.argmax(axis=1)
                        self.Fcancel[can_i[has], j[has]] = True
                    prim[mig_i] = _PR_IDLE
                    target[mig_i] = self.ep_t0[mig_i]
                    cont[mig_i] = _C_MIG
                rest_i = eidx[~emig]
                if rest_i.size:
                    d = self.ep_t0[rest_i] - self.C[rest_i]
                    tr = self.t[rest_i]
                    b1 = tr < d  # room for the pre-window checkpoint
                    b2 = ~b1 & (tr <= d)  # exactly at t0 - C
                    b3 = ~b1 & ~b2  # no time for the extra checkpoint
                    i1 = rest_i[b1]
                    prim[i1] = _PR_WORK
                    target[i1] = d[b1]
                    credit[i1] = True
                    cont[i1] = _C_PRECKPT
                    i2 = rest_i[b2]
                    prim[i2] = _PR_CKPT
                    cont[i2] = _C_MODE
                    i3 = rest_i[b3]
                    prim[i3] = _PR_WORK
                    target[i3] = tr[b3]  # max(t, t0 - C) == t here
                    credit[i3] = True
                    cont[i3] = _C_NT2

            # ---- pending episode primitives ---------------------------- #
            if occ[_PH_EP_PRECKPT]:
                i = np.flatnonzero(self.phase == _PH_EP_PRECKPT)
                prim[i] = _PR_CKPT
                cont[i] = _C_MODE

            if occ[_PH_EP_NT2]:
                i = np.flatnonzero(self.phase == _PH_EP_NT2)
                prim[i] = _PR_WORK
                target[i] = self.ep_t0[i]
                cont[i] = _C_MODE

            if occ[_PH_EP_NOCKPT]:
                i = np.flatnonzero(self.phase == _PH_EP_NOCKPT)
                prim[i] = _PR_WORK
                target[i] = self.ep_end[i]
                cont[i] = _C_MAIN

            if occ[_PH_EP_WC]:
                widx = np.flatnonzero(self.phase == _PH_EP_WC)
                over = self.t[widx] >= self.ep_end[widx] - _EPS
                self.phase[widx[over]] = _PH_MAIN  # window exhausted
                gidx = widx[~over]
                if gidx.size:
                    tp = self.T_P[gidx]
                    tp = np.where(np.isnan(tp), tp_eff_default[gidx], tp)
                    cg = self.C[gidx]
                    seg = np.minimum(
                        self.t[gidx] + (tp - cg), self.ep_end[gidx] - cg
                    )
                    wsel = seg > self.t[gidx]
                    iw = gidx[wsel]
                    prim[iw] = _PR_WORK
                    target[iw] = seg[wsel]
                    cont[iw] = _C_WC_CKPT
                    ik = gidx[~wsel]
                    prim[ik] = _PR_CKPT
                    cont[ik] = _C_WC

            if occ[_PH_EP_WC_CKPT]:
                i = np.flatnonzero(self.phase == _PH_EP_WC_CKPT)
                prim[i] = _PR_CKPT
                cont[i] = _C_WC

            # ---- execute one primitive per lane ------------------------ #
            workm = prim == _PR_WORK
            idlem = prim == _PR_IDLE
            ckm = prim == _PR_CKPT
            if workm.any():  # cap at job completion, pre-resolution clock
                remw = self.W - self.saved - self.unsaved
                target[workm] = np.minimum(target[workm], (self.t + remw)[workm])
            ckend = np.where(ckm, self.t + self.C, 0.0)
            # intent masks fixed with the end date: the rho-th regular ckpt
            # of a two-level lane is the disk tier (cost C + C2); the k_V-th
            # regular ckpt of a silent-error lane verifies (cost C + V).
            # Proactive ckpts hit the memory tier and never verify.
            reg_int = ckm & (cont == _C_CKPTREG)
            disk_int = reg_int & self.tl_m & (self.dk_ctr >= self.rho - 1)
            ver_int = reg_int & self.sil_m & (self.ck_v >= self.kv - 1)
            ckend[disk_int] += self.C2[disk_int]
            ckend[ver_int] += self.V[ver_int]

            # resolve stale faults (fault during downtime: recovery restarts;
            # rc is the duration of the repair in progress — D+R everywhere
            # except after a two-level disk recovery — and silent-error
            # strikes are not fail-stop events, so those lanes skip the
            # cascade entirely)
            res = workm | idlem | ckm
            idx = np.flatnonzero(res & ~self.sil_m)
            while idx.size:
                curf = self.F[idx, self.fi[idx]]
                curc = self.Fcancel[idx, self.fi[idx]]
                step = curc | (curf < self.t[idx])
                if not step.any():
                    break
                idx = idx[step]
                f = curf[step]
                hit = ~curc[step] & (f >= self.t[idx] - self.rc[idx])
                sub = idx[hit]
                self.n_faults[sub] += 1
                self.t[sub] = f[hit] + self.rc[sub]
                self.fi[idx] += 1
            nf = self.F[lanes, self.fi]
            # silent strikes never interrupt a primitive (latent until the
            # next verification): mask them out of the fail-stop check
            nf_k = np.where(self.sil_m, np.inf, nf) if self.has_sil else nf

            faulted = ((workm | idlem) & (nf_k <= target)) | (ckm & (nf_k < ckend))
            ok = res & ~faulted
            if faulted.any():
                if self.has_tl:
                    # tier coin consumed with the fault (column read before
                    # the cursor advances): u >= f sends recovery to disk
                    u = self.Ftier[lanes, self.fi]
                    disk = faulted & self.tl_m & (u >= self.fmem)
                    mem = faulted & self.tl_m & ~disk
                self.fi[faulted] += 1
                self.n_faults[faulted] += 1
                self.unsaved[faulted] = 0.0
                self.period_work[faulted] = 0.0
                self.t[faulted] = nf[faulted] + DR[faulted]
                self.phase[faulted] = _PH_MAIN
                if self.has_tl:
                    self.rc[mem] = DR[mem]
                    # disk-tier recovery: restart from the last disk ckpt
                    self.t[disk] = nf[disk] + self.DR2[disk]
                    self.saved[disk] = self.saved_d[disk]
                    self.dk_ctr[disk] = 0
                    self.rc[disk] = self.DR2[disk]
                    self.n_disk[disk] += 1

            wok = workm & ok
            if wok.any():
                dt = target - self.t
                self.unsaved[wok] += dt[wok]
                cw = wok & credit
                self.period_work[cw] += dt[cw]
                self.t[wok] = target[wok]
                fin = wok & (self.saved + self.unsaved >= self.W - _EPS)
                self.done[fin] = True
                self.phase[fin] = _PH_DONE
            if idlem.any():
                iok = idlem & ok
                self.t[iok] = target[iok]
            cok = ckm & ok
            if cok.any():
                self.t[cok] = ckend[cok]
                self.saved[cok] += self.unsaved[cok]
                self.unsaved[cok] = 0.0
                reg = cok & (cont == _C_CKPTREG)  # only regular ckpts use it
                self.n_pro[cok & ~reg] += 1
                self.n_reg[reg] += 1
                self.period_work[reg] = 0.0

            if self.has_tl and cok.any():
                # completed disk-tier ckpt: promote the durable frontier;
                # completed memory-tier regular ckpt: advance the nesting
                # counter (proactive ckpts hit the memory tier but do not)
                dk = cok & disk_int
                self.saved_d[dk] = self.saved[dk]
                self.dk_ctr[dk] = 0
                self.dk_ctr[
                    cok & self.tl_m & (cont == _C_CKPTREG) & ~disk_int
                ] += 1

            if self.has_sil:
                # consume latent strikes up to the new clock: they corrupt
                # state silently instead of interrupting the primitive
                sidx = np.flatnonzero(res & self.sil_m)
                while sidx.size:
                    curf = self.F[sidx, self.fi[sidx]]
                    hit = curf <= self.t[sidx]
                    if not hit.any():
                        break
                    sidx = sidx[hit]
                    self.corrupt[sidx] = np.minimum(
                        self.corrupt[sidx], curf[hit]
                    )
                    self.fi[sidx] += 1
                if cok.any():
                    vok = cok & ver_int
                    det = vok & np.isfinite(self.corrupt)
                    if det.any():
                        # verification caught a latent corruption: roll back
                        # past every unverified ckpt to the verified frontier
                        self.t[det] += DR[det]
                        self.saved[det] = self.saved_v[det]
                        self.period_work[det] = 0.0
                        self.ck_v[det] = 0
                        self.corrupt[det] = np.inf
                        self.n_faults[det] += 1
                        self.n_det[det] += 1
                    clean = vok & ~det
                    self.saved_v[clean] = self.saved[clean]
                    self.ck_v[clean] = 0
                    self.ck_v[
                        cok & self.sil_m & (cont == _C_CKPTREG) & ~ver_int
                    ] += 1

            # ---- continuations on success ------------------------------ #
            cidx = np.flatnonzero(ok & ~self.done)
            cc = cont[cidx]
            # simple continuations resolve through one phase lookup; the
            # special codes get a placeholder (MAIN) and are patched below
            self.phase[cidx] = _CONT2PH[cc]

            mig_idx = cidx[cc == _C_MIG]
            if mig_idx.size:
                self.n_mig[mig_idx] += 1

            mode_idx = cidx[cc == _C_MODE]
            if mode_idx.size:
                self.phase[mode_idx] = _MODE2PH[self.mode[mode_idx]]

            pop_idx = cidx[cc == _C_POP_EP]
            if pop_idx.size:
                self._pop_pred(pop_idx)
                self.phase[pop_idx] = _PH_EP_START

            ckr_idx = cidx[cc == _C_CKPTREG]
            if ckr_idx.size:
                # the action point fell inside the regular checkpoint: the
                # episode starts right after it completes (if still in the
                # future), else the prediction is consumed and dropped
                p0 = self.P0[ckr_idx, self.pi[ckr_idx]]
                take = (self.na_saved[ckr_idx] <= self.t[ckr_idx]) & np.isfinite(p0)
                tidx = ckr_idx[take]
                if tidx.size:
                    good = p0[take] >= self.t[tidx] - 1e-9
                    self._pop_pred(tidx)
                    self.phase[tidx[good]] = _PH_EP_START

        return BatchResult(
            makespan=self.out_makespan,
            work=self.work_full,
            n_faults=self.out_n_faults,
            n_proactive_ckpts=self.out_n_pro,
            n_regular_ckpts=self.out_n_reg,
            n_migrations=self.out_n_mig,
            trace_exhausted=self.out_exhausted,
            n_disk_recoveries=self.out_n_disk,
            n_detections=self.out_n_det,
        )

    def _fast_forward(
        self, mn: np.ndarray, na: np.ndarray, lanes: np.ndarray, wpp: np.ndarray
    ) -> None:
        """Collapse runs of *clean* regular periods into one array update.

        A period is clean when it is entered at a fresh checkpoint boundary
        (no partial period work, no unsaved work) and contains no fault, no
        prediction action point, and does not finish the job: the scalar
        engine then deterministically executes work(T_R - C) + checkpoint(C),
        advancing ``t`` by T_R and ``saved`` by T_R - C.  Fusing ``k`` such
        periods changes only float rounding (k fused multiplies vs k
        sequential adds, ~ulp-level drift on the makespan), never the event
        sequence.
        """
        idx = np.flatnonzero(
            mn & (self.period_work == 0.0) & (self.unsaved == 0.0)
        )
        if not idx.size:
            return
        fi = self.fi[idx]
        curf = self.F[idx, fi]
        keep = (curf >= self.t[idx]) & ~self.Fcancel[idx, fi]
        idx = idx[keep]
        if not idx.size:
            return
        curf = curf[keep]
        t = self.t[idx]
        t_r = self.T_R[idx]
        w = wpp[idx]
        na_i = na[idx]
        w_job = self.W[idx]
        sv = self.saved[idx]
        k_fault = np.floor((curf - t) / t_r)
        k_act = np.floor((na_i - t) / t_r)
        # a checkpoint ending exactly at the action point still triggers
        # the episode (na <= t at completion): exclude that period
        k_act = np.where(t + k_act * t_r >= na_i, k_act - 1.0, k_act)
        k_done = np.floor((w_job - sv - _EPS) / w)
        # the k-th period's work must not itself complete the job
        # (scalar done-check: saved + unsaved >= W - eps)
        k_done = np.where(sv + k_done * w >= w_job - _EPS, k_done - 1.0, k_done)
        k = np.minimum(np.minimum(k_fault, k_act), np.minimum(k_done, 4e15))
        if self.has_tl or self.has_sil:
            # never fuse across a disk-tier or verification checkpoint (they
            # cost more than C): cap the run at the current stride remainder
            cap = np.full(idx.shape[0], 4e15)
            tl = self.tl_m[idx]
            sl = self.sil_m[idx]
            cap[tl] = (self.rho[idx] - 1 - self.dk_ctr[idx])[tl]
            cap[sl] = (self.kv[idx] - 1 - self.ck_v[idx])[sl]
            k = np.minimum(k, np.maximum(cap, 0.0))
        ff = k >= 2.0
        if not ff.any():
            return
        idx = idx[ff]
        k = k[ff]
        self.t[idx] += k * self.T_R[idx]
        self.saved[idx] += k * wpp[idx]
        kk = k.astype(np.int64)
        self.n_reg[idx] += kk
        if self.has_tl:
            tl = self.tl_m[idx]
            self.dk_ctr[idx[tl]] += kk[tl]
        if self.has_sil:
            sl = self.sil_m[idx]
            self.ck_v[idx[sl]] += kk[sl]

    def _pop_pred(self, idx: np.ndarray) -> None:
        pi = self.pi[idx]
        t0v = self.P0[idx, pi]
        self.ep_t0[idx] = t0v
        self.ep_ft[idx] = self.Pft[idx, pi]
        self.ep_end[idx] = t0v + self.window[idx]
        self.pi[idx] = pi + 1


def simulate_batch(
    work,
    platform: Union[Platform, Sequence[Platform]],
    strategy: Union[Strategy, Sequence[Strategy]],
    traces: Union[BatchTraces, TraceSpec],
    rng: Optional[np.random.Generator] = None,
    max_iters: int = 50_000_000,
) -> BatchResult:
    """Simulate every lane of ``traces`` simultaneously.

    ``work``, ``platform`` and ``strategy`` are either shared by all lanes or
    per-lane sequences of length ``traces.n_lanes``.  ``rng`` is only
    consulted for fractional trust probabilities ``0 < q < 1``.

    A :class:`TraceSpec` (device-generation stream layout) is accepted by
    replaying its counter streams on the host (:meth:`TraceSpec.
    materialize`) — the validation bridge between the device-generated
    and host-generated paths.
    """
    if isinstance(traces, TraceSpec):
        traces = traces.materialize()
    L = traces.n_lanes
    (
        W, C, D, R, M, T_R, T_P, mode, q, C2, R2, V, fmem, rho, kv
    ) = _lane_params(work, platform, strategy, L)
    p_t0, p_ft, _ = _filter_trusted(traces, q, mode, rng)
    tl = mode == _M_TWO_LEVEL
    if (
        tl.any()
        and float(fmem[tl].max()) > 0.0
        and getattr(traces, "fault_tier", None) is None
    ):
        raise ValueError(
            "two-level lanes with f > 0 need per-fault tier draws: generate "
            "traces with make_event_traces_batch(..., tier=True)"
        )
    eng = _BatchEngine(
        W, C, D, R, M, T_R, T_P, mode, traces, p_t0, p_ft,
        C2=C2, R2=R2, V=V, fmem=fmem, rho=rho, kv=kv,
    )
    return eng.run(max_iters=max_iters)
