"""JAX/Pallas device simulation engine (``engine="jax"``).

Design note — the device lane semantics (mirrors ``batch_sim.py``)
==================================================================

This module re-expresses the NumPy lane-per-trace engine
(:mod:`repro.core.batch_sim`) as a *fixed-shape masked computation* that
jit-compiles to a single XLA while-loop, unlocking Monte-Carlo campaigns
(10^4-10^5 traces) the interpreter-bound engines cannot reach:

* **Stacked lane-state pytree** — every per-lane quantity of the NumPy
  engine (clock ``t``, ``saved``/``unsaved`` work, fault/prediction
  cursors ``fi``/``pi``, phase code, event counters, the mutable
  fault-cancellation mask) becomes one device array of shape ``(L,)``
  (``(L, F)`` for the cancellation mask) carried through
  ``lax.while_loop``.
* **Masked phase decisions** — the NumPy engine's boolean-index writes
  (``prim[ck] = ...``) become ``jnp.where`` merges keyed on the phase
  codes captured at the top of the iteration; every lane advances by
  exactly one primitive per outer iteration, exactly as in NumPy.
* **No live-lane repacking** — the NumPy engine compacts finished lanes
  away; here a finished lane goes *inert* (phase ``DONE`` masks every
  update) because fixed shapes are what lets XLA fuse each iteration
  into a handful of kernels.  Host-side ``chunk`` scheduling recovers
  the lost-work bound (and the memory bound) for very large grids.
* **Data-dependent inner loops** — skipping predictions whose action
  point passed, and cascading faults that strike during downtime, are
  nested ``lax.while_loop``s whose bodies advance *all* affected lanes
  per pass; they terminate in a few passes since each pass consumes one
  event per active lane.
* **Pallas hot step** — the masked primitive execution (fault check +
  work/idle/checkpoint update) is the dense elementwise block run every
  iteration; it executes as a Pallas kernel
  (:mod:`repro.kernels.sim_step`), interpret-mode off-TPU, with a
  pure-jnp fallback (``use_pallas=False``) that shares the same body.
* **Lane-sharded multi-device dispatch** — lanes are mutually
  independent, so ``devices=`` splits each chunk into equal per-device
  shards and runs the *same* compiled step on every device through a
  collective-free ``jax.pmap``; per-lane results are identical to the
  single-device path for any device count (each lane executes the same
  primitive sequence regardless of which lanes co-reside), and each
  device's while-loop exits as soon as its own shard finishes.
* **Async double-buffered chunk pipeline** — chunk packing is pure host
  NumPy and dispatch is JAX-async, so the scheduler packs and ships
  chunk ``k+1`` while chunk ``k`` executes, then fetches results one
  chunk behind the dispatch front (``copy_to_host_async`` first, so the
  D2H copies overlap too).  State buffers are donated to the executable.
* **Two-level compilation cache** — an in-process runner registry keyed
  on the (pallas, precision, migration, device-set) specialization, plus
  JAX's persistent compilation cache (:func:`enable_compilation_cache`
  or ``REPRO_JAX_CACHE_DIR``) so repeated sweep *processes* skip XLA
  recompiles of the same chunk shapes entirely.

Because this engine and the NumPy engine execute the same primitive
sequence in the same order, their makespans agree to float rounding when
run in float64 (``precision="x64"``, the default off-TPU; TPUs have no
f64 and fall back to f32).  Trust filtering happens host-side through
the NumPy engine's own filter, so the deterministic trust settings
``q in {0, 1}`` used by all paper strategies are trace-identical across
the scalar, NumPy-batch, and JAX engines.
"""

from __future__ import annotations

import contextlib
import os
import warnings
from functools import partial
from typing import Optional, Sequence, Union

import numpy as np

from . import batch_sim as B
from .batch_sim import BatchResult, pad_lane_axis
from .events import BatchTraces, pad_sentinel
from .simulator import Strategy, _EPS
from .waste import Platform

__all__ = [
    "simulate_batch_jax",
    "enable_compilation_cache",
    "LANE_TILE",
    "SHARD_TILE",
]

#: lane-count granularity: 8 f32 sublanes x 128 lanes, the Pallas tile
LANE_TILE = 1024

#: per-device lane granularity of the sharded dispatch (the Pallas row
#: width): small enough that 8-way sharding of a cache-sized CPU chunk
#: still leaves every device a few tiles, large enough to stay tiled
SHARD_TILE = 128

#: environment knob: point it at a directory to persist compiled
#: executables across processes (see :func:`enable_compilation_cache`)
CACHE_ENV = "REPRO_JAX_CACHE_DIR"

#: default chunks: bound device-resident lanes so 100k-lane grids don't
#: OOM (and bound the inert-lane overhead of the no-repacking design).
#: On CPU a cache-sized chunk beats one giant batch; accelerators want
#: large chunks to stay utilization-bound.
_DEFAULT_CHUNK_CPU = 5120
_DEFAULT_CHUNK_DEV = 16384


def _jit_run(consts, state, *, use_pallas, interpret, max_iters, eps,
             has_migration):
    import jax
    import jax.numpy as jnp
    from jax import lax

    from ..kernels.sim_step import (
        FLAG_CKPT_OK, FLAG_FAULTED, FLAG_FIN, FLAG_OK, FLAG_REG,
        PRIM_WORK_NC, masked_primitive_update, primitive_update,
    )

    CONT2PH = jnp.asarray(B._CONT2PH, jnp.int32)
    MODE2PH = jnp.asarray(B._MODE2PH, jnp.int32)

    # event arrays are (events, lanes): cursor gathers a[cursor[l], l]
    # then touch a handful of contiguous (L,)-rows (lanes advance through
    # their traces roughly in step), not one element per 2 KB row of the
    # (lanes, events) layout — the difference between L1 hits and L cache
    # misses per gather, several times per iteration
    F, P0, Pft = consts["F"], consts["P0"], consts["Pft"]
    W, C, DR = consts["W"], consts["C"], consts["DR"]
    T_R, T_P, mode = consts["T_R"], consts["T_P"], consts["mode"]
    horizon, window = consts["horizon"], consts["window"]
    wpp, lead_act = consts["wpp"], consts["lead_act"]
    tp_eff_default = consts["tp_eff_default"]
    frows = jnp.arange(F.shape[0], dtype=jnp.int32)[:, None]

    def take(a, idx):
        return jnp.take_along_axis(a, idx[None, :], axis=0)[0]

    def step(carry):
        it, st = carry
        t = st["t"]
        saved, unsaved = st["saved"], st["unsaved"]
        period_work, na_saved = st["period_work"], st["na_saved"]
        ep_t0, ep_end = st["ep_t0"], st["ep_end"]
        fi, pi = st["fi"], st["pi"]
        phase = st["phase"]  # PH_DONE marks finished lanes (no done array)
        # lanes that can migrate carry the fault-cancellation mask; all
        # other sweeps compile a specialized step without it (it would
        # cost an (L, F) carry copy + three gathers every iteration)
        Fcancel = st["Fcancel"] if has_migration else None
        ep_ft = st["ep_ft"] if has_migration else None

        prim = jnp.zeros_like(phase)  # int32, PRIM_NOOP
        target = jnp.zeros_like(t)
        cont = jnp.full_like(phase, -1)

        # ---- regular-mode decisions -------------------------------- #
        mn = phase == B._PH_MAIN

        def p_cond(pi_):  # skip predictions whose action point passed
            return jnp.any(mn & (take(P0, pi_) - lead_act < t))

        def p_body(pi_):
            adv = mn & (take(P0, pi_) - lead_act < t)
            return pi_ + adv.astype(pi_.dtype)

        pi = lax.while_loop(p_cond, p_body, pi)
        na = take(P0, pi) - lead_act

        # clean-period fast-forward (same fusion rule as the NumPy engine)
        curf = take(F, fi)
        ffm = (
            mn & (period_work == 0.0) & (unsaved == 0.0) & (curf >= t)
        )
        if has_migration:
            ffm &= ~take(Fcancel, fi)
        k_fault = jnp.floor((curf - t) / T_R)
        k_act = jnp.floor((na - t) / T_R)
        k_act = jnp.where(t + k_act * T_R >= na, k_act - 1.0, k_act)
        k_done = jnp.floor((W - saved - eps) / wpp)
        k_done = jnp.where(
            saved + k_done * wpp >= W - eps, k_done - 1.0, k_done
        )
        k = jnp.minimum(
            jnp.minimum(k_fault, k_act), jnp.minimum(k_done, 4e15)
        )
        ff = ffm & (k >= 2.0)
        t = jnp.where(ff, t + k * T_R, t)
        saved = jnp.where(ff, saved + k * wpp, saved)
        n_reg = st["n_reg"] + jnp.where(ff, k, 0.0).astype(st["n_reg"].dtype)

        exhausted = st["exhausted"] | (mn & (t > horizon))
        remaining = wpp - period_work
        ck = mn & (remaining <= eps)
        prim = jnp.where(ck, B._PR_CKPT, prim)
        cont = jnp.where(ck, B._C_CKPTREG, cont)
        na_saved = jnp.where(ck, na, na_saved)
        wk_na = mn & ~ck & (na < t + remaining)
        wk_seg = mn & ~ck & ~wk_na
        prim = jnp.where(wk_na | wk_seg, B._PR_WORK, prim)  # credited work
        target = jnp.where(wk_na, na, jnp.where(wk_seg, t + remaining, target))
        cont = jnp.where(wk_na, B._C_POP_EP, jnp.where(wk_seg, B._C_MAIN, cont))

        # ---- episode entry ----------------------------------------- #
        # occupancy-gated (the NumPy engine's bincount gate): episode
        # phases are empty on the vast majority of iterations.  The big
        # Fcancel buffer stays OUT of the gating conds — an identity
        # branch would copy it every iteration.
        es = phase == B._PH_EP_START
        emig = es & (mode == B._M_MIGRATION)
        if has_migration:
            # the predicted fault hits the vacated node: cancel it.  The
            # O(L*F) match scan only runs on iterations where some lane
            # migrates; the (row, mask) delta crosses the cond boundary
            # (small arrays), never the Fcancel buffer itself (an
            # identity branch would copy it every iteration), and the
            # mark lands as one fused elementwise OR.
            can = emig & ~jnp.isnan(ep_ft) & (ep_ft >= t)

            def _match(_):
                m = (F == ep_ft[None, :]) & (frows >= fi[None, :]) & ~Fcancel
                return (
                    jnp.argmax(m, axis=0).astype(jnp.int32),
                    can & m.any(axis=0),
                )

            def _nomatch(_):
                return jnp.zeros_like(fi), jnp.zeros_like(can)

            cj, setm = lax.cond(jnp.any(can), _match, _nomatch, 0)
            Fcancel = Fcancel | (setm[None, :] & (frows == cj[None, :]))

        def _ep_start(args):
            prim, target, cont = args
            prim = jnp.where(emig, B._PR_IDLE, prim)
            target = jnp.where(emig, ep_t0, target)
            cont = jnp.where(emig, B._C_MIG, cont)

            rest = es & ~(mode == B._M_MIGRATION)
            d = ep_t0 - C
            b1 = rest & (t < d)  # room for the pre-window checkpoint
            b2 = rest & ~(t < d) & (t <= d)  # exactly at t0 - C
            b3 = rest & (t > d)  # no time for the extra checkpoint
            prim = jnp.where(  # b1/b3: credited work (Alg. 1 line 12)
                b1 | b3, B._PR_WORK, jnp.where(b2, B._PR_CKPT, prim)
            )
            target = jnp.where(b1, d, jnp.where(b3, t, target))
            cont = jnp.where(
                b1, B._C_PRECKPT,
                jnp.where(b2, B._C_MODE, jnp.where(b3, B._C_NT2, cont)),
            )
            return prim, target, cont

        prim, target, cont = lax.cond(
            jnp.any(es), _ep_start, lambda a: a, (prim, target, cont)
        )

        # ---- pending episode primitives ---------------------------- #
        pmk = phase == B._PH_EP_PRECKPT
        prim = jnp.where(pmk, B._PR_CKPT, prim)
        cont = jnp.where(pmk, B._C_MODE, cont)

        nt2 = phase == B._PH_EP_NT2
        prim = jnp.where(nt2, PRIM_WORK_NC, prim)
        target = jnp.where(nt2, ep_t0, target)
        cont = jnp.where(nt2, B._C_MODE, cont)

        nck = phase == B._PH_EP_NOCKPT
        prim = jnp.where(nck, PRIM_WORK_NC, prim)
        target = jnp.where(nck, ep_end, target)
        cont = jnp.where(nck, B._C_MAIN, cont)

        wc = phase == B._PH_EP_WC

        def _wc(args):
            prim, target, cont, phase = args
            over = wc & (t >= ep_end - eps)
            phase = jnp.where(over, B._PH_MAIN, phase)  # window exhausted
            g = wc & ~over
            tp = jnp.where(jnp.isnan(T_P), tp_eff_default, T_P)
            seg = jnp.minimum(t + (tp - C), ep_end - C)
            wsel = g & (seg > t)
            gk = g & ~wsel
            prim = jnp.where(wsel, PRIM_WORK_NC, jnp.where(gk, B._PR_CKPT, prim))
            target = jnp.where(wsel, seg, target)
            cont = jnp.where(wsel, B._C_WC_CKPT, jnp.where(gk, B._C_WC, cont))
            return prim, target, cont, phase

        prim, target, cont, phase = lax.cond(
            jnp.any(wc), _wc, lambda a: a, (prim, target, cont, phase)
        )

        wck = phase == B._PH_EP_WC_CKPT
        prim = jnp.where(wck, B._PR_CKPT, prim)
        cont = jnp.where(wck, B._C_WC, cont)

        # ---- execute one primitive per lane ------------------------ #
        workm = (prim == B._PR_WORK) | (prim == PRIM_WORK_NC)
        ckm = prim == B._PR_CKPT
        res = prim != B._PR_NOOP
        # cap at job completion, pre-resolution clock (scalar order of ops)
        remw = W - saved - unsaved
        target = jnp.where(workm, jnp.minimum(target, t + remw), target)
        ckend = t + C  # only consulted under ckm

        # resolve stale faults (fault during downtime: recovery restarts)
        def s_cond(c):
            t_, fi_, _ = c
            cf = take(F, fi_)
            stale = cf < t_
            if has_migration:
                stale |= take(Fcancel, fi_)
            return jnp.any(res & stale)

        def s_body(c):
            t_, fi_, nflt_ = c
            cf = take(F, fi_)
            if has_migration:
                cc = take(Fcancel, fi_)
                stepm = res & (cc | (cf < t_))
                hit = stepm & ~cc & (cf >= t_ - DR)
            else:
                stepm = res & (cf < t_)
                hit = stepm & (cf >= t_ - DR)
            t_ = jnp.where(hit, cf + DR, t_)
            nflt_ = nflt_ + hit.astype(nflt_.dtype)
            fi_ = fi_ + stepm.astype(fi_.dtype)
            return t_, fi_, nflt_

        t, fi, n_faults = lax.while_loop(
            s_cond, s_body, (t, fi, st["n_faults"])
        )
        nf = take(F, fi)

        upd = masked_primitive_update if use_pallas else primitive_update
        kw = {"interpret": interpret} if use_pallas else {}
        t, saved, unsaved, period_work, flags = upd(
            prim, cont, target, ckend, nf,
            t, saved, unsaved, period_work, W, DR,
            eps=eps, reg_cont=int(B._C_CKPTREG), **kw,
        )
        faulted = (flags & FLAG_FAULTED) != 0
        ok = (flags & FLAG_OK) != 0
        fin = (flags & FLAG_FIN) != 0
        cok = (flags & FLAG_CKPT_OK) != 0
        reg = (flags & FLAG_REG) != 0

        fi = fi + faulted.astype(fi.dtype)
        n_faults = n_faults + faulted.astype(n_faults.dtype)
        phase = jnp.where(faulted, B._PH_MAIN, phase)
        phase = jnp.where(fin, B._PH_DONE, phase)
        n_pro = st["n_pro"] + (cok & ~reg).astype(st["n_pro"].dtype)
        n_reg = n_reg + reg.astype(n_reg.dtype)

        # ---- continuations on success ------------------------------ #
        cmask = ok & (phase != B._PH_DONE)
        cc = jnp.clip(cont, 0, CONT2PH.shape[0] - 1)
        phase = jnp.where(cmask, jnp.take(CONT2PH, cc), phase)

        n_mig = st["n_mig"] + (cmask & (cont == B._C_MIG)).astype(
            st["n_mig"].dtype
        )
        modem = cmask & (cont == B._C_MODE)
        phase = jnp.where(modem, jnp.take(MODE2PH, mode), phase)

        popm = cmask & (cont == B._C_POP_EP)
        ckr = cmask & (cont == B._C_CKPTREG)

        def _pop(args):
            # pop the prediction into the episode registers; for _C_CKPTREG
            # (action point fell inside the regular checkpoint) enter the
            # episode only if the window start is still current.  ep_ft is
            # only consulted by the migration cancel, so the fast path
            # neither carries nor gathers it.
            if has_migration:
                ep_t0, ep_ft, ep_end, pi, phase = args
            else:
                ep_t0, ep_end, pi, phase = args
            p0v = take(P0, pi)
            takep = ckr & (na_saved <= t) & jnp.isfinite(p0v)
            good = takep & (p0v >= t - 1e-9)
            pop = popm | takep
            ep_t0 = jnp.where(pop, p0v, ep_t0)
            ep_end = jnp.where(pop, p0v + window, ep_end)
            pi = pi + pop.astype(pi.dtype)
            phase = jnp.where(popm | good, B._PH_EP_START, phase)
            if has_migration:
                ep_ft = jnp.where(pop, take(Pft, pi - pop.astype(pi.dtype)),
                                  ep_ft)
                return ep_t0, ep_ft, ep_end, pi, phase
            return ep_t0, ep_end, pi, phase

        if has_migration:
            ep_t0, ep_ft, ep_end, pi, phase = lax.cond(
                jnp.any(popm | ckr), _pop, lambda a: a,
                (ep_t0, ep_ft, ep_end, pi, phase),
            )
        else:
            ep_t0, ep_end, pi, phase = lax.cond(
                jnp.any(popm | ckr), _pop, lambda a: a,
                (ep_t0, ep_end, pi, phase),
            )

        st = {
            "t": t, "saved": saved, "unsaved": unsaved,
            "period_work": period_work, "na_saved": na_saved,
            "ep_t0": ep_t0, "ep_end": ep_end,
            "fi": fi, "pi": pi,
            "n_faults": n_faults, "n_pro": n_pro, "n_reg": n_reg,
            "n_mig": n_mig, "phase": phase,
            "exhausted": exhausted,
        }
        if has_migration:
            st["ep_ft"] = ep_ft
            st["Fcancel"] = Fcancel
        return it + 1, st

    def cond(carry):
        it, st = carry
        return jnp.any(st["phase"] != B._PH_DONE) & (it < max_iters)

    n_it, final = lax.while_loop(cond, step, (jnp.int32(0), state))
    final = dict(final); final["_iters"] = n_it
    return final


_RUN_CACHE: dict = {}

_cache_env_done = False


def enable_compilation_cache(path: Union[str, "os.PathLike"]) -> None:
    """Persist compiled engine executables under ``path``.

    Repeated sweep invocations (separate processes hitting the same chunk
    shape / migration specialization) then skip XLA recompiles entirely:
    the in-process registry (``_RUN_CACHE``) already de-duplicates within
    a process, and this extends it across processes via
    ``jax.config.jax_compilation_cache_dir``.  Call it — or export
    ``REPRO_JAX_CACHE_DIR`` — *before the first JAX computation* of the
    process; JAX only picks the cache directory up at backend
    initialization.
    """
    import jax

    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(path))
    for opt, val in (
        # the engine's executables are small and quick to build one by
        # one but numerous (chunk shape x migration x precision), so
        # cache everything regardless of size / compile time
        ("jax_persistent_cache_min_entry_size_bytes", -1),
        ("jax_persistent_cache_min_compile_time_secs", 0.0),
    ):
        try:
            jax.config.update(opt, val)
        except AttributeError:  # pragma: no cover - knob renamed upstream
            pass


def _maybe_enable_cache_from_env() -> None:
    global _cache_env_done
    if _cache_env_done:
        return
    _cache_env_done = True
    path = os.environ.get(CACHE_ENV)
    if path:
        enable_compilation_cache(path)


def _resolve_devices(devices, mesh) -> list:
    """Normalize the ``devices=`` / ``mesh=`` knobs to a device list.

    ``devices`` accepts None (single default device — the bit-stable
    baseline), ``"all"``, an int (first n local devices), or an explicit
    sequence of jax devices; ``mesh`` accepts a ``jax.sharding.Mesh``
    whose device set is used (lane sharding is data-parallel, so only the
    flat device list matters)."""
    import jax

    if mesh is not None:
        if devices is not None:
            raise ValueError("pass either devices= or mesh=, not both")
        devs = [d for d in np.asarray(mesh.devices).flat]
    elif devices is None:
        devs = [jax.devices()[0]]
    elif isinstance(devices, str):
        if devices != "all":
            raise ValueError(f"devices={devices!r} (expected 'all')")
        devs = list(jax.devices())
    elif isinstance(devices, int):
        avail = jax.devices()
        if not 1 <= devices <= len(avail):
            raise ValueError(
                f"devices={devices} but this process has {len(avail)} "
                "jax device(s); use XLA_FLAGS=--xla_force_host_platform_"
                "device_count=N to fake host devices"
            )
        devs = avail[:devices]
    else:
        devs = list(devices)
        if not devs:
            raise ValueError("devices= must name at least one device")
    return devs


def _get_runner(
    use_pallas: bool, interpret: bool, max_iters: int, eps: float,
    has_migration: bool, devs,
):
    import jax

    key = (
        use_pallas, interpret, max_iters, eps, has_migration,
        tuple(d.id for d in devs),
    )
    if key not in _RUN_CACHE:
        step = partial(
            _jit_run, use_pallas=use_pallas, interpret=interpret,
            max_iters=max_iters, eps=eps, has_migration=has_migration,
        )
        if len(devs) == 1:
            _RUN_CACHE[key] = jax.jit(step, donate_argnums=(1,))
        else:
            # lane-sharded dispatch: lanes are mutually independent, so a
            # collective-free pmap over per-device lane blocks runs the
            # exact single-device program n_dev times — per-lane results
            # are identical by construction, and each device's while-loop
            # exits as soon as its own lanes finish
            _RUN_CACHE[key] = jax.pmap(
                step, devices=devs, donate_argnums=(1,)
            )
    return _RUN_CACHE[key]


#: per-lane result arrays pulled back from the device after each chunk
_OUT_KEYS = ("t", "n_faults", "n_pro", "n_reg", "n_mig", "exhausted", "phase")


def _pack_chunk(
    has_migration: bool, sl: slice, n_dev: int, n_pad: int, fdt, idt,
    W, C, D, R, M, T_R, T_P, mode, F, P0, Pft, horizon, window,
):
    """Host-side packing of one lane chunk into engine pytrees.

    Pure NumPy — no device work — so the async pipeline can pack chunk
    ``k+1`` while chunk ``k`` runs on the devices.  ``n_pad`` is the
    total padded lane count (``n_dev`` equal shards); sharded arrays gain
    a leading device axis for the pmap dispatch."""
    shard = n_pad // n_dev

    def lanes(a):  # (n_pad,) -> (n_pad,) | (n_dev, shard)
        return a if n_dev == 1 else a.reshape(n_dev, shard)

    def events(a):  # (n_pad, E) -> (E, n_pad) | (n_dev, E, shard)
        # (events, lanes) device layout — see the gather note in _jit_run
        if n_dev == 1:
            return np.ascontiguousarray(a.T)
        return np.ascontiguousarray(
            a.reshape(n_dev, shard, a.shape[1]).transpose(0, 2, 1)
        )

    def fvec(x, fill=0.0):
        return lanes(pad_lane_axis(x[sl], n_pad, fill).astype(fdt))

    Ch = fvec(C, 1.0)
    Mh = fvec(M, 1.0)
    modeh = lanes(pad_lane_axis(mode[sl], n_pad, 0).astype(np.int32))
    T_Rh = fvec(T_R, 2.0)
    windowh = fvec(window)
    consts = {
        "W": fvec(W, 1.0),
        "C": Ch,
        "DR": fvec(D) + fvec(R),
        "T_R": T_Rh,
        "T_P": fvec(T_P, np.nan),
        "mode": modeh,
        "horizon": fvec(horizon, np.inf),
        "window": windowh,
        "wpp": np.maximum(T_Rh - Ch, 1e-9),
        "lead_act": np.where(modeh == B._M_MIGRATION, Mh, Ch),
        "tp_eff_default": np.maximum(Ch, windowh),
        "F": events(pad_lane_axis(F[sl], n_pad, np.inf).astype(fdt)),
        "P0": events(pad_lane_axis(P0[sl], n_pad, np.inf).astype(fdt)),
        "Pft": events(pad_lane_axis(Pft[sl], n_pad, np.nan).astype(fdt)),
    }
    n_real = sl.stop - sl.start
    phase = np.full(n_pad, B._PH_MAIN, np.int32)
    phase[n_real:] = B._PH_DONE  # padding lanes start inert
    zf = lanes(np.zeros(n_pad, fdt))
    zi = lanes(np.zeros(n_pad, idt))
    state = {
        "t": zf, "saved": zf, "unsaved": zf, "period_work": zf,
        "na_saved": zf, "ep_t0": zf, "ep_end": zf,
        "fi": lanes(np.zeros(n_pad, np.int32)),
        "pi": lanes(np.zeros(n_pad, np.int32)),
        "n_faults": zi, "n_pro": zi, "n_reg": zi, "n_mig": zi,
        "phase": lanes(phase),
        "exhausted": lanes(np.zeros(n_pad, bool)),
    }
    if has_migration:
        state["ep_ft"] = lanes(np.full(n_pad, np.nan, fdt))
        state["Fcancel"] = np.zeros(consts["F"].shape, bool)
    return consts, state


def _dispatch(runner, devs, consts, state):
    """Ship one packed chunk to the device(s) and start it (async)."""
    import jax

    if len(devs) == 1:
        consts = jax.device_put(consts, devs[0])
        state = jax.device_put(state, devs[0])
    else:
        try:  # explicit per-device placement when available
            tm = jax.tree_util.tree_map
            consts, state = (
                jax.device_put_sharded(
                    [tm(lambda a: a[i], tree) for i in range(len(devs))],
                    devs,
                )
                for tree in (consts, state)
            )
        except AttributeError:  # pragma: no cover - pmap splits host arrays
            pass
    with warnings.catch_warnings():
        # state buffers are donated (packed fresh per chunk), but CPU
        # lacks donation: scope the advisory's suppression to this call
        # so user code's own donation warnings stay visible
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        return runner(consts, state)


def _fetch(final, n_real: int):
    """Pull one dispatched chunk's per-lane results back to the host."""
    for k in _OUT_KEYS:  # overlap the D2H copies across arrays
        final[k].copy_to_host_async()
    out = {k: np.asarray(final[k]).reshape(-1)[:n_real] for k in _OUT_KEYS}
    if not (out.pop("phase") == B._PH_DONE).all():  # pragma: no cover
        raise RuntimeError("jax batch simulator did not converge")
    return out


def simulate_batch_jax(
    work,
    platform: Union[Platform, Sequence[Platform]],
    strategy: Union[Strategy, Sequence[Strategy]],
    traces: BatchTraces,
    rng: Optional[np.random.Generator] = None,
    max_iters: int = 5_000_000,
    chunk: Union[int, str, None] = "auto",
    precision: str = "auto",
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    devices=None,
    mesh=None,
) -> BatchResult:
    """Device-resident :func:`repro.core.batch_sim.simulate_batch`.

    Parameters beyond the NumPy engine's:

    chunk       total lanes resident across the device(s) at once
                ("auto": 5120-10240 on CPU — cache-sized chunks beat one
                giant batch there — 16384 per device on accelerators;
                None: the whole batch).
                Chunks share one compiled executable (lane counts are
                padded to the Pallas tile and event widths rounded to
                powers of two).  Host-side packing of chunk ``k+1``
                overlaps device execution of chunk ``k`` (double-buffered
                async pipeline), and results are fetched one chunk
                behind the dispatch front.
    precision   "x64" (default off-TPU; float-rounding agreement with the
                NumPy engine), "x32" (TPU default — no f64 on TPU), or
                "auto".
    use_pallas  run the hot primitive-update step as the Pallas kernel
                (interpret-mode off-TPU); False uses the identical
                pure-jnp body.
    interpret   force/forbid Pallas interpret mode (default: off-TPU).
    devices     shard every chunk's lanes across these devices (None: the
                default device; "all": every local device; an int n: the
                first n local devices; or an explicit device sequence).
                Lanes are independent, so the sharded dispatch is a
                collective-free pmap and per-lane results are *identical*
                to the single-device path for any device count.
    mesh        a ``jax.sharding.Mesh``; shorthand for ``devices=`` over
                its (flattened) device set.  Mutually exclusive with
                ``devices=``.
    """
    import jax

    _maybe_enable_cache_from_env()
    L = traces.n_lanes
    W, C, D, R, M, T_R, T_P, mode, q = B._lane_params(
        work, platform, strategy, L
    )
    if L == 0:
        z = np.zeros(0)
        zi = np.zeros(0, np.int64)
        return BatchResult(z, z, zi, zi, zi, zi, np.zeros(0, bool))
    p_t0, p_ft, _ = B._filter_trusted(traces, q, mode, rng)
    # pow2-rounded sentinel widths: chunks (and similarly-sized batches)
    # hit the same compiled executable
    F = pad_sentinel(traces.fault_times, traces.n_faults, np.inf,
                     round_pow2=True, min_width=8)
    P0 = pad_sentinel(p_t0, traces.n_preds, np.inf,
                      round_pow2=True, min_width=8)
    Pft = pad_sentinel(p_ft, traces.n_preds, np.nan,
                       round_pow2=True, min_width=8)

    devs = _resolve_devices(devices, mesh)
    n_dev = len(devs)
    backend = devs[0].platform
    if precision == "auto":
        precision = "x32" if backend == "tpu" else "x64"
    if interpret is None:
        interpret = backend != "tpu"
    x64 = precision == "x64"

    if chunk == "auto":
        if backend == "cpu":
            # host devices share one cache hierarchy, so bound the TOTAL
            # resident lanes rather than scaling per device; x2 leaves the
            # async pipeline a second chunk in flight (measured optimum
            # across 1-8 forced host devices, see benchmarks/jax_engine)
            chunk = _DEFAULT_CHUNK_CPU * min(n_dev, 2)
        else:
            chunk = _DEFAULT_CHUNK_DEV * n_dev
    chunk = L if chunk is None else min(int(chunk), L)
    # equal per-device shards, padded to the tile; single-device keeps the
    # LANE_TILE quantum so chunk shapes (hence compiled executables) are
    # unchanged from the unsharded engine
    quant = LANE_TILE if n_dev == 1 else SHARD_TILE
    per_dev_lanes = -(-chunk // n_dev)
    shard = -(-per_dev_lanes // quant) * quant
    n_pad = shard * n_dev

    if x64 and not jax.config.jax_enable_x64:
        from jax.experimental import enable_x64

        ctx = enable_x64()
    else:
        ctx = contextlib.nullcontext()
    with ctx:
        fdt = np.float64 if x64 else np.float32
        idt = np.int64 if x64 else np.int32
        outs = []
        pend = None  # the chunk in flight: (dispatched pytree, n_real)
        for lo in range(0, L, chunk):
            sl = slice(lo, min(lo + chunk, L))
            # migration-free chunks compile a specialized step with no
            # fault-cancellation state (most sweeps; much less traffic)
            has_mig = bool((mode[sl] == B._M_MIGRATION).any())
            runner = _get_runner(
                use_pallas, interpret, max_iters, float(_EPS), has_mig, devs
            )
            consts, state = _pack_chunk(
                has_mig, sl, n_dev, n_pad, fdt, idt,
                W, C, D, R, M, T_R, T_P, mode, F, P0, Pft,
                traces.horizon, traces.window,
            )
            disp = _dispatch(runner, devs, consts, state)
            if pend is not None:  # fetch one chunk behind the dispatch
                outs.append(_fetch(*pend))
            pend = (disp, sl.stop - sl.start)
        outs.append(_fetch(*pend))
    cat = {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
    return BatchResult(
        makespan=cat["t"].astype(np.float64),
        work=W,
        n_faults=cat["n_faults"].astype(np.int64),
        n_proactive_ckpts=cat["n_pro"].astype(np.int64),
        n_regular_ckpts=cat["n_reg"].astype(np.int64),
        n_migrations=cat["n_mig"].astype(np.int64),
        trace_exhausted=cat["exhausted"],
    )
