"""JAX/Pallas device simulation engine (``engine="jax"``).

Design note — the device lane semantics (mirrors ``batch_sim.py``)
==================================================================

This module re-expresses the NumPy lane-per-trace engine
(:mod:`repro.core.batch_sim`) as a *fixed-shape masked computation* that
jit-compiles to a single XLA while-loop, unlocking Monte-Carlo campaigns
(10^4-10^5 traces) the interpreter-bound engines cannot reach:

* **Stacked lane-state pytree** — every per-lane quantity of the NumPy
  engine (clock ``t``, ``saved``/``unsaved`` work, fault/prediction
  cursors ``fi``/``pi``, phase code, event counters, the mutable
  fault-cancellation mask) becomes one device array of shape ``(L,)``
  (``(L, F)`` for the cancellation mask) carried through
  ``lax.while_loop``.
* **Masked phase decisions** — the NumPy engine's boolean-index writes
  (``prim[ck] = ...``) become ``jnp.where`` merges keyed on the phase
  codes captured at the top of the iteration; every lane advances by
  exactly one primitive per outer iteration, exactly as in NumPy.
* **No live-lane repacking** — the NumPy engine compacts finished lanes
  away; here a finished lane goes *inert* (phase ``DONE`` masks every
  update) because fixed shapes are what lets XLA fuse each iteration
  into a handful of kernels.  Host-side ``chunk`` scheduling recovers
  the lost-work bound (and the memory bound) for very large grids.
* **Data-dependent inner loops** — skipping predictions whose action
  point passed, and cascading faults that strike during downtime, are
  nested ``lax.while_loop``s whose bodies advance *all* affected lanes
  per pass; they terminate in a few passes since each pass consumes one
  event per active lane.
* **Pallas hot step** — the masked primitive execution (fault check +
  work/idle/checkpoint update) is the dense elementwise block run every
  iteration; it executes as a Pallas kernel
  (:mod:`repro.kernels.sim_step`), interpret-mode off-TPU, with a
  pure-jnp fallback (``use_pallas=False``) that shares the same body.
* **Lane-sharded multi-device dispatch** — lanes are mutually
  independent, so ``devices=`` shards each chunk's lane axis across a
  1-D ``("lanes",)`` mesh via ``shard_map`` (the ``jax.pmap`` runner it
  replaces kept a leading device axis host-side; ``devices=``/``mesh=``
  semantics are unchanged); per-lane results are identical to the
  single-device path for any device count (each lane executes the same
  primitive sequence regardless of which lanes co-reside), and each
  device's while-loop exits as soon as its own shard finishes.  Cell
  tables ride along replicated; ``collect="stats"`` reduces per-cell
  sums with one ``psum`` at chunk end into a *donated on-device
  accumulator*, so per-lane slabs never cross the host boundary — the
  host fetches O(cells) exactly once per call.
* **Async double-buffered chunk pipeline** — chunk packing is pure host
  NumPy and dispatch is JAX-async, so the scheduler packs and ships
  chunk ``k+1`` while chunk ``k`` executes, then fetches results one
  chunk behind the dispatch front (``copy_to_host_async`` first, so the
  D2H copies overlap too).  State buffers are donated to the executable.
* **Two-level compilation cache** — an in-process runner registry keyed
  on the (pallas, precision, migration, device-set) specialization, plus
  JAX's persistent compilation cache (:func:`enable_compilation_cache`
  or ``REPRO_JAX_CACHE_DIR``) so repeated sweep *processes* skip XLA
  recompiles of the same chunk shapes entirely.

Because this engine and the NumPy engine execute the same primitive
sequence in the same order, their makespans agree to float rounding when
run in float64 (``precision="x64"``, the default off-TPU; TPUs have no
f64 and fall back to f32).  Trust filtering happens host-side through
the NumPy engine's own filter, so the deterministic trust settings
``q in {0, 1}`` used by all paper strategies are trace-identical across
the scalar, NumPy-batch, and JAX engines.

Device trace generation (``trace_mode="device"`` / :class:`TraceSpec`)
======================================================================

Passing a :class:`~repro.core.events.TraceSpec` instead of materialized
:class:`~repro.core.events.BatchTraces` moves event generation *inside*
the engine: no host sampling, no sentinel-padded ``(lanes, events)``
slabs, no ``(events, lanes)`` transpose, no host->device event copy —
chunk packing ships O(lanes) scalars and chunking exists purely for
compilation-shape reuse, so multi-million-lane campaigns fit trivially.

**RNG stream layout** (the reproducibility contract; NumPy reference in
:meth:`TraceSpec.materialize`):

* lane ``i`` owns a 64-bit stream id ``spec.stream[i]`` — a *global*
  lane identity that travels with the lane through chunking, sharding
  and ``take``/``tile``, which is what makes results invariant to chunk
  size and device count for a fixed ``(seed, stream)`` assignment.
* per-(lane, kind) subkeys are derived once per chunk:
  ``threefry2x32(seed_words, (stream_lo, stream_hi << 4 | kind))`` with
  the five kinds of :mod:`repro.core.events` (``STREAM_FAULT_GAP``,
  ``STREAM_TP_COIN`` — word 0 the predicted coin, word 1 the window
  offset — ``STREAM_FP_GAP``, ``STREAM_TP_TRUST``, ``STREAM_FP_TRUST``).
* draw ``n`` of a stream is ``SplitMix64(subkey_as_u64, n)`` (x64; the
  x32/TPU fallback is ``threefry2x32(subkey, (n, 0))``) — counter
  indexed, never sequential, so cursors can replay a stream (the strike
  cursor re-walks the lookahead cursor's fault stream) and strategy-side
  draws (trust coins) never perturb trace-side draws.

**O(1) lane cursors** replace the per-lane event rows:

* *strike cursor* ``(sf_ctr, sf_time)`` — the next fault to hit the
  node; refilled by one counter draw when a fault resolves (fused into
  the Pallas hot step) or goes stale during downtime.
* *lookahead cursor* ``(la_ctr, la_time)`` + *pending-TP slot*
  ``(tp_t0, tp_ft, tp_ctr)`` — the fault stream is walked ahead of the
  strike cursor to find the next *visible* true-positive prediction
  (recall coin, then trust coin for fractional ``q``); its window
  position comes from the offset stream.
* *false-prediction cursor* ``(fp_ctr, fp_time)`` — an independent
  renewal stream at the Section 2.3 false-prediction rate.
* the merged prediction head is ``min(tp_t0, fp_time)`` (ties to the
  TP, matching the host generator's stable sort).  True positives are
  consumed in fault order; when a prediction window exceeds the fault
  inter-arrival gap the host path's time-sorted merge can order two TPs
  differently — a distribution-level (not per-trace) difference, which
  is why device-mode equivalence is statistical for ``window > 0`` and
  exact for exact-date predictions.
* *migration cancel slots* ``(ep_fctr, cancel_ctr[3])`` — the
  vacated-node fault is cancelled by counter index instead of an
  ``(L, F)`` mask scan.  Cancellations are set in fault order (TPs are
  consumed in fault order) and retired in fault order (the strike
  cursor visits indices monotonically), so three slots track pending
  cancellations exactly; a fourth *simultaneously pending* cancellation
  (four overlapping migration episodes with undelivered predicted
  faults) is dropped — beyond-pathological under any paper parameters.

Streams retire at the lane's generation horizon (date ``+inf``), exactly
like the host generator's ``(0, horizon]`` clipping.  Equivalence with
the host-generated path is statistical (same laws, different draws);
:meth:`TraceSpec.materialize` replays the identical streams on the host
for exactness tests and KS/accounting fidelity checks.
"""

from __future__ import annotations

import contextlib
import os
import warnings
from collections import OrderedDict
from dataclasses import dataclass
from functools import partial
from typing import Optional, Sequence, Union

import numpy as np

from . import batch_sim as B
from . import events as E
from .batch_sim import BatchResult, pad_lane_axis
from .events import BatchTraces, TraceSpec, pad_sentinel
from .simulator import Strategy, _EPS
from .waste import Platform

__all__ = [
    "simulate_batch_jax",
    "CellSums",
    "default_chunk_lanes",
    "device_interarrival_samples",
    "enable_compilation_cache",
    "LAST_TIMINGS",
    "LANE_TILE",
    "SHARD_TILE",
]

#: host-side time split of the most recent :func:`simulate_batch_jax`
#: call: {"trace_mode", "pack_s", "dispatch_s", "fetch_s", "n_chunks"}.
#: ``pack_s`` is host NumPy packing (events for the host trace mode,
#: O(lanes) scalars for device mode), ``dispatch_s`` device_put + async
#: launch, ``fetch_s`` the device wait + D2H copies.  Benchmarks read it
#: to attribute end-to-end time.
LAST_TIMINGS: dict = {}

#: lane-count granularity: 8 f32 sublanes x 128 lanes, the Pallas tile
LANE_TILE = 1024

#: per-device lane granularity of the sharded dispatch (the Pallas row
#: width): small enough that 8-way sharding of a cache-sized CPU chunk
#: still leaves every device a few tiles, large enough to stay tiled
SHARD_TILE = 128

#: environment knob: point it at a directory to persist compiled
#: executables across processes (see :func:`enable_compilation_cache`)
CACHE_ENV = "REPRO_JAX_CACHE_DIR"

#: default chunks: bound device-resident lanes so 100k-lane grids don't
#: OOM (and bound the inert-lane overhead of the no-repacking design).
#: On CPU a cache-sized chunk beats one giant batch; accelerators want
#: large chunks to stay utilization-bound.  Device trace mode carries no
#: event slabs — its per-lane state is ~50x smaller — so the cache-sized
#: CPU chunk holds twice the lanes (measured optimum at 40960 lanes).
_DEFAULT_CHUNK_CPU = 5120
_DEFAULT_CHUNK_CPU_SPEC = 10240
_DEFAULT_CHUNK_DEV = 16384


def default_chunk_lanes(
    devices=None, mesh=None, trace_mode: str = "device"
) -> int:
    """The lane count ``chunk="auto"`` resolves to for a device set.

    Public so callers that own the chunk loop themselves — the resumable
    campaign runner dispatches one engine call per campaign chunk so it
    can snapshot between them — pick the same measured-optimal chunk as
    the engine's internal pipeline."""
    devs = _resolve_devices(devices, mesh)
    n_dev = len(devs)
    if devs[0].platform == "cpu":
        base = (
            _DEFAULT_CHUNK_CPU_SPEC
            if trace_mode == "device"
            else _DEFAULT_CHUNK_CPU
        )
        return base * min(n_dev, 2)
    return _DEFAULT_CHUNK_DEV * n_dev


def _jit_run(consts, state, *, use_pallas, interpret, max_iters, eps,
             has_migration, has_two_level=False, has_silent=False,
             gen=None, gathered=(), n_seg=0):
    import jax
    import jax.numpy as jnp
    from jax import lax

    from ..kernels.sim_step import (
        FLAG_CKPT_OK, FLAG_FAULTED, FLAG_FIN, FLAG_OK, FLAG_REG,
        PRIM_WORK_NC, cell_gather, counter_uniform, counter_uniform2,
        masked_primitive_update, primitive_update, segment_cell_sums,
        stream_advance, stream_key, threefry2x32,
    )

    # cell multiplexing (fused sweeps): per-cell parameter tables are
    # broadcast to per-lane arrays by the lane -> cell index once per
    # chunk; everything downstream runs the ordinary per-lane program
    cidx = consts.get("cidx")
    if gathered:
        consts = cell_gather(consts, cidx, gathered)

    CONT2PH = jnp.asarray(B._CONT2PH, jnp.int32)
    MODE2PH = jnp.asarray(B._MODE2PH, jnp.int32)

    device_gen = gen is not None
    if device_gen:
        F = P0 = Pft = frows = None
    else:
        # event arrays are (events, lanes): cursor gathers a[cursor[l], l]
        # then touch a handful of contiguous (L,)-rows (lanes advance
        # through their traces roughly in step), not one element per 2 KB
        # row of the (lanes, events) layout — the difference between L1
        # hits and L cache misses per gather, several times per iteration
        F, P0, Pft = consts["F"], consts["P0"], consts["Pft"]
        frows = jnp.arange(F.shape[0], dtype=jnp.int32)[:, None]
    W, C, DR = consts["W"], consts["C"], consts["DR"]
    T_R, T_P, mode = consts["T_R"], consts["T_P"], consts["mode"]
    horizon, window = consts["horizon"], consts["window"]
    wpp, lead_act = consts["wpp"], consts["lead_act"]
    tp_eff_default = consts["tp_eff_default"]
    # two-level / silent-error phase families (specialized out of every
    # other sweep's compiled step, exactly like has_migration)
    tl_m = (mode == B._M_TWO_LEVEL) if has_two_level else None
    sil_m = (mode == B._M_SILENT) if has_silent else None
    if has_two_level:
        C2, DR2 = consts["C2"], consts["DR2"]
        fmem, rho = consts["fmem"], consts["rho"]
        Ftier = None if gen is not None else consts["Ftier"]
    if has_silent:
        V, kv = consts["V"], consts["kv"]

    def take(a, idx):
        return jnp.take_along_axis(a, idx[None, :], axis=0)[0]

    if device_gen:
        # ---- counter-based generator closures (see module docstring) -- #
        f_kind, f_param, fp_kind, fp_param, frac_q = gen
        fdt = horizon.dtype
        mtbf, fp_mean = consts["mtbf"], consts["fp_mean"]
        recall, q_eff = consts["recall"], consts["q_eff"]
        inf = jnp.asarray(jnp.inf, fdt)
        nan = jnp.asarray(jnp.nan, fdt)

        def subkey(kind):
            # Threefry-derived per-(lane, kind) subkeys, once per chunk;
            # packed by stream_key into the per-draw representation
            # (uint64 SplitMix key on x64, the pair itself on x32)
            return stream_key(*threefry2x32(
                consts["s0"], consts["s1"], consts["sid_lo"],
                (consts["sid_hi"] << 4) | jnp.uint32(kind),
            ))

        fg_key = subkey(E.STREAM_FAULT_GAP)
        tc_key = subkey(E.STREAM_TP_COIN)
        fp_key = subkey(E.STREAM_FP_GAP)
        if has_two_level:
            # recovery-tier coin of fault i: counter i of the tier stream
            # (the NumPy twin lives in TraceSpec.materialize)
            tier_key = subkey(E.STREAM_TIER)
        if frac_q:
            tt_key = subkey(E.STREAM_TP_TRUST)
            ft_key = subkey(E.STREAM_FP_TRUST)

        # law-multiplexed sampling: when the chunk mixes failure laws the
        # static (kind, param) specialization is replaced by per-lane law
        # indices + slot parameters gathered from the cell tables, and the
        # gap transform becomes a branchless select (gap_transform_indexed)
        f_law = f_lp = fp_law = fp_lp = None
        if f_kind == "indexed":
            f_law = consts["fault_law"]
            f_lp = (consts["fault_s1"], consts["fault_s2"])
        if fp_kind == "indexed":
            fp_law = consts["fp_law"]
            fp_lp = (consts["fp_s1"], consts["fp_s2"])

        def adv_fault(m, ctr, tm):
            return stream_advance(
                m, ctr, tm, fg_key, mtbf, horizon,
                kind=f_kind, param=f_param, law=f_law, lp=f_lp,
            )

        def adv_fp(m, ctr, tm):
            return stream_advance(
                m, ctr, tm, fp_key, fp_mean, horizon,
                kind=fp_kind, param=fp_param, law=fp_law, lp=fp_lp,
            )

        def tp_consume(m, la_ctr, la_time, tp_t0, tp_ft, tp_ctr):
            """Advance the lookahead fault cursor until the pending-TP
            slot holds the next *visible* true positive (or the stream
            dies at the horizon).  Advance-then-check: each pass draws
            one fault gap + the fused (coin, offset) pair per active
            lane, terminating in ~1/recall expected passes."""

            def cond(c):
                return jnp.any(c[0])

            def body(c):
                act, ctr, tm, t0, ft, tc = c
                ctr, tm = adv_fault(act, ctr, tm)
                u_coin, u_off = counter_uniform2(tc_key, ctr, fdt)
                vis = u_coin < recall
                if frac_q:
                    vis &= counter_uniform(tt_key, ctr, fdt) < q_eff
                alive = jnp.isfinite(tm)
                good = act & vis & alive
                t0 = jnp.where(
                    good, jnp.maximum(0.0, tm - u_off * window), t0
                )
                ft = jnp.where(good, tm, ft)
                tc = jnp.where(good, ctr, tc)
                dead = act & ~alive
                t0 = jnp.where(dead, inf, t0)
                ft = jnp.where(dead, nan, ft)
                act = act & ~(good | dead)
                return act, ctr, tm, t0, ft, tc

            _, la_ctr, la_time, tp_t0, tp_ft, tp_ctr = lax.while_loop(
                cond, body, (m, la_ctr, la_time, tp_t0, tp_ft, tp_ctr)
            )
            return la_ctr, la_time, tp_t0, tp_ft, tp_ctr

        def fp_consume(m, fp_ctr, fp_time):
            """Advance to the next false prediction; with fractional
            trust the stream is thinned by per-event trust coins."""

            def cond(c):
                return jnp.any(c[0])

            def body(c):
                act, ctr, tm = c
                ctr, tm = adv_fp(act, ctr, tm)
                if frac_q:
                    vis = counter_uniform(ft_key, ctr, fdt) < q_eff
                else:
                    vis = jnp.ones_like(act)
                act = act & ~vis & jnp.isfinite(tm)
                return act, ctr, tm

            _, fp_ctr, fp_time = lax.while_loop(
                cond, body, (m, fp_ctr, fp_time)
            )
            return fp_ctr, fp_time

    def step(carry):
        it, st = carry
        t = st["t"]
        saved, unsaved = st["saved"], st["unsaved"]
        period_work, na_saved = st["period_work"], st["na_saved"]
        ep_t0, ep_end = st["ep_t0"], st["ep_end"]
        phase = st["phase"]  # PH_DONE marks finished lanes (no done array)
        n_disk, n_det = st["n_disk"], st["n_det"]
        if has_two_level:
            saved_d, dk_ctr = st["saved_d"], st["dk_ctr"]
            rc = st["rc"]  # duration of the repair in progress
        else:
            rc = DR
        if has_silent:
            saved_v, ck_v = st["saved_v"], st["ck_v"]
            corrupt = st["corrupt"]
        if device_gen:
            fi = pi = None
            sf_ctr, sf_time = st["sf_ctr"], st["sf_time"]
            la_ctr, la_time = st["la_ctr"], st["la_time"]
            tp_t0, tp_ft, tp_ctr = st["tp_t0"], st["tp_ft"], st["tp_ctr"]
            fp_ctr, fp_time = st["fp_ctr"], st["fp_time"]
            if has_migration:
                ep_fctr = st["ep_fctr"]
                # retire cancel slots the strike cursor has passed
                cancels = tuple(
                    jnp.where(sf_ctr > st[k], -1, st[k])
                    for k in ("cancel0", "cancel1", "cancel2")
                )

                def is_cancelled(ctr):
                    return (
                        (ctr == cancels[0]) | (ctr == cancels[1])
                        | (ctr == cancels[2])
                    )
            else:
                ep_fctr = cancels = None
            Fcancel = None
        else:
            fi, pi = st["fi"], st["pi"]
            # lanes that can migrate carry the fault-cancellation mask;
            # all other sweeps compile a specialized step without it (it
            # would cost an (L, F) carry copy + three gathers every
            # iteration)
            Fcancel = st["Fcancel"] if has_migration else None
        ep_ft = st["ep_ft"] if has_migration else None

        prim = jnp.zeros_like(phase)  # int32, PRIM_NOOP
        target = jnp.zeros_like(t)
        cont = jnp.full_like(phase, -1)

        # ---- regular-mode decisions -------------------------------- #
        mn = phase == B._PH_MAIN

        if device_gen:
            # skip predictions whose action point passed: consume from
            # the merged (pending-TP, next-FP) head instead of a cursor
            def p_cond(c):
                tp_t0_, fp_time_ = c[2], c[6]
                head = jnp.minimum(tp_t0_, fp_time_)
                return jnp.any(mn & (head - lead_act < t))

            def p_body(c):
                la_ctr_, la_time_, tp_t0_, tp_ft_, tp_ctr_, fp_ctr_, fp_time_ = c
                head = jnp.minimum(tp_t0_, fp_time_)
                adv = mn & (head - lead_act < t)
                use_tp = adv & (tp_t0_ <= fp_time_)
                la_ctr_, la_time_, tp_t0_, tp_ft_, tp_ctr_ = tp_consume(
                    use_tp, la_ctr_, la_time_, tp_t0_, tp_ft_, tp_ctr_
                )
                fp_ctr_, fp_time_ = fp_consume(
                    adv & ~use_tp, fp_ctr_, fp_time_
                )
                return (la_ctr_, la_time_, tp_t0_, tp_ft_, tp_ctr_,
                        fp_ctr_, fp_time_)

            (la_ctr, la_time, tp_t0, tp_ft, tp_ctr, fp_ctr, fp_time) = (
                lax.while_loop(
                    p_cond, p_body,
                    (la_ctr, la_time, tp_t0, tp_ft, tp_ctr, fp_ctr, fp_time),
                )
            )
            na = jnp.minimum(tp_t0, fp_time) - lead_act
        else:
            def p_cond(pi_):  # skip predictions whose action point passed
                return jnp.any(mn & (take(P0, pi_) - lead_act < t))

            def p_body(pi_):
                adv = mn & (take(P0, pi_) - lead_act < t)
                return pi_ + adv.astype(pi_.dtype)

            pi = lax.while_loop(p_cond, p_body, pi)
            na = take(P0, pi) - lead_act

        # clean-period fast-forward (same fusion rule as the NumPy engine)
        curf = sf_time if device_gen else take(F, fi)
        ffm = (
            mn & (period_work == 0.0) & (unsaved == 0.0) & (curf >= t)
        )
        if has_migration:
            if device_gen:
                ffm &= ~is_cancelled(sf_ctr)
            else:
                ffm &= ~take(Fcancel, fi)
        k_fault = jnp.floor((curf - t) / T_R)
        k_act = jnp.floor((na - t) / T_R)
        k_act = jnp.where(t + k_act * T_R >= na, k_act - 1.0, k_act)
        k_done = jnp.floor((W - saved - eps) / wpp)
        k_done = jnp.where(
            saved + k_done * wpp >= W - eps, k_done - 1.0, k_done
        )
        k = jnp.minimum(
            jnp.minimum(k_fault, k_act), jnp.minimum(k_done, 4e15)
        )
        # never fuse across a disk-tier or verification checkpoint (they
        # cost more than C): cap the run at the current stride remainder
        if has_two_level:
            k = jnp.where(
                tl_m,
                jnp.minimum(k, jnp.maximum(rho - 1.0 - dk_ctr, 0.0)), k,
            )
        if has_silent:
            k = jnp.where(
                sil_m,
                jnp.minimum(k, jnp.maximum(kv - 1.0 - ck_v, 0.0)), k,
            )
        ff = ffm & (k >= 2.0)
        t = jnp.where(ff, t + k * T_R, t)
        saved = jnp.where(ff, saved + k * wpp, saved)
        n_reg = st["n_reg"] + jnp.where(ff, k, 0.0).astype(st["n_reg"].dtype)
        if has_two_level:
            dk_ctr = jnp.where(ff & tl_m, dk_ctr + k, dk_ctr)
        if has_silent:
            ck_v = jnp.where(ff & sil_m, ck_v + k, ck_v)

        exhausted = st["exhausted"] | (mn & (t > horizon))
        remaining = wpp - period_work
        ck = mn & (remaining <= eps)
        prim = jnp.where(ck, B._PR_CKPT, prim)
        cont = jnp.where(ck, B._C_CKPTREG, cont)
        na_saved = jnp.where(ck, na, na_saved)
        wk_na = mn & ~ck & (na < t + remaining)
        wk_seg = mn & ~ck & ~wk_na
        prim = jnp.where(wk_na | wk_seg, B._PR_WORK, prim)  # credited work
        target = jnp.where(wk_na, na, jnp.where(wk_seg, t + remaining, target))
        cont = jnp.where(wk_na, B._C_POP_EP, jnp.where(wk_seg, B._C_MAIN, cont))

        # ---- episode entry ----------------------------------------- #
        # occupancy-gated (the NumPy engine's bincount gate): episode
        # phases are empty on the vast majority of iterations.  The big
        # Fcancel buffer stays OUT of the gating conds — an identity
        # branch would copy it every iteration.
        es = phase == B._PH_EP_START
        emig = es & (mode == B._M_MIGRATION)
        if has_migration:
            # the predicted fault hits the vacated node: cancel it
            can = emig & ~jnp.isnan(ep_ft) & (ep_ft >= t)
            if device_gen:
                # cancel by fault-counter index (stored at pop time) —
                # elementwise merges instead of an (L, F) match scan.
                # Slots fill in fault order and retire in fault order;
                # a fourth simultaneously-pending cancel is dropped.
                c0, c1, c2 = cancels
                f0 = c0 < 0
                f1 = ~f0 & (c1 < 0)
                f2 = ~f0 & ~f1 & (c2 < 0)
                cancels = (
                    jnp.where(can & f0, ep_fctr, c0),
                    jnp.where(can & f1, ep_fctr, c1),
                    jnp.where(can & f2, ep_fctr, c2),
                )
            else:
                # The O(L*F) match scan only runs on iterations where
                # some lane migrates; the (row, mask) delta crosses the
                # cond boundary (small arrays), never the Fcancel buffer
                # itself (an identity branch would copy it every
                # iteration), and the mark lands as one fused
                # elementwise OR.
                def _match(_):
                    m = (
                        (F == ep_ft[None, :])
                        & (frows >= fi[None, :])
                        & ~Fcancel
                    )
                    return (
                        jnp.argmax(m, axis=0).astype(jnp.int32),
                        can & m.any(axis=0),
                    )

                def _nomatch(_):
                    return jnp.zeros_like(fi), jnp.zeros_like(can)

                cj, setm = lax.cond(jnp.any(can), _match, _nomatch, 0)
                Fcancel = Fcancel | (setm[None, :] & (frows == cj[None, :]))

        def _ep_start(args):
            prim, target, cont = args
            prim = jnp.where(emig, B._PR_IDLE, prim)
            target = jnp.where(emig, ep_t0, target)
            cont = jnp.where(emig, B._C_MIG, cont)

            rest = es & ~(mode == B._M_MIGRATION)
            d = ep_t0 - C
            b1 = rest & (t < d)  # room for the pre-window checkpoint
            b2 = rest & ~(t < d) & (t <= d)  # exactly at t0 - C
            b3 = rest & (t > d)  # no time for the extra checkpoint
            prim = jnp.where(  # b1/b3: credited work (Alg. 1 line 12)
                b1 | b3, B._PR_WORK, jnp.where(b2, B._PR_CKPT, prim)
            )
            target = jnp.where(b1, d, jnp.where(b3, t, target))
            cont = jnp.where(
                b1, B._C_PRECKPT,
                jnp.where(b2, B._C_MODE, jnp.where(b3, B._C_NT2, cont)),
            )
            return prim, target, cont

        prim, target, cont = lax.cond(
            jnp.any(es), _ep_start, lambda a: a, (prim, target, cont)
        )

        # ---- pending episode primitives ---------------------------- #
        pmk = phase == B._PH_EP_PRECKPT
        prim = jnp.where(pmk, B._PR_CKPT, prim)
        cont = jnp.where(pmk, B._C_MODE, cont)

        nt2 = phase == B._PH_EP_NT2
        prim = jnp.where(nt2, PRIM_WORK_NC, prim)
        target = jnp.where(nt2, ep_t0, target)
        cont = jnp.where(nt2, B._C_MODE, cont)

        nck = phase == B._PH_EP_NOCKPT
        prim = jnp.where(nck, PRIM_WORK_NC, prim)
        target = jnp.where(nck, ep_end, target)
        cont = jnp.where(nck, B._C_MAIN, cont)

        wc = phase == B._PH_EP_WC

        def _wc(args):
            prim, target, cont, phase = args
            over = wc & (t >= ep_end - eps)
            phase = jnp.where(over, B._PH_MAIN, phase)  # window exhausted
            g = wc & ~over
            tp = jnp.where(jnp.isnan(T_P), tp_eff_default, T_P)
            seg = jnp.minimum(t + (tp - C), ep_end - C)
            wsel = g & (seg > t)
            gk = g & ~wsel
            prim = jnp.where(wsel, PRIM_WORK_NC, jnp.where(gk, B._PR_CKPT, prim))
            target = jnp.where(wsel, seg, target)
            cont = jnp.where(wsel, B._C_WC_CKPT, jnp.where(gk, B._C_WC, cont))
            return prim, target, cont, phase

        prim, target, cont, phase = lax.cond(
            jnp.any(wc), _wc, lambda a: a, (prim, target, cont, phase)
        )

        wck = phase == B._PH_EP_WC_CKPT
        prim = jnp.where(wck, B._PR_CKPT, prim)
        cont = jnp.where(wck, B._C_WC, cont)

        # ---- execute one primitive per lane ------------------------ #
        workm = (prim == B._PR_WORK) | (prim == PRIM_WORK_NC)
        ckm = prim == B._PR_CKPT
        res = prim != B._PR_NOOP
        # cap at job completion, pre-resolution clock (scalar order of ops)
        remw = W - saved - unsaved
        target = jnp.where(workm, jnp.minimum(target, t + remw), target)
        ckend = t + C  # only consulted under ckm
        # intent masks fixed with the end date (before stale-fault
        # resolution, mirroring the NumPy engine): the rho-th regular
        # ckpt of a two-level lane is the disk tier (cost C + C2), the
        # k_V-th regular ckpt of a silent-error lane verifies (cost
        # C + V).  Proactive ckpts hit the memory tier and never verify.
        if has_two_level or has_silent:
            reg_int = ckm & (cont == B._C_CKPTREG)
        if has_two_level:
            disk_int = reg_int & tl_m & (dk_ctr >= rho - 1.0)
            ckend = jnp.where(disk_int, ckend + C2, ckend)
        if has_silent:
            ver_int = reg_int & sil_m & (ck_v >= kv - 1.0)
            ckend = jnp.where(ver_int, ckend + V, ckend)

        # resolve stale faults (fault during downtime: recovery restarts;
        # rc is the duration of the repair in progress — D+R everywhere
        # except after a two-level disk recovery — and silent-error
        # strikes are not fail-stop events, so those lanes skip the
        # cascade entirely)
        res_f = res & ~sil_m if has_silent else res
        if device_gen:
            def s_cond(c):
                t_, ctr_, tm_, _ = c
                stale = tm_ < t_
                if has_migration:
                    stale |= is_cancelled(ctr_)
                return jnp.any(res_f & stale)

            def s_body(c):
                t_, ctr_, tm_, nflt_ = c
                if has_migration:
                    cc = is_cancelled(ctr_)
                    stepm = res_f & (cc | (tm_ < t_))
                    hit = stepm & ~cc & (tm_ >= t_ - rc)
                else:
                    stepm = res_f & (tm_ < t_)
                    hit = stepm & (tm_ >= t_ - rc)
                t_ = jnp.where(hit, tm_ + rc, t_)
                nflt_ = nflt_ + hit.astype(nflt_.dtype)
                ctr_, tm_ = adv_fault(stepm, ctr_, tm_)
                return t_, ctr_, tm_, nflt_

            t, sf_ctr, sf_time, n_faults = lax.while_loop(
                s_cond, s_body, (t, sf_ctr, sf_time, st["n_faults"])
            )
            nf = sf_time
        else:
            def s_cond(c):
                t_, fi_, _ = c
                cf = take(F, fi_)
                stale = cf < t_
                if has_migration:
                    stale |= take(Fcancel, fi_)
                return jnp.any(res_f & stale)

            def s_body(c):
                t_, fi_, nflt_ = c
                cf = take(F, fi_)
                if has_migration:
                    cc = take(Fcancel, fi_)
                    stepm = res_f & (cc | (cf < t_))
                    hit = stepm & ~cc & (cf >= t_ - rc)
                else:
                    stepm = res_f & (cf < t_)
                    hit = stepm & (cf >= t_ - rc)
                t_ = jnp.where(hit, cf + rc, t_)
                nflt_ = nflt_ + hit.astype(nflt_.dtype)
                fi_ = fi_ + stepm.astype(fi_.dtype)
                return t_, fi_, nflt_

            t, fi, n_faults = lax.while_loop(
                s_cond, s_body, (t, fi, st["n_faults"])
            )
            nf = take(F, fi)
        if has_silent:
            # silent strikes never interrupt a primitive (latent until
            # the next verification): mask them off the fail-stop check;
            # the refill inside the kernel is masked on `faulted`, so the
            # strike cursor of a silent lane stays untouched
            nf = jnp.where(sil_m, jnp.asarray(jnp.inf, nf.dtype), nf)
        if has_two_level:
            # tier coin consumed with the fault (read at the
            # pre-consumption cursor): u >= f sends recovery to disk
            if device_gen:
                u_tier = counter_uniform(tier_key, sf_ctr, horizon.dtype)
            else:
                u_tier = take(Ftier, fi)

        upd = masked_primitive_update if use_pallas else primitive_update
        kw = {"interpret": interpret} if use_pallas else {}
        if device_gen:
            # the struck fault is consumed: the sampling step (refill the
            # strike cursor with one counter draw where faulted) is fused
            # into the hot-step kernel itself.  The kernel contract wants
            # stream[2] == nf (the Pallas entry reads the cursor time off
            # the nf input), so the silent lanes' +inf mask rides along
            # and their true cursor — untouched by construction, silent
            # lanes never fault in the kernel — is restored afterwards
            if has_silent:
                sil_ctr, sil_time = sf_ctr, sf_time
            kw["stream"] = (fg_key, sf_ctr, nf, mtbf, horizon)
            if f_kind == "indexed":
                kw["stream"] += (f_law, f_lp[0], f_lp[1])
            kw["gap"] = (f_kind, f_param)
            t, saved, unsaved, period_work, flags, sf_ctr, sf_time = upd(
                prim, cont, target, ckend, nf,
                t, saved, unsaved, period_work, W, DR,
                eps=eps, reg_cont=int(B._C_CKPTREG), **kw,
            )
            if has_silent:
                sf_ctr = jnp.where(sil_m, sil_ctr, sf_ctr)
                sf_time = jnp.where(sil_m, sil_time, sf_time)
        else:
            t, saved, unsaved, period_work, flags = upd(
                prim, cont, target, ckend, nf,
                t, saved, unsaved, period_work, W, DR,
                eps=eps, reg_cont=int(B._C_CKPTREG), **kw,
            )
        faulted = (flags & FLAG_FAULTED) != 0
        ok = (flags & FLAG_OK) != 0
        fin = (flags & FLAG_FIN) != 0
        cok = (flags & FLAG_CKPT_OK) != 0
        reg = (flags & FLAG_REG) != 0

        if not device_gen:
            fi = fi + faulted.astype(fi.dtype)
        n_faults = n_faults + faulted.astype(n_faults.dtype)
        phase = jnp.where(faulted, B._PH_MAIN, phase)
        phase = jnp.where(fin, B._PH_DONE, phase)
        n_pro = st["n_pro"] + (cok & ~reg).astype(st["n_pro"].dtype)
        n_reg = n_reg + reg.astype(n_reg.dtype)

        if has_two_level:
            # disk-tier recovery: restart from the last disk ckpt (the
            # kernel already applied the memory-tier rollback t = nf+DR)
            disk = faulted & tl_m & (u_tier >= fmem)
            mem = faulted & tl_m & ~disk
            t = jnp.where(disk, nf + DR2, t)
            saved = jnp.where(disk, saved_d, saved)
            dk_ctr = jnp.where(disk, 0.0, dk_ctr)
            rc = jnp.where(mem, DR, jnp.where(disk, DR2, rc))
            n_disk = n_disk + disk.astype(n_disk.dtype)
            # completed disk-tier ckpt: promote the durable frontier;
            # completed memory-tier regular ckpt: advance the nesting
            # counter (proactive ckpts hit the memory tier but do not)
            dk = cok & disk_int
            saved_d = jnp.where(dk, saved, saved_d)
            dk_ctr = jnp.where(dk, 0.0, dk_ctr)
            dk_ctr = jnp.where(reg & tl_m & ~disk_int, dk_ctr + 1.0, dk_ctr)

        if has_silent:
            # consume latent strikes up to the new clock: they corrupt
            # state silently instead of interrupting the primitive
            silr = res & sil_m
            if device_gen:
                def sc_cond(c):
                    _, tm_, _ = c
                    return jnp.any(silr & (tm_ <= t))

                def sc_body(c):
                    ctr_, tm_, cor_ = c
                    hit = silr & (tm_ <= t)
                    cor_ = jnp.where(hit, jnp.minimum(cor_, tm_), cor_)
                    ctr_, tm_ = adv_fault(hit, ctr_, tm_)
                    return ctr_, tm_, cor_

                sf_ctr, sf_time, corrupt = lax.while_loop(
                    sc_cond, sc_body, (sf_ctr, sf_time, corrupt)
                )
            else:
                def sc_cond(c):
                    fi_, _ = c
                    return jnp.any(silr & (take(F, fi_) <= t))

                def sc_body(c):
                    fi_, cor_ = c
                    cf = take(F, fi_)
                    hit = silr & (cf <= t)
                    cor_ = jnp.where(hit, jnp.minimum(cor_, cf), cor_)
                    return fi_ + hit.astype(fi_.dtype), cor_

                fi, corrupt = lax.while_loop(
                    sc_cond, sc_body, (fi, corrupt)
                )
            # verification caught a latent corruption: roll back past
            # every unverified ckpt to the verified frontier
            vok = cok & ver_int
            det = vok & jnp.isfinite(corrupt)
            t = jnp.where(det, t + DR, t)
            saved = jnp.where(det, saved_v, saved)
            period_work = jnp.where(det, 0.0, period_work)
            corrupt = jnp.where(
                det, jnp.asarray(jnp.inf, corrupt.dtype), corrupt
            )
            n_faults = n_faults + det.astype(n_faults.dtype)
            n_det = n_det + det.astype(n_det.dtype)
            clean = vok & ~det
            saved_v = jnp.where(clean, saved, saved_v)
            ck_v = jnp.where(vok, 0.0, ck_v)
            ck_v = jnp.where(reg & sil_m & ~ver_int, ck_v + 1.0, ck_v)

        # ---- continuations on success ------------------------------ #
        cmask = ok & (phase != B._PH_DONE)
        cc = jnp.clip(cont, 0, CONT2PH.shape[0] - 1)
        phase = jnp.where(cmask, jnp.take(CONT2PH, cc), phase)

        n_mig = st["n_mig"] + (cmask & (cont == B._C_MIG)).astype(
            st["n_mig"].dtype
        )
        modem = cmask & (cont == B._C_MODE)
        phase = jnp.where(modem, jnp.take(MODE2PH, mode), phase)

        popm = cmask & (cont == B._C_POP_EP)
        ckr = cmask & (cont == B._C_CKPTREG)

        if device_gen:
            def _pop(args):
                # pop the merged-head prediction into the episode
                # registers and refill the consumed cursor; for
                # _C_CKPTREG (action point fell inside the regular
                # checkpoint) enter the episode only if the window start
                # is still current
                if has_migration:
                    (ep_t0, ep_ft, ep_fctr, ep_end, la_ctr, la_time,
                     tp_t0, tp_ft, tp_ctr, fp_ctr, fp_time, phase) = args
                else:
                    (ep_t0, ep_end, la_ctr, la_time,
                     tp_t0, tp_ft, tp_ctr, fp_ctr, fp_time, phase) = args
                p0v = jnp.minimum(tp_t0, fp_time)
                takep = ckr & (na_saved <= t) & jnp.isfinite(p0v)
                good = takep & (p0v >= t - 1e-9)
                pop = popm | takep
                use_tp = pop & (tp_t0 <= fp_time)
                ep_t0 = jnp.where(pop, p0v, ep_t0)
                ep_end = jnp.where(pop, p0v + window, ep_end)
                phase = jnp.where(popm | good, B._PH_EP_START, phase)
                if has_migration:
                    ep_ft = jnp.where(
                        pop, jnp.where(use_tp, tp_ft, nan), ep_ft
                    )
                    ep_fctr = jnp.where(
                        pop, jnp.where(use_tp, tp_ctr, -1), ep_fctr
                    )
                la_ctr, la_time, tp_t0, tp_ft, tp_ctr = tp_consume(
                    use_tp, la_ctr, la_time, tp_t0, tp_ft, tp_ctr
                )
                fp_ctr, fp_time = fp_consume(pop & ~use_tp, fp_ctr, fp_time)
                if has_migration:
                    return (ep_t0, ep_ft, ep_fctr, ep_end, la_ctr, la_time,
                            tp_t0, tp_ft, tp_ctr, fp_ctr, fp_time, phase)
                return (ep_t0, ep_end, la_ctr, la_time,
                        tp_t0, tp_ft, tp_ctr, fp_ctr, fp_time, phase)

            if has_migration:
                (ep_t0, ep_ft, ep_fctr, ep_end, la_ctr, la_time, tp_t0,
                 tp_ft, tp_ctr, fp_ctr, fp_time, phase) = lax.cond(
                    jnp.any(popm | ckr), _pop, lambda a: a,
                    (ep_t0, ep_ft, ep_fctr, ep_end, la_ctr, la_time,
                     tp_t0, tp_ft, tp_ctr, fp_ctr, fp_time, phase),
                )
            else:
                (ep_t0, ep_end, la_ctr, la_time, tp_t0, tp_ft, tp_ctr,
                 fp_ctr, fp_time, phase) = lax.cond(
                    jnp.any(popm | ckr), _pop, lambda a: a,
                    (ep_t0, ep_end, la_ctr, la_time, tp_t0, tp_ft, tp_ctr,
                     fp_ctr, fp_time, phase),
                )
        else:
            def _pop(args):
                # pop the prediction into the episode registers; for
                # _C_CKPTREG (action point fell inside the regular
                # checkpoint) enter the episode only if the window start
                # is still current.  ep_ft is only consulted by the
                # migration cancel, so the fast path neither carries nor
                # gathers it.
                if has_migration:
                    ep_t0, ep_ft, ep_end, pi, phase = args
                else:
                    ep_t0, ep_end, pi, phase = args
                p0v = take(P0, pi)
                takep = ckr & (na_saved <= t) & jnp.isfinite(p0v)
                good = takep & (p0v >= t - 1e-9)
                pop = popm | takep
                ep_t0 = jnp.where(pop, p0v, ep_t0)
                ep_end = jnp.where(pop, p0v + window, ep_end)
                pi = pi + pop.astype(pi.dtype)
                phase = jnp.where(popm | good, B._PH_EP_START, phase)
                if has_migration:
                    ep_ft = jnp.where(
                        pop, take(Pft, pi - pop.astype(pi.dtype)), ep_ft
                    )
                    return ep_t0, ep_ft, ep_end, pi, phase
                return ep_t0, ep_end, pi, phase

            if has_migration:
                ep_t0, ep_ft, ep_end, pi, phase = lax.cond(
                    jnp.any(popm | ckr), _pop, lambda a: a,
                    (ep_t0, ep_ft, ep_end, pi, phase),
                )
            else:
                ep_t0, ep_end, pi, phase = lax.cond(
                    jnp.any(popm | ckr), _pop, lambda a: a,
                    (ep_t0, ep_end, pi, phase),
                )

        st = {
            "t": t, "saved": saved, "unsaved": unsaved,
            "period_work": period_work, "na_saved": na_saved,
            "ep_t0": ep_t0, "ep_end": ep_end,
            "n_faults": n_faults, "n_pro": n_pro, "n_reg": n_reg,
            "n_mig": n_mig, "phase": phase,
            "exhausted": exhausted,
            "n_disk": n_disk, "n_det": n_det,
        }
        if has_two_level:
            st.update(saved_d=saved_d, dk_ctr=dk_ctr, rc=rc)
        if has_silent:
            st.update(saved_v=saved_v, ck_v=ck_v, corrupt=corrupt)
        if device_gen:
            st.update(
                sf_ctr=sf_ctr, sf_time=sf_time,
                la_ctr=la_ctr, la_time=la_time,
                tp_t0=tp_t0, tp_ft=tp_ft, tp_ctr=tp_ctr,
                fp_ctr=fp_ctr, fp_time=fp_time,
            )
            if has_migration:
                st["ep_ft"] = ep_ft
                st["ep_fctr"] = ep_fctr
                st["cancel0"], st["cancel1"], st["cancel2"] = cancels
        else:
            st["fi"] = fi
            st["pi"] = pi
            if has_migration:
                st["ep_ft"] = ep_ft
                st["Fcancel"] = Fcancel
        return it + 1, st

    def cond(carry):
        it, st = carry
        return jnp.any(st["phase"] != B._PH_DONE) & (it < max_iters)

    if device_gen:
        # prime the cursors: first strike fault, first visible TP (walks
        # the lookahead stream), first visible false prediction.  Inert
        # (padding) lanes never activate a stream.
        state = dict(state)
        live = state["phase"] != B._PH_DONE
        neg1 = jnp.full_like(state["phase"], -1)
        zf = jnp.zeros_like(horizon)
        sf_ctr, sf_time = adv_fault(live, neg1, zf)
        pvis = live & (q_eff > 0.0)
        la_ctr, la_time, tp_t0, tp_ft, tp_ctr = tp_consume(
            pvis & (recall > 0.0), neg1, zf,
            jnp.full_like(horizon, jnp.inf), jnp.full_like(horizon, jnp.nan),
            neg1,
        )
        fp_act = pvis & jnp.isfinite(fp_mean)
        fp_ctr, fp_time = fp_consume(fp_act, neg1, zf)
        fp_time = jnp.where(fp_act, fp_time, jnp.asarray(jnp.inf, fdt))
        state.update(
            sf_ctr=sf_ctr, sf_time=sf_time, la_ctr=la_ctr, la_time=la_time,
            tp_t0=tp_t0, tp_ft=tp_ft, tp_ctr=tp_ctr,
            fp_ctr=fp_ctr, fp_time=fp_time,
        )
        if has_migration:
            state["ep_ft"] = jnp.full_like(horizon, jnp.nan)
            state["ep_fctr"] = neg1
            state["cancel0"] = neg1
            state["cancel1"] = neg1
            state["cancel2"] = neg1

    # two-level / silent lane state materializes in-jit (the packers ship
    # none of it); the disk/detection counters ride along unconditionally
    # so the fetch path and the stats reduction see a fixed column set
    state = dict(state)
    zt = jnp.zeros_like(state["t"])
    zctr = jnp.zeros_like(state["n_faults"])
    state.setdefault("n_disk", zctr)
    state.setdefault("n_det", zctr)
    if has_two_level:
        state.setdefault("saved_d", zt)
        state.setdefault("dk_ctr", zt)
        state.setdefault("rc", jnp.broadcast_to(DR, zt.shape) + zt)
    if has_silent:
        state.setdefault("saved_v", zt)
        state.setdefault("ck_v", zt)
        state.setdefault("corrupt", jnp.full_like(state["t"], jnp.inf))

    n_it, final = lax.while_loop(cond, step, (jnp.int32(0), state))
    final = dict(final); final["_iters"] = n_it
    if n_seg:
        # per-cell segment reduction on device: one (n_seg, 13) matrix of
        # Monte-Carlo sums per chunk instead of O(lanes) result fetches.
        # Padding lanes carry the sacrificial pad-row index, so their
        # degenerate waste (t = 0) lands in rows the host drops.
        ft = final["t"]
        fdt2 = ft.dtype
        waste = 1.0 - W / ft
        final["cell_sums"] = segment_cell_sums(
            [
                jnp.ones_like(ft),  # lane count
                ft, ft * ft,  # makespan moments
                waste, waste * waste,  # waste moments
                final["n_faults"].astype(fdt2),
                final["n_pro"].astype(fdt2),
                final["n_reg"].astype(fdt2),
                final["n_mig"].astype(fdt2),
                final["exhausted"].astype(fdt2),
                final["n_disk"].astype(fdt2),
                final["n_det"].astype(fdt2),
                (final["phase"] != B._PH_DONE).astype(fdt2),  # convergence
            ],
            cidx, n_seg,
        )
    return final


#: in-process runner registry, LRU-capped: a long-lived process (the
#: advisor-service path) sweeping many grid shapes would otherwise pin
#: every compiled executable forever.  64 keys comfortably covers any
#: one sweep's working set (pallas x migration x gen x device-set), and
#: evicted runners recompile cheaply through the persistent cache.
_RUN_CACHE: "OrderedDict" = OrderedDict()
_RUN_CACHE_MAX = 64

_cache_env_done = False


def enable_compilation_cache(path: Union[str, "os.PathLike"]) -> None:
    """Persist compiled engine executables under ``path``.

    Repeated sweep invocations (separate processes hitting the same chunk
    shape / migration specialization) then skip XLA recompiles entirely:
    the in-process registry (``_RUN_CACHE``) already de-duplicates within
    a process, and this extends it across processes via
    ``jax.config.jax_compilation_cache_dir``.  Call it — or export
    ``REPRO_JAX_CACHE_DIR`` — *before the first JAX computation* of the
    process; JAX only picks the cache directory up at backend
    initialization.
    """
    import jax

    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(path))
    for opt, val in (
        # the engine's executables are small and quick to build one by
        # one but numerous (chunk shape x migration x precision), so
        # cache everything regardless of size / compile time
        ("jax_persistent_cache_min_entry_size_bytes", -1),
        ("jax_persistent_cache_min_compile_time_secs", 0.0),
    ):
        try:
            jax.config.update(opt, val)
        except AttributeError:  # pragma: no cover - knob renamed upstream
            pass


def _maybe_enable_cache_from_env() -> None:
    global _cache_env_done
    if _cache_env_done:
        return
    _cache_env_done = True
    path = os.environ.get(CACHE_ENV)
    if path:
        enable_compilation_cache(path)


def _resolve_devices(devices, mesh) -> list:
    """Normalize the ``devices=`` / ``mesh=`` knobs to a device list.

    ``devices`` accepts None (single default device — the bit-stable
    baseline), ``"all"``, an int (first n local devices), or an explicit
    sequence of jax devices; ``mesh`` accepts a ``jax.sharding.Mesh``
    whose device set is used (lane sharding is data-parallel, so only the
    flat device list matters)."""
    import jax

    if mesh is not None:
        if devices is not None:
            raise ValueError("pass either devices= or mesh=, not both")
        devs = list(np.asarray(mesh.devices).flat)
    elif devices is None:
        devs = [jax.devices()[0]]
    elif isinstance(devices, str):
        if devices != "all":
            raise ValueError(f"devices={devices!r} (expected 'all')")
        devs = list(jax.devices())
    elif isinstance(devices, int):
        avail = jax.devices()
        if not 1 <= devices <= len(avail):
            raise ValueError(
                f"devices={devices} but this process has {len(avail)} "
                "jax device(s); use XLA_FLAGS=--xla_force_host_platform_"
                "device_count=N to fake host devices"
            )
        devs = avail[:devices]
    else:
        devs = list(devices)
        if not devs:
            raise ValueError("devices= must name at least one device")
    return devs


class _ShardedRunner:
    """shard_map dispatch of the engine step over a 1-D ``("lanes",)``
    mesh.

    Lanes are mutually independent, so every per-lane array is
    partitioned on its lane axis while the O(cells) tables ride along
    replicated — each device runs the exact single-device program on its
    own shard (per-lane results are identical by construction, and each
    device's while-loop exits as soon as its own lanes finish).  In
    stats mode the per-cell segment sums are the *only* collective: one
    ``psum`` at chunk end folds them into the donated replicated
    accumulator, so nothing O(lanes) ever leaves the devices.

    The wrapped ``shard_map`` needs in/out specs matching the exact
    pytree structure, which varies with trace mode and migration state;
    they are built lazily from the first chunk's keys (one jit per key
    structure, cached)."""

    def __init__(self, step, devs, gathered, stats):
        from jax.sharding import Mesh

        self._step = step
        self._devs = devs
        self._gathered = gathered
        self._stats = stats
        self.mesh = Mesh(np.asarray(devs), ("lanes",))
        self._jitted = {}

    def _pspec(self, key):
        from jax.sharding import PartitionSpec as P

        if key in self._gathered:
            return P()  # replicated cell table
        if key in ("F", "P0", "Pft", "Fcancel", "Ftier"):
            return P(None, "lanes")  # (events, lanes) slab
        return P("lanes")

    def place(self, tree: dict) -> dict:
        """Explicitly shard one packed chunk pytree onto the mesh (lane
        arrays split, tables replicated) — no implicit transfers, so the
        dispatch stays legal under ``jax.transfer_guard("disallow")``."""
        import jax
        from jax.sharding import NamedSharding

        return {
            k: jax.device_put(v, NamedSharding(self.mesh, self._pspec(k)))
            for k, v in tree.items()
        }

    def __call__(self, consts, state, *acc):
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        struct = (tuple(sorted(consts)), tuple(sorted(state)))
        fn = self._jitted.get(struct)
        if fn is None:
            cspec = {k: self._pspec(k) for k in consts}
            sspec = {k: self._pspec(k) for k in state}
            step = self._step
            if self._stats:
                def body(c, s, a):
                    cs = step(c, s)["cell_sums"]
                    return a + jax.lax.psum(cs, "lanes")

                fn = jax.jit(
                    shard_map(
                        body, mesh=self.mesh,
                        in_specs=(cspec, sspec, P()), out_specs=P(),
                        check_rep=False,
                    ),
                    donate_argnums=(1, 2),
                )
            else:
                def body(c, s):
                    final = step(c, s)
                    return {k: final[k] for k in _OUT_KEYS}

                fn = jax.jit(
                    shard_map(
                        body, mesh=self.mesh,
                        in_specs=(cspec, sspec),
                        out_specs={k: P("lanes") for k in _OUT_KEYS},
                        check_rep=False,
                    ),
                    donate_argnums=(1,),
                )
            self._jitted[struct] = fn
        return fn(consts, state, *acc)


def _get_runner(
    use_pallas: bool, interpret: bool, max_iters: int, eps: float,
    has_migration: bool, devs, gen=None, gathered=(), n_seg=0,
    stats=False, has_two_level: bool = False, has_silent: bool = False,
):
    import jax

    key = (
        use_pallas, interpret, max_iters, eps, has_migration,
        has_two_level, has_silent,
        tuple(d.id for d in devs), gen, gathered, n_seg, stats,
    )
    runner = _RUN_CACHE.get(key)
    if runner is not None:
        _RUN_CACHE.move_to_end(key)
        return runner
    step = partial(
        _jit_run, use_pallas=use_pallas, interpret=interpret,
        max_iters=max_iters, eps=eps, has_migration=has_migration,
        has_two_level=has_two_level, has_silent=has_silent,
        gen=gen, gathered=gathered, n_seg=n_seg,
    )
    if len(devs) > 1:
        runner = _ShardedRunner(step, devs, gathered, stats)
    elif stats:
        # fold this chunk's per-cell sums into the donated on-device
        # accumulator: the O(lanes) state never crosses the host boundary
        def run_stats(consts, state, acc):
            return acc + step(consts, state)["cell_sums"]

        runner = jax.jit(run_stats, donate_argnums=(1, 2))
    else:
        runner = jax.jit(step, donate_argnums=(1,))
    _RUN_CACHE[key] = runner
    while len(_RUN_CACHE) > _RUN_CACHE_MAX:
        _RUN_CACHE.popitem(last=False)
    return runner


#: per-lane result arrays pulled back from the device after each chunk
_OUT_KEYS = (
    "t", "n_faults", "n_pro", "n_reg", "n_mig", "n_disk", "n_det",
    "exhausted", "phase",
)


def _chunk_state(sl: slice, n_pad: int, fdt, idt):
    """Zeroed per-lane engine state of one chunk (padding lanes inert).

    Every packed array is flat ``(n_pad,)`` regardless of device count —
    the sharded dispatch partitions the lane axis through ``shard_map``
    placement, not a host-side leading device axis."""
    n_real = sl.stop - sl.start
    phase = np.full(n_pad, B._PH_MAIN, np.int32)
    phase[n_real:] = B._PH_DONE  # padding lanes start inert
    zf = np.zeros(n_pad, fdt)
    zi = np.zeros(n_pad, idt)
    state = {
        "t": zf, "saved": zf, "unsaved": zf, "period_work": zf,
        "na_saved": zf, "ep_t0": zf, "ep_end": zf,
        "n_faults": zi, "n_pro": zi, "n_reg": zi, "n_mig": zi,
        "phase": phase,
        "exhausted": np.zeros(n_pad, bool),
    }
    return state


def _pack_scalar_chunk(
    sl: slice, n_pad: int, fdt, idt,
    W, C, D, R, M, T_R, T_P, mode, horizon, window, horizon_fill,
    cidx=None, pad_cell=0, tl=None, sil=None,
):
    """Shared scalar packing of one lane chunk (pure NumPy): the
    per-lane engine constants and zeroed lane state common to both trace
    modes.  Returns ``(fvec, consts, state)`` — the padding helper so
    callers can append their mode-specific arrays.

    ``cidx`` (fused sweeps, per-lane trace layouts) appends the lane ->
    cell index used by the device-side per-cell segment reduction;
    padding lanes map to the sacrificial ``pad_cell`` row."""
    state = _chunk_state(sl, n_pad, fdt, idt)

    def fvec(x, fill=0.0):
        return pad_lane_axis(x[sl], n_pad, fill).astype(fdt)

    Ch = fvec(C, 1.0)
    Mh = fvec(M, 1.0)
    modeh = pad_lane_axis(mode[sl], n_pad, 0).astype(np.int32)
    T_Rh = fvec(T_R, 2.0)
    windowh = fvec(window)
    consts = {
        "W": fvec(W, 1.0),
        "C": Ch,
        "DR": fvec(D) + fvec(R),
        "T_R": T_Rh,
        "T_P": fvec(T_P, np.nan),
        "mode": modeh,
        "horizon": fvec(horizon, horizon_fill),
        "window": windowh,
        "wpp": np.maximum(T_Rh - Ch, 1e-9),
        "lead_act": np.where(modeh == B._M_MIGRATION, Mh, Ch),
        "tp_eff_default": np.maximum(Ch, windowh),
    }
    if tl is not None:
        # two-level lanes: disk-tier cost/recovery, memory-tier
        # probability, nesting stride (benign pad fills, as in the tables)
        C2a, R2a, fmema, rhoa = tl
        consts["C2"] = fvec(C2a)
        consts["DR2"] = fvec(D) + fvec(R2a)
        consts["fmem"] = fvec(fmema)
        consts["rho"] = fvec(rhoa, 1.0)
    if sil is not None:
        Va, kva = sil
        consts["V"] = fvec(Va)
        consts["kv"] = fvec(kva, 1.0)
    if cidx is not None:
        consts["cidx"] = pad_lane_axis(
            cidx[sl].astype(np.int32), n_pad, pad_cell
        )
    return fvec, consts, state


def _stream_consts(spec: TraceSpec, sl: slice, n_pad: int) -> dict:
    """Per-lane RNG stream identity of one chunk: the two seed words and
    the two halves of the 64-bit stream id.  This layout is *the*
    invariant that makes device-generated results chunk-, device-count-
    and dispatch-invariant, so both spec packers share this one
    implementation."""

    def uvec(x):
        return pad_lane_axis(x, n_pad, 0).astype(np.uint32)

    stream = spec.stream[sl]
    return {
        "s0": uvec(np.full(stream.shape, spec.seed & 0xFFFFFFFF, np.int64)),
        "s1": uvec(
            np.full(stream.shape, (spec.seed >> 32) & 0xFFFFFFFF, np.int64)
        ),
        "sid_lo": uvec(stream & 0xFFFFFFFF),
        "sid_hi": uvec((stream >> 32) & 0xFFFFFFFF),
    }


#: consts keys shipped as per-cell tables (and device-gathered by the
#: lane -> cell index) in the fused TraceSpec dispatch
_CELL_TABLE_KEYS = (
    "W", "C", "DR", "T_R", "T_P", "mode", "horizon", "window",
    "wpp", "lead_act", "tp_eff_default", "mtbf", "fp_mean", "recall", "q_eff",
    "fault_law", "fault_s1", "fault_s2", "fp_law", "fp_s1", "fp_s2",
    "C2", "DR2", "V", "fmem", "rho", "kv",
)


def _cell_tables(
    n_cells: int, n_tab: int, fdt,
    W, C, D, R, M, T_R, T_P, mode, horizon, window, horizon_fill,
    mtbf=None, fp_mean=None, recall=None, q_eff=None,
    fault_laws=None, fp_laws=None,
    C2=None, R2=None, V=None, fmem=None, rho=None, kv=None,
) -> dict:
    """Per-cell engine-parameter tables of a fused sweep (pure NumPy).

    One row per experiment cell plus ``n_tab - n_cells`` benign padding
    rows carrying exactly the per-lane packing fills (row ``n_cells`` is
    the sacrificial row padding lanes index), so the device-side gather
    reproduces the unfused per-lane packing bit for bit.  ``n_tab`` is
    rounded up by the caller so grids of similar size share compiled
    executables."""

    def tab(x, fill=0.0, dt=None):
        a = np.full(n_tab, fill, dt or fdt)
        a[:n_cells] = np.asarray(x)
        return a

    Ch = tab(C, 1.0)
    Mh = tab(M, 1.0)
    modeh = tab(mode, 0, np.int32)
    T_Rh = tab(T_R, 2.0)
    windowh = tab(window)
    tables = {
        "W": tab(W, 1.0),
        "C": Ch,
        "DR": tab(np.asarray(D) + np.asarray(R)),
        "T_R": T_Rh,
        "T_P": tab(T_P, np.nan),
        "mode": modeh,
        "horizon": tab(horizon, horizon_fill),
        "window": windowh,
        "wpp": np.maximum(T_Rh - Ch, 1e-9).astype(fdt),
        "lead_act": np.where(modeh == B._M_MIGRATION, Mh, Ch).astype(fdt),
        "tp_eff_default": np.maximum(Ch, windowh).astype(fdt),
    }
    if mtbf is not None:
        tables.update(
            mtbf=tab(mtbf, 1.0),
            fp_mean=tab(fp_mean, np.inf),
            recall=tab(recall),
            q_eff=tab(q_eff),
        )
    if fault_laws is not None:
        # law multiplexing: int32 law index + the two slot parameters of
        # the branchless indexed gap transform, one row per cell (pad
        # rows are benign exponential / zero-slot rows)
        law, lp = fault_laws
        tables.update(
            fault_law=tab(law, 0, np.int32),
            fault_s1=tab(lp[:, 1]),
            fault_s2=tab(lp[:, 2]),
        )
    if fp_laws is not None:
        law, lp = fp_laws
        tables.update(
            fp_law=tab(law, 0, np.int32),
            fp_s1=tab(lp[:, 1]),
            fp_s2=tab(lp[:, 2]),
        )
    if C2 is not None:
        # two-level / silent-error columns (benign pad rows: degenerate
        # strides, zero extra costs, f = 0 sends every failure to disk)
        tables.update(
            C2=tab(C2),
            DR2=tab(np.asarray(D) + np.asarray(R2)),
            V=tab(V),
            fmem=tab(fmem),
            rho=tab(rho, 1.0),
            kv=tab(kv, 1.0),
        )
    return tables


def _pack_chunk_spec_cells(
    tables: dict, spec: TraceSpec, cidx, pad_cell: int,
    sl: slice, n_pad: int, fdt, idt,
):
    """Chunk packing of the fused (cell-indexed) TraceSpec dispatch.

    The engine parameters travel as O(cells) tables (replicated across
    devices by the shard_map placement); the only per-lane payload is
    the int32 cell index plus the RNG stream identity — the leanest
    possible packing, which is what lets one dispatch carry an entire
    paper grid."""
    state = _chunk_state(sl, n_pad, fdt, idt)
    consts = dict(tables)
    consts["cidx"] = pad_lane_axis(
        cidx[sl].astype(np.int32), n_pad, pad_cell
    )
    consts.update(_stream_consts(spec, sl, n_pad))
    return consts, state


def _pack_chunk(
    has_migration: bool, sl: slice, n_pad: int, fdt, idt,
    W, C, D, R, M, T_R, T_P, mode, F, P0, Pft, horizon, window,
    cidx=None, pad_cell=0, tl=None, sil=None, Ftier=None,
):
    """Host-side packing of one lane chunk into engine pytrees.

    Pure NumPy — no device work — so the async pipeline can pack chunk
    ``k+1`` while chunk ``k`` runs on the devices.  ``n_pad`` is the
    total padded lane count; the sharded dispatch splits the lane axis
    at placement time."""
    fvec, consts, state = _pack_scalar_chunk(
        sl, n_pad, fdt, idt,
        W, C, D, R, M, T_R, T_P, mode, horizon, window, np.inf,
        cidx=cidx, pad_cell=pad_cell, tl=tl, sil=sil,
    )

    def events(a):  # (n_pad, E) -> (E, n_pad)
        # (events, lanes) device layout — see the gather note in _jit_run
        return np.ascontiguousarray(a.T)

    consts.update(
        F=events(pad_lane_axis(F[sl], n_pad, np.inf).astype(fdt)),
        P0=events(pad_lane_axis(P0[sl], n_pad, np.inf).astype(fdt)),
        Pft=events(pad_lane_axis(Pft[sl], n_pad, np.nan).astype(fdt)),
    )
    if Ftier is not None:
        # per-fault recovery-tier coins, aligned column for column with F
        consts["Ftier"] = events(
            pad_lane_axis(Ftier[sl], n_pad, 1.0).astype(fdt)
        )
    state["fi"] = np.zeros(n_pad, np.int32)
    state["pi"] = np.zeros(n_pad, np.int32)
    if has_migration:
        state["ep_ft"] = np.full(n_pad, np.nan, fdt)
        state["Fcancel"] = np.zeros(consts["F"].shape, bool)
    return consts, state


def _pack_chunk_spec(
    spec: TraceSpec, fp_mean, q_eff, sl: slice, n_pad: int,
    fdt, idt, W, C, D, R, M, T_R, T_P, mode, cidx=None, pad_cell=0,
    f_laws=None, fp_laws=None, tl=None, sil=None,
):
    """Host-side packing of one lane chunk of a per-lane :class:`TraceSpec`.

    O(lanes) scalars only — no event arrays, no transpose, no
    O(events x lanes) host->device copy; the cursors are primed inside
    the jitted program from the per-lane stream ids, so the async
    pipeline's packing leg is essentially free in device trace mode.
    Padding lanes get horizon -1: every stream dies on its first draw
    (gaps are >= 1e-9), so inert lanes never sample.  ``f_laws`` /
    ``fp_laws`` (mixed-law per-lane specs) append the per-lane law index
    and slot parameters of the indexed gap transform."""
    fvec, consts, state = _pack_scalar_chunk(
        sl, n_pad, fdt, idt,
        W, C, D, R, M, T_R, T_P, mode, spec.horizon, spec.window, -1.0,
        cidx=cidx, pad_cell=pad_cell, tl=tl, sil=sil,
    )

    consts.update(
        mtbf=fvec(spec.mtbf, 1.0),
        fp_mean=fvec(fp_mean, np.inf),
        recall=fvec(spec.recall),
        q_eff=fvec(q_eff),
    )
    if f_laws is not None:
        law, lp = f_laws
        consts.update(
            fault_law=pad_lane_axis(
                law[sl].astype(np.int32), n_pad, 0
            ),
            fault_s1=fvec(lp[:, 1]),
            fault_s2=fvec(lp[:, 2]),
        )
    if fp_laws is not None:
        law, lp = fp_laws
        consts.update(
            fp_law=pad_lane_axis(law[sl].astype(np.int32), n_pad, 0),
            fp_s1=fvec(lp[:, 1]),
            fp_s2=fvec(lp[:, 2]),
        )
    consts.update(_stream_consts(spec, sl, n_pad))
    return consts, state


def _dispatch(runner, devs, consts, state, *acc):
    """Ship one packed chunk to the device(s) and start it (async).

    All transfers are explicit ``device_put``s (sharded placement through
    the runner's mesh when dispatch is multi-device), so engine dispatch
    is legal under ``jax.transfer_guard("disallow")``."""
    import jax

    if isinstance(runner, _ShardedRunner):
        consts = runner.place(consts)
        state = runner.place(state)
    else:
        consts = jax.device_put(consts, devs[0])
        state = jax.device_put(state, devs[0])
    with warnings.catch_warnings():
        # state buffers are donated (packed fresh per chunk), but CPU
        # lacks donation: scope the advisory's suppression to this call
        # so user code's own donation warnings stay visible
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        return runner(consts, state, *acc)


def _acc_init(n_seg: int, fdt, devs):
    """Zeroed on-device ``(n_seg, 13)`` CellSums accumulator.

    Donated through every chunk dispatch of a ``collect="stats"`` call
    (replicated across the lane mesh when sharded) and explicitly
    fetched exactly once at the end — the only O(cells) D2H of the
    whole call."""
    import jax

    z = np.zeros((n_seg, 13), fdt)
    if len(devs) == 1:
        return jax.device_put(z, devs[0])
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    mesh = Mesh(np.asarray(devs), ("lanes",))
    return jax.device_put(z, NamedSharding(mesh, PartitionSpec()))


def _fetch(final, n_real: int):
    """Pull one dispatched chunk's per-lane results back to the host."""
    # the engine's one designed D2H point for per-lane results
    for k in _OUT_KEYS:
        final[k].copy_to_host_async()  # repro-lint: disable=host-sync
    out = {k: np.asarray(final[k])[:n_real] for k in _OUT_KEYS}
    if not (out.pop("phase") == B._PH_DONE).all():  # pragma: no cover
        raise RuntimeError("jax batch simulator did not converge")
    return out


#: column order of the device-side per-cell segment reduction
(
    _CS_N, _CS_T, _CS_T2, _CS_WASTE, _CS_WASTE2, _CS_NF, _CS_NPRO,
    _CS_NREG, _CS_NMIG, _CS_EXH, _CS_DISK, _CS_DET, _CS_NOTDONE,
) = range(13)


@dataclass
class CellSums:
    """Device-reduced per-cell Monte-Carlo sums of a fused sweep
    (``collect="stats"``): every field is an ``(n_cells,)`` array of
    sums over the cell's lanes, reduced on device and fetched as
    O(cells) scalars.  ``mean_*``/``ci95_*`` derive the usual summary
    statistics (CI via the ddof=1 sample variance)."""

    n: np.ndarray
    makespan_sum: np.ndarray
    makespan_sumsq: np.ndarray
    waste_sum: np.ndarray
    waste_sumsq: np.ndarray
    n_faults: np.ndarray
    n_proactive_ckpts: np.ndarray
    n_regular_ckpts: np.ndarray
    n_migrations: np.ndarray
    n_exhausted: np.ndarray
    n_disk_recoveries: np.ndarray
    n_detections: np.ndarray

    @property
    def n_cells(self) -> int:
        return int(self.n.shape[0])

    @staticmethod
    def _mean(s, n):
        with np.errstate(invalid="ignore", divide="ignore"):
            return s / n

    @staticmethod
    def _ci95(s, s2, n):
        with np.errstate(invalid="ignore", divide="ignore"):
            var = np.maximum(s2 - s * s / n, 0.0) / np.maximum(n - 1.0, 1.0)
            return np.where(n >= 2, 1.96 * np.sqrt(var / n), np.nan)

    @property
    def mean_waste(self) -> np.ndarray:
        return self._mean(self.waste_sum, self.n)

    @property
    def ci95_waste(self) -> np.ndarray:
        return self._ci95(self.waste_sum, self.waste_sumsq, self.n)

    @property
    def mean_makespan(self) -> np.ndarray:
        return self._mean(self.makespan_sum, self.n)

    @property
    def ci95_makespan(self) -> np.ndarray:
        return self._ci95(self.makespan_sum, self.makespan_sumsq, self.n)

    @classmethod
    def from_matrix(cls, cs: np.ndarray) -> "CellSums":
        return cls(
            n=cs[:, _CS_N], makespan_sum=cs[:, _CS_T],
            makespan_sumsq=cs[:, _CS_T2], waste_sum=cs[:, _CS_WASTE],
            waste_sumsq=cs[:, _CS_WASTE2], n_faults=cs[:, _CS_NF],
            n_proactive_ckpts=cs[:, _CS_NPRO],
            n_regular_ckpts=cs[:, _CS_NREG], n_migrations=cs[:, _CS_NMIG],
            n_exhausted=cs[:, _CS_EXH],
            n_disk_recoveries=cs[:, _CS_DISK],
            n_detections=cs[:, _CS_DET],
        )

    def as_matrix(self) -> np.ndarray:
        """The ``(n_cells, 12)`` column matrix (``_CS_*`` order, minus
        the internal not-done flag): sums are plain f64 adds, so partial
        sweeps accumulate by matrix addition — the resumable campaign's
        durable accumulator (:mod:`repro.ft.campaign`) is exactly this
        matrix summed chunk by chunk."""
        return np.stack(
            [
                np.asarray(self.n, np.float64),
                np.asarray(self.makespan_sum, np.float64),
                np.asarray(self.makespan_sumsq, np.float64),
                np.asarray(self.waste_sum, np.float64),
                np.asarray(self.waste_sumsq, np.float64),
                np.asarray(self.n_faults, np.float64),
                np.asarray(self.n_proactive_ckpts, np.float64),
                np.asarray(self.n_regular_ckpts, np.float64),
                np.asarray(self.n_migrations, np.float64),
                np.asarray(self.n_exhausted, np.float64),
                np.asarray(self.n_disk_recoveries, np.float64),
                np.asarray(self.n_detections, np.float64),
            ],
            axis=1,
        )


def simulate_batch_jax(
    work,
    platform: Union[Platform, Sequence[Platform]],
    strategy: Union[Strategy, Sequence[Strategy]],
    traces: Union[BatchTraces, TraceSpec],
    rng: Optional[np.random.Generator] = None,
    max_iters: int = 5_000_000,
    chunk: Union[int, str, None] = "auto",
    precision: str = "auto",
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    devices=None,
    mesh=None,
    cell_index=None,
    collect: str = "lanes",
) -> Union[BatchResult, "CellSums"]:
    """Device-resident :func:`repro.core.batch_sim.simulate_batch`.

    ``traces`` is either host-materialized :class:`BatchTraces` (the host
    trace mode) or a :class:`TraceSpec` (device trace mode): events are
    then sampled *inside* the engine from per-lane counter-based RNG
    streams — see the module docstring for the stream layout — and
    ``rng`` is ignored (fractional trust coins come from the lane's own
    trust streams, so results stay chunk- and device-count invariant).

    **Cell multiplexing** (fused experiment sweeps): ``cell_index`` maps
    every lane to one of ``n_cells`` experiment cells, and ``work`` /
    ``platform`` / ``strategy`` then describe *cells* (length
    ``n_cells``) instead of lanes.  With a cell-indexed
    :class:`TraceSpec` (required in device trace mode; defaulting
    ``cell_index`` from the spec) the engine parameters ship as O(cells)
    tables gathered on device.  The failure law itself is one of those
    tables: a cell-indexed spec may carry one ``Distribution`` *per
    cell* (tuple-valued ``fault_dist`` / ``false_pred_dist``), sampled
    through the branchless law-indexed gap transform — so ONE dispatch
    and one compiled executable per grid *shape* can run an entire
    mixed-law paper grid with lanes from many cells interleaved across
    chunks and shards.  Per-lane results are bit-identical to the
    equivalent per-lane call.  ``collect="stats"`` additionally
    segment-reduces per-cell Monte-Carlo sums on device into a donated
    accumulator and returns a :class:`CellSums` (one O(cells) fetch per
    call; per-lane arrays never reach the host) instead of per-lane
    arrays.

    Parameters beyond the NumPy engine's:

    chunk       total lanes resident across the device(s) at once
                ("auto": 5120-10240 on CPU — cache-sized chunks beat one
                giant batch there, and device trace mode fits twice the
                lanes per chunk — 16384 per device on accelerators;
                None: the whole batch).
                Chunks share one compiled executable (lane counts are
                padded to the Pallas tile and event widths rounded to
                powers of two).  Host-side packing of chunk ``k+1``
                overlaps device execution of chunk ``k`` (double-buffered
                async pipeline), and results are fetched one chunk
                behind the dispatch front.
    precision   "x64" (default off-TPU; float-rounding agreement with the
                NumPy engine), "x32" (TPU default — no f64 on TPU), or
                "auto".
    use_pallas  run the hot primitive-update step as the Pallas kernel
                (interpret-mode off-TPU); False uses the identical
                pure-jnp body.
    interpret   force/forbid Pallas interpret mode (default: off-TPU).
    devices     shard every chunk's lanes across these devices (None: the
                default device; "all": every local device; an int n: the
                first n local devices; or an explicit device sequence).
                Lanes are independent, so the sharded dispatch is a
                shard_map over a 1-D lane mesh (collective-free except
                for the single stats psum) and per-lane results are
                *identical* to the single-device path for any device
                count.
    mesh        a ``jax.sharding.Mesh``; shorthand for ``devices=`` over
                its (flattened) device set.  Mutually exclusive with
                ``devices=``.
    cell_index  (L,) int lane -> cell map; work/platform/strategy then
                have one entry per cell.  Defaults to the spec's own
                ``cell_index`` for cell-indexed :class:`TraceSpec`
                traces.
    collect     "lanes" (default): per-lane :class:`BatchResult`;
                "stats" (requires ``cell_index``): device-reduced
                per-cell :class:`CellSums`.
    """
    import time as _time

    import jax

    _maybe_enable_cache_from_env()
    is_spec = isinstance(traces, TraceSpec)
    spec_celled = is_spec and traces.cell_index is not None
    L = traces.n_lanes
    if collect not in ("lanes", "stats"):
        raise ValueError(
            f"unknown collect {collect!r} (expected 'lanes' or 'stats')"
        )
    if cell_index is None and spec_celled:
        cell_index = traces.cell_index
    celled = cell_index is not None
    if collect == "stats" and not celled:
        raise ValueError("collect='stats' requires cell_index")
    if celled and is_spec and not spec_celled:
        raise ValueError(
            "cell_index with a TraceSpec requires the cell-indexed "
            "layout (TraceSpec.cell_index)"
        )
    n_cells = 0
    if celled:
        cidx_g = np.asarray(cell_index, np.int32)
        if cidx_g.shape != (L,):
            raise ValueError(
                f"cell_index must have shape ({L},), got {cidx_g.shape}"
            )
        if spec_celled:
            n_cells = traces.n_cells
            if traces.cell_index is not cell_index and not np.array_equal(
                traces.cell_index, cidx_g
            ):
                raise ValueError(
                    "cell_index does not match traces.cell_index"
                )
        else:
            for arg in (platform, strategy):
                if not isinstance(arg, (Platform, Strategy)):
                    n_cells = len(arg)
                    break
            else:
                n_cells = int(cidx_g.max()) + 1 if L else 0
        if L and (cidx_g.min() < 0 or cidx_g.max() >= n_cells):
            raise ValueError(
                f"cell_index entries must be in [0, {n_cells})"
            )
    W, C, D, R, M, T_R, T_P, mode, q, C2, R2, V, fmem, rho, kv = (
        B._lane_params(work, platform, strategy, n_cells if celled else L)
    )
    if celled and not is_spec:
        # host event arrays are inherently per-lane: broadcast the cell
        # table host-side (cheap NumPy gathers) and keep only the
        # lane -> cell index for the device-side per-cell reduction
        W, C, D, R, M, T_R, T_P, mode, q, C2, R2, V, fmem, rho, kv = (
            a[cidx_g] for a in (
                W, C, D, R, M, T_R, T_P, mode, q, C2, R2, V, fmem, rho, kv
            )
        )
    # two-level / silent phase families are specialized out of every
    # other sweep's compiled step (and its packed payload), like migration
    any_tl = bool((mode == B._M_TWO_LEVEL).any())
    any_sil = bool((mode == B._M_SILENT).any())
    tl_extra = (C2, R2, fmem, rho) if any_tl else None
    sil_extra = (V, kv) if any_sil else None
    if L == 0:
        if collect == "stats":
            return CellSums.from_matrix(np.zeros((n_cells, 13)))
        z = np.zeros(0)
        zi = np.zeros(0, np.int64)
        return BatchResult(z, z, zi, zi, zi, zi, np.zeros(0, bool))
    t_pack = t_dispatch = t_fetch = 0.0
    t0 = _time.monotonic()
    if is_spec:
        def _dist_static(d):
            # mixed-law specs carry one Distribution per cell (or lane):
            # the static (kind, param) specialization collapses to the
            # "indexed" sentinel and the laws travel as data tables
            if isinstance(d, tuple):
                for x in d:
                    E.require_inverse_cdf(x)
                return "indexed", 0.0
            E.require_inverse_cdf(d)
            return d.kind, float(d.param)

        f_kind, f_param = _dist_static(traces.fault_dist)
        fp_kind, fp_param = _dist_static(traces.false_pred_dist)
        f_laws = (
            E.law_table(traces.fault_dist) if f_kind == "indexed" else None
        )
        fp_laws = (
            E.law_table(traces.false_pred_dist)
            if fp_kind == "indexed" else None
        )
        # engine-side trust: mode "none" / q<=0 sees no predictions,
        # fractional q thins both prediction streams via trust coins
        # (per-cell arrays in the fused layout — the gathered per-lane
        # values are identical, so is the compiled program); silent-error
        # lanes never trust the fail-stop predictor
        q_eff = np.where(
            (mode == B._M_NONE) | (mode == B._M_SILENT),
            0.0, np.clip(q, 0.0, 1.0),
        )
        frac_q = bool(((q_eff > 0.0) & (q_eff < 1.0)).any())
        gen = (f_kind, f_param, fp_kind, fp_param, frac_q)
        fp_mean = traces.fp_mean
        F = P0 = Pft = None
    else:
        gen = None
        p_t0, p_ft, _ = B._filter_trusted(traces, q, mode, rng)
        # pow2-rounded sentinel widths: chunks (and similarly-sized
        # batches) hit the same compiled executable
        F = pad_sentinel(traces.fault_times, traces.n_faults, np.inf,
                         round_pow2=True, min_width=8)
        P0 = pad_sentinel(p_t0, traces.n_preds, np.inf,
                          round_pow2=True, min_width=8)
        Pft = pad_sentinel(p_ft, traces.n_preds, np.nan,
                           round_pow2=True, min_width=8)
        if any_tl:
            FT = getattr(traces, "fault_tier", None)
            if FT is None:
                tl_lanes = mode == B._M_TWO_LEVEL
                if float(fmem[tl_lanes].max(initial=0.0)) > 0.0:
                    raise ValueError(
                        "two-level lanes with f > 0 need per-fault tier "
                        "draws: generate traces with "
                        "make_event_traces_batch(..., tier=True)"
                    )
                FT = np.ones_like(traces.fault_times)
            elif FT.shape[1] < traces.fault_times.shape[1]:
                FT = np.concatenate(
                    [FT, np.ones(
                        (FT.shape[0],
                         traces.fault_times.shape[1] - FT.shape[1])
                    )],
                    axis=1,
                )
            Ftier = pad_sentinel(FT, traces.n_faults, 1.0,
                                 round_pow2=True, min_width=8)
        else:
            Ftier = None
    t_pack += _time.monotonic() - t0

    devs = _resolve_devices(devices, mesh)
    n_dev = len(devs)
    backend = devs[0].platform
    if precision == "auto":
        precision = "x32" if backend == "tpu" else "x64"
    if interpret is None:
        interpret = backend != "tpu"
    x64 = precision == "x64"

    if chunk == "auto":
        if backend == "cpu":
            # host devices share one cache hierarchy, so bound the TOTAL
            # resident lanes rather than scaling per device; x2 leaves the
            # async pipeline a second chunk in flight (measured optimum
            # across 1-8 forced host devices, see benchmarks/jax_engine)
            base = _DEFAULT_CHUNK_CPU_SPEC if is_spec else _DEFAULT_CHUNK_CPU
            chunk = base * min(n_dev, 2)
        else:
            chunk = _DEFAULT_CHUNK_DEV * n_dev
    chunk = L if chunk is None else min(int(chunk), L)
    # equal per-device shards, padded to the tile; single-device keeps the
    # LANE_TILE quantum so chunk shapes (hence compiled executables) are
    # unchanged from the unsharded engine
    quant = LANE_TILE if n_dev == 1 else SHARD_TILE
    per_dev_lanes = -(-chunk // n_dev)
    shard = -(-per_dev_lanes // quant) * quant
    n_pad = shard * n_dev

    if x64 and not jax.config.jax_enable_x64:
        from jax.experimental import enable_x64

        ctx = enable_x64()
    else:
        ctx = contextlib.nullcontext()
    # fused sweeps: pad the cell table with benign rows to a power of two
    # (row n_cells is the sacrificial row padding lanes point at), so
    # similarly-sized grids share compiled executables
    want_lanes = collect != "stats"
    if celled:
        n_tab = max(8, 1 << int(n_cells).bit_length())
        gathered = _CELL_TABLE_KEYS if spec_celled else ()
        # the per-cell segment reduction only runs when its output is
        # wanted; lanes-mode celled dispatches skip the reduction work
        n_seg = n_tab if collect == "stats" else 0
    else:
        n_tab = 0
        gathered, n_seg = (), 0

    with ctx:
        fdt = np.float64 if x64 else np.float32
        idt = np.int64 if x64 else np.int32
        tables = None
        if spec_celled:
            tables = _cell_tables(
                n_cells, n_tab, fdt,
                W, C, D, R, M, T_R, T_P, mode,
                traces.horizon, traces.window, -1.0,
                mtbf=traces.mtbf, fp_mean=fp_mean,
                recall=traces.recall, q_eff=q_eff,
                fault_laws=f_laws, fp_laws=fp_laws,
                C2=C2 if (any_tl or any_sil) else None,
                R2=R2, V=V, fmem=fmem, rho=rho, kv=kv,
            )
        acc = None
        if not want_lanes:
            # per-cell sums accumulate *on device* across chunks (a
            # cell's lanes may straddle chunk boundaries): the donated
            # accumulator is carried through every dispatch and fetched
            # exactly once after the loop
            acc = _acc_init(n_seg, fdt, devs)
        outs = []
        pend = None  # the chunk in flight: (dispatched pytree, n_real)
        n_chunks = 0
        for lo in range(0, L, chunk):
            sl = slice(lo, min(lo + chunk, L))
            n_chunks += 1
            # migration-free (and two-level-free, silent-free) chunks
            # compile a specialized step with none of that family's state
            chunk_mode = mode[cidx_g[sl]] if spec_celled else mode[sl]
            has_mig = bool((chunk_mode == B._M_MIGRATION).any())
            has_tl = bool((chunk_mode == B._M_TWO_LEVEL).any())
            has_sil = bool((chunk_mode == B._M_SILENT).any())
            runner = _get_runner(
                use_pallas, interpret, max_iters, float(_EPS), has_mig,
                devs, gen, gathered, n_seg, stats=not want_lanes,
                has_two_level=has_tl, has_silent=has_sil,
            )
            t0 = _time.monotonic()
            if spec_celled:
                consts, state = _pack_chunk_spec_cells(
                    tables, traces, cidx_g, n_cells,
                    sl, n_pad, fdt, idt,
                )
            elif is_spec:
                consts, state = _pack_chunk_spec(
                    traces, fp_mean, q_eff, sl, n_pad, fdt, idt,
                    W, C, D, R, M, T_R, T_P, mode,
                    f_laws=f_laws, fp_laws=fp_laws,
                    tl=tl_extra if has_tl else None,
                    sil=sil_extra if has_sil else None,
                )
            else:
                consts, state = _pack_chunk(
                    has_mig, sl, n_pad, fdt, idt,
                    W, C, D, R, M, T_R, T_P, mode, F, P0, Pft,
                    traces.horizon, traces.window,
                    cidx=cidx_g if celled else None, pad_cell=n_cells,
                    tl=tl_extra if has_tl else None,
                    sil=sil_extra if has_sil else None,
                    Ftier=Ftier if has_tl else None,
                )
            t_pack += _time.monotonic() - t0
            t0 = _time.monotonic()
            if want_lanes:
                disp = _dispatch(runner, devs, consts, state)
                t_dispatch += _time.monotonic() - t0
                if pend is not None:  # fetch one chunk behind the dispatch
                    t0 = _time.monotonic()
                    outs.append(_fetch(*pend))
                    t_fetch += _time.monotonic() - t0
                pend = (disp, sl.stop - sl.start)
            else:
                acc = _dispatch(runner, devs, consts, state, acc)
                t_dispatch += _time.monotonic() - t0
        t0 = _time.monotonic()
        if want_lanes:
            outs.append(_fetch(*pend))
        else:
            # designed D2H point: one O(cells) stats matrix per run
            cs = np.asarray(jax.device_get(acc), np.float64)  # repro-lint: disable=host-sync
        t_fetch += _time.monotonic() - t0
    LAST_TIMINGS.clear()
    LAST_TIMINGS.update(
        trace_mode="device" if is_spec else "host",
        pack_s=t_pack, dispatch_s=t_dispatch, fetch_s=t_fetch,
        n_chunks=n_chunks,
    )
    if not want_lanes:
        if cs[:n_cells, _CS_NOTDONE].sum() != 0.0:  # pragma: no cover
            raise RuntimeError("jax batch simulator did not converge")
        return CellSums.from_matrix(cs[:n_cells])
    cat = {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}
    return BatchResult(
        makespan=cat["t"].astype(np.float64),
        work=W[cidx_g] if spec_celled else W,
        n_faults=cat["n_faults"].astype(np.int64),
        n_proactive_ckpts=cat["n_pro"].astype(np.int64),
        n_regular_ckpts=cat["n_reg"].astype(np.int64),
        n_migrations=cat["n_mig"].astype(np.int64),
        trace_exhausted=cat["exhausted"],
        n_disk_recoveries=cat["n_disk"].astype(np.int64),
        n_detections=cat["n_det"].astype(np.int64),
    )


def device_interarrival_samples(
    dist, mean: float, n: int, seed: int = 0, stream: int = 0
) -> np.ndarray:
    """Draw ``n`` inter-arrival samples through the *device* sampling path
    (jnp threefry + inverse-CDF transform, counters ``0..n-1`` of the
    lane's fault-gap stream) — the exact per-draw function the engine's
    cursors evaluate.  Used by the statistical-fidelity tests (KS against
    the host :class:`~repro.core.events.Distribution` law) and fully
    deterministic in ``(seed, stream)``."""
    import jax
    import jax.numpy as jnp

    from ..kernels.sim_step import gap_transform, splitmix64

    E.require_inverse_cdf(dist)
    if not jax.config.jax_enable_x64:
        from jax.experimental import enable_x64

        ctx = enable_x64()
    else:
        ctx = contextlib.nullcontext()
    with ctx:
        key = E.stream_key64_np(
            seed, np.asarray([stream], np.int64), E.STREAM_FAULT_GAP
        )
        ctr = jnp.arange(n, dtype=jnp.int64)  # event i <-> draw counter i
        x0, x1 = splitmix64(jnp.uint64(int(key[0])), ctr)
        g = gap_transform(
            dist.kind, float(dist.param), jnp.asarray(mean, jnp.float64),
            x0, x1, jnp.float64,
        )
        return np.asarray(g)
