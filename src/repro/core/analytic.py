"""Differentiable analytic waste layer over the fused per-cell tables.

Every closed-form waste model of :mod:`repro.core.waste` exists here a
second time as a *branchless, vectorizable* function of per-cell
parameter columns — the exact ``(n_cells,)`` table layout that
:func:`repro.core.jax_sim._cell_tables` ships to the fused device engine
(``C``/``DR``/``T_R``/``T_P``/``mode``/``window``/``lead_act``/
``mtbf``/``fp_mean``/``recall``/``q_eff`` and the law columns) — so ONE
parameter table drives both the analytic and the simulated half of the
reproduction, with no reshaping in between.  Each function has a jnp
twin in :mod:`repro.kernels.analytic` (registered in
``analysis.twins.TWIN_REGISTRY``); the jnp side is differentiable, which
is what the batched safeguarded-Newton period optimizer runs
:func:`jax.grad` through.

On top sits the unified optimizer entry point

    optimize(strategy, platform, pred, *,
             objective="waste" | "availability",
             method="analytic" | "newton" | "search", ...)

which collapses the per-strategy ``optimize_*`` case analyses, the
``t_*`` period helpers and the simulated ``best_period_search`` behind
one API (those legacy names live on as thin deprecated aliases).
Scalar inputs return an :class:`~repro.core.periods.OptimalPolicy`;
sequence inputs return a :class:`PolicyTable` whose ``method="newton"``
path solves every cell's period in one jitted device dispatch.

Precision note: the predictor's precision is *derived* from the table's
``fp_mean`` column (inverting
:func:`repro.core.events.false_prediction_mtbf`), exactly because the
fused engine ships ``fp_mean`` and not ``precision`` — the analytic
layer consumes the engine's table as-is.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import batch_sim as B
from . import events as E
from . import periods as P
from . import waste as W
from .periods import OptimalPolicy
from .waste import Platform, PredictorModel, i_prime

__all__ = [
    "precision_from_fp",
    "young_waste",
    "exact_waste",
    "migration_waste",
    "instant_waste",
    "nockpt_waste",
    "withckpt_waste",
    "two_level_waste",
    "silent_waste",
    "cell_waste",
    "table_waste",
    "cell_tables",
    "tables_from_cells",
    "analytic_waste_cells",
    "analytic_period_cells",
    "newton_optimize_tables",
    "PolicyTable",
    "optimize",
    "optimize_cells",
]

#: integer strategy-mode codes of the engine tables (values of
#: ``repro.core.batch_sim.MODE_CODES``, fixed by the packing format)
_M_NONE, _M_EXACT, _M_NOCKPT, _M_WITHCKPT, _M_MIGRATION = 0, 1, 2, 3, 4
_M_TWO_LEVEL, _M_SILENT = 5, 6

#: table columns the analytic layer consumes (subset of
#: ``jax_sim._CELL_TABLE_KEYS``), in the positional order of
#: :func:`cell_waste`'s column arguments after ``T``
TABLE_COLS = (
    "mode", "q_eff", "C", "DR", "lead_act", "mtbf", "recall",
    "window", "T_P", "tp_eff_default",
    "C2", "DR2", "V", "fmem", "rho", "kv",
)


# --------------------------------------------------------------------------- #
# Branchless waste models (NumPy side of the jnp twins)
# --------------------------------------------------------------------------- #
# repro-twin: repro.kernels.analytic.precision_from_fp
def precision_from_fp(mu, fp_mean, r):
    """Precision from the table's false-prediction mean inter-arrival.

    Inverts ``fp_mean = p mu / (r (1 - p))`` to ``p = r fp / (mu + r fp)``;
    an infinite ``fp_mean`` (no false predictions) means precision 1."""
    fin = np.isfinite(fp_mean)
    fp = np.where(fin, fp_mean, 1.0)
    return np.where(fin, r * fp / (mu + r * fp), 1.0)


# repro-twin: repro.kernels.analytic.young_waste
def young_waste(T, C, DR, mu):
    """WASTE^{q=0} (Section 3.3): Young's model over table columns."""
    return C / T + (T / 2.0 + DR) / mu


# repro-twin: repro.kernels.analytic.exact_waste
def exact_waste(T, q, C, DR, mu, r, p):
    """Equation (1): exact-date predictions, branchless."""
    p_safe = np.where(r > 0.0, p, 1.0)
    pred_term = np.where(r > 0.0, (q * r / p_safe) * C, 0.0)
    return C / T + ((1.0 - r * q) * T / 2.0 + DR + pred_term) / mu


# repro-twin: repro.kernels.analytic.migration_waste
def migration_waste(T, q, C, DR, M, mu, r, p):
    """Equation (3): proactive migration, branchless."""
    p_safe = np.where(r > 0.0, p, 1.0)
    pred_term = np.where(r > 0.0, (q * r / p_safe) * M, 0.0)
    return C / T + ((1.0 - r * q) * (T / 2.0 + DR) + pred_term) / mu


# repro-twin: repro.kernels.analytic.instant_waste
def instant_waste(T, q, C, DR, mu, r, p, E_f):
    """Equation (5): strategy Instant, branchless."""
    p_safe = np.where(r > 0.0, p, 1.0)
    pred_term = np.where(r > 0.0, (q * r / p_safe) * C, 0.0)
    lost = q * r * np.minimum(E_f, T / 2.0)
    return C / T + ((1.0 - r * q) * T / 2.0 + DR + pred_term + lost) / mu


# repro-twin: repro.kernels.analytic.nockpt_waste
def nockpt_waste(T, q, C, DR, mu, r, p, I, E_f):
    """Equation (6): strategy NoCkptI, branchless.

    The ``r <= 0`` fallback and the validity clamp ``I' <= mu_P`` of the
    scalar model become selects; divisor inputs are substituted with
    benign values on untaken branches so the jnp twin stays
    NaN-free under :func:`jax.grad`."""
    r_safe = np.where(r > 0.0, r, 0.5)
    p_safe = np.where(r > 0.0, p, 1.0)
    m_p = p_safe * mu / r_safe
    m_np = mu / (1.0 - r_safe)
    ip = np.minimum(i_prime(q, p_safe, I, E_f), m_p)
    reg_frac = 1.0 - ip / m_p
    w = (reg_frac / T + q / m_p) * C
    w = w + (p_safe * (1.0 - q) / m_p) * (T / 2.0)
    w = w + (p_safe * q / m_p) * E_f
    w = w + reg_frac / m_np * (T / 2.0)
    w = w + (p_safe / m_p + reg_frac / m_np) * DR
    return np.where(r > 0.0, w, young_waste(T, C, DR, mu))


# repro-twin: repro.kernels.analytic.withckpt_waste
def withckpt_waste(T, T_P, q, C, DR, mu, r, p, I, E_f):
    """Equation (4): strategy WithCkptI, branchless (see nockpt_waste)."""
    r_safe = np.where(r > 0.0, r, 0.5)
    p_safe = np.where(r > 0.0, p, 1.0)
    m_p = p_safe * mu / r_safe
    m_np = mu / (1.0 - r_safe)
    ip = np.minimum(i_prime(q, p_safe, I, E_f), m_p)
    reg_frac = 1.0 - ip / m_p
    w = (reg_frac / T + (ip / m_p) / T_P + q / m_p) * C
    w = w + (p_safe * (1.0 - q) / m_p) * (T / 2.0)
    w = w + (p_safe * q / m_p) * T_P
    w = w + reg_frac / m_np * (T / 2.0)
    w = w + (p_safe / m_p + reg_frac / m_np) * DR
    return np.where(r > 0.0, w, young_waste(T, C, DR, mu))


# repro-twin: repro.kernels.analytic.two_level_waste
def two_level_waste(T_m, T_d, C_m, C_d, D, R_m, R_d, mu, f, r, q, p):
    """Beyond-paper two-level model, branchless over per-cell columns.

    Canonical signature: ``D``/``R_m``/``R_d`` kept separate, exactly as
    in :func:`repro.core.waste.waste_two_level` (callers holding folded
    ``DR`` columns pass ``D=0``: the terms only ever appear summed).
    Prediction shields only the memory-tier work loss — a disk-tier
    failure destroys the proactive memory checkpoint along with the
    tier."""
    w = C_m / T_m + C_d / T_d
    w = w + (
        f * ((1.0 - r * q) * T_m / 2.0 + D + R_m)
        + (1.0 - f) * (T_d / 2.0 + D + R_d)
    ) / mu
    p_safe = np.where(r > 0.0, p, 1.0)
    pred = np.where((r > 0.0) & (q > 0.0), (q * r / p_safe) * C_m / mu, 0.0)
    return w + pred


# repro-twin: repro.kernels.analytic.silent_waste
def silent_waste(T, C, V, DR, mu, k):
    """Silent-error waste (arXiv:1310.8486, see ``waste.waste_silent``)
    branchless over per-cell columns: ``k`` periods per verification, a
    latent corruption forfeits the whole pattern plus recovery ``DR``."""
    return (k * C + V) / (k * T) + (k * T + V + DR) / mu


# repro-twin: repro.kernels.analytic.cell_waste
def cell_waste(
    T, mode, q, C, DR, lead_act, mu, r, p, window, T_P, tp_eff,
    C2, DR2, V, fmem, rho, kv,
):
    """Mode-dispatched waste over the fused engine's per-cell columns.

    Mirrors ``experiments.validation.analytic_waste``'s dispatch as one
    select chain: mode "exact" means Equation (1), or Equation (5) when
    the predictor is window-based; ``lead_act`` is the engine's
    premade migration-or-checkpoint lead column (M for migration cells,
    C otherwise); a NaN ``T_P`` (non-WithCkptI cells' fill) is replaced
    by the table's benign default so every branch stays finite under
    differentiation; and mode "none" / untrusted / recall-free cells
    fall back to Young's model exactly like the scalar dispatch."""
    E_f = 0.5 * window
    tp = np.where(np.isnan(T_P), tp_eff, T_P)
    w_y = young_waste(T, C, DR, mu)
    w = np.where(
        window > 0.0,
        instant_waste(T, q, C, DR, mu, r, p, E_f),
        exact_waste(T, q, C, DR, mu, r, p),
    )
    w = np.where(
        mode == _M_MIGRATION, migration_waste(T, q, C, DR, lead_act, mu, r, p), w
    )
    w = np.where(
        mode == _M_NOCKPT, nockpt_waste(T, q, C, DR, mu, r, p, window, E_f), w
    )
    w = np.where(
        mode == _M_WITHCKPT,
        withckpt_waste(T, tp, q, C, DR, mu, r, p, window, E_f),
        w,
    )
    w = np.where((mode == _M_NONE) | (q <= 0.0) | (r <= 0.0), w_y, w)
    w = np.where(
        mode == _M_TWO_LEVEL,
        two_level_waste(T, rho * T, C, C2, 0.0, DR, DR2, mu, fmem, r, q, p),
        w,
    )
    return np.where(mode == _M_SILENT, silent_waste(T, C, V, DR, mu, kv), w)


def table_waste(T, tables: Dict[str, np.ndarray]) -> np.ndarray:
    """:func:`cell_waste` applied to a ``_cell_tables`` column dict, with
    precision recovered from the ``fp_mean`` column.  Tables predating
    the two-level/silent columns get their benign fills (0/0/0/0/1/1)."""
    C = np.asarray(tables["C"], np.float64)
    z, one = np.zeros_like(C), np.ones_like(C)
    with np.errstate(divide="ignore", invalid="ignore"):
        p = precision_from_fp(tables["mtbf"], tables["fp_mean"], tables["recall"])
        return cell_waste(
            T, tables["mode"], tables["q_eff"], tables["C"], tables["DR"],
            tables["lead_act"], tables["mtbf"], tables["recall"], p,
            tables["window"], tables["T_P"], tables["tp_eff_default"],
            tables.get("C2", z), tables.get("DR2", z), tables.get("V", z),
            tables.get("fmem", z), tables.get("rho", one), tables.get("kv", one),
        )


# --------------------------------------------------------------------------- #
# The shared per-cell parameter table
# --------------------------------------------------------------------------- #
def cell_tables(
    work,
    platforms: Sequence[Platform],
    predictors: Sequence[PredictorModel],
    strategies: Sequence,
    horizon,
    fault_dists=None,
    fp_dists=None,
    n_tab: Optional[int] = None,
    dtype=np.float64,
) -> Dict[str, np.ndarray]:
    """Build the fused engine's per-cell parameter table host-side.

    Delegates to :func:`repro.core.jax_sim._cell_tables` — the one
    packing routine the device dispatch uses — so the analytic layer and
    the simulator consume byte-identical columns.  ``n_tab`` pads with
    the engine's benign rows (for pow2 executable sharing); default is
    no padding."""
    from . import jax_sim as J  # NumPy-only at import; kept lazy like core.__init__

    n = len(strategies)
    Wk, C, D, R, M, T_R, T_P, mode, q, C2, R2, V, fmem, rho, kv = (
        B._lane_params(work, list(platforms), list(strategies), n)
    )
    mtbf = np.asarray([p.mu for p in platforms], dtype=np.float64)
    recall = np.asarray([p.recall for p in predictors], dtype=np.float64)
    precision = np.asarray([p.precision for p in predictors], dtype=np.float64)
    window = np.asarray([p.window for p in predictors], dtype=np.float64)
    fp_mean = E.false_prediction_mtbf_batch(mtbf, recall, precision)
    # silent-error cells never trust the fail-stop predictor
    q_eff = np.where(
        (mode == B._M_NONE) | (mode == B._M_SILENT),
        0.0, np.clip(q, 0.0, 1.0),
    )
    fault_laws = E.law_table(fault_dists) if fault_dists is not None else None
    fp_laws = E.law_table(fp_dists) if fp_dists is not None else None
    return J._cell_tables(
        n, n_tab if n_tab is not None else n, dtype,
        Wk, C, D, R, M, T_R, T_P, mode,
        np.broadcast_to(np.asarray(horizon, np.float64), (n,)), window, -1.0,
        mtbf=mtbf, fp_mean=fp_mean, recall=recall, q_eff=q_eff,
        fault_laws=fault_laws, fp_laws=fp_laws,
        C2=C2, R2=R2, V=V, fmem=fmem, rho=rho, kv=kv,
    )


def tables_from_cells(
    cells: Sequence, n_tab: Optional[int] = None, dtype=np.float64
) -> Dict[str, np.ndarray]:
    """The shared table of a sequence of experiment cells (anything with
    ``work``/``platform``/``predictor``/``strategy``/``horizon_factor``
    and the grid's ``dist`` attributes, i.e.
    :class:`repro.experiments.grid.ExperimentCell`)."""
    dists = [getattr(c, "dist", None) for c in cells]
    have_laws = all(d is not None for d in dists) and len(cells) > 0
    if have_laws:
        try:
            for d in dists:
                E.require_inverse_cdf(d)
        except ValueError:
            have_laws = False
    return cell_tables(
        [c.work for c in cells],
        [c.platform for c in cells],
        [c.predictor for c in cells],
        [c.strategy for c in cells],
        [c.horizon_factor * c.work for c in cells],
        fault_dists=dists if have_laws else None,
        n_tab=n_tab,
        dtype=dtype,
    )


def analytic_waste_cells(cells: Sequence) -> np.ndarray:
    """First-order analytic waste of every cell at its operating period —
    the vectorized replacement of the per-cell strategy dispatch that
    :func:`repro.experiments.validation.analytic_waste` used to run."""
    tabs = tables_from_cells(cells)
    return table_waste(tabs["T_R"], tabs)


def analytic_period_cells(cells: Sequence) -> np.ndarray:
    """Closed-form uncapped optimal period per cell: ``T_extr^{q_eff}``
    (Section 3.3's unified formula, floored at C), evaluated on the
    shared table columns."""
    tabs = tables_from_cells(cells)
    with np.errstate(divide="ignore"):
        denom = 1.0 - tabs["recall"] * tabs["q_eff"]
        te = np.where(
            denom > 0.0,
            np.sqrt(2.0 * tabs["mtbf"] * tabs["C"] / np.where(denom > 0.0, denom, 1.0)),
            np.inf,
        )
    return np.maximum(te, tabs["C"])


# --------------------------------------------------------------------------- #
# Batched on-device period optimization (safeguarded Newton)
# --------------------------------------------------------------------------- #
def _mu_e_np(mu, r, p):
    """Vectorized :func:`repro.core.events.mu_e` (harmonic event rate)."""
    with np.errstate(divide="ignore"):
        inv_p = np.where(r > 0.0, r / (p * mu), 0.0)
        inv_np = np.where(r < 1.0, (1.0 - r) / mu, 0.0)
        inv = inv_p + inv_np
        return np.where(inv > 0.0, 1.0 / np.where(inv > 0.0, inv, 1.0), np.inf)


def _newton_bounds(
    tables: Dict[str, np.ndarray], alpha: float, capped: bool
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-cell period domains ``(lo, hi0, hi1)`` for the q=0 / q=q_eff
    Newton solves, mirroring the host case analyses: uncapped (the
    paper's Section 5 default) brackets generously past every extremal
    period; ``capped=True`` reproduces ``t_young`` / ``t_one``'s
    Section 3.2/4.3 validity caps (``_clamp`` semantics: hi >= lo)."""
    C, mu = tables["C"], tables["mtbf"]
    r, q, I = tables["recall"], tables["q_eff"], tables["window"]
    lo = np.asarray(C, np.float64)
    if capped:
        with np.errstate(invalid="ignore"):
            p = precision_from_fp(mu, tables["fp_mean"], r)
        cap1 = np.where(
            r > 0.0,
            np.maximum(alpha * _mu_e_np(mu, r, p) - I, C),
            np.maximum(alpha * mu, C),
        )
        cap0 = np.where(
            (I > 0.0) & (r > 0.0),
            np.maximum(alpha * _mu_e_np(mu, r, p) - I, C),
            np.maximum(alpha * mu, C),
        )
        return lo, np.maximum(cap0, lo), np.maximum(cap1, lo)
    te0 = np.sqrt(2.0 * mu * C)
    te1 = np.sqrt(2.0 * mu * C / np.maximum(1.0 - r * q, 0.015625))
    hi = 64.0 * np.maximum(te0, te1) + I + C
    if "fmem" in tables:  # two-level cells: T_m* grows like 1/sqrt(f)
        fm = np.maximum(np.asarray(tables["fmem"], np.float64), 0.015625)
        hi = np.where(
            np.asarray(tables["mode"]) == _M_TWO_LEVEL, hi / np.sqrt(fm), hi
        )
    return lo, hi, hi


def newton_optimize_tables(
    tables: Dict[str, np.ndarray],
    alpha: float = W.ALPHA,
    capped: bool = False,
    iters: int = 60,
    devices=None,
) -> Dict[str, np.ndarray]:
    """Solve every cell's optimal period in ONE jitted device dispatch.

    Runs :func:`repro.kernels.analytic.newton_policy` — per-cell
    safeguarded Newton with ``jax.grad``/hessian steps and bisection
    fallback on a shrinking derivative bracket, split at the Instant
    kink ``T = I`` — over the shared table, then the q in {0, q_eff}
    case analysis, exactly like the host ``optimize_*`` functions but
    for the whole grid at once.  Returns per-cell ``T_R``, ``q``,
    ``waste`` (min'd with 1), plus both branches' raw solutions.

    The table is padded to a pow2 row count with the engine's benign
    rows before dispatch so similarly-sized grids share one compiled
    executable; padding rows are dropped from the result."""
    import jax

    from ..kernels import analytic as K

    defaults = {"C2": 0.0, "DR2": 0.0, "V": 0.0, "fmem": 0.0,
                "rho": 1.0, "kv": 1.0}
    if any(k not in tables for k in defaults):
        tables = dict(tables)
        base = np.asarray(tables["C"], np.float64)
        for k, v in defaults.items():
            tables.setdefault(k, np.full_like(base, v))

    n = int(np.asarray(tables["C"]).shape[0])
    n_tab = max(8, 1 << max(int(n) - 1, 0).bit_length())
    if n and n_tab != n:
        padded = dict(tables)
        fills = {"T_P": np.nan, "fp_mean": np.inf, "C": 1.0, "mtbf": 1.0,
                 "T_R": 2.0, "lead_act": 1.0, "tp_eff_default": 1.0,
                 "rho": 1.0, "kv": 1.0}
        for k in TABLE_COLS + ("T_R", "fp_mean"):
            col = np.asarray(tables[k])
            pad = np.full(n_tab - n, fills.get(k, 0.0), col.dtype)
            padded[k] = np.concatenate([col, pad])
        tables_p = padded
    else:
        tables_p = tables
    lo, hi0, hi1 = _newton_bounds(tables_p, alpha, capped)

    if jax.config.jax_enable_x64:
        import contextlib

        ctx = contextlib.nullcontext()
    else:
        from jax.experimental import enable_x64

        ctx = enable_x64()
    with ctx:
        dev = None
        if devices:
            dev = devices[0] if isinstance(devices, (list, tuple)) else devices
        t = {
            k: np.asarray(tables_p[k]).astype(
                np.int32 if k == "mode" else np.float64
            )
            for k in TABLE_COLS + ("fp_mean",)
        }
        with np.errstate(invalid="ignore"):
            p = precision_from_fp(t["mtbf"], t["fp_mean"], t["recall"])
        args = [
            t["mode"], t["q_eff"], t["C"], t["DR"], t["lead_act"],
            t["mtbf"], t["recall"], p, t["window"], t["T_P"],
            t["tp_eff_default"], t["C2"], t["DR2"], t["V"], t["fmem"],
            t["rho"], t["kv"], lo, hi0, hi1,
        ]
        if dev is not None:
            args = [jax.device_put(a, dev) for a in args]
        out = K.newton_policy(*args, iters=iters)
        T, qs, waste, T0, w0, T1, w1 = (np.asarray(a)[:n] for a in out)
    return {
        "T_R": T, "q": qs, "waste": waste,
        "T0": T0, "waste0": w0, "T1": T1, "waste1": w1,
    }


# --------------------------------------------------------------------------- #
# The unified optimizer API
# --------------------------------------------------------------------------- #
@dataclass(frozen=True, eq=False)
class PolicyTable:
    """Batched :class:`OptimalPolicy`: one optimized operating point per
    cell, plus the shared parameter table that produced it."""

    strategy: Tuple[str, ...]
    q: np.ndarray
    T_R: np.ndarray
    waste: np.ndarray
    value: np.ndarray
    objective: str = "waste"
    method: str = "newton"
    T_P: Optional[np.ndarray] = None
    k_P: Optional[np.ndarray] = None
    tables: Optional[Dict[str, np.ndarray]] = None

    def __len__(self) -> int:
        return len(self.strategy)

    def __iter__(self):
        return (self[i] for i in range(len(self)))

    def __getitem__(self, i: int) -> OptimalPolicy:
        tp = None if self.T_P is None or np.isnan(self.T_P[i]) else float(self.T_P[i])
        kp = None
        if self.k_P is not None and self.k_P[i] > 0:
            kp = int(self.k_P[i])
        return OptimalPolicy(
            self.strategy[i], int(round(float(self.q[i]))), float(self.T_R[i]),
            float(self.waste[i]), T_P=tp, k_P=kp,
            objective=self.objective, value=float(self.value[i]),
        )


_ANALYTIC_DISPATCH = {
    "exact": P._optimize_exact,
    "migration": P._optimize_migration,
    "instant": P._optimize_instant,
    "nockpt": P._optimize_nockpt,
    "withckpt": P._optimize_withckpt,
    "two_level": P._optimize_two_level,
    "silent": P._optimize_silent,
    "best": P._best_policy,
}

_STRATEGY_NAMES = (
    "young", "daly", "exact", "instant", "nockpt", "withckpt",
    "migration", "two_level", "silent", "best",
)


def _optimize_young(platform, pred, alpha, capped):
    ty = P._t0(platform.mu, platform.C, alpha, capped)
    w0 = W.waste_young(ty, platform.C, platform.D, platform.R, platform.mu)
    return OptimalPolicy("young", 0, ty, min(w0, 1.0))


def _optimize_daly(platform, pred, alpha, capped):
    td = max(P._t_daly(platform.mu, platform.R, platform.C), platform.C)
    if capped:
        td = P._clamp(td, platform.C, max(alpha * platform.mu, platform.C))
    w0 = W.waste_young(td, platform.C, platform.D, platform.R, platform.mu)
    return OptimalPolicy("daly", 0, td, min(w0, 1.0))


def _with_objective(policy: OptimalPolicy, objective: str) -> OptimalPolicy:
    value = policy.waste if objective == "waste" else 1.0 - policy.waste
    return replace(policy, objective=objective, value=value)


def _strategy_stub(name: str, platform, pred):
    """Strategy object of a named family at a placeholder period (the
    optimizer solves T_R; T_P comes from the host integer partition,
    matching the simulator factories' degenerate-window fallback)."""
    from . import simulator as S

    factory = {
        "young": lambda: S.young(platform),
        "daly": lambda: S.daly(platform),
        "exact": lambda: S.exact_prediction(platform, pred),
        "instant": lambda: S.instant(platform, pred),
        "nockpt": lambda: S.nockpt(platform, pred),
        "withckpt": lambda: S.withckpt(platform, pred),
        "migration": lambda: S.migration(platform, pred),
        "two_level": lambda: S.two_level(platform, pred),
        "silent": lambda: S.silent(platform),
    }[name]
    return factory()


def _newton_policies(
    names: List[str],
    platforms: List[Platform],
    preds: List[PredictorModel],
    alpha: float,
    capped: bool,
    devices,
    objective: str,
) -> PolicyTable:
    """Batched method="newton": expand "best" items into their candidate
    families (Equation (12) pruning included), solve every candidate in
    one dispatch, then reduce back to one winner per item."""
    cand_names: List[str] = []
    cand_items: List[int] = []
    for i, (name, plat, pred) in enumerate(zip(names, platforms, preds)):
        if name == "best":
            if pred.window <= 0.0:
                fams = ["exact"]
            else:
                fams = ["instant", "nockpt"]
                if not P._nockpt_dominates(
                    plat.C, pred.precision, pred.window, pred.e_f
                ):
                    fams.append("withckpt")
        else:
            fams = [name]
        for f in fams:
            cand_names.append(f)
            cand_items.append(i)
    strategies = [
        _strategy_stub(f, platforms[i], preds[i])
        for f, i in zip(cand_names, cand_items)
    ]
    tabs = cell_tables(
        0.0,
        [platforms[i] for i in cand_items],
        [preds[i] for i in cand_items],
        strategies,
        0.0,
    )
    sol = newton_optimize_tables(tabs, alpha=alpha, capped=capped, devices=devices)
    n = len(names)
    best = np.full(n, np.inf)
    idx = np.full(n, -1, np.int64)
    for j, i in enumerate(cand_items):
        if sol["waste"][j] < best[i]:
            best[i] = sol["waste"][j]
            idx[i] = j
    T_P = np.array(
        [s.T_P if s.T_P is not None else np.nan for s in strategies]
    )[idx]
    waste = sol["waste"][idx]
    value = waste if objective == "waste" else 1.0 - waste
    return PolicyTable(
        strategy=tuple(cand_names[j] for j in idx),
        q=sol["q"][idx],
        T_R=sol["T_R"][idx],
        waste=waste,
        value=value,
        objective=objective,
        method="newton",
        T_P=T_P,
        tables=tabs,
    )


def optimize(
    strategy,
    platform,
    pred=None,
    *,
    objective: str = "waste",
    method: str = "analytic",
    alpha: float = W.ALPHA,
    capped: bool = False,
    engine=None,
    devices=None,
    mesh=None,
    config=None,
    work: float = 8 * 86400.0,
    n_runs: int = 20,
    seed: int = 0,
    fault_dist=None,
    grid=None,
) -> Union[OptimalPolicy, "PolicyTable"]:
    """The unified period optimizer (this PR's single entry point).

    strategy    a family name — "young", "daly", "exact", "instant",
                "nockpt", "withckpt", "migration" — or "best" (the
                paper's Section 4.3 recipe with Equation (12) pruning);
                a sequence of names batches (with ``platform`` / ``pred``
                broadcast or zipped) and returns a :class:`PolicyTable`.
    objective   "waste" minimizes the closed-form waste; "availability"
                maximizes 1 - waste (same argmin, the reported ``value``
                flips to availability).
    method      "analytic"  the paper's closed-form case analyses
                            (host; exact reproduction of the legacy
                            ``optimize_*`` results);
                "newton"    batched safeguarded Newton on the jnp twin
                            models — the whole batch solves in ONE
                            jitted device dispatch (``devices=`` pins
                            the device);
                "search"    simulated brute force (the legacy
                            ``best_period_search``): ``work``,
                            ``n_runs``, ``seed``, ``fault_dist``,
                            ``grid`` and ``engine``/``devices``/
                            ``mesh``/``config`` apply.
    capped      restrict periods to the Section 3.2/4.3 validity domain
                (the paper's own simulations use the uncapped default).
    """
    if objective not in ("waste", "availability"):
        raise ValueError(
            f"unknown objective {objective!r} "
            "(expected 'waste' or 'availability')"
        )
    if method not in ("analytic", "newton", "search"):
        raise ValueError(
            f"unknown method {method!r} "
            "(expected 'analytic', 'newton' or 'search')"
        )
    batched = isinstance(strategy, (list, tuple))
    names = list(strategy) if batched else [strategy]
    n = len(names)

    def _bcast(x, kind):
        if isinstance(x, (list, tuple)):
            if len(x) != n:
                raise ValueError(
                    f"{kind} sequence length {len(x)} != {n} strategies"
                )
            return list(x)
        return [x] * n

    platforms = _bcast(platform, "platform")
    preds = [
        p if p is not None else PredictorModel(0.0, 1.0)
        for p in _bcast(pred, "pred")
    ]
    for name in names:
        if name not in _STRATEGY_NAMES:
            raise ValueError(
                f"unknown strategy {name!r} "
                f"(expected one of {sorted(_STRATEGY_NAMES)})"
            )

    if method == "analytic":
        policies = []
        for name, plat, pm in zip(names, platforms, preds):
            if name == "young":
                pol = _optimize_young(plat, pm, alpha, capped)
            elif name == "daly":
                pol = _optimize_daly(plat, pm, alpha, capped)
            else:
                pol = _ANALYTIC_DISPATCH[name](plat, pm, alpha, capped)
            policies.append(_with_objective(pol, objective))
        if not batched:
            return policies[0]
        return PolicyTable(
            strategy=tuple(p.strategy for p in policies),
            q=np.array([p.q for p in policies], np.float64),
            T_R=np.array([p.T_R for p in policies]),
            waste=np.array([p.waste for p in policies]),
            value=np.array([p.value for p in policies]),
            objective=objective,
            method="analytic",
            T_P=np.array(
                [p.T_P if p.T_P is not None else np.nan for p in policies]
            ),
            k_P=np.array(
                [p.k_P if p.k_P is not None else 0 for p in policies],
                np.int64,
            ),
        )

    if method == "newton":
        table = _newton_policies(
            names, platforms, preds, alpha, capped, devices, objective
        )
        if batched:
            return table
        return table[0]

    # method == "search": the simulated brute force, per item
    from .engine import EngineConfig, resolve_engine_config

    cfg = config
    if cfg is None:
        cfg = EngineConfig(
            engine=engine if engine is not None else "batch",
            devices=devices, mesh=mesh,
        )
    elif engine is not None or devices is not None or mesh is not None:
        raise ValueError(
            "optimize: pass either config= or engine=/devices=/mesh=, not both"
        )
    from . import simulator as S

    policies = []
    for name, plat, pm in zip(names, platforms, preds):
        if name == "best":
            raise ValueError("strategy 'best' is not supported with method='search'")
        base = _strategy_stub(name, plat, pm)
        kwargs = {} if grid is None else {"grid": grid}
        best_t, best_w = S._best_period_search(
            work, plat, base, pm, n_runs=n_runs, seed=seed,
            fault_dist=fault_dist, config=cfg, **kwargs,
        )
        pol = OptimalPolicy(
            name, int(round(base.q)), best_t, min(best_w, 1.0), T_P=base.T_P
        )
        policies.append(_with_objective(pol, objective))
    if not batched:
        return policies[0]
    return PolicyTable(
        strategy=tuple(p.strategy for p in policies),
        q=np.array([p.q for p in policies], np.float64),
        T_R=np.array([p.T_R for p in policies]),
        waste=np.array([p.waste for p in policies]),
        value=np.array([p.value for p in policies]),
        objective=objective,
        method="search",
        T_P=np.array([p.T_P if p.T_P is not None else np.nan for p in policies]),
    )


def optimize_cells(
    cells: Sequence,
    objective: str = "waste",
    method: str = "newton",
    alpha: float = W.ALPHA,
    capped: bool = False,
    devices=None,
) -> PolicyTable:
    """Optimize the periods of a prebuilt experiment-cell sequence (the
    grid consumers' entry point): the cells' own strategies fix the
    family/q/T_P, only the regular period is re-solved."""
    if method != "newton":
        raise ValueError("optimize_cells supports method='newton' only")
    tabs = tables_from_cells(cells)
    sol = newton_optimize_tables(tabs, alpha=alpha, capped=capped, devices=devices)
    waste = sol["waste"]
    value = waste if objective == "waste" else 1.0 - waste
    return PolicyTable(
        strategy=tuple(c.strategy.name for c in cells),
        q=sol["q"],
        T_R=sol["T_R"],
        waste=waste,
        value=value,
        objective=objective,
        method="newton",
        T_P=tabs["T_P"][: len(cells)].copy(),
        tables=tabs,
    )
