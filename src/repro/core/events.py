"""Event and trace generation for faults and fault predictions.

Implements the event model of the paper (Aupy, Robert, Vivien, Zaidouni,
"Impact of fault prediction on checkpointing strategies", 2012), Section 2:

* Faults arrive as a renewal process with mean inter-arrival time ``mu``
  (the platform MTBF).  Distributions: Exponential (theory), Weibull with
  shape 0.5 / 0.7 (representative of real platforms), LogNormal (extra).
* A predictor with recall ``r`` and precision ``p`` predicts each fault
  independently with probability ``r`` (true positives).  False positives
  form an independent renewal process with mean inter-arrival time
  ``p * mu / (r * (1 - p))`` so that the three rate identities of Section
  2.3 hold:

      (1 - r) / mu = 1 / mu_NP
      r / mu       = p / mu_P
      1 / mu_e     = 1 / mu_P + 1 / mu_NP

* Window predictions cover an interval ``[t0, t0 + I]``; the true fault is
  uniformly distributed inside its window (the paper's default, giving
  ``E_I^f = I / 2``).  Exact-date predictions are the ``I = 0`` special
  case.  Every prediction is announced ``lead`` seconds before ``t0``
  (the paper requires ``lead >= C`` so a proactive checkpoint fits).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Iterator, List, Optional, Sequence

import numpy as np

__all__ = [
    "FaultEvent",
    "PredictionEvent",
    "EventTrace",
    "Distribution",
    "exponential",
    "weibull",
    "lognormal",
    "uniform",
    "make_fault_trace",
    "make_event_trace",
    "superposed_fault_times",
    "mu_np",
    "mu_p",
    "mu_e",
    "false_prediction_mtbf",
]


# --------------------------------------------------------------------------- #
# Rate identities (Section 2.3)
# --------------------------------------------------------------------------- #
def mu_np(mu: float, r: float) -> float:
    """Mean time between *unpredicted* faults: mu / (1 - r)."""
    if r >= 1.0:
        return math.inf
    return mu / (1.0 - r)


def mu_p(mu: float, r: float, p: float) -> float:
    """Mean time between *predicted events* (true + false positives): p mu / r."""
    if r <= 0.0:
        return math.inf
    return p * mu / r


def mu_e(mu: float, r: float, p: float) -> float:
    """Mean time between events of any type: 1/mu_e = 1/mu_P + 1/mu_NP."""
    inv = 0.0
    mp = mu_p(mu, r, p)
    mnp = mu_np(mu, r)
    if math.isfinite(mp):
        inv += 1.0 / mp
    if math.isfinite(mnp):
        inv += 1.0 / mnp
    if inv == 0.0:
        return math.inf
    return 1.0 / inv


def false_prediction_mtbf(mu: float, r: float, p: float) -> float:
    """Mean inter-arrival time of *false* predictions: p mu / (r (1 - p)).

    Derivation: prediction rate = r/(p mu); true-positive rate = r/mu;
    false-positive rate = r (1 - p) / (p mu).
    """
    if r <= 0.0 or p >= 1.0:
        return math.inf
    return p * mu / (r * (1.0 - p))


# --------------------------------------------------------------------------- #
# Inter-arrival distributions
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class Distribution:
    """A positive inter-arrival distribution with a given mean."""

    name: str
    sampler: Callable[[np.random.Generator, float, int], np.ndarray]

    def sample(self, rng: np.random.Generator, mean: float, n: int) -> np.ndarray:
        return self.sampler(rng, mean, n)


def _exp_sample(rng: np.random.Generator, mean: float, n: int) -> np.ndarray:
    return rng.exponential(mean, size=n)


def _weibull_sampler(shape: float) -> Callable:
    # scale so that E[X] = scale * Gamma(1 + 1/shape) = mean
    gamma_term = math.gamma(1.0 + 1.0 / shape)

    def sample(rng: np.random.Generator, mean: float, n: int) -> np.ndarray:
        scale = mean / gamma_term
        return scale * rng.weibull(shape, size=n)

    return sample


def _lognormal_sampler(sigma: float) -> Callable:
    def sample(rng: np.random.Generator, mean: float, n: int) -> np.ndarray:
        # E[X] = exp(mu + sigma^2/2) = mean
        mu_ln = math.log(mean) - sigma * sigma / 2.0
        return rng.lognormal(mu_ln, sigma, size=n)

    return sample


def _uniform_sample(rng: np.random.Generator, mean: float, n: int) -> np.ndarray:
    # U(0, 2*mean) has mean `mean`
    return rng.uniform(0.0, 2.0 * mean, size=n)


def exponential() -> Distribution:
    return Distribution("exponential", _exp_sample)


def weibull(shape: float) -> Distribution:
    return Distribution(f"weibull(k={shape})", _weibull_sampler(shape))


def lognormal(sigma: float = 1.0) -> Distribution:
    return Distribution(f"lognormal(sigma={sigma})", _lognormal_sampler(sigma))


def uniform() -> Distribution:
    return Distribution("uniform", _uniform_sample)


# --------------------------------------------------------------------------- #
# Events
# --------------------------------------------------------------------------- #
@dataclass(order=True)
class FaultEvent:
    """A fault striking the platform at absolute ``time``.

    ``predicted`` marks true positives (the matching PredictionEvent carries
    the same ``fault_time``).
    """

    time: float
    predicted: bool = field(default=False, compare=False)


@dataclass(order=True)
class PredictionEvent:
    """A prediction with window ``[t0, t0 + window]`` announced at
    ``t0 - lead``.  ``fault_time`` is None for false positives."""

    t0: float
    window: float = field(default=0.0, compare=False)
    fault_time: Optional[float] = field(default=None, compare=False)
    lead: float = field(default=math.inf, compare=False)

    @property
    def is_true_positive(self) -> bool:
        return self.fault_time is not None

    @property
    def announce_time(self) -> float:
        if math.isinf(self.lead):
            return -math.inf
        return self.t0 - self.lead


@dataclass
class EventTrace:
    """A merged trace of faults and predictions over ``[0, horizon]``."""

    horizon: float
    faults: List[FaultEvent]
    predictions: List[PredictionEvent]

    @property
    def n_true_positive(self) -> int:
        return sum(1 for p in self.predictions if p.is_true_positive)

    @property
    def n_false_positive(self) -> int:
        return sum(1 for p in self.predictions if not p.is_true_positive)

    @property
    def n_false_negative(self) -> int:
        return sum(1 for f in self.faults if not f.predicted)

    def empirical_recall(self) -> float:
        tp = self.n_true_positive
        fn = self.n_false_negative
        return tp / (tp + fn) if tp + fn else 0.0

    def empirical_precision(self) -> float:
        tp = self.n_true_positive
        fp = self.n_false_positive
        return tp / (tp + fp) if tp + fp else 0.0


def _arrival_times(
    rng: np.random.Generator, dist: Distribution, mean: float, horizon: float
) -> np.ndarray:
    """Cumulative renewal arrivals in (0, horizon]."""
    if not math.isfinite(mean):
        return np.empty(0)
    times: List[float] = []
    t = 0.0
    # draw in blocks for speed
    expected = max(16, int(horizon / mean * 1.5) + 8)
    while t < horizon:
        block = dist.sample(rng, mean, expected)
        block = np.maximum(block, 1e-9)  # guard zero inter-arrivals
        cum = t + np.cumsum(block)
        keep = cum[cum <= horizon]
        times.extend(keep.tolist())
        if len(keep) < len(cum):
            break
        t = float(cum[-1])
    return np.asarray(times)


def make_fault_trace(
    rng: np.random.Generator,
    horizon: float,
    mtbf: float,
    dist: Distribution | None = None,
) -> List[FaultEvent]:
    dist = dist or exponential()
    return [FaultEvent(float(t)) for t in _arrival_times(rng, dist, mtbf, horizon)]


def superposed_fault_times(
    rng: np.random.Generator,
    horizon: float,
    mtbf: float,
    n_components: int,
    dist: Distribution | None = None,
    stationary: bool = False,
) -> np.ndarray:
    """Platform trace as the superposition of ``n_components`` i.i.d.
    component renewal processes, each with MTBF ``n_components * mtbf``
    (Section 2.1: mu = mu_ind / N).

    The paper's Section 5 text ("a random trace of failures ... scaled so
    that its expectation corresponds to the platform MTBF") is ambiguous
    between a single renewal stream and this superposition.  The two differ
    enormously for Weibull shape < 1: with every component *fresh* at t = 0
    the early platform hazard diverges (burn-in), which is the only
    mechanism consistent with the paper's very large Weibull-k=0.5
    slowdowns.  ``stationary=True`` instead draws each component's first
    arrival from the inspection-paradox equilibrium (age-biased) law, under
    which the superposition is asymptotically Poisson.
    """
    dist = dist or exponential()
    mu_ind = n_components * mtbf
    if stationary:
        # equilibrium first arrival: stationary residual life = U * X with
        # X drawn *length-biased* (a random time instant lands in a gap
        # with probability proportional to the gap's length)
        pool = dist.sample(rng, mu_ind, max(4 * n_components, 20000))
        pool = np.maximum(pool, 1e-9)
        gaps = rng.choice(pool, size=n_components, p=pool / pool.sum())
        first = rng.uniform(0.0, 1.0, n_components) * gaps
    else:
        first = dist.sample(rng, mu_ind, n_components)
    times: List[float] = []
    frontier = first[first < horizon]
    times.extend(frontier.tolist())
    while len(frontier):
        nxt = frontier + np.maximum(
            dist.sample(rng, mu_ind, len(frontier)), 1e-9
        )
        nxt = nxt[nxt < horizon]
        times.extend(nxt.tolist())
        frontier = nxt
    return np.sort(np.asarray(times))


def make_event_trace(
    rng: np.random.Generator,
    horizon: float,
    mtbf: float,
    recall: float,
    precision: float,
    window: float = 0.0,
    lead: float = math.inf,
    fault_dist: Distribution | None = None,
    false_pred_dist: Distribution | None = None,
    n_components: Optional[int] = None,
    stationary: bool = False,
) -> EventTrace:
    """Generate the paper's merged trace (Section 5 methodology).

    1. Draw the fault trace from ``fault_dist`` scaled to mean ``mtbf``
       (single renewal stream), or — when ``n_components`` is given — as the
       superposition of per-component renewals (see
       :func:`superposed_fault_times`).
    2. Mark each fault predicted with probability ``recall``.
    3. Draw a false-prediction trace from ``false_pred_dist`` (default: same
       distribution family as the faults) scaled to mean
       ``p * mu / (r (1-p))``.
    4. Merge.  True-positive windows are placed so the fault is uniformly
       distributed inside the window.
    """
    fault_dist = fault_dist or exponential()
    false_pred_dist = false_pred_dist or fault_dist

    if n_components:
        times = superposed_fault_times(
            rng, horizon, mtbf, n_components, fault_dist, stationary
        )
        faults = [FaultEvent(float(t)) for t in times]
    else:
        faults = make_fault_trace(rng, horizon, mtbf, fault_dist)
    predictions: List[PredictionEvent] = []

    for f in faults:
        if rng.random() < recall:
            f.predicted = True
            offset = rng.uniform(0.0, window) if window > 0 else 0.0
            t0 = max(0.0, f.time - offset)
            predictions.append(
                PredictionEvent(t0=t0, window=window, fault_time=f.time, lead=lead)
            )

    fp_mean = false_prediction_mtbf(mtbf, recall, precision)
    for t in _arrival_times(rng, false_pred_dist, fp_mean, horizon):
        predictions.append(
            PredictionEvent(t0=float(t), window=window, fault_time=None, lead=lead)
        )

    faults.sort()
    predictions.sort()
    return EventTrace(horizon=horizon, faults=faults, predictions=predictions)
