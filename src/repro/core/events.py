"""Event and trace generation for faults and fault predictions.

Implements the event model of the paper (Aupy, Robert, Vivien, Zaidouni,
"Impact of fault prediction on checkpointing strategies", 2012), Section 2:

* Faults arrive as a renewal process with mean inter-arrival time ``mu``
  (the platform MTBF).  Distributions: Exponential (theory), Weibull with
  shape 0.5 / 0.7 (representative of real platforms), LogNormal (extra).
* A predictor with recall ``r`` and precision ``p`` predicts each fault
  independently with probability ``r`` (true positives).  False positives
  form an independent renewal process with mean inter-arrival time
  ``p * mu / (r * (1 - p))`` so that the three rate identities of Section
  2.3 hold:

      (1 - r) / mu = 1 / mu_NP
      r / mu       = p / mu_P
      1 / mu_e     = 1 / mu_P + 1 / mu_NP

* Window predictions cover an interval ``[t0, t0 + I]``; the true fault is
  uniformly distributed inside its window (the paper's default, giving
  ``E_I^f = I / 2``).  Exact-date predictions are the ``I = 0`` special
  case.  Every prediction is announced ``lead`` seconds before ``t0``
  (the paper requires ``lead >= C`` so a proactive checkpoint fits).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Callable, Iterator, List, Optional, Sequence

import numpy as np

__all__ = [
    "FaultEvent",
    "PredictionEvent",
    "EventTrace",
    "BatchTraces",
    "TraceSpec",
    "pad_sentinel",
    "Distribution",
    "exponential",
    "weibull",
    "lognormal",
    "uniform",
    "make_fault_trace",
    "make_event_trace",
    "make_event_traces_batch",
    "make_trace_spec",
    "LAW_INDEX",
    "LAW_EXPONENTIAL",
    "LAW_WEIBULL",
    "LAW_LOGNORMAL",
    "LAW_UNIFORM",
    "law_table",
    "gap_transform_indexed_np",
    "superposed_fault_times",
    "superposed_fault_times_batch",
    "mu_np",
    "mu_p",
    "mu_e",
    "false_prediction_mtbf",
    "false_prediction_mtbf_batch",
]


# --------------------------------------------------------------------------- #
# Rate identities (Section 2.3)
# --------------------------------------------------------------------------- #
def mu_np(mu: float, r: float) -> float:
    """Mean time between *unpredicted* faults: mu / (1 - r)."""
    if r >= 1.0:
        return math.inf
    return mu / (1.0 - r)


def mu_p(mu: float, r: float, p: float) -> float:
    """Mean time between *predicted events* (true + false positives): p mu / r."""
    if r <= 0.0:
        return math.inf
    return p * mu / r


def mu_e(mu: float, r: float, p: float) -> float:
    """Mean time between events of any type: 1/mu_e = 1/mu_P + 1/mu_NP."""
    inv = 0.0
    mp = mu_p(mu, r, p)
    mnp = mu_np(mu, r)
    if math.isfinite(mp):
        inv += 1.0 / mp
    if math.isfinite(mnp):
        inv += 1.0 / mnp
    if inv == 0.0:
        return math.inf
    return 1.0 / inv


def false_prediction_mtbf(mu: float, r: float, p: float) -> float:
    """Mean inter-arrival time of *false* predictions: p mu / (r (1 - p)).

    Derivation: prediction rate = r/(p mu); true-positive rate = r/mu;
    false-positive rate = r (1 - p) / (p mu).
    """
    if r <= 0.0 or p >= 1.0:
        return math.inf
    return p * mu / (r * (1.0 - p))


def false_prediction_mtbf_batch(
    mtbf: np.ndarray, recall: np.ndarray, precision: np.ndarray
) -> np.ndarray:
    """Vectorized :func:`false_prediction_mtbf` (``+inf`` where no false
    predictions occur) — shared by the host trace generator and the
    device-generation packing path."""
    with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
        return np.where(
            (recall > 0.0) & (precision < 1.0),
            precision * mtbf / np.maximum(recall * (1.0 - precision), 1e-300),
            np.inf,
        )


# --------------------------------------------------------------------------- #
# Inter-arrival distributions
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class Distribution:
    """A positive inter-arrival distribution with a given mean.

    ``kind``/``param`` identify the family for the device trace generator
    (:class:`TraceSpec`): the on-device inverse-CDF samplers dispatch on
    them statically.  Custom distributions may leave ``kind`` empty; they
    then work with every host path but not with ``trace_mode="device"``.
    """

    name: str
    sampler: Callable[[np.random.Generator, float, int], np.ndarray]
    kind: str = ""
    param: float = 0.0

    def sample(self, rng: np.random.Generator, mean: float, n: int) -> np.ndarray:
        return self.sampler(rng, mean, n)


def _exp_sample(rng: np.random.Generator, mean: float, n: int) -> np.ndarray:
    return rng.exponential(mean, size=n)


def _weibull_sampler(shape: float) -> Callable:
    # scale so that E[X] = scale * Gamma(1 + 1/shape) = mean
    gamma_term = math.gamma(1.0 + 1.0 / shape)

    def sample(rng: np.random.Generator, mean: float, n: int) -> np.ndarray:
        scale = mean / gamma_term
        return scale * rng.weibull(shape, size=n)

    return sample


def _lognormal_sampler(sigma: float) -> Callable:
    def sample(rng: np.random.Generator, mean: float, n: int) -> np.ndarray:
        # E[X] = exp(mu + sigma^2/2) = mean
        mu_ln = math.log(mean) - sigma * sigma / 2.0
        return rng.lognormal(mu_ln, sigma, size=n)

    return sample


def _uniform_sample(rng: np.random.Generator, mean: float, n: int) -> np.ndarray:
    # U(0, 2*mean) has mean `mean`
    return rng.uniform(0.0, 2.0 * mean, size=n)


def exponential() -> Distribution:
    return Distribution("exponential", _exp_sample, kind="exponential")


def weibull(shape: float) -> Distribution:
    return Distribution(
        f"weibull(k={shape})", _weibull_sampler(shape),
        kind="weibull", param=shape,
    )


def lognormal(sigma: float = 1.0) -> Distribution:
    return Distribution(
        f"lognormal(sigma={sigma})", _lognormal_sampler(sigma),
        kind="lognormal", param=sigma,
    )


def uniform() -> Distribution:
    return Distribution("uniform", _uniform_sample, kind="uniform")


# --------------------------------------------------------------------------- #
# Events
# --------------------------------------------------------------------------- #
@dataclass(order=True)
class FaultEvent:
    """A fault striking the platform at absolute ``time``.

    ``predicted`` marks true positives (the matching PredictionEvent carries
    the same ``fault_time``).  ``tier_u`` is the recovery-tier uniform of
    two-level checkpointing strategies (``tier_u >= f`` sends the recovery
    to the disk tier; the 1.0 default means "disk", so legacy traces stay
    valid for every strategy with ``f = 0``).
    """

    time: float
    predicted: bool = field(default=False, compare=False)
    tier_u: float = field(default=1.0, compare=False)


@dataclass(order=True)
class PredictionEvent:
    """A prediction with window ``[t0, t0 + window]`` announced at
    ``t0 - lead``.  ``fault_time`` is None for false positives."""

    t0: float
    window: float = field(default=0.0, compare=False)
    fault_time: Optional[float] = field(default=None, compare=False)
    lead: float = field(default=math.inf, compare=False)

    @property
    def is_true_positive(self) -> bool:
        return self.fault_time is not None

    @property
    def announce_time(self) -> float:
        if math.isinf(self.lead):
            return -math.inf
        return self.t0 - self.lead


@dataclass
class EventTrace:
    """A merged trace of faults and predictions over ``[0, horizon]``."""

    horizon: float
    faults: List[FaultEvent]
    predictions: List[PredictionEvent]

    @property
    def n_true_positive(self) -> int:
        return sum(1 for p in self.predictions if p.is_true_positive)

    @property
    def n_false_positive(self) -> int:
        return sum(1 for p in self.predictions if not p.is_true_positive)

    @property
    def n_false_negative(self) -> int:
        return sum(1 for f in self.faults if not f.predicted)

    def empirical_recall(self) -> float:
        tp = self.n_true_positive
        fn = self.n_false_negative
        return tp / (tp + fn) if tp + fn else 0.0

    def empirical_precision(self) -> float:
        tp = self.n_true_positive
        fp = self.n_false_positive
        return tp / (tp + fp) if tp + fp else 0.0


def _arrival_times(
    rng: np.random.Generator, dist: Distribution, mean: float, horizon: float
) -> np.ndarray:
    """Cumulative renewal arrivals in (0, horizon]."""
    if not math.isfinite(mean):
        return np.empty(0)
    times: List[float] = []
    t = 0.0
    # draw in blocks for speed
    expected = max(16, int(horizon / mean * 1.5) + 8)
    while t < horizon:
        block = dist.sample(rng, mean, expected)
        block = np.maximum(block, 1e-9)  # guard zero inter-arrivals
        cum = t + np.cumsum(block)
        keep = cum[cum <= horizon]
        times.extend(keep.tolist())
        if len(keep) < len(cum):
            break
        t = float(cum[-1])
    return np.asarray(times)


def make_fault_trace(
    rng: np.random.Generator,
    horizon: float,
    mtbf: float,
    dist: Distribution | None = None,
) -> List[FaultEvent]:
    dist = dist or exponential()
    return [FaultEvent(float(t)) for t in _arrival_times(rng, dist, mtbf, horizon)]


def superposed_fault_times(
    rng: np.random.Generator,
    horizon: float,
    mtbf: float,
    n_components: int,
    dist: Distribution | None = None,
    stationary: bool = False,
) -> np.ndarray:
    """Platform trace as the superposition of ``n_components`` i.i.d.
    component renewal processes, each with MTBF ``n_components * mtbf``
    (Section 2.1: mu = mu_ind / N).

    The paper's Section 5 text ("a random trace of failures ... scaled so
    that its expectation corresponds to the platform MTBF") is ambiguous
    between a single renewal stream and this superposition.  The two differ
    enormously for Weibull shape < 1: with every component *fresh* at t = 0
    the early platform hazard diverges (burn-in), which is the only
    mechanism consistent with the paper's very large Weibull-k=0.5
    slowdowns.  ``stationary=True`` instead draws each component's first
    arrival from the inspection-paradox equilibrium (age-biased) law, under
    which the superposition is asymptotically Poisson.
    """
    dist = dist or exponential()
    mu_ind = n_components * mtbf
    if stationary:
        # equilibrium first arrival: stationary residual life = U * X with
        # X drawn *length-biased* (a random time instant lands in a gap
        # with probability proportional to the gap's length)
        pool = dist.sample(rng, mu_ind, max(4 * n_components, 20000))
        pool = np.maximum(pool, 1e-9)
        gaps = rng.choice(pool, size=n_components, p=pool / pool.sum())
        first = rng.uniform(0.0, 1.0, n_components) * gaps
    else:
        first = dist.sample(rng, mu_ind, n_components)
    times: List[float] = []
    frontier = first[first < horizon]
    times.extend(frontier.tolist())
    while len(frontier):
        nxt = frontier + np.maximum(
            dist.sample(rng, mu_ind, len(frontier)), 1e-9
        )
        nxt = nxt[nxt < horizon]
        times.extend(nxt.tolist())
        frontier = nxt
    return np.sort(np.asarray(times))


def make_event_trace(
    rng: np.random.Generator,
    horizon: float,
    mtbf: float,
    recall: float,
    precision: float,
    window: float = 0.0,
    lead: float = math.inf,
    fault_dist: Distribution | None = None,
    false_pred_dist: Distribution | None = None,
    n_components: Optional[int] = None,
    stationary: bool = False,
) -> EventTrace:
    """Generate the paper's merged trace (Section 5 methodology).

    1. Draw the fault trace from ``fault_dist`` scaled to mean ``mtbf``
       (single renewal stream), or — when ``n_components`` is given — as the
       superposition of per-component renewals (see
       :func:`superposed_fault_times`).
    2. Mark each fault predicted with probability ``recall``.
    3. Draw a false-prediction trace from ``false_pred_dist`` (default: same
       distribution family as the faults) scaled to mean
       ``p * mu / (r (1-p))``.
    4. Merge.  True-positive windows are placed so the fault is uniformly
       distributed inside the window.
    """
    fault_dist = fault_dist or exponential()
    false_pred_dist = false_pred_dist or fault_dist

    if n_components:
        times = superposed_fault_times(
            rng, horizon, mtbf, n_components, fault_dist, stationary
        )
        faults = [FaultEvent(float(t)) for t in times]
    else:
        faults = make_fault_trace(rng, horizon, mtbf, fault_dist)
    predictions: List[PredictionEvent] = []

    for f in faults:
        if rng.random() < recall:
            f.predicted = True
            offset = rng.uniform(0.0, window) if window > 0 else 0.0
            t0 = max(0.0, f.time - offset)
            predictions.append(
                PredictionEvent(t0=t0, window=window, fault_time=f.time, lead=lead)
            )

    fp_mean = false_prediction_mtbf(mtbf, recall, precision)
    for t in _arrival_times(rng, false_pred_dist, fp_mean, horizon):
        predictions.append(
            PredictionEvent(t0=float(t), window=window, fault_time=None, lead=lead)
        )

    faults.sort()
    predictions.sort()
    return EventTrace(horizon=horizon, faults=faults, predictions=predictions)


# --------------------------------------------------------------------------- #
# Batched trace generation (lane-per-trace arrays)
# --------------------------------------------------------------------------- #
def pad_sentinel(
    a: np.ndarray,
    counts: np.ndarray,
    fill,
    round_pow2: bool = False,
    min_width: int = 1,
) -> np.ndarray:
    """Cursor-ready event array: guarantee at least one all-``fill``
    column past every lane's ``counts[i]`` valid events.

    Both vectorized engines walk event rows with per-lane cursors and rely
    on a terminating sentinel column instead of bounds checks.  Arrays that
    are already wide enough are adopted unchanged (zero copy — the engines
    never write them).  ``round_pow2`` rounds the column count up to a
    power of two so device engines see bucketed shapes and re-use their
    compiled executables across batches of slightly different widths.
    """
    need = (int(counts.max()) if counts.size else 0) + 1
    need = max(need, min_width)
    if round_pow2:
        need = 1 << (need - 1).bit_length()
    if a.shape[1] >= need:
        return a
    pad = np.full((a.shape[0], need - a.shape[1]), fill, dtype=a.dtype)
    return np.concatenate([a, pad], axis=1)


@dataclass
class BatchTraces:
    """``n_traces`` merged event traces as padded 2-D arrays (one lane per
    trace, one column per event).

    Rows are sorted in time; columns beyond a lane's event count are padded
    with ``+inf`` (``NaN`` for ``pred_fault``).  Generated batches carry at
    least one all-padding trailing column, which the vectorized engine uses
    as its cursor sentinel (adopting the arrays without copying).
    ``lane(i)`` materializes the scalar :class:`EventTrace` view of lane
    ``i`` — the exact trace the reference engine consumes in
    batched-vs-scalar equivalence checks.
    """

    horizon: np.ndarray  # (L,) per-lane horizon
    fault_times: np.ndarray  # (L, F) sorted fault dates, +inf padded
    fault_predicted: np.ndarray  # (L, F) bool, true-positive marks
    n_faults: np.ndarray  # (L,) valid fault count per lane
    pred_t0: np.ndarray  # (L, P) sorted window starts, +inf padded
    pred_fault: np.ndarray  # (L, P) matched fault date, NaN for false positives
    n_preds: np.ndarray  # (L,) valid prediction count per lane
    window: np.ndarray  # (L,) prediction-window length
    lead: np.ndarray  # (L,) announce lead
    #: (L, F) per-fault recovery-tier uniforms (two-level strategies;
    #: ``None`` on batches generated without ``tier=True``)
    fault_tier: Optional[np.ndarray] = None

    @property
    def n_lanes(self) -> int:
        return int(self.fault_times.shape[0])

    def lane(self, i: int) -> EventTrace:
        """Scalar :class:`EventTrace` view of lane ``i``."""
        nf = int(self.n_faults[i])
        npred = int(self.n_preds[i])
        tiers = (
            self.fault_tier[i, :nf]
            if self.fault_tier is not None
            else np.ones(nf)
        )
        faults = [
            FaultEvent(float(t), predicted=bool(p), tier_u=float(u))
            for t, p, u in zip(
                self.fault_times[i, :nf], self.fault_predicted[i, :nf], tiers
            )
        ]
        w, ld = float(self.window[i]), float(self.lead[i])
        preds = []
        for j in range(npred):
            ft = float(self.pred_fault[i, j])
            preds.append(
                PredictionEvent(
                    t0=float(self.pred_t0[i, j]),
                    window=w,
                    fault_time=None if math.isnan(ft) else ft,
                    lead=ld,
                )
            )
        return EventTrace(horizon=float(self.horizon[i]), faults=faults, predictions=preds)

    def tile(self, reps: int) -> "BatchTraces":
        """Repeat the whole batch ``reps`` times (lane block order preserved:
        lanes [0..L) then [0..L) again, ...) — used to evaluate several
        strategies on identical traces in a single engine call."""
        return BatchTraces(
            horizon=np.tile(self.horizon, reps),
            fault_times=np.tile(self.fault_times, (reps, 1)),
            fault_predicted=np.tile(self.fault_predicted, (reps, 1)),
            n_faults=np.tile(self.n_faults, reps),
            pred_t0=np.tile(self.pred_t0, (reps, 1)),
            pred_fault=np.tile(self.pred_fault, (reps, 1)),
            n_preds=np.tile(self.n_preds, reps),
            window=np.tile(self.window, reps),
            lead=np.tile(self.lead, reps),
            fault_tier=(
                None
                if self.fault_tier is None
                else np.tile(self.fault_tier, (reps, 1))
            ),
        )

    def take(self, rows) -> "BatchTraces":
        """New batch whose lane ``i`` is lane ``rows[i]`` of this batch
        (rows may repeat — several strategies sharing identical traces)."""
        rows = np.asarray(rows)
        return BatchTraces(
            horizon=self.horizon[rows],
            fault_times=self.fault_times[rows],
            fault_predicted=self.fault_predicted[rows],
            n_faults=self.n_faults[rows],
            pred_t0=self.pred_t0[rows],
            pred_fault=self.pred_fault[rows],
            n_preds=self.n_preds[rows],
            window=self.window[rows],
            lead=self.lead[rows],
            fault_tier=(
                None if self.fault_tier is None else self.fault_tier[rows]
            ),
        )

    @staticmethod
    def concat(parts: Sequence["BatchTraces"]) -> "BatchTraces":
        """Stack several batches into one (event columns padded to the
        widest part) so heterogeneous groups share a single engine call."""

        def cat2(arrs: List[np.ndarray], fill) -> np.ndarray:
            width = max(a.shape[1] for a in arrs)
            padded = [
                a
                if a.shape[1] == width
                else np.concatenate(
                    [a, np.full((a.shape[0], width - a.shape[1]), fill, a.dtype)],
                    axis=1,
                )
                for a in arrs
            ]
            return np.concatenate(padded, axis=0)

        if any(p.fault_tier is not None for p in parts):
            # lanes without draws fall back to the 1.0 ("disk") fill
            tier = cat2(
                [
                    p.fault_tier
                    if p.fault_tier is not None
                    else np.ones(p.fault_times.shape)
                    for p in parts
                ],
                1.0,
            )
        else:
            tier = None
        return BatchTraces(
            horizon=np.concatenate([p.horizon for p in parts]),
            fault_times=cat2([p.fault_times for p in parts], np.inf),
            fault_predicted=cat2([p.fault_predicted for p in parts], False),
            n_faults=np.concatenate([p.n_faults for p in parts]),
            pred_t0=cat2([p.pred_t0 for p in parts], np.inf),
            pred_fault=cat2([p.pred_fault for p in parts], np.nan),
            n_preds=np.concatenate([p.n_preds for p in parts]),
            window=np.concatenate([p.window for p in parts]),
            lead=np.concatenate([p.lead for p in parts]),
            fault_tier=tier,
        )


def _arrival_times_batch(
    rng: np.random.Generator,
    dist: Distribution,
    means: np.ndarray,
    horizons: np.ndarray,
    max_block: int = 4_000_000,
) -> tuple[np.ndarray, np.ndarray]:
    """Batched renewal arrivals: one vectorized sampling pass per round.

    Relies on every :class:`Distribution` being a scale family — sampling at
    mean 1 and multiplying by the per-lane mean yields the per-lane law.
    Returns ``(times (L, W) +inf padded, counts (L,))`` with arrivals in
    ``(0, horizon_i]`` per lane.

    The first round draws a full ``(L, m)`` block sized to the expected
    per-lane count; *refill* rounds (lanes whose cumulative arrivals have
    not yet crossed their horizon — the heavy-tail stragglers) draw one
    vectorized ``(n_unfinished, m)`` block over just those lanes, in
    ascending lane order, and their arrivals are scattered into the
    output in one pass at the end — both the sampling and the assembly
    cost O(stragglers), not O(L), per round.  On 100k-lane grids the
    refill rounds used to dominate generation time.  Traces at a given
    seed are unchanged when no refill occurs and differ (same law) when
    one does.
    """
    means = np.asarray(means, dtype=np.float64)
    horizons = np.asarray(horizons, dtype=np.float64)
    L = means.shape[0]
    finite = np.isfinite(means) & (means > 0.0)
    if L == 0 or not finite.any():
        return np.empty((L, 0)), np.zeros(L, dtype=np.int64)
    expected = np.where(finite, horizons / means, 0.0)

    # heterogeneous lanes: split fast lanes from slow ones so the block
    # width tracks each bucket's own expected count instead of the max
    if L >= 8 and expected.max() > 4.0 * max(np.median(expected), 1.0):
        cut = np.median(expected)
        lo = np.flatnonzero(expected <= cut)
        hi = np.flatnonzero(expected > cut)
        t_lo, c_lo = _arrival_times_batch(rng, dist, means[lo], horizons[lo], max_block)
        t_hi, c_hi = _arrival_times_batch(rng, dist, means[hi], horizons[hi], max_block)
        width = max(t_lo.shape[1], t_hi.shape[1])
        out = np.full((L, width), np.inf)
        out[lo, : t_lo.shape[1]] = t_lo
        out[hi, : t_hi.shape[1]] = t_hi
        counts = np.zeros(L, dtype=np.int64)
        counts[lo] = c_lo
        counts[hi] = c_hi
        return out, counts

    cap = max(16, max_block // L)
    m = int(np.clip(expected.max() * 1.25 + 8, 16, cap))
    block = dist.sample(rng, 1.0, (L, m)) * means[:, None]
    block = np.maximum(block, 1e-9)  # guard zero inter-arrivals
    block[~finite] = np.inf
    times = np.cumsum(block, axis=1)
    keep = times <= horizons[:, None]  # monotone rows: kept is a prefix
    counts = keep.sum(axis=1).astype(np.int64)
    tail = times[:, -1]
    ex_lanes: List[np.ndarray] = []
    ex_times: List[np.ndarray] = []
    act = np.flatnonzero(finite & (tail <= horizons))
    tail = tail[act]  # act-aligned from here on
    while act.size:
        m = max(16, m // 3)
        sub = np.maximum(
            dist.sample(rng, 1.0, (act.size, m)) * means[act, None], 1e-9
        )
        sub_t = tail[:, None] + np.cumsum(sub, axis=1)
        sk = sub_t <= horizons[act, None]
        cnt = sk.sum(axis=1)
        ex_lanes.append(np.repeat(act, cnt))
        ex_times.append(sub_t[sk])  # row-major: grouped by lane, sorted
        counts[act] += cnt
        tail = sub_t[:, -1]
        live = tail <= horizons[act]
        act, tail = act[live], tail[live]
    width = int(counts.max(initial=0))
    out = np.full((L, max(width, times.shape[1])), np.inf)
    out[:, : times.shape[1]] = np.where(keep, times, np.inf)
    if ex_lanes:
        lanes_cat = np.concatenate(ex_lanes)
        times_cat = np.concatenate(ex_times)
        # refill rounds append in time order per lane; a stable sort by
        # lane turns (round, lane) order into per-lane sorted runs
        order = np.argsort(lanes_cat, kind="stable")
        lanes_s = lanes_cat[order]
        base = keep.sum(axis=1)
        starts = np.concatenate([[0], np.cumsum(counts - base)[:-1]])
        pos = base[lanes_s] + np.arange(lanes_s.size) - starts[lanes_s]
        out[lanes_s, pos] = times_cat[order]
    return out[:, :width], counts


def _bc(x, L: int) -> np.ndarray:
    return np.broadcast_to(np.asarray(x, dtype=np.float64), (L,)).copy()


def superposed_fault_times_batch(
    rng: np.random.Generator,
    horizons: np.ndarray,
    mtbfs: np.ndarray,
    n_components: int,
    dist: Distribution | None = None,
    stationary: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Batched fresh-start :func:`superposed_fault_times`: every lane's
    component frontier advances in one flattened sampling pass per round
    (the frontier shrinks geometrically, so a handful of rounds covers the
    horizon).  Returns ``(times (L, W) +inf padded sorted, counts)``.

    ``stationary=True`` draws each component's first arrival from the
    equilibrium (length-biased residual-life) law, like the scalar path —
    but vectorized: each lane gets its *own* pool of unit-mean gaps
    (lanes are independent Monte-Carlo runs, so pools must not be shared
    — a shared pool's heavy atoms would correlate every run of a sweep
    and understate the per-cell CIs), and the length-biased choice runs
    as one offset-``searchsorted`` pass per lane block instead of a
    per-lane Python loop."""
    dist = dist or exponential()
    horizons = np.asarray(horizons, dtype=np.float64)
    mtbfs = np.asarray(mtbfs, dtype=np.float64)
    L = horizons.shape[0]
    mu_ind = mtbfs * n_components
    if stationary:
        # pool size trades length-biased fidelity (ratio bias O(1/K))
        # against the (block, K) memory of per-lane pools
        K = int(min(max(4 * n_components, 2048), 20000))
        first = np.empty((L, n_components))
        B = max(1, 4_000_000 // K)
        for lo in range(0, L, B):
            sl = slice(lo, min(lo + B, L))
            nb = sl.stop - sl.start
            pool = np.maximum(dist.sample(rng, 1.0, (nb, K)), 1e-9)
            cdf = np.cumsum(pool / pool.sum(axis=1, keepdims=True), axis=1)
            cdf[:, -1] = 1.0  # guard float-rounding shortfall
            rows = np.arange(nb)[:, None]
            u = rng.random((nb, n_components))
            # rows offset by 2 keep the flattened cdf globally sorted, so
            # one searchsorted inverts every lane's CDF at once
            idx = np.searchsorted(
                (cdf + 2.0 * rows).ravel(), (u + 2.0 * rows).ravel(),
                side="right",
            ).reshape(nb, n_components) - rows * K
            idx = np.minimum(idx, K - 1)
            gaps = pool[rows, idx] * mu_ind[sl][:, None]
            first[sl] = rng.uniform(0.0, 1.0, (nb, n_components)) * gaps
    else:
        first = dist.sample(rng, 1.0, (L, n_components)) * mu_ind[:, None]
    lane0, comp0 = np.nonzero(first < horizons[:, None])
    f_lane = lane0
    f_time = first[lane0, comp0]
    all_lanes = [f_lane]
    all_times = [f_time]
    while f_lane.size:
        gaps = np.maximum(
            dist.sample(rng, 1.0, f_lane.size) * mu_ind[f_lane], 1e-9
        )
        nxt = f_time + gaps
        keep = nxt < horizons[f_lane]
        f_lane = f_lane[keep]
        f_time = nxt[keep]
        all_lanes.append(f_lane)
        all_times.append(f_time)
    lanes_cat = np.concatenate(all_lanes)
    times_cat = np.concatenate(all_times)
    counts = np.bincount(lanes_cat, minlength=L).astype(np.int64)
    width = int(counts.max()) if lanes_cat.size else 0
    out = np.full((L, width), np.inf)
    order = np.lexsort((times_cat, lanes_cat))
    lanes_s = lanes_cat[order]
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(lanes_s.size) - starts[lanes_s]
    out[lanes_s, pos] = times_cat[order]
    return out, counts


def make_event_traces_batch(
    rng: np.random.Generator,
    n_traces: int,
    horizon,
    mtbf,
    recall,
    precision,
    window=0.0,
    lead=math.inf,
    fault_dist: Distribution | None = None,
    false_pred_dist: Distribution | None = None,
    n_components: Optional[int] = None,
    stationary: bool = False,
    tier: bool = False,
) -> BatchTraces:
    """Batched :func:`make_event_trace`: one array-of-events generation pass
    per distribution instead of ``n_traces`` Python loops.

    All trace parameters broadcast to per-lane ``(n_traces,)`` arrays, so a
    single call can carry a heterogeneous sweep (mixed MTBFs, predictors and
    windows).  The generated traces are distributionally identical to the
    scalar path but consume the RNG in a different order, so individual
    traces differ draw-for-draw from :func:`make_event_trace` at equal seeds.

    ``tier=True`` additionally draws per-fault recovery-tier uniforms
    (two-level checkpointing strategies).  The draw happens *after* every
    other draw, so traces at a given seed are unchanged when ``tier`` is
    left off.
    """
    L = int(n_traces)
    horizon = _bc(horizon, L)
    mtbf = _bc(mtbf, L)
    recall = _bc(recall, L)
    precision = _bc(precision, L)
    window = _bc(window, L)
    lead = _bc(lead, L)
    fault_dist = fault_dist or exponential()
    false_pred_dist = false_pred_dist or fault_dist

    if n_components:
        fault_times, n_faults = superposed_fault_times_batch(
            rng, horizon, mtbf, n_components, fault_dist, stationary
        )
    else:
        fault_times, n_faults = _arrival_times_batch(rng, fault_dist, mtbf, horizon)

    cols = np.arange(fault_times.shape[1])[None, :]
    valid = cols < n_faults[:, None]
    predicted = valid & (rng.random(fault_times.shape) < recall[:, None])

    # true-positive windows: fault uniformly distributed inside [t0, t0 + I]
    offsets = rng.random(fault_times.shape) * window[:, None]
    tp_t0 = np.where(predicted, np.maximum(0.0, fault_times - offsets), np.inf)
    tp_ft = np.where(predicted, fault_times, np.nan)

    fp_mean = false_prediction_mtbf_batch(mtbf, recall, precision)
    fp_t0, n_fp = _arrival_times_batch(rng, false_pred_dist, fp_mean, horizon)

    t0 = np.concatenate([tp_t0, fp_t0], axis=1)
    ft = np.concatenate([tp_ft, np.full(fp_t0.shape, np.nan)], axis=1)
    order = np.argsort(t0, axis=1, kind="stable")
    t0 = np.take_along_axis(t0, order, axis=1)
    ft = np.take_along_axis(ft, order, axis=1)
    n_preds = predicted.sum(axis=1).astype(np.int64) + n_fp

    # keep >= 1 trailing padding column: the engine's cursor sentinel
    pwidth = (int(n_preds.max()) if L else 0) + 1
    t0 = t0[:, :pwidth] if t0.shape[1] >= pwidth else np.concatenate(
        [t0, np.full((L, pwidth - t0.shape[1]), np.inf)], axis=1
    )
    ft = ft[:, :pwidth] if ft.shape[1] >= pwidth else np.concatenate(
        [ft, np.full((L, pwidth - ft.shape[1]), np.nan)], axis=1
    )
    fwidth = (int(n_faults.max()) if L else 0) + 1
    if fault_times.shape[1] < fwidth:
        fault_times = np.concatenate(
            [fault_times, np.full((L, fwidth - fault_times.shape[1]), np.inf)],
            axis=1,
        )
        predicted = np.concatenate(
            [predicted, np.zeros((L, fwidth - predicted.shape[1]), bool)], axis=1
        )

    return BatchTraces(
        horizon=horizon,
        fault_times=fault_times,
        fault_predicted=predicted[:, : fault_times.shape[1]],
        n_faults=n_faults,
        pred_t0=t0,
        pred_fault=ft,
        n_preds=n_preds,
        window=window,
        lead=lead,
        fault_tier=rng.random(fault_times.shape) if tier else None,
    )


# --------------------------------------------------------------------------- #
# Counter-based RNG trace specifications (device-side generation)
# --------------------------------------------------------------------------- #
#: stream kinds of the per-lane counter-based RNG layout.  Every lane owns
#: six independent streams, one per kind (the TP coin stream's two output
#: words carry the predicted coin and the window offset); draw ``i`` of a
#: stream never depends on any other draw, so the device engine, the NumPy
#: :meth:`TraceSpec.materialize` reference, and any cursor replaying the
#: stream see identical events regardless of chunking or device count.
(
    STREAM_FAULT_GAP,  # fault inter-arrival time i
    STREAM_TP_COIN,  # fault i: word0 = predicted coin, word1 = window offset
    STREAM_FP_GAP,  # false-prediction inter-arrival time j
    STREAM_TP_TRUST,  # trust coin for fault i's prediction (0 < q < 1 only)
    STREAM_FP_TRUST,  # trust coin for false prediction j (0 < q < 1 only)
    STREAM_TIER,  # recovery-tier coin for fault i (two-level strategies)
) = range(6)

#: Threefry-2x32 key-schedule parity constant (Salmon et al., SC'11)
_TF_PARITY = 0x1BD11BDA
#: Threefry-2x32 rotation schedule (repeating groups of four rounds)
_TF_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
#: Random123 default round count (known-answer tested)
THREEFRY_ROUNDS = 20

#: SplitMix64 constants (Vigna; Stafford Mix13 finalizer).  Subkeys are
#: derived with Threefry (quality key spacing, once per lane per stream
#: kind); per-*counter* draws — the hot path, one evaluation per lane per
#: event — use the ~10-op SplitMix64 mix, which passes BigCrush, instead
#: of an ~80-op cipher.
_SM_GAMMA = 0x9E3779B97F4A7C15
_SM_MIX1 = 0xBF58476D1CE4E5B9
_SM_MIX2 = 0x94D049BB133111EB


# repro-twin: repro.kernels.sim_step.threefry2x32
def threefry2x32(k0, k1, c0, c1, rounds: int = THREEFRY_ROUNDS):
    """Vectorized Threefry-2x32 block cipher (NumPy reference).

    Round/key-injection layout follows Random123 (injection after every
    fourth round).  The device engine re-implements the identical
    function in jnp (:func:`repro.kernels.sim_step.threefry2x32`); a
    bit-equality test pins the two together.  All inputs broadcast;
    returns two ``uint32`` words.
    """
    k0 = np.asarray(k0, np.uint32)
    k1 = np.asarray(k1, np.uint32)
    x0 = np.asarray(c0, np.uint32)
    x1 = np.asarray(c1, np.uint32)
    with np.errstate(over="ignore"):
        ks = (k0, k1, k0 ^ k1 ^ np.uint32(_TF_PARITY))
        x0 = x0 + ks[0]
        x1 = x1 + ks[1]
        for i in range(rounds):
            r = _TF_ROTATIONS[(i // 4) % 2][i % 4]
            x0 = x0 + x1
            x1 = (x1 << np.uint32(r)) | (x1 >> np.uint32(32 - r))
            x1 = x1 ^ x0
            if i % 4 == 3:
                s = i // 4 + 1
                x0 = x0 + ks[s % 3]
                x1 = x1 + ks[(s + 1) % 3] + np.uint32(s)
    return x0, x1


# repro-twin: repro.kernels.sim_step.splitmix64
def splitmix64(key64, ctr):
    """Counter-indexed SplitMix64 draw (NumPy reference): output ``ctr``
    of the stream whose state orbit starts at ``key64`` — i.e.
    ``mix(key64 + (ctr + 1) * GAMMA)``, exactly Vigna's generator with a
    random starting state.  Returns the (high, low) uint32 words of the
    64-bit output.  The jnp twin lives in :mod:`repro.kernels.sim_step`;
    a known-answer test pins both to the reference sequence
    (``key64 = 0`` -> ``0xE220A8397B1DCDAF, ...``)."""
    key64 = np.asarray(key64, np.uint64)
    with np.errstate(over="ignore"):
        z = key64 + (np.asarray(ctr, np.uint64) + np.uint64(1)) * np.uint64(
            _SM_GAMMA
        )
        z = (z ^ (z >> np.uint64(30))) * np.uint64(_SM_MIX1)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(_SM_MIX2)
        z = z ^ (z >> np.uint64(31))
    return (z >> np.uint64(32)).astype(np.uint32), z.astype(np.uint32)


# repro-twin: repro.kernels.sim_step.uniform24
def uniform24(bits, dtype=np.float64):
    """Map ``uint32`` words to uniforms in the *open* interval (0, 1):
    the top 24 bits, centered by half an ulp — so ``log`` and ``log1p``
    transforms never see an endpoint.  24-bit granularity is ~6e-8 of the
    mean, far below Monte-Carlo resolution, and keeps the f32 (TPU) and
    f64 paths on one code shape."""
    return ((bits >> np.uint32(8)).astype(dtype) + dtype(0.5)) * dtype(2.0**-24)


# repro-twin: repro.kernels.sim_step.gap_transform
def gap_transform_np(kind: str, param: float, mean, x0, x1):
    """Inverse-CDF inter-arrival transform of one counter draw (NumPy
    reference; mirrors :func:`repro.kernels.sim_step.gap_transform`).

    ``x0``/``x1`` are the two threefry output words; only the lognormal
    family consumes the second (Box–Muller phase).  Matches the host
    :class:`Distribution` families: same mean parameterization, same
    ``1e-9`` zero-gap guard."""
    u = uniform24(x0)
    if kind == "exponential":
        g = -np.log1p(-u) * mean
    elif kind == "weibull":
        scale = 1.0 / math.gamma(1.0 + 1.0 / param)
        g = (np.asarray(mean) * scale) * (-np.log1p(-u)) ** (1.0 / param)
    elif kind == "lognormal":
        z = np.sqrt(-2.0 * np.log(u)) * np.cos(2.0 * np.pi * uniform24(x1))
        with np.errstate(over="ignore"):
            g = np.exp(np.log(mean) - 0.5 * param * param + param * z)
    elif kind == "uniform":
        g = 2.0 * np.asarray(mean) * u
    else:
        raise ValueError(
            f"device trace generation supports exponential/weibull/"
            f"lognormal/uniform, got kind={kind!r}"
        )
    return np.maximum(g, 1e-9)


#: law indices of the cell-table ``law_index`` column — the per-cell
#: *data* encoding of the failure-law family (mixed-law fused dispatch)
LAW_EXPONENTIAL, LAW_WEIBULL, LAW_LOGNORMAL, LAW_UNIFORM = range(4)

#: ``Distribution.kind`` -> law index
LAW_INDEX = {
    "exponential": LAW_EXPONENTIAL,
    "weibull": LAW_WEIBULL,
    "lognormal": LAW_LOGNORMAL,
    "uniform": LAW_UNIFORM,
}


def law_table(dists):
    """Per-cell law table of a distribution sequence: ``(law, lp)`` with
    ``law`` an ``(n,)`` int32 law-index column and ``lp`` an ``(n, 4)``
    float64 unified parameter row ``[param, s1, s2, 0]``.

    The shape slots are pre-folded exactly as the compile-time-specialized
    transforms fold them (same Python-float expressions), so the indexed
    samplers reproduce the specialized paths bit-for-bit: Weibull ``s1 =
    1/Γ(1 + 1/k)``, ``s2 = 1/k``; lognormal ``s1 = σ``, ``s2 = σ²/2``;
    exponential/uniform need no shape (all-zero slots).  Slot 3 is
    reserved."""
    dists = tuple(dists)
    law = np.zeros(len(dists), np.int32)
    lp = np.zeros((len(dists), 4), np.float64)
    for i, d in enumerate(dists):
        require_inverse_cdf(d)
        law[i] = LAW_INDEX[d.kind]
        if d.kind == "weibull":
            lp[i, 0] = d.param
            lp[i, 1] = 1.0 / math.gamma(1.0 + 1.0 / d.param)
            lp[i, 2] = 1.0 / d.param
        elif d.kind == "lognormal":
            lp[i, 0] = d.param
            lp[i, 1] = d.param
            lp[i, 2] = 0.5 * d.param * d.param
    return law, lp


# repro-twin: repro.kernels.sim_step.gap_transform_indexed
def gap_transform_indexed_np(law, s1, s2, mean, x0, x1):
    """Law-multiplexed :func:`gap_transform_np` (NumPy reference; mirrors
    :func:`repro.kernels.sim_step.gap_transform_indexed`): ``law`` selects
    the family per element and ``(s1, s2)`` carry the pre-folded shape
    slots of :func:`law_table`.  All inputs broadcast.  Every family's
    branch evaluates (masked errstate) and a ``where`` chain selects — the
    same select order as the jnp twin, and each branch the same expression
    as the specialized transform, so a single-family slice is bit-identical
    to :func:`gap_transform_np`."""
    u = uniform24(x0)
    nlog = -np.log1p(-u)
    with np.errstate(over="ignore", invalid="ignore", divide="ignore"):
        g_exp = nlog * mean
        # mirror ndarray.__pow__'s scalar fast paths (x ** 2.0 -> x * x,
        # x ** 0.5 -> sqrt) so the data-driven exponent reproduces the
        # specialized transform's bits for those shapes too
        p = np.power(nlog, s2)
        p = np.where(s2 == 2.0, nlog * nlog, p)
        p = np.where(s2 == 0.5, np.sqrt(nlog), p)
        g_wei = (np.asarray(mean) * s1) * p
        z = np.sqrt(-2.0 * np.log(u)) * np.cos(2.0 * np.pi * uniform24(x1))
        g_log = np.exp(np.log(mean) - s2 + s1 * z)
        g_uni = 2.0 * np.asarray(mean) * u
    g = np.where(
        law == LAW_WEIBULL, g_wei,
        np.where(
            law == LAW_LOGNORMAL, g_log,
            np.where(law == LAW_UNIFORM, g_uni, g_exp),
        ),
    )
    return np.maximum(g, 1e-9)


def require_inverse_cdf(dist: Distribution) -> None:
    """Raise unless ``dist`` names a family the device sampler supports
    (single point of truth for the supported-family list)."""
    if not dist.kind:
        raise ValueError(
            f"distribution {dist.name!r} has no inverse-CDF kind; "
            "device trace generation supports exponential/weibull/"
            "lognormal/uniform"
        )


def stream_subkey_np(seed: int, stream, kind: int):
    """Per-(lane-stream, kind) subkey derivation (NumPy reference).

    ``seed`` is split into two key words; the counter words carry the
    64-bit stream id (low word verbatim, high word shifted past the
    4-bit kind tag), so distinct (stream, kind) pairs map to distinct
    cipher inputs."""
    stream = np.asarray(stream, np.int64)
    s0 = np.uint32(seed & 0xFFFFFFFF)
    s1 = np.uint32((seed >> 32) & 0xFFFFFFFF)
    c0 = (stream & 0xFFFFFFFF).astype(np.uint32)
    c1 = ((((stream >> 32) << 4) | kind) & 0xFFFFFFFF).astype(np.uint32)
    return threefry2x32(s0, s1, c0, c1)


def stream_key64_np(seed: int, stream, kind: int) -> np.ndarray:
    """The 64-bit SplitMix stream key: the two Threefry subkey words
    packed ``(high << 32) | low``."""
    k0, k1 = stream_subkey_np(seed, stream, kind)
    return (k0.astype(np.uint64) << np.uint64(32)) | k1.astype(np.uint64)


@dataclass
class TraceSpec:
    """A *generative* trace batch: per-lane parameters plus a counter-based
    RNG stream layout, in place of materialized event arrays.

    Where :class:`BatchTraces` stores ``(lanes, events)`` slabs sampled on
    the host, a ``TraceSpec`` stores only the O(lanes) parameters and lets
    the consumer sample events on demand: lane ``i``'s events are a pure
    function of ``(seed, stream[i])`` through the six counter-indexed
    streams above.  The JAX engine (``trace_mode="device"``) walks these
    streams with O(1) per-lane cursors; :meth:`materialize` replays the
    identical streams into a :class:`BatchTraces` on the host (NumPy), so
    host engines — and exactness tests — can consume the same traces.

    Lanes sharing a ``stream`` id face identical faults and predictions
    (the paired experiment design); ``take``/``tile`` preserve that by
    carrying the ids.

    **Cell-indexed layout** (the fused experiment sweep): with
    ``cell_index`` set, the six parameter arrays hold one row per *cell*
    (shape ``(n_cells,)``) and ``cell_index[i]`` names lane ``i``'s cell
    — only ``stream`` (and ``cell_index`` itself) stay per-lane, so a
    grid of hundreds of cells ships O(cells) parameters + O(lanes) int32
    to the device instead of O(lanes) float64 per parameter.  Lane
    semantics are *identical* to :meth:`expand`'s per-lane view; host
    consumers go through ``expand()``, the device engine gathers rows by
    ``cell_index`` on device.

    **Mixed-law layout**: ``fault_dist`` / ``false_pred_dist`` may each
    be a *tuple* of distributions — one per cell row (or per lane in the
    per-lane layout).  The failure law then rides the cell tables as data
    (an int32 ``law_index`` column plus the unified 4-slot parameter row
    of :func:`law_table`) and every consumer switches to the
    law-multiplexed transform, so grids mixing exponential / Weibull /
    lognormal / uniform families run as ONE device dispatch.  Build such
    specs with :meth:`concat_cells` or by passing distribution sequences
    to :func:`make_trace_spec`."""

    horizon: np.ndarray  # (L,) — or (n_cells,) when cell-indexed
    mtbf: np.ndarray  # (L,) | (n_cells,)
    recall: np.ndarray  # (L,) | (n_cells,)
    precision: np.ndarray  # (L,) | (n_cells,)
    window: np.ndarray  # (L,) | (n_cells,)
    lead: np.ndarray  # (L,) | (n_cells,)
    fault_dist: "Distribution | tuple"  # one per cell/lane when a tuple
    false_pred_dist: "Distribution | tuple"
    seed: int
    stream: np.ndarray  # (L,) int64 global RNG stream ids
    cell_index: Optional[np.ndarray] = None  # (L,) int32 lane -> cell row

    @property
    def n_lanes(self) -> int:
        return int(self.stream.shape[0])

    @property
    def n_cells(self) -> Optional[int]:
        """Cell-table row count (``None`` for the per-lane layout)."""
        if self.cell_index is None:
            return None
        return int(self.horizon.shape[0])

    @property
    def fp_mean(self) -> np.ndarray:
        """False-prediction mean inter-arrival; aligned with the parameter
        arrays (per-cell in the cell-indexed layout)."""
        return false_prediction_mtbf_batch(self.mtbf, self.recall, self.precision)

    @staticmethod
    def _gather_dists(d, rows):
        """Row-gather a per-row distribution tuple (identity for the
        shared-`Distribution` layout)."""
        if isinstance(d, tuple):
            return tuple(d[int(r)] for r in rows)
        return d

    def expand(self) -> "TraceSpec":
        """Per-lane view of a cell-indexed spec (identity otherwise):
        parameter rows gathered by ``cell_index``, same streams — the
        reference layout every host consumer sees."""
        if self.cell_index is None:
            return self
        ci = self.cell_index
        return TraceSpec(
            horizon=self.horizon[ci], mtbf=self.mtbf[ci],
            recall=self.recall[ci], precision=self.precision[ci],
            window=self.window[ci], lead=self.lead[ci],
            fault_dist=self._gather_dists(self.fault_dist, ci),
            false_pred_dist=self._gather_dists(self.false_pred_dist, ci),
            seed=self.seed, stream=self.stream,
        )

    def take(self, rows) -> "TraceSpec":
        rows = np.asarray(rows)
        if self.cell_index is not None:
            # lane selection: the cell table is untouched, lanes re-map
            return TraceSpec(
                horizon=self.horizon, mtbf=self.mtbf,
                recall=self.recall, precision=self.precision,
                window=self.window, lead=self.lead,
                fault_dist=self.fault_dist,
                false_pred_dist=self.false_pred_dist,
                seed=self.seed, stream=self.stream[rows],
                cell_index=self.cell_index[rows],
            )
        return TraceSpec(
            horizon=self.horizon[rows], mtbf=self.mtbf[rows],
            recall=self.recall[rows], precision=self.precision[rows],
            window=self.window[rows], lead=self.lead[rows],
            fault_dist=self._gather_dists(self.fault_dist, rows),
            false_pred_dist=self._gather_dists(self.false_pred_dist, rows),
            seed=self.seed, stream=self.stream[rows],
        )

    @classmethod
    def concat_cells(cls, specs) -> "TraceSpec":
        """Concatenate cell-indexed specs (one per failure-law family,
        disjoint stream-id ranges, shared seed) into ONE mixed-law
        cell-indexed spec: cell tables stack, lane ``cell_index`` offsets
        into the stacked table, and the per-cell distribution tuples make
        the law a data column — the single-dispatch input of the fused
        mixed-law sweep.  Lane order is the concatenation order; every
        lane keeps its stream id, so events are unchanged."""
        specs = list(specs)
        if not specs:
            raise ValueError("concat_cells needs at least one spec")
        seed = specs[0].seed
        if any(s.seed != seed for s in specs):
            raise ValueError("concat_cells requires a shared seed")
        if any(s.cell_index is None for s in specs):
            raise ValueError("concat_cells requires cell-indexed specs")

        def rows(d, n):
            return tuple(d) if isinstance(d, tuple) else (d,) * n

        fd: list = []
        fpd: list = []
        ci = []
        off = 0
        for s in specs:
            n = s.n_cells
            fd += rows(s.fault_dist, n)
            fpd += rows(s.false_pred_dist, n)
            ci.append(s.cell_index.astype(np.int64) + off)
            off += n

        def cat(name):
            return np.concatenate([getattr(s, name) for s in specs])

        return cls(
            horizon=cat("horizon"), mtbf=cat("mtbf"),
            recall=cat("recall"), precision=cat("precision"),
            window=cat("window"), lead=cat("lead"),
            fault_dist=tuple(fd), false_pred_dist=tuple(fpd),
            seed=seed, stream=cat("stream"),
            cell_index=np.concatenate(ci).astype(np.int32),
        )

    def tile(self, reps: int) -> "TraceSpec":
        return self.take(np.tile(np.arange(self.n_lanes), reps))

    def indexed(self) -> "TraceSpec":
        """Force the law-indexed sampler: broadcast a shared
        ``Distribution`` to the per-row tuple layout (identity when
        already tuple-valued).  Events are drawn from the same streams
        through the law-multiplexed transform instead of the
        law-specialized one — the bit-exact control for
        one-dispatch-vs-per-family dispatch comparisons."""
        n = self.n_cells if self.cell_index is not None else self.n_lanes

        def tup(d):
            return d if isinstance(d, tuple) else (d,) * n

        return replace(
            self,
            fault_dist=tup(self.fault_dist),
            false_pred_dist=tup(self.false_pred_dist),
        )

    def _grow_stream(self, kind: int, means: np.ndarray, max_events: int):
        """Replay one gap stream to (just past) every lane's horizon:
        ``(times (L, W), valid (L, W), counts (L,))``.  Sequential
        accumulation order matches the device cursors, so the times are
        bit-identical to what the engine observes (f64)."""
        L = self.n_lanes
        key = stream_key64_np(self.seed, self.stream, kind)
        dist = self.fault_dist if kind == STREAM_FAULT_GAP else self.false_pred_dist
        if isinstance(dist, tuple):  # mixed-law: per-lane law column
            law, lp = law_table(dist)
            law_c = law[:, None]
            s1_c, s2_c = lp[:, 1][:, None], lp[:, 2][:, None]
        with np.errstate(invalid="ignore"):
            expected = np.where(
                np.isfinite(means) & (means > 0), self.horizon / means, 0.0
            )
        K = int(np.clip(
            expected.max(initial=0.0) * 1.4 + 16, 16, max(max_events, 16)
        ))
        # ``max_events`` is a floor for the runaway guard, which scales
        # with the expected count so any cell the device path can run is
        # also replayable on the host (memory permitting)
        cap = max(max_events, int(expected.max(initial=0.0) * 4) + 64)
        last = np.zeros(L)
        start = 0
        cols: List[np.ndarray] = []
        while True:
            ctr = np.broadcast_to(
                np.arange(start, start + K, dtype=np.int64), (L, K)
            )
            x0, x1 = splitmix64(key[:, None], ctr)
            if isinstance(dist, tuple):
                gaps = gap_transform_indexed_np(
                    law_c, s1_c, s2_c, means[:, None], x0, x1
                )
            else:
                gaps = gap_transform_np(
                    dist.kind, dist.param, means[:, None], x0, x1
                )
            # seed the cumulative sum with `last` so later blocks keep
            # the cursor's sequential (last + g1) + g2 association —
            # bit-identical to the device accumulation, not last + (g1+g2)
            t = np.cumsum(
                np.concatenate([last[:, None], gaps], axis=1), axis=1
            )[:, 1:]
            cols.append(t)
            last = t[:, -1]
            if np.all(last > self.horizon):
                break
            start += K
            if start > cap:
                raise ValueError(
                    f"lane needs more than {cap} events to cover its "
                    "horizon; raise max_events"
                )
            K = max(16, K // 2)
        times = np.concatenate(cols, axis=1)
        valid = times <= self.horizon[:, None]
        return times, valid, valid.sum(axis=1).astype(np.int64)

    def materialize(self, max_events: int = 1 << 17) -> BatchTraces:
        """Replay the counter streams on the host into a
        :class:`BatchTraces` — the exact events the device engine samples
        lazily (fault dates bit-identical in f64; merged predictions
        time-sorted as in :func:`make_event_traces_batch`, whereas the
        device cursor consumes true-positive predictions in fault order).

        Trust coins (fractional ``q``) are *not* applied here: host
        engines draw trust from their own RNG, so fractional-``q`` runs
        agree with the device path only in distribution.  ``q`` in
        {0, 1} — every paper strategy — is filter-exact."""
        if self.cell_index is not None:
            return self.expand().materialize(max_events=max_events)
        L = self.n_lanes
        fault_times, valid, n_faults = self._grow_stream(
            STREAM_FAULT_GAP, self.mtbf, max_events
        )
        W = fault_times.shape[1]
        ctr = np.broadcast_to(np.arange(W, dtype=np.int64), (L, W))
        ckey = stream_key64_np(self.seed, self.stream, STREAM_TP_COIN)
        cw0, cw1 = splitmix64(ckey[:, None], ctr)
        predicted = valid & (uniform24(cw0) < self.recall[:, None])
        off = uniform24(cw1) * self.window[:, None]
        tp_t0 = np.where(
            predicted, np.maximum(0.0, fault_times - off), np.inf
        )
        tp_ft = np.where(predicted, fault_times, np.nan)
        fault_times = np.where(valid, fault_times, np.inf)

        fp_times, fp_valid, n_fp = self._grow_stream(
            STREAM_FP_GAP, self.fp_mean, max_events
        )
        fp_t0 = np.where(fp_valid, fp_times, np.inf)

        t0 = np.concatenate([tp_t0, fp_t0], axis=1)
        ft = np.concatenate([tp_ft, np.full(fp_t0.shape, np.nan)], axis=1)
        order = np.argsort(t0, axis=1, kind="stable")
        t0 = np.take_along_axis(t0, order, axis=1)
        ft = np.take_along_axis(ft, order, axis=1)
        n_preds = predicted.sum(axis=1).astype(np.int64) + n_fp

        pwidth = (int(n_preds.max()) if L else 0) + 1
        t0 = t0[:, :pwidth] if t0.shape[1] >= pwidth else np.concatenate(
            [t0, np.full((L, pwidth - t0.shape[1]), np.inf)], axis=1
        )
        ft = ft[:, :pwidth] if ft.shape[1] >= pwidth else np.concatenate(
            [ft, np.full((L, pwidth - ft.shape[1]), np.nan)], axis=1
        )
        fwidth = (int(n_faults.max()) if L else 0) + 1
        if fault_times.shape[1] < fwidth:
            fault_times = np.concatenate(
                [fault_times, np.full((L, fwidth - fault_times.shape[1]), np.inf)],
                axis=1,
            )
        else:
            fault_times = fault_times[:, :fwidth]
        # recovery-tier uniforms: counter draw i of the tier stream belongs
        # to fault column i — bit-identical to the device engine's
        # counter_uniform(tier_key, sf_ctr) read at each consumed fault
        tkey = stream_key64_np(self.seed, self.stream, STREAM_TIER)
        tctr = np.broadcast_to(
            np.arange(fault_times.shape[1], dtype=np.int64), fault_times.shape
        )
        fault_tier = uniform24(splitmix64(tkey[:, None], tctr)[0])
        return BatchTraces(
            horizon=self.horizon,
            fault_times=fault_times,
            fault_predicted=predicted[:, : fault_times.shape[1]],
            n_faults=n_faults,
            pred_t0=t0,
            pred_fault=ft,
            n_preds=n_preds,
            window=self.window,
            lead=self.lead,
            fault_tier=fault_tier,
        )


def make_trace_spec(
    n_traces: int,
    horizon,
    mtbf,
    recall,
    precision,
    window=0.0,
    lead=math.inf,
    fault_dist: "Distribution | Sequence[Distribution] | None" = None,
    false_pred_dist: "Distribution | Sequence[Distribution] | None" = None,
    seed: int = 0,
    stream=None,
    cell_index=None,
) -> TraceSpec:
    """Counter-RNG counterpart of :func:`make_event_traces_batch`: same
    broadcastable per-lane parameters, but returns the O(lanes)
    :class:`TraceSpec` instead of sampling events on the host.

    ``stream`` assigns the per-lane RNG stream ids (default
    ``arange(n_traces)``); pass disjoint ranges to make several specs
    independent under one seed, or repeated ids to pair lanes on
    identical traces.  Superposed component traces (``n_components``) are
    host-generation only.

    ``cell_index`` switches to the cell-indexed layout: the trace
    parameters then describe *cells* (broadcast to the cell-table length
    ``max(cell_index) + 1``) and ``n_traces`` lanes are mapped onto them
    by ``cell_index[i]`` — see :class:`TraceSpec`.

    ``fault_dist`` / ``false_pred_dist`` each also accept a *sequence* of
    distributions — one per cell row (per lane without ``cell_index``) —
    selecting the mixed-law layout."""
    L = int(n_traces)
    if stream is None:
        stream = np.arange(L, dtype=np.int64)
    else:
        stream = np.asarray(stream, dtype=np.int64)
        if stream.shape != (L,):
            raise ValueError(f"stream must have shape ({L},), got {stream.shape}")
    n_par = L
    if cell_index is not None:
        cell_index = np.asarray(cell_index, dtype=np.int32)
        if cell_index.shape != (L,):
            raise ValueError(
                f"cell_index must have shape ({L},), got {cell_index.shape}"
            )
        if L and cell_index.min() < 0:
            raise ValueError("cell_index entries must be >= 0")
        n_par = int(cell_index.max()) + 1 if L else 0

    def _dists(d, name):
        if isinstance(d, Distribution):
            require_inverse_cdf(d)
            return d
        d = tuple(d)
        if len(d) != n_par:
            raise ValueError(
                f"{name} sequence must have one entry per "
                f"{'cell' if cell_index is not None else 'lane'} "
                f"({n_par}), got {len(d)}"
            )
        for x in d:
            require_inverse_cdf(x)
        return d

    fault_dist = _dists(
        exponential() if fault_dist is None else fault_dist, "fault_dist"
    )
    false_pred_dist = _dists(
        fault_dist if false_pred_dist is None else false_pred_dist,
        "false_pred_dist",
    )
    return TraceSpec(
        horizon=_bc(horizon, n_par),
        mtbf=_bc(mtbf, n_par),
        recall=_bc(recall, n_par),
        precision=_bc(precision, n_par),
        window=_bc(window, n_par),
        lead=_bc(lead, n_par),
        fault_dist=fault_dist,
        false_pred_dist=false_pred_dist,
        seed=int(seed),
        stream=stream,
        cell_index=cell_index,
    )
