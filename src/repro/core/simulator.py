"""Discrete-event simulator for checkpointing strategies under fault traces.

Faithful re-implementation of the paper's Section 5 simulation engine:

* a job of ``work`` seconds of useful compute executes on a platform with
  checkpoint cost C, downtime D, recovery R;
* faults and (true/false) predictions arrive from an :class:`EventTrace`;
* a strategy decides the regular period T_R, whether to trust predictions
  (probability q), and what to do inside a prediction window (Instant /
  NoCkptI / WithCkptI), or to migrate (Section 3.4);
* the simulator reports the makespan and the empirical waste
  ``1 - work / makespan``.

The engine mirrors Algorithm 1 of the paper, including the W_reg bookkeeping
(work credited toward the interrupted regular period is preserved across
proactive episodes, and the "no time for an extra checkpoint" path credits
only ``max(0, t0 - C - ckpt_end)``, per line 12).
"""

from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from .engine import UNSET, EngineConfig, resolve_engine_config
from .events import Distribution, EventTrace, exponential
from .waste import Platform, PredictorModel
from . import periods as P

#: absolute time tolerance (seconds) — periods are O(10^3) s, so 1 us is
#: far below any modelled quantity yet far above float64 residuals.
_EPS = 1e-6

__all__ = [
    "Strategy",
    "young",
    "daly",
    "exact_prediction",
    "instant",
    "nockpt",
    "withckpt",
    "migration",
    "two_level",
    "silent",
    "SimResult",
    "simulate",
    "simulate_many",
    "best_period_search",
]


# --------------------------------------------------------------------------- #
# Strategy descriptions
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class Strategy:
    """An operating point of the scheduling algorithm.

    mode:
      "none"      ignore all predictions (Young / Daly / BestPeriod baselines)
      "exact"     Section 3 — proactive checkpoint right before the predicted
                  date (for window traces: act on t0, return to regular; this
                  is also the Instant strategy of Section 4)
      "nockpt"    Section 4 — no checkpoints inside the window
      "withckpt"  Section 4 — proactive period T_P inside the window
      "migration" Section 3.4 — migrate (cost M) instead of checkpointing
      "two_level" beyond-paper — memory-tier checkpoints of period T_R
                  nested in disk-tier checkpoints every ``rho``-th period
                  (see waste.waste_two_level)
      "silent"    beyond-paper — latent corruptions, verification every
                  ``k_V``-th checkpoint (see waste.waste_silent)
    """

    name: str
    T_R: float
    q: float = 0.0
    mode: str = "none"
    T_P: Optional[float] = None
    #: two-level nesting stride (every rho-th regular ckpt is disk-tier)
    rho: Optional[int] = None
    #: silent-error verification stride (every k_V-th regular ckpt verifies)
    k_V: Optional[int] = None


def young(platform: Platform) -> Strategy:
    """Uncapped Young period sqrt(2 mu C) (the simulation baseline)."""
    return Strategy("Young", P._t_extr(platform.mu, platform.C), q=0.0, mode="none")


def daly(platform: Platform) -> Strategy:
    return Strategy(
        "Daly", P._t_daly(platform.mu, platform.R, platform.C), q=0.0, mode="none"
    )


def _t1(platform: Platform, pred: PredictorModel) -> float:
    """Uncapped T_extr^{1} = sqrt(2 mu C / (1 - r)) — Section 5 uses the
    uncapped value to mimic a real execution."""
    return P._t_extr(platform.mu, platform.C, pred.recall, 1.0)


def exact_prediction(platform: Platform, pred: PredictorModel) -> Strategy:
    return Strategy("ExactPrediction", _t1(platform, pred), q=1.0, mode="exact")


def instant(platform: Platform, pred: PredictorModel) -> Strategy:
    return Strategy("Instant", _t1(platform, pred), q=1.0, mode="exact")


def nockpt(platform: Platform, pred: PredictorModel) -> Strategy:
    return Strategy("NoCkptI", _t1(platform, pred), q=1.0, mode="nockpt")


def withckpt(platform: Platform, pred: PredictorModel) -> Strategy:
    tp = P._t_p_opt(platform.C, pred.precision, pred.window, pred.e_f)
    if tp is None:  # window cannot hold a checkpoint: degenerate to NoCkptI
        return Strategy("WithCkptI", _t1(platform, pred), q=1.0, mode="nockpt")
    return Strategy(
        "WithCkptI", _t1(platform, pred), q=1.0, mode="withckpt", T_P=tp[0]
    )


def migration(platform: Platform, pred: PredictorModel) -> Strategy:
    return Strategy("Migration", _t1(platform, pred), q=1.0, mode="migration")


def two_level(platform: Platform, pred: Optional[PredictorModel] = None) -> Strategy:
    """Two-level checkpointing at the corrected joint extremizers: memory
    period T_m, disk stride rho = round(T_d / T_m) (>= 1 by the T_d >= T_m
    constraint of :func:`~repro.core.periods.two_level_periods`)."""
    C2 = platform.C2 if platform.C2 is not None else platform.C
    R2 = platform.R2 if platform.R2 is not None else platform.R
    f = platform.f if platform.f is not None else 0.0
    r = pred.recall if pred is not None else 0.0
    q = 1.0 if pred is not None and r > 0.0 else 0.0
    p = pred.precision if pred is not None else 1.0
    t_m, t_d = P.two_level_periods(
        platform.mu, platform.C, C2, f, r, q, p, platform.D, platform.R, R2
    )
    return Strategy(
        "TwoLevel", t_m, q=q, mode="two_level", rho=max(1, round(t_d / t_m))
    )


def silent(platform: Platform) -> Strategy:
    """Silent-error strategy: verified checkpoints every k_V-th period (the
    predictor never fires on latent corruptions, so q = 0 always)."""
    V = platform.V if platform.V is not None else platform.C
    t, k = P.silent_period(platform.mu, platform.C, V, platform.D, platform.R)
    return Strategy("Silent", t, q=0.0, mode="silent", k_V=k)


# --------------------------------------------------------------------------- #
# Simulation engine
# --------------------------------------------------------------------------- #
@dataclass
class SimResult:
    makespan: float
    work: float
    n_faults: int
    n_proactive_ckpts: int
    n_regular_ckpts: int
    n_migrations: int
    trace_exhausted: bool = False
    #: two-level disk-tier recoveries / silent-error detections (zero
    #: unless the strategy runs the corresponding mode)
    n_disk_recoveries: int = 0
    n_detections: int = 0

    @property
    def waste(self) -> float:
        return 1.0 - self.work / self.makespan


class _Engine:
    def __init__(
        self,
        work: float,
        platform: Platform,
        strategy: Strategy,
        trace: EventTrace,
        rng: np.random.Generator,
    ):
        self.W = work
        self.C = platform.C
        self.D = platform.D
        self.R = platform.R
        self.M = platform.M if platform.M is not None else platform.C
        self.strat = strategy
        self.t = 0.0
        self.saved = 0.0
        self.unsaved = 0.0
        self.period_work = 0.0
        self.done = False
        self.n_faults = 0
        self.n_pro = 0
        self.n_reg = 0
        self.n_mig = 0

        # two-level state: durable frontier, memory ckpts since it, and the
        # duration of the repair in progress (a fault during a repair
        # restarts the SAME repair — rc, not D+R)
        self.tl = strategy.mode == "two_level"
        self.sil = strategy.mode == "silent"
        self.C2 = platform.C2 if platform.C2 is not None else platform.C
        self.R2 = platform.R2 if platform.R2 is not None else platform.R
        self.V = platform.V if platform.V is not None else platform.C
        self.fmem = platform.f if platform.f is not None else 0.0
        self.rho = strategy.rho if strategy.rho is not None else 1
        self.kv = strategy.k_V if strategy.k_V is not None else 1
        self.saved_d = 0.0
        self.dk_ctr = 0
        self.rc = self.D + self.R
        # silent-error state: verified frontier, unverified ckpts since it,
        # earliest latent corruption time
        self.saved_v = 0.0
        self.ck_v = 0
        self.corrupt = math.inf
        self.n_disk = 0
        self.n_det = 0

        self.fault_times: List[float] = [f.time for f in trace.faults]
        # per-fault recovery-tier uniforms (u >= f sends recovery to disk;
        # the 1.0 default means "disk" and keeps legacy traces valid)
        self.tiers: List[float] = [
            getattr(f, "tier_u", 1.0) for f in trace.faults
        ]
        self.fi = 0
        # Trust decisions are drawn per prediction (probability q).  Silent
        # lanes never trust: a latent corruption is not a fail-stop event,
        # so the fail-stop predictor has nothing to predict.
        preds = trace.predictions
        if strategy.mode in ("none", "silent") or strategy.q <= 0.0:
            self.preds = []
        elif strategy.q >= 1.0:
            self.preds = list(preds)
        else:
            self.preds = [pr for pr in preds if rng.random() < strategy.q]
        self.pi = 0
        self.horizon = trace.horizon
        self.exhausted = False

    # -- event peeking ------------------------------------------------------ #
    def _next_fault(self) -> float:
        if self.sil:
            # silent strikes never interrupt a primitive (latent until the
            # next verification): consumed by _consume_silent instead
            return math.inf
        while self.fi < len(self.fault_times) and self.fault_times[self.fi] < self.t:
            # fault during downtime/recovery: recovery restarts (rc is the
            # duration of the repair in progress — D+R everywhere except
            # after a two-level disk recovery)
            f = self.fault_times[self.fi]
            if f >= self.t - self.rc:
                self.n_faults += 1
                self.t = f + self.rc
            self.fi += 1
        return (
            self.fault_times[self.fi] if self.fi < len(self.fault_times) else math.inf
        )

    def _consume_silent(self) -> None:
        """Consume latent strikes up to the current clock: they corrupt
        state silently instead of interrupting the primitive."""
        if not self.sil:
            return
        while self.fi < len(self.fault_times) and self.fault_times[self.fi] <= self.t:
            self.corrupt = min(self.corrupt, self.fault_times[self.fi])
            self.fi += 1

    def _next_action(self) -> float:
        """Time at which the next trusted prediction requires action."""
        lead = self.M if self.strat.mode == "migration" else self.C
        while self.pi < len(self.preds) and self.preds[self.pi].t0 - lead < self.t:
            self.pi += 1  # too late to act on this prediction
        if self.pi >= len(self.preds):
            return math.inf
        return self.preds[self.pi].t0 - lead

    # -- primitive timeline operations -------------------------------------- #
    def _handle_fault(self, t_fault: float) -> None:
        self.n_faults += 1
        self.unsaved = 0.0
        self.period_work = 0.0
        if self.tl:
            # tier coin consumed with the fault (callers advanced fi past
            # the consumed column already): u >= f sends recovery to disk
            u = self.tiers[self.fi - 1] if self.fi - 1 < len(self.tiers) else 1.0
            if u >= self.fmem:
                # disk-tier recovery: restart from the last disk checkpoint
                self.t = t_fault + self.D + self.R2
                self.saved = self.saved_d
                self.dk_ctr = 0
                self.rc = self.D + self.R2
                self.n_disk += 1
                return
            self.rc = self.D + self.R
        self.t = t_fault + self.D + self.R

    def _work_until(self, t_target: float, credit_period: bool = True) -> bool:
        """Perform useful work from self.t to t_target.

        Caps at job completion.  Returns True if a fault interrupted."""
        remaining = self.W - self.saved - self.unsaved
        t_target = min(t_target, self.t + remaining)
        nf = self._next_fault()
        if nf <= t_target:
            self.fi += 1
            self._handle_fault(nf)
            return True
        dt = t_target - self.t
        self.unsaved += dt
        if credit_period:
            self.period_work += dt
        self.t = t_target
        self._consume_silent()
        if self.saved + self.unsaved >= self.W - _EPS:
            self.done = True
        return False

    def _idle_until(self, t_target: float) -> bool:
        """Idle (no useful work) until t_target.  True if faulted."""
        nf = self._next_fault()
        if nf <= t_target:
            self.fi += 1
            self._handle_fault(nf)
            return True
        self.t = t_target
        self._consume_silent()
        return False

    def _checkpoint(self, proactive: bool) -> bool:
        """Take a checkpoint; returns True if a fault aborted it.

        A fault at the exact completion instant does *not* abort the
        checkpoint (this realizes the exact-date prediction semantics where
        the checkpoint completes right when the fault strikes).

        The rho-th regular checkpoint of a two-level lane is the disk tier
        (cost C + C2); the k_V-th regular checkpoint of a silent-error lane
        verifies (cost C + V) and detects any latent corruption, rolling
        back past every unverified checkpoint to the verified frontier.
        Proactive checkpoints hit the memory tier and never verify."""
        cost = self.C
        disk_int = ver_int = False
        if not proactive:
            disk_int = self.tl and self.dk_ctr >= self.rho - 1
            ver_int = self.sil and self.ck_v >= self.kv - 1
            if disk_int:
                cost += self.C2
            if ver_int:
                cost += self.V
        end = self.t + cost
        nf = self._next_fault()
        if nf < end:
            self.fi += 1
            self._handle_fault(nf)
            return True
        self.t = end
        self.saved += self.unsaved
        self.unsaved = 0.0
        if proactive:
            self.n_pro += 1
        else:
            self.n_reg += 1
            self.period_work = 0.0
            if self.tl:
                if disk_int:
                    self.saved_d = self.saved
                    self.dk_ctr = 0
                else:
                    self.dk_ctr += 1
        self._consume_silent()
        if not proactive and self.sil:
            if ver_int:
                if math.isfinite(self.corrupt):
                    # verification caught a latent corruption: recover and
                    # roll back to the last verified checkpoint
                    self.t += self.D + self.R
                    self.saved = self.saved_v
                    self.period_work = 0.0
                    self.corrupt = math.inf
                    self.n_faults += 1
                    self.n_det += 1
                else:
                    self.saved_v = self.saved
                self.ck_v = 0
            else:
                self.ck_v += 1
        return False

    # -- proactive episodes (Section 4 strategies) --------------------------- #
    def _episode(self, pred) -> None:
        """Handle one trusted prediction, starting at t = t0 - C (or later if
        a regular checkpoint was running at the action point)."""
        t0, I = pred.t0, pred.window
        mode = self.strat.mode

        if mode == "migration":
            # Migrate during [t0 - M, t0]; the predicted fault (if real)
            # hits the *vacated* node, so it is cancelled up front — the
            # migration completes right when the fault was due (Section
            # 3.4); other faults can still interrupt the migration.
            if pred.fault_time is not None and pred.fault_time >= self.t:
                try:
                    idx = self.fault_times.index(pred.fault_time, self.fi)
                    self.fault_times.pop(idx)
                except ValueError:
                    pass
            if self._idle_until(t0):
                return
            self.n_mig += 1
            return

        # Pre-window checkpoint, as late as possible (Figure 1(a)).
        if self.t <= t0 - self.C:
            if self.t < t0 - self.C:
                if self._work_until(t0 - self.C):
                    return
                if self.done:
                    return
            if self._checkpoint(proactive=True):
                return
        else:
            # no time for the extra checkpoint (Figure 1(b)): work until t0,
            # crediting only max(0, t0 - C - now) to the period (Alg. 1 l.12)
            credit_until = max(self.t, t0 - self.C)
            if self._work_until(credit_until, credit_period=True):
                return
            if not self.done and self._work_until(t0, credit_period=False):
                return
            if self.done:
                return

        if mode in ("exact", "two_level"):
            # Instant: straight back to regular mode at t0.  Two-level
            # episodes behave the same — the proactive checkpoint above
            # hit the memory tier (cost C, no disk-stride advance), and a
            # disk-tier fault will ignore it and roll back to the durable
            # frontier anyway (see _handle_fault).
            return

        if mode == "nockpt":
            self._work_until(t0 + I, credit_period=False)
            return

        if mode == "withckpt":
            T_P = self.strat.T_P or max(self.C, I)
            end = t0 + I
            while self.t < end - _EPS and not self.done:
                seg = min(self.t + (T_P - self.C), end - self.C)
                if seg > self.t:
                    if self._work_until(seg, credit_period=False):
                        return
                    if self.done:
                        return
                if self._checkpoint(proactive=True):
                    return
            return

        raise ValueError(f"unknown mode {mode!r}")  # pragma: no cover

    # -- main loop ----------------------------------------------------------- #
    def run(self) -> SimResult:
        T_R, C = self.strat.T_R, self.C
        work_per_period = max(T_R - C, 1e-9)
        guard = 0
        while not self.done:
            guard += 1
            if guard > 50_000_000:  # pragma: no cover
                raise RuntimeError("simulator did not converge")
            if self.t > self.horizon:
                self.exhausted = True
            na = self._next_action()
            remaining_to_ckpt = work_per_period - self.period_work

            if remaining_to_ckpt <= _EPS:
                # Regular checkpoint due.  If the action point falls inside
                # the checkpoint, Algorithm 1's "no time" path applies: the
                # episode starts right after this checkpoint completes.
                if self._checkpoint(proactive=False):
                    continue
                if na <= self.t and self.pi < len(self.preds):
                    pred = self.preds[self.pi]
                    self.pi += 1
                    if pred.t0 >= self.t - 1e-9:
                        self._episode(pred)
                continue

            # Work segment until the next regular checkpoint.
            seg_end = self.t + remaining_to_ckpt
            if na < seg_end:
                if self._work_until(na):
                    continue
                if self.done:
                    break
                pred = self.preds[self.pi]
                self.pi += 1
                self._episode(pred)
                continue
            if self._work_until(seg_end):
                continue

        return SimResult(
            makespan=self.t,
            work=self.W,
            n_faults=self.n_faults,
            n_proactive_ckpts=self.n_pro,
            n_regular_ckpts=self.n_reg,
            n_migrations=self.n_mig,
            trace_exhausted=self.exhausted,
            n_disk_recoveries=self.n_disk,
            n_detections=self.n_det,
        )


def simulate(
    work: float,
    platform: Platform,
    strategy: Strategy,
    trace: EventTrace,
    rng: Optional[np.random.Generator] = None,
) -> SimResult:
    rng = rng or np.random.default_rng(0)
    return _Engine(work, platform, strategy, trace, rng).run()


def _traces_for(
    work: float,
    platform: Platform,
    strategy: Strategy,
    pred: PredictorModel,
    n_runs: int,
    rng: np.random.Generator,
    fault_dist: Optional[Distribution],
    false_pred_dist: Optional[Distribution],
    horizon_factor: float,
    n_components: Optional[int],
    stationary: bool,
):
    from .events import make_event_traces_batch

    return make_event_traces_batch(
        rng,
        n_runs,
        horizon=horizon_factor * work,
        mtbf=platform.mu,
        recall=pred.recall if strategy.mode != "none" else 0.0,
        precision=pred.precision,
        window=pred.window,
        lead=pred.lead,
        fault_dist=fault_dist or exponential(),
        false_pred_dist=false_pred_dist,
        n_components=n_components,
        stationary=stationary,
    )


def simulate_many(
    work: float,
    platform: Platform,
    strategy: Strategy,
    pred: PredictorModel,
    n_runs: int = 100,
    seed: int = 0,
    fault_dist: Optional[Distribution] = None,
    false_pred_dist: Optional[Distribution] = None,
    horizon_factor: float = 12.0,
    n_components: Optional[int] = None,
    stationary: bool = False,
    engine=UNSET,
    devices=UNSET,
    mesh=UNSET,
    trace_mode=UNSET,
    config: Optional[EngineConfig] = None,
) -> List[SimResult]:
    """Average behaviour over ``n_runs`` random traces (paper: 100 runs).

    Traces are generated in one batched pass (see
    :func:`repro.core.events.make_event_traces_batch`) and, with the default
    ``engine="batch"``, simulated by the vectorized lane-per-trace engine
    (:mod:`repro.core.batch_sim`).  ``engine="jax"`` advances the same
    lanes device-resident (:mod:`repro.core.jax_sim`); ``devices=`` /
    ``mesh=`` shard the lanes across a device set (results are identical
    for any device count).  ``engine="scalar"`` runs the reference scalar
    engine over the *same* traces — useful as an oracle and for
    benchmarking the vectorization itself.

    ``trace_mode="device"`` skips host generation entirely: a
    :class:`~repro.core.events.TraceSpec` of counter-based RNG streams is
    built instead, which the JAX engine samples lazily *on the device*
    (O(1) cursor state per lane, no event arrays — see
    :mod:`repro.core.jax_sim`); the batch/scalar engines replay the same
    streams on the host via :meth:`TraceSpec.materialize`.  Device traces
    are statistically equivalent (same laws) but not draw-identical to
    host traces, and require an inverse-CDF-capable distribution
    (exp/Weibull/lognormal/uniform) without ``n_components``.

    ``n_components`` switches the fault trace from a single renewal stream
    to the superposition of per-component renewals (see events.py).

    ``config`` is the :class:`~repro.core.engine.EngineConfig` spelling
    of the engine knobs; the bare ``engine=``/``devices=``/``mesh=``/
    ``trace_mode=`` keywords are deprecated shims for it."""
    cfg = resolve_engine_config(
        config, "simulate_many",
        engine=engine, devices=devices, mesh=mesh, trace_mode=trace_mode,
    ).validate()
    engine, devices, mesh = cfg.engine, cfg.devices, cfg.mesh
    trace_mode = cfg.trace_mode
    if cfg.collect != "lanes":
        raise ValueError("simulate_many returns per-run results; use "
                         "run_grid for collect='stats'")
    rng = np.random.default_rng(seed)
    if trace_mode == "device":
        if n_components:
            raise ValueError(
                "trace_mode='device' does not support superposed component "
                "traces (n_components); use trace_mode='host'"
            )
        from .events import make_trace_spec

        traces = make_trace_spec(
            n_runs,
            horizon=horizon_factor * work,
            mtbf=platform.mu,
            recall=pred.recall if strategy.mode != "none" else 0.0,
            precision=pred.precision,
            window=pred.window,
            lead=pred.lead,
            fault_dist=fault_dist,
            false_pred_dist=false_pred_dist,
            seed=seed,
        )
    else:
        traces = _traces_for(
            work, platform, strategy, pred, n_runs, rng, fault_dist,
            false_pred_dist, horizon_factor, n_components, stationary,
        )
    if engine == "batch":
        from .batch_sim import simulate_batch

        return simulate_batch(work, platform, strategy, traces, rng=rng).to_results()
    if engine == "jax":
        from .jax_sim import simulate_batch_jax

        return simulate_batch_jax(
            work, platform, strategy, traces, rng=rng,
            devices=devices, mesh=mesh,
        ).to_results()
    if engine == "scalar":
        if trace_mode == "device":
            traces = traces.materialize()
        return [
            simulate(
                work, platform, strategy, traces.lane(i),
                np.random.default_rng(seed + 1000 * i + 17),
            )
            for i in range(n_runs)
        ]
    raise ValueError(
        f"unknown engine {engine!r} (expected 'batch', 'jax' or 'scalar')"
    )


#: BestPeriod's default period-multiplier grid (Section 5)
PERIOD_GRID = (0.25, 0.4, 0.6, 0.8, 1.0, 1.25, 1.6, 2.0, 3.0, 4.0)


def _best_period_search(
    work: float,
    platform: Platform,
    base: Strategy,
    pred: PredictorModel,
    n_runs: int = 20,
    seed: int = 0,
    fault_dist: Optional[Distribution] = None,
    grid: Sequence[float] = PERIOD_GRID,
    config: Optional[EngineConfig] = None,
) -> tuple[float, float]:
    """BestPeriod counterpart (Section 5): brute-force the regular period.

    All period multipliers are evaluated on identical traces in a single
    batched engine call (lanes = multipliers x runs).

    ``engine="jax"`` routes the period x runs lane block through the
    fused device engine as one cell-multiplexed ``collect="stats"``
    dispatch (one cell per candidate period, ``devices=``/``mesh=``
    shard the lanes): the per-period mean wastes come back as O(periods)
    device-reduced sums and no O(lanes) result arrays are ever
    materialized on the host.  Both engines consume identical traces, so
    they agree on the argmin (waste agrees to float rounding); if jax is
    unavailable the batch engine is used as a fallback.

    Returns ``(best_T_R, best_mean_waste)``."""
    cfg = (config if config is not None else EngineConfig()).validate()
    engine, devices, mesh = cfg.engine, cfg.devices, cfg.mesh
    if engine not in ("batch", "jax"):
        raise ValueError(
            f"unknown engine {engine!r} (expected 'batch' or 'jax')"
        )
    if cfg.trace_mode != "host":
        raise ValueError("best_period_search generates host traces only")
    if engine == "jax":
        try:
            import jax  # noqa: F401

            from .jax_sim import simulate_batch_jax
        except ImportError:  # pragma: no cover - jax is a soft dependency
            engine = "batch"
    rng = np.random.default_rng(seed)
    traces = _traces_for(
        work, platform, base, pred, n_runs, rng, fault_dist, None, 12.0,
        None, False,
    )
    periods = [max(platform.C * 1.01, base.T_R * m) for m in grid]
    if engine == "jax":
        strats_c = [
            Strategy(base.name, t_r, base.q, base.mode, base.T_P)
            for t_r in periods
        ]
        cidx = np.repeat(
            np.arange(len(periods), dtype=np.int32), n_runs
        )
        sums = simulate_batch_jax(
            [work] * len(periods), [platform] * len(periods), strats_c,
            traces.tile(len(grid)), rng=rng, cell_index=cidx,
            collect="stats", devices=devices, mesh=mesh,
        )
        mean_waste = sums.mean_waste
    else:
        from .batch_sim import simulate_batch

        strats: List[Strategy] = []
        for t_r in periods:
            strats.extend(
                [Strategy(base.name, t_r, base.q, base.mode, base.T_P)]
                * n_runs
            )
        res = simulate_batch(
            work, platform, strats, traces.tile(len(grid)), rng=rng
        )
        mean_waste = res.waste.reshape(len(grid), n_runs).mean(axis=1)
    gi = int(np.argmin(mean_waste))
    return periods[gi], float(mean_waste[gi])


def best_period_search(
    work: float,
    platform: Platform,
    base: Strategy,
    pred: PredictorModel,
    n_runs: int = 20,
    seed: int = 0,
    fault_dist: Optional[Distribution] = None,
    grid: Sequence[float] = PERIOD_GRID,
    engine=UNSET,
    devices=UNSET,
    mesh=UNSET,
    config: Optional[EngineConfig] = None,
) -> tuple[float, float]:
    """Deprecated spelling of the simulated period search — use
    :func:`repro.core.optimize` with ``method="search"`` (one API for the
    analytic, batched-Newton and simulated optimizers), or pass
    ``config=EngineConfig(...)`` for the engine knobs."""
    warnings.warn(
        "repro.core.best_period_search() is deprecated; use "
        "repro.core.optimize(..., method='search')",
        DeprecationWarning,
        stacklevel=2,
    )
    cfg = resolve_engine_config(
        config, "best_period_search",
        engine=engine, devices=devices, mesh=mesh,
    )
    return _best_period_search(
        work, platform, base, pred, n_runs=n_runs, seed=seed,
        fault_dist=fault_dist, grid=grid, config=cfg,
    )
