"""Core of the reproduction: the paper's analytical models and simulator.

Aupy, Robert, Vivien, Zaidouni — "Impact of fault prediction on
checkpointing strategies" (2012).

Modules:
  events      fault/prediction traces, rate identities (Section 2)
  waste       closed-form waste models, Eqs (1)(3)(4)(5)(6) (Sections 3-4)
  periods     optimal periods T_Y / T_1 / T_P, q in {0,1}, Eq (12) (Sections 3.3-4.3)
  analytic    the differentiable analytic layer: branchless waste twins
              over the fused engine's per-cell tables + the unified
              optimize() entry point (analytic / batched-Newton / search)
  engine      EngineConfig — the one home of the engine-selection knobs
  simulator   discrete-event engine reproducing Section 5 (scalar oracle)
  batch_sim   lane-per-trace vectorized engine (NumPy, one lane per trace)
  jax_sim     device-resident engine (jit + lax.while_loop + Pallas step;
              imported lazily so NumPy-only paths never pay the JAX import)
  predictor   predictor presets (Table 3) and runtime interface
"""

from .analytic import (
    PolicyTable,
    analytic_period_cells,
    analytic_waste_cells,
    optimize,
    optimize_cells,
)
from .batch_sim import (
    BatchResult,
    simulate_batch,
)
from .engine import (
    EngineConfig,
)
from .events import (
    BatchTraces,
    Distribution,
    EventTrace,
    FaultEvent,
    PredictionEvent,
    exponential,
    lognormal,
    make_event_trace,
    make_event_traces_batch,
    make_fault_trace,
    mu_e,
    mu_np,
    mu_p,
    uniform,
    weibull,
)
from .periods import (
    OptimalPolicy,
    best_policy,
    nockpt_dominates,
    optimize_exact,
    optimize_instant,
    optimize_migration,
    optimize_nockpt,
    optimize_withckpt,
    t_daly,
    t_extr,
    t_one,
    t_p_extr,
    t_p_opt,
    t_young,
)
from .predictor import (
    TABLE3_PREDICTORS,
    OnlinePredictor,
    SimulatedPredictor,
    predictor_preset,
)
from .simulator import (
    SimResult,
    Strategy,
    best_period_search,
    simulate,
    simulate_many,
)
from .waste import (
    ALPHA,
    Platform,
    PredictorModel,
    waste_checkpoint_only,
    waste_exact,
    waste_instant,
    waste_migration,
    waste_nockpt,
    waste_withckpt,
    waste_young,
)

# simulate_batch_jax deliberately stays out of __all__: a star import
# must remain jax-free; the lazy __getattr__ below still serves
# `repro.core.simulate_batch_jax` (and from-imports of it) on demand
__all__ = [k for k in dir() if not k.startswith("_")]


def __getattr__(name: str):
    if name == "simulate_batch_jax":  # lazy: pulls in jax on first use
        from .jax_sim import simulate_batch_jax

        return simulate_batch_jax
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
