"""Asynchronous checkpointing: device->host snapshot on the critical path,
disk drain in the background.

The paper's period formula wants the *blocking* cost C (the time training
is stalled); durability needs the *drain* to finish.  The executor
therefore tracks two quantities:

    C_block  = time of the synchronous device->host snapshot
    C_full   = C_block + background disk write

A checkpoint becomes *restorable* only once drained; until then the
previous durable checkpoint is the restore point.  (If a fault lands in
the drain window, we lose the in-flight checkpoint — exactly the risk the
paper's D+R+T/2 term already prices, since the restore point is older.)
"""

from __future__ import annotations

import threading
import time
from typing import Dict, Optional

import jax

from .store import CheckpointStore

__all__ = ["AsyncCheckpointer"]


class AsyncCheckpointer:
    def __init__(self, store: CheckpointStore, keep: int = 2):
        self.store = store
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._durable_step: Optional[int] = None
        self._last_metrics: Dict[str, float] = {}
        self._lock = threading.Lock()
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ #
    @property
    def durable_step(self) -> Optional[int]:
        with self._lock:
            return self._durable_step

    @property
    def metrics(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._last_metrics)

    def wait(self) -> None:
        t = self._thread
        if t is not None:
            t.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree, prev_tree=None) -> float:
        """Snapshot synchronously, drain asynchronously.

        Returns C_block (seconds the caller was stalled)."""
        self.wait()  # one in-flight checkpoint at a time
        t0 = time.monotonic()
        host = jax.tree.map(lambda x: jax.device_get(x), tree)
        c_block = time.monotonic() - t0

        def drain():
            try:
                t1 = time.monotonic()
                m = self.store.save(step, host, prev_tree=prev_tree)
                m["c_block"] = c_block
                m["c_full"] = c_block + (time.monotonic() - t1)
                with self._lock:
                    self._durable_step = step
                    self._last_metrics = m
                self.store.gc(keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=drain, daemon=True)
        self._thread.start()
        return c_block
