"""In-memory buddy checkpointing (double in-memory checkpoint/restart,
after Zheng, Ni & Kale [13]).

Each node keeps its own newest snapshot in host RAM *and* a replica of a
buddy node's snapshot.  A single-node failure restores from the buddy in
O(RAM copy) instead of O(disk read), collapsing the paper's R for the
common case; only multi-node or correlated failures fall back to the disk
tier.  On this single-process container the "nodes" are logical ranks and
the buddy exchange is a dict copy; on a real pod the exchange is one
ICI/DCN neighbor send of the local shard (cost modelled in ft/elastic.py).

The executor composes tiers: memory tier for fast restart, disk tier
(AsyncCheckpointer) for durability.
"""

from __future__ import annotations

import copy
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["BuddyMemoryCheckpoint"]


class BuddyMemoryCheckpoint:
    def __init__(self, n_nodes: int = 2):
        self.n_nodes = n_nodes
        # own[i] = (step, snapshot of rank i); buddy[i] = replica of own[(i-1) % n]
        self._own: Dict[int, Any] = {}
        self._buddy: Dict[int, Any] = {}

    def buddy_of(self, rank: int) -> int:
        return (rank + 1) % self.n_nodes

    def save(self, step: int, tree, rank: int = 0) -> float:
        """Snapshot to own RAM and replicate to the buddy.  Returns seconds."""
        t0 = time.monotonic()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._own[rank] = (step, host)
        self._buddy[self.buddy_of(rank)] = (step, copy.deepcopy(host))
        return time.monotonic() - t0

    def restore(self, rank: int = 0, lost: bool = False):
        """Restore rank's snapshot; ``lost=True`` simulates the node's RAM
        being gone, forcing the buddy path."""
        if not lost and rank in self._own:
            return self._own[rank]
        buddy_holder = self.buddy_of(rank)
        if buddy_holder in self._buddy:
            return self._buddy[buddy_holder]
        return None

    def latest_step(self, rank: int = 0) -> Optional[int]:
        got = self.restore(rank)
        return got[0] if got else None
