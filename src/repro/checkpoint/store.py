"""Sharded on-disk checkpoint store with atomic commit and re-shard restore.

Layout:

    <root>/step_000123.tmp-<nonce>/      (staging, renamed on commit)
    <root>/step_000123/
        manifest.json     tree structure, shapes, dtypes, crc32 per leaf,
                          codec info
        <leaf-key>.npy    raw (or codec-encoded) array payloads

Properties:
* **Atomic commit** — payloads land in a tmp dir; `os.replace` to the final
  name is the commit point, so a fault mid-write never yields a checkpoint
  that `latest_step` would restore.
* **Integrity** — per-leaf crc32 checked on restore.
* **Re-shard on restore** — arrays are loaded as host numpy and
  `jax.device_put` with *target* shardings, so a checkpoint written on a
  512-chip mesh restores onto 256 chips (elastic shrink after losing a
  pod) or onto a single CPU device for tests.
* **Codec** — optional int8(+delta) encoding via checkpoint/codec.py,
  shrinking the byte volume (and thus the paper's C).
"""

from __future__ import annotations

import json
import os
import shutil
import time
import uuid
import zlib
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import numpy as np

from . import codec as codec_mod

__all__ = ["CheckpointStore", "latest_step"]


def _flatten_with_keys(tree) -> Dict[str, Any]:
    flat = {}

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_paths:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp") and "tmp-" not in d:
            try:
                steps.append(int(d.split("_")[1]))
            except (IndexError, ValueError):
                continue
    return max(steps) if steps else None


@dataclass
class CheckpointStore:
    root: str
    codec: str = "raw"  # raw | int8 | int8_delta

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree, prev_tree=None) -> Dict[str, float]:
        """Blocking save.  Returns timing/byte metrics."""
        t0 = time.monotonic()
        flat = _flatten_with_keys(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        t_snapshot = time.monotonic() - t0

        prev_flat = _flatten_with_keys(prev_tree) if prev_tree is not None else {}
        os.makedirs(self.root, exist_ok=True)
        tmp = self._dir(step) + f".tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "codec": self.codec, "leaves": {}}
        raw_bytes = 0
        stored_bytes = 0
        for key, arr in host.items():
            raw_bytes += arr.nbytes
            fname = key.replace("/", "__") + ".npy"
            if self.codec != "raw" and arr.dtype in (np.float32, np.float16) and arr.size >= 1024:
                prev = prev_flat.get(key) if self.codec == "int8_delta" else None
                prev = (
                    np.asarray(jax.device_get(prev)) if prev is not None else None
                )
                payload, meta = codec_mod.encode_array(arr, prev)
                np.save(os.path.join(tmp, fname), payload, allow_pickle=False)
                meta["crc"] = zlib.crc32(payload.tobytes())
                stored_bytes += payload.nbytes
            else:
                np.save(os.path.join(tmp, fname), arr, allow_pickle=False)
                meta = {
                    "codec": "raw",
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                    "crc": zlib.crc32(arr.tobytes()),
                }
                stored_bytes += arr.nbytes
            manifest["leaves"][key] = meta
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = self._dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # commit point
        t_total = time.monotonic() - t0
        return {
            "t_snapshot": t_snapshot,
            "t_total": t_total,
            "raw_bytes": float(raw_bytes),
            "stored_bytes": float(stored_bytes),
        }

    # ------------------------------------------------------------------ #
    def restore(
        self,
        step: int,
        target=None,
        shardings=None,
        prev_tree=None,
    ):
        """Restore step.  ``target`` (pytree of arrays or ShapeDtypeStructs)
        supplies the tree structure; ``shardings`` (matching pytree or
        single sharding) re-shards onto the current mesh."""
        d = self._dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        prev_flat = _flatten_with_keys(prev_tree) if prev_tree is not None else {}

        host: Dict[str, np.ndarray] = {}
        for key, meta in manifest["leaves"].items():
            fname = key.replace("/", "__") + ".npy"
            payload = np.load(os.path.join(d, fname), allow_pickle=False)
            if zlib.crc32(payload.tobytes()) != meta["crc"]:
                raise IOError(f"checkpoint corruption in {key} at step {step}")
            if meta["codec"] == "raw":
                host[key] = payload
            else:
                prev = prev_flat.get(key)
                prev = np.asarray(jax.device_get(prev)) if prev is not None else None
                host[key] = codec_mod.decode_array(payload, meta, prev)

        if target is None:
            return host
        flat_target = _flatten_with_keys(target)
        missing = set(flat_target) - set(host)
        if missing:
            raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]} ...")

        flat_shard = (
            _flatten_with_keys(shardings)
            if shardings is not None and not _is_single_sharding(shardings)
            else None
        )

        restored = {}
        for key, ref in flat_target.items():
            arr = host[key]
            want_dtype = ref.dtype
            if str(arr.dtype) != str(want_dtype):
                arr = arr.astype(want_dtype)
            if flat_shard is not None:
                restored[key] = jax.device_put(arr, flat_shard[key])
            elif shardings is not None:
                restored[key] = jax.device_put(arr, shardings)
            else:
                restored[key] = jax.device_put(arr)
        # rebuild tree structure from target
        leaves_paths = jax.tree_util.tree_flatten_with_path(target)
        keys_in_order = [
            "/".join(_path_str(p) for p in path) for path, _ in leaves_paths[0]
        ]
        return jax.tree_util.tree_unflatten(
            leaves_paths[1], [restored[k] for k in keys_in_order]
        )

    def gc(self, keep: int = 2) -> None:
        """Drop all but the newest ``keep`` committed checkpoints."""
        if not os.path.isdir(self.root):
            return
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.root)
            if d.startswith("step_") and "tmp-" not in d
        )
        for s in steps[:-keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)


def _is_single_sharding(x) -> bool:
    return isinstance(x, jax.sharding.Sharding)
