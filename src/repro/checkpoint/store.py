"""Sharded on-disk checkpoint store with atomic commit and re-shard restore.

Layout:

    <root>/step_000123.tmp-<nonce>/      (staging, renamed on commit)
    <root>/step_000123/
        manifest.json     tree structure, shapes, dtypes, crc32 per leaf,
                          codec info
        <leaf-key>.npy    raw (or codec-encoded) array payloads

Properties:
* **Atomic commit** — payloads land in a tmp dir; `os.replace` to the final
  name is the commit point, so a fault mid-write never yields a checkpoint
  that `latest_step` would restore.  Payloads, the manifest and its
  checksum sidecar are fsync'd before the rename, and the parent
  directory after it — a power cut cannot commit unsynced bytes.
* **Integrity** — per-leaf crc32 checked on restore; `manifest.crc`
  sidecar guards the manifest itself.  `restore_latest` walks committed
  steps newest-first and *skips* torn or corrupt ones (truncated shard,
  crc mismatch, unreadable manifest), so a campaign resumes from the
  newest checkpoint that actually survived.
* **Re-shard on restore** — arrays are loaded as host numpy and
  `jax.device_put` with *target* shardings, so a checkpoint written on a
  512-chip mesh restores onto 256 chips (elastic shrink after losing a
  pod) or onto a single CPU device for tests.
* **Codec** — optional int8(+delta) encoding via checkpoint/codec.py,
  shrinking the byte volume (and thus the paper's C).
"""

from __future__ import annotations

import json
import os
import shutil
import time
import uuid
import warnings
import zlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from . import codec as codec_mod

__all__ = ["CheckpointStore", "latest_step"]


def _write_durable(path: str, writer) -> None:
    """Write via ``writer(file)`` and fsync before returning: bytes are
    on the platter (or the journal) before the commit rename can make
    the checkpoint visible."""
    with open(path, "wb") as f:
        writer(f)
        f.flush()
        os.fsync(f.fileno())


def _fsync_dir(path: str) -> None:
    """Persist a directory entry (the rename itself) — best-effort on
    filesystems without O_DIRECTORY fsync support."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - exotic fs
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover - exotic fs
        pass
    finally:
        os.close(fd)


def _flatten_with_keys(tree) -> Dict[str, Any]:
    flat = {}

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_paths:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp") and "tmp-" not in d:
            try:
                steps.append(int(d.split("_")[1]))
            except (IndexError, ValueError):
                continue
    return max(steps) if steps else None


@dataclass
class CheckpointStore:
    root: str
    codec: str = "raw"  # raw | int8 | int8_delta

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree, prev_tree=None) -> Dict[str, float]:
        """Blocking save.  Returns timing/byte metrics."""
        t0 = time.monotonic()
        flat = _flatten_with_keys(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        t_snapshot = time.monotonic() - t0

        prev_flat = _flatten_with_keys(prev_tree) if prev_tree is not None else {}
        os.makedirs(self.root, exist_ok=True)
        tmp = self._dir(step) + f".tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "codec": self.codec, "leaves": {}}
        raw_bytes = 0
        stored_bytes = 0
        for key, arr in host.items():
            raw_bytes += arr.nbytes
            fname = key.replace("/", "__") + ".npy"
            if self.codec != "raw" and arr.dtype in (np.float32, np.float16) and arr.size >= 1024:
                prev = prev_flat.get(key) if self.codec == "int8_delta" else None
                prev = (
                    np.asarray(jax.device_get(prev)) if prev is not None else None
                )
                payload, meta = codec_mod.encode_array(arr, prev)
                _write_durable(
                    os.path.join(tmp, fname),
                    lambda f, p=payload: np.save(f, p, allow_pickle=False),
                )
                meta["crc"] = zlib.crc32(payload.tobytes())
                stored_bytes += payload.nbytes
            else:
                _write_durable(
                    os.path.join(tmp, fname),
                    lambda f, a=arr: np.save(f, a, allow_pickle=False),
                )
                meta = {
                    "codec": "raw",
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                    "crc": zlib.crc32(arr.tobytes()),
                }
                stored_bytes += arr.nbytes
            manifest["leaves"][key] = meta
        mbytes = json.dumps(manifest).encode("utf-8")
        _write_durable(
            os.path.join(tmp, "manifest.json"), lambda f: f.write(mbytes)
        )
        # checksum sidecar: lets restore_latest reject a manifest whose
        # own bytes rotted without parsing garbage JSON first
        _write_durable(
            os.path.join(tmp, "manifest.crc"),
            lambda f: f.write(f"{zlib.crc32(mbytes):08x}".encode()),
        )
        final = self._dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # commit point
        _fsync_dir(self.root)
        t_total = time.monotonic() - t0
        return {
            "t_snapshot": t_snapshot,
            "t_total": t_total,
            "raw_bytes": float(raw_bytes),
            "stored_bytes": float(stored_bytes),
        }

    # ------------------------------------------------------------------ #
    def restore(
        self,
        step: int,
        target=None,
        shardings=None,
        prev_tree=None,
    ):
        """Restore step.  ``target`` (pytree of arrays or ShapeDtypeStructs)
        supplies the tree structure; ``shardings`` (matching pytree or
        single sharding) re-shards onto the current mesh."""
        d = self._dir(step)
        with open(os.path.join(d, "manifest.json"), "rb") as f:
            mbytes = f.read()
        crc_path = os.path.join(d, "manifest.crc")
        if os.path.exists(crc_path):  # sidecar absent on legacy checkpoints
            with open(crc_path) as f:
                want = f.read().strip()
            if f"{zlib.crc32(mbytes):08x}" != want:
                raise IOError(f"manifest corruption at step {step}")
        manifest = json.loads(mbytes.decode("utf-8"))
        prev_flat = _flatten_with_keys(prev_tree) if prev_tree is not None else {}

        host: Dict[str, np.ndarray] = {}
        for key, meta in manifest["leaves"].items():
            fname = key.replace("/", "__") + ".npy"
            payload = np.load(os.path.join(d, fname), allow_pickle=False)
            if zlib.crc32(payload.tobytes()) != meta["crc"]:
                raise IOError(f"checkpoint corruption in {key} at step {step}")
            if meta["codec"] == "raw":
                host[key] = payload
            else:
                prev = prev_flat.get(key)
                prev = np.asarray(jax.device_get(prev)) if prev is not None else None
                host[key] = codec_mod.decode_array(payload, meta, prev)

        if target is None:
            return host
        flat_target = _flatten_with_keys(target)
        missing = set(flat_target) - set(host)
        if missing:
            raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]} ...")

        flat_shard = (
            _flatten_with_keys(shardings)
            if shardings is not None and not _is_single_sharding(shardings)
            else None
        )

        restored = {}
        for key, ref in flat_target.items():
            arr = host[key]
            want_dtype = ref.dtype
            if str(arr.dtype) != str(want_dtype):
                arr = arr.astype(want_dtype)
            if flat_shard is not None:
                restored[key] = jax.device_put(arr, flat_shard[key])
            elif shardings is not None:
                restored[key] = jax.device_put(arr, shardings)
            else:
                restored[key] = jax.device_put(arr)
        # rebuild tree structure from target
        leaves_paths = jax.tree_util.tree_flatten_with_path(target)
        keys_in_order = [
            "/".join(_path_str(p) for p in path) for path, _ in leaves_paths[0]
        ]
        return jax.tree_util.tree_unflatten(
            leaves_paths[1], [restored[k] for k in keys_in_order]
        )

    def steps(self) -> List[int]:
        """Committed step numbers, ascending (staging dirs excluded)."""
        if not os.path.isdir(self.root):
            return []
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and "tmp-" not in d:
                try:
                    out.append(int(d.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return sorted(out)

    def restore_latest(
        self, target=None, shardings=None, prev_tree=None
    ) -> Optional[Tuple[int, Any]]:
        """Restore the newest checkpoint that passes integrity checks.

        Walks committed steps newest-first; a torn or corrupt one
        (truncated ``.npy`` shard, crc mismatch, missing or rotted
        manifest, missing leaves) is *skipped with a warning* instead of
        aborting the restore — the previous durable checkpoint is the
        restore point, exactly the risk the paper's recovery term
        already prices.  Returns ``(step, tree)`` or ``None`` if no
        checkpoint survives."""
        for step in reversed(self.steps()):
            try:
                tree = self.restore(
                    step, target=target, shardings=shardings,
                    prev_tree=prev_tree,
                )
                return step, tree
            except (IOError, OSError, ValueError, KeyError, EOFError,
                    json.JSONDecodeError) as e:
                warnings.warn(
                    f"skipping unusable checkpoint step {step}: {e}",
                    RuntimeWarning,
                    stacklevel=2,
                )
        return None

    def gc(self, keep: int = 2) -> None:
        """Drop all but the newest ``keep`` committed checkpoints."""
        for s in self.steps()[:-keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)


def _is_single_sharding(x) -> bool:
    return isinstance(x, jax.sharding.Sharding)
