"""Int8 (+delta) checkpoint codec.

The paper's waste scales as sqrt(C): halving checkpoint bytes cuts waste by
~29% of its checkpoint share.  Encoding:

* ``int8``        blockwise absmax quantization (block 256), 4x smaller
                  than f32 payloads (scales add ~1.6%);
* ``int8_delta``  quantize ``x - prev`` instead; between nearby optimizer
                  steps the delta has much smaller dynamic range, so the
                  same 8 bits carry ~256x finer resolution (lossy but
                  bounded by block absmax / 127).

The on-device tiled quantizer twin is ``kernels/ckpt_codec.py`` (Pallas);
this module is the host/numpy path used by the store, and the oracle the
kernel is validated against re-exports from here.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["encode_array", "decode_array", "encode_tree", "decode_tree"]

_BLOCK = 256


def _pack(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    flat = x.reshape(-1).astype(np.float32)
    pad = (-flat.size) % _BLOCK
    if pad:
        flat = np.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = np.maximum(np.abs(blocks).max(axis=1) / 127.0, 1e-12).astype(np.float32)
    q = np.clip(np.round(blocks / scale[:, None]), -127, 127).astype(np.int8)
    return q, scale


def encode_array(
    x: np.ndarray, prev: Optional[np.ndarray] = None
) -> Tuple[np.ndarray, Dict]:
    """Returns (payload bytes as a structured flat array, meta)."""
    base = x.astype(np.float32)
    mode = "int8"
    if prev is not None and prev.shape == x.shape:
        base = base - prev.astype(np.float32)
        mode = "int8_delta"
    q, scale = _pack(base)
    payload = np.concatenate([q.reshape(-1).view(np.uint8), scale.view(np.uint8)])
    meta = {
        "codec": mode,
        "dtype": str(x.dtype),
        "shape": list(x.shape),
        "n": int(x.size),
        "nblocks": int(scale.size),
    }
    return payload, meta


def decode_array(
    payload: np.ndarray, meta: Dict, prev: Optional[np.ndarray] = None
) -> np.ndarray:
    nblocks = meta["nblocks"]
    qn = nblocks * _BLOCK
    q = payload[:qn].view(np.int8).reshape(nblocks, _BLOCK)
    scale = payload[qn : qn + 4 * nblocks].view(np.float32)
    x = (q.astype(np.float32) * scale[:, None]).reshape(-1)[: meta["n"]]
    x = x.reshape(meta["shape"])
    if meta["codec"] == "int8_delta":
        if prev is None:
            raise ValueError("int8_delta payload needs the previous checkpoint")
        x = x + prev.astype(np.float32)
    return x.astype(meta["dtype"])


def encode_tree(flat: Dict[str, np.ndarray], prev: Optional[Dict] = None):
    out = {}
    for k, v in flat.items():
        p = prev.get(k) if prev else None
        out[k] = encode_array(np.asarray(v), p if p is None else np.asarray(p))
    return out


def decode_tree(enc: Dict, prev: Optional[Dict] = None):
    out = {}
    for k, (payload, meta) in enc.items():
        p = prev.get(k) if prev else None
        out[k] = decode_array(payload, meta, p if p is None else np.asarray(p))
    return out
