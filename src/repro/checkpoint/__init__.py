"""Checkpoint substrate: sharded store, async pipeline, buddy memory tier,
int8 delta codec.  The measured blocking cost feeds the paper's period
formula as C (see ft/executor.py)."""

from .store import CheckpointStore, latest_step
from .async_ckpt import AsyncCheckpointer
from .memory import BuddyMemoryCheckpoint
from .codec import encode_tree, decode_tree

__all__ = [
    "CheckpointStore",
    "latest_step",
    "AsyncCheckpointer",
    "BuddyMemoryCheckpoint",
    "encode_tree",
    "decode_tree",
]
