"""Logical-axis sharding rules (GSPMD) for the model stack.

Tensors in ``repro.models`` are annotated with *logical* axis names; a
:class:`ShardingRules` table maps those to mesh axes.  This keeps model code
mesh-agnostic: the same model runs on a single CPU device (``rules=None``,
all constraints become no-ops), the 16x16 single-pod mesh, or the
2x16x16 multi-pod mesh.

Default mapping (TPU v5e-class pod, axes ``(pod?, data, model)``):

    batch        -> (pod, data)     data parallelism
    vocab        -> model           embedding / LM-head tensor parallelism
    heads        -> model           attention-head TP (only when the arch's
                                    head count divides the axis; otherwise
                                    attention is replicated across `model`
                                    and the MLP soaks the parallelism)
    ff / inner   -> model           MLP / Mamba / RWKV feature TP
    experts      -> model           expert parallelism (MoE)
    cache_seq    -> model           sequence-sharded KV cache for decode
                                    (flash-decode style partial softmax,
                                    GSPMD inserts the combine collectives)
    dp_shard     -> data            ZeRO-1 optimizer-moment sharding
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec

AxisAssignment = Union[None, str, Tuple[str, ...]]

__all__ = ["ShardingRules", "make_rules", "logical_spec", "shard"]


@dataclass(frozen=True)
class ShardingRules:
    """Mapping from logical axis names to mesh axis names."""

    table: Mapping[str, AxisAssignment] = field(default_factory=dict)
    mesh: Optional[jax.sharding.Mesh] = None

    def assignment(self, logical: Optional[str]) -> AxisAssignment:
        if logical is None:
            return None
        return self.table.get(logical)

    def spec(self, *logical: Optional[str]) -> PartitionSpec:
        return PartitionSpec(*[self.assignment(l) for l in logical])

    def named(self, *logical: Optional[str]) -> NamedSharding:
        assert self.mesh is not None, "rules have no mesh bound"
        return NamedSharding(self.mesh, self.spec(*logical))

    def with_overrides(self, **kw: AxisAssignment) -> "ShardingRules":
        t = dict(self.table)
        t.update(kw)
        return replace(self, table=t)


def make_rules(
    mesh: jax.sharding.Mesh,
    *,
    shard_heads: bool = True,
    shard_experts: bool = True,
    zero1: bool = True,
    seq_shard_cache: bool = True,
    overrides: Optional[Mapping[str, AxisAssignment]] = None,
) -> ShardingRules:
    axes = mesh.axis_names
    data_axes: Tuple[str, ...] = tuple(a for a in axes if a in ("pod", "data"))
    model = "model" if "model" in axes else None
    table: dict[str, AxisAssignment] = {
        "batch": data_axes if data_axes else None,
        # activations may shard differently from inputs/caches: serve-mode
        # 2D weight sharding replicates activations over `data`
        # (act_batch=None) while the KV cache stays batch-sharded
        "act_batch": data_axes if data_axes else None,
        "cache_batch": data_axes if data_axes else None,
        "seq": None,
        # FSDP/ZeRO-3: weight matrices shard their d_model (input) dim over
        # `data`, giving 2-D (data x model) weight sharding — without it the
        # 400B-class archs replicate ~1 TB of parameters per data rank.
        # GSPMD inserts the per-layer weight all-gathers this implies.
        "d_model": "data" if "data" in axes else None,
        "vocab": model,
        "heads": model if shard_heads else None,
        "kv_heads": None,  # GQA KV is small; replicated across model
        "head_dim": None,
        # context parallelism: archs whose head count does not divide the
        # model axis (arctic 56H, qwen2-0.5b 14H, smollm 9H) shard the
        # attention *query sequence* over `model` instead — otherwise the
        # quadratic attention work replicates 16x across the axis.
        "attn_seq": None if shard_heads else model,
        "ff": model,
        "inner": model,  # mamba d_inner / rwkv feature dim
        "cache_inner": model,  # SSM cache feature dim (never widened)
        "state": None,
        "experts": model if shard_experts else None,
        "expert_ff": None,
        "layers": None,
        "cache_seq": model if seq_shard_cache else None,
        "dp_shard": "data" if (zero1 and "data" in axes) else None,
        "frontend": None,
    }
    if overrides:
        table.update(overrides)
    return ShardingRules(table=table, mesh=mesh)


def logical_spec(rules: Optional[ShardingRules], *logical) -> PartitionSpec:
    if rules is None:
        return PartitionSpec()
    return rules.spec(*logical)


def shard(x, rules: Optional[ShardingRules], *logical):
    """Apply a with_sharding_constraint from logical axis names (no-op when
    rules is None, e.g. single-device tests)."""
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.spec(*logical))
