"""Distribution substrate: logical-axis sharding rules and pipeline utils."""

from .sharding import ShardingRules, logical_spec, make_rules, shard

__all__ = ["ShardingRules", "logical_spec", "make_rules", "shard"]
