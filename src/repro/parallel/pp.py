"""Pipeline parallelism: GPipe-style microbatched stage execution.

The assigned shapes are served by DP x TP (+EP/SP) on the production mesh,
but at 1000+-node scale a pipeline axis bounds the TP collective domain,
so the framework ships a composable PP layer:

* the layer stack is split into ``n_stages`` contiguous stages;
* microbatches flow through stages in the classic GPipe schedule
  (fill, steady state, drain) implemented as a ``lax.scan`` over
  ``n_micro + n_stages - 1`` ticks with a ``collective_permute`` ring
  between stage neighbours each tick;
* runs under ``shard_map`` over a "stage" mesh axis; each rank holds only
  its stage's parameters (pipeline-sharded weights).

``pipeline_apply`` is forward-only-composable (jax differentiates through
the scan + ppermute); ``bubble_fraction`` gives the schedule's idle share
(n_stages - 1) / (n_micro + n_stages - 1) for the napkin math used when
choosing n_micro.

Validated in tests/test_pp.py: pipelined == sequential stack execution on
a forced multi-device mesh, plus the bubble accounting.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_apply", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Idle fraction of the GPipe schedule."""
    ticks = n_micro + n_stages - 1
    return (n_stages - 1) / ticks


def pipeline_apply(
    stage_fn: Callable,
    params_stacked,
    x: jax.Array,  # (n_micro, micro_batch, ...) microbatched activations
    mesh: jax.sharding.Mesh,
    axis: str = "stage",
):
    """Run ``stage_fn(stage_params, activation) -> activation`` as a
    GPipe pipeline over the ``axis`` mesh dimension.

    params_stacked: pytree with leading dim n_stages (sharded over `axis`).
    Returns activations of shape (n_micro, micro_batch, ...) — the output
    of the final stage per microbatch.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    ticks = n_micro + n_stages - 1

    def local(params_local, x_local):
        # params_local: stage's params (leading dim 1); x_local: full
        # microbatch stream replicated (simple variant; a production
        # deployment feeds stage 0 only)
        sid = jax.lax.axis_index(axis)
        p_stage = jax.tree.map(lambda a: a[0], params_local)
        micro = x_local  # (n_micro, mb, ...)
        mb_shape = micro.shape[1:]

        def tick(carry, t):
            buf, outputs = carry  # buf: activation entering this stage
            # stage s processes microbatch (t - s) when 0 <= t-s < n_micro
            mb_idx = t - sid
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            # stage 0 ingests fresh microbatches; others use the ring buf
            inject = jnp.where(
                sid == 0,
                micro[jnp.clip(mb_idx, 0, n_micro - 1)],
                buf,
            )
            y = stage_fn(p_stage, inject)
            y = jnp.where(active, y, buf)
            # last stage records its finished microbatch
            outputs = jnp.where(
                active & (sid == n_stages - 1),
                outputs.at[jnp.clip(mb_idx, 0, n_micro - 1)].set(y),
                outputs,
            )
            # ring: stage s -> s+1
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf_next = jax.lax.ppermute(y, axis, perm)
            return (buf_next, outputs), None

        buf0 = jnp.zeros(mb_shape, x.dtype)
        outs0 = jnp.zeros((n_micro,) + mb_shape, x.dtype)
        (_, outputs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(ticks)
        )
        # outputs live on the last stage; broadcast to all ranks via psum
        # of the one-hot-owned buffer (cheap relative to the compute)
        owned = jnp.where(sid == n_stages - 1, outputs, jnp.zeros_like(outputs))
        return jax.lax.psum(owned, axis)

    from jax.experimental.shard_map import shard_map

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )(params_stacked, x)
