"""Model substrate: transformer / MoE / SSM stacks for the assigned archs."""

from .transformer import LanguageModel

__all__ = ["LanguageModel"]
