"""Transformer primitives: norms, RoPE, GQA attention, SwiGLU MLP.

Pure-functional (params are pytrees of jnp arrays).  All activations and
weights are annotated with logical shardings via ``parallel.sharding.shard``;
with ``rules=None`` every annotation is a no-op (single-device tests).

Attention implementations:
  dense    materialized scores — short sequences (<= dense_attn_max)
  chunked  online-softmax over KV chunks (flash-style memory behaviour in
           pure XLA; the algorithmic twin of kernels/flash_attention.py)
  pallas   the Pallas TPU kernel (TPU runtime only)
Decode uses a single-token dot-product over the (optionally seq-sharded)
KV cache; with the cache sharded on `cache_seq`, GSPMD lowers the softmax
reductions into the flash-decode partial-combine pattern.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import ShardingRules, shard

__all__ = [
    "RuntimeFlags",
    "rms_norm",
    "rope_table",
    "apply_rope",
    "attention",
    "attention_decode",
    "swiglu_mlp",
    "init_attention",
    "init_mlp",
    "cross_entropy_loss",
]


@dataclass(frozen=True)
class RuntimeFlags:
    """Execution options — hillclimb levers, not architecture."""

    attn_impl: str = "auto"  # auto | dense | chunked | pallas
    dense_attn_max: int = 8192
    kv_chunk: int = 1024
    remat_policy: str = "none"  # none | full | dots
    compute_dtype: jnp.dtype = jnp.bfloat16
    moe_capacity_factor: Optional[float] = None  # override arch default
    seq_shard_prefill: bool = False  # sequence-parallel prefill activations


# --------------------------------------------------------------------------- #
# Norms / embeddings
# --------------------------------------------------------------------------- #
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_table(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """Returns (sin, cos) tables of shape positions.shape + (head_dim/2,)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., seq, heads, head_dim); sin/cos: (..., seq, head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# --------------------------------------------------------------------------- #
# Attention
# --------------------------------------------------------------------------- #
def init_attention(key, cfg, dtype) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(D)
    s_out = 1.0 / math.sqrt(H * hd)
    p = {
        "wq": (jax.random.normal(k1, (D, H, hd)) * s_in).astype(dtype),
        "wk": (jax.random.normal(k2, (D, KV, hd)) * s_in).astype(dtype),
        "wv": (jax.random.normal(k3, (D, KV, hd)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k4, (H, hd, D)) * s_out).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    return p


def attention_specs(cfg) -> dict:
    """Logical axes per attention parameter."""
    h = "heads" if cfg.shard_heads_ok() else None
    specs = {
        "wq": ("d_model", h, "head_dim"),
        "wk": ("d_model", "kv_heads", "head_dim"),
        "wv": ("d_model", "kv_heads", "head_dim"),
        "wo": (h, "head_dim", "d_model"),
    }
    if cfg.qkv_bias:
        specs["bq"] = (h, "head_dim")
        specs["bk"] = ("kv_heads", "head_dim")
        specs["bv"] = ("kv_heads", "head_dim")
    return specs


def _project_qkv(p, x, cfg, sin, cos, rules, head_ax):
    H, KV = cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    group = H // KV
    if group > 1:  # GQA: broadcast KV to per-query-head layout
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    q = shard(q, rules, "act_batch", "seq", head_ax, None)
    k = shard(k, rules, "act_batch", "seq", head_ax, None)
    v = shard(v, rules, "act_batch", "seq", head_ax, None)
    return q, k, v


def _dense_attn(q, k, v, causal: bool):
    hd = q.shape[-1]
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if causal:
        S, T = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", w, v)


def _chunked_attn(q, k, v, causal: bool, kv_chunk: int):
    """Online-softmax over KV chunks (flash-style, O(S * chunk) memory)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    n_chunks = max(T // kv_chunk, 1)
    kc = k.reshape(B, n_chunks, T // n_chunks, H, hd)
    vc = v.reshape(B, n_chunks, T // n_chunks, H, hd)
    scale = 1.0 / math.sqrt(hd)
    q32 = q.astype(jnp.float32)
    q_pos = jnp.arange(S)[:, None]

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, ci = inp
        s = jnp.einsum("bqhk,bshk->bhqs", q32, kb.astype(jnp.float32)) * scale
        if causal:
            kv_pos = ci * (T // n_chunks) + jnp.arange(T // n_chunks)[None, :]
            mask = q_pos + (T - S) >= kv_pos  # allow prefix offset
            s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqs,bshk->bhqk", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, a0),
        (
            jnp.moveaxis(kc, 1, 0),
            jnp.moveaxis(vc, 1, 0),
            jnp.arange(n_chunks),
        ),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.einsum("bhqk->bqhk", out).astype(q.dtype)


def attention(
    p: dict,
    x: jax.Array,
    cfg,
    sin: jax.Array,
    cos: jax.Array,
    rules: Optional[ShardingRules],
    flags: RuntimeFlags,
    causal: bool = True,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence attention (train / prefill).

    Returns (output, (k_cache, v_cache)) — the cache holds the *unrepeated*
    KV heads for decode reuse.
    """
    head_ax = "heads" if cfg.shard_heads_ok() else None
    # keep raw KV (per kv-head) for the cache before GQA broadcast
    k_raw = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_raw = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        k_raw = k_raw + p["bk"]
        v_raw = v_raw + p["bv"]
    k_raw = apply_rope(k_raw, sin, cos)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = apply_rope(q, sin, cos)
    group = cfg.num_heads // cfg.num_kv_heads
    k = jnp.repeat(k_raw, group, axis=2) if group > 1 else k_raw
    v = jnp.repeat(v_raw, group, axis=2) if group > 1 else v_raw
    # query (and output) shard over heads when possible, else over the
    # query-sequence dim (context parallelism — see parallel/sharding.py);
    # K/V stay seq-replicated so every q shard sees the full context.
    q = shard(q, rules, "act_batch", "attn_seq", head_ax, None)
    k = shard(k, rules, "act_batch", None, head_ax, None)
    v = shard(v, rules, "act_batch", None, head_ax, None)

    impl = flags.attn_impl
    if impl == "auto":
        impl = "dense" if q.shape[1] <= flags.dense_attn_max else "chunked"
    if impl == "pallas":
        from ..kernels import ops as kops

        out = kops.flash_attention(q, k, v, causal=causal)
    elif impl == "chunked":
        out = _chunked_attn(q, k, v, causal, flags.kv_chunk)
    else:
        out = _dense_attn(q, k, v, causal)
    out = shard(out, rules, "act_batch", "attn_seq", head_ax, None)
    y = jnp.einsum("bqhk,hkd->bqd", out, p["wo"])
    return shard(y, rules, "act_batch", "seq", None), (k_raw, v_raw)


def attention_decode(
    p: dict,
    x: jax.Array,  # (B, 1, D)
    cfg,
    pos: jax.Array,  # scalar position of the new token
    kv_cache: Tuple[jax.Array, jax.Array],  # (B, S_max, KV, hd) each
    rules: Optional[ShardingRules],
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token decode against a (seq-shardable) KV cache."""
    sin, cos = rope_table(pos[None], cfg.resolved_head_dim, cfg.rope_theta)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, sin[None], cos[None])
    k = apply_rope(k, sin[None], cos[None])

    ck, cv = kv_cache
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), pos, axis=1)
    ck = shard(ck, rules, "cache_batch", "cache_seq", None, None)
    cv = shard(cv, rules, "cache_batch", "cache_seq", None, None)

    group = cfg.num_heads // cfg.num_kv_heads
    B, S, KV, hd = ck.shape
    qh = q[:, 0].reshape(B, KV, group, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qh.astype(jnp.float32),
                        ck.astype(jnp.float32)) / math.sqrt(hd)
    valid = jnp.arange(S)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, cv.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.num_heads, hd).astype(x.dtype)
    y = jnp.einsum("bqhk,hkd->bqd", out, p["wo"])
    return shard(y, rules, "act_batch", None, None), (ck, cv)


# --------------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------------- #
def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "wi_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "wi_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    }


MLP_SPECS = {
    "wi_gate": ("d_model", "ff"),
    "wi_up": ("d_model", "ff"),
    "wo": ("ff", "d_model"),
}


def swiglu_mlp(p: dict, x: jax.Array, rules: Optional[ShardingRules]) -> jax.Array:
    h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    h = shard(h, rules, "act_batch", "seq", "ff")
    y = h @ p["wo"]
    return shard(y, rules, "act_batch", "seq", None)


# --------------------------------------------------------------------------- #
# Loss
# --------------------------------------------------------------------------- #
def cross_entropy_loss(
    logits: jax.Array,
    targets: jax.Array,
    mask: Optional[jax.Array] = None,
    rules: Optional[ShardingRules] = None,
) -> jax.Array:
    """Token-mean cross entropy, safe for vocab-sharded logits.

    Written so GSPMD never gathers the vocab dimension: the max and the
    exp-sum reduce *over* the sharded axis (partial reduce + tiny (B,S)
    all-reduce), and the gold logit is extracted by a masked sum over the
    sharded axis instead of ``take_along_axis`` (which would all-gather
    the full logits — 12.9 GB/device at 152k vocab).  The vocab-iota mask
    carries an explicit sharding constraint so propagation cannot decide
    to replicate it (and drag the logits with it)."""
    V = logits.shape[-1]
    l32 = logits.astype(jnp.float32)
    l32 = shard(l32, rules, "act_batch", "seq", "vocab")
    m = jax.lax.stop_gradient(jnp.max(l32, axis=-1))  # (B,S) partial+AR
    z = jnp.exp(l32 - m[..., None])
    logz = jnp.log(jnp.sum(z, axis=-1)) + m
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, l32.shape, len(l32.shape) - 1)
    sel = vocab_iota == targets[..., None]
    sel = shard(sel, rules, "act_batch", "seq", "vocab")
    gold = jnp.sum(jnp.where(sel, l32, 0.0), axis=-1)  # partial + AR
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
