"""Language-model assembly for all assigned architectures.

One class covers dense, MoE, SSM (RWKV6), hybrid (Jamba-style interleave)
and stub-frontend (audio/VLM) families:

* the layer stack is a repeating *pattern* of :class:`LayerSpec`s
  (pattern length P, repeated R times, L = P * R); parameters are stacked
  over R and the stack is driven by ``lax.scan`` -> HLO size is O(P), not
  O(L), which keeps 80-layer 72B configs compilable in seconds;
* each pattern position owns a mixer (attn | mamba | rwkv) and an MLP
  (dense | moe | rwkv_cm | none), with pre-RMSNorm residual wiring;
* ``loss_fn`` (train), ``prefill`` and ``decode_step`` (serving) are the
  three public entry points the launchers lower;
* modality frontends (musicgen EnCodec frames, LLaVA anyres patches) are
  stubs: the batch provides precomputed ``frontend`` embeddings that are
  prepended to the token embeddings (assignment rule).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, LayerSpec
from ..parallel.sharding import ShardingRules, shard
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (
    MLP_SPECS,
    RuntimeFlags,
    attention,
    attention_decode,
    attention_specs,
    cross_entropy_loss,
    init_attention,
    init_mlp,
    rms_norm,
    rope_table,
    swiglu_mlp,
)

__all__ = ["LanguageModel"]

_AUX_LOSS_WEIGHT = 0.01

#: parameters kept in float32 inside the compute graph (norm scales, SSM
#: decay/state params, router logits) — everything else is cast to the
#: compute dtype (bf16) at use time, mixed-precision style.
_KEEP_F32 = {
    "mixer_norm",
    "mlp_norm",
    "router",
    "A_log",
    "D_skip",
    "dt_b",
    "w0",
    "u",
    "ln",
    "mu",
}


def _cast_tree(d: dict, dtype) -> dict:
    out = {}
    for k, v in d.items():
        if isinstance(v, dict):
            out[k] = _cast_tree(v, dtype)
        elif k in _KEEP_F32:
            out[k] = v
        elif hasattr(v, "dtype") and jnp.issubdtype(v.dtype, jnp.floating):
            out[k] = v.astype(dtype)
        else:
            out[k] = v
    return out


def _dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


class LanguageModel:
    """Pure-functional LM; params/caches are plain pytrees."""

    def __init__(
        self,
        cfg: ArchConfig,
        rules: Optional[ShardingRules] = None,
        flags: Optional[RuntimeFlags] = None,
    ):
        self.cfg = cfg
        self.rules = rules
        self.flags = flags if flags is not None else RuntimeFlags()
        self.param_dtype = _dtype_of(cfg.param_dtype)

    # ------------------------------------------------------------------ #
    # Parameters
    # ------------------------------------------------------------------ #
    def _init_block(self, key, spec: LayerSpec) -> dict:
        cfg, dt = self.cfg, self.param_dtype
        km, kl = jax.random.split(key)
        block: dict = {"mixer_norm": jnp.ones((cfg.d_model,), jnp.float32)}
        if spec.mixer == "attn":
            block["mixer"] = init_attention(km, cfg, dt)
        elif spec.mixer == "mamba":
            block["mixer"] = ssm_mod.init_mamba(km, cfg, dt)
        elif spec.mixer == "rwkv":
            block["mixer"] = ssm_mod.init_rwkv(km, cfg, dt)
        else:
            raise ValueError(spec.mixer)
        if spec.mlp != "none":
            block["mlp_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
            if spec.mlp == "dense":
                block["mlp"] = init_mlp(kl, cfg.d_model, cfg.d_ff, dt)
            elif spec.mlp == "moe":
                block["mlp"] = moe_mod.init_moe(kl, cfg, dt)
            elif spec.mlp == "rwkv_cm":
                block["mlp"] = ssm_mod.init_rwkv_channel_mix(kl, cfg, dt)
            else:
                raise ValueError(spec.mlp)
        return block

    def init(self, key: jax.Array) -> dict:
        cfg, dt = self.cfg, self.param_dtype
        ke, kh, kb = jax.random.split(key, 3)
        params: dict = {
            "embed": (
                jax.random.normal(ke, (cfg.vocab_size, cfg.d_model)) * 0.02
            ).astype(dt),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(kh, (cfg.d_model, cfg.vocab_size))
                / math.sqrt(cfg.d_model)
            ).astype(dt)
        blocks = []
        for pi, spec in enumerate(cfg.pattern):
            keys = jax.random.split(jax.random.fold_in(kb, pi), cfg.n_repeats)
            blocks.append(
                jax.vmap(lambda k, spec=spec: self._init_block(k, spec))(keys)
            )
        params["blocks"] = tuple(blocks)
        return params

    def abstract_params(self) -> dict:
        """ShapeDtypeStruct pytree (no allocation) for AOT lowering."""
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def _block_specs(self, spec: LayerSpec) -> dict:
        cfg = self.cfg
        out: dict = {"mixer_norm": ("d_model",)}
        if spec.mixer == "attn":
            out["mixer"] = attention_specs(cfg)
        elif spec.mixer == "mamba":
            out["mixer"] = dict(ssm_mod.MAMBA_SPECS)
        elif spec.mixer == "rwkv":
            sp = dict(ssm_mod.RWKV_SPECS)
            if not cfg.shard_heads_ok():
                sp = {k: tuple(None if a == "heads" else a for a in v)
                      for k, v in sp.items()}
            out["mixer"] = sp
        if spec.mlp != "none":
            out["mlp_norm"] = ("d_model",)
            if spec.mlp == "dense":
                out["mlp"] = dict(MLP_SPECS)
            elif spec.mlp == "moe":
                sp = dict(moe_mod.MOE_SPECS)
                if not (self.cfg.moe and self.cfg.moe.dense_residual):
                    sp.pop("dense", None)
                out["mlp"] = sp
            elif spec.mlp == "rwkv_cm":
                out["mlp"] = dict(ssm_mod.RWKV_CM_SPECS)
        return out

    def param_specs(self) -> dict:
        """Pytree of logical-axis tuples matching ``init``'s structure.

        Stacked block leaves get a leading "layers" (unsharded) axis.
        The embedding table is sharded on d_model (gather stays local);
        the LM head on vocab (logits TP)."""
        cfg = self.cfg
        specs: dict = {
            # The table shards on *vocab*: token gathers lower to the
            # masked-partial + all-reduce pattern, which GSPMD partitions
            # robustly (a d_model-sharded table trips the partitioner
            # inside the microbatch scan, and for tied embeddings would
            # replicate the (B,S,V) logits — 12.9 GB/device at 152k vocab).
            "embed": ("vocab", None),
            "final_norm": ("d_model",),
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = ("d_model", "vocab")
        blocks = []
        for spec in cfg.pattern:
            bs = self._block_specs(spec)
            blocks.append(
                jax.tree.map(
                    lambda t: ("layers",) + tuple(t),
                    bs,
                    is_leaf=lambda t: isinstance(t, tuple),
                )
            )
        specs["blocks"] = tuple(blocks)
        return specs

    # ------------------------------------------------------------------ #
    # Caches
    # ------------------------------------------------------------------ #
    def cache_struct(self, batch: int, max_seq: int) -> dict:
        """ShapeDtypeStruct pytree for the serving cache."""
        cfg = self.cfg
        R = cfg.n_repeats
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        blocks = []
        for spec in cfg.pattern:
            if spec.mixer == "attn":
                c = {
                    "k": jax.ShapeDtypeStruct(
                        (R, batch, max_seq, KV, hd), jnp.bfloat16
                    ),
                    "v": jax.ShapeDtypeStruct(
                        (R, batch, max_seq, KV, hd), jnp.bfloat16
                    ),
                }
            elif spec.mixer == "mamba":
                sp = ssm_mod.mamba_cache_spec(cfg, batch)
                c = {
                    k: jax.ShapeDtypeStruct((R,) + s, d)
                    for k, (s, d) in sp.items()
                }
            else:  # rwkv
                sp = ssm_mod.rwkv_cache_spec(cfg, batch)
                c = {
                    k: jax.ShapeDtypeStruct((R,) + s, d)
                    for k, (s, d) in sp.items()
                }
                if spec.mlp == "rwkv_cm":
                    c["cm_last"] = jax.ShapeDtypeStruct(
                        (R, batch, cfg.d_model), jnp.bfloat16
                    )
            blocks.append(c)
        return {
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "blocks": tuple(blocks),
        }

    def cache_specs(self) -> dict:
        """Logical shardings for the cache (KV seq-sharded for decode)."""
        cfg = self.cfg
        blocks = []
        h = "heads" if cfg.shard_heads_ok() else None
        for spec in cfg.pattern:
            if spec.mixer == "attn":
                c = {
                    "k": ("layers", "batch", "cache_seq", "kv_heads", None),
                    "v": ("layers", "batch", "cache_seq", "kv_heads", None),
                }
            elif spec.mixer == "mamba":
                # "cache_inner" stays model-only: "inner" may widen to
                # (data, model) in serve2d mode, which would collide with
                # the batch axis already using `data` in this spec
                c = {
                    "conv": ("layers", "batch", None, "cache_inner"),
                    "ssm": ("layers", "batch", "cache_inner", "state"),
                }
            else:
                # note: the last dim is d_model-sized but must NOT use the
                # "d_model" logical name — that maps to the data axis
                # (FSDP), which "batch" already occupies in this spec
                c = {
                    "state": ("layers", "batch", h, None, None),
                    "last": ("layers", "batch", None),
                }
                if spec.mlp == "rwkv_cm":
                    c["cm_last"] = ("layers", "batch", None)
            blocks.append(c)
        return {"pos": (), "blocks": tuple(blocks)}

    def init_cache(self, batch: int, max_seq: int) -> dict:
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_struct(batch, max_seq)
        )

    # ------------------------------------------------------------------ #
    # Blocks
    # ------------------------------------------------------------------ #
    def _apply_block(
        self,
        spec: LayerSpec,
        bp: dict,
        x: jax.Array,
        sin,
        cos,
        mode: str,
        cache: Optional[dict],
        pos,
    ) -> Tuple[jax.Array, Optional[dict], jax.Array]:
        cfg, rules, flags = self.cfg, self.rules, self.flags
        aux = jnp.zeros((), jnp.float32)
        new_cache: Dict[str, Any] = {}

        h = rms_norm(x, bp["mixer_norm"], cfg.norm_eps)
        if spec.mixer == "attn":
            if mode == "decode":
                y, (ck, cv) = attention_decode(
                    bp["mixer"], h, cfg, pos, (cache["k"], cache["v"]), rules
                )
                new_cache = {"k": ck, "v": cv}
            else:
                y, (k_raw, v_raw) = attention(
                    bp["mixer"], h, cfg, sin, cos, rules, flags
                )
                if mode == "prefill":
                    new_cache = {
                        "k": k_raw.astype(jnp.bfloat16),
                        "v": v_raw.astype(jnp.bfloat16),
                    }
        elif spec.mixer == "mamba":
            y, st = ssm_mod.mamba_apply(
                bp["mixer"], h, cfg, rules, cache=cache if mode == "decode" else None
            )
            if mode in ("prefill", "decode"):
                new_cache = {
                    "conv": st["conv"].astype(jnp.bfloat16),
                    "ssm": st["ssm"],
                }
        else:  # rwkv
            y, st = ssm_mod.rwkv_apply(
                bp["mixer"], h, cfg, rules, cache=cache if mode == "decode" else None
            )
            if mode in ("prefill", "decode"):
                new_cache = {
                    "state": st["state"],
                    "last": st["last"].astype(jnp.bfloat16),
                }
        x = x + y

        if spec.mlp != "none":
            h2 = rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
            if spec.mlp == "dense":
                x = x + swiglu_mlp(bp["mlp"], h2, rules)
            elif spec.mlp == "moe":
                y2, aux = moe_mod.moe_apply(
                    bp["mlp"], h2, cfg, rules, self.flags.moe_capacity_factor
                )
                x = x + y2
            elif spec.mlp == "rwkv_cm":
                last = cache.get("cm_last") if (cache and mode == "decode") else None
                if last is not None:
                    last = last.astype(h2.dtype)
                y2, cm_last = ssm_mod.rwkv_channel_mix(bp["mlp"], h2, rules, last)
                x = x + y2
                if mode in ("prefill", "decode"):
                    new_cache["cm_last"] = cm_last.astype(jnp.bfloat16)
        return x, (new_cache or None), aux

    def _run_stack(
        self, params, x, sin, cos, mode: str, cache: Optional[dict], pos
    ):
        """Scan the repeated pattern; returns (x, new_cache_blocks, aux)."""
        cfg = self.cfg
        pattern = cfg.pattern

        def body(carry, xs):
            xc, aux = carry
            if mode == "decode":
                bslices, cslices = xs
            else:
                bslices, cslices = xs, tuple(None for _ in pattern)
            outs = []
            for pi, spec in enumerate(pattern):
                bp = _cast_tree(bslices[pi], self.flags.compute_dtype)
                xc, nc, a = self._apply_block(
                    spec, bp, xc, sin, cos, mode, cslices[pi], pos
                )
                aux = aux + a
                outs.append(nc if nc is not None else {})
            return (xc, aux), tuple(outs)

        if self.flags.remat_policy == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        elif self.flags.remat_policy == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                prevent_cse=False,
            )

        xs = (params["blocks"], cache["blocks"]) if mode == "decode" else params["blocks"]
        (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
        return x, caches, aux

    # ------------------------------------------------------------------ #
    # Entry points
    # ------------------------------------------------------------------ #
    def _rope(self, seq_len: int):
        """(sin, cos) tables, or (None, None) for attention-free stacks."""
        cfg = self.cfg
        if not any(s.mixer == "attn" for s in cfg.pattern):
            return None, None
        return rope_table(
            jnp.arange(seq_len), cfg.resolved_head_dim, cfg.rope_theta
        )

    def _embed(self, params, tokens, frontend=None):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(
            self.flags.compute_dtype
        )
        if frontend is not None:
            x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
        return shard(x, self.rules, "act_batch", "seq", None)

    def _head(self, params, x):
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        w = params.get("lm_head")
        if w is None:
            w = params["embed"].T
        logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
        return shard(logits, self.rules, "act_batch", "seq", "vocab")

    def loss_fn(self, params, batch) -> Tuple[jax.Array, dict]:
        """batch: {"tokens": (B, S_tok) int32, optional "frontend": (B,P,D)}."""
        cfg = self.cfg
        tokens = batch["tokens"]
        frontend = batch.get("frontend")
        prefix = frontend.shape[1] if frontend is not None else 0
        x = self._embed(params, tokens, frontend)
        S = x.shape[1]
        sin, cos = self._rope(S)
        x, _, aux = self._run_stack(params, x, sin, cos, "train", None, None)
        logits = self._head(params, x)
        tgt_logits = logits[:, prefix : S - 1]
        loss = cross_entropy_loss(tgt_logits, tokens[:, 1:], rules=self.rules)
        total = loss + _AUX_LOSS_WEIGHT * aux
        return total, {"ce": loss, "aux": aux}

    def prefill(self, params, tokens, max_seq: int, frontend=None):
        """Returns (last-token logits, cache ready for decode)."""
        cfg = self.cfg
        x = self._embed(params, tokens, frontend)
        B, S = x.shape[0], x.shape[1]
        sin, cos = self._rope(S)
        x, caches, _ = self._run_stack(params, x, sin, cos, "prefill", None, None)
        logits = self._head(params, x[:, -1:, :])

        # place prefill caches into fixed max_seq buffers
        full = self.init_cache(B, max_seq)
        blocks = []
        for pi, spec in enumerate(cfg.pattern):
            c = caches[pi]
            fb = full["blocks"][pi]
            if spec.mixer == "attn":
                nb = {
                    "k": jax.lax.dynamic_update_slice_in_dim(
                        fb["k"], c["k"], 0, axis=2
                    ),
                    "v": jax.lax.dynamic_update_slice_in_dim(
                        fb["v"], c["v"], 0, axis=2
                    ),
                }
            else:
                nb = c
            blocks.append(nb)
        cache = {"pos": jnp.asarray(S, jnp.int32), "blocks": tuple(blocks)}
        return logits, cache

    def decode_step(self, params, cache, tokens):
        """One new token per sequence.  tokens: (B, 1) int32."""
        cfg = self.cfg
        pos = cache["pos"]
        x = self._embed(params, tokens)
        sin = cos = None
        x, new_blocks, _ = self._run_stack(params, x, sin, cos, "decode", cache, pos)
        logits = self._head(params, x)
        new_cache = {"pos": pos + 1, "blocks": new_blocks}
        return logits, new_cache
