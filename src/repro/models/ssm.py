"""State-space / linear-recurrence mixers: Mamba (Jamba) and RWKV6 (Finch).

Both use an exact per-token ``lax.scan`` as the reference/model path (the
chunked Pallas kernel in ``kernels/rwkv6.py`` is the TPU-optimized twin,
validated against this path).  Decode steps carry O(1)-per-token state:

  mamba: conv window (B, d_conv-1, d_inner) + SSM state (B, d_inner, d_state)
  rwkv6: WKV state (B, H, head_dim, head_dim) + previous token (B, D)

RWKV6 note: we implement the Finch core — data-dependent per-channel decay
``w_t = exp(-exp(w0 + LoRA(x_t)))``, bonus ``u``, per-head state — with a
static token-shift lerp (the paper's extra ddlerp LoRAs are omitted; noted
in DESIGN.md, parameter-count impact < 1%).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import ShardingRules, shard

__all__ = [
    "init_mamba",
    "mamba_apply",
    "mamba_decode",
    "MAMBA_SPECS",
    "init_rwkv",
    "rwkv_apply",
    "rwkv_decode",
    "RWKV_SPECS",
    "init_rwkv_channel_mix",
    "rwkv_channel_mix",
    "rwkv_channel_mix_decode",
    "RWKV_CM_SPECS",
]


# --------------------------------------------------------------------------- #
# Mamba
# --------------------------------------------------------------------------- #
def init_mamba(key, cfg, dtype) -> dict:
    D = cfg.d_model
    din = cfg.d_inner
    ds = cfg.ssm.d_state
    dc = cfg.ssm.d_conv
    dtr = cfg.ssm.dt_rank or math.ceil(D / 16)
    ks = jax.random.split(key, 6)
    s_in = 1.0 / math.sqrt(D)
    return {
        "in_proj": (jax.random.normal(ks[0], (D, 2 * din)) * s_in).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (din, dc)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": (
            jax.random.normal(ks[2], (din, dtr + 2 * ds)) / math.sqrt(din)
        ).astype(dtype),
        "dt_w": (jax.random.normal(ks[3], (dtr, din)) / math.sqrt(dtr)).astype(dtype),
        "dt_b": jnp.full((din,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(
            jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32), (din, 1))
        ),
        "D_skip": jnp.ones((din,), jnp.float32),
        "out_proj": (
            jax.random.normal(ks[4], (din, D)) / math.sqrt(din)
        ).astype(dtype),
    }


MAMBA_SPECS = {
    "in_proj": ("d_model", "inner"),
    "conv_w": ("inner", None),
    "conv_b": ("inner",),
    "x_proj": ("inner", None),
    "dt_w": (None, "inner"),
    "dt_b": ("inner",),
    "A_log": ("inner", "state"),
    "D_skip": ("inner",),
    "out_proj": ("inner", "d_model"),
}


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv over seq.  x: (B, S, din); w: (din, K)."""
    K = w.shape[1]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, din)
    y = sum(xp[:, j : j + x.shape[1]] * w[:, j] for j in range(K))
    new_state = xp[:, -(K - 1) :] if K > 1 else pad
    return y + b, new_state


def _ssm_scan(dt, A, Bc, Cc, x, h0):
    """Selective scan.  dt,x: (B,S,din); Bc,Cc: (B,S,ds); A: (din,ds);
    h0: (B,din,ds).  Returns y (B,S,din), h_final."""
    dt_t = jnp.moveaxis(dt, 1, 0)  # (S,B,din)
    x_t = jnp.moveaxis(x, 1, 0)
    B_t = jnp.moveaxis(Bc, 1, 0)  # (S,B,ds)
    C_t = jnp.moveaxis(Cc, 1, 0)

    def step(h, inp):
        dti, xi, bi, ci = inp
        da = jnp.exp(dti[..., None] * A)  # (B,din,ds)
        h = da * h + (dti * xi)[..., None] * bi[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, ci)
        return h, y

    h, ys = jax.lax.scan(step, h0, (dt_t, x_t, B_t, C_t))
    return jnp.moveaxis(ys, 0, 1), h


def mamba_apply(
    p: dict,
    x: jax.Array,
    cfg,
    rules: Optional[ShardingRules],
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, dict]:
    """Full-sequence Mamba mixer.  Returns (y, new_cache)."""
    B, S, D = x.shape
    ds = cfg.ssm.d_state
    dtr = cfg.ssm.dt_rank or math.ceil(D / 16)
    din = cfg.d_inner

    xz = x @ p["in_proj"]
    xz = shard(xz, rules, "act_batch", "seq", "inner")
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_state = cache["conv"] if cache else None
    xc, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    dbc = xc @ p["x_proj"]
    dt_raw = dbc[..., :dtr]
    Bc = dbc[..., dtr : dtr + ds].astype(jnp.float32)
    Cc = dbc[..., dtr + ds :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw @ p["dt_w"] + p["dt_b"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])

    h0 = (
        cache["ssm"]
        if cache
        else jnp.zeros((B, din, ds), jnp.float32)
    )
    y, h = _ssm_scan(dt, A, Bc, Cc, xc.astype(jnp.float32), h0)
    y = (y + p["D_skip"] * xc.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = shard(y, rules, "act_batch", "seq", "inner")
    out = y @ p["out_proj"]
    return shard(out, rules, "act_batch", "seq", None), {"conv": new_conv, "ssm": h}


def mamba_decode(p, x, cfg, cache, rules):
    """Single-token Mamba step.  x: (B, 1, D)."""
    y, new_cache = mamba_apply(p, x, cfg, rules, cache=cache)
    return y, new_cache


def mamba_cache_spec(cfg, batch: int):
    din, ds, dc = cfg.d_inner, cfg.ssm.d_state, cfg.ssm.d_conv
    return {
        "conv": ((batch, dc - 1, din), jnp.bfloat16),
        "ssm": ((batch, din, ds), jnp.float32),
    }


# --------------------------------------------------------------------------- #
# RWKV6 time mix
# --------------------------------------------------------------------------- #
def init_rwkv(key, cfg, dtype) -> dict:
    D = cfg.d_model
    hd = cfg.ssm.rwkv_head_dim
    H = cfg.rwkv_heads
    lora = cfg.ssm.decay_lora
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(D)
    return {
        "mu": jnp.ones((5, D), jnp.float32) * 0.5,  # r,k,v,w,g shift lerps
        "w0": jnp.zeros((H, hd), jnp.float32),
        "w_lora_a": (jax.random.normal(ks[0], (D, lora)) * s).astype(dtype),
        "w_lora_b": (jax.random.normal(ks[1], (lora, H, hd)) * 0.1).astype(dtype),
        "u": jnp.zeros((H, hd), jnp.float32),
        "wr": (jax.random.normal(ks[2], (D, H, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[3], (D, H, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[4], (D, H, hd)) * s).astype(dtype),
        "wg": (jax.random.normal(ks[5], (D, H, hd)) * s).astype(dtype),
        "wo": (
            jax.random.normal(ks[6], (H, hd, D)) / math.sqrt(H * hd)
        ).astype(dtype),
        "ln": jnp.ones((H, hd), jnp.float32),
    }


RWKV_SPECS = {
    "mu": (None, "d_model"),
    "w0": ("heads", None),
    "w_lora_a": ("d_model", None),
    "w_lora_b": (None, "heads", None),
    "u": ("heads", None),
    "wr": ("d_model", "heads", None),
    "wk": ("d_model", "heads", None),
    "wv": ("d_model", "heads", None),
    "wg": ("d_model", "heads", None),
    "wo": ("heads", None, "d_model"),
    "ln": ("heads", None),
}


def _token_shift(x, last):
    """xs[t] = x[t-1]; xs[0] = last (zeros at sequence start)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _wkv_scan(r, k, v, w, u, s0):
    """Exact WKV6 recurrence.
    r,k,v,w: (B,S,H,hd); u: (H,hd); s0: (B,H,hd,hd) -> y (B,S,H,hd), sT."""
    rt = jnp.moveaxis(r, 1, 0)
    kt = jnp.moveaxis(k, 1, 0)
    vt = jnp.moveaxis(v, 1, 0)
    wt = jnp.moveaxis(w, 1, 0)

    def step(s, inp):
        ri, ki, vi, wi = inp
        kv = ki[..., :, None] * vi[..., None, :]  # (B,H,hd_k,hd_v)
        y = jnp.einsum("bhi,bhij->bhj", ri, s + u[..., None] * kv)
        s = wi[..., None] * s + kv
        return s, y

    s, ys = jax.lax.scan(step, s0, (rt, kt, vt, wt))
    return jnp.moveaxis(ys, 0, 1), s


def _rwkv_projections(p, x, last, cfg):
    B, S, D = x.shape
    H, hd = cfg.rwkv_heads, cfg.ssm.rwkv_head_dim
    xs = _token_shift(x, last)
    mu = p["mu"]
    xi = [(x + mu[i] * (xs - x)).astype(x.dtype) for i in range(5)]  # r,k,v,w,g
    r = jnp.einsum("bsd,dhk->bshk", xi[0], p["wr"]).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", xi[1], p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bsd,dhk->bshk", xi[2], p["wv"]).astype(jnp.float32)
    g = jnp.einsum("bsd,dhk->bshk", xi[4], p["wg"])
    dd = jnp.einsum("bsd,dl,lhk->bshk", xi[3], p["w_lora_a"], p["w_lora_b"])
    w = jnp.exp(-jnp.exp(p["w0"] + dd.astype(jnp.float32)))  # (0,1) decays
    return r, k, v, w, g


def rwkv_apply(
    p: dict,
    x: jax.Array,
    cfg,
    rules: Optional[ShardingRules],
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, dict]:
    B, S, D = x.shape
    H, hd = cfg.rwkv_heads, cfg.ssm.rwkv_head_dim
    last = cache["last"].astype(x.dtype) if cache else jnp.zeros((B, D), x.dtype)
    s0 = cache["state"] if cache else jnp.zeros((B, H, hd, hd), jnp.float32)

    r, k, v, w, g = _rwkv_projections(p, x, last, cfg)
    r = shard(r, rules, "act_batch", "seq", "heads", None)
    k = shard(k, rules, "act_batch", "seq", "heads", None)
    v = shard(v, rules, "act_batch", "seq", "heads", None)
    w = shard(w, rules, "act_batch", "seq", "heads", None)

    y, sT = _wkv_scan(r, k, v, w, p["u"], s0)
    # per-head group norm
    mean = y.mean(axis=-1, keepdims=True)
    var = y.var(axis=-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 1e-5) * p["ln"]
    y = (y.astype(x.dtype)) * jax.nn.silu(g)
    y = shard(y, rules, "act_batch", "seq", "heads", None)
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"])
    out = shard(out, rules, "act_batch", "seq", None)
    return out, {"state": sT, "last": x[:, -1, :]}


def rwkv_decode(p, x, cfg, cache, rules):
    return rwkv_apply(p, x, cfg, rules, cache=cache)


def rwkv_cache_spec(cfg, batch: int):
    H, hd = cfg.rwkv_heads, cfg.ssm.rwkv_head_dim
    return {
        "state": ((batch, H, hd, hd), jnp.float32),
        "last": ((batch, cfg.d_model), jnp.bfloat16),
    }


# --------------------------------------------------------------------------- #
# RWKV channel mix
# --------------------------------------------------------------------------- #
def init_rwkv_channel_mix(key, cfg, dtype) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu": jnp.ones((2, D), jnp.float32) * 0.5,
        "wk": (jax.random.normal(k1, (D, F)) / math.sqrt(D)).astype(dtype),
        "wv": (jax.random.normal(k2, (F, D)) / math.sqrt(F)).astype(dtype),
        "wr": (jax.random.normal(k3, (D, D)) / math.sqrt(D)).astype(dtype),
    }


RWKV_CM_SPECS = {
    "mu": (None, "d_model"),
    "wk": ("d_model", "ff"),
    "wv": ("ff", "d_model"),
    "wr": ("d_model", None),
}


def rwkv_channel_mix(p, x, rules, last=None):
    B, S, D = x.shape
    if last is None:
        last = jnp.zeros((B, D), x.dtype)
    xs = _token_shift(x, last)
    xk = (x + p["mu"][0] * (xs - x)).astype(x.dtype)
    xr = (x + p["mu"][1] * (xs - x)).astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    k = shard(k, rules, "act_batch", "seq", "ff")
    kv = k @ p["wv"]
    out = jax.nn.sigmoid(xr @ p["wr"]) * kv
    return shard(out, rules, "act_batch", "seq", None), x[:, -1, :]


def rwkv_channel_mix_decode(p, x, rules, last):
    return rwkv_channel_mix(p, x, rules, last=last)
