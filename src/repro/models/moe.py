"""Mixture-of-Experts layer with capacity-based sorted dispatch.

GShard/Switch-style expert parallelism adapted for GSPMD:

1. router top-k per token (softmax over chosen experts);
2. tokens sorted by expert id, ranked within expert, dropped beyond the
   capacity ``C = ceil(top_k * tokens * capacity_factor / E)``;
3. scatter into per-expert buffers ``(E, C, D)`` — the (E,) dim is sharded
   over the `model` mesh axis, so GSPMD lowers the scatter/gather pair into
   the canonical all-to-all dispatch/combine schedule;
4. expert SwiGLU as batched einsums over (E, C, ...);
5. weighted combine back to token order.

The dispatch cost is linear in tokens (sort + scatter), unlike the one-hot
matmul dispatch which is quadratic; expert FLOPs are exactly
``3 * 2 * E * C * D * F ~= top_k * cf * tokens * 3 * 2 * D * F``, i.e. the
active-parameter FLOPs the roofline model expects.

``arctic``-style dense residual: a regular MLP runs in parallel with the
expert path and the outputs are summed.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import ShardingRules, shard
from .layers import init_mlp

__all__ = ["init_moe", "moe_apply", "MOE_SPECS"]


def init_moe(key, cfg, dtype) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    E = cfg.moe.num_experts
    kr, k1, k2, k3, kd = jax.random.split(key, 5)
    s_in = 1.0 / math.sqrt(D)
    s_out = 1.0 / math.sqrt(F)
    p = {
        "router": (jax.random.normal(kr, (D, E)) * s_in).astype(jnp.float32),
        "wi_gate": (jax.random.normal(k1, (E, D, F)) * s_in).astype(dtype),
        "wi_up": (jax.random.normal(k2, (E, D, F)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k3, (E, F, D)) * s_out).astype(dtype),
    }
    if cfg.moe.dense_residual:
        p["dense"] = init_mlp(kd, D, F, dtype)
    return p


MOE_SPECS = {
    "router": ("d_model", None),
    "wi_gate": ("experts", "d_model", "expert_ff"),
    "wi_up": ("experts", "d_model", "expert_ff"),
    "wo": ("experts", "expert_ff", "d_model"),
    "dense": {
        "wi_gate": ("d_model", "ff"),
        "wi_up": ("d_model", "ff"),
        "wo": ("ff", "d_model"),
    },
}


def _capacity(tokens: int, top_k: int, num_experts: int, cf: float) -> int:
    cap = int(math.ceil(top_k * tokens * cf / num_experts))
    return max(4, ((cap + 3) // 4) * 4)  # pad to a multiple of 4


def _model_axis_size(rules: Optional[ShardingRules]) -> int:
    if rules is None or rules.mesh is None:
        return 1
    a = rules.assignment("experts")
    if a is None:
        return 1
    ax = a if isinstance(a, str) else a[0]
    return rules.mesh.shape[ax]


def moe_apply_shard_map(
    p: dict,
    x: jax.Array,  # (B, S, D)
    cfg,
    rules: ShardingRules,
    capacity_factor: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map (the performance path).

    Within a DP group the activations are replicated across the model axis,
    so every model rank already *has* the tokens its local experts need —
    dispatch costs zero communication.  Each rank masks the router output
    to its expert slice, sorts/ranks locally, runs its E/TP experts, and
    the only collective is one psum of the (T_local, D) combine over the
    model axis (plus the FSDP all-gather of the expert weights' d_model
    shards).  This avoids GSPMD's scatter-on-sharded-dim fallback, which
    all-gathers every token (measured: 64 GiB/layer on qwen3-moe).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    B, S, D = x.shape
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    cf = capacity_factor or cfg.moe.capacity_factor
    T = B * S
    model_ax = rules.assignment("experts")
    model_ax = model_ax if isinstance(model_ax, str) else model_ax[0]
    M = mesh.shape[model_ax]
    E_l = E // M
    dp_axes = rules.assignment("act_batch") or ()
    dp_axes = dp_axes if isinstance(dp_axes, tuple) else (dp_axes,)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    T_l = T // dp_size
    C = _capacity(T_l, K, E, cf)

    xt = x.reshape(T, D)
    logits = xt.astype(jnp.float32) @ p["router"]  # (T, E) — plain TP math
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)
    gate_vals = (
        gate_vals / jnp.maximum(gate_vals.sum(axis=-1, keepdims=True), 1e-9)
    ).astype(x.dtype)

    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # two expert-weight regimes (the FSDP-vs-weight-stationary hillclimb):
    #  * d_model FSDP ("d_model"->data): gather the weights' D shards per
    #    layer call — wire = expert-weight bytes / data;
    #  * weight-stationary ("expert_ff"->data): weights never move; token
    #    buffers all-gather over data and partial outputs reduce-scatter
    #    back — wire = token-buffer bytes, ~10-100x smaller for big experts.
    data_ax = rules.assignment("d_model")
    ef_ax = rules.assignment("expert_ff")

    def local(xt_l, eid_l, g_l, wg_l, wu_l, wo_l):
        if data_ax is not None and ef_ax is None:
            wg_l = jax.lax.all_gather(wg_l, data_ax, axis=1, tiled=True)
            wu_l = jax.lax.all_gather(wu_l, data_ax, axis=1, tiled=True)
            wo_l = jax.lax.all_gather(wo_l, data_ax, axis=2, tiled=True)
        m = jax.lax.axis_index(model_ax)
        local_eid = eid_l.reshape(-1) - m * E_l  # (T_l*K,)
        sel = (local_eid >= 0) & (local_eid < E_l)
        flat_e = jnp.where(sel, local_eid, E_l)  # E_l = overflow bucket
        order = jnp.argsort(flat_e)
        se = flat_e[order]
        st = (jnp.arange(T_l * K) // K)[order]
        sg = g_l.reshape(-1)[order]
        sizes = jnp.zeros((E_l + 1,), jnp.int32).at[se].add(1)
        starts = jnp.cumsum(sizes) - sizes
        rank = jnp.arange(T_l * K) - starts[se]
        keep = (rank < C) & (se < E_l)
        slot = jnp.where(keep, se * C + rank, 0)
        vals = jnp.where(keep[:, None], xt_l[st], 0)
        buf = jnp.zeros((E_l * C, xt_l.shape[1]), xt_l.dtype).at[slot].add(vals)
        buf = buf.reshape(E_l, C, -1)
        if ef_ax is not None:
            # weight-stationary: gather every data rank's token buffer,
            # compute this rank's F-slice for all of them, reduce-scatter
            # the partial outputs back to their owners
            buf_all = jax.lax.all_gather(buf, ef_ax)  # (Gd, E_l, C, D)
            h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf_all, wg_l))
            h = h * jnp.einsum("gecd,edf->gecf", buf_all, wu_l)
            ob_part = jnp.einsum("gecf,efd->gecd", h, wo_l)
            ob = jax.lax.psum_scatter(
                ob_part, ef_ax, scatter_dimension=0, tiled=False
            ).reshape(E_l * C, -1)
        else:
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg_l))
            h = h * jnp.einsum("ecd,edf->ecf", buf, wu_l)
            ob = jnp.einsum("ecf,efd->ecd", h, wo_l).reshape(E_l * C, -1)
        contrib = jnp.where(keep[:, None], ob[slot], 0) * sg[:, None]
        y = jnp.zeros_like(xt_l).at[st].add(contrib)
        return jax.lax.psum(y, model_ax)

    dp = dp_axes if dp_axes else None
    if ef_ax is not None:  # weight-stationary expert layout
        w_specs = (
            P(model_ax, None, ef_ax),
            P(model_ax, None, ef_ax),
            P(model_ax, ef_ax, None),
        )
    else:  # FSDP layout: d_model dim sharded over data
        w_specs = (
            P(model_ax, data_ax, None),
            P(model_ax, data_ax, None),
            P(model_ax, None, data_ax),
        )
    y = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(dp, None),  # tokens: replicated over model within the group
            P(dp, None),
            P(dp, None),
        )
        + w_specs,
        out_specs=P(dp, None),
        check_rep=False,
    )(xt, expert_ids, gate_vals, p["wi_gate"], p["wi_up"], p["wo"])
    y = y.reshape(B, S, D)
    y = shard(y, rules, "act_batch", "seq", None)

    if "dense" in p:
        from .layers import swiglu_mlp

        y = y + swiglu_mlp(p["dense"], x, rules)
    return y, aux


def _dp_groups(rules: Optional[ShardingRules]) -> int:
    """Number of data-parallel shards (the dispatch locality granularity)."""
    if rules is None or rules.mesh is None:
        return 1
    a = rules.assignment("batch")
    if a is None:
        return 1
    axes = a if isinstance(a, tuple) else (a,)
    g = 1
    for ax in axes:
        g *= rules.mesh.shape[ax]
    return g


def moe_apply(
    p: dict,
    x: jax.Array,  # (B, S, D)
    cfg,
    rules: Optional[ShardingRules],
    capacity_factor: Optional[float] = None,
    impl: str = "auto",
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balance loss (scalar)).

    impl="auto" picks the shard_map expert-parallel path whenever a mesh
    with a divisible expert axis is available (see moe_apply_shard_map);
    the pure-GSPMD path below is the single-device / fallback
    implementation, with dispatch blocked per DP group so sort/rank stay
    local to each shard."""
    if impl in ("auto", "shard_map") and rules is not None and rules.mesh is not None:
        m = _model_axis_size(rules)
        dp = _dp_groups(rules)
        tokens = x.shape[0] * x.shape[1]
        if m > 1 and cfg.moe.num_experts % m == 0 and tokens % max(dp, 1) == 0:
            return moe_apply_shard_map(p, x, cfg, rules, capacity_factor)
    B, S, D = x.shape
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    cf = capacity_factor or cfg.moe.capacity_factor
    T = B * S
    G = _dp_groups(rules)
    if T % G:
        G = 1
    Tg = T // G
    C = _capacity(Tg, K, E, cf)

    xt = x.reshape(G, Tg, D)
    xt = shard(xt, rules, "act_batch", None, None)
    logits = (xt.astype(jnp.float32)) @ p["router"]  # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (G, Tg, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    # load-balance auxiliary loss (Switch-style), over all tokens
    me = probs.mean(axis=(0, 1))  # (E,)
    ce = (
        jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (T * K)
    )
    aux = E * jnp.sum(me * ce)

    # ---- per-group sorted dispatch ---------------------------------------- #
    TK = Tg * K
    flat_expert = expert_ids.reshape(G, TK)
    flat_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg), K)[None], (G, TK)
    )
    flat_gate = gate_vals.reshape(G, TK)

    order = jnp.argsort(flat_expert, axis=1)
    se = jnp.take_along_axis(flat_expert, order, axis=1)
    st = jnp.take_along_axis(flat_token, order, axis=1)
    sg = jnp.take_along_axis(flat_gate, order, axis=1)
    group_sizes = jnp.zeros((G, E), jnp.int32).at[
        jnp.arange(G)[:, None], se
    ].add(1)
    starts = jnp.cumsum(group_sizes, axis=1) - group_sizes
    rank = jnp.arange(TK)[None, :] - jnp.take_along_axis(starts, se, axis=1)
    keep = rank < C
    # dropped entries are zeroed and added to slot 0 (capacity guarantees
    # no two kept entries collide, so `.add` of zeros is safe) — this keeps
    # the flat buffer exactly E*C wide, which the model axis divides, so
    # the scatter target can be expert-sharded instead of replicated.
    slot = jnp.where(keep, se * C + rank, 0)

    gi = jnp.arange(G)[:, None]
    gathered = jnp.take_along_axis(xt, st[..., None], axis=1).astype(x.dtype)
    gathered = jnp.where(keep[..., None], gathered, 0)
    buf = jnp.zeros((G, E * C, D), x.dtype)
    buf = shard(buf, rules, "act_batch", "experts", None)
    buf = buf.at[gi, slot].add(gathered)
    buf = shard(buf, rules, "act_batch", "experts", None)
    buf = buf.reshape(G, E, C, D)
    # G -> data, E -> model: the reshard here IS the dispatch all-to-all
    buf = shard(buf, rules, "act_batch", "experts", None, None)

    # ---- expert computation ------------------------------------------------ #
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["wi_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["wi_up"])
    h = shard(h, rules, "act_batch", "experts", None, "expert_ff")
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    out_buf = shard(out_buf, rules, "batch", "experts", None, None)

    # ---- combine (all-to-all back) ----------------------------------------- #
    out_flat = out_buf.reshape(G, E * C, D)
    contrib = jnp.take_along_axis(out_flat, slot[..., None], axis=1)
    contrib = jnp.where(keep[..., None], contrib, 0.0) * sg[..., None].astype(
        x.dtype
    )
    y = jnp.zeros((G, Tg, D), x.dtype).at[gi, st].add(contrib)
    y = y.reshape(B, S, D)
    y = shard(y, rules, "act_batch", "seq", None)

    if "dense" in p:  # arctic: parallel dense residual
        from .layers import swiglu_mlp

        y = y + swiglu_mlp(p["dense"], x, rules)
    return y, aux
