"""Causal flash-attention forward kernel (Pallas, TPU).

Design (TPU-native, not a CUDA port):
* inputs are pre-flattened to (BH, S, head_dim) — GQA is resolved in the
  ops wrapper by broadcasting KV heads, so the kernel sees plain MHA;
* 3D grid (BH, q_blocks, kv_blocks); the kv dimension is innermost and
  TPU grids execute sequentially, so the online-softmax running state
  (m, l, acc) lives in VMEM scratch carried across kv steps;
* BlockSpecs stream (blk_q x hd) Q tiles and (blk_k x hd) KV tiles
  HBM->VMEM; with blk_q = blk_k = 512 and hd = 128 the working set is
  ~0.8 MB << 16 MB VMEM, and all matmul dims are multiples of the 128-wide
  MXU;
* fully-masked causal blocks are skipped via pl.when on the block index
  (upper-triangle blocks cost nothing but the grid step).

Validated against ref.flash_attention_ref in interpret mode over
shape/dtype sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["flash_attention_bhsd"]

NEG_INF = np.float32(-1e30)


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, causal, blk_q, blk_k
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # block (qi, ki) is fully masked iff ki*blk_k > qi*blk_q + blk_q - 1
        run = ki * blk_k <= qi * blk_q + blk_q - 1

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # (blk_q, hd)
        k = k_ref[0].astype(jnp.float32)  # (blk_k, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))
        ) * scale  # (blk_q, blk_k)
        if causal:
            rows = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ()))
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _out():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


def flash_attention_bhsd(
    q: jax.Array,  # (BH, S, hd)
    k: jax.Array,  # (BH, T, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    blk_q: int = 512,
    blk_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    BH, S, hd = q.shape
    T = k.shape[1]
    blk_q = min(blk_q, S)
    blk_k = min(blk_k, T)
    assert S % blk_q == 0 and T % blk_k == 0, (S, T, blk_q, blk_k)
    scale = 1.0 / math.sqrt(hd)
    grid = (BH, S // blk_q, T // blk_k)

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, blk_q=blk_q, blk_k=blk_k
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            _vmem((blk_q, 1), jnp.float32),
            _vmem((blk_q, 1), jnp.float32),
            _vmem((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
