"""On-device int8 (+delta) checkpoint quantizer — Pallas, TPU.

This kernel shrinks the paper's C at the source: quantizing shards on-device
before the device->host DMA cuts the transferred bytes ~4x (waste scales as
sqrt(C), Section 3.3).  Blockwise absmax over 256-element blocks, matching
checkpoint/codec.py's host layout exactly (the host decoder reads kernel
output directly).

Grid tiles rows of a (n_blocks, 256) view; each step loads a
(tile x 256) f32 slab (+optional previous-checkpoint slab for delta),
emits int8 codes and f32 scales.  VMEM per step at tile=512:
512 x 256 x 4 B x 2 ~= 1 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quantize_blocks", "dequantize_blocks", "BLOCK"]

BLOCK = 256


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _quant_delta_kernel(x_ref, p_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32) - p_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quantize_blocks(
    x: jax.Array,
    prev: jax.Array | None = None,
    *,
    tile: int = 512,
    interpret: bool = False,
):
    """x: (n_blocks, 256) f32 -> (int8 codes (n_blocks,256), scales (n_blocks,1))."""
    nb = x.shape[0]
    tile = min(tile, nb)
    assert nb % tile == 0 and x.shape[1] == BLOCK
    grid = (nb // tile,)
    out_shape = [
        jax.ShapeDtypeStruct((nb, BLOCK), jnp.int8),
        jax.ShapeDtypeStruct((nb, 1), jnp.float32),
    ]
    out_specs = [
        pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
        pl.BlockSpec((tile, 1), lambda i: (i, 0)),
    ]
    if prev is None:
        return pl.pallas_call(
            _quant_kernel,
            grid=grid,
            in_specs=[pl.BlockSpec((tile, BLOCK), lambda i: (i, 0))],
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(x)
    return pl.pallas_call(
        _quant_delta_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, prev)


def dequantize_blocks(
    q: jax.Array, s: jax.Array, prev: jax.Array | None = None, *, tile: int = 512,
    interpret: bool = False,
):
    nb = q.shape[0]
    tile = min(tile, nb)
    assert nb % tile == 0

    def kern(q_ref, s_ref, o_ref):
        o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]

    def kern_delta(q_ref, s_ref, p_ref, o_ref):
        o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...] + p_ref[
            ...
        ].astype(jnp.float32)

    grid = (nb // tile,)
    out_shape = jax.ShapeDtypeStruct((nb, BLOCK), jnp.float32)
    out_spec = pl.BlockSpec((tile, BLOCK), lambda i: (i, 0))
    if prev is None:
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
                pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            ],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(q, s)
    return pl.pallas_call(
        kern_delta,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile, BLOCK), lambda i: (i, 0)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(q, s, prev)
