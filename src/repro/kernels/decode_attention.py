"""Flash-decode kernel: one query token against a long KV cache (Pallas, TPU).

Decode is bandwidth-bound: the cost is reading the KV cache once.  The
kernel streams (blk_k x hd) cache tiles HBM->VMEM on a sequential grid and
maintains the online-softmax state for the single query row in VMEM
scratch.  Cache positions beyond ``pos`` (the current length) are masked —
``pos`` arrives via scalar prefetch (SMEM), so the same compiled kernel
serves every decode step.

Layout: (BH, hd) query, (BH, S_max, hd) cache, GQA pre-broadcast in ops.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["decode_attention_bhd"]

NEG_INF = np.float32(-1e30)


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, blk_k):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[0]
    # skip tiles entirely beyond the live cache
    @pl.when(ki * blk_k <= pos)
    def _body():
        q = q_ref[...].astype(jnp.float32)  # (1, hd)
        k = k_ref[0].astype(jnp.float32)  # (blk_k, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (1, blk_k)
        cols = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols <= pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ()))
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _out():
        o_ref[...] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


def decode_attention_bhd(
    q: jax.Array,  # (BH, hd)
    k: jax.Array,  # (BH, S_max, hd)
    v: jax.Array,
    pos: jax.Array,  # scalar int32: index of the newest valid cache entry
    *,
    blk_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    BH, S, hd = k.shape
    blk_k = min(blk_k, S)
    assert S % blk_k == 0
    scale = 1.0 / math.sqrt(hd)
    grid = (BH, S // blk_k)
    from jax.experimental.pallas import tpu as pltpu

    kern = functools.partial(_decode_kernel, scale=scale, blk_k=blk_k)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, hd), lambda b, ki, pos_ref: (b, 0)),
                pl.BlockSpec((1, blk_k, hd), lambda b, ki, pos_ref: (b, ki, 0)),
                pl.BlockSpec((1, blk_k, hd), lambda b, ki, pos_ref: (b, ki, 0)),
            ],
            out_specs=pl.BlockSpec((1, hd), lambda b, ki, pos_ref: (b, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((BH, hd), q.dtype),
        interpret=interpret,
    )(pos.reshape(1).astype(jnp.int32), q, k, v)
