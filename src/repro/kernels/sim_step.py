"""Pallas kernel for the batch simulator's masked primitive-update step.

This is the one dense elementwise block the device simulation engine
(:mod:`repro.core.jax_sim`) executes *every* outer iteration: given the
primitive each lane decided to run (work segment / idle segment /
checkpoint), the pre-resolved next-fault date, and the lane state, it

1. applies the fault check (a fault at or before the primitive's target
   interrupts work/idle; a fault strictly before a checkpoint's end date
   aborts it — the exact-date prediction semantics of the scalar oracle),
2. advances the clock and the saved/unsaved/period-work accounting with
   masked updates, and
3. reports the outcome flags (faulted / ok / job finished / checkpoint
   committed / regular checkpoint) packed in one int32 bitfield.

Lane state is laid out as ``(rows, 128)`` float slabs (rows a multiple of
the sublane tile), so the kernel is a pure VPU elementwise pass.  On
non-TPU backends it runs in interpret mode (exact semantics); the pure-jnp
:func:`primitive_update` is both the kernel body and the no-Pallas
fallback, so the two paths are bit-identical by construction.

Primitive codes extend ``repro.core.batch_sim``'s 0 noop / 1 work /
2 idle / 3 checkpoint with 4 = work *not* credited toward the regular
period (the device engine folds the NumPy engine's separate ``credit``
flag into the primitive code — one less lane array per iteration).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "PRIM_NOOP",
    "PRIM_WORK",
    "PRIM_IDLE",
    "PRIM_CKPT",
    "PRIM_WORK_NC",
    "FLAG_FAULTED",
    "FLAG_OK",
    "FLAG_FIN",
    "FLAG_CKPT_OK",
    "FLAG_REG",
    "primitive_update",
    "masked_primitive_update",
]

#: primitive kinds (0-3 shared with repro.core.batch_sim's _PR_* codes;
#: 4 is the device engine's uncredited-work variant of PRIM_WORK)
PRIM_NOOP, PRIM_WORK, PRIM_IDLE, PRIM_CKPT, PRIM_WORK_NC = 0, 1, 2, 3, 4

#: outcome bitfield
FLAG_FAULTED = 1  # a fault interrupted the primitive
FLAG_OK = 2  # primitive completed without fault
FLAG_FIN = 4  # the work segment finished the job
FLAG_CKPT_OK = 8  # a checkpoint committed (saved <- saved + unsaved)
FLAG_REG = 16  # ... and it was a *regular* (period-resetting) checkpoint


def primitive_update(
    prim, cont, target, ckend, nf, t, saved, unsaved, pw, W, DR,
    *, eps: float, reg_cont: int,
):
    """One masked primitive execution; mirrors the NumPy engine's
    execute-one-primitive-per-lane block statement for statement.

    ``target`` must already be capped at job completion and ``ckend``
    fixed from the pre-fault-resolution clock (the caller replicates the
    scalar oracle's order of operations); ``nf`` is each lane's next
    pending fault after stale-fault resolution.  Returns
    ``(t, saved, unsaved, period_work, flags)``.
    """
    creditb = prim == PRIM_WORK
    workm = creditb | (prim == PRIM_WORK_NC)
    idlem = prim == PRIM_IDLE
    ckm = prim == PRIM_CKPT
    res = workm | idlem | ckm

    faulted = ((workm | idlem) & (nf <= target)) | (ckm & (nf < ckend))
    ok = res & ~faulted

    t1 = jnp.where(faulted, nf + DR, t)
    unsaved1 = jnp.where(faulted, 0.0, unsaved)
    pw1 = jnp.where(faulted, 0.0, pw)

    wok = workm & ok
    dt = target - t
    unsaved2 = jnp.where(wok, unsaved1 + dt, unsaved1)
    pw2 = jnp.where(wok & creditb, pw1 + dt, pw1)
    t2 = jnp.where(wok, target, t1)
    fin = wok & (saved + unsaved2 >= W - eps)

    iok = idlem & ok
    t3 = jnp.where(iok, target, t2)

    cok = ckm & ok
    t4 = jnp.where(cok, ckend, t3)
    saved2 = jnp.where(cok, saved + unsaved2, saved)
    unsaved3 = jnp.where(cok, 0.0, unsaved2)
    reg = cok & (cont == reg_cont)
    pw3 = jnp.where(reg, 0.0, pw2)

    flags = (
        faulted.astype(jnp.int32) * FLAG_FAULTED
        + ok.astype(jnp.int32) * FLAG_OK
        + fin.astype(jnp.int32) * FLAG_FIN
        + cok.astype(jnp.int32) * FLAG_CKPT_OK
        + reg.astype(jnp.int32) * FLAG_REG
    )
    return t4, saved2, unsaved3, pw3, flags


def _step_kernel(
    prim_ref, cont_ref, target_ref, ckend_ref, nf_ref,
    t_ref, saved_ref, unsaved_ref, pw_ref, w_ref, dr_ref,
    t_out, saved_out, unsaved_out, pw_out, flags_out,
    *, eps: float, reg_cont: int,
):
    t, saved, unsaved, pw, flags = primitive_update(
        prim_ref[...], cont_ref[...], target_ref[...],
        ckend_ref[...], nf_ref[...], t_ref[...], saved_ref[...],
        unsaved_ref[...], pw_ref[...], w_ref[...], dr_ref[...],
        eps=eps, reg_cont=reg_cont,
    )
    t_out[...] = t
    saved_out[...] = saved
    unsaved_out[...] = unsaved
    pw_out[...] = pw
    flags_out[...] = flags


def masked_primitive_update(
    prim, cont, target, ckend, nf, t, saved, unsaved, pw, W, DR,
    *, eps: float, reg_cont: int, interpret: bool | None = None,
    tile: int = 8,
):
    """Pallas entry point over flat ``(L,)`` lane vectors, L % 128 == 0.

    The lane axis is viewed as ``(L // 128, 128)`` and tiled ``tile`` rows
    per grid step (8 rows = the f32 sublane tile).  ``interpret`` defaults
    to True off-TPU (the repo-wide kernel idiom, see kernels/ops.py).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    L = t.shape[0]
    if L % 128:
        raise ValueError(f"lane count {L} not a multiple of 128")
    rows = L // 128
    if interpret:
        tile = rows  # no VMEM budget to respect: one grid step, no slicing
    tile = max(1, min(tile, rows))
    while rows % tile:
        tile //= 2

    fdt = t.dtype

    def as2d(x, dtype):
        return jnp.asarray(x, dtype).reshape(rows, 128)

    ins = [
        as2d(prim, jnp.int32),
        as2d(cont, jnp.int32),
        as2d(target, fdt),
        as2d(ckend, fdt),
        as2d(nf, fdt),
        as2d(t, fdt),
        as2d(saved, fdt),
        as2d(unsaved, fdt),
        as2d(pw, fdt),
        as2d(W, fdt),
        as2d(DR, fdt),
    ]
    spec = pl.BlockSpec((tile, 128), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((rows, 128), fdt)] * 4 + [
        jax.ShapeDtypeStruct((rows, 128), jnp.int32)
    ]
    outs = pl.pallas_call(
        partial(_step_kernel, eps=eps, reg_cont=reg_cont),
        grid=(rows // tile,),
        in_specs=[spec] * len(ins),
        out_specs=[spec] * len(out_shape),
        out_shape=out_shape,
        # the float lane-state slabs (t/saved/unsaved/pw, inputs 5-8) are
        # loop-carried intermediates: alias them onto the corresponding
        # outputs so the step updates state in place instead of streaming
        # four fresh (rows, 128) buffers per iteration
        input_output_aliases={5: 0, 6: 1, 7: 2, 8: 3},
        interpret=interpret,
    )(*ins)
    return tuple(o.reshape(L) for o in outs)
